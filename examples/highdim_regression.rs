//! High-dimensional regression: the curse of dimensionality, attacked
//! from two directions.
//!
//! ```bash
//! cargo run --release --example highdim_regression [-- scale]
//! ```
//!
//! **Scenario 1 (paper §5):** SKIP vs SGPR on a d = 22 dataset, where
//! KISS-GP's dense Kronecker grid (m²² points) is impossible and SKIP's
//! d-fold product of 1-D grids wins.
//!
//! **Scenario 2 (sparse grids):** grid-based inference *itself* at
//! d = 9, impossible for the dense mᵈ tensor grid, via the
//! combination-technique sparse grid (`GridSpec::Sparse` — Yadav,
//! Sheldon & Musco 2023): train a sparse-grid KISS-GP, freeze it into a
//! serving snapshot, and answer queries from the grid-side stencil
//! caches alone.

use skip_gp::data::{dataset_by_name, generate};
use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant, Sgpr};
use skip_gp::grid::GridSpec;
use skip_gp::linalg::Matrix;
use skip_gp::serve::{ModelSnapshot, SnapshotConfig, VarianceMode};
use skip_gp::solvers::CgConfig;
use skip_gp::util::{mae, Timer};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(0.04);

    // ------------------------------------------------------------------
    // Scenario 1: SKIP vs SGPR at d = 22 (the paper's §5 comparison).
    // ------------------------------------------------------------------
    let spec = dataset_by_name("kegg").expect("kegg registered");
    let data = generate(spec, scale);
    println!(
        "KEGG surrogate: n={} d={} (paper n={})",
        data.n(),
        data.d(),
        spec.n
    );
    println!(
        "KISS-GP here would need m^d = 100^{} ≈ 10^{} grid points — impossible.\n",
        data.d(),
        2 * data.d()
    );

    // SKIP with m = 100 points per dimension.
    let t = Timer::start();
    let mut skip = MvmGp::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        MvmGpConfig { grid: GridSpec::uniform(100), rank: 30, ..Default::default() },
    );
    skip.fit(8, 0.1).expect("skip fit");
    let skip_pred = skip.predict_mean(&data.xtest);
    let skip_mae = mae(&skip_pred, &data.ytest);
    let skip_s = t.elapsed_s();
    println!("SKIP (m=100/dim, r=30): MAE {skip_mae:.4}  train {skip_s:.1}s");

    // SGPR with 200 inducing points covering the full 22-D space.
    let t = Timer::start();
    let mut sgpr = Sgpr::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        200,
        0,
    );
    sgpr.fit(8, 0.1).expect("sgpr fit");
    let sgpr_pred = sgpr.predict_mean(&data.xtest);
    let sgpr_mae = mae(&sgpr_pred, &data.ytest);
    let sgpr_s = t.elapsed_s();
    println!("SGPR (m=200):          MAE {sgpr_mae:.4}  train {sgpr_s:.1}s");

    println!(
        "\nSKIP/SGPR error ratio {:.2}, time ratio {:.2}",
        skip_mae / sgpr_mae,
        skip_s / sgpr_s
    );
    assert!(
        skip_mae < 1.2 * sgpr_mae,
        "SKIP should be competitive: {skip_mae} vs {sgpr_mae}"
    );

    // ------------------------------------------------------------------
    // Scenario 2: sparse-grid KISS-GP at d = 9, where the dense tensor
    // grid is budget-infeasible but the combination technique is cheap.
    // ------------------------------------------------------------------
    let spec9 = dataset_by_name("protein").expect("protein registered");
    let data9 = generate(spec9, (scale * 0.5).min(0.03));
    let d = data9.d();
    let level = 3usize;
    let sparse = GridSpec::sparse(level);
    let dense_cells = 17f64.powi(d as i32); // level-3 resolution, densely
    let sparse_cells = sparse.total_points(d).expect("sparse never overflows");
    println!(
        "\nProtein surrogate: n={} d={d} — dense grid at matching resolution \
         would hold 17^{d} ≈ {dense_cells:.1e} points; the sparse grid stores {sparse_cells}.",
        data9.n()
    );

    // The dense path refuses outright (typed error, not an OOM):
    let dense_gp = MvmGp::new(
        data9.xtrain.clone(),
        data9.ytrain.clone(),
        GpHypers::init_for_dim(d),
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(17),
            ..Default::default()
        },
    );
    let refusal = match dense_gp.build_operator(&dense_gp.hypers, 0) {
        Ok(_) => panic!("dense 17^9 grid must refuse"),
        Err(e) => e,
    };
    println!("dense Kronecker path: {refusal}");

    // The sparse path trains, snapshots, and serves.
    let t = Timer::start();
    let mut gp9 = MvmGp::new(
        data9.xtrain.clone(),
        data9.ytrain.clone(),
        GpHypers::init_for_dim(d),
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: sparse,
            rank: 20,
            cg: CgConfig { max_iters: 60, tol: 1e-5, ..CgConfig::default() },
            ..Default::default()
        },
    );
    gp9.fit(4, 0.1).expect("sparse-grid fit");
    let train9_s = t.elapsed_s();
    let pred9 = gp9.predict_mean(&data9.xtest);
    let mae9 = mae(&pred9, &data9.ytest);
    // Baseline: predict the training mean everywhere.
    let ymean = data9.ytrain.iter().sum::<f64>() / data9.n() as f64;
    let const_pred = vec![ymean; data9.ytest.len()];
    let mae_const = mae(&const_pred, &data9.ytest);
    println!(
        "sparse-grid KISS (level {level}, {} terms, {} points): \
         MAE {mae9:.4} vs constant-predictor {mae_const:.4}, train {train9_s:.1}s",
        gp9.predict_cache().map(|c| c.terms().len()).unwrap_or(0),
        sparse_cells
    );
    assert!(
        mae9 < 0.9 * mae_const,
        "sparse-grid model should beat the constant predictor: {mae9} vs {mae_const}"
    );

    // Freeze → reload → serve from the caches alone.
    let snap = ModelSnapshot::from_mvm(
        &gp9,
        &SnapshotConfig { variance: VarianceMode::Lanczos(32), ..Default::default() },
    )
    .expect("sparse snapshot");
    let bytes = snap.to_bytes();
    let back = ModelSnapshot::from_bytes(&bytes).expect("sparse snapshot reload");
    let q = Matrix::from_fn(64, d, |i, j| data9.xtest.get(i, j));
    let (means, vars) = back.cache.predict(&q);
    assert_eq!(means, snap.cache.predict_mean(&q), "reload must be bitwise identical");
    assert!(vars.iter().all(|v| v.is_finite() && *v > 0.0));
    println!(
        "served 64 queries from the reloaded sparse snapshot \
         ({} bytes, {} grid cells, variance rank {})",
        bytes.len(),
        back.cache.total_grid(),
        back.cache.var_rank()
    );
    println!("highdim_regression OK");
}
