//! High-dimensional regression: SKIP vs SGPR on a d = 22 dataset — the
//! paper's §5 scenario, where KISS-GP's Kronecker grid (m²² points) is
//! impossible and SKIP's d-fold product of 1-D grids wins.
//!
//! ```bash
//! cargo run --release --example highdim_regression [-- scale]
//! ```

use skip_gp::data::{dataset_by_name, generate};
use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, Sgpr};
use skip_gp::util::{mae, Timer};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(0.04);
    let spec = dataset_by_name("kegg").expect("kegg registered");
    let data = generate(spec, scale);
    println!(
        "KEGG surrogate: n={} d={} (paper n={})",
        data.n(),
        data.d(),
        spec.n
    );
    println!(
        "KISS-GP here would need m^d = 100^{} ≈ 10^{} grid points — impossible.\n",
        data.d(),
        2 * data.d()
    );

    // SKIP with m = 100 points per dimension.
    let t = Timer::start();
    let mut skip = MvmGp::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        MvmGpConfig { grid_m: 100, rank: 30, ..Default::default() },
    );
    skip.fit(8, 0.1);
    let skip_pred = skip.predict_mean(&data.xtest);
    let skip_mae = mae(&skip_pred, &data.ytest);
    let skip_s = t.elapsed_s();
    println!("SKIP (m=100/dim, r=30): MAE {skip_mae:.4}  train {skip_s:.1}s");

    // SGPR with 200 inducing points covering the full 22-D space.
    let t = Timer::start();
    let mut sgpr = Sgpr::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        200,
        0,
    );
    sgpr.fit(8, 0.1).expect("sgpr fit");
    let sgpr_pred = sgpr.predict_mean(&data.xtest);
    let sgpr_mae = mae(&sgpr_pred, &data.ytest);
    let sgpr_s = t.elapsed_s();
    println!("SGPR (m=200):          MAE {sgpr_mae:.4}  train {sgpr_s:.1}s");

    println!(
        "\nSKIP/SGPR error ratio {:.2}, time ratio {:.2}",
        skip_mae / sgpr_mae,
        skip_s / sgpr_s
    );
    assert!(
        skip_mae < 1.2 * sgpr_mae,
        "SKIP should be competitive: {skip_mae} vs {sgpr_mae}"
    );
    println!("highdim_regression OK");
}
