//! Serving end to end: train → snapshot → reload → serve a burst.
//!
//! ```bash
//! cargo run --release --example serving
//! ```
//!
//! Trains a SKIP GP on a synthetic surface, freezes it into a model
//! snapshot on disk, reloads the snapshot (no training data needed), and
//! serves a burst of concurrent queries through the request batcher —
//! printing QPS, p50/p99 latency, and the realized batch-size histogram,
//! plus a one-at-a-time baseline for comparison. Finishes with a round
//! trip through the TCP line-protocol server.

use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant};
use skip_gp::grid::GridSpec;
use skip_gp::linalg::Matrix;
use skip_gp::serve::{
    BatcherConfig, ModelSnapshot, RequestBatcher, ServeEngine, Server, ServerConfig,
    SnapshotConfig, VarianceMode,
};
use skip_gp::util::{mae, Rng, Timer};
use std::sync::Arc;
use std::time::Duration;

fn target(x: &[f64]) -> f64 {
    (2.0 * x[0]).sin() + 0.5 * (3.0 * x[1]).cos()
}

/// Push `total` queries through a fresh batcher (4 client threads, each
/// keeping a pipeline of requests outstanding); returns achieved QPS.
fn burst(engine: &Arc<ServeEngine>, cfg: BatcherConfig, total: usize) -> f64 {
    let batcher = RequestBatcher::start(engine.clone(), cfg);
    let clients = 4;
    let per_client = total / clients;
    let t = Timer::start();
    std::thread::scope(|s| {
        for c in 0..clients {
            let handle = batcher.handle();
            s.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                let window = 64;
                let mut pending = std::collections::VecDeque::new();
                for _ in 0..per_client {
                    if pending.len() >= window {
                        let rx: std::sync::mpsc::Receiver<_> = pending.pop_front().unwrap();
                        rx.recv().unwrap();
                    }
                    let q = [rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)];
                    pending.push_back(handle.submit(&q));
                }
                for rx in pending {
                    rx.recv().unwrap();
                }
            });
        }
    });
    let elapsed = t.elapsed_s();
    batcher.shutdown();
    (clients * per_client) as f64 / elapsed
}

fn main() {
    // --- Train a SKIP GP on y = sin(2x₀) + ½cos(3x₁) + ε.
    let mut rng = Rng::new(0);
    let n = 800;
    let xs = Matrix::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> = (0..n)
        .map(|i| target(xs.row(i)) + 0.05 * rng.normal())
        .collect();
    let cfg = MvmGpConfig {
        variant: MvmVariant::Skip,
        grid: GridSpec::uniform(64),
        rank: 25,
        ..Default::default()
    };
    let mut gp = MvmGp::new(xs, ys, GpHypers::init_for_dim(2), cfg);
    let t = Timer::start();
    gp.fit(10, 0.1).expect("training");
    println!("trained 10 ADAM steps in {:.2}s", t.elapsed_s());

    // --- Freeze into a snapshot and write it to disk.
    let t = Timer::start();
    let snap = ModelSnapshot::from_mvm(
        &gp,
        &SnapshotConfig {
            grid: Some(GridSpec::uniform(64)),
            variance: VarianceMode::Lanczos(32),
            ..Default::default()
        },
    )
    .expect("snapshot build");
    let build_s = t.elapsed_s();
    let path = std::env::temp_dir().join(format!("skipgp-serving-{}.snap", std::process::id()));
    snap.save(&path).expect("snapshot save");
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!(
        "snapshot: {} grid cells, variance rank {}, built in {build_s:.2}s, {bytes} bytes",
        snap.cache.total_grid(),
        snap.cache.var_rank()
    );

    // --- Reload (training data no longer needed) and sanity-check.
    let loaded = ModelSnapshot::load(&path).expect("snapshot load");
    std::fs::remove_file(&path).ok();
    let xt = Matrix::from_fn(200, 2, |_, _| rng.uniform_in(-0.9, 0.9));
    let from_disk = loaded.cache.predict_mean(&xt);
    let in_memory = snap.cache.predict_mean(&xt);
    assert_eq!(from_disk, in_memory, "reload must be bitwise identical");
    let truth: Vec<f64> = (0..200).map(|i| target(xt.row(i))).collect();
    let err = mae(&from_disk, &truth);
    println!("reloaded snapshot test MAE vs noiseless target: {err:.4}");
    assert!(err < 0.1, "serving example regression degraded: MAE {err}");

    // --- Serve a burst through the batcher, batched vs one-at-a-time.
    let total = 20_000;
    let engine_batched = Arc::new(ServeEngine::new(loaded.clone()).expect("serve engine"));
    let qps_batched = burst(
        &engine_batched,
        BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(2) },
        total,
    );
    let lat = engine_batched.metrics.latency_snapshot("serve.request");
    println!(
        "batched  (t≤64): {qps_batched:>10.0} QPS   p50 {:>7.1}µs   p99 {:>7.1}µs",
        lat.p50_s * 1e6,
        lat.p99_s * 1e6
    );
    let hist = engine_batched.metrics.value_histogram("serve.batch_size");
    let cells: Vec<String> = hist.iter().map(|(v, c)| format!("{v}×{c}")).collect();
    println!("batch-size histogram: {}", cells.join(" "));

    let engine_single = Arc::new(ServeEngine::new(loaded).expect("serve engine"));
    let qps_single = burst(
        &engine_single,
        BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
        total,
    );
    let lat1 = engine_single.metrics.latency_snapshot("serve.request");
    println!(
        "one-at-a-time :  {qps_single:>10.0} QPS   p50 {:>7.1}µs   p99 {:>7.1}µs",
        lat1.p50_s * 1e6,
        lat1.p99_s * 1e6
    );
    println!("batching speedup: {:.2}x", qps_batched / qps_single);

    // --- And once more over TCP.
    let engine = engine_batched;
    let server = Server::start(
        engine,
        ServerConfig { bind: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
    )
    .expect("server start");
    {
        use std::io::{BufRead, BufReader, Write};
        let stream = std::net::TcpStream::connect(server.addr()).expect("connect");
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(writer, "predict 0.25 -0.5").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        println!("tcp {} → {}", server.addr(), line.trim());
        assert!(line.starts_with("ok "), "tcp response: {line}");
        writeln!(writer, "quit").unwrap();
    }
    server.shutdown();
    println!("serving example OK");
}
