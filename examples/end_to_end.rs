//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! Pipeline exercised:
//!   1. Layer-1/2 artifacts (Pallas kernels → JAX graph → HLO text) are
//!      loaded through PJRT by the Rust runtime (`make artifacts` first).
//!   2. The Layer-3 SKIP GP trains on the Protein surrogate
//!      (n ≈ 1600, d = 9) with the **PJRT backend** serving the
//!      Lemma-3.1 contraction whenever a compatible artifact shape is
//!      registered, falling back to native otherwise.
//!   3. The MLL training curve is logged, predictions are scored, and the
//!      PJRT/native call split is reported — Python never runs here.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Headline metrics (recorded in EXPERIMENTS.md): test MAE vs the SGPR
//! baseline, train time, and PJRT call count > 0.

use skip_gp::coordinator::Session;
use skip_gp::data::{dataset_by_name, generate};
use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, Sgpr};
use skip_gp::grid::GridSpec;
use skip_gp::runtime::PjrtBackend;
use skip_gp::util::{mae, Timer};
use std::path::Path;
use std::sync::Arc;

fn main() {
    let spec = dataset_by_name("protein").expect("protein registered");
    let data = generate(spec, 0.04);
    println!(
        "end-to-end: SKIP GP on protein surrogate (n={}, d={})",
        data.n(),
        data.d()
    );

    // Layer 1+2 → runtime: load AOT artifacts. Hard requirement for this
    // driver — it exists to prove the full stack composes.
    let artifacts = Path::new("artifacts");
    let backend = match PjrtBackend::load(artifacts) {
        Ok(b) => Arc::new(b),
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT runtime up: artifacts loaded from {}", artifacts.display());

    // Layer 3: train with the PJRT contraction backend.
    // n is chosen ≤ 4096 so the hadamard_mvm_n4096_r32 artifact serves the
    // root contraction (larger shapes fall back to native — also fine).
    // Every merge-tree Lanczos iteration routes a Lemma-3.1 contraction
    // through the artifact (~4 ms/call incl. literal upload), so the demo
    // keeps n ≈ 600 and r = 25 to finish in about a minute.
    let cfg = MvmGpConfig {
        grid: GridSpec::uniform(100),
        rank: 25,
        refresh_rank: 80,
        seed: 0,
        ..Default::default()
    };
    let mut gp = MvmGp::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        cfg,
    )
    .with_backend(backend.clone());

    let t = Timer::start();
    let steps = 6;
    let trace = gp.fit(steps, 0.1).expect("training");
    let skip_train_s = t.elapsed_s();
    println!("\nMLL curve ({} ADAM steps):", steps);
    for (i, mll) in trace.iter().enumerate() {
        println!("  step {i:>3}  mll/n = {:+.4}", mll / data.n() as f64);
    }
    let pred = gp.predict_mean(&data.xtest);
    let skip_mae = mae(&pred, &data.ytest);
    let (pjrt_calls, native_calls) = backend.call_counts();
    println!(
        "\nSKIP: MAE {skip_mae:.4}, train {skip_train_s:.1}s, \
         backend calls: {pjrt_calls} pjrt / {native_calls} native"
    );

    // Baseline for the headline comparison.
    let t = Timer::start();
    let mut sgpr = Sgpr::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        200,
        0,
    );
    sgpr.fit(steps, 0.1).expect("sgpr");
    let sgpr_mae = mae(&sgpr.predict_mean(&data.xtest), &data.ytest);
    let sgpr_train_s = t.elapsed_s();
    println!("SGPR(m=200): MAE {sgpr_mae:.4}, train {sgpr_train_s:.1}s");

    // Record the run.
    let mut session = Session::new("end_to_end", Path::new("results")).expect("session");
    session.header(&["method", "n", "d", "mae", "train_s", "pjrt_calls", "native_calls"]);
    session.rowf(&[&"skip_pjrt", &data.n(), &data.d(), &skip_mae, &skip_train_s, &pjrt_calls, &native_calls]);
    session.rowf(&[&"sgpr_m200", &data.n(), &data.d(), &sgpr_mae, &sgpr_train_s, &0, &0]);
    let path = session.finish().expect("csv");
    println!("wrote {}", path.display());

    // The composition claims this driver certifies:
    assert!(pjrt_calls > 0, "PJRT artifact path was never exercised");
    assert!(skip_mae.is_finite() && skip_mae < 0.8, "SKIP failed to learn");
    println!("\nend_to_end OK — all three layers composed");
}
