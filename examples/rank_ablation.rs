//! Ablation: how the Lanczos rank r controls SKIP's end-to-end quality.
//!
//! For a fixed hyperparameter setting on the Elevators surrogate this
//! prints, per rank: the raw MVM relative error of the SKIP operator, the
//! relative error of the CG solve α = K̂⁻¹y against the Cholesky oracle,
//! and the resulting test MAE. It makes the design choice behind
//! `MvmGpConfig::refresh_rank` (and its 14·d scaling) measurable: the
//! solve amplifies operator error by ~the condition number, so prediction
//! needs substantially higher rank than training (paper §7's
//! rank(A∘B) ≤ rank(A)·rank(B) caveat in action).
//!
//! ```bash
//! cargo run --release --example rank_ablation
//! ```

use skip_gp::data::{dataset_by_name, generate};
use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig};
use skip_gp::kernels::ProductKernel;
use skip_gp::linalg::{norm2, Cholesky};
use skip_gp::operators::LinearOp;
use skip_gp::solvers::{cg_solve, CgConfig};
use skip_gp::util::{mae, rel_err, Rng};
fn main() {
    let spec = dataset_by_name("elevators").unwrap();
    let data = generate(spec, 0.06);
    let h = GpHypers::new(2.309, 1.949, 0.2835);
    let kern = ProductKernel::rbf(data.d(), h.ell(), h.sf2());
    let mut khat = kern.gram_sym(&data.xtrain);
    khat.add_diag(h.sn2());
    let chol = Cholesky::new_with_jitter(&khat, 1e-10).unwrap();
    let ae = chol.solve(&data.ytrain);
    let pe = kern.gram(&data.xtest, &data.xtrain).matvec(&ae);
    println!("exact: MAE={:.4} |a|={:.1}", mae(&pe, &data.ytest), norm2(&ae));
    for rank in [100usize, 160, 240] {
        let gp = MvmGp::new(data.xtrain.clone(), data.ytrain.clone(), h,
            MvmGpConfig { grid_m: 100, rank, ..Default::default() });
        let op = gp.build_operator_with_rank(&h, 0, rank);
        let mut rng = Rng::new(1);
        let v = rng.normal_vec(data.n());
        let merr = rel_err(&op.matvec(&v), &khat.matvec(&v));
        let sol = cg_solve(&op, &data.ytrain, CgConfig { max_iters: 300, tol: 1e-7 });
        let p = kern.gram(&data.xtest, &data.xtrain).matvec(&sol.x);
        println!("rank={rank}: mvm_err={merr:.3e} a_err={:.2e} MAE={:.4}",
            rel_err(&sol.x, &ae), mae(&p, &data.ytest));
    }
}
