//! Ablation: how the Lanczos rank r controls SKIP's end-to-end quality —
//! and what each rank *costs* in the Theorem 3.3 accounting.
//!
//! For a fixed hyperparameter setting on the Elevators surrogate this
//! prints, per rank: the raw MVM relative error of the SKIP operator, the
//! relative error of the CG solve α = K̂⁻¹y against the Cholesky oracle,
//! the resulting test MAE, and the build diagnostics from
//! `SkipBuildStats` — `leaf_mvms` (the realized d·r of the theorem's
//! `O(d·r·μ(K⁽ⁱ⁾))` leaf term) plus the achieved leaf/merge ranks, which
//! show whether the rank cap or spectral decay truncated each tree node
//! (the §7 rank(A∘B) ≤ rank(A)·rank(B) caveat in action). It makes the
//! design choice behind `MvmGpConfig::refresh_rank` (and its 14·d
//! scaling) measurable: the solve amplifies operator error by ~the
//! condition number, so prediction needs substantially higher rank than
//! training.
//!
//! ```bash
//! cargo run --release --example rank_ablation
//! ```

use skip_gp::data::{dataset_by_name, generate};
use skip_gp::gp::GpHypers;
use skip_gp::kernels::ProductKernel;
use skip_gp::linalg::{norm2, Cholesky};
use skip_gp::operators::{AffineOp, LinearOp, SkiOp, SkipComponent, SkipOp};
use skip_gp::solvers::{cg_solve, CgConfig};
use skip_gp::util::{mae, mean, rel_err, Rng};

fn main() {
    let spec = dataset_by_name("elevators").unwrap();
    let data = generate(spec, 0.06);
    let h = GpHypers::new(2.309, 1.949, 0.2835);
    let d = data.d();
    let kern = ProductKernel::rbf(d, h.ell(), h.sf2());
    let mut khat = kern.gram_sym(&data.xtrain);
    khat.add_diag(h.sn2());
    let chol = Cholesky::new_with_jitter(&khat, 1e-10).unwrap();
    let ae = chol.solve(&data.ytrain);
    let pe = kern.gram(&data.xtest, &data.xtrain).matvec(&ae);
    println!("exact: MAE={:.4} |a|={:.1}", mae(&pe, &data.ytest), norm2(&ae));
    let comp_kern = ProductKernel::rbf(d, h.ell(), 1.0);
    for rank in [100usize, 160, 240] {
        // Build the SKIP operator directly (rather than through MvmGp) so
        // the merge tree's SkipBuildStats are visible.
        let skis: Vec<SkiOp> = (0..d)
            .map(|k| {
                SkiOp::new(&data.xtrain.col(k), &comp_kern.factors[k], 100)
                    .expect("SKI grid fit")
            })
            .collect();
        let comps: Vec<SkipComponent> = skis
            .iter()
            .map(|s| SkipComponent::Op(s as &dyn LinearOp))
            .collect();
        let mut build_rng = Rng::new(0);
        let skip = SkipOp::build_native(comps, rank, &mut build_rng);
        let stats = skip.stats.clone();
        let op = AffineOp { inner: Box::new(skip), scale: h.sf2(), shift: h.sn2() };
        let mut rng = Rng::new(1);
        let v = rng.normal_vec(data.n());
        let merr = rel_err(&op.matvec(&v), &khat.matvec(&v));
        let cg = CgConfig { max_iters: 300, tol: 1e-7, ..CgConfig::default() };
        let sol = cg_solve(&op, &data.ytrain, cg);
        let p = kern.gram(&data.xtest, &data.xtrain).matvec(&sol.x);
        println!(
            "rank={rank}: mvm_err={merr:.3e} a_err={:.2e} MAE={:.4}",
            rel_err(&sol.x, &ae),
            mae(&p, &data.ytest)
        );
        // Theorem 3.3 cost accounting for this build.
        let leaf_ranks: Vec<f64> = stats.leaf_ranks.iter().map(|&r| r as f64).collect();
        let merge_ranks: Vec<f64> = stats.merge_ranks.iter().map(|&r| r as f64).collect();
        println!(
            "           build: leaf_mvms={} (= realized d*r, worst case {}), \
             mean leaf rank {:.1}, merges={} mean merge rank {:.1}",
            stats.leaf_mvms,
            d * rank,
            mean(&leaf_ranks),
            stats.merge_ranks.len(),
            if merge_ranks.is_empty() { 0.0 } else { mean(&merge_ranks) },
        );
    }
}
