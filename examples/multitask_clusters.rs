//! Multi-task clustering on synthetic childhood-growth data — the paper's
//! §6 application: discover latent developmental subpopulations by Gibbs
//! sampling with SKIP-accelerated marginal likelihoods, then extrapolate
//! a child's growth from a handful of early measurements.
//!
//! ```bash
//! cargo run --release --example multitask_clusters
//! ```

use skip_gp::data::growth::{generate, split_child, GrowthConfig};
use skip_gp::gp::{ClusterMtgp, ClusterMtgpConfig};
use skip_gp::util::{mae, Timer};

fn main() {
    let growth = generate(&GrowthConfig {
        num_children: 24,
        num_clusters: 3,
        min_obs: 8,
        max_obs: 16,
        seed: 7,
        ..Default::default()
    });
    println!(
        "{} children, {} observations, 3 latent subpopulations",
        growth.data.num_tasks,
        growth.data.len()
    );

    let mut model = ClusterMtgp::new(
        growth.data.clone(),
        ClusterMtgpConfig { num_clusters: 3, use_skip: true, seed: 7, ..Default::default() },
    );
    let t = Timer::start();
    let changes = model.run_gibbs(6);
    println!(
        "Gibbs (SKIP-accelerated MLLs): 6 sweeps in {:.1}s, changes per sweep {:?}",
        t.elapsed_s(),
        changes
    );

    // Pairwise agreement with the generator's true clusters
    // (label-permutation invariant).
    let s = growth.data.num_tasks;
    let mut agree = 0usize;
    let mut total = 0usize;
    for i in 0..s {
        for j in (i + 1)..s {
            total += 1;
            let same_model = model.assignments[i] == model.assignments[j];
            let same_true = growth.true_cluster[i] == growth.true_cluster[j];
            if same_model == same_true {
                agree += 1;
            }
        }
    }
    let agreement = agree as f64 / total as f64;
    println!("cluster recovery (pairwise agreement): {:.1}%", 100.0 * agreement);

    // Extrapolate child 0's growth from its first 4 measurements.
    let child = 0usize;
    let (_, _, tail_x, tail_y) = split_child(&growth.data, child, 4);
    if !tail_x.is_empty() {
        let pred = model
            .predict_mean(&tail_x, &vec![child; tail_x.len()])
            .expect("predict");
        println!(
            "extrapolation MAE for child 0 ({} future points): {:.4}",
            tail_x.len(),
            mae(&pred, &tail_y)
        );
    }
    // Posterior over child 0's subpopulation.
    let post = model.cluster_posterior(child, 99);
    println!(
        "cluster posterior for child 0: {:?} (true {})",
        post.iter().map(|p| format!("{p:.2}")).collect::<Vec<_>>(),
        growth.true_cluster[child]
    );
    assert!(agreement > 0.7, "clustering degraded: {agreement}");
    println!("multitask_clusters OK");
}
