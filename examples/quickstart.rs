//! Quickstart: fit a SKIP GP to a 2-D toy function in a few seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the minimal public-API path: generate data → configure
//! `MvmGp` with the SKIP operator → train hyperparameters with ADAM →
//! predict and score.

use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant};
use skip_gp::grid::GridSpec;
use skip_gp::linalg::Matrix;
use skip_gp::util::{mae, Rng, Timer};

fn target(x: &[f64]) -> f64 {
    (2.0 * x[0]).sin() + 0.5 * (3.0 * x[1]).cos()
}

fn main() {
    let mut rng = Rng::new(0);
    let n = 600;
    // Training data: y = sin(2x₀) + ½cos(3x₁) + ε on [-1, 1]².
    let xs = Matrix::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> = (0..n)
        .map(|i| target(xs.row(i)) + 0.05 * rng.normal())
        .collect();
    let xtest = Matrix::from_fn(200, 2, |_, _| rng.uniform_in(-0.95, 0.95));
    let ytest: Vec<f64> = (0..200).map(|i| target(xtest.row(i))).collect();

    // SKIP: each input dimension gets a 1-D SKI kernel on a 64-point
    // grid; the product is handled by the Lanczos merge tree.
    let cfg = MvmGpConfig {
        variant: MvmVariant::Skip,
        grid: GridSpec::uniform(64),
        rank: 25,
        ..Default::default()
    };
    let mut gp = MvmGp::new(xs, ys, GpHypers::init_for_dim(2), cfg);

    let t = Timer::start();
    let trace = gp.fit(12, 0.1).expect("training");
    println!("trained 12 ADAM steps in {:.2}s", t.elapsed_s());
    println!(
        "  marginal log likelihood per point: {:.3} → {:.3}",
        trace.first().unwrap() / 600.0,
        trace.last().unwrap() / 600.0
    );
    println!(
        "  learned hypers: ell={:.3} sf2={:.3} sn2={:.4}",
        gp.hypers.ell(),
        gp.hypers.sf2(),
        gp.hypers.sn2()
    );

    let pred = gp.predict_mean(&xtest);
    let err = mae(&pred, &ytest);
    println!("test MAE on the noiseless target: {err:.4}");
    assert!(err < 0.1, "quickstart regression degraded: MAE {err}");
    println!("quickstart OK");
}
