//! Closed-loop Bayesian optimization on a D-SKI surrogate.
//!
//! ```bash
//! cargo run --release --example bayes_opt
//! ```
//!
//! Demonstrates the derivative-observation path end to end: every
//! objective evaluation returns `(y, ∇y)`, the surrogate is a KISS-GP
//! with gradient stencil rows (`MvmGp::new_with_grads`, Eriksson et al.
//! 2018), and each loop iteration streams the new `(y, ∇y)` pair into a
//! live [`IncrementalState`] with a warm-started re-solve — no refit.
//! The acquisition is expected improvement over a random candidate set,
//! with the predictive mean and solver-grade variance served by the
//! same live state.

use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant};
use skip_gp::grid::GridSpec;
use skip_gp::linalg::Matrix;
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::{Rng, Timer};

/// Objective: two Gaussian bumps on [-1, 1]², global maximum ≈ 1 at
/// (0.3, -0.2). Returns the value and its analytic gradient — the
/// "derivative observations come for free" setting D-SKI targets
/// (adjoint solvers, automatic differentiation, physical sensors).
fn objective(x: &[f64]) -> (f64, Vec<f64>) {
    let bump = |cx: f64, cy: f64, w: f64| {
        let (dx, dy) = (x[0] - cx, x[1] - cy);
        let v = (-w * (dx * dx + dy * dy)).exp();
        (v, -2.0 * w * dx * v, -2.0 * w * dy * v)
    };
    let (v1, g1x, g1y) = bump(0.3, -0.2, 4.0);
    let (v2, g2x, g2y) = bump(-0.6, 0.6, 6.0);
    (v1 + 0.6 * v2, vec![g1x + 0.6 * g2x, g1y + 0.6 * g2y])
}

/// Standard normal pdf.
fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF via the Abramowitz–Stegun erf polynomial
/// (|error| < 1.5e-7 — far below acquisition noise).
fn normal_cdf(z: f64) -> f64 {
    let t = 1.0 / (1.0 + 0.2316419 * z.abs());
    let poly = t
        * (0.319381530
            + t * (-0.356563782 + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
    let tail = normal_pdf(z) * poly;
    if z >= 0.0 {
        1.0 - tail
    } else {
        tail
    }
}

/// Expected improvement (maximization) of a Gaussian `N(mean, var)` over
/// the incumbent `best`.
fn expected_improvement(mean: f64, var: f64, best: f64) -> f64 {
    let sigma = var.max(1e-18).sqrt();
    let z = (mean - best) / sigma;
    (mean - best) * normal_cdf(z) + sigma * normal_pdf(z)
}

fn main() {
    let mut rng = Rng::new(7);
    let d = 2;

    // Seed design: a handful of random evaluations, each contributing
    // its value AND its gradient (1 + d rows of the extended operator).
    let n0 = 12;
    let xs = Matrix::from_fn(n0, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let mut ys = Vec::with_capacity(n0);
    let mut grads = Matrix::zeros(n0, d);
    for i in 0..n0 {
        let (y, g) = objective(xs.row(i));
        ys.push(y);
        grads.row_mut(i).copy_from_slice(&g);
    }
    let seed_best = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

    // D-SKI surrogate: KISS on a dense grid (the gradient rows
    // differentiate the tensor-product W — SKIP has no such W), then a
    // live streaming state so loop iterations ingest instead of refit.
    let cfg = MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid: GridSpec::uniform(32),
        cg: skip_gp::solvers::CgConfig { max_iters: 400, tol: 1e-8, ..Default::default() },
        ..Default::default()
    };
    let gp = MvmGp::new_with_grads(xs, ys.clone(), grads, GpHypers::new(0.35, 1.0, 1e-4), cfg)
        .expect("D-SKI surrogate");
    let mut state =
        IncrementalState::from_mvm(&gp, StreamConfig::default()).expect("live state");

    let mut best_y = seed_best;
    let mut best_x = vec![0.0; d];
    let iterations = 15;
    let candidates = 256;
    let t = Timer::start();
    for it in 0..iterations {
        // Acquisition: EI over a fresh random candidate set, scored from
        // the live surrogate's mean and solver-grade variance.
        let cand = Matrix::from_fn(candidates, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let means = state.predict_mean(&cand);
        let vars = state.predict_var(&cand).expect("single-task variance");
        let (mut pick, mut pick_ei) = (0, f64::NEG_INFINITY);
        for i in 0..candidates {
            let ei = expected_improvement(means[i], vars[i], best_y);
            if ei > pick_ei {
                pick = i;
                pick_ei = ei;
            }
        }

        // Evaluate the objective and stream `(y, ∇y)` into the model —
        // one warm-started re-solve, the serving path's `observe … grad …`.
        let x = cand.row(pick).to_vec();
        let (y, g) = objective(&x);
        let report = state.ingest_with_grad(&x, y, &g).expect("ingest");
        if y > best_y {
            best_y = y;
            best_x = x.clone();
        }
        println!(
            "iter {it:2}: evaluated ({:+.3}, {:+.3}) → y={y:+.4} (EI {pick_ei:.2e}, \
             {} CG iters{}) best={best_y:+.4}",
            x[0],
            x[1],
            report.solve_iters,
            if report.refreshed.is_some() { ", refreshed" } else { "" },
        );
    }

    // Near the optimum the surrogate's own mean-gradient should vanish —
    // the same derivative stencils that ingest ∇y also differentiate μ.
    let q = Matrix::from_vec(1, d, best_x.clone());
    let gmu = state.predict_grad(&q);
    let gnorm = (gmu.row(0)[0].powi(2) + gmu.row(0)[1].powi(2)).sqrt();

    println!(
        "\nBO loop: {iterations} evaluations in {:.2}s ({} gradient points in the model)",
        t.elapsed_s(),
        state.num_grad_points(),
    );
    println!(
        "seed best {seed_best:+.4} → final best {best_y:+.4} at ({:+.3}, {:+.3}), \
         ‖∇μ‖ there = {gnorm:.3}",
        best_x[0], best_x[1]
    );
    assert!(
        best_y >= seed_best,
        "BO must never regress below its seed incumbent"
    );
    assert!(
        best_y > 0.8,
        "BO with derivative observations should approach the global max ≈ 1 \
         (got {best_y})"
    );
    println!("bayes_opt OK");
}
