#!/usr/bin/env python3
"""Generate the checked-in historical snapshot fixtures.

The migration tests in rust/tests/serve_roundtrip.rs pin every snapshot
format version this build still reads against a byte-exact fixture file.
The v1/v2 fixtures predate this script; it generates the v3 and v4 ones
(rust/tests/fixtures/snapshot_v3.bin, snapshot_v4.bin) from the layouts
documented in rust/src/serve/snapshot.rs:

  v4 = v5 without the multi-task payload: pending entries carry no task
       field and there is no trailing task-section flag.
  v3 = v4 without the u32 alpha_space field (after refresh_rank).

Every float in the payloads is an exact binary fraction, so the Rust
tests can assert field values and predictions bitwise. Deterministic:
re-running reproduces identical bytes.

Run from the repo root:  python3 tools/make_snapshot_fixtures.py
"""

import struct
from pathlib import Path

MAGIC = b"SKGPSNAP"
FIXTURES = Path(__file__).resolve().parent.parent / "rust" / "tests" / "fixtures"


def fnv1a(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x00000100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def f64(v):
    return struct.pack("<d", v)


def body(version, *, variant, train_rank, refresh_rank, alpha_space, sizes,
         axes, alpha, pending):
    """Common v3/v4 layout; alpha_space=None omits the field (v3)."""
    d = len(sizes)
    n = len(alpha)
    r = 2
    out = bytearray()
    out += MAGIC
    out += u32(version)
    out += u32(d)
    out += u32(n)
    out += u32(r)
    out += u32(variant)
    out += u32(train_rank)
    out += u32(refresh_rank)
    if alpha_space is not None:
        out += u32(alpha_space)
    # hypers: log ell, log sf2, log sn2 — all exact binary fractions.
    out += f64(-0.25) + f64(0.125) + f64(-3.0)
    # Rectilinear spec.
    out += u32(1)
    for m in sizes:
        out += u32(m)
    # One term, coefficient 1.
    out += u32(1)
    out += f64(1.0)
    for (mn, h, m) in axes:
        out += f64(mn) + f64(h) + u32(m)
    for a in alpha:
        out += f64(a)
    m_total = 1
    for m in sizes:
        m_total *= m
    for i in range(m_total):
        out += f64(i * 0.015625 - 0.5)
    for i in range(m_total * r):
        out += f64((i % 17) * 0.03125 - 0.25)
    out += u32(len(pending))
    for (seq, x, y) in pending:
        out += u64(seq)
        for v in x:
            out += f64(v)
        out += f64(y)
    out += u64(fnv1a(bytes(out)))
    return bytes(out)


def main():
    FIXTURES.mkdir(parents=True, exist_ok=True)

    # v3: d=2, n=6, r=2, SKIP variant, no alpha_space field, one pending
    # observation. Grids 10 x 9 starting at exact fractions.
    v3 = body(
        3,
        variant=0,
        train_rank=9,
        refresh_rank=15,
        alpha_space=None,
        sizes=[10, 9],
        axes=[(-1.25, 0.25, 10), (-0.5, 0.125, 9)],
        alpha=[0.25 * i - 0.5 for i in range(6)],
        pending=[(7, [0.5, -0.25], 2.25)],
    )
    (FIXTURES / "snapshot_v3.bin").write_bytes(v3)
    print(f"wrote snapshot_v3.bin ({len(v3)} bytes)")

    # v4: d=2, n=7, r=2, KISS variant, grid-space alpha provenance
    # (alpha_space=1 — the field v4 introduced), two pending
    # observations. Grids 11 x 7.
    v4 = body(
        4,
        variant=1,
        train_rank=11,
        refresh_rank=13,
        alpha_space=1,
        sizes=[11, 7],
        axes=[(-1.25, 0.25, 11), (-0.5, 0.125, 7)],
        alpha=[0.25 * i - 0.75 for i in range(7)],
        pending=[(2, [0.25, -0.375], 1.5), (5, [-1.0, 0.125], -0.75)],
    )
    (FIXTURES / "snapshot_v4.bin").write_bytes(v4)
    print(f"wrote snapshot_v4.bin ({len(v4)} bytes)")


if __name__ == "__main__":
    main()
