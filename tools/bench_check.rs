//! CI bench-regression gate: compare the current `results/BENCH_*.json`
//! perf records against checked-in baselines and fail on regression.
//!
//! ```text
//! bench_check [--baseline DIR] [--current DIR] [--tol F]
//!             (defaults: results/baselines, results, 0.20)
//! ```
//!
//! For every `BENCH_*.json` in the baseline directory, the current
//! directory must contain a record of the same name. Both are parsed
//! (hand-rolled reader — no serde offline) and flattened to
//! dotted-path numeric fields; a field is **gated** only when it is
//! present in *both* records and its name marks it perf-relevant:
//!
//! - higher-is-better: name contains `speedup`, `ratio`, or `qps` —
//!   regression when `current < baseline·(1 − tol)`;
//! - lower-is-better: name ends in `_us`, `_ms`, `_s`, or `_iters`, or
//!   contains `latency` — regression when `current > baseline·(1 + tol)`;
//! - two-sided band (checked first, by exact field name — see
//!   `BAND_FIELDS`): scaling ratios asserting flatness, e.g.
//!   `per_iter_us_ratio_1e6_vs_1e4` — regression when the current value
//!   leaves `baseline ± 10%` in *either* direction.
//!
//! Everything else (counts, sizes, flags) is informational. Baselines
//! therefore control exposure: checking in a baseline with only the
//! machine-portable ratio fields gates exactly those, and raw-latency
//! baselines can be seeded later from CI's own uploaded artifacts. A
//! gated field *missing from the current record* fails too — silently
//! dropping a tracked number is how regressions hide.
//!
//! Exit status: 0 clean, 1 regression (with a readable per-field diff
//! in the step log), 2 usage/IO error.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Minimal JSON value (subset sufficient for the bench records).
#[derive(Clone, Debug, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { s: s.as_bytes(), i: 0 }
    }

    fn err(&self, msg: &str) -> String {
        format!("JSON parse error at byte {}: {msg}", self.i)
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && (self.s[self.i] as char).is_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        self.skip_ws();
        if self.s.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        self.skip_ws();
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.s[start..self.i])
            .map_err(|_| self.err("bad number bytes"))?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while let Some(&c) = self.s.get(self.i) {
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.s.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        other => {
                            return Err(self.err(&format!(
                                "unsupported escape '\\{}'",
                                other as char
                            )))
                        }
                    }
                }
                other => out.push(other as char),
            }
        }
        Err(self.err("unterminated string"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            fields.push((key, self.value()?));
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser::new(text);
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.s.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

/// Flatten to dotted-path → numeric value (arrays as `path[i]`).
fn flatten(v: &Json, prefix: &str, out: &mut BTreeMap<String, f64>) {
    match v {
        Json::Num(x) => {
            out.insert(prefix.to_string(), *x);
        }
        Json::Obj(fields) => {
            for (k, child) in fields {
                let p = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                flatten(child, &p, out);
            }
        }
        Json::Arr(items) => {
            for (i, child) in items.iter().enumerate() {
                flatten(child, &format!("{prefix}[{i}]"), out);
            }
        }
        _ => {}
    }
}

/// How a flattened field is gated, from its final path segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Gate {
    HigherIsBetter,
    LowerIsBetter,
    /// Two-sided: must stay within ±[`BAND`] of the baseline. For
    /// scaling ratios that assert *flatness* — drifting below the band
    /// is as suspicious as growing above it (it usually means the
    /// measurement broke, not that the code got faster).
    Band,
    Ignored,
}

/// Fields gated two-sided (checked before the generic name rules, which
/// would otherwise classify a `ratio` as higher-is-better).
const BAND_FIELDS: &[&str] = &["per_iter_us_ratio_1e6_vs_1e4"];
/// Half-width of the [`Gate::Band`] acceptance window.
const BAND: f64 = 0.10;

fn classify(path: &str) -> Gate {
    // Last dotted segment, with any array index stripped.
    let last = path.rsplit('.').next().unwrap_or(path);
    let last = last.split('[').next().unwrap_or(last).to_ascii_lowercase();
    if BAND_FIELDS.contains(&last.as_str()) {
        return Gate::Band;
    }
    if last.contains("speedup") || last.contains("ratio") || last.contains("qps") {
        return Gate::HigherIsBetter;
    }
    if last.ends_with("_us")
        || last.ends_with("_ms")
        || last.ends_with("_s")
        || last.ends_with("_iters")
        || last.contains("latency")
    {
        return Gate::LowerIsBetter;
    }
    Gate::Ignored
}

/// One field-level verdict.
#[derive(Clone, Debug)]
struct Finding {
    path: String,
    baseline: f64,
    current: Option<f64>,
    gate: Gate,
    regressed: bool,
}

/// Compare one baseline record against the matching current record.
fn compare_records(baseline: &Json, current: &Json, tol: f64) -> Vec<Finding> {
    let mut base_fields = BTreeMap::new();
    let mut cur_fields = BTreeMap::new();
    flatten(baseline, "", &mut base_fields);
    flatten(current, "", &mut cur_fields);
    let mut findings = Vec::new();
    for (path, &b) in &base_fields {
        let gate = classify(path);
        if gate == Gate::Ignored {
            continue;
        }
        match cur_fields.get(path) {
            None => findings.push(Finding {
                path: path.clone(),
                baseline: b,
                current: None,
                gate,
                regressed: true, // a tracked field vanished
            }),
            Some(&c) => {
                let regressed = match gate {
                    Gate::HigherIsBetter => c < b * (1.0 - tol),
                    Gate::LowerIsBetter => c > b * (1.0 + tol),
                    Gate::Band => (c - b).abs() > b.abs() * BAND,
                    Gate::Ignored => false,
                };
                findings.push(Finding {
                    path: path.clone(),
                    baseline: b,
                    current: Some(c),
                    gate,
                    regressed,
                });
            }
        }
    }
    findings
}

fn load_json(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// Run the gate over two directories. Returns (report, any_regression).
fn check_dirs(baseline_dir: &Path, current_dir: &Path, tol: f64) -> Result<(String, bool), String> {
    let mut names: Vec<String> = std::fs::read_dir(baseline_dir)
        .map_err(|e| format!("cannot list {}: {e}", baseline_dir.display()))?
        .filter_map(|entry| {
            let name = entry.ok()?.file_name().to_string_lossy().into_owned();
            (name.starts_with("BENCH_") && name.ends_with(".json")).then_some(name)
        })
        .collect();
    names.sort();
    if names.is_empty() {
        return Err(format!(
            "no BENCH_*.json baselines in {}",
            baseline_dir.display()
        ));
    }

    let mut out = String::new();
    let mut any_regression = false;
    for name in &names {
        let base = load_json(&baseline_dir.join(name))?;
        let cur_path = current_dir.join(name);
        if !cur_path.exists() {
            any_regression = true;
            out.push_str(&format!(
                "{name}: MISSING current record {} — did the bench stop emitting it?\n",
                cur_path.display()
            ));
            continue;
        }
        let cur = load_json(&cur_path)?;
        let findings = compare_records(&base, &cur, tol);
        if findings.is_empty() {
            out.push_str(&format!("{name}: no gated fields in baseline (informational only)\n"));
            continue;
        }
        out.push_str(&format!("{name}:\n"));
        for f in &findings {
            let arrow = match f.gate {
                Gate::HigherIsBetter => "≥",
                Gate::LowerIsBetter => "≤",
                Gate::Band => "≈",
                Gate::Ignored => "·",
            };
            let shown_tol = if f.gate == Gate::Band { BAND } else { tol };
            match f.current {
                None => {
                    out.push_str(&format!(
                        "  FAIL {path:<40} baseline {b:>12.3} → (field missing)\n",
                        path = f.path,
                        b = f.baseline
                    ));
                }
                Some(c) => {
                    let delta = if f.baseline != 0.0 {
                        (c - f.baseline) / f.baseline * 100.0
                    } else {
                        0.0
                    };
                    let verdict = if f.regressed { "FAIL" } else { "ok  " };
                    out.push_str(&format!(
                        "  {verdict} {path:<40} baseline {b:>12.3} {arrow} current {c:>12.3} \
                         ({delta:+.1}%, tol ±{t:.0}%)\n",
                        path = f.path,
                        b = f.baseline,
                        t = shown_tol * 100.0
                    ));
                }
            }
            any_regression |= f.regressed;
        }
    }

    // Current records with no baseline are future gates, not failures.
    if let Ok(entries) = std::fs::read_dir(current_dir) {
        let mut extra: Vec<String> = entries
            .filter_map(|entry| {
                let name = entry.ok()?.file_name().to_string_lossy().into_owned();
                (name.starts_with("BENCH_")
                    && name.ends_with(".json")
                    && !names.contains(&name))
                .then_some(name)
            })
            .collect();
        extra.sort();
        for name in extra {
            out.push_str(&format!(
                "{name}: no baseline — seed one in {} to start gating it\n",
                baseline_dir.display()
            ));
        }
    }
    Ok((out, any_regression))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut baseline_dir = PathBuf::from("results/baselines");
    let mut current_dir = PathBuf::from("results");
    let mut tol = 0.20f64;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--baseline" if i + 1 < args.len() => {
                baseline_dir = PathBuf::from(&args[i + 1]);
                i += 2;
            }
            "--current" if i + 1 < args.len() => {
                current_dir = PathBuf::from(&args[i + 1]);
                i += 2;
            }
            "--tol" if i + 1 < args.len() => {
                tol = match args[i + 1].parse() {
                    Ok(v) => v,
                    Err(_) => {
                        eprintln!("bad --tol value '{}'", args[i + 1]);
                        return ExitCode::from(2);
                    }
                };
                i += 2;
            }
            other => {
                eprintln!(
                    "unknown argument '{other}'\n\
                     usage: bench_check [--baseline DIR] [--current DIR] [--tol F]"
                );
                return ExitCode::from(2);
            }
        }
    }

    match check_dirs(&baseline_dir, &current_dir, tol) {
        Err(e) => {
            eprintln!("bench_check: {e}");
            ExitCode::from(2)
        }
        Ok((report, regressed)) => {
            print!("{report}");
            if regressed {
                eprintln!("bench_check: PERF REGRESSION (tolerance ±{:.0}%)", tol * 100.0);
                ExitCode::from(1)
            } else {
                println!("bench_check: all gated fields within ±{:.0}%", tol * 100.0);
                ExitCode::SUCCESS
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "skipgp-benchcheck-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    const BASELINE: &str = r#"{
  "bench": "stream",
  "speedup_single_vs_refresh": 6.0,
  "ingest_p50_us": 500.0,
  "warm_iters_p50": 10,
  "n": 4096
}"#;

    #[test]
    fn parses_and_flattens_nested_records() {
        let v = parse(
            r#"{"a": {"b": 1.5, "qps": 10.0}, "cases": [{"mvm_s": 0.25}], "tag": "x"}"#,
        )
        .unwrap();
        let mut flat = BTreeMap::new();
        flatten(&v, "", &mut flat);
        assert_eq!(flat.get("a.b"), Some(&1.5));
        assert_eq!(flat.get("a.qps"), Some(&10.0));
        assert_eq!(flat.get("cases[0].mvm_s"), Some(&0.25));
        assert!(!flat.contains_key("tag"));
    }

    #[test]
    fn classification_by_field_name() {
        assert_eq!(classify("speedup_single_vs_refresh"), Gate::HigherIsBetter);
        assert_eq!(classify("one_at_a_time.qps"), Gate::HigherIsBetter);
        assert_eq!(classify("iters_ratio"), Gate::HigherIsBetter);
        // Band fields are matched before the generic "ratio" rule.
        assert_eq!(classify("per_iter_us_ratio_1e6_vs_1e4"), Gate::Band);
        assert_eq!(
            classify("scaling.per_iter_us_ratio_1e6_vs_1e4"),
            Gate::Band
        );
        assert_eq!(classify("ingest_p50_us"), Gate::LowerIsBetter);
        assert_eq!(classify("refresh_ms"), Gate::LowerIsBetter);
        assert_eq!(classify("cache_build_s"), Gate::LowerIsBetter);
        assert_eq!(classify("warm_iters_p50"), Gate::Ignored);
        assert_eq!(classify("cases[0].points"), Gate::Ignored);
        assert_eq!(classify("n"), Gate::Ignored);
    }

    #[test]
    fn within_tolerance_passes() {
        let base = parse(BASELINE).unwrap();
        // 10% slower ingest, 10% lower speedup: inside ±20%.
        let cur = parse(
            r#"{"bench": "stream", "speedup_single_vs_refresh": 5.4,
                "ingest_p50_us": 550.0, "warm_iters_p50": 12, "n": 4096}"#,
        )
        .unwrap();
        let findings = compare_records(&base, &cur, 0.20);
        assert!(findings.iter().all(|f| !f.regressed), "{findings:?}");
        // Improvements pass too, by any margin.
        let better = parse(
            r#"{"bench": "stream", "speedup_single_vs_refresh": 60.0,
                "ingest_p50_us": 5.0, "warm_iters_p50": 1, "n": 4096}"#,
        )
        .unwrap();
        let findings = compare_records(&base, &better, 0.20);
        assert!(findings.iter().all(|f| !f.regressed), "{findings:?}");
    }

    /// Acceptance: a doctored record outside tolerance is rejected.
    #[test]
    fn doctored_record_outside_tolerance_is_rejected() {
        let base = parse(BASELINE).unwrap();
        // Speedup collapsed 6.0 → 2.0: a real regression.
        let doctored = parse(
            r#"{"bench": "stream", "speedup_single_vs_refresh": 2.0,
                "ingest_p50_us": 500.0, "warm_iters_p50": 10, "n": 4096}"#,
        )
        .unwrap();
        let findings = compare_records(&base, &doctored, 0.20);
        let bad: Vec<_> = findings.iter().filter(|f| f.regressed).collect();
        assert_eq!(bad.len(), 1, "{findings:?}");
        assert_eq!(bad[0].path, "speedup_single_vs_refresh");

        // Latency blown past tolerance regresses too.
        let slow = parse(
            r#"{"bench": "stream", "speedup_single_vs_refresh": 6.0,
                "ingest_p50_us": 2500.0, "warm_iters_p50": 10, "n": 4096}"#,
        )
        .unwrap();
        let findings = compare_records(&base, &slow, 0.20);
        assert!(
            findings.iter().any(|f| f.regressed && f.path == "ingest_p50_us"),
            "{findings:?}"
        );

        // A tracked field silently vanishing is a failure, not a skip.
        let dropped = parse(r#"{"bench": "stream", "ingest_p50_us": 500.0}"#).unwrap();
        let findings = compare_records(&base, &dropped, 0.20);
        assert!(
            findings
                .iter()
                .any(|f| f.regressed && f.current.is_none()),
            "{findings:?}"
        );
    }

    /// The flatness band is two-sided: both growth above and collapse
    /// below baseline ± 10% regress, while drift inside the band passes
    /// regardless of the (wider) one-sided --tol.
    #[test]
    fn band_field_gates_both_directions() {
        let base =
            parse(r#"{"bench": "gridspace", "per_iter_us_ratio_1e6_vs_1e4": 1.0}"#)
                .unwrap();
        let inside =
            parse(r#"{"bench": "gridspace", "per_iter_us_ratio_1e6_vs_1e4": 1.08}"#)
                .unwrap();
        let findings = compare_records(&base, &inside, 0.20);
        assert!(findings.iter().all(|f| !f.regressed), "{findings:?}");

        // 1.25× per-iteration growth: scaling is no longer flat, even
        // though a generic "ratio" field would pass (higher is better).
        let above =
            parse(r#"{"bench": "gridspace", "per_iter_us_ratio_1e6_vs_1e4": 1.25}"#)
                .unwrap();
        let findings = compare_records(&base, &above, 0.20);
        assert!(
            findings
                .iter()
                .any(|f| f.regressed && f.path == "per_iter_us_ratio_1e6_vs_1e4"),
            "{findings:?}"
        );

        // A collapse below the band fails too — it means the measurement
        // broke, not that an O(m log m) iteration got 30% cheaper.
        let below =
            parse(r#"{"bench": "gridspace", "per_iter_us_ratio_1e6_vs_1e4": 0.7}"#)
                .unwrap();
        let findings = compare_records(&base, &below, 0.20);
        assert!(findings.iter().any(|f| f.regressed), "{findings:?}");
    }

    /// End-to-end over directories: the gate fails on a doctored record
    /// and on a missing current record, with a readable diff.
    #[test]
    fn directory_gate_end_to_end() {
        let bdir = tmpdir("base");
        let cdir = tmpdir("cur");
        std::fs::write(bdir.join("BENCH_stream.json"), BASELINE).unwrap();
        std::fs::write(
            cdir.join("BENCH_stream.json"),
            r#"{"bench": "stream", "speedup_single_vs_refresh": 2.0,
                "ingest_p50_us": 500.0, "warm_iters_p50": 10, "n": 4096}"#,
        )
        .unwrap();
        // Extra current record without a baseline: noted, not fatal.
        std::fs::write(cdir.join("BENCH_new.json"), r#"{"speedup": 3.0}"#).unwrap();
        let (report, regressed) = check_dirs(&bdir, &cdir, 0.20).unwrap();
        assert!(regressed, "{report}");
        assert!(report.contains("FAIL speedup_single_vs_refresh"), "{report}");
        assert!(report.contains("BENCH_new.json: no baseline"), "{report}");

        // Healthy current record passes.
        std::fs::write(
            cdir.join("BENCH_stream.json"),
            r#"{"bench": "stream", "speedup_single_vs_refresh": 7.5,
                "ingest_p50_us": 420.0, "warm_iters_p50": 8, "n": 4096}"#,
        )
        .unwrap();
        let (report, regressed) = check_dirs(&bdir, &cdir, 0.20).unwrap();
        assert!(!regressed, "{report}");

        // Missing current record fails loudly.
        std::fs::remove_file(cdir.join("BENCH_stream.json")).unwrap();
        let (report, regressed) = check_dirs(&bdir, &cdir, 0.20).unwrap();
        assert!(regressed);
        assert!(report.contains("MISSING"), "{report}");

        std::fs::remove_dir_all(&bdir).ok();
        std::fs::remove_dir_all(&cdir).ok();
    }
}
