//! Cross-module integration tests: full pipelines spanning kernels →
//! operators → solvers → models, plus the PJRT runtime when artifacts are
//! built. These complement the per-module unit tests by exercising the
//! exact compositions the harness and examples rely on.

#![allow(clippy::needless_range_loop)] // index-heavy numeric test/bench loops

use skip_gp::data::growth::{generate as generate_growth, GrowthConfig};
use skip_gp::data::{dataset_by_name, generate, gaussian_cloud};
use skip_gp::gp::{
    ClusterMtgp, ClusterMtgpConfig, ExactGp, GpHypers, Mtgp, MtgpConfig, MvmGp,
    MvmGpConfig, MvmVariant, Sgpr,
};
use skip_gp::grid::GridSpec;
use skip_gp::kernels::ProductKernel;
use skip_gp::operators::{LinearOp, SkiOp, SkipComponent, SkipOp};
use skip_gp::solvers::{cg_solve, slq_logdet, CgConfig, SlqConfig};
use skip_gp::util::{mae, rel_err, Rng};

/// The paper's central pipeline at small scale: SKI per dimension →
/// SKIP merge → CG solve → prediction, checked against the exact GP.
#[test]
fn skip_pipeline_matches_exact_gp_predictions() {
    let mut rng = Rng::new(1);
    let n = 300;
    let d = 3;
    let xs = skip_gp::linalg::Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let f = |row: &[f64]| row.iter().map(|&x| (2.0 * x).sin()).sum::<f64>();
    let ys: Vec<f64> = (0..n).map(|i| f(xs.row(i)) + 0.05 * rng.normal()).collect();
    let xt = skip_gp::linalg::Matrix::from_fn(60, d, |_, _| rng.uniform_in(-0.9, 0.9));
    let h = GpHypers::new(0.8, 1.0, 0.05);

    let mut exact = ExactGp::new(xs.clone(), ys.clone(), h);
    exact.refresh().unwrap();
    let pe = exact.predict_mean(&xt);

    let mut skip = MvmGp::new(
        xs,
        ys,
        h,
        MvmGpConfig {
            grid: GridSpec::uniform(64),
            rank: 40,
            refresh_rank: 80,
            ..Default::default()
        },
    );
    skip.refresh().unwrap();
    let ps = skip.predict_mean(&xt);
    assert!(
        mae(&pe, &ps) < 0.02,
        "SKIP and exact GP disagree: mae {}",
        mae(&pe, &ps)
    );
}

/// MLL consistency across all three inference paths on one dataset.
#[test]
fn mll_consistency_exact_skip_kiss() {
    let spec = dataset_by_name("power").unwrap();
    let data = generate(spec, 0.015);
    let h = GpHypers::init_for_dim(data.d());
    let exact = ExactGp::new(data.xtrain.clone(), data.ytrain.clone(), h)
        .mll(&h)
        .unwrap();
    let n = data.n() as f64;
    for variant in [MvmVariant::Skip, MvmVariant::Kiss] {
        let gp = MvmGp::new(
            data.xtrain.clone(),
            data.ytrain.clone(),
            h,
            MvmGpConfig {
                variant,
                grid: GridSpec::uniform(32),
                rank: 60,
                slq: SlqConfig { num_probes: 20, max_rank: 40 },
                cg: CgConfig { max_iters: 200, tol: 1e-7, ..CgConfig::default() },
                ..Default::default()
            },
        );
        let est = gp.mll(&h, 3).unwrap();
        let gap = (est - exact).abs() / n;
        assert!(gap < 0.06, "{variant:?}: {est} vs exact {exact} ({gap} nats/pt)");
    }
}

/// SGPR bound and exact MLL bracket the SKIP estimate on smooth data.
#[test]
fn sgpr_bound_below_exact() {
    let spec = dataset_by_name("power").unwrap();
    let data = generate(spec, 0.015);
    let h = GpHypers::init_for_dim(data.d());
    let exact = ExactGp::new(data.xtrain.clone(), data.ytrain.clone(), h)
        .mll(&h)
        .unwrap();
    let elbo = Sgpr::new(data.xtrain.clone(), data.ytrain.clone(), h, 60, 0)
        .elbo(&h)
        .unwrap();
    assert!(elbo <= exact + 1e-6);
}

/// End-to-end cluster workflow: generate → Gibbs (SKIP MLLs) → predict.
#[test]
fn cluster_workflow_end_to_end() {
    let growth = generate_growth(&GrowthConfig {
        num_children: 12,
        min_obs: 8,
        max_obs: 12,
        seed: 5,
        ..Default::default()
    });
    let mut model = ClusterMtgp::new(
        growth.data.clone(),
        ClusterMtgpConfig { use_skip: true, seed: 5, ..Default::default() },
    );
    model.run_gibbs(5);
    // Predictions for every observation should track the data.
    let pred = model
        .predict_mean(&growth.data.x, &growth.data.task_of)
        .unwrap();
    let err = mae(&pred, &growth.data.y);
    assert!(err < 0.2, "in-sample mae {err}");
}

/// MTGP: SKIP operator and dense covariance agree through a CG solve.
#[test]
fn mtgp_skip_solve_matches_dense_solve() {
    let growth = generate_growth(&GrowthConfig {
        num_children: 10,
        min_obs: 6,
        max_obs: 10,
        seed: 9,
        ..Default::default()
    });
    let mtgp = Mtgp::new(
        growth.data.clone(),
        skip_gp::kernels::Stationary1d::matern52(0.5),
        2,
        0.1,
        MtgpConfig { rank: 40, ..Default::default() },
    );
    let dense = mtgp.khat_dense();
    let chol = skip_gp::linalg::Cholesky::new_with_jitter(&dense, 1e-10).unwrap();
    let alpha_exact = chol.solve(&growth.data.y);
    let op = mtgp.build_skip_operator(3);
    let cg = CgConfig { max_iters: 300, tol: 1e-8, ..CgConfig::default() };
    let sol = cg_solve(&op, &growth.data.y, cg);
    assert!(
        rel_err(&sol.x, &alpha_exact) < 0.05,
        "alpha rel err {}",
        rel_err(&sol.x, &alpha_exact)
    );
}

/// SLQ logdet through the SKIP operator tracks the dense logdet.
#[test]
fn slq_on_skip_operator_tracks_dense() {
    let mut rng = Rng::new(11);
    let n = 200;
    let d = 2;
    let xs = gaussian_cloud(n, d, 11);
    let kern = ProductKernel::rbf(d, 1.2, 1.0);
    let skis: Vec<SkiOp> = (0..d)
        .map(|k| SkiOp::new(&xs.col(k), &kern.factors[k], 64).unwrap())
        .collect();
    let comps: Vec<SkipComponent> = skis
        .iter()
        .map(|s| SkipComponent::Op(s as &dyn LinearOp))
        .collect();
    let skip = SkipOp::build_native(comps, 60, &mut rng);
    let op = skip_gp::operators::AffineOp { inner: Box::new(skip), scale: 1.0, shift: 0.3 };
    let mut dense = kern.gram_sym(&xs);
    dense.add_diag(0.3);
    let want = skip_gp::linalg::Cholesky::new_with_jitter(&dense, 1e-10)
        .unwrap()
        .logdet();
    let got = slq_logdet(
        &op,
        SlqConfig { num_probes: 40, max_rank: 40 },
        &mut Rng::new(12),
    );
    let gap = (got - want).abs() / n as f64;
    assert!(gap < 0.05, "slq {got} vs dense {want} ({gap} nats/pt)");
}

/// PJRT backend inside a full SKIP training loop agrees with native.
#[test]
fn pjrt_backend_training_matches_native() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use skip_gp::runtime::PjrtBackend;
    use std::sync::Arc;
    let spec = dataset_by_name("power").unwrap();
    let data = generate(spec, 0.01);
    let h = GpHypers::init_for_dim(data.d());
    let cfg = MvmGpConfig {
        grid: GridSpec::uniform(32),
        rank: 15,
        refresh_rank: 30,
        ..Default::default()
    };
    // Native path.
    let mut native = MvmGp::new(data.xtrain.clone(), data.ytrain.clone(), h, cfg.clone());
    native.refresh().unwrap();
    let pn = native.predict_mean(&data.xtest);
    // PJRT path (same seed → same Lanczos probes → same decompositions up
    // to artifact numerics).
    let backend = Arc::new(PjrtBackend::load(&dir).unwrap());
    let mut pjrt = MvmGp::new(data.xtrain.clone(), data.ytrain.clone(), h, cfg)
        .with_backend(backend.clone());
    pjrt.refresh().unwrap();
    let pp = pjrt.predict_mean(&data.xtest);
    // The two paths compute the same math but with different summation
    // orders inside XLA; Lanczos amplifies ulp-level differences, so
    // compare at prediction level, not bitwise.
    assert!(rel_err(&pp, &pn) < 1e-2, "pjrt vs native rel err {}", rel_err(&pp, &pn));
    let (calls, _) = backend.call_counts();
    assert!(calls > 0, "pjrt path unused");
}
