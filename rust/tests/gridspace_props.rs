//! Cross-solver equivalence suite for the grid-space iteration engine:
//! the m×m normal-equations path (`solvers::gridspace`, Yadav, Sheldon &
//! Musco 2021) must reproduce the data-space CG oracle on every problem
//! both can solve — dense Kronecker and sparse-grid KISS, cold and
//! streaming — and a grid-space-trained model must pin against the dense
//! `ExactGp` references on the on-grid serving fixture.
//!
//! The agreement tolerances are derived, not tuned: both solvers stop on
//! the same certificate `‖K̂α − y‖ ≤ tol·‖y‖`, so
//! `‖Δα‖₂ ≤ 2·tol·‖y‖₂/λ_min ≤ 2·tol·‖y‖₂/σ_n²`, and with σ_n² = 1,
//! `mae(Δα) ≤ ‖Δα‖₂/√n ≈ 2·tol` — asserting 1e-8 at tol = 1e-10 leaves
//! two orders of slack for the attainable CG floor (≈ ε·κ).

#![allow(clippy::needless_range_loop)] // index-heavy numeric test loops

use skip_gp::gp::{ExactGp, GpHypers, MvmGp, MvmGpConfig, MvmVariant, SolveSpace};
use skip_gp::grid::{Grid1d, GridSpec};
use skip_gp::kernels::ProductKernel;
use skip_gp::linalg::Matrix;
use skip_gp::operators::KroneckerSkiOp;
use skip_gp::serve::VarianceMode;
use skip_gp::solvers::{CgConfig, SolverPolicy};
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::{mae, Rng};

/// Smooth toy regression problem on [−1, 1]^d.
fn toy(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let f = |row: &[f64]| -> f64 {
        row.iter().enumerate().map(|(k, &x)| ((k + 1) as f64 * x).sin()).sum()
    };
    let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> = (0..n).map(|i| f(xs.row(i)) + 0.05 * rng.normal()).collect();
    (xs, ys)
}

/// Refresh one KISS model per solve space on the same data/spec and
/// return both cached αs (data-space first).
fn alphas_both_spaces(
    xs: &Matrix,
    ys: &[f64],
    spec: GridSpec,
    hypers: GpHypers,
) -> (Vec<f64>, Vec<f64>) {
    let cfg = |space: SolveSpace| MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid: spec.clone(),
        cg: CgConfig { max_iters: 1500, tol: 1e-10, ..Default::default() },
        policy: SolverPolicy { warm_start: false, space, ..Default::default() },
        ..Default::default()
    };
    let mut data = MvmGp::new(xs.clone(), ys.to_vec(), hypers, cfg(SolveSpace::Data));
    data.refresh().unwrap();
    assert!(
        !data.alpha_solved_in_grid_space(),
        "SolveSpace::Data must keep the n-space oracle path"
    );
    let mut grid = MvmGp::new(xs.clone(), ys.to_vec(), hypers, cfg(SolveSpace::Grid));
    grid.refresh().unwrap();
    assert!(
        grid.alpha_solved_in_grid_space(),
        "SolveSpace::Grid must route the y-solve through the grid engine"
    );
    (
        data.alpha().unwrap().to_vec(),
        grid.alpha().unwrap().to_vec(),
    )
}

/// Acceptance: grid-space and data-space solves agree to 1e-8 across
/// n ∈ {64, 1024, 4096} × d ∈ {1, 2, 3}, dense Kronecker grids.
#[test]
fn grid_and_data_space_agree_dense_kronecker() {
    // σ_n² = 1 keeps the derived mae bound at ≈ 2·tol (see module docs).
    let hypers = GpHypers::new(0.6, 1.0, 1.0);
    for (di, &d) in [1usize, 2, 3].iter().enumerate() {
        let m = [16usize, 12, 8][di];
        for &n in &[64usize, 1024, 4096] {
            let (xs, ys) = toy(n, d, 31 * d as u64 + n as u64);
            let (a_data, a_grid) =
                alphas_both_spaces(&xs, &ys, GridSpec::Uniform(m), hypers);
            let err = mae(&a_data, &a_grid);
            assert!(
                err < 1e-8,
                "dense n={n} d={d} m={m}: data vs grid α mae {err:e}"
            );
        }
    }
}

/// Acceptance: the same equivalence on sparse-grid (combination
/// technique) KISS, whose grid systems carry signed multi-term `G`.
#[test]
fn grid_and_data_space_agree_sparse_grid() {
    let hypers = GpHypers::new(0.6, 1.0, 1.0);
    for &d in &[1usize, 2, 3] {
        for &n in &[64usize, 1024, 4096] {
            let (xs, ys) = toy(n, d, 71 * d as u64 + n as u64);
            let (a_data, a_grid) =
                alphas_both_spaces(&xs, &ys, GridSpec::Sparse { level: 3 }, hypers);
            let err = mae(&a_data, &a_grid);
            assert!(
                err < 1e-8,
                "sparse n={n} d={d} level=3: data vs grid α mae {err:e}"
            );
        }
    }
}

/// The serving suite's on-grid fixture (`serve_roundtrip.rs`), widened to
/// the full margin-fit node range 2..=13 and with both extremes forced
/// into every column: the data bounds are then exactly
/// `[g.point(2), g.point(13)]`, so `GridSpec::Uniform(16)`'s re-fit
/// (`Grid1d::fit` over data bounds) lands on this same lattice (to
/// rounding) and the cubic stencil stays an exact selection.
fn on_grid_problem(n: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix) {
    let d = 3;
    let m = 16usize;
    let g = Grid1d::fit(0.0, 1.0, m).unwrap();
    let mut rng = Rng::new(seed);
    let mut lattice = |rows: usize| {
        Matrix::from_fn(rows, d, |_, _| g.point(2 + rng.below(m - 4)))
    };
    let mut xs = lattice(n);
    for k in 0..d {
        xs.data[k] = g.point(2); // row 0: lower data bound (= 0.0)
        xs.data[d + k] = g.point(13); // row 1: upper data bound (≈ 1.0)
    }
    let xt = lattice(64);
    let ys: Vec<f64> = (0..n)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + (3.0 * r[1]).cos() * r[2] + 0.05 * rng.normal()
        })
        .collect();
    (xs, ys, xt)
}

/// Acceptance: a KISS model trained *entirely in grid space* pins its
/// predictive mean and variance against the dense `ExactGp` references
/// within 1e-6 on the n=256, d=3 on-grid case — on-grid SKI is exact, so
/// the only daylight between the two models is solver tolerance.
#[test]
fn grid_space_trained_model_matches_exact_gp_within_1e6() {
    let (xs, ys, xt) = on_grid_problem(256, 1);
    let h = GpHypers::new(0.45, 1.3, 0.05);
    let mut exact = ExactGp::new(xs.clone(), ys.clone(), h);
    exact.refresh().unwrap();
    let want_mean = exact.predict_mean(&xt);
    let want_var = exact.predict_var(&xt);

    let cfg = MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid: GridSpec::Uniform(16),
        cg: CgConfig { max_iters: 1500, tol: 1e-11, ..Default::default() },
        policy: SolverPolicy { space: SolveSpace::Grid, ..Default::default() },
        ..Default::default()
    };
    let mut gp = MvmGp::new(xs, ys, h, cfg);
    gp.refresh().unwrap();
    assert!(gp.alpha_solved_in_grid_space());

    let got_mean = gp.predict_mean(&xt);
    let got_var = gp.predict_var(&xt).unwrap();
    for i in 0..xt.rows {
        assert!(
            (got_mean[i] - want_mean[i]).abs() < 1e-6,
            "mean[{i}]: grid-trained {} vs exact {}",
            got_mean[i],
            want_mean[i]
        );
        assert!(
            (got_var[i] - want_var[i]).abs() < 1e-6,
            "var[{i}]: grid-trained {} vs exact {}",
            got_var[i],
            want_var[i]
        );
    }
}

/// The banded `WᵀW` stencil Gram is pinned elementwise against the dense
/// `Wᵀ·W` assembled column-by-column from the operator's own `W`/`Wᵀ`
/// matvecs — same stencils, so only summation-order rounding separates
/// them.
#[test]
fn wtw_band_matches_dense_gram_elementwise() {
    let (d, m, n) = (2usize, 8usize, 80usize);
    let mut rng = Rng::new(9);
    let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let kern = ProductKernel::rbf(d, 0.5, 1.0);
    let op = KroneckerSkiOp::new(&xs, &kern, m).unwrap();
    let gram = op.grid_space_op().unwrap();
    let total = m * m;
    assert_eq!(gram.dim(), total);
    assert_eq!(gram.band_width(), 49, "(2·4−1)² offsets for a d=2 cubic stencil");
    for j in 0..total {
        let mut e = vec![0.0; total];
        e[j] = 1.0;
        let dense_col = op.wt_matvec(&op.w_matvec(&e)); // (Wᵀ·W)·e_j
        let band_col = gram.apply(&e);
        for i in 0..total {
            assert!(
                (band_col[i] - dense_col[i]).abs() < 1e-10,
                "G[{i},{j}]: band {} vs dense {}",
                band_col[i],
                dense_col[i]
            );
        }
    }
}

/// Acceptance: 64 one-at-a-time grid-mode ingests — each an incremental
/// `WᵀW`/`Wᵀy` fold plus a warm-started grid re-solve — match a
/// from-scratch grid-space refit on the full point set within 1e-6.
#[test]
fn incremental_grid_ingests_match_scratch_grid_refit() {
    let d = 2;
    let (n0, n_stream) = (96usize, 64usize);
    let mut rng = Rng::new(17);
    let f = |r: &[f64]| (2.0 * r[0]).sin() + (3.0 * r[1]).cos();
    let xs0 = Matrix::from_fn(n0, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys0: Vec<f64> = (0..n0).map(|i| f(xs0.row(i)) + 0.02 * rng.normal()).collect();
    let streamed: Vec<(Vec<f64>, f64)> = (0..n_stream)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-0.9, 0.9)).collect();
            let y = f(&x) + 0.02 * rng.normal();
            (x, y)
        })
        .collect();

    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 12).unwrap(),
        Grid1d::fit(-1.0, 1.0, 12).unwrap(),
    ];
    let h = GpHypers::new(0.6, 1.0, 0.05);
    let cg = CgConfig { max_iters: 800, tol: 1e-11, ..Default::default() };
    // Purely incremental policy (no count/outlier-triggered refreshes),
    // exact variance so the live and cold factors are deterministic.
    let scfg = StreamConfig {
        refresh_every: 0,
        var_drift_budget: 0,
        error_z: 0.0,
        log_capacity: 4096,
        variance: VarianceMode::Exact,
        patch_eps: 1e-12,
        policy: SolverPolicy { space: SolveSpace::Grid, ..Default::default() },
        ..Default::default()
    };
    let mut live = IncrementalState::new(
        xs0.clone(),
        ys0.clone(),
        h,
        axes.clone(),
        cg,
        scfg.clone(),
    )
    .unwrap();
    assert!(live.solved_in_grid_space(), "explicit grid mode from the first solve");
    for (x, y) in &streamed {
        live.ingest(x, *y).unwrap();
    }
    assert!(live.solved_in_grid_space(), "grid mode survives 64 ingests");
    assert_eq!(live.n(), n0 + n_stream);

    // Cold reference: one-shot grid-space build on the full set.
    let mut xs_full = xs0;
    let mut ys_full = ys0;
    for (x, y) in &streamed {
        xs_full.data.extend_from_slice(x);
        xs_full.rows += 1;
        ys_full.push(*y);
    }
    let cold = IncrementalState::new(xs_full, ys_full, h, axes, cg, scfg).unwrap();
    assert!(cold.solved_in_grid_space());

    let aerr = mae(live.alpha(), cold.alpha());
    assert!(aerr < 1e-6, "incremental vs scratch α mae {aerr:e}");
    for _ in 0..40 {
        let q = [rng.uniform_in(-0.8, 0.8), rng.uniform_in(-0.8, 0.8)];
        let (lm, lv) = (live.cache().predict_mean_one(&q), live.cache().predict_var_one(&q));
        let (cm, cv) = (cold.cache().predict_mean_one(&q), cold.cache().predict_var_one(&q));
        assert!((lm - cm).abs() < 1e-6, "mean: live {lm} vs cold {cm}");
        assert!((lv - cv).abs() < 1e-6, "var: live {lv} vs cold {cv}");
    }
}

/// Nightly-lane (`cargo test --release -- --ignored`) scale check: the
/// equivalence holds at n = 10⁵, where the grid path's per-iteration
/// advantage actually matters. Too slow for the debug-mode tier-1 lane.
#[test]
#[ignore = "n=1e5 equivalence solve; run in the release --ignored lane"]
fn grid_and_data_space_agree_at_1e5() {
    let hypers = GpHypers::new(0.6, 1.0, 1.0);
    let (xs, ys) = toy(100_000, 2, 5);
    let (a_data, a_grid) =
        alphas_both_spaces(&xs, &ys, GridSpec::Uniform(32), hypers);
    let err = mae(&a_data, &a_grid);
    assert!(err < 1e-8, "n=1e5: data vs grid α mae {err:e}");
}
