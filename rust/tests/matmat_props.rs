//! Property tests for the batched multi-RHS MVM engine.
//!
//! Every structured operator's `matmat` fast path promises *exactly* the
//! semantics of the serial column-by-column reference
//! (`matmat_via_matvec`): these tests pin that contract across random
//! shapes and block widths t ∈ {1, 3, 8}, and pin block-CG to per-column
//! agreement with single-RHS CG — including the acceptance case of a
//! SKIP-backed `K̂` with 8 simultaneous right-hand sides.

#![allow(clippy::needless_range_loop)] // index-heavy numeric test/bench loops

use skip_gp::kernels::{ProductKernel, Stationary1d, TaskKernel};
use skip_gp::linalg::Matrix;
use skip_gp::operators::lowrank::{HadamardPairOp, NativeBackend};
use skip_gp::operators::{
    matmat_via_matvec, AffineOp, DenseOp, DiagOp, KroneckerSkiOp, LanczosFactor,
    LinearOp, ScaledOp, ShiftedOp, SkiOp, SkipComponent, SkipOp, SumOp, TaskOp,
};
use skip_gp::solvers::{block_cg_solve, cg_solve, lanczos, CgConfig};
use skip_gp::util::{rel_err, Rng};

/// Assert `op.matmat` matches the serial reference for t ∈ {1, 3, 8}.
///
/// The fast paths are flop-reordered (fused passes, paired FFTs, thread
/// chunking), so the comparison is to tight relative tolerance rather
/// than bitwise.
fn check_matmat(op: &dyn LinearOp, rng: &mut Rng, label: &str) {
    let n = op.dim();
    for t in [1usize, 3, 8] {
        let block = Matrix::from_fn(n, t, |_, _| rng.normal());
        let fast = op.matmat(&block);
        let reference = matmat_via_matvec(op, &block);
        assert_eq!((fast.rows, fast.cols), (n, t), "{label}: shape at t={t}");
        let scale = reference.fro_norm().max(1.0);
        let diff = fast.max_abs_diff(&reference);
        assert!(
            diff <= 1e-9 * scale,
            "{label}: t={t} max diff {diff:.3e} vs scale {scale:.3e}"
        );
    }
}

fn random_spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let b = Matrix::from_fn(n, n, |_, _| rng.normal());
    let mut a = b.matmul_t(&b);
    a.add_diag(n as f64 * 0.05);
    a
}

fn random_factor(n: usize, r: usize, rng: &mut Rng) -> LanczosFactor {
    let q = Matrix::from_fn(n, r, |_, _| rng.normal());
    let mut t = Matrix::from_fn(r, r, |_, _| rng.normal());
    t.symmetrize();
    LanczosFactor { q, t }
}

#[test]
fn dense_diag_and_wrappers_matmat() {
    let mut rng = Rng::new(1);
    for n in [5usize, 23, 64] {
        let dense = DenseOp(Matrix::from_fn(n, n, |_, _| rng.normal()));
        check_matmat(&dense, &mut rng, "DenseOp");

        let diag = DiagOp(rng.normal_vec(n));
        check_matmat(&diag, &mut rng, "DiagOp");

        let shifted = ShiftedOp::new(&dense, 1.7);
        check_matmat(&shifted, &mut rng, "ShiftedOp");

        let scaled = ScaledOp { inner: &dense, scale: -0.3 };
        check_matmat(&scaled, &mut rng, "ScaledOp");

        let affine = AffineOp {
            inner: Box::new(DenseOp(Matrix::from_fn(n, n, |_, _| rng.normal()))),
            scale: 2.5,
            shift: 0.9,
        };
        check_matmat(&affine, &mut rng, "AffineOp");
    }
}

#[test]
fn sum_op_matmat() {
    let mut rng = Rng::new(2);
    for n in [7usize, 40] {
        let sum = SumOp {
            terms: vec![
                Box::new(DenseOp(Matrix::from_fn(n, n, |_, _| rng.normal()))),
                Box::new(DiagOp(rng.normal_vec(n))),
                Box::new(DenseOp(Matrix::from_fn(n, n, |_, _| rng.normal()))),
            ],
        };
        check_matmat(&sum, &mut rng, "SumOp");
    }
}

#[test]
fn ski_op_matmat() {
    let mut rng = Rng::new(3);
    for (n, m) in [(50usize, 32usize), (211, 64), (400, 128)] {
        let xs = rng.uniform_vec(n, -1.0, 1.0);
        let kern = Stationary1d::rbf(0.5);
        let op = SkiOp::new(&xs, &kern, m).unwrap();
        check_matmat(&op, &mut rng, "SkiOp");
    }
}

#[test]
fn kronecker_ski_op_matmat() {
    let mut rng = Rng::new(4);
    for (n, d, m) in [(60usize, 2usize, 16usize), (90, 3, 12)] {
        let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let kern = ProductKernel::rbf(d, 0.8, 1.2);
        let op = KroneckerSkiOp::new(&xs, &kern, m).unwrap();
        check_matmat(&op, &mut rng, "KroneckerSkiOp");
    }
}

/// The sparse-grid SKI operator is a SumOp of coefficient-scaled
/// anisotropic Kronecker terms; its block path must match the serial
/// reference like every other operator.
#[test]
fn sparse_grid_ski_operator_matmat() {
    use skip_gp::grid::{grid_ski_operator, InducingGrid, SparseGrid};
    let mut rng = Rng::new(12);
    let xs = Matrix::from_fn(70, 3, |_, _| rng.uniform_in(-1.0, 1.0));
    let kern = ProductKernel::rbf(3, 0.8, 1.1);
    let grid = SparseGrid::fit(&xs, 4).unwrap();
    assert!(grid.terms().len() > 1);
    let op = grid_ski_operator(&xs, &kern, &grid);
    check_matmat(op.as_ref(), &mut rng, "SparseGridSkiOp");
}

#[test]
fn lanczos_factor_and_hadamard_pair_matmat() {
    let mut rng = Rng::new(5);
    for (n, r1, r2) in [(30usize, 3usize, 5usize), (120, 8, 8), (75, 1, 6)] {
        let a = random_factor(n, r1, &mut rng);
        let b = random_factor(n, r2, &mut rng);
        check_matmat(&a, &mut rng, "LanczosFactor");
        let backend = NativeBackend;
        let pair = HadamardPairOp { a: &a, b: &b, backend: &backend };
        check_matmat(&pair, &mut rng, "HadamardPairOp");
    }
}

#[test]
fn skip_op_matmat_single_and_pair_roots() {
    let mut rng = Rng::new(6);
    // d = 1 → Root::Single; d = 3 → merge tree with a Pair root.
    for d in [1usize, 3] {
        let n = 80;
        let xs = Matrix::from_fn(n, d, |_, _| rng.normal());
        let k = ProductKernel::rbf(d, 1.0, 1.0);
        let grams: Vec<Matrix> = (0..d)
            .map(|dd| {
                Matrix::from_fn(n, n, |i, j| {
                    k.factors[dd].eval(xs.get(i, dd), xs.get(j, dd))
                })
            })
            .collect();
        let ops: Vec<DenseOp> = grams.into_iter().map(DenseOp).collect();
        let comps: Vec<SkipComponent> = ops
            .iter()
            .map(|o| SkipComponent::Op(o as &dyn LinearOp))
            .collect();
        let skip = SkipOp::build_native(comps, 25, &mut rng);
        check_matmat(&skip, &mut rng, "SkipOp");
    }
}

#[test]
fn task_op_matmat() {
    let mut rng = Rng::new(7);
    for (n, s, q) in [(40usize, 5usize, 2usize), (130, 9, 3)] {
        let task_of: Vec<usize> = (0..n).map(|_| rng.below(s)).collect();
        let b = Matrix::from_fn(s, q, |_, _| rng.normal() * 0.5);
        let diag: Vec<f64> = (0..s).map(|_| rng.uniform_in(0.1, 0.5)).collect();
        let op = TaskOp::new(task_of, TaskKernel::new(b, diag));
        check_matmat(&op, &mut rng, "TaskOp");
    }
}

#[test]
fn block_cg_matches_single_cg_on_dense_spd() {
    let dense = random_spd(60, 8);
    let op = DenseOp(dense);
    let mut rng = Rng::new(9);
    for t in [1usize, 3, 8] {
        let b = Matrix::from_fn(60, t, |_, _| rng.normal());
        let block = block_cg_solve(&op, &b, CgConfig::default());
        assert!(block.all_converged());
        for j in 0..t {
            let single = cg_solve(&op, &b.col(j), CgConfig::default());
            let err = rel_err(&block.x.col(j), &single.x);
            assert!(err < 1e-8, "t={t} col {j}: {err}");
        }
    }
}

/// The acceptance case: block-CG with t = 8 right-hand sides against a
/// SKIP-backed `K̂ = SKIP + σ²I`, agreeing with 8 independent CG solves
/// to 1e-8 per column.
#[test]
fn block_cg_8rhs_on_skip_operator_matches_serial() {
    let mut rng = Rng::new(10);
    let n = 300;
    let d = 3;
    let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let k = ProductKernel::rbf(d, 0.9, 1.0);
    let skis: Vec<SkiOp> = (0..d)
        .map(|dd| SkiOp::new(&xs.col(dd), &k.factors[dd], 64).unwrap())
        .collect();
    let comps: Vec<SkipComponent> = skis
        .iter()
        .map(|s| SkipComponent::Op(s as &dyn LinearOp))
        .collect();
    let skip = SkipOp::build_native(comps, 30, &mut rng);
    let khat = AffineOp { inner: Box::new(skip), scale: 1.0, shift: 0.3 };

    let t = 8;
    let b = Matrix::from_fn(n, t, |_, _| rng.normal());
    let cfg = CgConfig { max_iters: 400, tol: 1e-12, ..CgConfig::default() };
    let block = block_cg_solve(&khat, &b, cfg);
    for j in 0..t {
        let single = cg_solve(&khat, &b.col(j), cfg);
        assert!(single.converged, "serial col {j} did not converge");
        assert!(block.columns[j].converged, "block col {j} did not converge");
        let err = rel_err(&block.x.col(j), &single.x);
        assert!(err < 1e-8, "col {j}: block vs serial rel err {err}");
    }
    // The whole point: one block MVM per iteration, not t.
    let max_iters = block.columns.iter().map(|c| c.iters).max().unwrap();
    assert_eq!(block.matmats, max_iters);
}

/// Batched Lanczos must agree with sequential Lanczos probe-by-probe even
/// when the operator's matmat takes a reordered (fused/FFT-paired) path.
#[test]
fn batched_lanczos_agrees_on_structured_operator() {
    let mut rng = Rng::new(11);
    let n = 150;
    let xs = rng.uniform_vec(n, 0.0, 2.0);
    let kern = Stationary1d::matern52(0.6);
    let ski = SkiOp::new(&xs, &kern, 48).unwrap();
    let shifted = AffineOp { inner: Box::new(ski), scale: 1.0, shift: 0.4 };
    let mut probes = Matrix::zeros(n, 4);
    for j in 0..4 {
        probes.set_col(j, &rng.normal_vec(n));
    }
    // Modest rank: well before Krylov breakdown, where Lanczos is stable
    // enough that the reordered (FFT-paired) matmat cannot perturb the
    // recurrence beyond rounding amplification.
    let batch = skip_gp::solvers::lanczos_batch(&shifted, &probes, 8, 1e-10);
    for (j, got) in batch.iter().enumerate() {
        let want = lanczos(&shifted, &probes.col(j), 8, 1e-10);
        assert_eq!(got.rank(), want.rank(), "probe {j}");
        for (ga, wa) in got.alphas.iter().zip(&want.alphas) {
            assert!((ga - wa).abs() < 1e-6 * (1.0 + wa.abs()), "probe {j} alpha");
        }
    }
}
