//! Property tests for D-SKI derivative observations (ISSUE 10).
//!
//! - On-grid D-SKI models match a dense derivative-kernel oracle
//!   ([`ExactGradGp`]) in both predictive mean and mean-gradient to
//!   1e-5, for d ∈ {1, 2}.
//! - Streaming `(y, ∇y)` ingestion (singles and blocks) matches a cold
//!   refit on the full data to 1e-6, and a forced [`IncrementalState::refresh`]
//!   does not move predictions.
//! - Snapshot format v6 round-trips bitwise with grad-carrying pending
//!   entries, and every historical format v1–v5 still migrates (v5 via a
//!   byte-spliced downgrade — no fixture file predates v6 pending grads).

use std::collections::HashSet;
use std::path::PathBuf;

use skip_gp::gp::{ExactGp, ExactGradGp, GpHypers, MvmGp, MvmGpConfig, MvmVariant};
use skip_gp::grid::{Grid1d, GridSpec};
use skip_gp::linalg::Matrix;
use skip_gp::serve::{
    ModelSnapshot, SnapshotConfig, VarianceMode, SNAPSHOT_VERSION,
};
use skip_gp::solvers::CgConfig;
use skip_gp::stream::{IncrementalState, Observation, StreamConfig};
use skip_gp::util::Rng;

/// Tight CG so solver error sits far below the stencil-accuracy
/// tolerances the assertions pin.
fn tight_cg() -> CgConfig {
    CgConfig { max_iters: 2000, tol: 1e-10, ..Default::default() }
}

fn kiss_cfg(m: usize) -> MvmGpConfig {
    MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid: GridSpec::uniform(m),
        cg: tight_cg(),
        ..Default::default()
    }
}

/// Streaming config with every automatic refresh trigger disabled, so
/// the test exercises the warm incremental path and nothing else.
fn warm_only_cfg() -> StreamConfig {
    StreamConfig {
        refresh_every: 0,
        var_drift_budget: 0,
        error_z: 0.0,
        log_capacity: 64,
        variance: VarianceMode::Lanczos(32),
        patch_eps: 1e-12,
        ..Default::default()
    }
}

/// D-SKI on on-grid 1-D data matches the dense derivative-kernel oracle:
/// when every training point sits exactly on an inducing node, the value
/// stencils are exact and the derivative stencils are O(h²·k'''), far
/// below 1e-5 at this grid density.
#[test]
fn dski_matches_dense_derivative_oracle_1d() {
    let m = 512;
    let n = 120;
    let g = Grid1d::fit(0.0, 1.0, m).unwrap();
    // Node indices spanning the full interior [2, m-3], endpoints
    // included — data min/max are then exactly 0 and 1, so the model's
    // own grid fit reproduces these axes.
    let f = |x: f64| (3.0 * x).sin() + 0.5 * (5.0 * x).cos();
    let fp = |x: f64| 3.0 * (3.0 * x).cos() - 2.5 * (5.0 * x).sin();
    let xs = Matrix::from_fn(n, 1, |k, _| {
        let i = 2 + ((k * (m - 5)) as f64 / (n - 1) as f64).round() as usize;
        g.point(i)
    });
    let ys: Vec<f64> = (0..n).map(|k| f(xs.get(k, 0))).collect();
    let grads = Matrix::from_fn(n, 1, |k, _| fp(xs.get(k, 0)));
    let h = GpHypers::new(2.0, 1.0, 0.1);

    let mut gp =
        MvmGp::new_with_grads(xs.clone(), ys.clone(), grads.clone(), h, kiss_cfg(m))
            .unwrap();
    gp.refresh().unwrap();
    let mut oracle = ExactGradGp::new(xs, ys, grads, h);
    oracle.refresh().unwrap();

    let mut rng = Rng::new(11);
    let q = Matrix::from_fn(40, 1, |_, _| rng.uniform_in(0.03, 0.97));
    let (mean, want_mean) = (gp.predict_mean(&q), oracle.predict_mean(&q));
    let (grad, want_grad) = (gp.predict_grad(&q), oracle.predict_grad(&q));
    for i in 0..q.rows {
        assert!(
            (mean[i] - want_mean[i]).abs() <= 1e-5,
            "1-D mean at x={}: ski {} vs oracle {}",
            q.get(i, 0),
            mean[i],
            want_mean[i]
        );
        assert!(
            (grad.get(i, 0) - want_grad.get(i, 0)).abs() <= 1e-5,
            "1-D mean-gradient at x={}: ski {} vs oracle {}",
            q.get(i, 0),
            grad.get(i, 0),
            want_grad.get(i, 0)
        );
    }
}

/// Same property in 2-D: an 8×8 lattice of inducing nodes (corners
/// included) as training data, KISS D-SKI vs the dense oracle.
#[test]
fn dski_matches_dense_derivative_oracle_2d() {
    let m = 200;
    let g = Grid1d::fit(0.0, 1.0, m).unwrap();
    let f = |x0: f64, x1: f64| (2.0 * x0).sin() * (3.0 * x1).cos();
    let g0 = |x0: f64, x1: f64| 2.0 * (2.0 * x0).cos() * (3.0 * x1).cos();
    let g1 = |x0: f64, x1: f64| -3.0 * (2.0 * x0).sin() * (3.0 * x1).sin();
    let idx: Vec<usize> =
        (0..8).map(|a| 2 + ((a * (m - 5)) as f64 / 7.0).round() as usize).collect();
    assert_eq!((idx[0], idx[7]), (2, m - 3), "lattice must include the grid corners");
    let n = idx.len() * idx.len();
    let xs = Matrix::from_fn(n, 2, |k, j| {
        let (a, b) = (idx[k / 8], idx[k % 8]);
        g.point(if j == 0 { a } else { b })
    });
    let ys: Vec<f64> = (0..n).map(|k| f(xs.get(k, 0), xs.get(k, 1))).collect();
    let grads = Matrix::from_fn(n, 2, |k, j| {
        let (x0, x1) = (xs.get(k, 0), xs.get(k, 1));
        if j == 0 {
            g0(x0, x1)
        } else {
            g1(x0, x1)
        }
    });
    let h = GpHypers::new(2.5, 1.0, 0.1);

    let mut gp =
        MvmGp::new_with_grads(xs.clone(), ys.clone(), grads.clone(), h, kiss_cfg(m))
            .unwrap();
    gp.refresh().unwrap();
    let mut oracle = ExactGradGp::new(xs, ys, grads, h);
    oracle.refresh().unwrap();

    let mut rng = Rng::new(12);
    let q = Matrix::from_fn(30, 2, |_, _| rng.uniform_in(0.03, 0.97));
    let (mean, want_mean) = (gp.predict_mean(&q), oracle.predict_mean(&q));
    let (grad, want_grad) = (gp.predict_grad(&q), oracle.predict_grad(&q));
    for i in 0..q.rows {
        assert!(
            (mean[i] - want_mean[i]).abs() <= 1e-5,
            "2-D mean at row {i}: ski {} vs oracle {}",
            mean[i],
            want_mean[i]
        );
        for j in 0..2 {
            assert!(
                (grad.get(i, j) - want_grad.get(i, j)).abs() <= 1e-5,
                "2-D mean-gradient at row {i} axis {j}: ski {} vs oracle {}",
                grad.get(i, j),
                want_grad.get(i, j)
            );
        }
    }
}

/// Data for the streaming tests: 40 points in [-1, 1]² with analytic
/// gradients; the corners (-1,-1) and (1,1) sit in the first two rows so
/// every prefix ≥ 2 spans the same bounding box (identical grid axes
/// between the streamed prefix model and the cold full-data refit).
fn bo_style_data(seed: u64) -> (Matrix, Vec<f64>, Matrix) {
    let mut rng = Rng::new(seed);
    let n = 40;
    let f = |x0: f64, x1: f64| (1.3 * x0).sin() + 0.7 * (1.9 * x1).cos();
    let xs = Matrix::from_fn(n, 2, |i, j| match (i, j) {
        (0, _) => -1.0,
        (1, _) => 1.0,
        _ => rng.uniform_in(-1.0, 1.0),
    });
    let ys: Vec<f64> = (0..n).map(|i| f(xs.get(i, 0), xs.get(i, 1))).collect();
    let grads = Matrix::from_fn(n, 2, |i, j| {
        if j == 0 {
            1.3 * (1.3 * xs.get(i, 0)).cos()
        } else {
            -0.7 * 1.9 * (1.9 * xs.get(i, 1)).sin()
        }
    });
    (xs, ys, grads)
}

fn rows(m: &Matrix, lo: usize, hi: usize) -> Matrix {
    Matrix::from_fn(hi - lo, m.cols, |i, j| m.get(lo + i, j))
}

/// Streamed `(y, ∇y)` ingestion — six warm singles plus one block —
/// matches a cold refit on the full 40-point data set to 1e-6 in both
/// mean and mean-gradient.
#[test]
fn streamed_grad_ingest_matches_cold_refit() {
    let (xs, ys, grads) = bo_style_data(21);
    let h = GpHypers::new(0.7, 1.0, 0.05);

    let prefix = MvmGp::new_with_grads(
        rows(&xs, 0, 28),
        ys[..28].to_vec(),
        rows(&grads, 0, 28),
        h,
        kiss_cfg(32),
    )
    .unwrap();
    let mut state = IncrementalState::from_mvm(&prefix, warm_only_cfg()).unwrap();
    for i in 28..34 {
        let report = state
            .ingest_with_grad(xs.row(i), ys[i], grads.row(i))
            .unwrap_or_else(|e| panic!("ingest row {i}: {e}"));
        assert_eq!(report.accepted, 1, "row {i}");
    }
    state
        .ingest_block_grads(&rows(&xs, 34, 40), &ys[34..40], &rows(&grads, 34, 40))
        .unwrap();
    assert_eq!(state.n(), 40);
    assert_eq!(state.num_grad_points(), 40);

    let mut cold =
        MvmGp::new_with_grads(xs.clone(), ys.clone(), grads, h, kiss_cfg(32)).unwrap();
    cold.refresh().unwrap();

    let mut rng = Rng::new(22);
    let q = Matrix::from_fn(25, 2, |_, _| rng.uniform_in(-0.95, 0.95));
    let (mean, want_mean) = (state.predict_mean(&q), cold.predict_mean(&q));
    let (grad, want_grad) = (state.predict_grad(&q), cold.predict_grad(&q));
    for i in 0..q.rows {
        assert!(
            (mean[i] - want_mean[i]).abs() <= 1e-6,
            "streamed mean at row {i}: {} vs cold {}",
            mean[i],
            want_mean[i]
        );
        for j in 0..2 {
            assert!(
                (grad.get(i, j) - want_grad.get(i, j)).abs() <= 1e-6,
                "streamed mean-gradient at row {i} axis {j}: {} vs cold {}",
                grad.get(i, j),
                want_grad.get(i, j)
            );
        }
    }
}

/// Mixed ingestion — value-only points interleaved with `(y, ∇y)` pairs —
/// then a forced full refresh: the rebuild re-derives the extended
/// operator from the same observation set, so predictions move ≤ 1e-6.
#[test]
fn mixed_ingest_survives_forced_refresh() {
    let (xs, ys, grads) = bo_style_data(33);
    let h = GpHypers::new(0.7, 1.0, 0.05);
    let prefix = MvmGp::new_with_grads(
        rows(&xs, 0, 30),
        ys[..30].to_vec(),
        rows(&grads, 0, 30),
        h,
        kiss_cfg(32),
    )
    .unwrap();
    let mut state = IncrementalState::from_mvm(&prefix, warm_only_cfg()).unwrap();
    for i in 30..40 {
        // Even rows stream a bare value, odd rows the full (y, ∇y) pair.
        if i % 2 == 0 {
            state.ingest(xs.row(i), ys[i]).unwrap();
        } else {
            state.ingest_with_grad(xs.row(i), ys[i], grads.row(i)).unwrap();
        }
    }
    assert_eq!(state.n(), 40);
    assert_eq!(state.num_grad_points(), 35);

    let mut rng = Rng::new(34);
    let q = Matrix::from_fn(20, 2, |_, _| rng.uniform_in(-0.95, 0.95));
    let warm_mean = state.predict_mean(&q);
    let warm_grad = state.predict_grad(&q);
    state.refresh().unwrap();
    let cold_mean = state.predict_mean(&q);
    let cold_grad = state.predict_grad(&q);
    for i in 0..q.rows {
        assert!(
            (warm_mean[i] - cold_mean[i]).abs() <= 1e-6,
            "refresh moved the mean at row {i}: {} vs {}",
            warm_mean[i],
            cold_mean[i]
        );
        for j in 0..2 {
            assert!(
                (warm_grad.get(i, j) - cold_grad.get(i, j)).abs() <= 1e-6,
                "refresh moved the mean-gradient at row {i} axis {j}: {} vs {}",
                warm_grad.get(i, j),
                cold_grad.get(i, j)
            );
        }
    }
}

/// A small frozen snapshot to carry pending entries through the format
/// tests.
fn base_snapshot(seed: u64) -> ModelSnapshot {
    let mut rng = Rng::new(seed);
    let xs = Matrix::from_fn(40, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> =
        (0..40).map(|i| xs.get(i, 0).sin() + 0.01 * rng.normal()).collect();
    let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.8, 1.0, 0.05));
    gp.refresh().unwrap();
    ModelSnapshot::from_exact(
        &gp,
        &SnapshotConfig {
            grid: Some(GridSpec::uniform(16)),
            variance: VarianceMode::Exact,
            ..Default::default()
        },
    )
    .unwrap()
}

/// FNV-1a, matching the snapshot trailer checksum.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Format v6 round-trips bitwise with a grad-carrying pending entry next
/// to a grad-free one, and every v1–v4 fixture file still migrates
/// (their pending logs are necessarily gradient-free).
#[test]
fn snapshot_v6_roundtrips_and_every_fixture_migrates() {
    let mut snap = base_snapshot(41);
    snap.pending = vec![
        Observation {
            seq: 3,
            task: 0,
            x: vec![0.25, -0.5],
            y: 1.25,
            grad: Some(vec![0.5, -2.0]),
        },
        Observation { seq: 4, task: 0, x: vec![0.1, 0.2], y: -0.75, grad: None },
    ];
    let bytes = snap.to_bytes();
    let back = ModelSnapshot::from_bytes(&bytes).unwrap();
    assert_eq!(back.version, SNAPSHOT_VERSION);
    assert_eq!(back.pending, snap.pending, "pending gradients must survive");
    assert_eq!(back.to_bytes(), bytes, "v6 round-trip must be bitwise");

    let q = Matrix::from_vec(3, 2, vec![0.1, -0.3, 0.6, 0.1, -0.4, -0.2]);
    for (file, ver) in [
        ("snapshot_v1.bin", 1u32),
        ("snapshot_v2.bin", 2),
        ("snapshot_v3.bin", 3),
        ("snapshot_v4.bin", 4),
    ] {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("rust/tests/fixtures")
            .join(file);
        let raw = std::fs::read(&path).unwrap_or_else(|e| panic!("{file}: {e}"));
        let old = ModelSnapshot::from_bytes(&raw).unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(old.version, ver, "{file}");
        assert!(
            old.pending.iter().all(|o| o.grad.is_none()),
            "{file}: historical formats predate derivative observations"
        );
        let mean = old.cache.predict_mean(&q);
        let resaved = ModelSnapshot::from_bytes(&old.to_bytes())
            .unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(resaved.version, SNAPSHOT_VERSION, "{file}");
        assert_eq!(resaved.cache.predict_mean(&q), mean, "{file}: migration changed means");
        assert_eq!(resaved.pending, old.pending, "{file}: pending log must survive");
    }
}

/// v5 migration, spliced programmatically (no fixture file exists for
/// v5): a v5 file is a v6 file minus the 4-byte grad flag per pending
/// entry. Dropping the flags, patching the version word, and
/// re-checksumming yields a file that loads with `grad = None`
/// everywhere and re-saves bitwise-identical to the native v6 encoding.
#[test]
fn snapshot_v5_splice_migrates_gradient_free() {
    let mut snap = base_snapshot(42);
    snap.pending = vec![
        Observation { seq: 7, task: 0, x: vec![0.5, -0.25], y: 1.5, grad: None },
        Observation { seq: 9, task: 0, x: vec![0.0, 0.75], y: -0.5, grad: None },
    ];
    let v6 = snap.to_bytes();
    let d = 2;
    let entry_v6 = 8 + 4 + d * 8 + 8 + 4; // seq, task, x, y, grad flag
    // The single-task file tail is the 4-byte task flag plus the 8-byte
    // checksum; the pending section is a 4-byte count then the entries.
    let pend_start = v6.len() - 12 - 4 - 2 * entry_v6;
    let mut v5 = Vec::with_capacity(v6.len() - 8);
    v5.extend_from_slice(&v6[..pend_start + 4]);
    for i in 0..2 {
        let start = pend_start + 4 + i * entry_v6;
        v5.extend_from_slice(&v6[start..start + entry_v6 - 4]);
    }
    v5.extend_from_slice(&v6[v6.len() - 12..v6.len() - 8]);
    v5[8..12].copy_from_slice(&5u32.to_le_bytes());
    let sum = fnv1a(&v5);
    v5.extend_from_slice(&sum.to_le_bytes());

    let migrated = ModelSnapshot::from_bytes(&v5).unwrap();
    assert_eq!(migrated.version, 5);
    assert_eq!(
        migrated.pending, snap.pending,
        "v5 entries migrate with grad = None"
    );
    assert_eq!(migrated.to_bytes(), v6, "re-save must be the native v6 encoding");
}

/// Large-n D-SKI: 2 000 points × 3 rows each is a 6 000-row extended
/// system — the scale where the dense oracle is already infeasible.
#[test]
#[ignore = "scale test: ~6k-row extended operator; run in the nightly --ignored lane"]
fn dski_large_n_builds_streams_and_predicts() {
    let mut rng = Rng::new(99);
    let n = 2000;
    let f = |x0: f64, x1: f64| (1.1 * x0).sin() + 0.5 * (1.7 * x1).cos();
    let xs = Matrix::from_fn(n, 2, |i, j| match (i, j) {
        (0, _) => -1.0,
        (1, _) => 1.0,
        _ => rng.uniform_in(-1.0, 1.0),
    });
    let ys: Vec<f64> = (0..n).map(|i| f(xs.get(i, 0), xs.get(i, 1))).collect();
    let grads = Matrix::from_fn(n, 2, |i, j| {
        if j == 0 {
            1.1 * (1.1 * xs.get(i, 0)).cos()
        } else {
            -0.5 * 1.7 * (1.7 * xs.get(i, 1)).sin()
        }
    });
    let cfg = MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid: GridSpec::uniform(64),
        cg: CgConfig { max_iters: 1500, tol: 1e-8, ..Default::default() },
        ..Default::default()
    };
    let gp = MvmGp::new_with_grads(xs, ys, grads, GpHypers::new(0.6, 1.0, 0.05), cfg)
        .unwrap();
    let mut state = IncrementalState::from_mvm(&gp, warm_only_cfg()).unwrap();

    let q = Matrix::from_fn(64, 2, |_, _| rng.uniform_in(-0.9, 0.9));
    let mean = state.predict_mean(&q);
    let grad = state.predict_grad(&q);
    let mut seen = HashSet::new();
    for i in 0..q.rows {
        assert!(mean[i].is_finite(), "mean at row {i}");
        assert!(
            grad.get(i, 0).is_finite() && grad.get(i, 1).is_finite(),
            "gradient at row {i}"
        );
        // The surrogate should track the smooth target at this density.
        assert!(
            (mean[i] - f(q.get(i, 0), q.get(i, 1))).abs() < 0.2,
            "mean at row {i} drifted: {} vs {}",
            mean[i],
            f(q.get(i, 0), q.get(i, 1))
        );
        seen.insert(mean[i].to_bits());
    }
    assert!(seen.len() > 1, "predictions must not collapse to a constant");

    for k in 0..8 {
        let x = [rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0)];
        let (y, g) = (
            f(x[0], x[1]),
            [1.1 * (1.1 * x[0]).cos(), -0.5 * 1.7 * (1.7 * x[1]).sin()],
        );
        let report = state.ingest_with_grad(&x, y, &g).unwrap();
        assert_eq!(report.accepted, 1, "streamed point {k}");
    }
    assert_eq!(state.n(), n + 8);
    assert_eq!(state.num_grad_points(), n + 8);
}
