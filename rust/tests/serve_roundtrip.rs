//! Integration tests for the serving subsystem: snapshot round-trips,
//! cached-prediction accuracy against the dense `ExactGp` references, and
//! batched-vs-one-at-a-time serving equivalence (t ∈ {1, 8, 64}).

#![allow(clippy::needless_range_loop)] // index-heavy numeric test/bench loops

use skip_gp::gp::{ExactGp, GpHypers};
use skip_gp::grid::{Grid1d, GridSpec};
use skip_gp::linalg::Matrix;
use skip_gp::serve::{
    BatcherConfig, ModelSnapshot, RequestBatcher, ServeEngine, Server, ServerConfig,
    SnapshotConfig, SnapshotVariant, VarianceMode, SNAPSHOT_VERSION,
};
use skip_gp::solvers::CgConfig;
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("skipgp-serve-{tag}-{}.snap", std::process::id()))
}

/// n=256, d=3 training set whose points sit exactly on the serving grid's
/// nodes, so the cubic stencil is exact (weight 1 on the node) and the
/// cache path reproduces the dense algebra to rounding.
fn on_grid_problem(
    n: usize,
    seed: u64,
) -> (Matrix, Vec<f64>, Vec<Grid1d>, Matrix) {
    let d = 3;
    let m = 16;
    let g = Grid1d::fit(0.0, 1.0, m).unwrap();
    let mut rng = Rng::new(seed);
    let mut lattice = |rows: usize| {
        Matrix::from_fn(rows, d, |_, _| {
            // Interior nodes only (full cubic stencil).
            g.point(2 + rng.below(m - 4))
        })
    };
    let xs = lattice(n);
    let xt = lattice(64);
    let ys: Vec<f64> = (0..n)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + (3.0 * r[1]).cos() * r[2] + 0.05 * rng.normal()
        })
        .collect();
    (xs, ys, vec![g.clone(), g.clone(), g], xt)
}

/// Acceptance: cached predict_mean / predict_var match the ExactGp dense
/// references within 1e-6 on an n=256, d=3 problem.
#[test]
fn cached_predictions_match_exact_gp_within_1e6() {
    let (xs, ys, grids, xt) = on_grid_problem(256, 1);
    let h = GpHypers::new(0.45, 1.3, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let want_mean = gp.predict_mean(&xt);
    let want_var = gp.predict_var(&xt);

    let snap = ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Exact).unwrap();
    let got_mean = snap.cache.predict_mean(&xt);
    let got_var = snap.cache.predict_var(&xt);

    for i in 0..xt.rows {
        assert!(
            (got_mean[i] - want_mean[i]).abs() < 1e-6,
            "mean[{i}]: cached {} vs exact {}",
            got_mean[i],
            want_mean[i]
        );
        assert!(
            (got_var[i] - want_var[i]).abs() < 1e-6,
            "var[{i}]: cached {} vs exact {}",
            got_var[i],
            want_var[i]
        );
    }
}

/// Off-grid queries: the cache inherits only the (small) SKI interpolation
/// error.
#[test]
fn cached_predictions_accurate_off_grid() {
    let (xs, ys, grids, _) = on_grid_problem(256, 2);
    let h = GpHypers::new(0.45, 1.3, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap = ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Exact).unwrap();
    let mut rng = Rng::new(3);
    let xt = Matrix::from_fn(64, 3, |_, _| rng.uniform_in(0.15, 0.85));
    let want_mean = gp.predict_mean(&xt);
    let want_var = gp.predict_var(&xt);
    let got_mean = snap.cache.predict_mean(&xt);
    let got_var = snap.cache.predict_var(&xt);
    let mmae = skip_gp::util::mae(&got_mean, &want_mean);
    let vmae = skip_gp::util::mae(&got_var, &want_var);
    assert!(mmae < 5e-3, "off-grid mean mae {mmae}");
    assert!(vmae < 5e-3, "off-grid var mae {vmae}");
}

/// Snapshot → save → load → bitwise-equal predictions.
#[test]
fn snapshot_file_roundtrip_is_bitwise_equal() {
    let (xs, ys, grids, xt) = on_grid_problem(128, 4);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Lanczos(32)).unwrap();

    let path = tmpfile("roundtrip");
    snap.save(&path).unwrap();
    let back = ModelSnapshot::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(back.hypers, snap.hypers);
    assert_eq!(back.alpha, snap.alpha);
    // Bitwise-identical predictions, mean and variance, on- and off-grid.
    let mut rng = Rng::new(5);
    let off = Matrix::from_fn(40, 3, |_, _| rng.uniform_in(0.0, 1.0));
    for q in [&xt, &off] {
        assert_eq!(snap.cache.predict_mean(q), back.cache.predict_mean(q));
        assert_eq!(snap.cache.predict_var(q), back.cache.predict_var(q));
    }
}

/// Batched serving equals one-at-a-time serving, bit for bit, at
/// t ∈ {1, 8, 64}.
#[test]
fn batched_serving_equals_one_at_a_time() {
    let (xs, ys, grids, _) = on_grid_problem(128, 6);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Lanczos(24)).unwrap();
    let mut rng = Rng::new(7);
    let queries: Vec<Vec<f64>> = (0..256)
        .map(|_| (0..3).map(|_| rng.uniform_in(0.0, 1.0)).collect())
        .collect();
    // One-at-a-time reference straight off the cache.
    let reference: Vec<(f64, f64)> = queries
        .iter()
        .map(|q| (snap.cache.predict_mean_one(q), snap.cache.predict_var_one(q)))
        .collect();

    for t in [1usize, 8, 64] {
        let engine = Arc::new(ServeEngine::new(snap.clone()).unwrap());
        let batcher = RequestBatcher::start(
            engine.clone(),
            BatcherConfig {
                max_batch: t,
                max_wait: std::time::Duration::from_millis(1),
            },
        );
        let handle = batcher.handle();
        // Submit everything up front so batches actually fill to t…
        let pending: Vec<_> = queries.iter().map(|q| handle.submit(q)).collect();
        // …then drain in order.
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.batch_size >= 1 && resp.batch_size <= t);
            assert_eq!(
                (resp.mean, resp.var),
                reference[i],
                "t={t}, query {i}: batched != one-at-a-time"
            );
        }
        let served = engine.metrics.counter("serve.points");
        assert_eq!(served, queries.len() as u64);
        if t == 1 {
            // max_batch=1 must never coalesce.
            let hist = engine.metrics.value_histogram("serve.batch_size");
            assert_eq!(hist.keys().copied().max(), Some(1));
        }
        drop(handle);
        batcher.shutdown();
    }
}

/// The TCP front-end serves the same numbers the cache computes, via the
/// shortest-round-trip float formatting.
#[test]
fn tcp_server_round_trip() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let (xs, ys, grids, _) = on_grid_problem(96, 8);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Lanczos(16)).unwrap();
    let engine = Arc::new(ServeEngine::new(snap.clone()).unwrap());
    let server = Server::start(
        engine,
        ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            batcher: BatcherConfig::default(),
        },
    )
    .unwrap();
    let addr = server.addr();

    {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();

        writeln!(writer, "ping").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok pong");

        line.clear();
        writeln!(writer, "dim").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok 3");

        line.clear();
        writeln!(writer, "predict 0.4 0.5 0.6").unwrap();
        reader.read_line(&mut line).unwrap();
        let toks: Vec<&str> = line.trim().split_whitespace().collect();
        assert_eq!(toks[0], "ok", "line: {line}");
        let mean: f64 = toks[1].parse().unwrap();
        let var: f64 = toks[2].parse().unwrap();
        assert_eq!(mean, snap.cache.predict_mean_one(&[0.4, 0.5, 0.6]));
        assert_eq!(var, snap.cache.predict_var_one(&[0.4, 0.5, 0.6]));

        line.clear();
        writeln!(writer, "predict 1.0 2.0").unwrap(); // wrong arity
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("err"), "line: {line}");

        line.clear();
        writeln!(writer, "stats").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("qps="), "line: {line}");

        writeln!(writer, "quit").unwrap();
    } // connection closes here, releasing its batcher handle
    server.shutdown();
}

/// Mean-only snapshots refuse to serve (no silent missing uncertainty),
/// and the budget guard refuses absurd grids.
#[test]
fn serving_guards() {
    let (xs, ys, grids, _) = on_grid_problem(64, 9);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let mean_only =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::None).unwrap();
    let err = match ServeEngine::new(mean_only) {
        Ok(_) => panic!("mean-only snapshot must not serve"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("variance"), "{err}");

    let err = ModelSnapshot::from_exact(
        &gp,
        &SnapshotConfig {
            grid: Some(GridSpec::uniform(512)),
            variance: VarianceMode::None,
            max_grid_cells: 1 << 20,
            ..Default::default()
        },
    )
    .unwrap_err();
    assert!(err.to_string().contains("budget"), "{err}");
}

/// Path of the checked-in format-version-1 snapshot fixture. Its payload
/// is synthetic but deterministic: d=2, n=6, r=3, Exact variant,
/// hypers (log ℓ, log σ_f², log σ_n²) = (−0.25, 0.125, −3),
/// grids (min −1.25, h 0.25, m 12) × (min −0.5, h 0.125, m 9),
/// α[i] = 0.25·i − 0.75, mean[i] = i·0.015625 − 0.5,
/// var[i·3+j] = ((i·3+j) mod 17)·0.03125 − 0.25 — every value exactly
/// representable, so the assertions below are bitwise.
fn v1_fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/snapshot_v1.bin")
}

/// v1 files load through the in-memory migration: a single term with
/// coefficient 1 and a rectilinear spec derived from the stored axes —
/// and predict **identically** after a v2 re-save.
#[test]
fn v1_fixture_migrates_and_predicts_identically() {
    let bytes = std::fs::read(v1_fixture_path()).expect("v1 fixture present");
    let snap = ModelSnapshot::from_bytes(&bytes).expect("v1 fixture loads");

    // Migrated structure.
    assert_eq!(snap.version, 1, "version field records what was read");
    assert_eq!(snap.cache.dim(), 2);
    assert_eq!(snap.alpha.len(), 6);
    assert_eq!(snap.cache.var_rank(), 3);
    assert_eq!(snap.cache.terms().len(), 1, "v1 had exactly one implicit term");
    let term = &snap.cache.terms()[0];
    assert_eq!(term.coeff, 1.0);
    assert_eq!(term.axes[0].m, 12);
    assert_eq!(term.axes[1].m, 9);
    assert_eq!(snap.cache.spec, GridSpec::Rectilinear(vec![12, 9]));

    // Exact payload values (all exactly representable).
    assert_eq!(snap.hypers.log_ell, -0.25);
    assert_eq!(snap.hypers.log_sf2, 0.125);
    assert_eq!(snap.hypers.log_sn2, -3.0);
    assert_eq!(snap.alpha[1], -0.5);
    assert_eq!(term.mean[0], -0.5);
    assert_eq!(term.mean[4], 4.0 * 0.015625 - 0.5);
    assert_eq!(term.var_r.get(0, 1), 0.03125 - 0.25);

    // Migration predicts identically through a v2 re-save.
    let q = Matrix::from_vec(
        5,
        2,
        vec![0.1, -0.3, 0.7, 0.2, -0.5, -0.8, 0.0, 0.0, 0.9, 0.4],
    );
    let mean_v1 = snap.cache.predict_mean(&q);
    let var_v1 = snap.cache.predict_var(&q);
    let v3_bytes = snap.to_bytes();
    assert_ne!(v3_bytes, bytes, "writers always emit the newest version");
    let back = ModelSnapshot::from_bytes(&v3_bytes).expect("v3 re-save loads");
    assert_eq!(back.version, SNAPSHOT_VERSION);
    assert!(back.pending.is_empty(), "migrated v1 has no pending log");
    assert_eq!(back.cache.spec, snap.cache.spec);
    assert_eq!(back.cache.predict_mean(&q), mean_v1, "migration changed means");
    assert_eq!(back.cache.predict_var(&q), var_v1, "migration changed variances");
    for (m, v) in mean_v1.iter().zip(&var_v1) {
        assert!(m.is_finite() && v.is_finite() && *v > 0.0);
    }
}

/// Path of the checked-in format-version-2 snapshot fixture. Synthetic
/// but deterministic: d=2, n=5, r=2, KISS variant, train/refresh ranks
/// 7/9, hypers (log ℓ, log σ_f², log σ_n²) = (−0.25, 0.125, −3),
/// rectilinear spec [10, 8], one term with coefficient 1 and axes
/// (min −1.25, h 0.25, m 10) × (min −0.5, h 0.125, m 8),
/// α[i] = 0.25·i − 0.5, mean[i] = i·0.015625 − 0.5,
/// var[i] = (i mod 17)·0.03125 − 0.25 — every value exactly
/// representable, so the assertions below are bitwise.
fn v2_fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/snapshot_v2.bin")
}

/// v2 files load through the in-memory migration — an empty pending log
/// — and predict **identically** after a v3 re-save (the same bitwise
/// pin the v1→v2 migration carries).
#[test]
fn v2_fixture_migrates_and_predicts_identically() {
    let bytes = std::fs::read(v2_fixture_path()).expect("v2 fixture present");
    let snap = ModelSnapshot::from_bytes(&bytes).expect("v2 fixture loads");

    // Migrated structure.
    assert_eq!(snap.version, 2, "version field records what was read");
    assert!(snap.pending.is_empty(), "v2 migrates to an empty pending log");
    assert_eq!(snap.cache.dim(), 2);
    assert_eq!(snap.alpha.len(), 5);
    assert_eq!(snap.cache.var_rank(), 2);
    assert_eq!(snap.cache.spec, GridSpec::Rectilinear(vec![10, 8]));
    assert_eq!(snap.cache.terms().len(), 1);

    // Exact payload values (all exactly representable).
    let term = &snap.cache.terms()[0];
    assert_eq!(term.coeff, 1.0);
    assert_eq!(term.axes[0].min, -1.25);
    assert_eq!(term.axes[0].h, 0.25);
    assert_eq!(term.axes[0].m, 10);
    assert_eq!(term.axes[1].m, 8);
    assert_eq!(snap.hypers.log_ell, -0.25);
    assert_eq!(snap.hypers.log_sf2, 0.125);
    assert_eq!(snap.hypers.log_sn2, -3.0);
    assert_eq!(snap.alpha[3], 0.25);
    assert_eq!(term.mean[4], 4.0 * 0.015625 - 0.5);
    assert_eq!(term.var_r.get(0, 1), 0.03125 - 0.25);

    // Migration predicts identically through a v3 re-save.
    let q = Matrix::from_vec(
        4,
        2,
        vec![-1.0, -0.4, 0.3, 0.1, 0.9, 0.4, -0.2, -0.45],
    );
    let mean_v2 = snap.cache.predict_mean(&q);
    let var_v2 = snap.cache.predict_var(&q);
    let v3_bytes = snap.to_bytes();
    assert_ne!(v3_bytes, bytes, "writers always emit the newest version");
    let back = ModelSnapshot::from_bytes(&v3_bytes).expect("v3 re-save loads");
    assert_eq!(back.version, SNAPSHOT_VERSION);
    assert!(back.pending.is_empty());
    assert_eq!(back.cache.predict_mean(&q), mean_v2, "migration changed means");
    assert_eq!(back.cache.predict_var(&q), var_v2, "migration changed variances");
    for (m, v) in mean_v2.iter().zip(&var_v2) {
        assert!(m.is_finite() && v.is_finite() && *v > 0.0);
    }
}

/// Path of the checked-in format-version-3 snapshot fixture (generated
/// by tools/make_snapshot_fixtures.py). Synthetic but deterministic:
/// d=2, n=6, r=2, SKIP variant, train/refresh ranks 9/15, hypers
/// (log ℓ, log σ_f², log σ_n²) = (−0.25, 0.125, −3), rectilinear spec
/// [10, 9], one term with coefficient 1 and axes
/// (min −1.25, h 0.25, m 10) × (min −0.5, h 0.125, m 9),
/// α[i] = 0.25·i − 0.5, mean[i] = i·0.015625 − 0.5,
/// var[i] = (i mod 17)·0.03125 − 0.25, one pending observation
/// (seq 7, x = [0.5, −0.25], y = 2.25) — every value exactly
/// representable, so the assertions below are bitwise.
fn v3_fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/snapshot_v3.bin")
}

/// v3 files predate the `alpha_space` provenance field: they migrate to
/// data-space (`alpha_space = 0`) with their pending log intact, and
/// predict **identically** after a v5 re-save.
#[test]
fn v3_fixture_migrates_and_predicts_identically() {
    let bytes = std::fs::read(v3_fixture_path()).expect("v3 fixture present");
    let snap = ModelSnapshot::from_bytes(&bytes).expect("v3 fixture loads");

    // Migrated structure.
    assert_eq!(snap.version, 3, "version field records what was read");
    assert_eq!(snap.variant, SnapshotVariant::Skip);
    assert_eq!(snap.alpha_space, 0, "v3 migrates to data-space provenance");
    assert!(snap.tasks.is_none(), "v3 predates the multi-task head");
    assert_eq!(snap.train_rank, 9);
    assert_eq!(snap.refresh_rank, 15);
    assert_eq!(snap.cache.dim(), 2);
    assert_eq!(snap.alpha.len(), 6);
    assert_eq!(snap.cache.var_rank(), 2);
    assert_eq!(snap.cache.spec, GridSpec::Rectilinear(vec![10, 9]));

    // Exact payload values (all exactly representable).
    let term = &snap.cache.terms()[0];
    assert_eq!(term.coeff, 1.0);
    assert_eq!(term.axes[0].min, -1.25);
    assert_eq!(term.axes[0].h, 0.25);
    assert_eq!(term.axes[0].m, 10);
    assert_eq!(term.axes[1].m, 9);
    assert_eq!(snap.hypers.log_ell, -0.25);
    assert_eq!(snap.hypers.log_sf2, 0.125);
    assert_eq!(snap.hypers.log_sn2, -3.0);
    assert_eq!(snap.alpha[2], 0.0);
    assert_eq!(term.mean[4], 4.0 * 0.015625 - 0.5);
    assert_eq!(term.var_r.get(0, 1), 0.03125 - 0.25);

    // The pending log (new in v3) survives, carrying task 0 after the
    // migration to the task-aware entry layout.
    assert_eq!(snap.pending.len(), 1);
    assert_eq!(snap.pending[0].seq, 7);
    assert_eq!(snap.pending[0].task, 0, "pre-v5 pending entries are task 0");
    assert_eq!(snap.pending[0].x, vec![0.5, -0.25]);
    assert_eq!(snap.pending[0].y, 2.25);

    // Migration predicts identically through a v5 re-save.
    let q = Matrix::from_vec(4, 2, vec![-0.9, -0.4, 0.3, 0.1, 0.8, 0.4, -0.2, -0.45]);
    let mean_v3 = snap.cache.predict_mean(&q);
    let var_v3 = snap.cache.predict_var(&q);
    let v5_bytes = snap.to_bytes();
    assert_ne!(v5_bytes, bytes, "writers always emit the newest version");
    let back = ModelSnapshot::from_bytes(&v5_bytes).expect("v5 re-save loads");
    assert_eq!(back.version, SNAPSHOT_VERSION);
    assert_eq!(back.alpha_space, 0);
    assert!(back.tasks.is_none());
    assert_eq!(back.pending, snap.pending, "pending log must survive the re-save");
    assert_eq!(back.cache.predict_mean(&q), mean_v3, "migration changed means");
    assert_eq!(back.cache.predict_var(&q), var_v3, "migration changed variances");
    for (m, v) in mean_v3.iter().zip(&var_v3) {
        assert!(m.is_finite() && v.is_finite() && *v > 0.0);
    }
}

/// Path of the checked-in format-version-4 snapshot fixture (generated
/// by tools/make_snapshot_fixtures.py). Synthetic but deterministic:
/// d=2, n=7, r=2, KISS variant, train/refresh ranks 11/13, grid-space
/// α provenance (`alpha_space = 1` — the field v4 introduced), hypers
/// (−0.25, 0.125, −3), rectilinear spec [11, 7], one term with
/// coefficient 1 and axes (min −1.25, h 0.25, m 11) ×
/// (min −0.5, h 0.125, m 7), α[i] = 0.25·i − 0.75,
/// mean[i] = i·0.015625 − 0.5, var[i] = (i mod 17)·0.03125 − 0.25, two
/// pending observations (seq 2, [0.25, −0.375], 1.5) and
/// (seq 5, [−1.0, 0.125], −0.75) — every value exactly representable,
/// so the assertions below are bitwise.
fn v4_fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/snapshot_v4.bin")
}

/// v4 files carry `alpha_space` but predate the multi-task payload:
/// loading preserves the provenance bit, migrates the pending entries
/// to task 0, leaves the task head empty, and predicts **identically**
/// after a v5 re-save.
#[test]
fn v4_fixture_migrates_and_predicts_identically() {
    let bytes = std::fs::read(v4_fixture_path()).expect("v4 fixture present");
    let snap = ModelSnapshot::from_bytes(&bytes).expect("v4 fixture loads");

    // Migrated structure.
    assert_eq!(snap.version, 4, "version field records what was read");
    assert_eq!(snap.variant, SnapshotVariant::Kiss);
    assert_eq!(snap.alpha_space, 1, "v4's provenance field is preserved");
    assert!(snap.tasks.is_none(), "v4 predates the multi-task head");
    assert_eq!(snap.num_tasks(), 1);
    assert!(!snap.is_multitask());
    assert_eq!(snap.train_rank, 11);
    assert_eq!(snap.refresh_rank, 13);
    assert_eq!(snap.cache.dim(), 2);
    assert_eq!(snap.alpha.len(), 7);
    assert_eq!(snap.cache.var_rank(), 2);
    assert_eq!(snap.cache.spec, GridSpec::Rectilinear(vec![11, 7]));

    // Exact payload values (all exactly representable).
    let term = &snap.cache.terms()[0];
    assert_eq!(term.coeff, 1.0);
    assert_eq!(term.axes[0].min, -1.25);
    assert_eq!(term.axes[0].h, 0.25);
    assert_eq!(term.axes[0].m, 11);
    assert_eq!(term.axes[1].m, 7);
    assert_eq!(snap.hypers.log_ell, -0.25);
    assert_eq!(snap.hypers.log_sf2, 0.125);
    assert_eq!(snap.hypers.log_sn2, -3.0);
    assert_eq!(snap.alpha[3], 0.0);
    assert_eq!(term.mean[4], 4.0 * 0.015625 - 0.5);
    assert_eq!(term.var_r.get(0, 1), 0.03125 - 0.25);

    // Pending entries migrate to task 0 (v4 had no per-entry task id).
    assert_eq!(snap.pending.len(), 2);
    assert_eq!(snap.pending[0].seq, 2);
    assert_eq!(snap.pending[0].x, vec![0.25, -0.375]);
    assert_eq!(snap.pending[0].y, 1.5);
    assert_eq!(snap.pending[1].seq, 5);
    assert_eq!(snap.pending[1].x, vec![-1.0, 0.125]);
    assert_eq!(snap.pending[1].y, -0.75);
    assert!(snap.pending.iter().all(|o| o.task == 0));

    // Migration predicts identically through a v5 re-save.
    let q = Matrix::from_vec(4, 2, vec![0.1, -0.3, 0.7, 0.2, -0.5, -0.4, 1.0, 0.0]);
    let mean_v4 = snap.cache.predict_mean(&q);
    let var_v4 = snap.cache.predict_var(&q);
    let v5_bytes = snap.to_bytes();
    assert_ne!(v5_bytes, bytes, "writers always emit the newest version");
    let back = ModelSnapshot::from_bytes(&v5_bytes).expect("v5 re-save loads");
    assert_eq!(back.version, SNAPSHOT_VERSION);
    assert_eq!(back.alpha_space, 1, "provenance survives the re-save");
    assert!(back.tasks.is_none());
    assert_eq!(back.pending, snap.pending, "pending log must survive the re-save");
    assert_eq!(back.cache.predict_mean(&q), mean_v4, "migration changed means");
    assert_eq!(back.cache.predict_var(&q), var_v4, "migration changed variances");
    for (m, v) in mean_v4.iter().zip(&var_v4) {
        assert!(m.is_finite() && v.is_finite() && *v > 0.0);
    }
}

/// Concurrent serving: multiple TCP clients interleave `observe` and
/// `predict`; after every streamed point is acknowledged, predictions
/// match a cold model built on the full point set to 1e-6.
#[test]
fn concurrent_observe_and_predict_matches_cold_refit() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let d = 2;
    let (n0, n_stream, clients) = (160, 48, 3);
    let mut rng = Rng::new(42);
    let xs0 = Matrix::from_fn(n0, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let f = |r: &[f64]| (2.0 * r[0]).sin() + (3.0 * r[1]).cos();
    let ys0: Vec<f64> = (0..n0).map(|i| f(xs0.row(i)) + 0.02 * rng.normal()).collect();
    let streamed: Vec<(Vec<f64>, f64)> = (0..n_stream)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-0.9, 0.9)).collect();
            let y = f(&x) + 0.02 * rng.normal();
            (x, y)
        })
        .collect();

    // Explicit fixed axes keep the live and cold models on the *same*
    // inducing grid regardless of data bounds.
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 16).unwrap(),
        Grid1d::fit(-1.0, 1.0, 16).unwrap(),
    ];
    let h = GpHypers::new(0.6, 1.0, 0.05);
    let cg = CgConfig { max_iters: 600, tol: 1e-11, ..Default::default() };
    // Exact variance, rebuilt every ingest (drift budget 0), no policy
    // refreshes — the test exercises the purely-incremental path.
    let scfg = StreamConfig {
        refresh_every: 0,
        var_drift_budget: 0,
        error_z: 0.0,
        log_capacity: 4096,
        variance: VarianceMode::Exact,
        patch_eps: 1e-12,
        ..Default::default()
    };
    let live = IncrementalState::new(
        xs0.clone(),
        ys0.clone(),
        h,
        axes.clone(),
        cg,
        scfg.clone(),
    )
    .unwrap();
    let engine = Arc::new(ServeEngine::new_live(live).unwrap());
    assert!(engine.is_live());
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            batcher: BatcherConfig::default(),
        },
    )
    .unwrap();
    let addr = server.addr();

    // Interleaved observe + predict traffic from several clients.
    std::thread::scope(|s| {
        for c in 0..clients {
            let chunk: Vec<(Vec<f64>, f64)> = streamed
                .iter()
                .skip(c)
                .step_by(clients)
                .cloned()
                .collect();
            s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                for (x, y) in &chunk {
                    line.clear();
                    writeln!(writer, "observe {} {} {}", x[0], x[1], y).unwrap();
                    reader.read_line(&mut line).unwrap();
                    assert!(line.starts_with("ok "), "observe ack: {line}");
                    // Interleave a predict; mid-stream values reflect a
                    // prefix of the data, so only sanity-check them.
                    line.clear();
                    writeln!(writer, "predict {} {}", x[0], x[1]).unwrap();
                    reader.read_line(&mut line).unwrap();
                    assert!(line.starts_with("ok "), "predict: {line}");
                }
                writeln!(writer, "quit").unwrap();
            });
        }
    });

    // Every observation acknowledged ⇒ the published model holds all
    // n0 + n_stream points.
    assert_eq!(
        engine.metrics.counter("stream.points"),
        n_stream as u64,
        "all streamed points ingested"
    );

    // Cold reference: the same model built in one shot on the full set.
    let mut xs_full = xs0.clone();
    let mut ys_full = ys0.clone();
    for (x, y) in &streamed {
        xs_full.data.extend_from_slice(x);
        xs_full.rows += 1;
        ys_full.push(*y);
    }
    let cold = IncrementalState::new(xs_full, ys_full, h, axes, cg, scfg).unwrap();

    // Final predictions over TCP match the cold model to 1e-6.
    {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        for _ in 0..40 {
            let q = [rng.uniform_in(-0.8, 0.8), rng.uniform_in(-0.8, 0.8)];
            line.clear();
            writeln!(writer, "predict {} {}", q[0], q[1]).unwrap();
            reader.read_line(&mut line).unwrap();
            let toks: Vec<&str> = line.trim().split_whitespace().collect();
            assert_eq!(toks[0], "ok", "line: {line}");
            let mean: f64 = toks[1].parse().unwrap();
            let var: f64 = toks[2].parse().unwrap();
            let want_mean = cold.cache().predict_mean_one(&q);
            let want_var = cold.cache().predict_var_one(&q);
            assert!(
                (mean - want_mean).abs() < 1e-6,
                "streamed mean {mean} vs cold {want_mean}"
            );
            assert!(
                (var - want_var).abs() < 1e-6,
                "streamed var {var} vs cold {want_var}"
            );
        }
        writeln!(writer, "quit").unwrap();
    }
    server.shutdown();
}

/// Frozen engines refuse `observe` with a typed error over the wire.
#[test]
fn frozen_engine_rejects_observe() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let (xs, ys, grids, _) = on_grid_problem(64, 10);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Lanczos(16)).unwrap();
    let engine = Arc::new(ServeEngine::new(snap).unwrap());
    assert!(!engine.is_live());
    let server = Server::start(
        engine,
        ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            batcher: BatcherConfig::default(),
        },
    )
    .unwrap();
    let addr = server.addr();
    {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        writeln!(writer, "observe 0.4 0.5 0.6 1.0").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("err"), "line: {line}");
        assert!(line.contains("live"), "line: {line}");
        // Bad arity and non-finite values are per-connection errors.
        line.clear();
        writeln!(writer, "observe 0.4 0.5").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("err"), "line: {line}");
        line.clear();
        writeln!(writer, "observe 0.4 0.5 0.6 nan").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("err"), "line: {line}");
        writeln!(writer, "quit").unwrap();
    }
    server.shutdown();
}

/// Legacy-server shutdown regression: `shutdown()` must return promptly
/// even with connections still open mid-session (the accept loop blocks
/// now — the self-connect wake has to reach it), and after it returns
/// every connection is force-closed, so no handler thread outlives the
/// server.
#[test]
fn server_shutdown_closes_open_connections_promptly() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::{Duration, Instant};

    let (xs, ys, grids, _) = on_grid_problem(64, 12);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Lanczos(16)).unwrap();
    let engine = Arc::new(ServeEngine::new(snap).unwrap());
    let server = Server::start(
        engine,
        ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            batcher: BatcherConfig::default(),
        },
    )
    .unwrap();
    let addr = server.addr();

    // Two live connections: one mid-protocol, one fully idle. Neither
    // says `quit`.
    let active = TcpStream::connect(addr).unwrap();
    active.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let idle = TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = active.try_clone().unwrap();
    let mut reader = BufReader::new(active);
    let mut line = String::new();
    writeln!(writer, "ping").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "ok pong");

    let t0 = Instant::now();
    server.shutdown();
    let took = t0.elapsed();
    assert!(took < Duration::from_secs(5), "shutdown hung for {took:?}");

    // Both sockets see EOF: the server force-closed them and joined the
    // handlers (the old code leaked the handler threads here).
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap_or(0), 0, "active: {line}");
    let mut reader = BufReader::new(idle);
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap_or(0), 0, "idle: {line}");
}

/// Multi-model routing over TCP through the fleet front-end: two models
/// in one registry, addressed per-request with `model <id>`, each
/// serving its own snapshot's predictions; `models` lists both; an
/// unaddressed request (no default model configured) is a clean error.
#[test]
fn fleet_routes_requests_to_the_addressed_model() {
    use skip_gp::coordinator::Metrics;
    use skip_gp::serve::{FleetConfig, FleetServer, ModelRegistry, RegistryConfig};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let dir = std::env::temp_dir()
        .join(format!("skipgp-fleet-route-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut snaps = Vec::new();
    for (id, seed) in [("alpha", 13u64), ("beta", 14u64)] {
        let (xs, ys, grids, _) = on_grid_problem(96, seed);
        let h = GpHypers::new(0.45, 1.3, 0.05);
        let mut gp = ExactGp::new(xs, ys, h);
        gp.refresh().unwrap();
        let snap = ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Exact).unwrap();
        snap.save(&dir.join(format!("{id}.snap"))).unwrap();
        snaps.push(snap);
    }

    let metrics = Arc::new(Metrics::new());
    let registry = Arc::new(ModelRegistry::new(
        RegistryConfig {
            dir: Some(dir.clone()),
            shards: 2,
            ..Default::default()
        },
        metrics.clone(),
    ));
    let server = FleetServer::start(
        registry,
        FleetConfig {
            bind: "127.0.0.1:0".to_string(),
            workers: 2,
            default_model: None,
            ..Default::default()
        },
    )
    .unwrap();

    let stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();

    // Both models are discoverable before either is resident.
    writeln!(writer, "models").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "ok alpha beta", "models: {line}");

    // Per-request addressing returns each model's own predictions,
    // bitwise-equal to its snapshot cache.
    let q = [0.51, 0.32, 0.77];
    for (snap, id) in snaps.iter().zip(["alpha", "beta"]) {
        line.clear();
        writeln!(writer, "model {id} predict {} {} {}", q[0], q[1], q[2]).unwrap();
        reader.read_line(&mut line).unwrap();
        let toks: Vec<&str> = line.trim().split_whitespace().collect();
        assert_eq!(toks[0], "ok", "{id}: {line}");
        let mean: f64 = toks[1].parse().unwrap();
        let var: f64 = toks[2].parse().unwrap();
        let (want_mean, want_var) = snap.cache.predict_one(&q);
        assert_eq!(mean.to_bits(), want_mean.to_bits(), "{id} mean");
        assert_eq!(var.to_bits(), want_var.to_bits(), "{id} var");
    }
    // The two models genuinely differ (different training seeds).
    let a = snaps[0].cache.predict_mean_one(&q);
    let b = snaps[1].cache.predict_mean_one(&q);
    assert_ne!(a.to_bits(), b.to_bits(), "test snapshots coincide");

    // model-prefixed dim, and a clean error without a default model.
    line.clear();
    writeln!(writer, "model beta dim").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "ok 3");
    line.clear();
    writeln!(writer, "predict {} {} {}", q[0], q[1], q[2]).unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.starts_with("err") && line.contains("no model specified"),
        "unaddressed request: {line}"
    );
    line.clear();
    writeln!(writer, "model ghost predict 0 0 0").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.starts_with("err") && line.contains("unknown model"),
        "unknown id: {line}"
    );
    writeln!(writer, "quit").unwrap();

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Two clients, two models, one fleet plane: each client holds its own
/// connection and interleaves per-request-addressed predicts against
/// *both* models (starting on different ones, alternating every
/// request). Every response must be bitwise-equal to the addressed
/// snapshot's own cache, so concurrent cross-model traffic cannot bleed
/// state between residents.
#[test]
fn two_clients_interleave_predicts_across_models() {
    use skip_gp::coordinator::Metrics;
    use skip_gp::serve::{FleetConfig, FleetServer, ModelRegistry, RegistryConfig};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let dir = std::env::temp_dir()
        .join(format!("skipgp-fleet-interleave-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut snaps = Vec::new();
    for (id, seed) in [("alpha", 21u64), ("beta", 22u64)] {
        let (xs, ys, grids, _) = on_grid_problem(96, seed);
        let h = GpHypers::new(0.45, 1.3, 0.05);
        let mut gp = ExactGp::new(xs, ys, h);
        gp.refresh().unwrap();
        let snap = ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Exact).unwrap();
        snap.save(&dir.join(format!("{id}.snap"))).unwrap();
        snaps.push(snap);
    }
    // The two models genuinely differ (different training seeds).
    let probe = [0.5, 0.5, 0.5];
    assert_ne!(
        snaps[0].cache.predict_mean_one(&probe).to_bits(),
        snaps[1].cache.predict_mean_one(&probe).to_bits(),
        "test snapshots coincide"
    );

    let metrics = Arc::new(Metrics::new());
    let registry = Arc::new(ModelRegistry::new(
        RegistryConfig {
            dir: Some(dir.clone()),
            shards: 2,
            ..Default::default()
        },
        metrics,
    ));
    let server = FleetServer::start(
        registry,
        FleetConfig {
            bind: "127.0.0.1:0".to_string(),
            workers: 2,
            default_model: None,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr();

    let snaps = &snaps;
    std::thread::scope(|scope| {
        for client in 0..2usize {
            scope.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                stream
                    .set_read_timeout(Some(std::time::Duration::from_secs(30)))
                    .unwrap();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                let mut rng = Rng::new(100 + client as u64);
                for i in 0..32 {
                    let which = (client + i) % 2;
                    let id = ["alpha", "beta"][which];
                    let q = [
                        rng.uniform_in(0.2, 0.8),
                        rng.uniform_in(0.2, 0.8),
                        rng.uniform_in(0.2, 0.8),
                    ];
                    line.clear();
                    writeln!(writer, "model {id} predict {} {} {}", q[0], q[1], q[2]).unwrap();
                    reader.read_line(&mut line).unwrap();
                    let toks: Vec<&str> = line.trim().split_whitespace().collect();
                    assert_eq!(toks[0], "ok", "client {client} iter {i}: {line}");
                    let mean: f64 = toks[1].parse().unwrap();
                    let var: f64 = toks[2].parse().unwrap();
                    let (want_mean, want_var) = snaps[which].cache.predict_one(&q);
                    assert_eq!(
                        mean.to_bits(),
                        want_mean.to_bits(),
                        "client {client} iter {i} {id} mean"
                    );
                    assert_eq!(
                        var.to_bits(),
                        want_var.to_bits(),
                        "client {client} iter {i} {id} var"
                    );
                }
                // Single-task residents answer the task-count verb too.
                line.clear();
                writeln!(writer, "model alpha tasks").unwrap();
                reader.read_line(&mut line).unwrap();
                assert_eq!(line.trim(), "ok 1", "client {client}: {line}");
                writeln!(writer, "quit").unwrap();
            });
        }
    });

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// An unknown *future* version is a clean typed error, not a parse
/// attempt — the version gate rejects before any field is trusted.
#[test]
fn future_version_is_a_clean_typed_error() {
    let mut bytes = std::fs::read(v1_fixture_path()).expect("v1 fixture present");
    bytes[8] = 7; // version u32 little-endian low byte: 1 → 7
    let err = match ModelSnapshot::from_bytes(&bytes) {
        Ok(_) => panic!("future version must not parse"),
        Err(e) => e,
    };
    let msg = err.to_string();
    assert!(msg.contains("version 7"), "unhelpful error: {msg}");
    assert!(msg.contains("snapshot"), "not a typed snapshot error: {msg}");
}
