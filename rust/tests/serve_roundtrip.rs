//! Integration tests for the serving subsystem: snapshot round-trips,
//! cached-prediction accuracy against the dense `ExactGp` references, and
//! batched-vs-one-at-a-time serving equivalence (t ∈ {1, 8, 64}).

use skip_gp::gp::{ExactGp, GpHypers};
use skip_gp::linalg::Matrix;
use skip_gp::operators::Grid1d;
use skip_gp::serve::{
    BatcherConfig, ModelSnapshot, RequestBatcher, ServeEngine, Server, ServerConfig,
    SnapshotConfig, VarianceMode,
};
use skip_gp::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("skipgp-serve-{tag}-{}.snap", std::process::id()))
}

/// n=256, d=3 training set whose points sit exactly on the serving grid's
/// nodes, so the cubic stencil is exact (weight 1 on the node) and the
/// cache path reproduces the dense algebra to rounding.
fn on_grid_problem(
    n: usize,
    seed: u64,
) -> (Matrix, Vec<f64>, Vec<Grid1d>, Matrix) {
    let d = 3;
    let m = 16;
    let g = Grid1d::fit(0.0, 1.0, m);
    let mut rng = Rng::new(seed);
    let mut lattice = |rows: usize| {
        Matrix::from_fn(rows, d, |_, _| {
            // Interior nodes only (full cubic stencil).
            g.point(2 + rng.below(m - 4))
        })
    };
    let xs = lattice(n);
    let xt = lattice(64);
    let ys: Vec<f64> = (0..n)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + (3.0 * r[1]).cos() * r[2] + 0.05 * rng.normal()
        })
        .collect();
    (xs, ys, vec![g.clone(), g.clone(), g], xt)
}

/// Acceptance: cached predict_mean / predict_var match the ExactGp dense
/// references within 1e-6 on an n=256, d=3 problem.
#[test]
fn cached_predictions_match_exact_gp_within_1e6() {
    let (xs, ys, grids, xt) = on_grid_problem(256, 1);
    let h = GpHypers::new(0.45, 1.3, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let want_mean = gp.predict_mean(&xt);
    let want_var = gp.predict_var(&xt);

    let snap = ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Exact).unwrap();
    let got_mean = snap.cache.predict_mean(&xt);
    let got_var = snap.cache.predict_var(&xt);

    for i in 0..xt.rows {
        assert!(
            (got_mean[i] - want_mean[i]).abs() < 1e-6,
            "mean[{i}]: cached {} vs exact {}",
            got_mean[i],
            want_mean[i]
        );
        assert!(
            (got_var[i] - want_var[i]).abs() < 1e-6,
            "var[{i}]: cached {} vs exact {}",
            got_var[i],
            want_var[i]
        );
    }
}

/// Off-grid queries: the cache inherits only the (small) SKI interpolation
/// error.
#[test]
fn cached_predictions_accurate_off_grid() {
    let (xs, ys, grids, _) = on_grid_problem(256, 2);
    let h = GpHypers::new(0.45, 1.3, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap = ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Exact).unwrap();
    let mut rng = Rng::new(3);
    let xt = Matrix::from_fn(64, 3, |_, _| rng.uniform_in(0.15, 0.85));
    let want_mean = gp.predict_mean(&xt);
    let want_var = gp.predict_var(&xt);
    let got_mean = snap.cache.predict_mean(&xt);
    let got_var = snap.cache.predict_var(&xt);
    let mmae = skip_gp::util::mae(&got_mean, &want_mean);
    let vmae = skip_gp::util::mae(&got_var, &want_var);
    assert!(mmae < 5e-3, "off-grid mean mae {mmae}");
    assert!(vmae < 5e-3, "off-grid var mae {vmae}");
}

/// Snapshot → save → load → bitwise-equal predictions.
#[test]
fn snapshot_file_roundtrip_is_bitwise_equal() {
    let (xs, ys, grids, xt) = on_grid_problem(128, 4);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Lanczos(32)).unwrap();

    let path = tmpfile("roundtrip");
    snap.save(&path).unwrap();
    let back = ModelSnapshot::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(back.hypers, snap.hypers);
    assert_eq!(back.alpha, snap.alpha);
    // Bitwise-identical predictions, mean and variance, on- and off-grid.
    let mut rng = Rng::new(5);
    let off = Matrix::from_fn(40, 3, |_, _| rng.uniform_in(0.0, 1.0));
    for q in [&xt, &off] {
        assert_eq!(snap.cache.predict_mean(q), back.cache.predict_mean(q));
        assert_eq!(snap.cache.predict_var(q), back.cache.predict_var(q));
    }
}

/// Batched serving equals one-at-a-time serving, bit for bit, at
/// t ∈ {1, 8, 64}.
#[test]
fn batched_serving_equals_one_at_a_time() {
    let (xs, ys, grids, _) = on_grid_problem(128, 6);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Lanczos(24)).unwrap();
    let mut rng = Rng::new(7);
    let queries: Vec<Vec<f64>> = (0..256)
        .map(|_| (0..3).map(|_| rng.uniform_in(0.0, 1.0)).collect())
        .collect();
    // One-at-a-time reference straight off the cache.
    let reference: Vec<(f64, f64)> = queries
        .iter()
        .map(|q| (snap.cache.predict_mean_one(q), snap.cache.predict_var_one(q)))
        .collect();

    for t in [1usize, 8, 64] {
        let engine = Arc::new(ServeEngine::new(snap.clone()).unwrap());
        let batcher = RequestBatcher::start(
            engine.clone(),
            BatcherConfig {
                max_batch: t,
                max_wait: std::time::Duration::from_millis(1),
            },
        );
        let handle = batcher.handle();
        // Submit everything up front so batches actually fill to t…
        let pending: Vec<_> = queries.iter().map(|q| handle.submit(q)).collect();
        // …then drain in order.
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.batch_size >= 1 && resp.batch_size <= t);
            assert_eq!(
                (resp.mean, resp.var),
                reference[i],
                "t={t}, query {i}: batched != one-at-a-time"
            );
        }
        let served = engine.metrics.counter("serve.points");
        assert_eq!(served, queries.len() as u64);
        if t == 1 {
            // max_batch=1 must never coalesce.
            let hist = engine.metrics.value_histogram("serve.batch_size");
            assert_eq!(hist.keys().copied().max(), Some(1));
        }
        drop(handle);
        batcher.shutdown();
    }
}

/// The TCP front-end serves the same numbers the cache computes, via the
/// shortest-round-trip float formatting.
#[test]
fn tcp_server_round_trip() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let (xs, ys, grids, _) = on_grid_problem(96, 8);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let snap =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Lanczos(16)).unwrap();
    let engine = Arc::new(ServeEngine::new(snap.clone()).unwrap());
    let server = Server::start(
        engine,
        ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            batcher: BatcherConfig::default(),
        },
    )
    .unwrap();
    let addr = server.addr();

    {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();

        writeln!(writer, "ping").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok pong");

        line.clear();
        writeln!(writer, "dim").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok 3");

        line.clear();
        writeln!(writer, "predict 0.4 0.5 0.6").unwrap();
        reader.read_line(&mut line).unwrap();
        let toks: Vec<&str> = line.trim().split_whitespace().collect();
        assert_eq!(toks[0], "ok", "line: {line}");
        let mean: f64 = toks[1].parse().unwrap();
        let var: f64 = toks[2].parse().unwrap();
        assert_eq!(mean, snap.cache.predict_mean_one(&[0.4, 0.5, 0.6]));
        assert_eq!(var, snap.cache.predict_var_one(&[0.4, 0.5, 0.6]));

        line.clear();
        writeln!(writer, "predict 1.0 2.0").unwrap(); // wrong arity
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("err"), "line: {line}");

        line.clear();
        writeln!(writer, "stats").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("qps="), "line: {line}");

        writeln!(writer, "quit").unwrap();
    } // connection closes here, releasing its batcher handle
    server.shutdown();
}

/// Mean-only snapshots refuse to serve (no silent missing uncertainty),
/// and the budget guard refuses absurd grids.
#[test]
fn serving_guards() {
    let (xs, ys, grids, _) = on_grid_problem(64, 9);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let mean_only =
        ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::None).unwrap();
    let err = match ServeEngine::new(mean_only) {
        Ok(_) => panic!("mean-only snapshot must not serve"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("variance"), "{err}");

    let err = ModelSnapshot::from_exact(
        &gp,
        &SnapshotConfig {
            grid_m: 512,
            variance: VarianceMode::None,
            max_grid_cells: 1 << 20,
        },
    )
    .unwrap_err();
    assert!(err.to_string().contains("budget"), "{err}");
}
