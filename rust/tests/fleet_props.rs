//! Integration tests for the sharded multi-model serving plane
//! (`skip_gp::serve::fleet`): sharded-vs-single bitwise prediction
//! equivalence, registry LRU eviction + reload round-trips, live/frozen
//! coexistence under a pinned registry entry, admission-control `busy`
//! replies, and graceful-drain shutdown semantics.

use skip_gp::coordinator::Metrics;
use skip_gp::gp::{ExactGp, GpHypers};
use skip_gp::grid::Grid1d;
use skip_gp::linalg::Matrix;
use skip_gp::serve::{
    BatcherConfig, FleetConfig, FleetServer, ModelRegistry, ModelSnapshot,
    RegistryConfig, ShardedModel, VarianceMode,
};
use skip_gp::solvers::CgConfig;
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A fresh per-test temp directory (removed by the caller).
fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("skipgp-fleet-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small d=3 frozen snapshot with exact variance: training points on
/// the serving grid's interior nodes (same construction as the
/// serve_roundtrip suite), plus 64 off-node test points.
fn small_snapshot(seed: u64) -> (ModelSnapshot, Matrix) {
    let (d, m, n) = (3, 16, 96);
    let g = Grid1d::fit(0.0, 1.0, m).unwrap();
    let mut rng = Rng::new(seed);
    let xs = Matrix::from_fn(n, d, |_, _| g.point(2 + rng.below(m - 4)));
    let ys: Vec<f64> = (0..n)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + (3.0 * r[1]).cos() * r[2] + 0.05 * rng.normal()
        })
        .collect();
    let h = GpHypers::new(0.45, 1.3, 0.05);
    let mut gp = ExactGp::new(xs, ys, h);
    gp.refresh().unwrap();
    let grids = vec![g.clone(), g.clone(), g];
    let snap = ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Exact).unwrap();
    let xt = Matrix::from_fn(64, d, |_, _| rng.uniform_in(0.15, 0.85));
    (snap, xt)
}

/// A small d=2 live incremental model (exact variance, no policy
/// refreshes) for live/frozen coexistence tests.
fn small_live() -> IncrementalState {
    let (d, n0) = (2, 48);
    let mut rng = Rng::new(7);
    let xs = Matrix::from_fn(n0, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> = (0..n0)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + r[1] + 0.02 * rng.normal()
        })
        .collect();
    let axes = vec![Grid1d::fit(-1.0, 1.0, 8).unwrap(); 2];
    let h = GpHypers::new(0.6, 1.0, 0.05);
    let cg = CgConfig { max_iters: 400, tol: 1e-10, ..Default::default() };
    let scfg = StreamConfig {
        refresh_every: 0,
        var_drift_budget: 0,
        error_z: 0.0,
        log_capacity: 1024,
        variance: VarianceMode::Exact,
        patch_eps: 1e-12,
        ..Default::default()
    };
    IncrementalState::new(xs, ys, h, axes, cg, scfg).unwrap()
}

/// Acceptance: shards are replicas, so predictions are **bitwise**
/// identical at k ∈ {1, 2, 8} — sharding decides where a query runs,
/// never what it returns.
#[test]
fn sharded_predictions_bitwise_equal_across_shard_counts() {
    let (snap, xt) = small_snapshot(11);
    let metrics = Arc::new(Metrics::new());

    let reference: Vec<(u64, u64)> = {
        let single = ShardedModel::from_snapshot(
            "m",
            snap.clone(),
            1,
            BatcherConfig::default(),
            metrics.clone(),
        )
        .unwrap();
        (0..xt.rows)
            .map(|i| {
                let r = single.predict(xt.row(i));
                (r.mean.to_bits(), r.var.to_bits())
            })
            .collect()
    };
    // k=1 equals the raw cache (sanity of the reference itself).
    for (i, &(mb, vb)) in reference.iter().enumerate() {
        let (want_mean, want_var) = snap.cache.predict_one(xt.row(i));
        assert_eq!(mb, want_mean.to_bits(), "k=1 mean[{i}] differs from cache");
        assert_eq!(vb, want_var.to_bits(), "k=1 var[{i}] differs from cache");
    }

    for k in [2usize, 8] {
        let sharded = ShardedModel::from_snapshot(
            "m",
            snap.clone(),
            k,
            BatcherConfig::default(),
            metrics.clone(),
        )
        .unwrap();
        assert_eq!(sharded.shard_count(), k);
        let mut shards_hit = std::collections::BTreeSet::new();
        for (i, &(mb, vb)) in reference.iter().enumerate() {
            shards_hit.insert(sharded.route(xt.row(i)));
            let r = sharded.predict(xt.row(i));
            assert_eq!(r.mean.to_bits(), mb, "k={k} mean[{i}] not bitwise equal");
            assert_eq!(r.var.to_bits(), vb, "k={k} var[{i}] not bitwise equal");
        }
        // Routing actually spreads load — equivalence must not come from
        // everything landing on shard 0.
        assert!(
            shards_hit.len() > 1,
            "k={k}: all 64 queries routed to one shard ({shards_hit:?})"
        );
        sharded.shutdown();
    }
}

/// Registry: lazy load from disk on miss, LRU eviction under the memory
/// budget, and a reload round-trip that serves bitwise-identical
/// predictions after the eviction.
#[test]
fn registry_lru_evicts_and_reloads_bitwise_identically() {
    let dir = tmpdir("lru");
    let (snap_a, xt) = small_snapshot(21);
    let (snap_b, _) = small_snapshot(22);
    let (snap_c, _) = small_snapshot(23);
    snap_a.save(&dir.join("a.snap")).unwrap();
    snap_b.save(&dir.join("b.snap")).unwrap();
    snap_c.save(&dir.join("c.snap")).unwrap();

    // Budget fits two resident models but not three.
    let bytes = snap_a.approx_bytes();
    let metrics = Arc::new(Metrics::new());
    let reg = ModelRegistry::new(
        RegistryConfig {
            dir: Some(dir.clone()),
            memory_budget: 2 * bytes + bytes / 2,
            shards: 1,
            batcher: BatcherConfig::default(),
        },
        metrics.clone(),
    );

    let want_b: Vec<u64> = (0..xt.rows)
        .map(|i| snap_b.cache.predict_mean_one(xt.row(i)).to_bits())
        .collect();

    reg.get("a").unwrap();
    reg.get("b").unwrap();
    assert_eq!(reg.len(), 2);
    reg.get("a").unwrap(); // bump a's recency: b is now LRU
    reg.get("c").unwrap(); // over budget → evict b
    assert!(reg.contains("a") && reg.contains("c"), "ids: {:?}", reg.ids());
    assert!(!reg.contains("b"), "b should have been LRU-evicted");
    assert_eq!(metrics.counter("serve.fleet.evictions"), 1);
    assert_eq!(metrics.counter("serve.fleet.loads"), 3);
    assert_eq!(metrics.counter("serve.fleet.hits"), 1);

    // Reload round-trip: the re-fetched b serves the same bits.
    let b = reg.get("b").unwrap();
    for (i, &want) in want_b.iter().enumerate() {
        let got = b.predict(xt.row(i)).mean.to_bits();
        assert_eq!(got, want, "reloaded b: mean[{i}] not bitwise equal");
    }
    assert_eq!(metrics.counter("serve.fleet.loads"), 4);
    assert!(reg.available().contains(&"b".to_string()));

    drop(b);
    drop(reg);
    std::fs::remove_dir_all(&dir).ok();
}

/// Live and frozen models coexist in one registry; the live one is
/// pinned and survives arbitrary eviction pressure, keeps accepting
/// observations, and the frozen one still refuses them.
#[test]
fn live_model_is_pinned_and_coexists_with_frozen() {
    let dir = tmpdir("pin");
    let (snap, _) = small_snapshot(31);
    snap.save(&dir.join("frozen.snap")).unwrap();

    let metrics = Arc::new(Metrics::new());
    let reg = ModelRegistry::new(
        RegistryConfig {
            dir: Some(dir.clone()),
            memory_budget: 1, // everything is over budget
            shards: 1,
            batcher: BatcherConfig::default(),
        },
        metrics.clone(),
    );
    let live = ShardedModel::live(
        "hot",
        small_live(),
        BatcherConfig::default(),
        metrics.clone(),
    )
    .unwrap();
    let live = reg.insert(live, true);
    assert!(live.is_live());

    let frozen = reg.get("frozen").unwrap();
    assert!(!frozen.is_live());
    // Pinned (live) + just-loaded (frozen) are both exempt: the registry
    // overshoots its budget rather than evicting either.
    assert!(reg.contains("hot") && reg.contains("frozen"));
    assert_eq!(metrics.counter("serve.fleet.evictions"), 0);

    // The live model ingests through the registry handle…
    let hot = reg.get("hot").unwrap();
    let ack = hot.observe(&[0.3, -0.2], 0.7);
    let ack = ack.result.expect("live model must accept observations");
    assert!(!ack.duplicate);
    assert!(ack.n >= 48, "model size after ingest: {}", ack.n);

    // …while the frozen one still refuses with the typed message.
    let r = frozen.observe(&[0.5, 0.5, 0.5], 1.0);
    let msg = r.result.expect_err("frozen model must reject observations");
    assert!(msg.contains("live"), "unexpected refusal: {msg}");

    std::fs::remove_dir_all(&dir).ok();
}

/// Admission control: with `max_inflight = 1` and a slow batcher, a
/// pipeline of three predicts gets exactly one `ok` and two immediate
/// `busy` replies — never queueing beyond the cap, never dropping the
/// connection.
#[test]
fn saturated_fleet_replies_busy_instead_of_queueing() {
    let (snap, _) = small_snapshot(41);
    let metrics = Arc::new(Metrics::new());
    let reg = Arc::new(ModelRegistry::new(
        RegistryConfig::default(),
        metrics.clone(),
    ));
    // A long max_wait parks the first prediction in its batch window, so
    // the follow-ups are provably rejected *while* one is in flight.
    let model = ShardedModel::from_snapshot(
        "m",
        snap,
        1,
        BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(300) },
        metrics.clone(),
    )
    .unwrap();
    reg.insert(model, true);
    let server = FleetServer::start(
        reg,
        FleetConfig {
            bind: "127.0.0.1:0".to_string(),
            workers: 2,
            max_inflight: 1,
            default_model: Some("m".to_string()),
            ..Default::default()
        },
    )
    .unwrap();

    let stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer
        .write_all(b"predict 0.5 0.5 0.5\npredict 0.4 0.4 0.4\npredict 0.3 0.3 0.3\n")
        .unwrap();
    let mut replies = Vec::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        replies.push(line.trim().to_string());
    }
    assert!(replies[0].starts_with("ok "), "first reply: {}", replies[0]);
    assert!(
        replies[1].starts_with("busy 1 ") && replies[2].starts_with("busy 1 "),
        "over-cap replies must be busy: {replies:?}"
    );
    assert_eq!(metrics.counter("serve.fleet.rejected"), 2);
    assert_eq!(metrics.counter("serve.fleet.requests"), 1);

    server.shutdown();
}

/// Shutdown regression: an in-flight prediction is answered during the
/// drain phase (not dropped), idle connections are closed, and
/// `shutdown()` returns with no server thread left running — all well
/// inside the grace period.
#[test]
fn fleet_shutdown_drains_inflight_and_closes_idle_conns() {
    let (snap, _) = small_snapshot(51);
    let metrics = Arc::new(Metrics::new());
    let reg = Arc::new(ModelRegistry::new(
        RegistryConfig::default(),
        metrics.clone(),
    ));
    // 250ms batch window: the response is still pending when shutdown
    // starts, so delivering it proves the drain actually drains.
    let model = ShardedModel::from_snapshot(
        "m",
        snap,
        2,
        BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(250) },
        metrics,
    )
    .unwrap();
    reg.insert(model, true);
    let server = FleetServer::start(
        reg,
        FleetConfig {
            bind: "127.0.0.1:0".to_string(),
            workers: 2,
            grace: Duration::from_secs(5),
            default_model: Some("m".to_string()),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr();

    let idle = TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let busy = TcpStream::connect(addr).unwrap();
    busy.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = busy.try_clone().unwrap();
    writer.write_all(b"predict 0.5 0.5 0.5\n").unwrap();
    // Give a worker time to read + admit the request before the drain
    // stops all reading.
    std::thread::sleep(Duration::from_millis(100));

    let t0 = Instant::now();
    server.shutdown();
    let took = t0.elapsed();
    assert!(took < Duration::from_secs(5), "shutdown took {took:?}");

    // The admitted prediction was answered before its connection closed…
    let mut reader = BufReader::new(busy);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ok "), "in-flight reply after drain: {line}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap_or(0), 0, "conn must be closed");

    // …and the idle connection was closed too (EOF, not a hang).
    let mut reader = BufReader::new(idle);
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap_or(0), 0, "idle conn must be closed");
}

fn thread_count() -> Option<usize> {
    let s = std::fs::read_to_string("/proc/self/status").ok()?;
    s.lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

/// Nightly-lane scale check (`cargo test --release -- --ignored`): hold
/// thousands of concurrent connections open against one fleet server
/// with a bounded worker pool — no thread-per-connection blowup — and
/// verify a sample of them still serve traffic. Degrades gracefully if
/// the runner's fd limit cuts the connection count short.
#[test]
#[ignore = "scale test: ~10k sockets; run in the nightly --ignored lane"]
fn fleet_holds_thousands_of_concurrent_connections() {
    let (snap, _) = small_snapshot(61);
    let metrics = Arc::new(Metrics::new());
    let reg = Arc::new(ModelRegistry::new(
        RegistryConfig::default(),
        metrics.clone(),
    ));
    let model = ShardedModel::from_snapshot(
        "m",
        snap,
        4,
        BatcherConfig::default(),
        metrics,
    )
    .unwrap();
    reg.insert(model, true);
    let server = FleetServer::start(
        reg,
        FleetConfig {
            bind: "127.0.0.1:0".to_string(),
            max_conns: 20_000,
            default_model: Some("m".to_string()),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr();

    let target = 10_000;
    let mut conns = Vec::new();
    for _ in 0..target {
        // Both endpoints live in this process: every connection costs two
        // fds, so an fd-limited runner stops early instead of failing.
        match TcpStream::connect(addr) {
            Ok(c) => {
                c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                conns.push(c);
            }
            Err(_) => break,
        }
    }
    assert!(
        conns.len() >= 1_000,
        "only {} concurrent connections (fd limit too low?)",
        conns.len()
    );
    println!("holding {} concurrent connections", conns.len());

    if let Some(t) = thread_count() {
        assert!(
            t < 128,
            "{t} threads for {} connections — thread-per-connection regression",
            conns.len()
        );
    }

    // Every 50th connection serves a round-trip while the rest idle.
    let mut served = 0;
    for c in conns.iter().step_by(50) {
        let mut writer = c.try_clone().unwrap();
        writer.write_all(b"predict 0.5 0.5 0.5\n").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ok "), "reply on sampled conn: {line}");
        served += 1;
    }
    assert!(served >= 20, "sampled {served} round-trips");
    assert_eq!(server.conn_count(), conns.len());

    let t0 = Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "shutdown with {} conns took {:?}",
        conns.len(),
        t0.elapsed()
    );
}
