//! Property and integration tests for the inducing-grid subsystem:
//! cubic-stencil convergence order and boundary clamping, degenerate-fit
//! guards, and the headline dense-vs-sparse agreement — sparse-grid SKI
//! matches dense Kronecker SKI predictive mean/variance within 1e-3 on a
//! d = 3 problem where both are feasible, and opens d = 8 where the
//! dense mᵈ path refuses.

#![allow(clippy::needless_range_loop)] // index-heavy numeric test/bench loops

use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant};
use skip_gp::grid::{cubic_stencil, Grid1d, GridSpec, InducingGrid, SparseGrid};
use skip_gp::kernels::ProductKernel;
use skip_gp::linalg::Matrix;
use skip_gp::operators::KroneckerSkiOp;
use skip_gp::solvers::CgConfig;
use skip_gp::util::{mae, Rng};

/// Keys cubic convolution is third-order: halving h cuts the
/// interpolation error of a smooth function by ~8×. Assert ≥ 4× per grid
/// doubling (the fit's margin makes the effective h shrink slightly
/// faster than 2×, so the realized ratios are ≥ 8).
#[test]
fn cubic_interpolation_error_shrinks_at_h3() {
    let f = |x: f64| (3.0 * x).sin();
    let mut rng = Rng::new(1);
    let pts: Vec<f64> = (0..200).map(|_| rng.uniform_in(0.05, 0.95)).collect();
    let mut errs = Vec::new();
    for m in [16usize, 32, 64] {
        let g = Grid1d::fit(0.0, 1.0, m).unwrap();
        let vals: Vec<f64> = g.points().iter().map(|&u| f(u)).collect();
        let mut emax = 0.0f64;
        for &x in &pts {
            let (b, w) = cubic_stencil(x, &g);
            let got: f64 = (0..4).map(|k| w[k] * vals[b + k]).sum();
            emax = emax.max((got - f(x)).abs());
        }
        errs.push(emax);
    }
    assert!(errs[0] < 1e-3, "coarse grid already too wrong: {errs:?}");
    assert!(errs[1] < errs[0] / 4.0, "not third-order: {errs:?}");
    assert!(errs[2] < errs[1] / 4.0, "not third-order: {errs:?}");
    assert!(errs[2] < 1e-5, "fine-grid floor: {errs:?}");
}

/// Stencils clamp correctly at both domain boundaries: the base index
/// stays inside the axis, mildly extrapolated points keep a renormalized
/// partition of unity, and far-field points degrade to all-zero weights
/// (the prior), never out-of-bounds indices.
#[test]
fn cubic_stencil_clamps_at_domain_boundaries() {
    let g = Grid1d::fit(0.0, 1.0, 16).unwrap();
    // Slightly outside the grid on both sides.
    for x in [g.point(0) - 0.4 * g.h, g.max() + 0.4 * g.h] {
        let (b, w) = cubic_stencil(x, &g);
        assert!(b <= g.m - 4, "base out of range at {x}");
        let sum: f64 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-10, "renormalized sum {sum} at {x}");
    }
    // Data-domain boundary points (the margin fit guarantees full
    // interior stencils there).
    for x in [0.0, 1.0] {
        let (b, w) = cubic_stencil(x, &g);
        assert!(b + 4 <= g.m);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-10);
    }
    // Far outside: every weight underflows to exactly zero.
    for x in [-100.0, 100.0] {
        let (b, w) = cubic_stencil(x, &g);
        assert!(b <= g.m - 4);
        assert!(w.iter().all(|&v| v == 0.0), "far-field weights {w:?}");
    }
}

/// Degenerate inputs surface as typed grid errors through the whole
/// stack, not NaN spacings (regression: m < 6 used to produce a negative
/// or infinite h; a constant feature a zero-width grid).
#[test]
fn degenerate_grid_inputs_error_through_the_stack() {
    for m in [3usize, 4, 5] {
        let err = Grid1d::fit(0.0, 1.0, m).unwrap_err();
        assert!(matches!(err, skip_gp::Error::Grid(_)), "m={m}: {err}");
    }
    let err = Grid1d::fit(0.3, 0.3, 32).unwrap_err();
    assert!(err.to_string().contains("constant"), "{err}");

    // A constant feature column reaches the same typed error via the
    // model's operator build.
    let mut rng = Rng::new(2);
    let xs = Matrix::from_fn(30, 2, |_, j| if j == 1 { 0.5 } else { rng.normal() });
    let ys = vec![0.0; 30];
    let gp = MvmGp::new(
        xs,
        ys,
        GpHypers::default_init(),
        MvmGpConfig { grid: GridSpec::uniform(32), ..Default::default() },
    );
    let err = match gp.build_operator(&gp.hypers, 0) {
        Ok(_) => panic!("constant feature must not fit a grid"),
        Err(e) => e,
    };
    assert!(matches!(err, skip_gp::Error::Grid(_)), "{err}");
}

/// Spec/data mismatches and over-MAX_TENSOR_DIM tensor grids are typed
/// errors up front, not index or assert panics deep in construction.
#[test]
fn spec_mismatch_and_overwide_tensor_grids_error_typed() {
    let mut rng = Rng::new(6);
    // Rectilinear spec naming fewer dims than the data: typed error from
    // the SKIP path (which reads per-dimension sizes).
    let xs = Matrix::from_fn(30, 3, |_, _| rng.uniform_in(-1.0, 1.0));
    let gp = MvmGp::new(
        xs,
        vec![0.0; 30],
        GpHypers::default_init(),
        MvmGpConfig {
            grid: GridSpec::Rectilinear(vec![16, 16]),
            ..Default::default()
        },
    );
    let err = match gp.build_operator(&gp.hypers, 0) {
        Ok(_) => panic!("mismatched rectilinear spec must not build"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("dimensions"), "{err}");

    // A sparse tensor grid beyond the stencil machinery's d ≤ 16 bound:
    // typed refusal from the Kiss path (SKIP stays available up there).
    let xs = Matrix::from_fn(40, 17, |_, _| rng.uniform_in(-1.0, 1.0));
    let gp = MvmGp::new(
        xs,
        vec![0.0; 40],
        GpHypers::init_for_dim(17),
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::sparse(2),
            ..Default::default()
        },
    );
    let err = match gp.build_operator(&gp.hypers, 0) {
        Ok(_) => panic!("d=17 tensor grid must refuse"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("SKIP"), "{err}");
}

fn toy(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix) {
    let mut rng = Rng::new(seed);
    let f = |row: &[f64]| -> f64 {
        (2.0 * row[0]).sin()
            + row[1..].iter().enumerate().map(|(k, &x)| ((k + 1) as f64 * x).cos()).sum::<f64>()
    };
    let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> = (0..n).map(|i| f(xs.row(i)) + 0.05 * rng.normal()).collect();
    let xt = Matrix::from_fn(15, d, |_, _| rng.uniform_in(-0.85, 0.85));
    (xs, ys, xt)
}

/// Acceptance: sparse-grid SKI agrees with dense Kronecker SKI within
/// 1e-3 on predictive mean *and* variance, on a d = 3 problem where both
/// are feasible.
#[test]
fn sparse_agrees_with_dense_kiss_within_1e3_d3() {
    let (xs, ys, xt) = toy(140, 3, 3);
    let h = GpHypers::new(0.9, 1.0, 0.05);
    let cg = CgConfig { max_iters: 300, tol: 1e-8, ..CgConfig::default() };
    let mut dense = MvmGp::new(
        xs.clone(),
        ys.clone(),
        h,
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(20),
            cg,
            ..Default::default()
        },
    );
    let mut sparse = MvmGp::new(
        xs,
        ys,
        h,
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::sparse(6),
            cg,
            ..Default::default()
        },
    );
    dense.refresh().unwrap();
    sparse.refresh().unwrap();

    let mean_d = dense.predict_mean(&xt);
    let mean_s = sparse.predict_mean(&xt);
    let mean_mae = mae(&mean_s, &mean_d);
    let mean_max = mean_s
        .iter()
        .zip(&mean_d)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(mean_max < 1e-3, "mean: max {mean_max:.2e}, mae {mean_mae:.2e}");

    let var_d = dense.predict_var(&xt).unwrap();
    let var_s = sparse.predict_var(&xt).unwrap();
    let var_max = var_s
        .iter()
        .zip(&var_d)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(var_max < 1e-3, "var: max {var_max:.2e}");

    // And the sparse grid really is the smaller object at matched
    // resolution in high d — here just sanity-check the term structure.
    assert!(sparse.predict_cache().unwrap().terms().len() > 1);
}

/// The d = 8 regime the dense path cannot touch: the sparse grid stores
/// under a thousand points, trains (refresh + solve), builds a live
/// multi-term stencil cache, and predicts finite values.
#[test]
fn sparse_grid_opens_d8_where_dense_refuses() {
    let (xs, ys, xt) = toy(120, 8, 4);
    // Dense 17-per-dim would be 17^8 ≈ 7e9 cells: typed refusal.
    let dense = MvmGp::new(
        xs.clone(),
        ys.clone(),
        GpHypers::init_for_dim(8),
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(17),
            ..Default::default()
        },
    );
    assert!(dense.build_operator(&dense.hypers, 0).is_err());

    // The noise floor must dominate the level-2 combination error (the
    // signed sum is not exactly PSD — see grid::sparse).
    let h = GpHypers::new(GpHypers::init_for_dim(8).ell(), 1.0, 0.25);
    let spec = GridSpec::sparse(2);
    assert!(spec.total_points(8).unwrap() < 1000);
    let mut gp = MvmGp::new(
        xs,
        ys,
        h,
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: spec,
            cg: CgConfig { max_iters: 80, tol: 1e-6, ..CgConfig::default() },
            ..Default::default()
        },
    );
    gp.refresh().unwrap();
    let cache = gp.predict_cache().expect("sparse cache fits any budget");
    assert!(cache.terms().len() > 1);
    let pred = gp.predict_mean(&xt);
    assert!(pred.iter().all(|p| p.is_finite()));
    let var = gp.predict_var(&xt).unwrap();
    assert!(var.iter().all(|v| v.is_finite() && *v > 0.0));
}

/// Every combination-technique term carries the textbook coefficient
/// `(−1)^q · C(d−1, q)` for its layer `q = ℓ − |l|₁` (Griebel et al.) —
/// pinned by decoding each term's per-axis levels back out of its fitted
/// axis sizes (`m(0) = 1`, `m(l) = 2^{l+1} + 1`).
#[test]
fn combination_coefficients_match_binomial_signs() {
    // C(n, k) by the multiplicative rule — exact in f64 at these sizes.
    fn binom(n: usize, k: usize) -> f64 {
        let mut c = 1.0f64;
        for i in 0..k {
            c = c * (n - i) as f64 / (i + 1) as f64;
        }
        c
    }
    // Inverse of `sparse_axis_points`.
    fn axis_level(m: usize) -> usize {
        if m == 1 {
            return 0;
        }
        let l = (m - 1).trailing_zeros() as usize - 1;
        assert_eq!((1usize << (l + 1)) + 1, m, "not a sparse axis size: {m}");
        l
    }
    for (d, level) in [(2usize, 3usize), (3, 3), (3, 4), (4, 2)] {
        let bounds = vec![(-1.0, 1.0); d];
        let grid = SparseGrid::from_bounds(&bounds, level, d).unwrap();
        assert!(grid.terms().len() > 1, "d={d} ℓ={level}: multi-term expected");
        for term in grid.terms() {
            let l1: usize = term.axes.iter().map(|g| axis_level(g.m)).sum();
            assert!(l1 <= level, "d={d} ℓ={level}: layer |l|₁={l1} out of range");
            let q = level - l1;
            assert!(q <= d - 1, "d={d} ℓ={level}: q={q} beyond the combination depth");
            let want = if q % 2 == 0 { binom(d - 1, q) } else { -binom(d - 1, q) };
            assert_eq!(
                term.coeff, want,
                "d={d} ℓ={level} |l|₁={l1}: coefficient {} != (−1)^{q}·C({}, {q})",
                term.coeff,
                d - 1
            );
        }
    }
}

/// A hand-built degenerate axis (zero or negative spacing) is a typed
/// [`Error::Grid`] from `grid_space_op` — the grid-space engine refuses
/// to assemble `WᵀW` over a zero-width column instead of producing NaN
/// bands.
#[test]
fn degenerate_axis_is_a_typed_grid_error_from_grid_space_op() {
    let mut rng = Rng::new(8);
    let xs = Matrix::from_fn(24, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let kern = ProductKernel::rbf(2, 0.5, 1.0);
    let good = Grid1d::fit(-1.0, 1.0, 8).unwrap();
    for bad in [
        Grid1d { min: 0.0, h: 0.0, m: 8 },
        Grid1d { min: 0.0, h: -0.25, m: 8 },
        Grid1d { min: 0.0, h: f64::NAN, m: 8 },
    ] {
        let op = KroneckerSkiOp::with_grids(&xs, &kern, vec![good.clone(), bad.clone()]);
        let err = match op.grid_space_op() {
            Ok(_) => panic!("degenerate axis (h={}) must not assemble WᵀW", bad.h),
            Err(e) => e,
        };
        assert!(matches!(err, skip_gp::Error::Grid(_)), "h={}: {err}", bad.h);
        assert!(err.to_string().contains("degenerate"), "h={}: {err}", bad.h);
    }
}

/// The sparse grid's point count grows near-linearly in d while the
/// dense grid explodes exponentially — the numbers behind the bench.
#[test]
fn sparse_point_count_scales_gently_in_d() {
    let mut rng = Rng::new(5);
    let mut last = 0usize;
    for d in [2usize, 4, 8] {
        let xs = Matrix::from_fn(50, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let g = SparseGrid::fit(&xs, 3).unwrap();
        let pts = g.total_points();
        // (When 17^d overflows usize the dense side has made the point.)
        if let Some(cells) = 17usize.checked_pow(d as u32) {
            assert!(pts < cells, "d={d}: {pts} !< {cells}");
        }
        assert!(pts > last, "point count should grow with d");
        last = pts;
        assert!(pts < 25_000, "d={d}: sparse grid unexpectedly large ({pts})");
    }
}
