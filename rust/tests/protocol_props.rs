//! Property tests for the typed wire protocol
//! (`skip_gp::serve::protocol`): every request round-trips
//! `format_request` → `parse_request` bitwise, the response formatter
//! pins the legacy byte strings, and — the point of having ONE parser —
//! malformed lines draw byte-identical `err` replies from the legacy
//! TCP server and the fleet reactor.

use skip_gp::coordinator::Metrics;
use skip_gp::gp::{ExactGp, GpHypers};
use skip_gp::grid::Grid1d;
use skip_gp::linalg::Matrix;
use skip_gp::serve::{
    BatcherConfig, FleetConfig, FleetServer, ModelRegistry, ModelShape,
    ModelSnapshot, ObserveRequest, PredictRequest, RegistryConfig, Request, Response,
    ServeEngine, Server, ServerConfig, ShardedModel, VarianceMode,
};
use skip_gp::serve::protocol::{format_request, parse_request};
use skip_gp::solvers::CgConfig;
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every request in the catalog — across single- and multi-task shapes,
/// with sign-of-zero, subnormal-adjacent, huge, and irrational payloads —
/// survives `format_request` → `parse_request` with bitwise-identical
/// float payloads.
#[test]
fn every_request_round_trips_format_to_parse_bitwise() {
    let tricky = [
        0.0,
        -0.0,
        1.0 / 3.0,
        f64::MIN_POSITIVE,
        -1e300,
        std::f64::consts::PI,
        -2.5e-3,
        42.0,
    ];
    for d in [1usize, 3] {
        let shape = ModelShape::single(d);
        let mut reqs = vec![
            Request::Quit,
            Request::Ping,
            Request::Dim,
            Request::Tasks,
            Request::Stats,
        ];
        for w in tricky.windows(d) {
            reqs.push(Request::Predict(PredictRequest { task: 0, x: w.to_vec() }));
            reqs.push(Request::Observe(ObserveRequest {
                task: 0,
                x: w.to_vec(),
                y: tricky[1],
                grad: None,
            }));
            reqs.push(Request::Observe(ObserveRequest {
                task: 0,
                x: w.to_vec(),
                y: f64::MIN_POSITIVE,
                grad: Some(w.iter().map(|v| -v).collect()),
            }));
        }
        for req in &reqs {
            let line = format_request(req, false);
            let back = parse_request(&line, &shape, false)
                .unwrap_or_else(|e| panic!("`{line}` failed to parse: {e}"))
                .unwrap_or_else(|| panic!("`{line}` parsed as blank"));
            assert_eq!(&back, req, "structural round-trip of `{line}`");
            match (&back, req) {
                (Request::Predict(b), Request::Predict(r)) => {
                    assert_eq!(bits(&b.x), bits(&r.x), "payload bits of `{line}`");
                }
                (Request::Observe(b), Request::Observe(r)) => {
                    assert_eq!(bits(&b.x), bits(&r.x), "payload bits of `{line}`");
                    assert_eq!(b.y.to_bits(), r.y.to_bits(), "y bits of `{line}`");
                    assert_eq!(
                        b.grad.as_deref().map(bits),
                        r.grad.as_deref().map(bits),
                        "gradient bits of `{line}`"
                    );
                }
                _ => {}
            }
        }
    }

    // Multi-task: task-led forms, including the enrollment id on observe.
    let mt = ModelShape { dim: 2, num_tasks: 3, multitask: true };
    for req in [
        Request::Predict(PredictRequest { task: 2, x: vec![-0.0, 1e300] }),
        Request::Observe(ObserveRequest {
            task: 3, // enrollment: one past the current task count
            x: vec![std::f64::consts::PI, f64::MIN_POSITIVE],
            y: -1.0 / 3.0,
            grad: None,
        }),
    ] {
        let line = format_request(&req, true);
        let back = parse_request(&line, &mt, false).unwrap().unwrap();
        assert_eq!(back, req, "multi-task round-trip of `{line}`");
    }

    // The fleet-only verb round-trips where it is enabled…
    assert_eq!(
        parse_request("models", &ModelShape::single(2), true).unwrap().unwrap(),
        Request::Models
    );
    assert_eq!(format_request(&Request::Models, false), "models");
    // …and is a doomed predict where it is not (legacy behavior).
    assert_eq!(
        parse_request("models", &ModelShape::single(2), false).unwrap_err(),
        "not a number: 'models'"
    );
}

/// The response formatter reproduces the legacy wire strings byte for
/// byte — these are the exact lines PR 7's clients already parse.
#[test]
fn response_formats_pin_the_legacy_bytes() {
    use skip_gp::serve::{ObserveAck, ObserveResponse, PredictResponse};
    assert_eq!(Response::Pong.format(), "ok pong");
    assert_eq!(Response::Dim(3).format(), "ok 3");
    assert_eq!(Response::Tasks(1).format(), "ok 1");
    assert_eq!(Response::Models(vec![]).format(), "ok");
    assert_eq!(
        Response::Models(vec!["a".into(), "b".into()]).format(),
        "ok a b"
    );
    assert_eq!(Response::Error("boom".into()).format(), "err boom");
    assert_eq!(
        Response::Busy { limit: 7 }.format(),
        "busy 7 requests in flight, retry later"
    );
    assert_eq!(
        Response::Predict(PredictResponse {
            mean: 0.5,
            var: 0.25,
            latency: Duration::from_micros(12),
            batch_size: 3,
        })
        .format(),
        "ok 0.5 0.25 12.0 3"
    );
    let obs = |result| ObserveResponse {
        result,
        latency: Duration::from_micros(8),
        batch_size: 2,
    };
    assert_eq!(
        Response::Observe(obs(Ok(ObserveAck {
            seq: 9,
            duplicate: false,
            n: 41,
            pending: 5,
            refreshed: false,
        })))
        .format(),
        "ok 9 41 5 8.0 2"
    );
    assert_eq!(
        Response::Observe(obs(Ok(ObserveAck {
            seq: 0,
            duplicate: true,
            n: 41,
            pending: 5,
            refreshed: false,
        })))
        .format(),
        "ok dup 41 5 8.0 2"
    );
    assert_eq!(
        Response::Observe(obs(Err("frozen".into()))).format(),
        "err frozen"
    );
}

/// A small d=3 frozen snapshot (interior-node training data, same
/// construction as the serve_roundtrip suite).
fn small_snapshot(seed: u64) -> ModelSnapshot {
    let (d, m, n) = (3, 16, 96);
    let g = Grid1d::fit(0.0, 1.0, m).unwrap();
    let mut rng = Rng::new(seed);
    let xs = Matrix::from_fn(n, d, |_, _| g.point(2 + rng.below(m - 4)));
    let ys: Vec<f64> = (0..n)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + (3.0 * r[1]).cos() * r[2] + 0.05 * rng.normal()
        })
        .collect();
    let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.45, 1.3, 0.05));
    gp.refresh().unwrap();
    let grids = vec![g.clone(), g.clone(), g];
    ModelSnapshot::from_exact_with_grids(&gp, grids, &VarianceMode::Exact).unwrap()
}

/// A small d=2 live model with every automatic refresh trigger disabled.
fn small_live(seed: u64) -> IncrementalState {
    let (d, n0) = (2, 48);
    let mut rng = Rng::new(seed);
    let xs = Matrix::from_fn(n0, d, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> = (0..n0)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + r[1] + 0.02 * rng.normal()
        })
        .collect();
    let axes = vec![Grid1d::fit(-1.0, 1.0, 8).unwrap(); 2];
    let cg = CgConfig { max_iters: 400, tol: 1e-10, ..Default::default() };
    let scfg = StreamConfig {
        refresh_every: 0,
        var_drift_budget: 0,
        error_z: 0.0,
        log_capacity: 1024,
        variance: VarianceMode::Exact,
        patch_eps: 1e-12,
        ..Default::default()
    };
    IncrementalState::new(xs, ys, GpHypers::new(0.6, 1.0, 0.05), axes, cg, scfg)
        .unwrap()
}

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let writer = stream.try_clone().unwrap();
        Client { writer, reader: BufReader::new(stream) }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        writeln!(self.writer, "{line}").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        reply.trim_end_matches('\n').to_string()
    }
}

/// The acceptance property: the legacy thread-per-connection server and
/// the fleet reactor answer every malformed line with **byte-identical**
/// typed errors, because both front-ends run the one parser in
/// `serve::protocol`.
#[test]
fn malformed_lines_err_identically_on_legacy_and_fleet_front_ends() {
    let snap = small_snapshot(61);

    let engine = Arc::new(ServeEngine::new(snap.clone()).unwrap());
    let legacy = Server::start(
        engine,
        ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            batcher: BatcherConfig::default(),
        },
    )
    .unwrap();

    let metrics = Arc::new(Metrics::new());
    let reg = Arc::new(ModelRegistry::new(RegistryConfig::default(), metrics.clone()));
    let model =
        ShardedModel::from_snapshot("m", snap, 1, BatcherConfig::default(), metrics)
            .unwrap();
    reg.insert(model, true);
    let fleet = FleetServer::start(
        reg,
        FleetConfig {
            bind: "127.0.0.1:0".to_string(),
            workers: 2,
            max_inflight: 64,
            default_model: Some("m".to_string()),
            ..Default::default()
        },
    )
    .unwrap();

    let mut lc = Client::connect(legacy.addr());
    let mut fc = Client::connect(fleet.addr());

    // (line, expected reply) — the expectation pins the wording, the
    // cross-front-end assertion pins the byte identity.
    let catalog = [
        ("predict one 2 3", "err not a number: 'one'"),
        ("one 2 3", "err not a number: 'one'"),
        ("predict 1 2", "err expected 3 numbers, got 2"),
        ("predict 1 2 3 4", "err expected 3 numbers, got 4"),
        ("observe 1 2 3", "err expected 4 numbers, got 3"),
        ("observe 1 2 3 nan", "err non-finite observation"),
        ("observe 1 2 3 4 grad 1 2", "err expected 3 numbers, got 2"),
        ("observe 1 2 3 4 grad", "err expected 3 numbers, got 0"),
        ("observe 1 2 3 4 grad x y z", "err not a number: 'x'"),
        ("observe 1 2 3 4 grad 1 2 inf", "err non-finite gradient observation"),
        ("observe 1 2 3 4 5", "err expected 4 numbers, got 5"),
    ];
    for (line, want) in catalog {
        let from_legacy = lc.roundtrip(line);
        let from_fleet = fc.roundtrip(line);
        assert_eq!(from_legacy, want, "legacy reply to `{line}`");
        assert_eq!(
            from_fleet, from_legacy,
            "front-ends diverged on `{line}`"
        );
    }

    // `models` is the one verb the front-ends legitimately disagree on:
    // the legacy server never had it (the token falls through to the
    // predict parse), the fleet answers with its resident ids.
    assert_eq!(lc.roundtrip("models"), "err not a number: 'models'");
    assert_eq!(fc.roundtrip("models"), "ok m");

    assert_eq!(lc.roundtrip("ping"), "ok pong");
    assert_eq!(fc.roundtrip("model m ping"), "ok pong");
    assert_eq!(fc.roundtrip("model m"), "err usage: model <id> <verb> …");
    // Resolution errors precede parse errors (ping skips resolution, so
    // probe with a verb that needs the model).
    assert_eq!(fc.roundtrip("model nope ping"), "ok pong");
    assert_eq!(
        fc.roundtrip("model nope dim"),
        "err fleet error: unknown model 'nope' (and no --models directory to \
         load from)"
    );
    assert_eq!(fc.roundtrip("dim"), "ok 3");

    lc.roundtrip("quit");
    drop(lc);
    drop(fc);
    legacy.shutdown();
    fleet.shutdown();
}

/// The D-SKI `grad` clause end to end on both front-ends: a live model
/// behind each accepts `observe … grad …`, acknowledges with the
/// standard observe reply, and flags the bitwise-identical resend as a
/// duplicate.
#[test]
fn grad_observations_flow_through_both_front_ends() {
    let legacy_engine = Arc::new(ServeEngine::new_live(small_live(71)).unwrap());
    let legacy = Server::start(
        legacy_engine,
        ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            batcher: BatcherConfig::default(),
        },
    )
    .unwrap();

    let metrics = Arc::new(Metrics::new());
    let reg = Arc::new(ModelRegistry::new(RegistryConfig::default(), metrics.clone()));
    let model =
        ShardedModel::live("hot", small_live(71), BatcherConfig::default(), metrics)
            .unwrap();
    reg.insert(model, true);
    let fleet = FleetServer::start(
        reg,
        FleetConfig {
            bind: "127.0.0.1:0".to_string(),
            workers: 2,
            max_inflight: 64,
            default_model: Some("hot".to_string()),
            ..Default::default()
        },
    )
    .unwrap();

    for addr in [legacy.addr(), fleet.addr()] {
        let mut c = Client::connect(addr);
        let reply = c.roundtrip("observe 0.5 -0.25 1.7 grad 0.3 -0.4");
        let toks: Vec<&str> = reply.split_whitespace().collect();
        assert_eq!(toks[0], "ok", "grad observe on {addr}: {reply}");
        let seq: u64 = toks[1].parse().unwrap_or_else(|_| {
            panic!("grad observe on {addr} must ack with a sequence: {reply}")
        });
        assert!(seq > 0, "{reply}");
        assert_eq!(toks[2].parse::<usize>().unwrap(), 49, "n after ingest: {reply}");

        // The bitwise-identical (x, y, ∇y) payload is a duplicate…
        let dup = c.roundtrip("observe 0.5 -0.25 1.7 grad 0.3 -0.4");
        assert!(dup.starts_with("ok dup "), "resend on {addr}: {dup}");
        // …but the same (x, y) with a different gradient is not.
        let fresh = c.roundtrip("observe 0.5 -0.25 1.7 grad 0.3 -0.5");
        assert!(
            fresh.starts_with("ok ") && !fresh.starts_with("ok dup"),
            "gradient payload must participate in dedup: {fresh}"
        );
    }
    legacy.shutdown();
    fleet.shutdown();
}
