//! Multi-task GP property suite (paper §6) — promoted from the in-module
//! tests of `gp/mtgp.rs` and `operators/task.rs` so the public API, not
//! crate internals, carries the contract:
//!
//! - SKIP MLL agrees with the exact dense MLL on a toy workload.
//! - `fit_dense` improves the MLL and recovers the latent task grouping.
//! - A trained multi-task model beats a pooled single-task baseline on
//!   heterogeneous tasks (the §6 motivation).
//! - The SKIP operator MVM matches the dense multi-task covariance.
//! - `TaskOp::diag()` is pinned against the dense coregionalization
//!   oracle.

use skip_gp::gp::{Mtgp, MtgpConfig, MtgpData};
use skip_gp::kernels::{Stationary1d, TaskKernel};
use skip_gp::linalg::Matrix;
use skip_gp::operators::{LinearOp, TaskOp};
use skip_gp::solvers::{CgConfig, SlqConfig};
use skip_gp::util::{mae, rel_err, Rng};

/// Two latent groups of tasks: group 0 follows sin, group 1 follows
/// −sin; within-group tasks share structure.
fn toy_tasks(s: usize, per_task: usize, seed: u64) -> MtgpData {
    let mut rng = Rng::new(seed);
    let mut x = Vec::new();
    let mut y = Vec::new();
    let mut task_of = Vec::new();
    for t in 0..s {
        let sign = if t % 2 == 0 { 1.0 } else { -1.0 };
        for _ in 0..per_task {
            let xi = rng.uniform_in(0.0, 3.0);
            x.push(xi);
            y.push(sign * (1.5 * xi).sin() + 0.05 * rng.normal());
            task_of.push(t);
        }
    }
    MtgpData { x, y, task_of, num_tasks: s }
}

#[test]
fn skip_mll_matches_dense_mll() {
    let data = toy_tasks(6, 15, 1);
    let cfg = MtgpConfig {
        rank: 30,
        slq: SlqConfig { num_probes: 30, max_rank: 30 },
        cg: CgConfig { max_iters: 200, tol: 1e-7, ..CgConfig::default() },
        ..Default::default()
    };
    let mtgp = Mtgp::new(data, Stationary1d::matern52(1.0), 2, 0.1, cfg);
    let dense = mtgp.mll_dense().unwrap();
    let fast = mtgp.mll_skip(3);
    let rel = (fast - dense).abs() / dense.abs();
    assert!(rel < 0.05, "skip {fast} vs dense {dense} rel {rel}");
}

#[test]
fn fit_improves_mll_and_learns_task_structure() {
    let data = toy_tasks(6, 12, 2);
    let cfg = MtgpConfig::default();
    let mut mtgp = Mtgp::new(data, Stationary1d::matern52(1.0), 2, 0.2, cfg);
    let trace = mtgp.fit_dense(25, 0.1).unwrap();
    assert!(trace.last().unwrap() > trace.first().unwrap());
    // Learned task covariance should correlate same-group tasks (0,2)
    // more than cross-group (0,1).
    let m = mtgp.task_kernel.to_dense();
    let same = m.get(0, 2);
    let cross = m.get(0, 1);
    assert!(same > cross, "same-group {same} vs cross-group {cross}");
}

#[test]
fn multitask_beats_pooled_on_heterogeneous_tasks() {
    let data = toy_tasks(4, 20, 3);
    // Held-out points for task 1 (the −sin group).
    let xt: Vec<f64> = (0..20).map(|i| 0.15 * i as f64).collect();
    let yt: Vec<f64> = xt.iter().map(|&x| -(1.5 * x).sin()).collect();
    let tt = vec![1usize; 20];
    let cfg = MtgpConfig::default();
    let mut mtgp = Mtgp::new(data.clone(), Stationary1d::matern52(1.0), 2, 0.2, cfg);
    mtgp.fit_dense(25, 0.1).unwrap();
    let pred = mtgp.predict_mean(&xt, &tt);
    let mtgp_mae = mae(&pred, &yt);
    // Pooled model: single task — predicts ~0 everywhere (groups cancel).
    let pooled = {
        let mut d2 = data;
        d2.task_of = vec![0; d2.len()];
        d2.num_tasks = 1;
        let mut m = Mtgp::new(d2, Stationary1d::matern52(1.0), 1, 0.2, MtgpConfig::default());
        m.refresh().unwrap();
        m.predict_mean(&xt, &vec![0; 20])
    };
    let pooled_mae = mae(&pooled, &yt);
    assert!(
        mtgp_mae < pooled_mae,
        "mtgp {mtgp_mae} should beat pooled {pooled_mae}"
    );
}

#[test]
fn skip_operator_mvm_matches_dense() {
    let data = toy_tasks(5, 10, 4);
    let cfg = MtgpConfig { rank: 30, ..Default::default() };
    let mtgp = Mtgp::new(data, Stationary1d::matern52(0.8), 2, 0.15, cfg);
    let op = mtgp.build_skip_operator(7);
    let dense = mtgp.khat_dense();
    let mut rng = Rng::new(8);
    let v = rng.normal_vec(dense.rows);
    let err = rel_err(&op.matvec(&v), &dense.matvec(&v));
    assert!(err < 2e-2, "rel err {err}");
}

#[test]
fn task_op_diag_matches_dense() {
    let n = 50;
    let s = 7;
    let q = 2;
    let mut rng = Rng::new(1);
    let task_of: Vec<usize> = (0..n).map(|_| rng.below(s)).collect();
    let b = Matrix::from_fn(s, q, |_, _| rng.normal() * 0.5);
    let diag: Vec<f64> = (0..s).map(|_| rng.uniform_in(0.1, 0.5)).collect();
    let kern = TaskKernel::new(b, diag);
    let dense = Matrix::from_fn(n, n, |i, j| kern.eval(task_of[i], task_of[j]));
    let op = TaskOp::new(task_of, kern);
    let got = op.diag().expect("TaskOp diagonal is exact and always available");
    assert_eq!(got.len(), n);
    for (i, g) in got.iter().enumerate() {
        assert!(
            (g - dense.get(i, i)).abs() < 1e-12,
            "diag[{i}] = {g} vs dense {}",
            dense.get(i, i)
        );
    }
}
