//! Cross-layer multi-task equivalence suite — the contract that pins
//! multi-task GPs (paper §6) across every layer of the stack at once:
//!
//! - **Streaming ≡ batch**: a streamed multi-task model — including a
//!   task enrolled online mid-stream — matches a cold refit on the full
//!   point set to 1e-6 in mean *and* variance, per task.
//! - **Sharded ≡ single-engine**: a sharded multi-task model answers
//!   bitwise-identically to the underlying snapshot caches at every
//!   replica count k ∈ {1, 2, 8}.
//! - **Snapshots**: multi-task snapshots round-trip bitwise at the
//!   current format version, and the v1–v4 historical fixtures migrate
//!   with identical predictions (the v5→v6 step is pinned by
//!   `dski_props.rs`).
//! - **Identity task kernel ≡ independent models**: with `B = 0, D = I`
//!   the multi-task posterior factorizes, so each task matches its own
//!   single-task model to 1e-6.
//! - The unsupported-configuration errors name exactly which
//!   configurations remain outside each path, and the wire protocol
//!   validates task ids end-to-end (including online enrollment).

#![allow(clippy::needless_range_loop)] // index-heavy numeric test loops

use skip_gp::coordinator::Metrics;
use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant, SolveSpace};
use skip_gp::grid::{Grid1d, GridSpec};
use skip_gp::kernels::TaskKernel;
use skip_gp::linalg::Matrix;
use skip_gp::serve::{
    BatcherConfig, ModelSnapshot, ServeEngine, Server, ServerConfig, ShardedModel,
    VarianceMode, SNAPSHOT_VERSION,
};
use skip_gp::solvers::{CgConfig, SolverPolicy};
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;

/// Distinct smooth target per task so cross-task structure is real.
fn task_fn(t: usize, r: &[f64]) -> f64 {
    let base = (2.0 * r[0]).sin() + (3.0 * r[1]).cos();
    match t % 3 {
        0 => base,
        1 => -base,
        _ => 0.5 * base + r[0],
    }
}

/// Contiguous per-task row blocks (task t's rows precede task t+1's):
/// d=2 points in (−0.95, 0.95) with per-task targets plus small noise.
/// Returns the advanced Rng so callers draw query points from the same
/// deterministic sequence.
fn mt_data(per_task: usize, s: usize, seed: u64) -> (Matrix, Vec<f64>, Vec<usize>, Rng) {
    let mut rng = Rng::new(seed);
    let n = per_task * s;
    let mut data = Vec::with_capacity(n * 2);
    let mut ys = Vec::with_capacity(n);
    let mut task_of = Vec::with_capacity(n);
    for t in 0..s {
        for _ in 0..per_task {
            let x0 = rng.uniform_in(-0.95, 0.95);
            let x1 = rng.uniform_in(-0.95, 0.95);
            data.push(x0);
            data.push(x1);
            ys.push(task_fn(t, &[x0, x1]) + 0.02 * rng.normal());
            task_of.push(t);
        }
    }
    (Matrix::from_vec(n, 2, data), ys, task_of, rng)
}

/// Fixed inducing axes: live and cold models share the same grid
/// regardless of data bounds.
fn axes12() -> Vec<Grid1d> {
    vec![
        Grid1d::fit(-1.0, 1.0, 12).unwrap(),
        Grid1d::fit(-1.0, 1.0, 12).unwrap(),
    ]
}

fn tight_cg() -> CgConfig {
    CgConfig { max_iters: 600, tol: 1e-11, ..Default::default() }
}

/// Exact variance, rebuilt on every ingest, no policy refreshes: the
/// purely-incremental path at solver-grade accuracy (the same settings
/// the single-task cold-refit equivalence test uses).
fn exact_cfg() -> StreamConfig {
    StreamConfig {
        refresh_every: 0,
        var_drift_budget: 0,
        error_z: 0.0,
        log_capacity: 4096,
        variance: VarianceMode::Exact,
        patch_eps: 1e-12,
        ..Default::default()
    }
}

/// The 3-task coregionalization kernel several tests share.
fn three_task_kernel() -> TaskKernel {
    TaskKernel::new(
        Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.5, 0.25, -0.5, 1.0]),
        vec![0.5, 0.25, 0.125],
    )
}

/// Streaming ≡ batch, with online enrollment: a 2-task model streams 19
/// points one at a time — one of them naming task 2 == num_tasks, which
/// enrolls a brand-new task mid-stream — and every per-task cache then
/// matches a cold refit on the full point set (with the task kernel
/// extended by the same decoupled enrollment row) to 1e-6 in mean and
/// variance.
#[test]
fn streamed_enrollment_matches_cold_multitask_refit() {
    let (xs0, ys0, task_of0, mut rng) = mt_data(48, 2, 1);
    let kernel = TaskKernel::new(Matrix::from_vec(2, 1, vec![1.0, 0.6]), vec![0.4, 0.3]);
    let h = GpHypers::new(0.6, 1.0, 0.05);
    let mut live = IncrementalState::new_multitask(
        xs0.clone(),
        ys0.clone(),
        (kernel.clone(), task_of0.clone()),
        h,
        axes12(),
        tight_cg(),
        exact_cfg(),
    )
    .unwrap();
    assert_eq!(live.num_tasks(), 2);
    assert!(live.is_multitask());

    // 12 points on the existing tasks, then one naming task 2 (online
    // enrollment), then 6 more across all three — the enrolled task
    // keeps learning after its birth.
    let mut streamed: Vec<(usize, Vec<f64>, f64)> = Vec::new();
    for i in 0..12 {
        let t = i % 2;
        let x = vec![rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)];
        let y = task_fn(t, &x) + 0.02 * rng.normal();
        streamed.push((t, x, y));
    }
    {
        let x = vec![rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)];
        let y = task_fn(2, &x) + 0.02 * rng.normal();
        streamed.push((2, x, y));
    }
    for i in 0..6 {
        let t = i % 3;
        let x = vec![rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)];
        let y = task_fn(t, &x) + 0.02 * rng.normal();
        streamed.push((t, x, y));
    }

    let mut enrolled = 0;
    for (t, x, y) in &streamed {
        let xm = Matrix::from_vec(1, 2, x.clone());
        let report = live.ingest_block_tasks(&xm, &[*y], &[*t]).unwrap();
        assert_eq!(report.accepted, 1, "task {t}");
        enrolled += report.enrolled;
    }
    assert_eq!(enrolled, 1, "exactly one online enrollment");
    assert_eq!(live.num_tasks(), 3);
    assert_eq!(live.stats.enrollments, 1);

    // Cold reference: one shot on the full point set, with the task
    // kernel extended by the same decoupled enrollment row the live
    // path appends.
    let mut cold_kernel = kernel;
    assert_eq!(cold_kernel.enroll(), 2);
    let mut xs_full = xs0;
    let mut ys_full = ys0;
    let mut task_full = task_of0;
    for (t, x, y) in &streamed {
        xs_full.data.extend_from_slice(x);
        xs_full.rows += 1;
        ys_full.push(*y);
        task_full.push(*t);
    }
    let cold = IncrementalState::new_multitask(
        xs_full,
        ys_full,
        (cold_kernel, task_full),
        h,
        axes12(),
        tight_cg(),
        exact_cfg(),
    )
    .unwrap();

    for t in 0..3 {
        let lc = live.task_cache(t).expect("live cache");
        let cc = cold.task_cache(t).expect("cold cache");
        for _ in 0..15 {
            let q = [rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)];
            let (lm, lv) = lc.predict_one(&q);
            let (cm, cv) = cc.predict_one(&q);
            assert!(
                (lm - cm).abs() < 1e-6,
                "task {t} mean: streamed {lm} vs cold {cm}"
            );
            assert!(
                (lv - cv).abs() < 1e-6,
                "task {t} var: streamed {lv} vs cold {cv}"
            );
        }
    }
}

/// Sharded ≡ single-engine: every task-addressed prediction from a
/// sharded multi-task model is bitwise-identical to the underlying
/// snapshot's per-task cache, at every replica count k ∈ {1, 2, 8} —
/// sharding is a throughput decision, never a numerics decision.
#[test]
fn sharded_multitask_predictions_are_bitwise_identical() {
    let (xs, ys, task_of, mut rng) = mt_data(20, 3, 2);
    let live = IncrementalState::new_multitask(
        xs,
        ys,
        (three_task_kernel(), task_of),
        GpHypers::new(0.6, 1.0, 0.05),
        axes12(),
        tight_cg(),
        exact_cfg(),
    )
    .unwrap();
    let snap = live.to_snapshot();
    assert!(snap.is_multitask());
    assert_eq!(snap.num_tasks(), 3);

    let queries: Vec<(usize, [f64; 2])> = (0..48)
        .map(|i| (i % 3, [rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)]))
        .collect();
    let reference: Vec<(f64, f64)> = queries
        .iter()
        .map(|(t, q)| snap.task_cache(*t).unwrap().predict_one(q))
        .collect();

    for k in [1usize, 2, 8] {
        let model = ShardedModel::from_snapshot(
            "mt",
            snap.clone(),
            k,
            BatcherConfig::default(),
            Arc::new(Metrics::new()),
        )
        .unwrap();
        assert_eq!(model.shard_count(), k);
        assert_eq!(model.num_tasks(), 3);
        assert!(model.is_multitask());
        for ((t, q), want) in queries.iter().zip(&reference) {
            let got = model.predict_task(*t, q);
            assert_eq!(got.mean.to_bits(), want.0.to_bits(), "k={k} task={t} mean");
            assert_eq!(got.var.to_bits(), want.1.to_bits(), "k={k} task={t} var");
        }
        // An out-of-range task is NaN-poisoned, not a worker failure.
        let poisoned = model.predict_task(9, &queries[0].1);
        assert!(poisoned.mean.is_nan() && poisoned.var.is_nan(), "k={k}");
    }
}

/// Multi-task snapshots round-trip **bitwise** at the current format
/// version (encode → decode → re-encode reproduces the identical byte
/// string), and all four historical fixtures still load and predict
/// identically after a current-format re-save (v1: implicit single
/// term; v2: no pending log; v3: no α provenance; v4: no multi-task
/// payload; the gradient-payload v5→v6 step is pinned by
/// `dski_props.rs`).
#[test]
fn multitask_snapshot_roundtrips_and_every_fixture_migrates() {
    let (xs, ys, task_of, mut rng) = mt_data(15, 3, 3);
    let live = IncrementalState::new_multitask(
        xs,
        ys,
        (three_task_kernel(), task_of),
        GpHypers::new(0.6, 1.0, 0.05),
        axes12(),
        tight_cg(),
        exact_cfg(),
    )
    .unwrap();
    let snap = live.to_snapshot();
    let bytes = snap.to_bytes();
    let back = ModelSnapshot::from_bytes(&bytes).expect("snapshot loads");
    assert_eq!(back.version, SNAPSHOT_VERSION);
    assert_eq!(back.num_tasks(), 3);
    assert_eq!(back.to_bytes(), bytes, "round-trip must be bitwise");
    for t in 0..3 {
        let q = [rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)];
        let want = snap.task_cache(t).unwrap().predict_one(&q);
        let got = back.task_cache(t).unwrap().predict_one(&q);
        assert_eq!(got.0.to_bits(), want.0.to_bits(), "task {t} mean");
        assert_eq!(got.1.to_bits(), want.1.to_bits(), "task {t} var");
    }

    // Queries inside every fixture's grid support.
    let q = Matrix::from_vec(3, 2, vec![0.1, -0.3, 0.6, 0.1, -0.4, -0.2]);
    for (file, ver) in [
        ("snapshot_v1.bin", 1u32),
        ("snapshot_v2.bin", 2),
        ("snapshot_v3.bin", 3),
        ("snapshot_v4.bin", 4),
    ] {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("rust/tests/fixtures")
            .join(file);
        let raw = std::fs::read(&path).unwrap_or_else(|e| panic!("{file}: {e}"));
        let old = ModelSnapshot::from_bytes(&raw).unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(old.version, ver, "{file}");
        assert!(old.tasks.is_none(), "{file}: historical formats are single-task");
        assert!(old.pending.iter().all(|o| o.task == 0), "{file}");
        let mean = old.cache.predict_mean(&q);
        let var = old.cache.predict_var(&q);
        let resaved =
            ModelSnapshot::from_bytes(&old.to_bytes()).unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(resaved.version, SNAPSHOT_VERSION, "{file}");
        assert_eq!(resaved.cache.predict_mean(&q), mean, "{file}: migration changed means");
        assert_eq!(resaved.cache.predict_var(&q), var, "{file}: migration changed variances");
        assert_eq!(resaved.pending, old.pending, "{file}: pending log must survive");
    }
}

/// With the identity task kernel (B = 0, D = I) the multi-task
/// covariance is block-diagonal over contiguous task blocks, so each
/// task's posterior factorizes: the 2-task model matches two
/// independently-built single-task models to 1e-6 in mean and variance.
#[test]
fn identity_task_kernel_matches_independent_single_task_models() {
    let h = GpHypers::new(0.6, 1.0, 0.05);
    let per = 70;
    let (xs, ys, task_of, mut rng) = mt_data(per, 2, 4);
    let multi = IncrementalState::new_multitask(
        xs.clone(),
        ys.clone(),
        (TaskKernel::independent(2), task_of),
        h,
        axes12(),
        tight_cg(),
        exact_cfg(),
    )
    .unwrap();

    // The same two row blocks as independent single-task models.
    let mut singles = Vec::new();
    for t in 0..2 {
        let xb = Matrix::from_fn(per, 2, |i, j| xs.get(t * per + i, j));
        let yb = ys[t * per..(t + 1) * per].to_vec();
        singles.push(
            IncrementalState::new(xb, yb, h, axes12(), tight_cg(), exact_cfg()).unwrap(),
        );
    }

    for t in 0..2 {
        let mc = multi.task_cache(t).expect("multi cache");
        let sc = singles[t].cache();
        for _ in 0..20 {
            let q = [rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)];
            let (mm, mv) = mc.predict_one(&q);
            let (sm, sv) = sc.predict_one(&q);
            assert!(
                (mm - sm).abs() < 1e-6,
                "task {t} mean: multi {mm} vs single {sm}"
            );
            assert!(
                (mv - sv).abs() < 1e-6,
                "task {t} var: multi {mv} vs single {sv}"
            );
        }
    }
}

/// The unsupported-configuration errors name *exactly* which
/// configurations remain outside each path — no more blanket "KISS
/// only" wording that misleads about what is actually supported.
#[test]
fn unsupported_configurations_are_named_precisely() {
    let mut rng = Rng::new(5);
    let n = 40;
    let mut data = Vec::with_capacity(n * 2);
    for _ in 0..n * 2 {
        data.push(rng.uniform_in(-1.0, 1.0));
    }
    let xs = Matrix::from_vec(n, 2, data);
    let ys: Vec<f64> = (0..n).map(|i| task_fn(0, xs.row(i))).collect();
    let h = GpHypers::new(0.6, 1.0, 0.05);

    // SKIP variant: online updates stay unsupported for a structural
    // reason the error must state.
    let skip = MvmGp::new(
        xs.clone(),
        ys.clone(),
        h,
        MvmGpConfig { variant: MvmVariant::Skip, ..Default::default() },
    );
    let err = IncrementalState::from_mvm(&skip, exact_cfg()).unwrap_err().to_string();
    assert!(err.contains("KISS (grid) variant"), "{err}");
    assert!(
        err.contains("SKIP models remain unsupported (single- and multi-task alike)"),
        "{err}"
    );

    // Sparse-grid KISS: also unsupported, for a *different* stated
    // reason (multi-term grids cannot extend row-by-row).
    let sparse = MvmGp::new(
        xs.clone(),
        ys.clone(),
        h,
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::Sparse { level: 3 },
            ..Default::default()
        },
    );
    let err = IncrementalState::from_mvm(&sparse, exact_cfg()).unwrap_err().to_string();
    assert!(err.contains("single-term dense grid"), "{err}");
    assert!(err.contains("sparse-grid multi-term models remain unsupported"), "{err}");
    assert!(err.contains("(single- and multi-task alike)"), "{err}");

    // Multi-task guards: task-less ingest and solver-grade predict_var.
    let (mxs, mys, mtask, _) = mt_data(10, 2, 6);
    let mut mt = IncrementalState::new_multitask(
        mxs.clone(),
        mys.clone(),
        (TaskKernel::independent(2), mtask.clone()),
        h,
        axes12(),
        tight_cg(),
        exact_cfg(),
    )
    .unwrap();
    let one = Matrix::from_vec(1, 2, vec![0.1, 0.2]);
    let err = mt.ingest_block(&one, &[1.0]).unwrap_err().to_string();
    assert!(err.contains("this model is multi-task"), "{err}");
    assert!(err.contains("observations must name a task"), "{err}");
    let err = mt.predict_var(&one).unwrap_err().to_string();
    assert!(err.contains("solver-grade predict_var is single-task only"), "{err}");
    assert!(err.contains("per-task caches"), "{err}");

    // Single-task states reject task-addressed observations.
    let mut st = IncrementalState::new(xs, ys, h, axes12(), tight_cg(), exact_cfg()).unwrap();
    let err = st.ingest_block_tasks(&one, &[1.0], &[1]).unwrap_err().to_string();
    assert!(err.contains("this model is single-task"), "{err}");

    // Grid-space re-solves have no multi-task normal form — refused at
    // construction, not at the first ingest.
    let grid_cfg = StreamConfig {
        policy: SolverPolicy { space: SolveSpace::Grid, ..Default::default() },
        ..exact_cfg()
    };
    let err = IncrementalState::new_multitask(
        mxs,
        mys,
        (TaskKernel::independent(2), mtask),
        h,
        axes12(),
        tight_cg(),
        grid_cfg,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("grid-space re-solves are single-task only"), "{err}");
    assert!(err.contains("no grid-space normal form"), "{err}");

    // A frozen engine's refusal names what stays frozen.
    let engine = ServeEngine::new(mt.to_snapshot()).unwrap();
    let err = engine.observe_block(&one, &[1.0]).unwrap_err().to_string();
    assert!(err.contains("frozen snapshot"), "{err}");
    assert!(
        err.contains("SKIP and sparse-grid multi-term snapshots stay frozen"),
        "{err}"
    );
}

/// The wire protocol validates task ids end-to-end on a live multi-task
/// model: `tasks` reports the count, task-less predicts are protocol
/// errors, out-of-range ids are named, a well-formed predict is bitwise
/// the addressed task's cache, and `observe <num_tasks> …` enrolls a
/// brand-new task online.
#[test]
fn multitask_wire_protocol_validates_and_enrolls() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let (xs, ys, task_of, _) = mt_data(16, 3, 7);
    let live = IncrementalState::new_multitask(
        xs,
        ys,
        (three_task_kernel(), task_of),
        GpHypers::new(0.6, 1.0, 0.05),
        axes12(),
        tight_cg(),
        exact_cfg(),
    )
    .unwrap();
    let engine = Arc::new(ServeEngine::new_live(live).unwrap());
    let snap = engine.snapshot();
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            batcher: BatcherConfig::default(),
        },
    )
    .unwrap();
    let addr = server.addr();
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(30)))
            .unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();

        writeln!(writer, "tasks").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok 3", "tasks: {line}");

        // Task-less predict on a multi-task model is a protocol error.
        line.clear();
        writeln!(writer, "predict 0.1 0.2").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("err"), "line: {line}");
        assert!(line.contains("must lead with a task id"), "line: {line}");

        // Out-of-range predict task.
        line.clear();
        writeln!(writer, "predict 5 0.1 0.2").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("err") && line.contains("out of range"), "line: {line}");

        // A well-formed task-addressed predict is bitwise the addressed
        // task's cache.
        line.clear();
        writeln!(writer, "predict 1 0.25 -0.5").unwrap();
        reader.read_line(&mut line).unwrap();
        let toks: Vec<&str> = line.trim().split_whitespace().collect();
        assert_eq!(toks[0], "ok", "line: {line}");
        let mean: f64 = toks[1].parse().unwrap();
        let var: f64 = toks[2].parse().unwrap();
        let (want_mean, want_var) = snap.task_cache(1).unwrap().predict_one(&[0.25, -0.5]);
        assert_eq!(mean.to_bits(), want_mean.to_bits(), "wire mean");
        assert_eq!(var.to_bits(), want_var.to_bits(), "wire var");

        // Observing task 9 is out of range even for enrollment (only
        // task == num_tasks enrolls), and the error says so.
        line.clear();
        writeln!(writer, "observe 9 0.3 0.3 1.0").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("err") && line.contains("would enroll"), "line: {line}");

        // observe <num_tasks> enrolls a brand-new task online.
        line.clear();
        writeln!(writer, "observe 3 0.3 0.3 1.0").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ok "), "enrollment ack: {line}");
        line.clear();
        writeln!(writer, "tasks").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok 4", "post-enrollment: {line}");

        writeln!(writer, "quit").unwrap();
    }
    assert_eq!(engine.metrics.counter("stream.enrollments"), 1);
    server.shutdown();
}

/// Nightly scale lane (runs under `cargo test --release -- --ignored`):
/// a T = 1024 task fleet — 1023 tasks at construction, the 1024th
/// enrolled online, finite serving across the whole task range. Lanczos
/// variance and an untriggered drift budget keep this about the task
/// axis, not about dense O(n³) factorization.
#[test]
#[ignore = "nightly scale lane: T = 1024 online task enrollment (minutes in release)"]
fn enrollment_scales_to_1024_tasks() {
    let s = 1023;
    let per = 2;
    let (xs, ys, task_of, _) = mt_data(per, s, 8);
    let mut rng = Rng::new(9);
    let b = Matrix::from_fn(s, 2, |_, _| 0.1 * rng.normal());
    let kernel = TaskKernel::new(b, vec![0.5; s]);
    let cfg = StreamConfig {
        refresh_every: 0,
        var_drift_budget: usize::MAX,
        error_z: 0.0,
        log_capacity: 4096,
        variance: VarianceMode::Lanczos(8),
        patch_eps: 1e-12,
        ..Default::default()
    };
    let cg = CgConfig { max_iters: 500, tol: 1e-6, ..Default::default() };
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
    ];
    // σ_n² = 0.3 bounds the condition number so the big Hadamard solves
    // converge well inside the iteration budget.
    let h = GpHypers::new(0.6, 1.0, 0.3);
    let mut live =
        IncrementalState::new_multitask(xs, ys, (kernel, task_of), h, axes, cg, cfg).unwrap();
    assert_eq!(live.num_tasks(), s);

    let report = live
        .ingest_block_tasks(&Matrix::from_vec(1, 2, vec![0.25, -0.5]), &[0.75], &[s])
        .unwrap();
    assert_eq!(report.enrolled, 1);
    assert_eq!(live.num_tasks(), 1024);
    for t in [0usize, 511, 1022, 1023] {
        let (m, v) = live.task_cache(t).expect("cache").predict_one(&[0.1, 0.2]);
        assert!(m.is_finite() && v.is_finite(), "task {t}: ({m}, {v})");
    }
}
