//! Property tests for the preconditioned solver subsystem
//! (`skip_gp::solvers::precond` + the PCG rewrite of `cg`/`block_cg`):
//!
//! - PCG and plain CG agree to ≤ 1e-8 on every operator family (dense,
//!   SKI, Kronecker-SKI, SKIP) — preconditioning never changes the
//!   answer, only the iteration count.
//! - Pivoted-Cholesky rank sweep: iterations decrease monotonically with
//!   rank on an ill-conditioned (small-σ_n²) covariance.
//! - Warm starts are never worse: seeding with the solution returns it
//!   bitwise in 0 iterations, and seeding with any partial iterate never
//!   increases the iteration count.
//! - Block-CG convergence is judged **per column** against each column's
//!   own ‖b_j‖ — the mixed-norm regression test that pins the criterion
//!   (a shared block norm would silently leave small-norm columns
//!   unsolved next to large-norm ones).
//! - `Precision::Mixed` meets the same residual certificate as f64 on
//!   every operator family, and on a σ_n² = 1e-8 covariance — where raw
//!   f32 CG floors out at O(1) relative residual — the refinement loop's
//!   stall detection hands off to f64 CG and still certifies.

use skip_gp::kernels::{ProductKernel, Stationary1d};
use skip_gp::linalg::{norm2, Matrix};
use skip_gp::operators::{
    AffineOp, DenseOp, KroneckerSkiOp, LinearOp, SkiOp, SkipComponent, SkipOp,
};
use skip_gp::solvers::{
    block_cg_solve, block_cg_solve_with, build_preconditioner, cg_solve, cg_solve_with,
    raw_cg_f32, refined_cg_solve, CgConfig, IdentityPrecond, PivotedCholeskyPrecond,
    Precision, PrecondSpec, Preconditioner,
};
use skip_gp::util::{rel_err, Rng};

const NOISE: f64 = 1e-3;

fn tight() -> CgConfig {
    CgConfig { max_iters: 3000, tol: 1e-10, ..Default::default() }
}

/// Low-rank-dominated dense covariance `G Gᵀ + σ_n² I` — the
/// ill-conditioned shape GP solves live in.
fn dense_covariance(n: usize, rank: usize, seed: u64) -> DenseOp {
    let mut rng = Rng::new(seed);
    let g = Matrix::from_fn(n, rank, |_, _| rng.normal());
    let mut a = g.matmul_t(&g);
    a.add_diag(NOISE);
    DenseOp(a)
}

/// 1-D SKI-backed K̂ = K_SKI + σ_n² I.
fn ski_covariance(n: usize, m: usize, seed: u64) -> AffineOp {
    let mut rng = Rng::new(seed);
    let xs = rng.uniform_vec(n, -2.0, 2.0);
    let kern = Stationary1d::rbf(0.5);
    let ski = SkiOp::new(&xs, &kern, m).expect("ski grid");
    AffineOp { inner: Box::new(ski), scale: 1.0, shift: NOISE }
}

/// 2-D Kronecker-grid K̂.
fn kron_covariance(n: usize, m: usize, seed: u64) -> AffineOp {
    let mut rng = Rng::new(seed);
    let xs = Matrix::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let kern = ProductKernel::rbf(2, 0.6, 1.0);
    let op = KroneckerSkiOp::new(&xs, &kern, m).expect("kron grid");
    AffineOp { inner: Box::new(op), scale: 1.0, shift: NOISE }
}

/// 2-D SKIP-backed K̂ (rank-truncated merge tree + noise).
fn skip_covariance(n: usize, seed: u64) -> AffineOp {
    let mut rng = Rng::new(seed);
    let xs = Matrix::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let kern = ProductKernel::rbf(2, 0.8, 1.0);
    let skis: Vec<SkiOp> = (0..2)
        .map(|k| SkiOp::new(&xs.col(k), &kern.factors[k], 64).expect("ski grid"))
        .collect();
    let comps: Vec<SkipComponent> =
        skis.iter().map(|s| SkipComponent::Op(s as &dyn LinearOp)).collect();
    let skip = SkipOp::build_native(comps, 30, &mut rng);
    AffineOp { inner: Box::new(skip), scale: 1.0, shift: NOISE }
}

/// PCG must reproduce the plain-CG solution to ≤ 1e-8 (tight solves, so
/// the comparison measures the preconditioner, not the stopping point).
fn assert_pcg_matches_cg(op: &dyn LinearOp, rank: usize, seed: u64, label: &str) {
    let mut rng = Rng::new(seed);
    let y = rng.normal_vec(op.dim());
    let plain = cg_solve(op, &y, tight());
    assert!(plain.converged, "{label}: plain CG did not converge");
    let pre = build_preconditioner(op, Some(NOISE), PrecondSpec::PivChol { rank });
    let pcg = cg_solve_with(op, &y, pre.as_ref(), None, tight());
    assert!(pcg.converged, "{label}: PCG did not converge");
    let err = rel_err(&pcg.x, &plain.x);
    assert!(err < 1e-8, "{label}: PCG drifted from CG by {err}");
    assert!(
        pcg.iters <= plain.iters,
        "{label}: PCG took {} iters vs CG {}",
        pcg.iters,
        plain.iters
    );
}

#[test]
fn pcg_matches_cg_on_dense() {
    let op = dense_covariance(120, 10, 1);
    assert_pcg_matches_cg(&op, 15, 2, "dense");
}

#[test]
fn pcg_matches_cg_on_ski() {
    let op = ski_covariance(400, 128, 3);
    assert_pcg_matches_cg(&op, 30, 4, "ski");
}

#[test]
fn pcg_matches_cg_on_kronecker() {
    let op = kron_covariance(150, 16, 5);
    assert_pcg_matches_cg(&op, 25, 6, "kronecker");
}

#[test]
fn pcg_matches_cg_on_skip() {
    let op = skip_covariance(200, 7);
    assert_pcg_matches_cg(&op, 25, 8, "skip");
}

#[test]
fn jacobi_matches_cg_on_scaled_system() {
    // Strongly varying diagonal (the regime Jacobi helps): D A D with
    // D log-uniform over two decades.
    let n = 100;
    let base = dense_covariance(n, 8, 9).0;
    let mut rng = Rng::new(10);
    let d: Vec<f64> = (0..n).map(|_| 10f64.powf(rng.uniform_in(-1.0, 1.0))).collect();
    let scaled = Matrix::from_fn(n, n, |i, j| d[i] * base.get(i, j) * d[j]);
    let op = DenseOp(scaled);
    let y = rng.normal_vec(n);
    let plain = cg_solve(&op, &y, tight());
    let jac = build_preconditioner(&op, None, PrecondSpec::Jacobi);
    assert_eq!(jac.name(), "jacobi", "dense diagonal must be available");
    let pre = cg_solve_with(&op, &y, jac.as_ref(), None, tight());
    assert!(plain.converged && pre.converged);
    assert!(rel_err(&pre.x, &plain.x) < 1e-8);
    assert!(pre.iters <= plain.iters, "jacobi {} vs {}", pre.iters, plain.iters);
}

#[test]
fn pivchol_rank_sweep_monotonically_reduces_iterations() {
    let op = ski_covariance(400, 128, 11);
    let mut rng = Rng::new(12);
    let y = rng.normal_vec(op.dim());
    let cfg = CgConfig { max_iters: 3000, tol: 1e-8, ..Default::default() };
    let mut iters = Vec::new();
    for rank in [0usize, 5, 15, 40] {
        let sol = if rank == 0 {
            cg_solve(&op, &y, cfg)
        } else {
            let pre =
                build_preconditioner(&op, Some(NOISE), PrecondSpec::PivChol { rank });
            cg_solve_with(&op, &y, pre.as_ref(), None, cfg)
        };
        assert!(sol.converged, "rank {rank} did not converge");
        iters.push(sol.iters);
    }
    for w in iters.windows(2) {
        assert!(w[1] <= w[0], "rank sweep not monotone: {iters:?}");
    }
    assert!(
        iters[3] * 3 <= iters[0],
        "rank 40 should cut iterations ≥ 3x: {iters:?}"
    );
}

#[test]
fn warm_start_is_never_worse() {
    let op = ski_covariance(300, 64, 13);
    let mut rng = Rng::new(14);
    let y = rng.normal_vec(op.dim());
    let cfg = CgConfig { max_iters: 2000, tol: 1e-8, ..Default::default() };
    let id = IdentityPrecond::new(op.dim());
    let cold = cg_solve_with(&op, &y, &id, None, cfg);
    assert!(cold.converged);

    // Seeding with a solution solved two digits inside the tolerance:
    // bitwise return, zero iterations.
    let seed = cg_solve_with(
        &op,
        &y,
        &id,
        None,
        CgConfig { tol: 1e-10, ..cfg },
    );
    assert!(seed.converged);
    let exact = cg_solve_with(&op, &y, &id, Some(&seed.x), cfg);
    assert_eq!(exact.iters, 0);
    assert_eq!(exact.x, seed.x);

    // Seeding with any partial iterate is no worse than starting cold
    // (±1: a restart rebuilds the Krylov space, which can cost a single
    // iteration against continuing — the exact guarantee above is the
    // zero-iteration bitwise one).
    for budget in [1usize, 3, 10, 30] {
        let partial = cg_solve_with(
            &op,
            &y,
            &id,
            None,
            CgConfig { max_iters: budget, ..cfg },
        );
        let warm = cg_solve_with(&op, &y, &id, Some(&partial.x), cfg);
        assert!(warm.converged);
        assert!(
            warm.iters <= cold.iters + 1,
            "seed after {budget} cold iters: warm {} vs cold {}",
            warm.iters,
            cold.iters
        );
        assert!(rel_err(&warm.x, &cold.x) < 1e-6);
    }
    // A deep seed must show a real saving, not just parity.
    let deep = cg_solve_with(
        &op,
        &y,
        &id,
        None,
        CgConfig { max_iters: cold.iters.saturating_sub(10).max(1), ..cfg },
    );
    let warm = cg_solve_with(&op, &y, &id, Some(&deep.x), cfg);
    assert!(
        warm.iters < cold.iters,
        "near-solution seed saved nothing: warm {} vs cold {}",
        warm.iters,
        cold.iters
    );
}

#[test]
fn block_cg_mixed_norm_columns_each_meet_their_own_tolerance() {
    // Columns at scales 1e6, 1, 1e-6 (plus an exact zero column): per-
    // column relative convergence must hold for every one. Against a
    // whole-block criterion the 1e-6-scaled column would "converge"
    // instantly while carrying an O(1) relative error.
    let op = dense_covariance(80, 8, 15);
    let mut rng = Rng::new(16);
    let scales = [1e6, 1.0, 1e-6, 0.0];
    let mut b = Matrix::zeros(80, scales.len());
    for (j, &s) in scales.iter().enumerate() {
        let col: Vec<f64> = (0..80).map(|_| s * rng.normal()).collect();
        b.set_col(j, &col);
    }
    let tol = 1e-8;
    let sol = block_cg_solve(&op, &b, CgConfig { max_iters: 2000, tol, ..Default::default() });
    assert!(sol.all_converged());
    for (j, &s) in scales.iter().enumerate() {
        let bj = b.col(j);
        let axj = op.matvec(&sol.x.col(j));
        let resid: Vec<f64> = axj.iter().zip(&bj).map(|(a, bv)| a - bv).collect();
        if s == 0.0 {
            assert_eq!(sol.x.col(j), vec![0.0; 80], "zero RHS solves to zero");
            continue;
        }
        let rel = norm2(&resid) / norm2(&bj);
        // True-residual slack over the recurrence tolerance.
        assert!(rel < tol * 100.0, "column {j} (scale {s:e}): true rel resid {rel}");
    }
}

#[test]
fn preconditioned_block_with_solution_seeds_is_free() {
    let op = kron_covariance(120, 16, 17);
    let mut rng = Rng::new(18);
    let b = Matrix::from_fn(120, 3, |_, _| rng.normal());
    let cfg = CgConfig { max_iters: 2000, tol: 1e-8, ..Default::default() };
    let pre = PivotedCholeskyPrecond::build(&op, 20, Some(NOISE)).unwrap();
    // Seeds solved two digits inside the warm solve's tolerance.
    let cold = block_cg_solve_with(&op, &b, &pre, None, CgConfig { tol: 1e-10, ..cfg });
    assert!(cold.all_converged());
    let warm = block_cg_solve_with(&op, &b, &pre, Some(&cold.x), cfg);
    assert!(warm.all_converged());
    assert_eq!(warm.x.data, cold.x.data, "solution seeds return bitwise");
    assert!(warm.columns.iter().all(|c| c.iters == 0));
    assert_eq!(warm.matmats, 1, "only the initial-residual block MVM");
}

/// `Precision::Mixed` on `CgConfig` routes the solve through iterative
/// refinement; both arithmetics stop on the same certificate, so the
/// solutions must agree on every operator family with an f32 mirror.
#[test]
fn mixed_precision_meets_the_f64_certificate_on_every_family() {
    let tol = 1e-8;
    let cfg = CgConfig { max_iters: 3000, tol, ..Default::default() };
    let mixed_cfg = CgConfig { precision: Precision::Mixed, ..cfg };
    let ops: Vec<(Box<dyn LinearOp>, &str)> = vec![
        (Box::new(dense_covariance(120, 10, 21)), "dense"),
        (Box::new(ski_covariance(400, 128, 22)), "ski"),
        (Box::new(kron_covariance(150, 16, 23)), "kronecker"),
    ];
    for (op, label) in &ops {
        let mut rng = Rng::new(24);
        let y = rng.normal_vec(op.dim());
        let gold = cg_solve(op.as_ref(), &y, cfg);
        assert!(gold.converged, "{label}: f64 CG did not converge");
        let id = IdentityPrecond::new(op.dim());
        let mixed = cg_solve_with(op.as_ref(), &y, &id, None, mixed_cfg);
        assert!(mixed.converged, "{label}: mixed solve did not converge");
        // The certificate is measured on the *true* f64 residual — verify
        // it independently of anything the solver reported.
        let ax = op.matvec(&mixed.x);
        let resid: Vec<f64> = ax.iter().zip(&y).map(|(a, b)| a - b).collect();
        let rel = norm2(&resid) / norm2(&y);
        assert!(rel <= tol * 10.0, "{label}: true rel residual {rel:e}");
        let err = rel_err(&mixed.x, &gold.x);
        assert!(err < 1e-4, "{label}: mixed drifted from f64 by {err:e}");
    }
}

/// Block solves honor the precision switch too: every column of a Mixed
/// block solve must land within the certificate-derived band of its f64
/// twin.
#[test]
fn mixed_precision_block_solve_matches_f64_per_column() {
    let op = kron_covariance(150, 16, 25);
    let mut rng = Rng::new(26);
    let b = Matrix::from_fn(150, 3, |_, _| rng.normal());
    let cfg = CgConfig { max_iters: 3000, tol: 1e-8, ..Default::default() };
    let gold = block_cg_solve(&op, &b, cfg);
    assert!(gold.all_converged());
    let id = IdentityPrecond::new(op.dim());
    let mixed = block_cg_solve_with(
        &op,
        &b,
        &id,
        None,
        CgConfig { precision: Precision::Mixed, ..cfg },
    );
    assert!(mixed.all_converged(), "mixed block solve did not converge");
    for j in 0..b.cols {
        let err = rel_err(&mixed.x.col(j), &gold.x.col(j));
        assert!(err < 1e-4, "column {j}: mixed drifted from f64 by {err:e}");
    }
}

/// The reason refinement exists: on a σ_n² = 1e-8 covariance
/// (κ ≈ 1e8, far beyond `1/eps32`) raw f32 CG floors out at O(1)
/// relative residual, while `refined_cg_solve` — via its stall detector
/// and f64 fallback — still meets the certificate. The spectrum is 8
/// large eigenvalues plus a repeated 1e-8 cluster, so f64 CG terminates
/// in a few dozen iterations; only the arithmetic separates the two.
#[test]
fn raw_f32_cg_stalls_where_refinement_still_certifies() {
    let n = 80;
    let mut rng = Rng::new(27);
    // Scale to λmax = O(1) so the f64 attainable floor (≈ eps64·κ) sits
    // two orders below the 1e-6 tolerance and the test bounds are
    // derived, not tuned.
    let scale = 1.0 / (n as f64).sqrt();
    let g = Matrix::from_fn(n, 8, |_, _| scale * rng.normal());
    let mut a = g.matmul_t(&g);
    a.add_diag(1e-8);
    let op = DenseOp(a);
    let y = rng.normal_vec(n);
    let cfg = CgConfig { max_iters: 3000, tol: 1e-6, ..Default::default() };

    let raw = raw_cg_f32(&op, &y, cfg).expect("dense operators have an f32 mirror");
    assert!(
        raw.rel_residual > 1e-3,
        "raw f32 CG should stall far above tolerance on κ≈1e8, got {:e}",
        raw.rel_residual
    );

    let id = IdentityPrecond::new(n);
    let refined = refined_cg_solve(&op, &y, &id, None, cfg);
    assert!(
        refined.converged,
        "refinement must certify where raw f32 stalls (rel {:e})",
        refined.rel_residual
    );
    let ax = op.matvec(&refined.x);
    let resid: Vec<f64> = ax.iter().zip(&y).map(|(a, b)| a - b).collect();
    let rel = norm2(&resid) / norm2(&y);
    assert!(rel <= 1e-5, "refined true rel residual {rel:e}");
}

#[test]
fn plain_block_cg_equals_preconditioned_block_with_identity() {
    // `block_cg_solve` (spec: None) and an explicit identity must produce
    // byte-identical solutions and per-column iteration counts — the
    // backward-compatibility contract of the PCG rewrite.
    let op = ski_covariance(200, 64, 19);
    let mut rng = Rng::new(20);
    let b = Matrix::from_fn(200, 4, |_, _| rng.normal());
    let cfg = CgConfig { max_iters: 2000, tol: 1e-8, ..Default::default() };
    let a = block_cg_solve(&op, &b, cfg);
    let id = IdentityPrecond::new(op.dim());
    let c = block_cg_solve_with(&op, &b, &id, None, cfg);
    assert_eq!(a.x.data, c.x.data);
    for (ca, cc) in a.columns.iter().zip(&c.columns) {
        assert_eq!(ca.iters, cc.iters);
    }
}
