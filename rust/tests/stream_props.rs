//! Property tests for the streaming-ingestion subsystem
//! (`skip_gp::stream`): incremental-vs-scratch agreement, dedup, the
//! refresh policy triggers, and snapshot-v3 pending-log persistence.

#![allow(clippy::needless_range_loop)] // index-heavy numeric test loops

use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant};
use skip_gp::grid::{Grid1d, GridSpec};
use skip_gp::linalg::Matrix;
use skip_gp::serve::{ModelSnapshot, VarianceMode};
use skip_gp::solvers::CgConfig;
use skip_gp::stream::{IncrementalState, RefreshReason, RowOutcome, StreamConfig};
use skip_gp::util::Rng;

fn smooth(r: &[f64]) -> f64 {
    r.iter()
        .enumerate()
        .map(|(k, &x)| ((k + 1) as f64 * 2.0 * x).sin())
        .sum()
}

/// Initial data with pinned per-dimension bounds [−1, 1], so a grid
/// fitted to the initial set is identical to one fitted to the union
/// with later points drawn strictly inside.
fn pinned_data(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>, Rng) {
    let mut rng = Rng::new(seed);
    let mut xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    for k in 0..d {
        xs.set(0, k, -1.0);
        xs.set(1, k, 1.0);
    }
    let ys: Vec<f64> = (0..n).map(|i| smooth(xs.row(i)) + 0.02 * rng.normal()).collect();
    (xs, ys, rng)
}

fn stream_points(rng: &mut Rng, count: usize, d: usize) -> Vec<(Vec<f64>, f64)> {
    (0..count)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-0.9, 0.9)).collect();
            let y = smooth(&x) + 0.02 * rng.normal();
            (x, y)
        })
        .collect()
}

/// No-policy stream config: ingestion stays purely incremental.
fn quiet_cfg() -> StreamConfig {
    StreamConfig {
        refresh_every: 0,
        var_drift_budget: usize::MAX,
        error_z: 0.0,
        log_capacity: 1 << 16,
        variance: VarianceMode::None,
        patch_eps: 1e-12,
        ..StreamConfig::default()
    }
}

/// Acceptance: streaming 64 points one at a time into an n=1024, d=2
/// KISS-SKI model matches a scratch-built model on the same 1088 points
/// — predictive mean and variance agree to ≤ 1e-6.
#[test]
fn incremental_ingest_matches_scratch_refit_1024() {
    let (n0, extra, d) = (1024, 64, 2);
    let (xs0, ys0, mut rng) = pinned_data(n0, d, 1);
    let streamed = stream_points(&mut rng, extra, d);

    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut cfg = MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid: GridSpec::uniform(32),
        ..Default::default()
    };
    // Both sides solve far below the 1e-6 acceptance band, so the
    // comparison measures the incremental algebra, not solver slack.
    cfg.cg.tol = 1e-12;
    cfg.cg.max_iters = 800;

    // Live model: adopt the initial-data model, then stream one at a
    // time. No policy refreshes — every point takes the warm path.
    let gp0 = MvmGp::new(xs0.clone(), ys0.clone(), h, cfg.clone());
    let mut live = IncrementalState::from_mvm(&gp0, quiet_cfg()).unwrap();
    for (x, y) in &streamed {
        let report = live.ingest(x, *y).unwrap();
        assert_eq!(report.accepted, 1);
        assert!(report.refreshed.is_none(), "policy must stay quiet");
    }
    assert_eq!(live.n(), n0 + extra);
    assert_eq!(live.pending(), extra);
    assert_eq!(live.stats.refreshes, 1, "only the construction refresh ran");

    // Scratch model on the full 1088-point set.
    let mut xs_full = xs0;
    let mut ys_full = ys0;
    for (x, y) in &streamed {
        xs_full.data.extend_from_slice(x);
        xs_full.rows += 1;
        ys_full.push(*y);
    }
    let mut scratch = MvmGp::new(xs_full, ys_full, h, cfg);
    scratch.refresh().unwrap();

    // Same frozen grid: the streamed points stayed inside the pinned
    // bounds, so the scratch fit reproduces the live axes exactly.
    assert_eq!(scratch.fitted_grid_axes().unwrap(), live.axes().to_vec());

    let xt = Matrix::from_fn(20, d, |_, _| rng.uniform_in(-0.85, 0.85));
    let live_mean = live.predict_mean(&xt);
    let scratch_mean = scratch.predict_mean(&xt);
    let live_var = live.predict_var(&xt).unwrap();
    let scratch_var = scratch.predict_var(&xt).unwrap();
    for i in 0..xt.rows {
        assert!(
            (live_mean[i] - scratch_mean[i]).abs() <= 1e-6,
            "mean[{i}]: streamed {} vs scratch {}",
            live_mean[i],
            scratch_mean[i]
        );
        assert!(
            (live_var[i] - scratch_var[i]).abs() <= 1e-6,
            "var[{i}]: streamed {} vs scratch {}",
            live_var[i],
            scratch_var[i]
        );
    }
}

/// The patched mean cache equals a cold-built cache on the same data
/// (the delta scatter loses nothing beyond float ordering).
#[test]
fn patched_mean_cache_equals_cold_rebuild() {
    let d = 2;
    let (xs0, ys0, mut rng) = pinned_data(96, d, 2);
    let streamed = stream_points(&mut rng, 24, d);
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 12).unwrap(),
        Grid1d::fit(-1.0, 1.0, 12).unwrap(),
    ];
    let h = GpHypers::new(0.6, 1.0, 0.05);
    let cg = CgConfig { max_iters: 400, tol: 1e-11, ..Default::default() };

    let mut live =
        IncrementalState::new(xs0.clone(), ys0.clone(), h, axes.clone(), cg, quiet_cfg())
            .unwrap();
    let mut patched_rows = 0usize;
    for (x, y) in &streamed {
        patched_rows += live.ingest(x, *y).unwrap().rows_patched;
    }
    assert!(patched_rows > 0, "patches must actually touch stencils");

    let mut xs_full = xs0;
    let mut ys_full = ys0;
    for (x, y) in &streamed {
        xs_full.data.extend_from_slice(x);
        xs_full.rows += 1;
        ys_full.push(*y);
    }
    let cold = IncrementalState::new(xs_full, ys_full, h, axes, cg, quiet_cfg()).unwrap();

    let live_mean = &live.cache().terms()[0].mean;
    let cold_mean = &cold.cache().terms()[0].mean;
    assert_eq!(live_mean.len(), cold_mean.len());
    let scale = cold_mean.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (a, b) in live_mean.iter().zip(cold_mean) {
        assert!(
            (a - b).abs() <= 1e-8 * scale,
            "patched cache drifted: {a} vs {b}"
        );
    }
}

/// Bitwise-duplicate observations are dropped without touching the model.
#[test]
fn duplicate_observations_are_dropped() {
    let (xs0, ys0, _) = pinned_data(40, 2, 3);
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
    ];
    let mut live = IncrementalState::new(
        xs0,
        ys0,
        GpHypers::new(0.6, 1.0, 0.05),
        axes,
        CgConfig::default(),
        quiet_cfg(),
    )
    .unwrap();
    let first = live.ingest(&[0.25, -0.125], 0.75).unwrap();
    assert_eq!(first.accepted, 1);
    assert_eq!(live.n(), 41);
    let again = live.ingest(&[0.25, -0.125], 0.75).unwrap();
    assert_eq!(again.accepted, 0);
    assert_eq!(again.duplicates, 1);
    assert_eq!(again.outcomes, vec![RowOutcome::Duplicate]);
    assert_eq!(live.n(), 41, "duplicate must not grow the model");
    // A re-measurement (same x, different y) is a fresh observation.
    let remeasure = live.ingest(&[0.25, -0.125], 0.8).unwrap();
    assert_eq!(remeasure.accepted, 1);
    assert_eq!(live.n(), 42);

    // Duplicates *within one coalesced block* (two clients retrying the
    // same observation into the same batch) dedup too — one point
    // ingested, per-row outcomes preserved.
    let xs = Matrix::from_vec(3, 2, vec![0.5, 0.5, 0.5, 0.5, 0.375, -0.25]);
    let block = live.ingest_block(&xs, &[1.0, 1.0, 2.0]).unwrap();
    assert_eq!(block.accepted, 2);
    assert_eq!(block.duplicates, 1);
    assert_eq!(block.outcomes[1], RowOutcome::Duplicate);
    assert!(matches!(block.outcomes[0], RowOutcome::Accepted { .. }));
    assert!(matches!(block.outcomes[2], RowOutcome::Accepted { .. }));
    assert_eq!(live.n(), 44);
}

/// A full observation ring escalates to a refresh that absorbs the log.
#[test]
fn ring_full_escalates_to_refresh() {
    let (xs0, ys0, mut rng) = pinned_data(40, 2, 4);
    let streamed = stream_points(&mut rng, 4, 2);
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
    ];
    let cfg = StreamConfig { log_capacity: 4, ..quiet_cfg() };
    let mut live = IncrementalState::new(
        xs0,
        ys0,
        GpHypers::new(0.6, 1.0, 0.05),
        axes,
        CgConfig::default(),
        cfg,
    )
    .unwrap();
    for (i, (x, y)) in streamed.iter().enumerate() {
        let report = live.ingest(x, *y).unwrap();
        if i < 3 {
            assert!(report.refreshed.is_none(), "ingest {i} refreshed early");
        } else {
            assert_eq!(report.refreshed, Some(RefreshReason::RingFull));
            assert_eq!(report.pending, 0, "refresh absorbs the pending log");
        }
    }
    assert_eq!(live.n(), 44);
}

/// The every-N-points policy triggers a refresh on schedule.
#[test]
fn refresh_every_policy_fires() {
    let (xs0, ys0, mut rng) = pinned_data(40, 2, 5);
    let streamed = stream_points(&mut rng, 6, 2);
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
    ];
    let cfg = StreamConfig { refresh_every: 3, ..quiet_cfg() };
    let mut live = IncrementalState::new(
        xs0,
        ys0,
        GpHypers::new(0.6, 1.0, 0.05),
        axes,
        CgConfig::default(),
        cfg,
    )
    .unwrap();
    let mut reasons = Vec::new();
    for (x, y) in &streamed {
        reasons.push(live.ingest(x, *y).unwrap().refreshed);
    }
    assert_eq!(
        reasons,
        vec![
            None,
            None,
            Some(RefreshReason::EveryN),
            None,
            None,
            Some(RefreshReason::EveryN)
        ]
    );
}

/// An outlier observation (standardized residual beyond `error_z`)
/// escalates to a full refresh.
#[test]
fn outlier_escalates_to_refresh() {
    let (xs0, ys0, _) = pinned_data(60, 2, 6);
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
    ];
    let cfg = StreamConfig { error_z: 4.0, ..quiet_cfg() };
    let mut live = IncrementalState::new(
        xs0,
        ys0,
        GpHypers::new(0.6, 1.0, 0.05),
        axes,
        CgConfig::default(),
        cfg,
    )
    .unwrap();
    // A well-predicted point does not trigger…
    let calm = live.ingest(&[0.2, 0.3], smooth(&[0.2, 0.3])).unwrap();
    assert!(calm.refreshed.is_none());
    // …a wild one does.
    let wild = live.ingest(&[0.1, -0.2], 500.0).unwrap();
    assert_eq!(wild.refreshed, Some(RefreshReason::Outlier));
    assert_eq!(live.stats.outlier_refreshes, 1);
}

/// Snapshot format v3 persists the pending log; replaying it into a
/// fresh model reproduces the live model's predictions.
#[test]
fn snapshot_v3_persists_and_replays_pending_log() {
    let (xs0, ys0, mut rng) = pinned_data(80, 2, 7);
    let streamed = stream_points(&mut rng, 10, 2);
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 10).unwrap(),
        Grid1d::fit(-1.0, 1.0, 10).unwrap(),
    ];
    let h = GpHypers::new(0.6, 1.0, 0.05);
    let cg = CgConfig { max_iters: 400, tol: 1e-11, ..Default::default() };
    let cfg = StreamConfig { variance: VarianceMode::Exact, ..quiet_cfg() };

    let mut live =
        IncrementalState::new(xs0.clone(), ys0.clone(), h, axes.clone(), cg, cfg.clone())
            .unwrap();
    for (x, y) in &streamed {
        live.ingest(x, *y).unwrap();
    }
    assert_eq!(live.pending(), streamed.len());

    // The pending log rides the snapshot bytes bitwise.
    let snap = live.to_snapshot();
    assert_eq!(snap.pending.len(), streamed.len());
    let bytes = snap.to_bytes();
    let back = ModelSnapshot::from_bytes(&bytes).unwrap();
    assert_eq!(back.pending, snap.pending);
    for (o, (x, y)) in back.pending.iter().zip(&streamed) {
        assert_eq!(&o.x, x, "pending x must be bitwise");
        assert_eq!(o.y, *y, "pending y must be bitwise");
    }

    // Replaying the pending log into a fresh base model reproduces the
    // live predictions.
    let mut replayed =
        IncrementalState::new(xs0, ys0, h, axes, cg, cfg).unwrap();
    let report = replayed.ingest_observations(&back.pending).unwrap();
    assert_eq!(report.accepted, streamed.len());
    let xt = Matrix::from_fn(15, 2, |_, _| rng.uniform_in(-0.8, 0.8));
    let a = live.predict_mean(&xt);
    let b = replayed.predict_mean(&xt);
    for (u, v) in a.iter().zip(&b) {
        assert!((u - v).abs() < 1e-8, "replayed mean {v} vs live {u}");
    }
}

/// Streaming rejects model families it cannot update online, with typed
/// errors that say so.
#[test]
fn unsupported_models_are_typed_errors() {
    let (xs, ys, _) = pinned_data(50, 2, 8);
    let h = GpHypers::new(0.6, 1.0, 0.05);
    // SKIP variant: the merge tree cannot extend by a row.
    let skip_gp_model = MvmGp::new(
        xs.clone(),
        ys.clone(),
        h,
        MvmGpConfig { grid: GridSpec::uniform(16), ..Default::default() },
    );
    let err = IncrementalState::from_mvm(&skip_gp_model, quiet_cfg()).unwrap_err();
    assert!(err.to_string().contains("KISS"), "{err}");
    // Sparse (multi-term) grids: the single-term patch path does not
    // apply.
    let sparse = MvmGp::new(
        xs,
        ys,
        h,
        MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::sparse(3),
            ..Default::default()
        },
    );
    let err = IncrementalState::from_mvm(&sparse, quiet_cfg()).unwrap_err();
    assert!(err.to_string().contains("single-term"), "{err}");
}

/// Non-finite observations are rejected before any state mutates.
#[test]
fn non_finite_observations_are_rejected() {
    let (xs0, ys0, _) = pinned_data(40, 2, 9);
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
        Grid1d::fit(-1.0, 1.0, 8).unwrap(),
    ];
    let mut live = IncrementalState::new(
        xs0,
        ys0,
        GpHypers::new(0.6, 1.0, 0.05),
        axes,
        CgConfig::default(),
        quiet_cfg(),
    )
    .unwrap();
    let err = live.ingest(&[f64::NAN, 0.1], 1.0).unwrap_err();
    assert!(err.to_string().contains("non-finite"), "{err}");
    let err = live.ingest(&[0.1, 0.2], f64::INFINITY).unwrap_err();
    assert!(err.to_string().contains("non-finite"), "{err}");
    assert_eq!(live.n(), 40, "rejected observations must not grow the model");
}
