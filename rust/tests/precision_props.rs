//! Property tests for the mixed-precision MVM substrate
//! (`operators::LinearOpF32` + `solvers::refine`):
//!
//! - Every f32 operator view (SKI, Kronecker-SKI, the affine/sum
//!   wrappers, sparse-grid compositions) reproduces its f64 parent
//!   elementwise to f32-accumulation accuracy — the views are *storage*
//!   mirrors, not approximations.
//! - `Precision::Mixed` training meets the acceptance bar end to end:
//!   the cached α agrees with an f64-trained twin to ≤ 1e-6 in data
//!   space, grid space, and under streaming ingestion — because both
//!   paths stop on the same `‖K̂α − y‖_{M⁻¹} ≤ tol·‖y‖_{M⁻¹}`
//!   certificate, the agreement is derived (≈ 2·tol/σ_n²), not tuned.
//! - The precision switch folds down from the model/stream configs into
//!   every solve site: a Mixed run must actually tick the
//!   `solver.refine.*` meters.

use skip_gp::coordinator::metrics;
use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant, SolveSpace};
use skip_gp::grid::{build_grid, grid_ski_operator, GridSpec};
use skip_gp::kernels::{ProductKernel, Stationary1d};
use skip_gp::linalg::Matrix;
use skip_gp::operators::{AffineOp, KroneckerSkiOp, LinearOp, LinearOpF32, SkiOp};
use skip_gp::serve::VarianceMode;
use skip_gp::solvers::{CgConfig, Precision, SolverPolicy};
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::{mae, Rng};

/// Elementwise f32-view agreement: `|K v − K₃₂ v₃₂|_i ≤ tol·‖Kv‖_∞`.
/// The bound covers f32 storage rounding (≈ 6e-8 relative) plus f32
/// accumulation over the stencil/butterfly chains — 1e-3 leaves two
/// orders of slack at the test sizes while still catching any use of a
/// stale or truncated buffer outright.
fn assert_f32_view_matches(op: &dyn LinearOp, seed: u64, label: &str) {
    let view = op.as_f32().unwrap_or_else(|| panic!("{label}: missing f32 view"));
    let n = op.dim();
    assert_eq!(view.dim(), n, "{label}: view dimension");
    let mut rng = Rng::new(seed);
    let v = rng.normal_vec(n);
    let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    let w64 = op.matvec(&v);
    let w32 = view.matvec_f32(&v32);
    assert_eq!(w32.len(), n, "{label}: view output length");
    let scale = w64.iter().fold(1.0f64, |m, &x| m.max(x.abs()));
    for (i, (&a, &b)) in w64.iter().zip(&w32).enumerate() {
        let err = (a - b as f64).abs();
        assert!(
            err <= 1e-3 * scale,
            "{label}: row {i} diverged: f64 {a} vs f32 {b} (scale {scale:e})"
        );
    }
}

#[test]
fn ski_f32_view_matches_f64() {
    let mut rng = Rng::new(1);
    let xs = rng.uniform_vec(500, -2.0, 2.0);
    let kern = Stationary1d::rbf(0.5);
    let op = SkiOp::new(&xs, &kern, 128).expect("ski grid");
    assert_f32_view_matches(&op, 2, "ski");
}

#[test]
fn kronecker_f32_view_matches_f64() {
    let mut rng = Rng::new(3);
    let xs = Matrix::from_fn(400, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let kern = ProductKernel::rbf(2, 0.6, 1.0);
    let op = KroneckerSkiOp::new(&xs, &kern, 16).expect("kron grid");
    assert_f32_view_matches(&op, 4, "kronecker");
    // The typed view and the trait-object view are the same mirror.
    let view = op.f32_view();
    let mut rng = Rng::new(4);
    let v = rng.normal_vec(op.dim());
    let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    let via_trait = op.as_f32().expect("kron f32 view").matvec_f32(&v32);
    assert_eq!(view.matvec_f32(&v32), via_trait, "typed and trait views must agree");
}

#[test]
fn affine_wrapper_composes_f32_view() {
    // σ_f²·K + σ_n²·I — the exact covariance shape every solve sees.
    let mut rng = Rng::new(5);
    let xs = rng.uniform_vec(300, -2.0, 2.0);
    let kern = Stationary1d::rbf(0.4);
    let ski = SkiOp::new(&xs, &kern, 64).expect("ski grid");
    let op = AffineOp { inner: Box::new(ski), scale: 2.5, shift: 1e-3 };
    assert_f32_view_matches(&op, 6, "affine(ski)");
}

#[test]
fn sparse_grid_composition_has_f32_view() {
    // The combination-technique operator is a SumOp of coefficient-scaled
    // Kronecker terms (signed coefficients included) — the wrapper
    // delegation must surface one composite f32 view for it.
    let mut rng = Rng::new(7);
    let xs = Matrix::from_fn(350, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let kern = ProductKernel::rbf(2, 0.6, 1.0);
    let grid = build_grid(&xs, &GridSpec::Sparse { level: 3 }).expect("sparse grid");
    let op = grid_ski_operator(&xs, &kern, grid.as_ref());
    assert_f32_view_matches(op.as_ref(), 8, "sparse-grid sum");
}

/// Smooth toy regression problem on [−1, 1]^d (pinned bounds so a grid
/// fitted to the initial rows also covers streamed interior points).
fn toy(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let f = |row: &[f64]| -> f64 {
        row.iter().enumerate().map(|(k, &x)| ((k + 1) as f64 * x).sin()).sum()
    };
    let mut xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    for k in 0..d {
        xs.set(0, k, -1.0);
        xs.set(1, k, 1.0);
    }
    let ys: Vec<f64> = (0..n).map(|i| f(xs.row(i)) + 0.05 * rng.normal()).collect();
    (xs, ys)
}

fn kiss_cfg(space: SolveSpace, precision: Precision) -> MvmGpConfig {
    MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid: GridSpec::uniform(16),
        cg: CgConfig { max_iters: 1500, tol: 1e-10, ..Default::default() },
        policy: SolverPolicy {
            warm_start: false,
            space,
            precision,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Train two KISS models on the same data — one per precision — and
/// return both cached αs (f64 first).
fn alphas_both_precisions(space: SolveSpace, seed: u64) -> (Vec<f64>, Vec<f64>) {
    // σ_n² = 1 keeps the derived α bound at ≈ 2·tol (module docs).
    let hypers = GpHypers::new(0.6, 1.0, 1.0);
    let (xs, ys) = toy(1024, 2, seed);
    let f64_cfg = kiss_cfg(space, Precision::F64);
    let mut f64_gp = MvmGp::new(xs.clone(), ys.clone(), hypers, f64_cfg);
    f64_gp.refresh().unwrap();
    let mut mixed_gp = MvmGp::new(xs, ys, hypers, kiss_cfg(space, Precision::Mixed));
    mixed_gp.refresh().unwrap();
    (f64_gp.alpha().unwrap().to_vec(), mixed_gp.alpha().unwrap().to_vec())
}

/// Acceptance: Mixed training reproduces the f64 α to ≤ 1e-6 in data
/// space, and the refinement meters prove the mixed path actually ran
/// (the config fold-down from `MvmGpConfig.precision` into every solve).
#[test]
fn mixed_training_matches_f64_data_space() {
    let g = metrics::global();
    let refined = |g: &skip_gp::coordinator::metrics::Metrics| {
        g.counter("solver.refine.sweeps") + g.counter("solver.refine.fallback.no_f32")
    };
    let sweeps0 = refined(g);
    let (a64, amix) = alphas_both_precisions(SolveSpace::Data, 11);
    let err = mae(&a64, &amix);
    assert!(err < 1e-6, "data-space mixed vs f64 α mae {err:e}");
    let sweeps1 = refined(g);
    assert!(
        sweeps1 > sweeps0,
        "Precision::Mixed must route the y-solve through solvers::refine"
    );
}

/// The same acceptance through the grid-space (m×m normal-equations)
/// engine, whose inner solves run against the StencilGram system.
#[test]
fn mixed_training_matches_f64_grid_space() {
    let (a64, amix) = alphas_both_precisions(SolveSpace::Grid, 13);
    let err = mae(&a64, &amix);
    assert!(err < 1e-6, "grid-space mixed vs f64 α mae {err:e}");
}

/// Streaming ingestion under a Mixed-precision solver policy: after
/// identical one-at-a-time ingests, the live α and predictive means agree
/// with an f64 streaming twin to the acceptance band.
#[test]
fn mixed_streaming_matches_f64() {
    let (n0, extra, d) = (512, 32, 2);
    let (xs0, ys0) = toy(n0, d, 17);
    let mut rng = Rng::new(18);
    let smooth = |x: &[f64]| -> f64 {
        x.iter().enumerate().map(|(k, &v)| ((k + 1) as f64 * v).sin()).sum()
    };
    let streamed: Vec<(Vec<f64>, f64)> = (0..extra)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-0.9, 0.9)).collect();
            let y = smooth(&x);
            (x, y)
        })
        .collect();
    let hypers = GpHypers::new(0.6, 1.0, 1.0);

    let quiet = |precision: Precision| StreamConfig {
        refresh_every: 0,
        var_drift_budget: usize::MAX,
        error_z: 0.0,
        variance: VarianceMode::None,
        policy: SolverPolicy { precision, ..Default::default() },
        ..StreamConfig::default()
    };
    let run = |precision: Precision| -> IncrementalState {
        // The base model stays f64 either way: the stream-level switch
        // alone must carry Mixed into the per-ingest re-solves.
        let gp = MvmGp::new(
            xs0.clone(),
            ys0.clone(),
            hypers,
            kiss_cfg(SolveSpace::Data, Precision::F64),
        );
        let mut live = IncrementalState::from_mvm(&gp, quiet(precision)).unwrap();
        for (x, y) in &streamed {
            let report = live.ingest(x, *y).expect("ingest");
            assert_eq!(report.accepted, 1);
        }
        live
    };
    let f64_live = run(Precision::F64);
    let mixed_live = run(Precision::Mixed);
    assert_eq!(mixed_live.n(), n0 + extra);

    let err = mae(f64_live.alpha(), mixed_live.alpha());
    assert!(err < 1e-6, "streamed mixed vs f64 α mae {err:e}");

    let step = 1.8 / (64 * d) as f64;
    let xtest = Matrix::from_fn(64, d, |i, k| -0.9 + step * (i * d + k) as f64);
    let m64 = f64_live.predict_mean(&xtest);
    let mmix = mixed_live.predict_mean(&xtest);
    let perr = mae(&m64, &mmix);
    assert!(perr < 1e-6, "streamed mixed vs f64 predictive mean mae {perr:e}");
}
