//! Benchmark harness: one module per paper table/figure.
//!
//! | Experiment | Module | CLI |
//! |---|---|---|
//! | Fig 2 left (MVM error vs rank) | [`fig2`] | `skip-gp bench fig2-left` |
//! | Fig 2 right (time vs m/dim)    | [`fig2`] | `skip-gp bench fig2-right` |
//! | Table 1 (MAE + train time)     | [`table1`] | `skip-gp bench table1` |
//! | Table 2 (complexities)         | [`table2`] | `skip-gp bench table2` |
//! | Fig 3 (cluster posterior)      | [`fig3`] | `skip-gp bench fig3` |
//! | Fig 4 (MAE vs #tasks)          | [`fig4`] | `skip-gp bench fig4` |
//! | §6 20× MLL speedup             | [`mtgp_speed`] | `skip-gp bench mtgp-speedup` |

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod mtgp_speed;
pub mod table1;
pub mod table2;
