//! Table 1 harness: Test MAE + train time for Full GP, SGPR (m = 200/400/
//! 800), KISS-GP and SKIP across the six benchmark datasets.
//!
//! Scope rules follow the paper: the Full GP runs only on the two smallest
//! datasets (Pumadyn, Elevators); KISS-GP runs only where d ≤ 5
//! (precipitation); SKIP runs everywhere with m = 100 per dimension.
//!
//! This testbed is one CPU core (the paper used a Titan Xp), so datasets
//! are generated at `scale` of their paper sizes; what must reproduce is
//! the *ordering*: SKIP ≈ or better than SGPR's MAE at a fraction of the
//! train time on d > 5 datasets.

use crate::coordinator::Session;
use crate::data::{generate, RegressionData, DATASETS};
use crate::gp::{ExactGp, GpHypers, MvmGp, MvmGpConfig, MvmVariant, Sgpr};
use crate::util::{mae, Timer};
use crate::Result;
use std::path::Path;

/// Table-1 run configuration.
pub struct Table1Config {
    /// Fraction of each dataset's paper-scale n.
    pub scale: f64,
    /// ADAM steps per model.
    pub steps: usize,
    /// Exact GP hard cap on n (n³ cost).
    pub exact_cap: usize,
    /// SGPR inducing-point counts.
    pub sgpr_m: Vec<usize>,
    /// SKIP inducing points per dimension (paper: 100).
    pub skip_m: usize,
    /// SKIP Lanczos rank.
    pub rank: usize,
    /// Restrict to one dataset (None = all).
    pub only: Option<String>,
    pub seed: u64,
}

impl Default for Table1Config {
    fn default() -> Self {
        Table1Config {
            scale: 0.125,
            steps: 10,
            exact_cap: 2500,
            sgpr_m: vec![200, 400, 800],
            skip_m: 100,
            rank: 30,
            only: None,
            seed: 0,
        }
    }
}

/// One method's outcome on one dataset.
#[derive(Clone, Debug)]
pub struct MethodResult {
    pub dataset: String,
    pub method: String,
    pub mae: f64,
    pub train_s: f64,
    pub n: usize,
    pub d: usize,
}

fn run_exact(data: &RegressionData, cfg: &Table1Config) -> Result<MethodResult> {
    let mut gp = ExactGp::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
    );
    let t = Timer::start();
    gp.fit(cfg.steps, 0.1)?;
    let train_s = t.elapsed_s();
    let pred = gp.predict_mean(&data.xtest);
    Ok(MethodResult {
        dataset: data.name.clone(),
        method: "full_gp".into(),
        mae: mae(&pred, &data.ytest),
        train_s,
        n: data.n(),
        d: data.d(),
    })
}

fn run_sgpr(data: &RegressionData, m: usize, cfg: &Table1Config) -> Result<MethodResult> {
    let mut gp = Sgpr::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        m,
        cfg.seed,
    );
    let t = Timer::start();
    gp.fit(cfg.steps, 0.1)?;
    let train_s = t.elapsed_s();
    let pred = gp.predict_mean(&data.xtest);
    Ok(MethodResult {
        dataset: data.name.clone(),
        method: format!("sgpr_m{m}"),
        mae: mae(&pred, &data.ytest),
        train_s,
        n: data.n(),
        d: data.d(),
    })
}

fn run_mvm(
    data: &RegressionData,
    variant: MvmVariant,
    cfg: &Table1Config,
) -> Result<MethodResult> {
    let name = match variant {
        MvmVariant::Skip => "skip".to_string(),
        MvmVariant::Kiss => "kiss_gp".to_string(),
    };
    let grid_m = match variant {
        MvmVariant::Skip => cfg.skip_m,
        // KISS: total grid mᵈ — keep per-dim grid modest like the paper's
        // low-d setting.
        MvmVariant::Kiss => 40,
    };
    let mut gp = MvmGp::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        MvmGpConfig {
            variant,
            grid: crate::grid::GridSpec::uniform(grid_m),
            rank: cfg.rank,
            seed: cfg.seed,
            ..Default::default()
        },
    );
    let t = Timer::start();
    gp.fit(cfg.steps, 0.1)?;
    let train_s = t.elapsed_s();
    let pred = gp.predict_mean(&data.xtest);
    Ok(MethodResult {
        dataset: data.name.clone(),
        method: name,
        mae: mae(&pred, &data.ytest),
        train_s,
        n: data.n(),
        d: data.d(),
    })
}

/// Run Table 1 and return all rows (also written to CSV).
pub fn table1(cfg: &Table1Config, out_dir: &Path) -> Result<Vec<MethodResult>> {
    let mut session = Session::new("table1", out_dir)?;
    session.header(&["dataset", "n", "d", "method", "test_mae", "train_time_s"]);
    let mut all = Vec::new();
    // The six Table-1 datasets (everything registered except power).
    for spec in DATASETS.iter().filter(|s| s.name != "power") {
        if let Some(only) = &cfg.only {
            if only != spec.name {
                continue;
            }
        }
        let data = generate(spec, cfg.scale);
        println!(
            "── {} (n={}, d={}, paper n={}) ──",
            spec.name,
            data.n(),
            data.d(),
            spec.n
        );
        let mut results = Vec::new();
        // Full GP: two smallest datasets only (paper's applicability rule).
        if matches!(spec.name, "pumadyn" | "elevators") && data.n() <= cfg.exact_cap {
            results.push(run_exact(&data, cfg)?);
        }
        for &m in &cfg.sgpr_m {
            results.push(run_sgpr(&data, m.min(data.n()), cfg)?);
        }
        // KISS-GP: applicable only when d ≤ 5 (precipitation here).
        if data.d() <= 5 {
            results.push(run_mvm(&data, MvmVariant::Kiss, cfg)?);
        }
        results.push(run_mvm(&data, MvmVariant::Skip, cfg)?);
        for r in &results {
            println!(
                "  {:<10} mae={:.4}  train={:.2}s",
                r.method, r.mae, r.train_s
            );
            session.rowf(&[&r.dataset, &r.n, &r.d, &r.method, &r.mae, &r.train_s]);
        }
        all.extend(results);
    }
    session.print_table();
    let path = session.finish()?;
    println!("wrote {}", path.display());
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_single_tiny_dataset() {
        let dir = std::env::temp_dir().join(format!("skipgp-t1-{}", std::process::id()));
        let cfg = Table1Config {
            scale: 0.02,
            steps: 3,
            exact_cap: 400,
            sgpr_m: vec![50],
            skip_m: 32,
            rank: 30,
            only: Some("protein".into()),
            seed: 0,
        };
        let rows = table1(&cfg, &dir).unwrap();
        // protein: SGPR + SKIP (no exact: not in the two smallest; no KISS: d=9).
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.mae.is_finite() && r.mae < 1.5));
        assert!(rows.iter().any(|r| r.method == "skip"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn skip_learns_signal_on_highdim_dataset() {
        // MAE clearly below the z-scored target std of 1 (predicting the
        // mean would give MAE ≈ 0.8).
        let dir = std::env::temp_dir().join(format!("skipgp-t1b-{}", std::process::id()));
        let cfg = Table1Config {
            scale: 0.05,
            steps: 5,
            exact_cap: 0,
            sgpr_m: vec![],
            skip_m: 50,
            rank: 40,
            only: Some("pumadyn".into()),
            seed: 1,
        };
        let rows = table1(&cfg, &dir).unwrap();
        let skip = rows.iter().find(|r| r.method == "skip").unwrap();
        assert!(skip.mae < 0.75, "skip mae {}", skip.mae);
        std::fs::remove_dir_all(dir).ok();
    }
}
