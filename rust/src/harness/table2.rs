//! Table 2 harness: asymptotic complexity of one inference step.
//!
//! The table itself is analytic; we print it verbatim and then *verify*
//! the key scalings empirically: time one covariance MVM per method
//! across a sweep of n (and the KISS grid across m) and fit the log-log
//! slope. Success = measured slope within ±0.35 of the theoretical
//! exponent (constants and cache effects put wiggle on small problems).

use crate::coordinator::Session;
use crate::data::gaussian_cloud;
use crate::gp::GpHypers;
use crate::kernels::ProductKernel;
use crate::linalg::Cholesky;
use crate::operators::{KroneckerSkiOp, LinearOp, SkiOp, SkipComponent, SkipOp};
use crate::util::{bench_median_s, ols_slope, Rng};
use crate::Result;
use std::path::Path;

/// The analytic Table 2 (printed as-is).
pub const ANALYTIC: &[(&str, &str)] = &[
    ("GP (Chol)", "O(n^3)"),
    ("GP (MVM)", "O(p n^2)"),
    ("SVGP", "O(n m^2 + m^3 + d n m)"),
    ("KISS-GP", "O(p n + p d m^d log m)"),
    ("SKIP", "O(d r n + d r m log m + r^3 n log d + p r^2 n)"),
];

pub struct Table2Config {
    /// n sweep for the per-method scaling fit.
    pub ns: Vec<usize>,
    pub d: usize,
    pub rank: usize,
    pub grid_m: usize,
    pub seed: u64,
}

impl Default for Table2Config {
    fn default() -> Self {
        Table2Config {
            ns: vec![256, 512, 1024, 2048],
            d: 4,
            rank: 20,
            grid_m: 64,
            seed: 0,
        }
    }
}

/// Measured scaling row.
#[derive(Clone, Debug)]
pub struct ScalingRow {
    pub method: String,
    pub variable: String,
    pub theoretical_slope: f64,
    pub measured_slope: f64,
    pub times: Vec<(usize, f64)>,
}

fn fit_slope(times: &[(usize, f64)]) -> f64 {
    let lx: Vec<f64> = times.iter().map(|(n, _)| (*n as f64).ln()).collect();
    let ly: Vec<f64> = times.iter().map(|(_, t)| t.ln()).collect();
    ols_slope(&lx, &ly)
}

/// Run Table 2: print the analytic table, then empirical slope checks.
pub fn table2(cfg: &Table2Config, out_dir: &Path) -> Result<Vec<ScalingRow>> {
    let mut session = Session::new("table2", out_dir)?;
    session.header(&["method", "variable", "theory_slope", "measured_slope"]);
    println!("Table 2 (analytic complexities of one inference step):");
    for (m, c) in ANALYTIC {
        println!("  {m:<12} {c}");
    }
    println!("\nEmpirical scaling fits (log-log slope of MVM/solve time):");
    let mut rows = Vec::new();
    let h = GpHypers::default_init();
    let kern = ProductKernel::rbf(cfg.d, h.ell(), 1.0);

    // 1. Cholesky factorization vs n → slope 3.
    {
        let mut times = Vec::new();
        for &n in &cfg.ns {
            let xs = gaussian_cloud(n, cfg.d, cfg.seed);
            let mut k = kern.gram_sym(&xs);
            k.add_diag(0.1);
            let t = bench_median_s(2, 0.05, || {
                let _ = Cholesky::new(&k).unwrap();
            });
            times.push((n, t));
        }
        rows.push(ScalingRow {
            method: "gp_chol".into(),
            variable: "n".into(),
            theoretical_slope: 3.0,
            measured_slope: fit_slope(&times),
            times,
        });
    }

    // 2. Dense kernel MVM vs n → slope 2 (the GP-MVM per-iteration cost).
    {
        let mut times = Vec::new();
        for &n in &cfg.ns {
            let xs = gaussian_cloud(n, cfg.d, cfg.seed + 1);
            let k = kern.gram_sym(&xs);
            let mut rng = Rng::new(cfg.seed);
            let v = rng.normal_vec(n);
            let t = bench_median_s(3, 0.05, || {
                let _ = k.matvec(&v);
            });
            times.push((n, t));
        }
        rows.push(ScalingRow {
            method: "gp_mvm".into(),
            variable: "n".into(),
            theoretical_slope: 2.0,
            measured_slope: fit_slope(&times),
            times,
        });
    }

    // 3. SKIP MVM vs n → slope 1 (O(r²n) after the cached decomposition).
    {
        let mut times = Vec::new();
        for &n in &cfg.ns {
            let xs = gaussian_cloud(n, cfg.d, cfg.seed + 2);
            let skis = (0..cfg.d)
                .map(|k| SkiOp::new(&xs.col(k), &kern.factors[k], cfg.grid_m))
                .collect::<Result<Vec<SkiOp>>>()?;
            let comps: Vec<SkipComponent> = skis
                .iter()
                .map(|s| SkipComponent::Op(s as &dyn LinearOp))
                .collect();
            let mut rng = Rng::new(cfg.seed + 3);
            let skip = SkipOp::build_native(comps, cfg.rank, &mut rng);
            let v = rng.normal_vec(n);
            let t = bench_median_s(3, 0.05, || {
                let _ = skip.matvec(&v);
            });
            times.push((n, t));
        }
        rows.push(ScalingRow {
            method: "skip_mvm".into(),
            variable: "n".into(),
            theoretical_slope: 1.0,
            measured_slope: fit_slope(&times),
            times,
        });
    }

    // 4. SKI (1-D) MVM vs n → slope 1.
    {
        let mut times = Vec::new();
        for &n in &cfg.ns {
            let xs = gaussian_cloud(n, 1, cfg.seed + 4);
            let ski = SkiOp::new(&xs.col(0), &kern.factors[0], cfg.grid_m)?;
            let mut rng = Rng::new(cfg.seed);
            let v = rng.normal_vec(n);
            let t = bench_median_s(5, 0.05, || {
                let _ = ski.matvec(&v);
            });
            times.push((n, t));
        }
        rows.push(ScalingRow {
            method: "ski_mvm".into(),
            variable: "n".into(),
            theoretical_slope: 1.0,
            measured_slope: fit_slope(&times),
            times,
        });
    }

    // 5. KISS-GP grid cost vs m (d = 3, fixed n) → superlinear in m
    //    (the d·mᵈ·log m grid term; slope ≈ d = 3 in m).
    {
        let d = 3usize;
        let n = 512;
        let kern3 = ProductKernel::rbf(d, 1.0, 1.0);
        let xs = gaussian_cloud(n, d, cfg.seed + 5);
        let mut times = Vec::new();
        for &m in &[8usize, 16, 32, 64] {
            let op = KroneckerSkiOp::new(&xs, &kern3, m)?;
            let mut rng = Rng::new(cfg.seed);
            let v = rng.normal_vec(n);
            let t = bench_median_s(3, 0.05, || {
                let _ = op.matvec(&v);
            });
            times.push((m, t));
        }
        rows.push(ScalingRow {
            method: "kiss_mvm".into(),
            variable: "m".into(),
            theoretical_slope: 3.0,
            measured_slope: fit_slope(&times),
            times,
        });
    }

    for r in &rows {
        println!(
            "  {:<10} vs {:<2} theory {:.1}  measured {:.2}   {:?}",
            r.method,
            r.variable,
            r.theoretical_slope,
            r.measured_slope,
            r.times
                .iter()
                .map(|(n, t)| format!("{n}:{:.2e}", t))
                .collect::<Vec<_>>()
        );
        session.rowf(&[
            &r.method,
            &r.variable,
            &r.theoretical_slope,
            &r.measured_slope,
        ]);
    }
    session.print_table();
    let path = session.finish()?;
    println!("wrote {}", path.display());
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_table_has_all_methods() {
        let names: Vec<&str> = ANALYTIC.iter().map(|(m, _)| *m).collect();
        for want in ["GP (Chol)", "GP (MVM)", "SVGP", "KISS-GP", "SKIP"] {
            assert!(names.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn scaling_fits_are_sane() {
        let dir = std::env::temp_dir().join(format!("skipgp-t2-{}", std::process::id()));
        let cfg = Table2Config {
            ns: vec![128, 256, 512],
            d: 3,
            rank: 10,
            grid_m: 32,
            seed: 0,
        };
        let rows = table2(&cfg, &dir).unwrap();
        let chol = rows.iter().find(|r| r.method == "gp_chol").unwrap();
        let skip = rows.iter().find(|r| r.method == "skip_mvm").unwrap();
        // Cholesky must scale clearly superlinearly; SKIP clearly sublinear
        // vs Cholesky. Exact slopes jitter at these tiny sizes, so assert
        // the ordering rather than tight bands.
        assert!(
            chol.measured_slope > skip.measured_slope + 0.8,
            "chol {} vs skip {}",
            chol.measured_slope,
            skip.measured_slope
        );
        assert!(chol.measured_slope > 2.0, "chol slope {}", chol.measured_slope);
        assert!(skip.measured_slope < 1.8, "skip slope {}", skip.measured_slope);
        std::fs::remove_dir_all(dir).ok();
    }
}
