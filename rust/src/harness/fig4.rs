//! Figure 4 harness: extrapolation MAE (left panel) and runtime (right
//! panel) on the childhood-growth workload as a function of the number of
//! tasks, for three models: shared (single-task) GP, standard MTGP, and
//! the cluster MTGP.
//!
//! Protocol (paper §6): a fixed set of evaluation children contributes
//! only its first half of measurements; models extrapolate the second
//! half. Additional children (tasks) are added to the model, which should
//! refine everyone's extrapolations — with cluster-MTGP ≤ MTGP < shared.

use crate::coordinator::Session;
use crate::data::growth::{generate, split_child, GrowthConfig};
use crate::gp::mtgp::MtgpData;
use crate::gp::{
    ClusterMtgp, ClusterMtgpConfig, ExactGp, GpHypers, Mtgp, MtgpConfig,
};
use crate::linalg::Matrix;
use crate::util::{mae, Timer};
use crate::Result;
use std::path::Path;

pub struct Fig4Config {
    /// Evaluation children (fixed).
    pub eval_children: usize,
    /// Total task counts to sweep (must be > eval_children).
    pub task_counts: Vec<usize>,
    pub num_clusters: usize,
    /// Fraction of each eval child's measurements observed.
    pub observed_frac: f64,
    pub mtgp_steps: usize,
    pub gibbs_sweeps: usize,
    pub seed: u64,
}

impl Default for Fig4Config {
    fn default() -> Self {
        Fig4Config {
            eval_children: 12,
            task_counts: vec![16, 24, 36, 48],
            num_clusters: 3,
            observed_frac: 0.5,
            mtgp_steps: 12,
            gibbs_sweeps: 4,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub num_tasks: usize,
    pub method: String,
    pub mae: f64,
    pub seconds: f64,
}

/// Build the training set: all non-eval children in full, eval children
/// truncated to their observed head; returns (train data, eval queries).
struct EvalSplit {
    train: MtgpData,
    /// (x, task, y_true) extrapolation targets.
    queries: Vec<(f64, usize, f64)>,
}

fn build_split(full: &MtgpData, eval_children: usize, frac: f64) -> EvalSplit {
    let mut x = Vec::new();
    let mut y = Vec::new();
    let mut task_of = Vec::new();
    let mut queries = Vec::new();
    for child in 0..full.num_tasks {
        if child < eval_children {
            let total = full.task_of.iter().filter(|&&t| t == child).count();
            let keep = ((total as f64 * frac) as usize).max(2);
            let (hx, hy, tx, ty) = split_child(full, child, keep);
            for (xi, yi) in hx.iter().zip(&hy) {
                x.push(*xi);
                y.push(*yi);
                task_of.push(child);
            }
            for (xi, yi) in tx.iter().zip(&ty) {
                queries.push((*xi, child, *yi));
            }
        } else {
            for i in 0..full.len() {
                if full.task_of[i] == child {
                    x.push(full.x[i]);
                    y.push(full.y[i]);
                    task_of.push(child);
                }
            }
        }
    }
    EvalSplit {
        train: MtgpData { x, y, task_of, num_tasks: full.num_tasks },
        queries,
    }
}

/// Run Fig 4 and return all rows.
pub fn fig4(cfg: &Fig4Config, out_dir: &Path) -> Result<Vec<Fig4Row>> {
    let mut session = Session::new("fig4", out_dir)?;
    session.header(&["num_tasks", "method", "extrap_mae", "time_s"]);
    let mut rows = Vec::new();
    for &num_tasks in &cfg.task_counts {
        assert!(num_tasks > cfg.eval_children);
        let growth = generate(&GrowthConfig {
            num_children: num_tasks,
            num_clusters: cfg.num_clusters,
            min_obs: 6,
            max_obs: 14,
            seed: cfg.seed, // same seed → eval children identical across sweeps
            ..Default::default()
        });
        let split = build_split(&growth.data, cfg.eval_children, cfg.observed_frac);
        let qx: Vec<f64> = split.queries.iter().map(|q| q.0).collect();
        let qt: Vec<usize> = split.queries.iter().map(|q| q.1).collect();
        let qy: Vec<f64> = split.queries.iter().map(|q| q.2).collect();
        println!(
            "── {} tasks (n={}, {} extrapolation targets) ──",
            num_tasks,
            split.train.len(),
            qy.len()
        );

        // 1. Shared GP: pool everything as one task.
        {
            let t = Timer::start();
            let xs = Matrix::col_vec(&split.train.x);
            let mut gp = ExactGp::new(
                xs,
                split.train.y.clone(),
                GpHypers::new(0.3, 1.0, 0.05),
            );
            gp.fit(8, 0.1)?;
            let qxm = Matrix::col_vec(&qx);
            let pred = gp.predict_mean(&qxm);
            let m = mae(&pred, &qy);
            let dt = t.elapsed_s();
            println!("  shared_gp     mae={m:.4}  ({dt:.1}s)");
            session.rowf(&[&num_tasks, &"shared_gp", &m, &dt]);
            rows.push(Fig4Row { num_tasks, method: "shared_gp".into(), mae: m, seconds: dt });
        }

        // 2. Standard MTGP (low-rank task kernel, trained dense).
        {
            let t = Timer::start();
            let mut mtgp = Mtgp::new(
                split.train.clone(),
                crate::kernels::Stationary1d::matern52(0.4),
                2,
                0.05,
                MtgpConfig { seed: cfg.seed, ..Default::default() },
            );
            mtgp.fit_dense(cfg.mtgp_steps, 0.1)?;
            let pred = mtgp.predict_mean(&qx, &qt);
            let m = mae(&pred, &qy);
            let dt = t.elapsed_s();
            println!("  mtgp          mae={m:.4}  ({dt:.1}s)");
            session.rowf(&[&num_tasks, &"mtgp", &m, &dt]);
            rows.push(Fig4Row { num_tasks, method: "mtgp".into(), mae: m, seconds: dt });
        }

        // 3. Cluster MTGP: Gibbs over assignments (SKIP-accelerated MLL),
        //    dense prediction under the sampled clustering.
        {
            let t = Timer::start();
            let mut cm = ClusterMtgp::new(
                split.train.clone(),
                ClusterMtgpConfig {
                    num_clusters: cfg.num_clusters,
                    use_skip: true,
                    seed: cfg.seed,
                    ..Default::default()
                },
            );
            cm.run_gibbs(cfg.gibbs_sweeps);
            let pred = cm.predict_mean(&qx, &qt)?;
            let m = mae(&pred, &qy);
            let dt = t.elapsed_s();
            println!("  cluster_mtgp  mae={m:.4}  ({dt:.1}s)");
            session.rowf(&[&num_tasks, &"cluster_mtgp", &m, &dt]);
            rows.push(Fig4Row {
                num_tasks,
                method: "cluster_mtgp".into(),
                mae: m,
                seconds: dt,
            });
        }
    }
    session.print_table();
    let path = session.finish()?;
    println!("wrote {}", path.display());
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multitask_models_beat_shared_gp() {
        let dir = std::env::temp_dir().join(format!("skipgp-f4-{}", std::process::id()));
        let cfg = Fig4Config {
            eval_children: 6,
            task_counts: vec![14],
            mtgp_steps: 8,
            gibbs_sweeps: 3,
            seed: 1,
            ..Default::default()
        };
        let rows = fig4(&cfg, &dir).unwrap();
        let get = |m: &str| rows.iter().find(|r| r.method == m).unwrap().mae;
        let shared = get("shared_gp");
        let mtgp = get("mtgp");
        let cluster = get("cluster_mtgp");
        // Clustered growth curves: any task-aware model must beat pooling.
        assert!(mtgp < shared, "mtgp {mtgp} vs shared {shared}");
        assert!(cluster < shared, "cluster {cluster} vs shared {shared}");
        std::fs::remove_dir_all(dir).ok();
    }
}
