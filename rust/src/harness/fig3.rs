//! Figure 3 harness: cluster posterior for a new task as its number of
//! observed measurements grows (3 → 5 → 9 in the paper).
//!
//! Protocol: fit cluster assignments on a training population by Gibbs
//! sampling, then introduce a held-out child with only its first k
//! measurements and report p(λ_new = c | y) for each cluster c. The
//! paper's qualitative claim: the posterior concentrates on the true
//! subpopulation as k grows.

use crate::coordinator::Session;
use crate::data::growth::{generate, split_child, without_child, GrowthConfig};
use crate::gp::mtgp::MtgpData;
use crate::gp::{ClusterMtgp, ClusterMtgpConfig};
use crate::Result;
use std::path::Path;

pub struct Fig3Config {
    pub num_children: usize,
    pub num_clusters: usize,
    /// Observed-measurement counts to sweep for the new task.
    pub keeps: Vec<usize>,
    pub gibbs_sweeps: usize,
    pub use_skip: bool,
    pub seed: u64,
}

impl Default for Fig3Config {
    fn default() -> Self {
        Fig3Config {
            num_children: 24,
            num_clusters: 3,
            keeps: vec![3, 5, 9],
            gibbs_sweeps: 6,
            use_skip: true,
            seed: 0,
        }
    }
}

/// Posterior rows: (keep, per-cluster probabilities, true cluster).
pub fn fig3(cfg: &Fig3Config, out_dir: &Path) -> Result<Vec<(usize, Vec<f64>, usize)>> {
    let mut session = Session::new("fig3", out_dir)?;
    session.header(&["observed", "p_cluster0", "p_cluster1", "p_cluster2", "true_cluster"]);
    let growth = generate(&GrowthConfig {
        num_children: cfg.num_children,
        num_clusters: cfg.num_clusters,
        min_obs: 8,
        max_obs: 16,
        seed: cfg.seed,
        ..Default::default()
    });
    // Hold out the last child as the "new task".
    let new_child = cfg.num_children - 1;
    let true_cluster = growth.true_cluster[new_child];
    let base = without_child(&growth.data, new_child);
    // Fit assignments on the training population.
    let mut model = ClusterMtgp::new(
        base.clone(),
        ClusterMtgpConfig {
            num_clusters: cfg.num_clusters,
            use_skip: cfg.use_skip,
            seed: cfg.seed,
            ..Default::default()
        },
    );
    println!(
        "Fig 3: Gibbs over {} training children ({} sweeps, {} path)…",
        cfg.num_children - 1,
        cfg.gibbs_sweeps,
        if cfg.use_skip { "SKIP" } else { "dense" }
    );
    model.run_gibbs(cfg.gibbs_sweeps);
    println!(
        "  training assignments: {:?}\n  truth:                {:?}",
        model.assignments,
        &growth.true_cluster[..cfg.num_children - 1]
    );
    // Gibbs labels are permutation-invariant: map each true cluster to the
    // model label that holds the majority of its training tasks, so the
    // reported posteriors are in *true-cluster* coordinates.
    let label_map: Vec<usize> = (0..cfg.num_clusters)
        .map(|true_c| {
            let mut votes = vec![0usize; cfg.num_clusters];
            for t in 0..cfg.num_children - 1 {
                if growth.true_cluster[t] == true_c {
                    votes[model.assignments[t]] += 1;
                }
            }
            votes
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(i, _)| i)
                .unwrap_or(true_c)
        })
        .collect();
    println!("  label map (true→model): {label_map:?}");

    let mut out = Vec::new();
    for &keep in &cfg.keeps {
        let (hx, hy, _, _) = split_child(&growth.data, new_child, keep);
        // Rebuild data with the truncated new task appended.
        let mut data = base.clone();
        for (x, y) in hx.iter().zip(&hy) {
            data.x.push(*x);
            data.y.push(*y);
            data.task_of.push(new_child);
        }
        let mut m2 = ClusterMtgp::new(
            MtgpData { num_tasks: cfg.num_children, ..data },
            ClusterMtgpConfig {
                num_clusters: cfg.num_clusters,
                use_skip: cfg.use_skip,
                seed: cfg.seed,
                ..Default::default()
            },
        );
        let mut assignments = model.assignments.clone();
        if assignments.len() < cfg.num_children {
            assignments.push(0); // placeholder for the new task
        }
        m2.assignments = assignments;
        // Copy trained kernels.
        m2.k_cluster = model.k_cluster;
        m2.k_indiv = model.k_indiv;
        m2.cluster_var = model.cluster_var;
        m2.indiv_var = model.indiv_var;
        m2.sn2 = model.sn2;
        let post_model = m2.cluster_posterior(new_child, cfg.seed ^ keep as u64);
        // Re-express in true-cluster coordinates via the label map.
        let post: Vec<f64> = (0..cfg.num_clusters)
            .map(|true_c| post_model[label_map[true_c]])
            .collect();
        println!(
            "  observed={keep:>2}  posterior(true coords)={:?}  (true cluster {true_cluster})",
            post.iter().map(|p| format!("{p:.2}")).collect::<Vec<_>>()
        );
        let mut cells = vec![keep.to_string()];
        for c in 0..3 {
            cells.push(format!("{:.4}", post.get(c).copied().unwrap_or(f64::NAN)));
        }
        cells.push(true_cluster.to_string());
        session.row(&cells);
        out.push((keep, post, true_cluster));
    }
    session.print_table();
    let path = session.finish()?;
    println!("wrote {}", path.display());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn posterior_concentrates_with_more_observations() {
        let dir = std::env::temp_dir().join(format!("skipgp-f3-{}", std::process::id()));
        let cfg = Fig3Config {
            num_children: 13,
            keeps: vec![2, 8],
            gibbs_sweeps: 4,
            use_skip: false, // dense path: deterministic small-n oracle
            seed: 3,
            ..Default::default()
        };
        let rows = fig3(&cfg, &dir).unwrap();
        let p_true_few = rows[0].1[rows[0].2];
        let p_true_many = rows[1].1[rows[1].2];
        // With more observations, the truth should not get *less* likely,
        // and should end up dominant.
        assert!(
            p_true_many >= p_true_few - 0.1,
            "few {p_true_few} many {p_true_many}"
        );
        assert!(p_true_many > 0.5, "final posterior {p_true_many}");
        std::fs::remove_dir_all(dir).ok();
    }
}
