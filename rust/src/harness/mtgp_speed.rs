//! §6 speedup claim: "For n = 4000, SKIP speeds up marginal likelihood
//! computations by a factor of 20" (vs the dense-covariance path).
//!
//! We time one MLL evaluation of the multi-task model through both paths
//! across an n sweep and report the speedup factor.

use crate::coordinator::Session;
use crate::data::growth::{generate, GrowthConfig};
use crate::gp::{Mtgp, MtgpConfig};
use crate::kernels::Stationary1d;
use crate::util::Timer;
use crate::Result;
use std::path::Path;

pub struct MtgpSpeedConfig {
    /// Observation counts to sweep.
    pub ns: Vec<usize>,
    pub seed: u64,
}

impl Default for MtgpSpeedConfig {
    fn default() -> Self {
        MtgpSpeedConfig { ns: vec![500, 1000, 2000, 4000], seed: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct SpeedRow {
    pub n: usize,
    pub dense_s: f64,
    pub skip_s: f64,
    pub speedup: f64,
}

/// Run the MLL timing sweep.
pub fn mtgp_speedup(cfg: &MtgpSpeedConfig, out_dir: &Path) -> Result<Vec<SpeedRow>> {
    let mut session = Session::new("mtgp_speedup", out_dir)?;
    session.header(&["n", "dense_mll_s", "skip_mll_s", "speedup"]);
    let mut rows = Vec::new();
    for &n in &cfg.ns {
        // ~12 observations per child → children count scales with n.
        let children = (n / 12).max(4);
        let growth = generate(&GrowthConfig {
            num_children: children,
            min_obs: 10,
            max_obs: 14,
            seed: cfg.seed,
            ..Default::default()
        });
        let data = growth.data;
        let actual_n = data.len();
        let mtgp = Mtgp::new(
            data,
            Stationary1d::matern52(0.4),
            2,
            0.05,
            MtgpConfig { seed: cfg.seed, ..Default::default() },
        );
        let t = Timer::start();
        let dense_mll = mtgp.mll_dense()?;
        let dense_s = t.elapsed_s();
        let t = Timer::start();
        let skip_mll = mtgp.mll_skip(cfg.seed);
        let skip_s = t.elapsed_s();
        let speedup = dense_s / skip_s;
        // Sanity: the two estimates agree to a few nats per 100 points.
        let gap = (dense_mll - skip_mll).abs() / actual_n as f64;
        println!(
            "  n={actual_n:>5}  dense={dense_s:.3}s  skip={skip_s:.3}s  speedup={speedup:.1}x  (mll gap {gap:.3} nats/pt)"
        );
        session.rowf(&[&actual_n, &dense_s, &skip_s, &speedup]);
        rows.push(SpeedRow { n: actual_n, dense_s, skip_s, speedup });
    }
    session.print_table();
    let path = session.finish()?;
    println!("wrote {}", path.display());
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_is_faster_at_moderate_n() {
        let dir = std::env::temp_dir().join(format!("skipgp-ms-{}", std::process::id()));
        let cfg = MtgpSpeedConfig { ns: vec![2000], seed: 0 };
        let rows = mtgp_speedup(&cfg, &dir).unwrap();
        assert!(
            rows[0].speedup > 1.5,
            "SKIP should beat dense at n≈2000: {:?}",
            rows[0]
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn speedup_grows_with_n() {
        let dir = std::env::temp_dir().join(format!("skipgp-ms2-{}", std::process::id()));
        let cfg = MtgpSpeedConfig { ns: vec![400, 1200], seed: 1 };
        let rows = mtgp_speedup(&cfg, &dir).unwrap();
        assert!(
            rows[1].speedup > rows[0].speedup,
            "speedup should grow: {rows:?}"
        );
        std::fs::remove_dir_all(dir).ok();
    }
}
