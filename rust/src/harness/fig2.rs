//! Figure 2 harnesses.
//!
//! **Left:** relative error of SKIP MVMs vs the exact product-kernel MVM
//! as a function of Lanczos rank r, for d ∈ {4, 8, 12} (paper §4: n = 2500
//! points from N(0, I), RBF ℓ = 1; "<1% error by r ≈ 30").
//!
//! **Right:** per-inference-step time vs inducing points *per dimension*
//! for SKIP, KISS-GP and SGPR on the d = 4 Power surrogate — the curse-of-
//! dimensionality picture (KISS-GP's grid is m⁴).

use crate::coordinator::Session;
use crate::data::{dataset_by_name, gaussian_cloud, generate};
use crate::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant, Sgpr};
use crate::grid::GridSpec;
use crate::kernels::ProductKernel;
use crate::operators::{LinearOp, SkiOp, SkipComponent, SkipOp};
use crate::util::{rel_err, Rng, Timer};
use crate::Result;
use std::path::Path;

/// Config for the Fig-2-left sweep.
pub struct Fig2LeftConfig {
    pub n: usize,
    pub dims: Vec<usize>,
    pub ranks: Vec<usize>,
    pub trials: usize,
    pub grid_m: usize,
    pub seed: u64,
}

impl Default for Fig2LeftConfig {
    fn default() -> Self {
        Fig2LeftConfig {
            n: 2500,
            dims: vec![4, 8, 12],
            ranks: vec![4, 8, 16, 24, 32, 40],
            trials: 5,
            grid_m: 256,
            seed: 0,
        }
    }
}

/// Run Fig 2 (left): mean relative MVM error per (d, r).
pub fn fig2_left(cfg: &Fig2LeftConfig, out_dir: &Path) -> Result<()> {
    let mut session = Session::new("fig2_left", out_dir)?;
    session.header(&["d", "rank", "mean_rel_err", "trials"]);
    println!(
        "Fig 2 (left): SKIP MVM relative error, n={}, dims {:?}",
        cfg.n, cfg.dims
    );
    for &d in &cfg.dims {
        let xs = gaussian_cloud(cfg.n, d, cfg.seed.wrapping_add(d as u64));
        // "Lengthscale 1" in the per-dimension-normalized convention
        // (ℓ = √d ⇒ k(x,x′) = exp(−‖x−x′‖²/2d)): with raw ℓ = 1 and
        // N(0, I) inputs the d ≥ 8 product Gram is numerically the
        // identity (E‖x−x′‖² = 2d), which *no* low-rank method can
        // approximate — and the paper's own <1 % @ r≈30 for d = 12 is
        // only attainable in the normalized regime.
        let kern = ProductKernel::rbf(d, (d as f64).sqrt(), 1.0);
        // Exact product-kernel Gram (oracle MVM).
        let exact = session.metrics.time("exact_gram", || kern.gram_sym(&xs));
        // Per-dimension SKI components: grid fine enough that
        // interpolation error sits below the Lanczos error floor.
        let skis = (0..d)
            .map(|k| SkiOp::new(&xs.col(k), &kern.factors[k], cfg.grid_m))
            .collect::<Result<Vec<SkiOp>>>()?;
        for &r in &cfg.ranks {
            let mut errs = Vec::with_capacity(cfg.trials);
            for trial in 0..cfg.trials {
                let mut rng =
                    Rng::new(cfg.seed ^ (trial as u64 * 7919 + r as u64 * 31 + d as u64));
                let comps: Vec<SkipComponent> = skis
                    .iter()
                    .map(|s| SkipComponent::Op(s as &dyn LinearOp))
                    .collect();
                let skip = session.metrics.time("skip_build", || {
                    SkipOp::build_native(comps, r, &mut rng)
                });
                let v = rng.normal_vec(cfg.n);
                let got = session.metrics.time("skip_mvm", || skip.matvec(&v));
                let want = exact.matvec(&v);
                errs.push(rel_err(&got, &want));
            }
            let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
            println!("  d={d:>2}  r={r:>3}  rel_err={mean_err:.3e}");
            session.rowf(&[&d, &r, &mean_err, &cfg.trials]);
        }
    }
    session.print_table();
    let path = session.finish()?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Config for the Fig-2-right sweep.
pub struct Fig2RightConfig {
    /// Training subset size from the Power surrogate.
    pub n: usize,
    /// Inducing points per dimension to sweep.
    pub m_per_dim: Vec<usize>,
    pub rank: usize,
    pub seed: u64,
    /// KISS grid cap: skip m where mᵈ exceeds this.
    pub kiss_grid_cap: usize,
}

impl Default for Fig2RightConfig {
    fn default() -> Self {
        Fig2RightConfig {
            n: 2500,
            m_per_dim: vec![10, 20, 40, 80, 160],
            rank: 30,
            seed: 0,
            kiss_grid_cap: 200_000,
        }
    }
}

/// Run Fig 2 (right): one-training-step wall time vs m per dimension.
pub fn fig2_right(cfg: &Fig2RightConfig, out_dir: &Path) -> Result<()> {
    let mut session = Session::new("fig2_right", out_dir)?;
    session.header(&["method", "m_per_dim", "total_grid", "step_time_s"]);
    let spec = dataset_by_name("power").expect("power dataset registered");
    let scale = (cfg.n as f64 / spec.n as f64).min(1.0);
    let data = generate(spec, scale);
    let d = data.d();
    println!(
        "Fig 2 (right): inference-step time vs m/dim on power surrogate (n={}, d={d})",
        data.n()
    );
    let h = GpHypers::init_for_dim(d);
    for &m in &cfg.m_per_dim {
        // SKIP: m inducing points per 1-D kernel.
        {
            let gp = MvmGp::new(
                data.xtrain.clone(),
                data.ytrain.clone(),
                h,
                MvmGpConfig {
                    variant: MvmVariant::Skip,
                    grid: GridSpec::uniform(m.max(6)),
                    rank: cfg.rank,
                    seed: cfg.seed,
                    ..Default::default()
                },
            );
            let t = Timer::start();
            let _ = gp.mll_grad(&h, cfg.seed)?;
            let dt = t.elapsed_s();
            println!("  skip     m={m:>4}  step={dt:.3}s");
            session.rowf(&[&"skip", &m, &(m * d), &dt]);
        }
        // KISS-GP: mᵈ grid — skip when infeasible (that is the point).
        let grid_total = (m.max(6) as f64).powi(d as i32);
        if grid_total <= cfg.kiss_grid_cap as f64 {
            let gp = MvmGp::new(
                data.xtrain.clone(),
                data.ytrain.clone(),
                h,
                MvmGpConfig {
                    variant: MvmVariant::Kiss,
                    grid: GridSpec::uniform(m.max(6)),
                    rank: cfg.rank,
                    seed: cfg.seed,
                    ..Default::default()
                },
            );
            let t = Timer::start();
            let _ = gp.mll_grad(&h, cfg.seed)?;
            let dt = t.elapsed_s();
            println!("  kiss-gp  m={m:>4}  step={dt:.3}s (grid {grid_total:.0})");
            session.rowf(&[&"kiss", &m, &(grid_total as usize), &dt]);
        } else {
            println!("  kiss-gp  m={m:>4}  SKIPPED (grid {grid_total:.2e} exceeds cap)");
            session.rowf(&[&"kiss", &m, &(grid_total as usize), &f64::NAN]);
        }
        // SGPR with m total inducing points.
        {
            let mut sgpr = Sgpr::new(
                data.xtrain.clone(),
                data.ytrain.clone(),
                h,
                m,
                cfg.seed,
            );
            let t = Timer::start();
            let _ = sgpr.fit(1, 0.1)?;
            let dt = t.elapsed_s();
            println!("  sgpr     m={m:>4}  step={dt:.3}s");
            session.rowf(&[&"sgpr", &m, &m, &dt]);
        }
    }
    session.print_table();
    let path = session.finish()?;
    println!("wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_left_tiny_runs_and_errors_decay() {
        let dir = std::env::temp_dir().join(format!("skipgp-f2l-{}", std::process::id()));
        let cfg = Fig2LeftConfig {
            n: 120,
            dims: vec![4],
            ranks: vec![4, 24],
            trials: 2,
            grid_m: 64,
            seed: 1,
        };
        fig2_left(&cfg, &dir).unwrap();
        let csv = std::fs::read_to_string(dir.join("fig2_left.csv")).unwrap();
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|c| c.parse().unwrap()).collect())
            .collect();
        assert_eq!(rows.len(), 2);
        // error at r=24 below error at r=4
        assert!(rows[1][2] < rows[0][2], "{rows:?}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fig2_right_tiny_runs() {
        let dir = std::env::temp_dir().join(format!("skipgp-f2r-{}", std::process::id()));
        let cfg = Fig2RightConfig {
            n: 150,
            m_per_dim: vec![8],
            rank: 10,
            seed: 2,
            kiss_grid_cap: 100_000,
        };
        fig2_right(&cfg, &dir).unwrap();
        let csv = std::fs::read_to_string(dir.join("fig2_right.csv")).unwrap();
        assert!(csv.lines().count() >= 4); // header + 3 methods
        std::fs::remove_dir_all(dir).ok();
    }
}
