//! Native stub for the PJRT executor (compiled when the `xla` feature is
//! off — the offline build environment has no XLA binding crate).
//!
//! The API mirrors [`executor`](super) exactly so every consumer (CLI,
//! benches, integration tests, examples) compiles unchanged:
//! [`Runtime::load`] / [`PjrtBackend::load`] report [`Error::Xla`], and a
//! `PjrtBackend` that somehow exists routes every contraction to the
//! native Lemma-3.1 implementation — including the fused multi-RHS block
//! path, so batched solves lose nothing when artifacts are absent.

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::operators::lowrank::{
    hadamard_pair_matmat_native, hadamard_pair_matvec_native, ContractionBackend,
    LanczosFactor,
};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The `xla` feature alone selects this stub with a diagnostic that
/// points at the missing binding; without it, at the missing feature.
#[cfg(feature = "xla")]
fn unavailable() -> Error {
    Error::Xla(
        "`xla` feature is on but no PJRT binding is vendored: vendor the \
         xla crate (see Cargo.toml) and rebuild with --features xla-bindings"
            .into(),
    )
}

#[cfg(not(feature = "xla"))]
fn unavailable() -> Error {
    Error::Xla(
        "built without the `xla` feature: PJRT artifacts cannot be executed \
         (vendor the xla binding crate and rebuild with --features xla)"
            .into(),
    )
}

/// Stub runtime: loading always fails with [`Error::Xla`].
pub struct Runtime {
    /// Executions served by PJRT (always 0 in the stub).
    pub pjrt_calls: AtomicUsize,
}

impl Runtime {
    /// Always fails: the `xla` feature is not compiled in.
    pub fn load(_dir: &Path) -> Result<Runtime> {
        Err(unavailable())
    }

    /// Number of compiled hadamard artifacts (always 0 in the stub).
    pub fn num_hadamard(&self) -> usize {
        0
    }

    /// No artifact ever fits: callers fall back to native.
    pub fn hadamard_pair_matvec(
        &self,
        _a: &LanczosFactor,
        _b: &LanczosFactor,
        _v: &[f64],
    ) -> Option<Result<Vec<f64>>> {
        None
    }

    /// No artifact ever fits: callers fall back to native.
    pub fn rbf_mean(
        &self,
        _xtest: &Matrix,
        _xtrain: &Matrix,
        _alpha: &[f64],
        _ell: f64,
        _sf2: f64,
    ) -> Option<Result<Vec<f64>>> {
        None
    }
}

/// Stub backend with the same surface as the real `PjrtBackend`.
pub struct PjrtBackend {
    /// Count of native-fallback calls (every call, in the stub).
    pub native_calls: AtomicUsize,
}

impl PjrtBackend {
    pub fn new(_runtime: Runtime) -> Self {
        PjrtBackend { native_calls: AtomicUsize::new(0) }
    }

    /// Always fails: the `xla` feature is not compiled in.
    pub fn load(_dir: &Path) -> Result<Self> {
        Err(unavailable())
    }

    /// (pjrt_calls, native_calls) so far — pjrt is always 0 in the stub.
    pub fn call_counts(&self) -> (usize, usize) {
        (0, self.native_calls.load(Ordering::Relaxed))
    }

    /// No artifacts in the stub: always `None` (caller uses native eval).
    pub fn rbf_mean(
        &self,
        _xtest: &Matrix,
        _xtrain: &Matrix,
        _alpha: &[f64],
        _ell: f64,
        _sf2: f64,
    ) -> Option<Result<Vec<f64>>> {
        None
    }
}

impl ContractionBackend for PjrtBackend {
    fn hadamard_pair_matvec(
        &self,
        a: &LanczosFactor,
        b: &LanczosFactor,
        v: &[f64],
    ) -> Vec<f64> {
        self.native_calls.fetch_add(1, Ordering::Relaxed);
        hadamard_pair_matvec_native(a, b, v)
    }

    fn hadamard_pair_matmat(
        &self,
        a: &LanczosFactor,
        b: &LanczosFactor,
        m: &Matrix,
    ) -> Matrix {
        self.native_calls.fetch_add(1, Ordering::Relaxed);
        hadamard_pair_matmat_native(a, b, m)
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rel_err, Rng};

    #[test]
    fn load_reports_missing_feature() {
        let err = PjrtBackend::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("xla"), "got: {err}");
    }

    /// The xla CI lane exercises this: with the feature on (but no
    /// binding vendored), the diagnostic points at `xla-bindings`.
    #[cfg(feature = "xla")]
    #[test]
    fn load_with_feature_points_at_missing_binding() {
        let err = PjrtBackend::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("xla-bindings"), "got: {err}");
    }

    #[test]
    fn stub_backend_contracts_natively() {
        let mut rng = Rng::new(1);
        let n = 40;
        let q = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let mut t = Matrix::from_fn(3, 3, |_, _| rng.normal());
        t.symmetrize();
        let f = LanczosFactor { q, t };
        let backend = PjrtBackend { native_calls: AtomicUsize::new(0) };
        let v = rng.normal_vec(n);
        let got = backend.hadamard_pair_matvec(&f, &f, &v);
        let want = hadamard_pair_matvec_native(&f, &f, &v);
        assert!(rel_err(&got, &want) < 1e-15);
        assert_eq!(backend.call_counts(), (0, 1));
    }
}
