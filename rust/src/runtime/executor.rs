//! PJRT execution of AOT artifacts.
//!
//! Loads each `artifacts/*.hlo.txt` module (HLO text → `HloModuleProto` →
//! `XlaComputation` → PJRT compile) once at startup; the compiled
//! executables then serve the Rust hot path with zero Python involvement.
//!
//! Shape policy: artifacts are compiled for fixed (n, r). A request with
//! n′ ≤ n and r′ ≤ r is served by **zero-padding** — padding rows of Q and
//! zero rows/columns of T contribute nothing to `S = Q₁ᵀD_vQ₂`,
//! `M = T₁ST₂ᵀ`, or the row-wise bilinear diagonal, so the result is
//! exact, not approximate.

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::operators::lowrank::{
    hadamard_pair_matvec_native, ContractionBackend, LanczosFactor,
};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::artifact::{load_manifest, ArtifactEntry};

fn xe(e: impl std::fmt::Display) -> Error {
    Error::Xla(e.to_string())
}

/// A compiled Hadamard-pair MVM artifact.
struct HadamardExe {
    n: usize,
    r: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// A compiled RBF predictive-mean artifact.
struct RbfMeanExe {
    n_test: usize,
    n_train: usize,
    d: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT runtime holding the client and all compiled executables.
pub struct Runtime {
    _client: xla::PjRtClient,
    hadamard: Vec<HadamardExe>,
    rbf_mean: Vec<RbfMeanExe>,
    /// Executions served by PJRT (for metrics).
    pub pjrt_calls: AtomicUsize,
}

// The xla crate's raw pointers are not Sync-annotated; executions are
// serialized through the Mutex in PjrtBackend below.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load every artifact in `dir` (must contain `manifest.json`).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let entries = load_manifest(dir)?;
        let client = xla::PjRtClient::cpu().map_err(xe)?;
        let mut hadamard = Vec::new();
        let mut rbf_mean = Vec::new();
        for e in &entries {
            match e.op.as_str() {
                "hadamard_mvm" => {
                    let exe = Self::compile(&client, e)?;
                    hadamard.push(HadamardExe {
                        n: e.dim("n").ok_or_else(|| miss(e, "n"))?,
                        r: e.dim("r").ok_or_else(|| miss(e, "r"))?,
                        exe,
                    });
                }
                "rbf_mean" => {
                    let exe = Self::compile(&client, e)?;
                    rbf_mean.push(RbfMeanExe {
                        n_test: e.dim("n_test").ok_or_else(|| miss(e, "n_test"))?,
                        n_train: e.dim("n_train").ok_or_else(|| miss(e, "n_train"))?,
                        d: e.dim("d").ok_or_else(|| miss(e, "d"))?,
                        exe,
                    });
                }
                // hadamard_chain is exercised by benches directly.
                _ => {}
            }
        }
        // Smallest-first so routing picks the cheapest compatible shape.
        hadamard.sort_by_key(|h| (h.n, h.r));
        rbf_mean.sort_by_key(|h| (h.n_train, h.n_test, h.d));
        Ok(Runtime { _client: client, hadamard, rbf_mean, pjrt_calls: AtomicUsize::new(0) })
    }

    fn compile(
        client: &xla::PjRtClient,
        e: &ArtifactEntry,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = e
            .path
            .to_str()
            .ok_or_else(|| Error::Artifact(format!("non-utf8 path for {}", e.name)))?;
        let proto = xla::HloModuleProto::from_text_file(path).map_err(xe)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(xe)
    }

    /// Number of compiled hadamard artifacts.
    pub fn num_hadamard(&self) -> usize {
        self.hadamard.len()
    }

    /// Lemma-3.1 contraction on the smallest compatible artifact, or None
    /// if no artifact fits (caller falls back to native).
    pub fn hadamard_pair_matvec(
        &self,
        a: &LanczosFactor,
        b: &LanczosFactor,
        v: &[f64],
    ) -> Option<Result<Vec<f64>>> {
        let n = a.dim();
        let r = a.rank().max(b.rank());
        let exe = self.hadamard.iter().find(|h| h.n >= n && h.r >= r)?;
        Some(self.run_hadamard(exe, a, b, v))
    }

    fn run_hadamard(
        &self,
        h: &HadamardExe,
        a: &LanczosFactor,
        b: &LanczosFactor,
        v: &[f64],
    ) -> Result<Vec<f64>> {
        let n = a.dim();
        let (np, rp) = (h.n, h.r);
        let pad_q = |m: &Matrix| -> Result<xla::Literal> {
            let mut buf = vec![0.0f64; np * rp];
            for i in 0..m.rows {
                buf[i * rp..i * rp + m.cols].copy_from_slice(m.row(i));
            }
            xla::Literal::vec1(&buf)
                .reshape(&[np as i64, rp as i64])
                .map_err(xe)
        };
        let pad_t = |m: &Matrix| -> Result<xla::Literal> {
            let mut buf = vec![0.0f64; rp * rp];
            for i in 0..m.rows {
                buf[i * rp..i * rp + m.cols].copy_from_slice(m.row(i));
            }
            xla::Literal::vec1(&buf)
                .reshape(&[rp as i64, rp as i64])
                .map_err(xe)
        };
        let mut vbuf = vec![0.0f64; np];
        vbuf[..n].copy_from_slice(v);
        let args = [
            pad_q(&a.q)?,
            pad_t(&a.t)?,
            pad_q(&b.q)?,
            pad_t(&b.t)?,
            xla::Literal::vec1(&vbuf),
        ];
        let result = self.exec_tuple1(&h.exe, &args)?;
        self.pjrt_calls.fetch_add(1, Ordering::Relaxed);
        Ok(result[..n].to_vec())
    }

    /// Predictive mean on the smallest compatible artifact (zero-padding
    /// test and train rows; padded α entries are zero so they add nothing).
    pub fn rbf_mean(
        &self,
        xtest: &Matrix,
        xtrain: &Matrix,
        alpha: &[f64],
        ell: f64,
        sf2: f64,
    ) -> Option<Result<Vec<f64>>> {
        let (nt, d) = (xtest.rows, xtest.cols);
        let ns = xtrain.rows;
        let exe = self
            .rbf_mean
            .iter()
            .find(|h| h.n_test >= nt && h.n_train >= ns && h.d >= d)?;
        Some(self.run_rbf_mean(exe, xtest, xtrain, alpha, ell, sf2))
    }

    fn run_rbf_mean(
        &self,
        h: &RbfMeanExe,
        xtest: &Matrix,
        xtrain: &Matrix,
        alpha: &[f64],
        ell: f64,
        sf2: f64,
    ) -> Result<Vec<f64>> {
        let nt = xtest.rows;
        // Pad coordinates with a far-away sentinel so padded *test* rows
        // don't matter (we slice them off) and padded *train* rows get
        // α = 0 anyway. Extra dims (d < artifact d) pad with equal zeros
        // on both sides → distance contribution 0 → exact.
        let pad_x = |m: &Matrix, rows: usize, cols: usize| -> Result<xla::Literal> {
            let mut buf = vec![0.0f64; rows * cols];
            for i in 0..m.rows {
                buf[i * cols..i * cols + m.cols].copy_from_slice(m.row(i));
            }
            xla::Literal::vec1(&buf)
                .reshape(&[rows as i64, cols as i64])
                .map_err(xe)
        };
        let mut abuf = vec![0.0f64; h.n_train];
        abuf[..alpha.len()].copy_from_slice(alpha);
        let args = [
            pad_x(xtest, h.n_test, h.d)?,
            pad_x(xtrain, h.n_train, h.d)?,
            xla::Literal::vec1(&abuf),
            xla::Literal::vec1(&[ell, sf2]),
        ];
        let result = self.exec_tuple1(&h.exe, &args)?;
        self.pjrt_calls.fetch_add(1, Ordering::Relaxed);
        Ok(result[..nt].to_vec())
    }

    fn exec_tuple1(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<f64>> {
        let out = exe.execute::<xla::Literal>(args).map_err(xe)?;
        let lit = out[0][0].to_literal_sync().map_err(xe)?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let inner = lit.to_tuple1().map_err(xe)?;
        inner.to_vec::<f64>().map_err(xe)
    }
}

fn miss(e: &ArtifactEntry, k: &str) -> Error {
    Error::Artifact(format!("artifact {} missing dim '{k}'", e.name))
}

/// [`ContractionBackend`] that routes to PJRT artifacts when a compatible
/// shape is registered and falls back to the native implementation
/// otherwise. Execution is serialized (PJRT CPU client is not Sync).
pub struct PjrtBackend {
    runtime: Mutex<Runtime>,
    /// Count of native-fallback calls (for metrics).
    pub native_calls: AtomicUsize,
}

impl PjrtBackend {
    pub fn new(runtime: Runtime) -> Self {
        PjrtBackend { runtime: Mutex::new(runtime), native_calls: AtomicUsize::new(0) }
    }

    /// Load artifacts from `dir` and wrap in a backend.
    pub fn load(dir: &Path) -> Result<Self> {
        Ok(Self::new(Runtime::load(dir)?))
    }

    /// (pjrt_calls, native_calls) so far.
    pub fn call_counts(&self) -> (usize, usize) {
        let rt = self.runtime.lock().unwrap();
        (
            rt.pjrt_calls.load(Ordering::Relaxed),
            self.native_calls.load(Ordering::Relaxed),
        )
    }

    /// Predictive mean through PJRT if a compatible artifact exists.
    pub fn rbf_mean(
        &self,
        xtest: &Matrix,
        xtrain: &Matrix,
        alpha: &[f64],
        ell: f64,
        sf2: f64,
    ) -> Option<Result<Vec<f64>>> {
        let rt = self.runtime.lock().unwrap();
        rt.rbf_mean(xtest, xtrain, alpha, ell, sf2)
    }
}

impl ContractionBackend for PjrtBackend {
    fn hadamard_pair_matvec(
        &self,
        a: &LanczosFactor,
        b: &LanczosFactor,
        v: &[f64],
    ) -> Vec<f64> {
        {
            let rt = self.runtime.lock().unwrap();
            if let Some(res) = rt.hadamard_pair_matvec(a, b, v) {
                match res {
                    Ok(out) => return out,
                    Err(e) => {
                        // Artifact execution failed — fall back but surface it.
                        eprintln!("pjrt backend error ({e}); falling back to native");
                    }
                }
            }
        }
        self.native_calls.fetch_add(1, Ordering::Relaxed);
        hadamard_pair_matvec_native(a, b, v)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rel_err, Rng};
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    fn random_factor(n: usize, r: usize, seed: u64) -> LanczosFactor {
        let mut rng = Rng::new(seed);
        let q = Matrix::from_fn(n, r, |_, _| rng.normal());
        let mut t = Matrix::from_fn(r, r, |_, _| rng.normal());
        t.symmetrize();
        LanczosFactor { q, t }
    }

    #[test]
    fn pjrt_matches_native_exact_shape() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let backend = PjrtBackend::load(&artifacts_dir()).unwrap();
        let (n, r) = (1024, 16);
        let a = random_factor(n, r, 1);
        let b = random_factor(n, r, 2);
        let mut rng = Rng::new(3);
        let v = rng.normal_vec(n);
        let got = backend.hadamard_pair_matvec(&a, &b, &v);
        let want = hadamard_pair_matvec_native(&a, &b, &v);
        assert!(rel_err(&got, &want) < 1e-10, "err {}", rel_err(&got, &want));
        let (pjrt, native) = backend.call_counts();
        assert_eq!(pjrt, 1);
        assert_eq!(native, 0);
    }

    #[test]
    fn pjrt_zero_padding_is_exact() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let backend = PjrtBackend::load(&artifacts_dir()).unwrap();
        // Odd shape well below the smallest artifact (1024, 16).
        let (n, r1, r2) = (700, 9, 13);
        let a = random_factor(n, r1, 4);
        let b = random_factor(n, r2, 5);
        let mut rng = Rng::new(6);
        let v = rng.normal_vec(n);
        let got = backend.hadamard_pair_matvec(&a, &b, &v);
        let want = hadamard_pair_matvec_native(&a, &b, &v);
        assert!(rel_err(&got, &want) < 1e-10, "err {}", rel_err(&got, &want));
    }

    #[test]
    fn oversize_falls_back_to_native() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let backend = PjrtBackend::load(&artifacts_dir()).unwrap();
        let (n, r) = (5000, 8); // n exceeds every artifact
        let a = random_factor(n, r, 7);
        let b = random_factor(n, r, 8);
        let mut rng = Rng::new(9);
        let v = rng.normal_vec(n);
        let got = backend.hadamard_pair_matvec(&a, &b, &v);
        let want = hadamard_pair_matvec_native(&a, &b, &v);
        assert!(rel_err(&got, &want) < 1e-12);
        let (pjrt, native) = backend.call_counts();
        assert_eq!(pjrt, 0);
        assert_eq!(native, 1);
    }

    #[test]
    fn rbf_mean_matches_native_eval() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        use crate::kernels::ProductKernel;
        let backend = PjrtBackend::load(&artifacts_dir()).unwrap();
        let mut rng = Rng::new(10);
        let (nt, ns, d) = (100, 500, 3);
        let xt = Matrix::from_fn(nt, d, |_, _| rng.normal());
        let xs = Matrix::from_fn(ns, d, |_, _| rng.normal());
        let alpha = rng.normal_vec(ns);
        let (ell, sf2) = (0.9, 1.3);
        let got = backend.rbf_mean(&xt, &xs, &alpha, ell, sf2).unwrap().unwrap();
        let kern = ProductKernel::rbf(d, ell, sf2);
        let want = kern.gram(&xt, &xs).matvec(&alpha);
        assert!(rel_err(&got, &want) < 1e-10, "err {}", rel_err(&got, &want));
    }
}
