//! PJRT runtime: load and execute AOT artifacts from the Rust hot path.
//!
//! `make artifacts` (python, build-time only) lowers the Layer-2 JAX
//! graphs to HLO text; this module compiles them on the PJRT CPU client
//! and serves them behind the [`crate::operators::ContractionBackend`]
//! abstraction. Python never runs at request time.

pub mod artifact;

/// Real PJRT executor — needs `--features xla-bindings` *and* the
/// vendored `xla` binding crate (see Cargo.toml).
#[cfg(feature = "xla-bindings")]
pub mod executor;

/// Native stub with the same API — the offline default, and what the
/// `xla` feature alone compiles (with feature-aware diagnostics; CI's
/// xla lane builds and tests this configuration so the feature wiring
/// cannot rot unbuilt).
#[cfg(not(feature = "xla-bindings"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use artifact::{load_manifest, ArtifactEntry};
pub use executor::{PjrtBackend, Runtime};
