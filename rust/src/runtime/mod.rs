//! PJRT runtime: load and execute AOT artifacts from the Rust hot path.
//!
//! `make artifacts` (python, build-time only) lowers the Layer-2 JAX
//! graphs to HLO text; this module compiles them on the PJRT CPU client
//! and serves them behind the [`crate::operators::ContractionBackend`]
//! abstraction. Python never runs at request time.

pub mod artifact;

/// Real PJRT executor — needs the vendored `xla` binding crate.
#[cfg(feature = "xla")]
pub mod executor;

/// Native stub with the same API (the offline default; see Cargo.toml).
#[cfg(not(feature = "xla"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use artifact::{load_manifest, ArtifactEntry};
pub use executor::{PjrtBackend, Runtime};
