//! PJRT runtime: load and execute AOT artifacts from the Rust hot path.
//!
//! `make artifacts` (python, build-time only) lowers the Layer-2 JAX
//! graphs to HLO text; this module compiles them on the PJRT CPU client
//! and serves them behind the [`crate::operators::ContractionBackend`]
//! abstraction. Python never runs at request time.

pub mod artifact;
pub mod executor;

pub use artifact::{load_manifest, ArtifactEntry};
pub use executor::{PjrtBackend, Runtime};
