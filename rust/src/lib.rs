//! # skip-gp
//!
//! A production-oriented reproduction of **“Product Kernel Interpolation
//! for Scalable Gaussian Processes”** (Gardner, Pleiss, Wu, Weinberger,
//! Wilson — AISTATS 2018), built as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! - **Layer 3 (this crate)** — the full GP inference library: kernels,
//!   structured linear operators (SKI, SKIP, Kronecker), iterative solvers
//!   (CG, Lanczos, stochastic Lanczos quadrature), GP models (exact, SGPR,
//!   KISS-GP, SKIP-GP, multi-task, cluster multi-task), dataset substrate,
//!   and the benchmark harness that regenerates every table and figure in
//!   the paper.
//! - **Layer 2 (`python/compile/model.py`)** — JAX compute graphs for the
//!   SKIP hot path, AOT-lowered to HLO text at build time.
//! - **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   Lemma-3.1 contraction and RBF kernel tiles, checked against pure-jnp
//!   oracles.
//!
//! Python never runs on the request path: `rust/src/runtime` loads the AOT
//! artifacts through PJRT and `rust/src/coordinator` orchestrates
//! experiments over native + PJRT execution.
//!
//! Every solve routes through the **batched multi-RHS MVM engine**: all
//! structured operators implement a [`operators::LinearOp::matmat`] fast
//! path that carries an n×t block through the structure in one pass, and
//! [`solvers::block_cg_solve`] / [`solvers::lanczos_batch`] fuse the
//! per-iteration MVMs of simultaneous right-hand sides / probes into
//! single block traversals. How many iterations those solves need is
//! governed by the **preconditioned solver subsystem**
//! ([`solvers::precond`]): partial pivoted-Cholesky / Jacobi
//! preconditioners built from cheap operator column/diagonal accessors
//! ([`operators::LinearOp::col_at`] / [`operators::LinearOp::diag`]),
//! plus warm-started CG for optimizer loops and cache refreshes — see
//! `docs/SOLVERS.md` for the tuning guide.
//!
//! Trained models deploy through the **serving subsystem** ([`serve`]):
//! versioned model snapshots freeze the predictive caches onto the
//! inducing grid, after which each query costs one sparse
//! interpolation-stencil dot (mean) plus a rank-r gemv (variance), and a
//! request batcher + TCP front-end (`skip-gp serve`) coalesce concurrent
//! traffic into blocks for the batched engine. Served models stay
//! **live** through the streaming subsystem ([`stream`]): new
//! observations extend the interpolation matrix by one sparse stencil
//! row and re-solve `K̂α = y` with warm-started PCG (reusing the cached
//! preconditioner), patching the predictive caches in place instead of
//! refitting — `skip-gp serve --live` / `skip-gp observe` end to end.
//!
//! Inducing grids are a first-class subsystem ([`grid`]): every grid
//! consumer — SKI operators, KISS-GP, the serving caches, snapshots —
//! goes through the [`grid::InducingGrid`] trait, with two
//! implementations: [`grid::RectilinearGrid`] (per-dimension sizes and
//! bounds) and [`grid::SparseGrid`] (the combination technique of Yadav,
//! Sheldon & Musco 2023), whose near-linear-in-d point count removes the
//! dense Kronecker path's mᵈ barrier and opens d ≈ 8–10 regression to
//! grid-based inference.
//!
//! See `ARCHITECTURE.md` at the repository root for the three-layer
//! design, a paper-equation → module map, and the batched-MVM data flow;
//! `README.md` covers how to build, test, and run the harness.

// Index-heavy numeric kernels: explicit `for i in 0..n` loops mirror the
// math and keep scatter/gather symmetric; the iterator forms clippy
// prefers obscure the stencil/fiber indexing. Builder-style numeric
// routines legitimately take many scalar knobs.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod coordinator;
pub mod data;
pub mod error;
pub mod gp;
pub mod grid;
pub mod harness;
pub mod kernels;
pub mod linalg;
pub mod operators;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod stream;
pub mod util;

pub use error::{Error, Result};
