//! Rectilinear (full tensor-product) inducing grids.
//!
//! The classic KISS-GP grid: one margin-fitted cubic axis per input
//! dimension, with **per-dimension sizes and bounds** (generalizing the
//! historical uniform-m `Grid1d` bundle). The grid is a single
//! [`GridTerm`] with coefficient 1, so every consumer of the
//! [`InducingGrid`] trait treats it as the one-term special case of the
//! combination-technique sum.

use super::axis::Grid1d;
use super::{column_bounds, GridSpec, GridTerm, InducingGrid};
use crate::linalg::Matrix;
use crate::Result;

/// A full tensor-product grid of per-dimension [`Grid1d`] axes.
#[derive(Clone, Debug)]
pub struct RectilinearGrid {
    spec: GridSpec,
    /// Exactly one term, coefficient 1.
    terms: Vec<GridTerm>,
}

impl RectilinearGrid {
    /// Fit one margin-covered axis per column of `xs` with per-dimension
    /// sizes `sizes` (`sizes.len()` must equal `xs.cols`).
    pub fn fit(xs: &Matrix, sizes: &[usize]) -> Result<Self> {
        assert_eq!(
            sizes.len(),
            xs.cols,
            "one grid size per input dimension"
        );
        let bounds = column_bounds(xs);
        let axes = sizes
            .iter()
            .zip(&bounds)
            .map(|(&m, &(lo, hi))| Grid1d::fit(lo, hi, m))
            .collect::<Result<Vec<_>>>()?;
        Ok(RectilinearGrid {
            spec: GridSpec::Rectilinear(sizes.to_vec()),
            terms: vec![GridTerm::new(1.0, axes)],
        })
    }

    /// Fit with the same size `m` on every dimension (the historical
    /// `grid_m` configuration; the spec round-trips as
    /// [`GridSpec::Uniform`]).
    pub fn fit_uniform(xs: &Matrix, m: usize) -> Result<Self> {
        let mut grid = Self::fit(xs, &vec![m; xs.cols])?;
        grid.spec = GridSpec::Uniform(m);
        Ok(grid)
    }

    /// Wrap explicit per-dimension axes (tests place training data exactly
    /// on grid nodes this way; the snapshot loader rebuilds caches from
    /// persisted axes through here).
    pub fn from_axes(axes: Vec<Grid1d>) -> Self {
        assert!(!axes.is_empty(), "rectilinear grid needs at least one axis");
        RectilinearGrid {
            spec: GridSpec::Rectilinear(axes.iter().map(|g| g.m).collect()),
            terms: vec![GridTerm::new(1.0, axes)],
        }
    }

    /// The per-dimension axes.
    pub fn axes(&self) -> &[Grid1d] {
        &self.terms[0].axes
    }
}

impl InducingGrid for RectilinearGrid {
    fn dim(&self) -> usize {
        self.terms[0].axes.len()
    }

    fn spec(&self) -> GridSpec {
        self.spec.clone()
    }

    fn terms(&self) -> &[GridTerm] {
        &self.terms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn per_dimension_sizes_and_bounds() {
        let mut rng = Rng::new(1);
        let xs = Matrix::from_fn(50, 2, |_, j| {
            if j == 0 {
                rng.uniform_in(-1.0, 1.0)
            } else {
                rng.uniform_in(5.0, 9.0)
            }
        });
        let g = RectilinearGrid::fit(&xs, &[16, 8]).unwrap();
        assert_eq!(g.dim(), 2);
        assert_eq!(g.terms().len(), 1);
        assert_eq!(g.total_points(), 16 * 8);
        assert_eq!(g.spec(), GridSpec::Rectilinear(vec![16, 8]));
        // Axis 1 covers the shifted column, with margin.
        let a1 = &g.axes()[1];
        assert!(a1.point(0) < 5.0 && a1.max() > 9.0);
    }

    #[test]
    fn uniform_spec_roundtrips() {
        let mut rng = Rng::new(2);
        let xs = Matrix::from_fn(30, 3, |_, _| rng.uniform_in(0.0, 1.0));
        let g = RectilinearGrid::fit_uniform(&xs, 12).unwrap();
        assert_eq!(g.spec(), GridSpec::Uniform(12));
        assert_eq!(g.total_points(), 12 * 12 * 12);
    }

    #[test]
    fn degenerate_column_is_a_typed_error() {
        let mut rng = Rng::new(3);
        // Column 1 is constant.
        let xs = Matrix::from_fn(20, 2, |_, j| {
            if j == 0 {
                rng.uniform_in(0.0, 1.0)
            } else {
                0.25
            }
        });
        let err = RectilinearGrid::fit(&xs, &[16, 16]).unwrap_err();
        assert!(err.to_string().contains("constant"), "{err}");
    }
}
