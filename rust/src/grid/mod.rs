//! First-class inducing-grid subsystem.
//!
//! SKI-family methods (paper §2.3) place inducing points on structured
//! grids so that the grid kernel is Kronecker–Toeplitz and interpolation
//! stencils are local. Historically every consumer of a grid — the SKI
//! operators, the KISS-GP model, the serving caches — carried its own
//! copy of the fitting/stencil/budget logic, all hard-wired to one
//! uniform mᵈ tensor grid. This module owns all of it behind one trait:
//!
//! - [`Grid1d`] (in [`axis`]) — a validated 1-D axis with margin or
//!   exact-cover fitting and cubic/linear/constant stencils;
//! - [`GridTerm`] — a rectilinear tensor product of axes with a signed
//!   coefficient: the unit every grid decomposes into;
//! - [`InducingGrid`] — the trait: a grid is a list of terms plus a
//!   serializable [`GridSpec`];
//! - [`RectilinearGrid`] — one term, coefficient 1: the classic KISS-GP
//!   grid, now with per-dimension sizes and bounds;
//! - [`SparseGrid`] — the combination technique (Yadav, Sheldon & Musco,
//!   2023): a signed sum of anisotropic terms whose point count grows
//!   near-linearly in d, breaking the mᵈ barrier that capped the
//!   Kronecker path at d ≲ 5.
//!
//! [`grid_ski_operator`] turns any grid into the SKI approximation of a
//! product kernel on the data — a [`KroneckerSkiOp`] per term, summed
//! with the term coefficients — and the serving layer's
//! `crate::serve::cache::PredictCache` builds its grid-side predictive
//! caches per term through the same trait, so dense and sparse grids
//! snapshot, reload, and serve identically.

pub mod axis;
pub mod rectilinear;
pub mod sparse;

pub use axis::{
    axis_stencil, axis_stencil_deriv, axis_width, cubic_stencil, cubic_stencil_deriv,
    tensor_stencil, tensor_stencil_grad, tensor_stencil_size, tensor_strides, Grid1d,
    MAX_TENSOR_DIM, MIN_FIT_POINTS, STENCIL,
};
pub use rectilinear::RectilinearGrid;
pub use sparse::{combination_terms, sparse_axis_points, MAX_SPARSE_TERMS, SparseGrid};

use crate::kernels::ProductKernel;
use crate::linalg::{Matrix, SymToeplitz};
use crate::operators::{AffineOp, KroneckerSkiOp, LinearOp, SumOp};
use crate::util::parallel::par_map;
use crate::{Error, Result};

/// Serializable description of an inducing grid — what a model config
/// carries and what a snapshot persists (the fitted axes are data-derived
/// and stored separately).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GridSpec {
    /// m points on every dimension (the historical `grid_m`).
    Uniform(usize),
    /// Explicit per-dimension sizes.
    Rectilinear(Vec<usize>),
    /// Combination-technique sparse grid at the given level (see
    /// [`sparse`] for the growth rule and cost model).
    Sparse { level: usize },
}

impl GridSpec {
    /// Uniform m-per-dimension spec (convenience constructor).
    pub fn uniform(m: usize) -> Self {
        GridSpec::Uniform(m)
    }

    /// Sparse combination-technique spec at `level`.
    pub fn sparse(level: usize) -> Self {
        GridSpec::Sparse { level }
    }

    /// 1-D grid size for dimension `k` — what the SKIP path's d
    /// independent SKI grids use (a sparse spec maps to its finest axis).
    /// Callers validate the spec against the data dimensionality first
    /// ([`GridSpec::validate_for_dim`]).
    pub fn size_for_dim(&self, k: usize) -> usize {
        match self {
            GridSpec::Uniform(m) => *m,
            GridSpec::Rectilinear(sizes) => sizes[k],
            GridSpec::Sparse { level } => sparse_axis_points(*level),
        }
    }

    /// Check this spec against input dimensionality `d`: a rectilinear
    /// spec must name exactly d sizes. Typed [`Error::Grid`] instead of
    /// the index panic a mismatched spec would otherwise hit.
    pub fn validate_for_dim(&self, d: usize) -> Result<()> {
        if let GridSpec::Rectilinear(sizes) = self {
            if sizes.len() != d {
                return Err(Error::Grid(format!(
                    "rectilinear spec names {} dimensions but the data has {d}",
                    sizes.len()
                )));
            }
        }
        Ok(())
    }

    /// Total stored grid points for input dimensionality `d`, or `None`
    /// on overflow (the mᵈ blow-up this subsystem exists to avoid).
    pub fn total_points(&self, d: usize) -> Option<usize> {
        match self {
            GridSpec::Uniform(m) => {
                let mut cells = 1usize;
                for _ in 0..d {
                    cells = cells.checked_mul(*m)?;
                }
                Some(cells)
            }
            GridSpec::Rectilinear(sizes) => {
                debug_assert_eq!(sizes.len(), d);
                let mut cells = 1usize;
                for &m in sizes {
                    cells = cells.checked_mul(m)?;
                }
                Some(cells)
            }
            GridSpec::Sparse { level } => {
                let terms = combination_terms(d, *level).ok()?;
                let mut total = 0usize;
                for (_, levels) in &terms {
                    let mut cells = 1usize;
                    for &l in levels {
                        cells = cells.checked_mul(sparse_axis_points(l))?;
                    }
                    total = total.checked_add(cells)?;
                }
                Some(total)
            }
        }
    }

    /// A strictly coarser spec, or `None` when already at the floor —
    /// the serving layer's budget loop shrinks a too-large grid through
    /// here (a coarser serving grid only costs interpolation accuracy).
    pub fn shrink(&self) -> Option<GridSpec> {
        match self {
            GridSpec::Uniform(m) => {
                if *m <= MIN_FIT_POINTS {
                    return None;
                }
                Some(GridSpec::Uniform((m * 3 / 4).max(MIN_FIT_POINTS)))
            }
            GridSpec::Rectilinear(sizes) => {
                if sizes.iter().all(|&m| m <= MIN_FIT_POINTS) {
                    return None;
                }
                Some(GridSpec::Rectilinear(
                    sizes.iter().map(|&m| (m * 3 / 4).max(MIN_FIT_POINTS)).collect(),
                ))
            }
            GridSpec::Sparse { level } => {
                if *level <= 1 {
                    return None;
                }
                Some(GridSpec::Sparse { level: level - 1 })
            }
        }
    }

    /// Short human-readable form (`"m=64/dim"`, `"sparse(level=3)"`, …).
    pub fn describe(&self) -> String {
        match self {
            GridSpec::Uniform(m) => format!("m={m}/dim"),
            GridSpec::Rectilinear(sizes) => {
                let s: Vec<String> = sizes.iter().map(|m| m.to_string()).collect();
                format!("m=[{}]", s.join("x"))
            }
            GridSpec::Sparse { level } => format!("sparse(level={level})"),
        }
    }
}

/// One rectilinear tensor-product term of an inducing grid: per-dimension
/// axes plus the signed combination coefficient (1 for a dense grid).
#[derive(Clone, Debug)]
pub struct GridTerm {
    /// Signed combination coefficient c_t.
    pub coeff: f64,
    /// Per-dimension axes (dimension 0 slowest in the flat layout).
    pub axes: Vec<Grid1d>,
}

impl GridTerm {
    pub fn new(coeff: f64, axes: Vec<Grid1d>) -> Self {
        GridTerm { coeff, axes }
    }

    /// Per-dimension sizes.
    pub fn dims(&self) -> Vec<usize> {
        self.axes.iter().map(|g| g.m).collect()
    }

    /// Row-major strides of the term's flat layout.
    pub fn strides(&self) -> Vec<usize> {
        tensor_strides(&self.dims())
    }

    /// Total grid points Π m_k of this term.
    pub fn total(&self) -> usize {
        self.axes.iter().map(|g| g.m).product()
    }

    /// `(flat index, weight)` pairs emitted per point.
    pub fn stencil_size(&self) -> usize {
        tensor_stencil_size(&self.axes)
    }

    /// Toeplitz grid-kernel factor per axis for the 1-D kernels `factors`
    /// (one per dimension, e.g. `ProductKernel::factors`).
    pub fn toeplitz_factors(
        &self,
        factors: &[crate::kernels::Stationary1d],
    ) -> Vec<SymToeplitz> {
        debug_assert_eq!(factors.len(), self.axes.len());
        self.axes
            .iter()
            .zip(factors)
            .map(|(g, k)| SymToeplitz::new(k.toeplitz_column(g.m, g.h)))
            .collect()
    }
}

/// An inducing grid: a signed sum of rectilinear terms with a
/// serializable spec. Implementations: [`RectilinearGrid`] (one term,
/// coefficient 1) and [`SparseGrid`] (combination technique).
pub trait InducingGrid: Send + Sync {
    /// Input dimensionality d.
    fn dim(&self) -> usize;

    /// The serializable spec this grid was built from.
    fn spec(&self) -> GridSpec;

    /// The rectilinear terms (never empty).
    fn terms(&self) -> &[GridTerm];

    /// Total stored grid points across terms.
    fn total_points(&self) -> usize {
        self.terms().iter().map(|t| t.total()).sum()
    }
}

/// Per-dimension `(lo, hi)` data bounds of the columns of `xs`.
/// Degenerate columns surface as errors downstream in `Grid1d::fit`.
pub(crate) fn column_bounds(xs: &Matrix) -> Vec<(f64, f64)> {
    (0..xs.cols)
        .map(|k| {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for i in 0..xs.rows {
                let v = xs.get(i, k);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            (lo, hi)
        })
        .collect()
}

/// Build the grid named by `spec`, fitted to the columns of `xs`.
///
/// Tensor grids of any kind are bounded by the stencil machinery's
/// [`MAX_TENSOR_DIM`]; beyond it the build refuses with a typed error
/// (the SKIP variant, which never forms tensor stencils, has no such
/// bound).
pub fn build_grid(xs: &Matrix, spec: &GridSpec) -> Result<Box<dyn InducingGrid>> {
    if xs.cols == 0 {
        return Err(Error::Grid("cannot fit a grid to 0-dimensional data".into()));
    }
    if xs.cols > MAX_TENSOR_DIM {
        return Err(Error::Grid(format!(
            "tensor grids support at most d = {MAX_TENSOR_DIM} dimensions \
             (data has {}); use the SKIP variant for higher d",
            xs.cols
        )));
    }
    spec.validate_for_dim(xs.cols)?;
    match spec {
        GridSpec::Uniform(m) => Ok(Box::new(RectilinearGrid::fit_uniform(xs, *m)?)),
        GridSpec::Rectilinear(sizes) => Ok(Box::new(RectilinearGrid::fit(xs, sizes)?)),
        GridSpec::Sparse { level } => Ok(Box::new(SparseGrid::fit(xs, *level)?)),
    }
}

/// The term decomposition behind [`grid_ski_operator`]: one
/// `(coefficient, KroneckerSkiOp)` per grid term, in term order. Exposed
/// separately so callers that need the *same* concrete operators in two
/// compositions — the KISS model's data-space covariance view and its
/// grid-space normal-equations system (`crate::solvers::gridspace`) —
/// can `Arc`-share them instead of building the stencils twice.
pub fn grid_ski_parts(
    xs: &Matrix,
    kern: &ProductKernel,
    grid: &dyn InducingGrid,
) -> Vec<(f64, KroneckerSkiOp)> {
    let terms = grid.terms();
    assert!(!terms.is_empty(), "inducing grid has no terms");
    if terms.len() == 1 {
        // Single term: build directly (no parallel dispatch), preserving
        // the historical dense-grid construction path bit-for-bit.
        return vec![(
            terms[0].coeff,
            KroneckerSkiOp::with_grids(xs, kern, terms[0].axes.clone()),
        )];
    }
    // Term construction is embarrassingly parallel (each decodes its own
    // stencils over the data once).
    par_map(terms, 4, |t| {
        (t.coeff, KroneckerSkiOp::with_grids(xs, kern, t.axes.clone()))
    })
}

/// SKI approximation of `kern` on the data `xs` over `grid`:
/// `K ≈ Σ_t c_t · W_t (⊗_k K_t,k) W_tᵀ`, one [`KroneckerSkiOp`] per term.
/// A single-term grid returns the operator directly (bit-identical to the
/// historical dense-Kronecker path); multi-term grids return a
/// [`SumOp`] of coefficient-scaled terms, so `matvec`/`matmat` ride the
/// existing block-MVM engine unchanged.
pub fn grid_ski_operator(
    xs: &Matrix,
    kern: &ProductKernel,
    grid: &dyn InducingGrid,
) -> Box<dyn LinearOp> {
    let parts = grid_ski_parts(xs, kern, grid);
    if parts.len() == 1 && parts[0].0 == 1.0 {
        let (_, op) = parts.into_iter().next().expect("one part");
        return Box::new(op);
    }
    let terms: Vec<Box<dyn LinearOp>> = parts
        .into_iter()
        .map(|(coeff, op)| {
            Box::new(AffineOp { inner: Box::new(op), scale: coeff, shift: 0.0 })
                as Box<dyn LinearOp>
        })
        .collect();
    Box::new(SumOp { terms })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rel_err, Rng};

    fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0))
    }

    #[test]
    fn spec_total_points() {
        assert_eq!(GridSpec::uniform(32).total_points(3), Some(32_768));
        assert_eq!(GridSpec::uniform(100).total_points(32), None); // overflow
        assert_eq!(
            GridSpec::Rectilinear(vec![4, 8, 2]).total_points(3),
            Some(64)
        );
        // d=2, level 2: layers |l|∈{2,1}: (1,9)+(5,5)+(9,1)+(1,5)+(5,1)=49−(10)=…
        // just check it matches the term enumeration.
        let spec = GridSpec::sparse(2);
        let want: usize = combination_terms(2, 2)
            .unwrap()
            .iter()
            .map(|(_, ls)| ls.iter().map(|&l| sparse_axis_points(l)).product::<usize>())
            .sum();
        assert_eq!(spec.total_points(2), Some(want));
    }

    #[test]
    fn spec_shrink_reaches_a_floor() {
        let mut spec = GridSpec::uniform(100);
        let mut steps = 0;
        while let Some(s) = spec.shrink() {
            spec = s;
            steps += 1;
            assert!(steps < 64, "shrink does not terminate");
        }
        assert_eq!(spec, GridSpec::uniform(MIN_FIT_POINTS));
        assert_eq!(GridSpec::sparse(3).shrink(), Some(GridSpec::sparse(2)));
        assert_eq!(GridSpec::sparse(1).shrink(), None);
    }

    #[test]
    fn sparse_operator_approximates_kernel_2d() {
        let xs = random_points(60, 2, 40);
        let kern = ProductKernel::rbf(2, 0.8, 1.0);
        let grid = SparseGrid::fit(&xs, 5).unwrap();
        let op = grid_ski_operator(&xs, &kern, &grid);
        let exact = kern.gram_sym(&xs);
        let mut rng = Rng::new(41);
        let v = rng.normal_vec(60);
        let err = rel_err(&op.matvec(&v), &exact.matvec(&v));
        assert!(err < 2e-2, "sparse SKI rel err {err}");
    }

    #[test]
    fn sparse_operator_error_decreases_with_level() {
        let xs = random_points(50, 2, 42);
        let kern = ProductKernel::rbf(2, 0.9, 1.0);
        let exact = kern.gram_sym(&xs);
        let mut rng = Rng::new(43);
        let v = rng.normal_vec(50);
        let want = exact.matvec(&v);
        let mut last = f64::INFINITY;
        for level in [2usize, 4, 6] {
            let grid = SparseGrid::fit(&xs, level).unwrap();
            let op = grid_ski_operator(&xs, &kern, &grid);
            let err = rel_err(&op.matvec(&v), &want);
            assert!(err < last, "level {level}: {err} !< {last}");
            last = err;
        }
        assert!(last < 5e-3, "finest level err {last}");
    }

    #[test]
    fn single_term_grid_returns_plain_kronecker_op() {
        let xs = random_points(40, 2, 44);
        let kern = ProductKernel::rbf(2, 0.7, 1.3);
        let grid = RectilinearGrid::fit_uniform(&xs, 16).unwrap();
        let via_trait = grid_ski_operator(&xs, &kern, &grid);
        let direct = KroneckerSkiOp::new(&xs, &kern, 16).unwrap();
        let mut rng = Rng::new(45);
        let v = rng.normal_vec(40);
        // Bit-identical: the trait path must not change the dense-grid math.
        assert_eq!(via_trait.matvec(&v), direct.matvec(&v));
    }

    #[test]
    fn grid_term_helpers() {
        let axes = vec![
            Grid1d::fit(0.0, 1.0, 8).unwrap(),
            Grid1d::fit_any(0.0, 1.0, 1).unwrap(),
            Grid1d::fit_any(0.0, 1.0, 3).unwrap(),
        ];
        let t = GridTerm::new(-2.0, axes);
        assert_eq!(t.dims(), vec![8, 1, 3]);
        assert_eq!(t.total(), 24);
        assert_eq!(t.strides(), vec![3, 3, 1]);
        assert_eq!(t.stencil_size(), 4 * 1 * 2);
    }
}
