//! Sparse inducing grids via the combination technique.
//!
//! The dense Kronecker grid of KISS-GP spends mᵈ points to resolve every
//! dimension at full resolution simultaneously — the curse of
//! dimensionality that caps it at d ≲ 5. *Kernel Interpolation with
//! Sparse Grids* (Yadav, Sheldon & Musco, 2023) escapes it by
//! interpolating on a **sparse grid**: the combination technique writes
//! the sparse-grid interpolant at level ℓ as a signed sum of full (but
//! anisotropic) tensor-product interpolants,
//!
//! ```text
//! I_ℓ = Σ_{q=0}^{d−1} (−1)^q · C(d−1, q) · Σ_{|l|₁ = ℓ−q} I_l
//! ```
//!
//! where each multi-index `l = (l_1 … l_d)` names a rectilinear grid with
//! `m(l_k)` points on axis k (here `m(0) = 1`, `m(l) = 2^{l+1}+1`). Each
//! term is exactly the machinery this crate already has — a Kronecker
//! product of Toeplitz axis kernels behind a tensor interpolation stencil
//! — so a sparse-grid SKI operator is a [`crate::operators::SumOp`] of
//! scaled [`crate::operators::KroneckerSkiOp`]s and the whole interpolant
//! rides the existing block-MVM engine unchanged.
//!
//! Point count grows as O(2^ℓ · ℓ^{d−1}) instead of mᵈ: at d = 10,
//! level 3 stores a few tens of thousands of points where the dense grid
//! would need 10²⁰. The cross-dimension error terms cancel between the
//! signed layers, leaving O(h_ℓ^p (log h_ℓ⁻¹)^{d−1}) interpolation error
//! for a p-th order axis stencil.
//!
//! Caveat: the signed sum is not exactly positive semi-definite — the
//! combination can carry small negative eigenvalues of the order of the
//! approximation error. The GP operator is always used noise-shifted
//! (`+ σ_n² I`), which dominates them at practical levels; pick the level
//! so the kernel approximation error sits below the noise floor.

use super::axis::Grid1d;
use super::{column_bounds, GridSpec, GridTerm, InducingGrid};
use crate::linalg::Matrix;
use crate::{Error, Result};

/// Hard cap on combination-technique terms: C(ℓ+d−1, d−1) grows quickly
/// in d, and each term is an operator build. Exceeding this is always a
/// configuration error (lower the level).
pub const MAX_SPARSE_TERMS: usize = 20_000;

/// Axis size at 1-D refinement level `l`: 1, 5, 9, 17, 33, 65, …
/// (`m(0) = 1`, `m(l) = 2^{l+1} + 1`). Level 0 is the constant axis that
/// lets high-d terms stay tiny; level 1 already carries a full cubic
/// stencil.
pub fn sparse_axis_points(l: usize) -> usize {
    if l == 0 {
        1
    } else {
        (1usize << (l + 1)) + 1
    }
}

/// Binomial coefficient C(n, k) in f64 (exact for the small n used here;
/// requires k ≤ n).
fn binom(n: usize, k: usize) -> f64 {
    debug_assert!(k <= n);
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

/// All compositions of `total` into `d` non-negative parts, appended to
/// `out` with `prefix` as the already-fixed leading levels.
fn push_compositions(
    total: usize,
    d: usize,
    prefix: &mut Vec<usize>,
    out: &mut Vec<Vec<usize>>,
) {
    if d == 1 {
        prefix.push(total);
        out.push(prefix.clone());
        prefix.pop();
        return;
    }
    for first in 0..=total {
        prefix.push(first);
        push_compositions(total - first, d - 1, prefix, out);
        prefix.pop();
    }
}

/// The combination-technique layers for dimension `d` at level `level`:
/// `(coefficient, per-dimension levels)` pairs. Coefficients sum to 1
/// (the combined interpolant reproduces constants exactly).
pub fn combination_terms(d: usize, level: usize) -> Result<Vec<(f64, Vec<usize>)>> {
    if d == 0 {
        return Err(Error::Grid("sparse grid needs d >= 1".into()));
    }
    if level > 24 {
        return Err(Error::Grid(format!(
            "sparse-grid level {level} is absurd (axis sizes overflow)"
        )));
    }
    // Count the terms first (stars and bars): the layer |l|₁ = s holds
    // C(s+d−1, d−1) grids.
    let mut expected = 0.0f64;
    for q in 0..=(d - 1).min(level) {
        expected += binom(level - q + d - 1, d - 1);
    }
    if expected > MAX_SPARSE_TERMS as f64 {
        return Err(Error::Grid(format!(
            "sparse grid at d={d}, level={level} needs {expected:.0} \
             combination terms (> {MAX_SPARSE_TERMS}) — lower the level"
        )));
    }
    let mut terms = Vec::new();
    for q in 0..=(d - 1).min(level) {
        let sign = if q % 2 == 0 { 1.0 } else { -1.0 };
        let coeff = sign * binom(d - 1, q);
        let mut comps = Vec::new();
        push_compositions(level - q, d, &mut Vec::new(), &mut comps);
        for levels in comps {
            terms.push((coeff, levels));
        }
    }
    Ok(terms)
}

/// A combination-technique sparse grid: a signed sum of anisotropic
/// rectilinear terms.
#[derive(Clone, Debug)]
pub struct SparseGrid {
    level: usize,
    d: usize,
    terms: Vec<GridTerm>,
}

impl SparseGrid {
    /// Fit a level-`level` sparse grid to the columns of `xs`.
    pub fn fit(xs: &Matrix, level: usize) -> Result<Self> {
        let d = xs.cols;
        let bounds = column_bounds(xs);
        Self::from_bounds(&bounds, level, d)
    }

    /// Fit from explicit per-dimension `(lo, hi)` bounds.
    pub fn from_bounds(
        bounds: &[(f64, f64)],
        level: usize,
        d: usize,
    ) -> Result<Self> {
        assert_eq!(bounds.len(), d);
        let mut terms = Vec::new();
        for (coeff, levels) in combination_terms(d, level)? {
            let axes = levels
                .iter()
                .zip(bounds)
                .map(|(&l, &(lo, hi))| Grid1d::fit_any(lo, hi, sparse_axis_points(l)))
                .collect::<Result<Vec<_>>>()?;
            terms.push(GridTerm::new(coeff, axes));
        }
        Ok(SparseGrid { level, d, terms })
    }

    /// Combination level ℓ.
    pub fn level(&self) -> usize {
        self.level
    }
}

impl InducingGrid for SparseGrid {
    fn dim(&self) -> usize {
        self.d
    }

    fn spec(&self) -> GridSpec {
        GridSpec::Sparse { level: self.level }
    }

    fn terms(&self) -> &[GridTerm] {
        &self.terms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn combination_coefficients_sum_to_one() {
        for (d, level) in [(1usize, 3usize), (2, 4), (3, 5), (8, 3), (10, 2)] {
            let terms = combination_terms(d, level).unwrap();
            let sum: f64 = terms.iter().map(|(c, _)| c).sum();
            assert!(
                (sum - 1.0).abs() < 1e-9,
                "d={d} level={level}: coefficient sum {sum}"
            );
            // Every layer |l|₁ is within [level−(d−1), level] (clamped at 0).
            for (_, levels) in &terms {
                assert_eq!(levels.len(), d);
                let s: usize = levels.iter().sum();
                assert!(s <= level, "d={d} level={level}: |l|={s}");
                assert!(s + d > level, "d={d} level={level}: |l|={s}");
            }
        }
    }

    #[test]
    fn layer_counts_match_stars_and_bars() {
        // d=2, level=2: |l|=2 has 3 grids (+1 each), |l|=1 has 2 (−1 each).
        let terms = combination_terms(2, 2).unwrap();
        assert_eq!(terms.len(), 5);
        let plus = terms.iter().filter(|(c, _)| *c > 0.0).count();
        let minus = terms.iter().filter(|(c, _)| *c < 0.0).count();
        assert_eq!((plus, minus), (3, 2));
    }

    #[test]
    fn growth_rule() {
        assert_eq!(sparse_axis_points(0), 1);
        assert_eq!(sparse_axis_points(1), 5);
        assert_eq!(sparse_axis_points(2), 9);
        assert_eq!(sparse_axis_points(3), 17);
        assert_eq!(sparse_axis_points(4), 33);
    }

    #[test]
    fn point_count_breaks_the_m_to_the_d_barrier() {
        let mut rng = Rng::new(5);
        let xs = Matrix::from_fn(40, 8, |_, _| rng.uniform_in(-1.0, 1.0));
        let g = SparseGrid::fit(&xs, 3).unwrap();
        assert_eq!(g.dim(), 8);
        assert_eq!(g.terms().len(), 165); // C(10,7)+C(9,7)+C(8,7)+C(7,7)
        let pts = g.total_points();
        // ~10k points where a 17-per-dim dense grid would need 17^8 ≈ 7e9.
        assert!(pts < 20_000, "sparse grid too large: {pts}");
        assert!(pts > 100, "suspiciously small: {pts}");
    }

    #[test]
    fn term_cap_is_enforced() {
        let bounds = vec![(0.0, 1.0); 16];
        let err = SparseGrid::from_bounds(&bounds, 8, 16).unwrap_err();
        assert!(err.to_string().contains("terms"), "{err}");
    }
}
