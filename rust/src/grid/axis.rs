//! 1-D inducing-grid axes and local interpolation stencils.
//!
//! Every inducing grid in this crate — the 1-D SKI grids, the dense
//! Kronecker tensor grid, and each anisotropic term of the sparse
//! combination-technique grid — is a Cartesian product of [`Grid1d`]
//! axes. This module owns the axis type, its (validated) fitting rules,
//! and the per-axis interpolation stencils:
//!
//! - **cubic** (Keys 1981, a = −1/2, 4 weights) for axes with m ≥ 4
//!   points — the classic SKI choice, O(h³) on smooth functions;
//! - **linear** (2 weights) for tiny axes with m ∈ {2, 3};
//! - **constant** (weight 1) for single-point axes (m = 1) — the coarsest
//!   level of a sparse-grid term.
//!
//! [`tensor_stencil`] takes the per-axis stencils to their tensor product
//! over a row-major grid, emitting `(flat index, weight)` pairs; it is the
//! single stencil-extraction primitive shared by the Kronecker SKI
//! operator and the serving layer's predictive caches.
//!
//! Each stencil also has an analytic derivative ([`cubic_stencil_deriv`],
//! [`axis_stencil_deriv`], composed by [`tensor_stencil_grad`]): the
//! D-SKI extension (Eriksson et al. 2018) represents a gradient
//! observation ∂f/∂x_a as a row of ∂W/∂x_a — the same grid support with
//! differentiated weights — so derivative data rides the existing
//! Kronecker MVM machinery unchanged.

use crate::{Error, Result};

/// Number of interpolation weights per point on a cubic axis.
pub const STENCIL: usize = 4;

/// Fewest points for which the margin-fitted cubic grid of [`Grid1d::fit`]
/// is well defined (the fit reserves 2 cells of margin on each side, so
/// `h = span / (m − 5)` needs m ≥ 6).
pub const MIN_FIT_POINTS: usize = 6;

/// A regular 1-D grid of inducing points.
#[derive(Clone, Debug, PartialEq)]
pub struct Grid1d {
    /// Left-most grid point.
    pub min: f64,
    /// Grid spacing h.
    pub h: f64,
    /// Number of grid points m.
    pub m: usize,
}

/// Shared validation for both fitting rules.
fn check_bounds(lo: f64, hi: f64) -> Result<()> {
    if !lo.is_finite() || !hi.is_finite() {
        return Err(Error::Grid(format!(
            "non-finite data bounds [{lo}, {hi}]"
        )));
    }
    if hi < lo {
        return Err(Error::Grid(format!(
            "reversed data bounds [{lo}, {hi}]"
        )));
    }
    if hi == lo {
        return Err(Error::Grid(format!(
            "degenerate (constant) feature: lo == hi == {lo}; a grid \
             cannot be scaled to a zero-width column"
        )));
    }
    Ok(())
}

impl Grid1d {
    /// Build a grid of `m ≥ 6` points covering `[lo, hi]` with enough
    /// margin that every data point has a full interior cubic stencil.
    ///
    /// Returns [`Error::Grid`] for degenerate inputs: non-finite or
    /// reversed bounds, a constant feature (`lo == hi`), or
    /// `m <` [`MIN_FIT_POINTS`] (the margin formula `h = span/(m−5)`
    /// yields an invalid spacing below that).
    pub fn fit(lo: f64, hi: f64, m: usize) -> Result<Self> {
        check_bounds(lo, hi)?;
        if m < MIN_FIT_POINTS {
            return Err(Error::Grid(format!(
                "grid size m={m} < {MIN_FIT_POINTS}: the margin-fitted \
                 cubic stencil needs at least {MIN_FIT_POINTS} points"
            )));
        }
        let span = hi - lo;
        // Reserve 2 grid cells of margin on each side for the stencil.
        let h = span / (m - 5) as f64;
        Ok(Grid1d { min: lo - 2.0 * h, h, m })
    }

    /// Build a grid of `m ≥ 1` points covering `[lo, hi]` exactly (no
    /// stencil margin): `m = 1` places the single point at the interval
    /// center, `m ≥ 2` spaces the points `span/(m−1)` apart with the end
    /// points on the bounds. Sizes `m ≥` [`MIN_FIT_POINTS`] delegate to
    /// the margin fit of [`Grid1d::fit`].
    ///
    /// This is the fitting rule for the anisotropic axes of sparse-grid
    /// terms, whose coarsest levels have 1-point axes.
    pub fn fit_any(lo: f64, hi: f64, m: usize) -> Result<Self> {
        if m >= MIN_FIT_POINTS {
            return Self::fit(lo, hi, m);
        }
        check_bounds(lo, hi)?;
        if m == 0 {
            return Err(Error::Grid("grid size m=0".into()));
        }
        let span = hi - lo;
        if m == 1 {
            return Ok(Grid1d { min: 0.5 * (lo + hi), h: span, m: 1 });
        }
        Ok(Grid1d { min: lo, h: span / (m - 1) as f64, m })
    }

    /// Grid point i.
    #[inline]
    pub fn point(&self, i: usize) -> f64 {
        self.min + i as f64 * self.h
    }

    /// All grid points.
    pub fn points(&self) -> Vec<f64> {
        (0..self.m).map(|i| self.point(i)).collect()
    }

    /// Right-most grid point (`point(m − 1)`).
    #[inline]
    pub fn max(&self) -> f64 {
        self.point(self.m - 1)
    }

    /// Width of this axis's interpolation stencil (4 cubic, 2 linear,
    /// 1 constant) — determined by the axis size alone.
    #[inline]
    pub fn stencil_width(&self) -> usize {
        axis_width(self.m)
    }
}

/// Stencil width for an m-point axis (see [`Grid1d::stencil_width`]).
#[inline]
pub fn axis_width(m: usize) -> usize {
    if m >= STENCIL {
        STENCIL
    } else if m >= 2 {
        2
    } else {
        1
    }
}

/// Keys (1981) cubic convolution kernel, a = −1/2, support |s| < 2.
#[inline]
fn cubic_weight(s: f64) -> f64 {
    let a = -0.5;
    let s = s.abs();
    if s < 1.0 {
        ((a + 2.0) * s - (a + 3.0)) * s * s + 1.0
    } else if s < 2.0 {
        a * (((s - 5.0) * s + 8.0) * s - 4.0)
    } else {
        0.0
    }
}

/// Derivative dw/ds of [`cubic_weight`] (signed argument; odd symmetry
/// about 0 since the kernel itself is even).
#[inline]
fn cubic_weight_deriv(s: f64) -> f64 {
    let a = -0.5;
    let sign = if s < 0.0 { -1.0 } else { 1.0 };
    let s = s.abs();
    sign * if s < 1.0 {
        (3.0 * (a + 2.0) * s - 2.0 * (a + 3.0)) * s
    } else if s < 2.0 {
        a * ((3.0 * s - 10.0) * s + 8.0)
    } else {
        0.0
    }
}

/// Stencil of point `x` on `grid` (m ≥ 4): left-most grid index plus the
/// four (renormalized) cubic convolution weights. Shared by the 1-D
/// `InterpMatrix` and the tensor-product weights of KISS-GP.
pub fn cubic_stencil(x: f64, grid: &Grid1d) -> (usize, [f64; STENCIL]) {
    let u = (x - grid.min) / grid.h;
    let fi = u.floor() as isize;
    let base = (fi - 1).clamp(0, grid.m as isize - STENCIL as isize) as usize;
    let mut row_w = [0.0; STENCIL];
    let mut wsum = 0.0;
    for (k, rw) in row_w.iter_mut().enumerate() {
        *rw = cubic_weight(u - (base + k) as f64);
        wsum += *rw;
    }
    // Renormalize: guards partition-of-unity at clamped boundaries.
    if wsum.abs() > 1e-12 {
        for rw in row_w.iter_mut() {
            *rw /= wsum;
        }
    }
    (base, row_w)
}

/// Derivative of the (renormalized) cubic stencil of [`cubic_stencil`]
/// with respect to `x`: the same base index plus the four weight
/// derivatives. With Σ the raw weight sum, the renormalized weight is
/// w_k/Σ, so d/dx (w_k/Σ) = (w_k′·Σ − w_k·Σ′)/Σ² · (1/h) — the quotient
/// rule keeps the derivative exact through the boundary renormalization
/// (in the interior Σ ≡ 1 and Σ′ ≡ 0, recovering the plain chain rule).
/// This is the D-SKI row primitive (Eriksson et al. 2018): ∂W/∂x rows
/// reuse the value stencil's support.
pub fn cubic_stencil_deriv(x: f64, grid: &Grid1d) -> (usize, [f64; STENCIL]) {
    let u = (x - grid.min) / grid.h;
    let fi = u.floor() as isize;
    let base = (fi - 1).clamp(0, grid.m as isize - STENCIL as isize) as usize;
    let mut w = [0.0; STENCIL];
    let mut dw = [0.0; STENCIL];
    let mut wsum = 0.0;
    let mut dsum = 0.0;
    for k in 0..STENCIL {
        let s = u - (base + k) as f64;
        w[k] = cubic_weight(s);
        dw[k] = cubic_weight_deriv(s);
        wsum += w[k];
        dsum += dw[k];
    }
    let mut out = [0.0; STENCIL];
    let inv_h = 1.0 / grid.h;
    if wsum.abs() > 1e-12 {
        for k in 0..STENCIL {
            out[k] = (dw[k] * wsum - w[k] * dsum) / (wsum * wsum) * inv_h;
        }
    } else {
        for k in 0..STENCIL {
            out[k] = dw[k] * inv_h;
        }
    }
    (base, out)
}

/// Derivative stencil of point `x` on an axis of **any** size: base grid
/// index, stencil width w ∈ {1, 2, 4}, and the w weight derivatives
/// d/dx in the first w slots. Cubic axes differentiate the renormalized
/// Keys stencil; linear axes have slope ±1/h (0 where the stencil is
/// clamped to the axis ends, matching the piecewise-constant
/// extrapolation of [`axis_stencil`]); constant axes contribute 0.
pub fn axis_stencil_deriv(x: f64, grid: &Grid1d) -> (usize, usize, [f64; STENCIL]) {
    let m = grid.m;
    if m >= STENCIL {
        let (base, dw) = cubic_stencil_deriv(x, grid);
        (base, STENCIL, dw)
    } else if m >= 2 {
        let u_raw = (x - grid.min) / grid.h;
        let u = u_raw.clamp(0.0, (m - 1) as f64);
        let i = (u.floor() as usize).min(m - 2);
        let inv_h = if (0.0..=(m - 1) as f64).contains(&u_raw) {
            1.0 / grid.h
        } else {
            0.0 // clamped: interpolant is constant outside the axis
        };
        (i, 2, [-inv_h, inv_h, 0.0, 0.0])
    } else {
        (0, 1, [0.0; STENCIL])
    }
}

/// Stencil of point `x` on an axis of **any** size: returns the base grid
/// index, the stencil width w ∈ {1, 2, 4}, and the w weights in the first
/// w slots of the array. Cubic for m ≥ 4, linear (clamped to the axis)
/// for m ∈ {2, 3}, constant for m = 1.
pub fn axis_stencil(x: f64, grid: &Grid1d) -> (usize, usize, [f64; STENCIL]) {
    let m = grid.m;
    if m >= STENCIL {
        let (base, w) = cubic_stencil(x, grid);
        (base, STENCIL, w)
    } else if m >= 2 {
        let u = ((x - grid.min) / grid.h).clamp(0.0, (m - 1) as f64);
        let i = (u.floor() as usize).min(m - 2);
        let t = u - i as f64;
        (i, 2, [1.0 - t, t, 0.0, 0.0])
    } else {
        (0, 1, [1.0, 0.0, 0.0, 0.0])
    }
}

/// Row-major strides of a tensor-product grid with per-dimension sizes
/// `dims` (dimension 0 slowest — the layout shared by
/// `crate::operators::kronecker` and the serving layer's grid-side
/// predictive caches).
pub fn tensor_strides(dims: &[usize]) -> Vec<usize> {
    let d = dims.len();
    let mut strides = vec![1usize; d];
    for k in (0..d.saturating_sub(1)).rev() {
        strides[k] = strides[k + 1] * dims[k + 1];
    }
    strides
}

/// Maximum tensor-stencil dimensionality (4ᵈ weights per point becomes
/// astronomically large long before this bound binds on cubic axes;
/// sparse-grid terms with mostly 1-point axes stay cheap far beyond it
/// but share the bound for the fixed-size scratch arrays).
pub const MAX_TENSOR_DIM: usize = 16;

/// Number of `(flat index, weight)` pairs [`tensor_stencil`] emits per
/// point on the product of `grids`: Π per-axis stencil widths.
pub fn tensor_stencil_size(grids: &[Grid1d]) -> usize {
    grids.iter().map(|g| g.stencil_width()).product()
}

/// Tensor-product interpolation stencil of the d-dimensional point `x` on
/// the per-dimension grids `grids`: calls `emit(flat_index, weight)` for
/// each of the [`tensor_stencil_size`] (flat grid index, product weight)
/// pairs, in the fixed order where the last dimension's offset varies
/// fastest. `strides` must be [`tensor_strides`] of the grid sizes.
///
/// Axes of any size compose: cubic axes contribute 4 offsets, linear
/// axes 2, constant axes 1 — so a sparse-grid term whose coarse axes are
/// single points costs only as much as its refined axes.
///
/// This is the single-point stencil-extraction primitive shared by the
/// KISS-GP operator's interpolation matrix and the O(1)-per-point
/// predictive caches in `crate::serve::cache`.
pub fn tensor_stencil<F: FnMut(usize, f64)>(
    x: &[f64],
    grids: &[Grid1d],
    strides: &[usize],
    mut emit: F,
) {
    let d = grids.len();
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(strides.len(), d);
    assert!(d <= MAX_TENSOR_DIM, "tensor stencil supports d <= {MAX_TENSOR_DIM}");
    let mut bases = [0usize; MAX_TENSOR_DIM];
    let mut widths = [1usize; MAX_TENSOR_DIM];
    let mut wts = [[0.0f64; STENCIL]; MAX_TENSOR_DIM];
    let mut size = 1usize;
    for k in 0..d {
        let (b, wd, ws) = axis_stencil(x[k], &grids[k]);
        bases[k] = b;
        widths[k] = wd;
        wts[k] = ws;
        size *= wd;
    }
    for c in 0..size {
        let mut flat = 0usize;
        let mut weight = 1.0;
        let mut cc = c;
        for k in (0..d).rev() {
            let o = cc % widths[k];
            cc /= widths[k];
            flat += (bases[k] + o) * strides[k];
            weight *= wts[k][o];
        }
        emit(flat, weight);
    }
}

/// Tensor-product **derivative** stencil of the d-dimensional point `x`
/// with respect to coordinate `axis`: identical support, emission order,
/// and pair count as [`tensor_stencil`], but the weights are
/// ∂/∂x_axis of the product weights — the derivative stencil of
/// [`axis_stencil_deriv`] along `axis` composed with the value stencils
/// of [`axis_stencil`] on every other dimension. These are the gradient
/// rows of D-SKI: `(∂W/∂x_axis) u` interpolates ∂f/∂x_axis from the same
/// grid values `u` the value rows use.
pub fn tensor_stencil_grad<F: FnMut(usize, f64)>(
    x: &[f64],
    axis: usize,
    grids: &[Grid1d],
    strides: &[usize],
    mut emit: F,
) {
    let d = grids.len();
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(strides.len(), d);
    assert!(axis < d, "gradient axis {axis} out of range for d={d}");
    assert!(d <= MAX_TENSOR_DIM, "tensor stencil supports d <= {MAX_TENSOR_DIM}");
    let mut bases = [0usize; MAX_TENSOR_DIM];
    let mut widths = [1usize; MAX_TENSOR_DIM];
    let mut wts = [[0.0f64; STENCIL]; MAX_TENSOR_DIM];
    let mut size = 1usize;
    for k in 0..d {
        let (b, wd, ws) = if k == axis {
            axis_stencil_deriv(x[k], &grids[k])
        } else {
            axis_stencil(x[k], &grids[k])
        };
        bases[k] = b;
        widths[k] = wd;
        wts[k] = ws;
        size *= wd;
    }
    for c in 0..size {
        let mut flat = 0usize;
        let mut weight = 1.0;
        let mut cc = c;
        for k in (0..d).rev() {
            let o = cc % widths[k];
            cc /= widths[k];
            flat += (bases[k] + o) * strides[k];
            weight *= wts[k][o];
        }
        emit(flat, weight);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn grid_covers_data_with_margin() {
        let g = Grid1d::fit(-1.0, 1.0, 20).unwrap();
        assert!(g.point(0) < -1.0);
        assert!(g.point(g.m - 1) > 1.0);
        // Interior stencil for boundary data points.
        let u = (-1.0 - g.min) / g.h;
        assert!(u >= 1.0);
        let u = (1.0 - g.min) / g.h;
        assert!(u <= (g.m - 3) as f64 + 1.0);
    }

    #[test]
    fn fit_rejects_degenerate_inputs() {
        // Constant feature.
        let err = Grid1d::fit(0.7, 0.7, 16).unwrap_err();
        assert!(err.to_string().contains("constant"), "{err}");
        // Too few points for the margin formula (historically m = 4 gave a
        // negative spacing and m = 5 an infinite one).
        for m in [0usize, 3, 4, 5] {
            let err = Grid1d::fit(0.0, 1.0, m).unwrap_err();
            assert!(err.to_string().contains("grid"), "m={m}: {err}");
        }
        // Non-finite and reversed bounds.
        assert!(Grid1d::fit(f64::NAN, 1.0, 16).is_err());
        assert!(Grid1d::fit(0.0, f64::INFINITY, 16).is_err());
        assert!(Grid1d::fit(1.0, 0.0, 16).is_err());
        // fit_any shares the bound checks.
        assert!(Grid1d::fit_any(0.5, 0.5, 3).is_err());
        assert!(Grid1d::fit_any(0.0, 1.0, 0).is_err());
    }

    #[test]
    fn fit_any_covers_exactly() {
        let g = Grid1d::fit_any(-1.0, 3.0, 5).unwrap();
        assert_eq!(g.m, 5);
        assert!((g.point(0) + 1.0).abs() < 1e-12);
        assert!((g.point(4) - 3.0).abs() < 1e-12);
        let g1 = Grid1d::fit_any(-1.0, 3.0, 1).unwrap();
        assert_eq!(g1.m, 1);
        assert!((g1.point(0) - 1.0).abs() < 1e-12); // interval center
        // m >= 6 delegates to the margin fit.
        let g6 = Grid1d::fit_any(-1.0, 3.0, 12).unwrap();
        assert_eq!(g6, Grid1d::fit(-1.0, 3.0, 12).unwrap());
    }

    #[test]
    fn axis_stencils_partition_unity() {
        let mut rng = Rng::new(3);
        for m in [1usize, 2, 3, 5, 16] {
            let g = Grid1d::fit_any(0.0, 1.0, m).unwrap();
            assert_eq!(g.stencil_width(), axis_width(m));
            for _ in 0..40 {
                let x = rng.uniform_in(0.0, 1.0);
                let (base, wd, w) = axis_stencil(x, &g);
                assert!(base + wd <= m, "stencil exceeds axis: m={m}");
                let sum: f64 = w[..wd].iter().sum();
                assert!((sum - 1.0).abs() < 1e-10, "m={m}: sum {sum}");
            }
        }
    }

    #[test]
    fn linear_stencil_interpolates_linears_exactly() {
        let g = Grid1d::fit_any(0.0, 2.0, 3).unwrap();
        let f: Vec<f64> = g.points().iter().map(|&u| 3.0 * u - 1.0).collect();
        let mut rng = Rng::new(4);
        for _ in 0..30 {
            let x = rng.uniform_in(0.0, 2.0);
            let (b, wd, w) = axis_stencil(x, &g);
            let got: f64 = (0..wd).map(|k| w[k] * f[b + k]).sum();
            assert!((got - (3.0 * x - 1.0)).abs() < 1e-10);
        }
    }

    #[test]
    fn tensor_stencil_mixed_widths_2d() {
        // One cubic axis × one constant axis: 4 pairs, weights match the
        // 1-D cubic stencil, flat indices walk the cubic axis only.
        let gx = Grid1d::fit(0.0, 1.0, 16).unwrap();
        let g1 = Grid1d::fit_any(0.0, 1.0, 1).unwrap();
        let strides = tensor_strides(&[16, 1]);
        assert_eq!(strides, vec![1, 1]);
        let grids = [gx.clone(), g1];
        assert_eq!(tensor_stencil_size(&grids), 4);
        let x = [0.37, 0.9];
        let (base, w) = cubic_stencil(0.37, &gx);
        let mut got = Vec::new();
        tensor_stencil(&x, &grids, &strides, |g, wt| got.push((g, wt)));
        assert_eq!(got.len(), 4);
        for (k, (gi, wt)) in got.iter().enumerate() {
            assert_eq!(*gi, base + k);
            assert_eq!(*wt, w[k]);
        }
    }

    #[test]
    fn cubic_stencil_deriv_matches_finite_differences() {
        let g = Grid1d::fit(-1.0, 1.0, 16).unwrap();
        let mut rng = Rng::new(7);
        let eps = 1e-6;
        for _ in 0..60 {
            let x = rng.uniform_in(-1.0, 1.0);
            let (b, dw) = cubic_stencil_deriv(x, &g);
            let (bp, wp) = cubic_stencil(x + eps, &g);
            let (bm, wm) = cubic_stencil(x - eps, &g);
            // Stay within one stencil window (skip the rare base flip).
            if bp != bm || bp != b {
                continue;
            }
            for k in 0..STENCIL {
                let fd = (wp[k] - wm[k]) / (2.0 * eps);
                assert!(
                    (dw[k] - fd).abs() < 1e-5,
                    "x={x}: dw[{k}]={} vs fd {fd}",
                    dw[k]
                );
            }
        }
    }

    #[test]
    fn derivative_weights_sum_to_zero() {
        // d/dx of a partition of unity is identically zero.
        let mut rng = Rng::new(11);
        for m in [2usize, 3, 5, 16] {
            let g = Grid1d::fit_any(0.0, 1.0, m).unwrap();
            for _ in 0..40 {
                let x = rng.uniform_in(0.0, 1.0);
                let (_, wd, dw) = axis_stencil_deriv(x, &g);
                let sum: f64 = dw[..wd].iter().sum();
                assert!(sum.abs() < 1e-9, "m={m}: derivative sum {sum}");
            }
        }
        // Constant axes contribute an exactly-zero derivative.
        let g1 = Grid1d::fit_any(0.0, 1.0, 1).unwrap();
        let (_, wd, dw) = axis_stencil_deriv(0.3, &g1);
        assert_eq!(wd, 1);
        assert_eq!(dw[0], 0.0);
    }

    #[test]
    fn derivative_stencil_differentiates_linears_exactly() {
        // A cubic-convolution interpolant reproduces linear functions, so
        // its derivative stencil must reproduce their (constant) slope.
        let g = Grid1d::fit(0.0, 2.0, 20).unwrap();
        let f: Vec<f64> = g.points().iter().map(|&u| 3.0 * u - 1.0).collect();
        let mut rng = Rng::new(5);
        for _ in 0..40 {
            let x = rng.uniform_in(0.0, 2.0);
            let (b, dw) = cubic_stencil_deriv(x, &g);
            let got: f64 = (0..STENCIL).map(|k| dw[k] * f[b + k]).sum();
            assert!((got - 3.0).abs() < 1e-9, "slope at {x}: {got}");
        }
        // Linear axes too (slope ±1/h inside the axis).
        let g3 = Grid1d::fit_any(0.0, 2.0, 3).unwrap();
        let f3: Vec<f64> = g3.points().iter().map(|&u| 3.0 * u - 1.0).collect();
        for _ in 0..20 {
            let x = rng.uniform_in(0.0, 2.0);
            let (b, wd, dw) = axis_stencil_deriv(x, &g3);
            let got: f64 = (0..wd).map(|k| dw[k] * f3[b + k]).sum();
            assert!((got - 3.0).abs() < 1e-9, "linear-axis slope at {x}: {got}");
        }
    }

    #[test]
    fn tensor_stencil_grad_matches_finite_differences_2d() {
        let gx = Grid1d::fit(-1.0, 1.0, 12).unwrap();
        let gy = Grid1d::fit(0.0, 2.0, 9).unwrap();
        let grids = [gx, gy];
        let strides = tensor_strides(&[12, 9]);
        // A smooth surrogate on the grid: interpolate it and compare the
        // gradient stencil against central differences of the value
        // stencil applied to the same grid vector.
        let total = 12 * 9;
        let u: Vec<f64> = (0..total)
            .map(|i| {
                let (ix, iy) = (i / 9, i % 9);
                ((ix as f64) * 0.3).sin() + ((iy as f64) * 0.2).cos()
            })
            .collect();
        let interp = |x: &[f64]| {
            let mut acc = 0.0;
            tensor_stencil(x, &grids, &strides, |flat, w| acc += w * u[flat]);
            acc
        };
        let eps = 1e-6;
        let mut rng = Rng::new(21);
        for _ in 0..25 {
            let x = [rng.uniform_in(-0.9, 0.9), rng.uniform_in(0.1, 1.9)];
            for axis in 0..2 {
                let mut got = 0.0;
                let mut count = 0usize;
                tensor_stencil_grad(&x, axis, &grids, &strides, |flat, w| {
                    assert!(flat < total);
                    got += w * u[flat];
                    count += 1;
                });
                assert_eq!(count, STENCIL * STENCIL);
                let mut xp = x;
                let mut xm = x;
                xp[axis] += eps;
                xm[axis] -= eps;
                let fd = (interp(&xp) - interp(&xm)) / (2.0 * eps);
                assert!(
                    (got - fd).abs() < 1e-4,
                    "axis {axis} at {x:?}: {got} vs fd {fd}"
                );
            }
        }
    }

    #[test]
    fn tensor_stencil_partition_of_unity_2d() {
        let gx = Grid1d::fit(-1.0, 1.0, 12).unwrap();
        let gy = Grid1d::fit(0.0, 2.0, 9).unwrap();
        let strides = tensor_strides(&[12, 9]);
        assert_eq!(strides, vec![9, 1]);
        let mut rng = Rng::new(13);
        for _ in 0..25 {
            let x = [rng.uniform_in(-1.0, 1.0), rng.uniform_in(0.0, 2.0)];
            let mut sum = 0.0;
            let mut count = 0;
            tensor_stencil(&x, &[gx.clone(), gy.clone()], &strides, |flat, w| {
                assert!(flat < 12 * 9);
                sum += w;
                count += 1;
            });
            assert_eq!(count, STENCIL * STENCIL);
            assert!((sum - 1.0).abs() < 1e-10, "2-D partition of unity: {sum}");
        }
    }
}
