//! Synthetic surrogates for the paper's regression datasets.
//!
//! The UCI datasets (and the proprietary precipitation data) are not
//! available in this offline environment, so each is replaced by a
//! generator matching its (n, d) shape and qualitative structure: a
//! random additive + pairwise-interaction response surface whose
//! smoothness and noise level differ per dataset. Table-1 comparisons are
//! *relative between methods on the same data*, which these surrogates
//! preserve (see DESIGN.md §4 for the substitution argument).

use crate::linalg::Matrix;
use crate::util::Rng;

/// A regression dataset specification mirroring one of the paper's.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Paper's n (we scale down via `scale` at generation time).
    pub n: usize,
    pub d: usize,
    /// Generator seed (fixed → dataset is reproducible).
    pub seed: u64,
    /// Number of additive sinusoidal components.
    pub num_terms: usize,
    /// Observation noise level.
    pub noise: f64,
}

/// The six Table-1 datasets plus the Fig-2-right Power dataset.
pub const DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "pumadyn", n: 8192, d: 32, seed: 101, num_terms: 12, noise: 0.30 },
    DatasetSpec { name: "elevators", n: 16599, d: 18, seed: 102, num_terms: 10, noise: 0.10 },
    DatasetSpec { name: "precipitation", n: 120_000, d: 3, seed: 103, num_terms: 16, noise: 0.25 },
    DatasetSpec { name: "kegg", n: 48827, d: 22, seed: 104, num_terms: 10, noise: 0.08 },
    DatasetSpec { name: "protein", n: 45730, d: 9, seed: 105, num_terms: 14, noise: 0.20 },
    DatasetSpec { name: "video", n: 68784, d: 16, seed: 106, num_terms: 12, noise: 0.12 },
    DatasetSpec { name: "power", n: 9568, d: 4, seed: 107, num_terms: 8, noise: 0.10 },
];

/// Look up a dataset by name.
pub fn dataset_by_name(name: &str) -> Option<&'static DatasetSpec> {
    DATASETS.iter().find(|s| s.name == name)
}

/// A train/test regression problem (inputs z-scored per dimension to
/// [-1, 1]-ish range, targets z-scored; MAE is reported in target units).
#[derive(Clone, Debug)]
pub struct RegressionData {
    pub name: String,
    pub xtrain: Matrix,
    pub ytrain: Vec<f64>,
    pub xtest: Matrix,
    pub ytest: Vec<f64>,
}

impl RegressionData {
    pub fn n(&self) -> usize {
        self.xtrain.rows
    }

    pub fn d(&self) -> usize {
        self.xtrain.cols
    }
}

/// Random smooth response surface: additive sinusoids over random
/// projections plus sparse pairwise interactions.
struct Surface {
    // (weight vector, phase, amplitude) per term
    terms: Vec<(Vec<f64>, f64, f64)>,
    // (dim a, dim b, amplitude)
    inters: Vec<(usize, usize, f64)>,
}

impl Surface {
    fn sample(spec: &DatasetSpec, rng: &mut Rng) -> Self {
        let terms = (0..spec.num_terms)
            .map(|_| {
                // Random direction with O(1/√d) entries keeps the argument
                // of sin at O(1) scale for any d.
                let w: Vec<f64> = (0..spec.d)
                    .map(|_| rng.normal() * 1.5 / (spec.d as f64).sqrt())
                    .collect();
                (w, rng.uniform_in(0.0, std::f64::consts::TAU), rng.uniform_in(0.5, 1.5))
            })
            .collect();
        let n_inter = (spec.d / 2).min(6);
        let inters = (0..n_inter)
            .map(|_| {
                let a = rng.below(spec.d);
                let mut b = rng.below(spec.d);
                if b == a {
                    b = (b + 1) % spec.d;
                }
                (a, b, rng.uniform_in(0.2, 0.6))
            })
            .collect();
        Surface { terms, inters }
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let mut y = 0.0;
        for (w, phase, amp) in &self.terms {
            let proj: f64 = w.iter().zip(x).map(|(a, b)| a * b).sum();
            y += amp * (proj + phase).sin();
        }
        for &(a, b, amp) in &self.inters {
            y += amp * x[a] * x[b];
        }
        y
    }
}

/// Generate a dataset at `scale` (0 < scale ≤ 1 shrinks n; test fraction
/// 10%, capped at 2000 test points to bound exact-cross-kernel predicts).
pub fn generate(spec: &DatasetSpec, scale: f64) -> RegressionData {
    assert!(scale > 0.0 && scale <= 1.0);
    let n_total = ((spec.n as f64 * scale) as usize).max(50);
    let n_test = (n_total / 10).clamp(10, 2000);
    let n_train = n_total - n_test;
    let mut rng = Rng::new(spec.seed);
    let surface = Surface::sample(spec, &mut rng);
    let gen_split = |rng: &mut Rng, n: usize, surface: &Surface| {
        let xs = Matrix::from_fn(n, spec.d, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..n)
            .map(|i| surface.eval(xs.row(i)) + spec.noise * rng.normal())
            .collect();
        (xs, ys)
    };
    let (xtrain, ytrain) = gen_split(&mut rng, n_train, &surface);
    let (xtest, ytest) = gen_split(&mut rng, n_test, &surface);
    // z-score targets on train statistics (models use a zero prior mean;
    // the paper's pipelines standardize likewise).
    let std = crate::util::Standardizer::fit(&ytrain);
    RegressionData {
        name: spec.name.to_string(),
        xtrain,
        ytrain: std.transform_vec(&ytrain),
        xtest,
        ytest: std.transform_vec(&ytest),
    }
}

/// Standard-normal inputs with an RBF-sampled-like response — the §4
/// synthetic MVM-accuracy setting ("2500 data points in d dimensions from
/// N(0, I)"). Targets are irrelevant there; only inputs are used.
pub fn gaussian_cloud(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, _| rng.normal())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mean, std_dev};

    #[test]
    fn specs_match_paper_shapes() {
        let by = |n: &str| dataset_by_name(n).unwrap();
        assert_eq!((by("pumadyn").n, by("pumadyn").d), (8192, 32));
        assert_eq!((by("elevators").n, by("elevators").d), (16599, 18));
        assert_eq!(by("precipitation").d, 3);
        assert_eq!((by("kegg").n, by("kegg").d), (48827, 22));
        assert_eq!((by("protein").n, by("protein").d), (45730, 9));
        assert_eq!((by("video").n, by("video").d), (68784, 16));
        assert_eq!(by("power").d, 4);
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = dataset_by_name("protein").unwrap();
        let a = generate(spec, 0.02);
        let b = generate(spec, 0.02);
        assert_eq!(a.ytrain, b.ytrain);
        assert_eq!(a.xtest.data, b.xtest.data);
    }

    #[test]
    fn scale_controls_size() {
        let spec = dataset_by_name("elevators").unwrap();
        let small = generate(spec, 0.01);
        let large = generate(spec, 0.05);
        assert!(large.n() > 3 * small.n());
        assert_eq!(small.d(), 18);
    }

    #[test]
    fn signal_exceeds_noise() {
        // The response surface must carry learnable signal: total std
        // clearly above the injected noise level.
        for name in ["pumadyn", "protein", "power"] {
            let spec = dataset_by_name(name).unwrap();
            let data = generate(spec, 0.05);
            let sd = std_dev(&data.ytrain);
            // After z-scoring, std = 1; noise std in z units must stay
            // well below 1 so there is learnable signal.
            assert!((sd - 1.0).abs() < 1e-9, "{name}: std {sd}");
            assert!(mean(&data.ytrain).abs() < 1e-9);
            let _ = spec;
        }
    }

    #[test]
    fn train_test_same_distribution() {
        let spec = dataset_by_name("power").unwrap();
        let data = generate(spec, 0.2);
        let (mtr, mte) = (mean(&data.ytrain), mean(&data.ytest));
        assert!((mtr - mte).abs() < 0.3, "train mean {mtr} vs test mean {mte}");
    }

    #[test]
    fn gaussian_cloud_moments() {
        let xs = gaussian_cloud(3000, 4, 7);
        let col: Vec<f64> = xs.col(2);
        assert!(mean(&col).abs() < 0.1);
        assert!((std_dev(&col) - 1.0).abs() < 0.1);
    }
}
