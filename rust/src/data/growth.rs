//! Synthetic childhood-development (growth-curve) data — the §6 workload.
//!
//! Surrogate for the Gates-foundation longitudinal dataset: each task is a
//! child with 5–30 weight measurements at irregular ages; children belong
//! to latent subpopulations (above-average / average / below-average
//! development, Fig. 3's three cluster archetypes) with cluster-level mean
//! curves plus individual Matérn-like wiggles.

use crate::gp::mtgp::MtgpData;
use crate::util::Rng;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct GrowthConfig {
    pub num_children: usize,
    /// Latent clusters (paper uses above/average/below = 3).
    pub num_clusters: usize,
    pub min_obs: usize,
    pub max_obs: usize,
    /// Observation noise on weight (z-scored units).
    pub noise: f64,
    pub seed: u64,
}

impl Default for GrowthConfig {
    fn default() -> Self {
        GrowthConfig {
            num_children: 30,
            num_clusters: 3,
            min_obs: 5,
            max_obs: 30,
            noise: 0.08,
            seed: 0,
        }
    }
}

/// Generated growth data: observations plus ground-truth cluster labels.
#[derive(Clone, Debug)]
pub struct GrowthData {
    pub data: MtgpData,
    /// True cluster of each child (for evaluation only).
    pub true_cluster: Vec<usize>,
}

/// Cluster-level mean growth curve on age t ∈ [0, 1] (normalized 0–24
/// months): logistic rise whose asymptote/rate depend on the cluster.
fn cluster_curve(cluster: usize, num_clusters: usize, t: f64) -> f64 {
    // Spread asymptotes symmetrically around 0 in z-scored weight units.
    let offset = if num_clusters == 1 {
        0.0
    } else {
        2.4 * (cluster as f64 / (num_clusters - 1) as f64) - 1.2
    };
    // Shared logistic growth shape + cluster level + mild slope variation.
    let rate = 6.0 + cluster as f64;
    let logistic = 1.0 / (1.0 + (-rate * (t - 0.35)).exp());
    offset + 1.6 * logistic - 0.8
}

/// Generate the growth dataset.
pub fn generate(cfg: &GrowthConfig) -> GrowthData {
    let mut rng = Rng::new(cfg.seed);
    let mut x = Vec::new();
    let mut y = Vec::new();
    let mut task_of = Vec::new();
    let mut true_cluster = Vec::with_capacity(cfg.num_children);
    for child in 0..cfg.num_children {
        let c = rng.below(cfg.num_clusters);
        true_cluster.push(c);
        let n_obs = cfg.min_obs + rng.below(cfg.max_obs - cfg.min_obs + 1);
        // Individual variation: smooth random offset + slope.
        let indiv_offset = 0.15 * rng.normal();
        let indiv_slope = 0.2 * rng.normal();
        let indiv_phase = rng.uniform_in(0.0, std::f64::consts::TAU);
        for _ in 0..n_obs {
            let t = rng.uniform_in(0.0, 1.0);
            let mean = cluster_curve(c, cfg.num_clusters, t)
                + indiv_offset
                + indiv_slope * (t - 0.5)
                + 0.05 * (8.0 * t + indiv_phase).sin();
            x.push(t);
            y.push(mean + cfg.noise * rng.normal());
            task_of.push(child);
        }
    }
    GrowthData {
        data: MtgpData { x, y, task_of, num_tasks: cfg.num_children },
        true_cluster,
    }
}

/// Split one child's observations into the first `keep` (by age) for
/// conditioning and the rest for extrapolation evaluation — the Fig. 3/4
/// protocol ("predict future development from limited measurements").
pub fn split_child(
    data: &MtgpData,
    child: usize,
    keep: usize,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut obs: Vec<(f64, f64)> = data
        .x
        .iter()
        .zip(&data.y)
        .zip(&data.task_of)
        .filter(|(_, &t)| t == child)
        .map(|((&x, &y), _)| (x, y))
        .collect();
    obs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let keep = keep.min(obs.len());
    let (head, tail) = obs.split_at(keep);
    (
        head.iter().map(|p| p.0).collect(),
        head.iter().map(|p| p.1).collect(),
        tail.iter().map(|p| p.0).collect(),
        tail.iter().map(|p| p.1).collect(),
    )
}

/// Remove a child's observations entirely (to re-add a truncated version).
pub fn without_child(data: &MtgpData, child: usize) -> MtgpData {
    let mut x = Vec::new();
    let mut y = Vec::new();
    let mut task_of = Vec::new();
    for i in 0..data.len() {
        if data.task_of[i] != child {
            x.push(data.x[i]);
            y.push(data.y[i]);
            task_of.push(data.task_of[i]);
        }
    }
    MtgpData { x, y, task_of, num_tasks: data.num_tasks }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_counts_in_range() {
        let g = generate(&GrowthConfig { num_children: 20, ..Default::default() });
        for child in 0..20 {
            let cnt = g.data.task_of.iter().filter(|&&t| t == child).count();
            assert!((5..=30).contains(&cnt), "child {child}: {cnt} obs");
        }
        assert_eq!(g.true_cluster.len(), 20);
    }

    #[test]
    fn clusters_are_separated() {
        // Mean weight at late age must be ordered by cluster index.
        let v0 = cluster_curve(0, 3, 0.9);
        let v1 = cluster_curve(1, 3, 0.9);
        let v2 = cluster_curve(2, 3, 0.9);
        assert!(v0 < v1 && v1 < v2, "{v0} {v1} {v2}");
        assert!(v2 - v0 > 1.5, "separation {}", v2 - v0);
    }

    #[test]
    fn deterministic() {
        let cfg = GrowthConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.data.y, b.data.y);
        assert_eq!(a.true_cluster, b.true_cluster);
    }

    #[test]
    fn split_child_orders_by_age() {
        let g = generate(&GrowthConfig { num_children: 5, seed: 3, ..Default::default() });
        let (hx, hy, tx, _ty) = split_child(&g.data, 2, 4);
        assert_eq!(hx.len(), 4);
        assert_eq!(hy.len(), 4);
        for w in hx.windows(2) {
            assert!(w[0] <= w[1]);
        }
        if let (Some(&last_head), Some(&first_tail)) = (hx.last(), tx.first()) {
            assert!(last_head <= first_tail);
        }
    }

    #[test]
    fn without_child_removes_only_that_child() {
        let g = generate(&GrowthConfig { num_children: 6, seed: 4, ..Default::default() });
        let reduced = without_child(&g.data, 3);
        assert!(reduced.task_of.iter().all(|&t| t != 3));
        let removed = g.data.task_of.iter().filter(|&&t| t == 3).count();
        assert_eq!(reduced.len(), g.data.len() - removed);
    }
}
