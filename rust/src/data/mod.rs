//! Dataset substrate: synthetic surrogates for every dataset the paper
//! evaluates on (UCI regression suites, the precipitation data, and the
//! Gates childhood-growth data). See DESIGN.md §4 for the substitution
//! rationale.

pub mod growth;
pub mod synthetic;

pub use growth::{generate as generate_growth, GrowthConfig, GrowthData};
pub use synthetic::{
    dataset_by_name, gaussian_cloud, generate, DatasetSpec, RegressionData, DATASETS,
};
