//! Experiment scheduler: runs a queue of named jobs with isolation.
//!
//! The harness registers one job per table/figure; `run_all` executes them
//! sequentially (this testbed exposes a single core), captures panics so
//! one failing experiment cannot take down a sweep, and reports per-job
//! wall time and status.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

/// Outcome of one job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    Ok,
    Failed(String),
    Skipped(String),
}

/// Report for one executed job.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub name: String,
    pub status: JobStatus,
    pub seconds: f64,
}

type JobFn = Box<dyn FnOnce() -> crate::Result<()>>;

/// A queue of experiments.
pub struct Scheduler {
    jobs: Vec<(String, JobFn)>,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler {
    pub fn new() -> Self {
        Scheduler { jobs: Vec::new() }
    }

    /// Register a job.
    pub fn add(&mut self, name: &str, f: impl FnOnce() -> crate::Result<()> + 'static) {
        self.jobs.push((name.to_string(), Box::new(f)));
    }

    /// Number of queued jobs.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Run everything; panics and errors are contained per job.
    pub fn run_all(self) -> Vec<JobReport> {
        let mut reports = Vec::with_capacity(self.jobs.len());
        for (name, job) in self.jobs {
            println!("── running {name} ──");
            let t0 = Instant::now();
            let status = match catch_unwind(AssertUnwindSafe(job)) {
                Ok(Ok(())) => JobStatus::Ok,
                Ok(Err(e)) => JobStatus::Failed(e.to_string()),
                Err(p) => {
                    let msg = p
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "panic".to_string());
                    JobStatus::Failed(format!("panicked: {msg}"))
                }
            };
            let seconds = t0.elapsed().as_secs_f64();
            if let JobStatus::Failed(e) = &status {
                eprintln!("job {name} FAILED: {e}");
            }
            reports.push(JobReport { name, status, seconds });
        }
        reports
    }
}

/// Print a one-line summary per job, plus the aggregate solver effort
/// (CG / block-CG iteration quantiles and convergence failures) recorded
/// by every solve the jobs ran.
pub fn print_summary(reports: &[JobReport]) {
    println!("\n=== experiment summary ===");
    for r in reports {
        let s = match &r.status {
            JobStatus::Ok => "ok".to_string(),
            JobStatus::Failed(e) => format!("FAILED ({e})"),
            JobStatus::Skipped(why) => format!("skipped ({why})"),
        };
        println!("  {:<18} {:>8.2}s  {}", r.name, r.seconds, s);
    }
    let solvers = crate::coordinator::metrics::global().solver_report();
    if !solvers.is_empty() {
        println!("--- solver effort ---");
        print!("{solvers}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_jobs_in_order() {
        use std::sync::{Arc, Mutex};
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut s = Scheduler::new();
        for i in 0..3 {
            let log = log.clone();
            s.add(&format!("job{i}"), move || {
                log.lock().unwrap().push(i);
                Ok(())
            });
        }
        let reports = s.run_all();
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2]);
        assert!(reports.iter().all(|r| r.status == JobStatus::Ok));
    }

    #[test]
    fn contains_panics() {
        let mut s = Scheduler::new();
        s.add("boom", || panic!("kaboom"));
        s.add("after", || Ok(()));
        let reports = s.run_all();
        assert!(matches!(reports[0].status, JobStatus::Failed(_)));
        assert_eq!(reports[1].status, JobStatus::Ok);
    }

    #[test]
    fn propagates_errors_as_failed() {
        let mut s = Scheduler::new();
        s.add("err", || Err(crate::Error::Config("bad".into())));
        let reports = s.run_all();
        match &reports[0].status {
            JobStatus::Failed(e) => assert!(e.contains("bad")),
            other => panic!("unexpected {other:?}"),
        }
    }
}
