//! Experiment coordination: metrics, sessions, and the job scheduler that
//! drives the benchmark harness (Layer 3's orchestration role).

pub mod metrics;
pub mod scheduler;
pub mod session;

pub use metrics::{LatencyHistogram, LatencySnapshot, Metrics};
pub use scheduler::{print_summary, JobReport, JobStatus, Scheduler};
pub use session::Session;
