//! Experiment sessions: named runs that collect metrics and emit CSV.
//!
//! Every harness entry point (`skip-gp bench …`) runs inside a session so
//! results land in `results/<name>.csv` with uniform metadata, and the
//! per-op metrics (MVM counts, CG iterations, timer totals) are printed
//! alongside the paper-style table.

use super::metrics::Metrics;
use crate::error::Result;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// A running experiment.
pub struct Session {
    pub name: String,
    pub out_dir: PathBuf,
    pub metrics: Metrics,
    start: Instant,
    rows: Vec<Vec<String>>,
    header: Option<Vec<String>>,
}

impl Session {
    /// Start a session writing into `out_dir` (created if needed).
    pub fn new(name: &str, out_dir: &Path) -> Result<Self> {
        fs::create_dir_all(out_dir)?;
        Ok(Session {
            name: name.to_string(),
            out_dir: out_dir.to_path_buf(),
            metrics: Metrics::new(),
            start: Instant::now(),
            rows: Vec::new(),
            header: None,
        })
    }

    /// Set the CSV header (once).
    pub fn header(&mut self, cols: &[&str]) {
        assert!(self.header.is_none(), "header already set");
        self.header = Some(cols.iter().map(|s| s.to_string()).collect());
    }

    /// Append a result row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        if let Some(h) = &self.header {
            assert_eq!(cells.len(), h.len(), "row width != header width");
        }
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format mixed cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v);
    }

    /// Elapsed wall-clock seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Write the collected rows as CSV and return the path written.
    ///
    /// Reruns of the same session name land in fresh `<name>_runNN.csv`
    /// files instead of overwriting `<name>.csv`; consumers should use the
    /// returned path rather than reconstructing it. Claiming a path uses
    /// `create_new` (atomic create-if-absent), so even two sessions
    /// finishing concurrently get distinct files.
    pub fn finish(&self) -> Result<PathBuf> {
        for i in 0u32.. {
            let path = if i == 0 {
                self.out_dir.join(format!("{}.csv", self.name))
            } else {
                self.out_dir.join(format!("{}_run{i:02}.csv", self.name))
            };
            let mut f = match fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(f) => f,
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => continue,
                Err(e) => return Err(e.into()),
            };
            if let Some(h) = &self.header {
                writeln!(f, "{}", h.join(","))?;
            }
            for r in &self.rows {
                writeln!(f, "{}", r.join(","))?;
            }
            return Ok(path);
        }
        unreachable!("ran out of run indices")
    }

    /// Pretty-print the collected rows as an aligned table.
    pub fn print_table(&self) {
        let mut widths: Vec<usize> = Vec::new();
        let all: Vec<&Vec<String>> =
            self.header.iter().chain(self.rows.iter()).collect();
        for row in &all {
            for (i, c) in row.iter().enumerate() {
                if widths.len() <= i {
                    widths.push(0);
                }
                widths[i] = widths[i].max(c.len());
            }
        }
        for (ri, row) in all.iter().enumerate() {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("  {}", line.join("  "));
            if ri == 0 && self.header.is_some() {
                let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
                println!("  {}", "-".repeat(total));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("skipgp-session-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn writes_csv() {
        let dir = tmpdir("a");
        let mut s = Session::new("test_exp", &dir).unwrap();
        s.header(&["method", "mae", "time_s"]);
        s.rowf(&[&"skip", &0.07, &1.5]);
        s.rowf(&[&"sgpr", &0.16, &4.2]);
        let path = s.finish().unwrap();
        let text = fs::read_to_string(path).unwrap();
        assert!(text.starts_with("method,mae,time_s\n"));
        assert!(text.contains("skip,0.07,1.5"));
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rerun_does_not_overwrite_earlier_results() {
        let dir = tmpdir("rerun");
        let mut first = Session::new("exp", &dir).unwrap();
        first.header(&["k", "v"]);
        first.rowf(&[&"a", &1]);
        let p1 = first.finish().unwrap();

        let mut second = Session::new("exp", &dir).unwrap();
        second.header(&["k", "v"]);
        second.rowf(&[&"b", &2]);
        let p2 = second.finish().unwrap();
        let p3 = second.finish().unwrap(); // even a double-finish is safe

        assert_ne!(p1, p2);
        assert_ne!(p2, p3);
        assert!(p2.file_name().unwrap().to_str().unwrap().contains("_run01"));
        // The first run's contents survived the rerun.
        let t1 = fs::read_to_string(&p1).unwrap();
        assert!(t1.contains("a,1"), "first run clobbered: {t1}");
        let t2 = fs::read_to_string(&p2).unwrap();
        assert!(t2.contains("b,2"));
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let dir = tmpdir("b");
        let mut s = Session::new("x", &dir).unwrap();
        s.header(&["a", "b"]);
        s.row(&["1".into()]);
    }

    #[test]
    fn metrics_accessible() {
        let dir = tmpdir("c");
        let s = Session::new("m", &dir).unwrap();
        s.metrics.incr("ops", 2);
        assert_eq!(s.metrics.counter("ops"), 2);
        fs::remove_dir_all(dir).ok();
    }
}
