//! Lightweight metrics registry: counters, timers, latency histograms,
//! and small-integer value histograms, keyed by name.
//!
//! The latency histograms back the serving layer's per-request QPS/p50/p99
//! accounting (`crate::serve`): log-bucketed, so recording is O(1) and
//! quantiles are read off the cumulative bucket counts with bounded
//! (±~9%) relative error — plenty for dashboard-grade latency numbers.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Process-wide metrics registry. The iterative solvers (`cg_solve`,
/// `block_cg_solve`) record their iteration counts and convergence
/// failures here — they are called from deep inside operator code with no
/// session handle to thread through — and session summaries read the
/// solver histograms back out ([`Metrics::solver_report`]).
pub fn global() -> &'static Metrics {
    static GLOBAL: OnceLock<Metrics> = OnceLock::new();
    GLOBAL.get_or_init(Metrics::new)
}

/// Record one solver run into the [`global`] registry: iteration count
/// into the `solver.<name>.iters` value histogram, plus a
/// `solver.<name>.fail` counter when the solve did not converge.
pub fn record_solver(name: &str, iters: usize, converged: bool) {
    let g = global();
    g.observe(&format!("solver.{name}.iters"), iters as u64);
    if !converged {
        g.incr(&format!("solver.{name}.fail"), 1);
    }
}

/// Aggregated timer statistics.
#[derive(Clone, Debug, Default)]
pub struct TimerStats {
    pub count: usize,
    pub total_s: f64,
    pub max_s: f64,
}

/// Number of log-spaced latency buckets (4 per octave from 1 µs).
const LAT_BUCKETS: usize = 128;
/// Lower edge of bucket 0, seconds.
const LAT_MIN_S: f64 = 1e-6;

/// Log-bucketed latency histogram (4 buckets per power of two starting at
/// 1 µs, so bucket edges grow by 2^(1/4) ≈ 1.19×).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    total_s: f64,
    max_s: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: vec![0; LAT_BUCKETS],
            count: 0,
            total_s: 0.0,
            max_s: 0.0,
        }
    }
}

impl LatencyHistogram {
    fn bucket_index(seconds: f64) -> usize {
        if seconds <= LAT_MIN_S {
            return 0;
        }
        let i = ((seconds / LAT_MIN_S).log2() * 4.0).floor() as isize;
        i.clamp(0, LAT_BUCKETS as isize - 1) as usize
    }

    /// Geometric midpoint of bucket `i` (its representative latency).
    fn bucket_value(i: usize) -> f64 {
        LAT_MIN_S * 2f64.powf((i as f64 + 0.5) / 4.0)
    }

    pub fn record(&mut self, seconds: f64) {
        self.buckets[Self::bucket_index(seconds)] += 1;
        self.count += 1;
        self.total_s += seconds;
        self.max_s = self.max_s.max(seconds);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Approximate quantile `q ∈ [0, 1]` (0 when empty).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_value(i).min(self.max_s);
            }
        }
        self.max_s
    }

    /// Immutable summary for reporting.
    pub fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            count: self.count,
            mean_s: if self.count == 0 { 0.0 } else { self.total_s / self.count as f64 },
            p50_s: self.quantile(0.50),
            p90_s: self.quantile(0.90),
            p99_s: self.quantile(0.99),
            max_s: self.max_s,
        }
    }
}

/// Point-in-time latency summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    timers: Mutex<BTreeMap<String, TimerStats>>,
    latencies: Mutex<BTreeMap<String, LatencyHistogram>>,
    values: Mutex<BTreeMap<String, BTreeMap<u64, u64>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str, by: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record a duration under `name`.
    pub fn record(&self, name: &str, seconds: f64) {
        let mut t = self.timers.lock().unwrap();
        let e = t.entry(name.to_string()).or_default();
        e.count += 1;
        e.total_s += seconds;
        e.max_s = e.max_s.max(seconds);
    }

    /// Time a closure under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Record one latency observation (seconds) under `name`.
    pub fn record_latency(&self, name: &str, seconds: f64) {
        let mut l = self.latencies.lock().unwrap();
        l.entry(name.to_string()).or_default().record(seconds);
    }

    /// Record a batch of latency observations under one lock acquisition
    /// (the request batcher records a whole batch's latencies at once).
    pub fn record_latency_many(&self, name: &str, seconds: &[f64]) {
        if seconds.is_empty() {
            return;
        }
        let mut l = self.latencies.lock().unwrap();
        let h = l.entry(name.to_string()).or_default();
        for &s in seconds {
            h.record(s);
        }
    }

    /// Latency summary for `name` (zeros when never recorded).
    pub fn latency_snapshot(&self, name: &str) -> LatencySnapshot {
        self.latencies
            .lock()
            .unwrap()
            .get(name)
            .map(|h| h.snapshot())
            .unwrap_or_default()
    }

    /// Record an integer observation (e.g. a batch size) under `name`.
    pub fn observe(&self, name: &str, value: u64) {
        let mut v = self.values.lock().unwrap();
        *v.entry(name.to_string()).or_default().entry(value).or_insert(0) += 1;
    }

    /// Exact value → count histogram for `name` (empty when never seen).
    pub fn value_histogram(&self, name: &str) -> BTreeMap<u64, u64> {
        self.values
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    /// Quantile `q ∈ [0, 1]` of the integer observations under `name`
    /// (exact — the value histograms store every distinct value; 0 when
    /// never recorded).
    pub fn value_quantile(&self, name: &str, q: f64) -> u64 {
        let values = self.values.lock().unwrap();
        let Some(hist) = values.get(name) else {
            return 0;
        };
        let total: u64 = hist.values().sum();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (v, c) in hist.iter() {
            cum += c;
            if cum >= target {
                return *v;
            }
        }
        *hist.keys().next_back().unwrap()
    }

    /// One line per solver with recorded runs: count, p50/p99 iterations,
    /// convergence failures — plus, when preconditioning/warm starts were
    /// active, their accounting (setup MVMs spent, seeds given, seeds that
    /// converged with zero iterations). Empty string when no solver ever
    /// ran — the session summary printer skips it then.
    ///
    /// Reading these lines (and what to do when p99 is high) is covered
    /// in `docs/SOLVERS.md`.
    pub fn solver_report(&self) -> String {
        let mut out = String::new();
        let names: Vec<String> = {
            let values = self.values.lock().unwrap();
            values
                .keys()
                .filter_map(|k| {
                    k.strip_prefix("solver.")?
                        .strip_suffix(".iters")
                        .map(|s| s.to_string())
                })
                .collect()
        };
        for name in names {
            let iters_key = format!("solver.{name}.iters");
            let total: u64 = self.value_histogram(&iters_key).values().sum();
            out.push_str(&format!(
                "  solver {name:<9} {total:>8} solves  iters p50={} p99={}  failures={}\n",
                self.value_quantile(&iters_key, 0.50),
                self.value_quantile(&iters_key, 0.99),
                self.counter(&format!("solver.{name}.fail")),
            ));
        }
        let setup: u64 = self
            .value_histogram("solver.precond.setup_matvecs")
            .iter()
            .map(|(v, c)| v * c)
            .sum();
        let fallbacks = self.counter("solver.precond.fallback");
        // Fallbacks alone (e.g. Jacobi degrading to identity, which costs
        // no setup MVMs) must still surface — they mean the requested
        // preconditioner never took effect.
        if setup > 0 || fallbacks > 0 {
            out.push_str(&format!(
                "  precond   setup mvms={setup} (fallbacks={fallbacks})\n"
            ));
        }
        let seeded = self.counter("solver.warm.seeded");
        if seeded > 0 {
            out.push_str(&format!(
                "  warm      {seeded} solves seeded, {} converged at the seed\n",
                self.counter("solver.warm.hit")
            ));
        }
        // Solve-space routing: which engine the y-solves actually ran in
        // (grid-space normal equations vs data-space CG), plus Auto-mode
        // fallbacks to data space (over-budget gram, non-converged cold
        // grid solve). Only printed once a space was ever chosen.
        let grid = self.counter("solver.space.grid");
        let data = self.counter("solver.space.data");
        let space_fallbacks = self.counter("solver.space.fallback");
        if grid > 0 || data > 0 || space_fallbacks > 0 {
            out.push_str(&format!(
                "  space     grid={grid} data={data} solves (auto fallbacks={space_fallbacks})\n"
            ));
        }
        // Mixed-precision refinement accounting: outer correction sweeps
        // plus every road back to full f64 (no f32 operator mirror, a
        // stalled inner solve, an exhausted sweep budget). Only printed
        // once the refinement wrapper ever ran or fell back — the
        // `solver refine` iteration line above comes from the shared
        // `record_solver` path.
        let sweeps = self.counter("solver.refine.sweeps");
        let refine_fallbacks = self.counter("solver.refine.fallback.no_f32")
            + self.counter("solver.refine.fallback.stall")
            + self.counter("solver.refine.fallback.sweep_budget");
        if sweeps > 0 || refine_fallbacks > 0 {
            out.push_str(&format!(
                "  refine    {sweeps} f64 correction sweeps, f64 fallbacks={refine_fallbacks} \
                 (no-f32={} stall={} budget={})\n",
                self.counter("solver.refine.fallback.no_f32"),
                self.counter("solver.refine.fallback.stall"),
                self.counter("solver.refine.fallback.sweep_budget"),
            ));
        }
        out
    }

    /// Streaming-ingestion summary from the `stream.*` keys the serving
    /// engine records (ingest p50/p99, warm-start iteration savings,
    /// cache patch-vs-rebuild counts). Empty string when nothing was
    /// ever ingested — callers skip printing it then.
    pub fn stream_report(&self) -> String {
        let points = self.counter("stream.points");
        let duplicates = self.counter("stream.duplicates");
        if points == 0 && duplicates == 0 {
            return String::new();
        }
        let ingest = self.latency_snapshot("stream.ingest");
        let mut out = format!(
            "  stream    {points} points ingested ({duplicates} duplicates dropped) \
             p50={:.1}µs p99={:.1}µs\n",
            ingest.p50_s * 1e6,
            ingest.p99_s * 1e6
        );
        out.push_str(&format!(
            "  ingest    α-solve iters p50={} p99={}, warm start saved p50={} iters\n",
            self.value_quantile("stream.solve.iters", 0.50),
            self.value_quantile("stream.solve.iters", 0.99),
            self.value_quantile("stream.solve.iters_saved", 0.50),
        ));
        out.push_str(&format!(
            "  caches    {} mean patches ({} rows scattered), {} variance rebuilds, \
             {} full refreshes\n",
            self.counter("stream.cache.mean_patches"),
            self.counter("stream.cache.rows_patched"),
            self.counter("stream.cache.var_rebuilds"),
            self.counter("stream.refreshes"),
        ));
        out
    }

    /// Serving-fleet summary from the `serve.fleet.*` keys the reactor
    /// and model registry record (routed/rejected requests, in-flight and
    /// shard-queue-depth p99s, registry hit/eviction accounting,
    /// connection admission). Empty string when the fleet never served —
    /// callers skip printing it then.
    pub fn fleet_report(&self) -> String {
        let routed = self.counter("serve.fleet.requests");
        let rejected = self.counter("serve.fleet.rejected");
        let loads = self.counter("serve.fleet.loads");
        if routed == 0 && rejected == 0 && loads == 0 {
            return String::new();
        }
        let mut out = format!(
            "  fleet     {routed} requests routed ({rejected} rejected busy), \
             inflight p99={} shard queue depth p99={}\n",
            self.value_quantile("serve.fleet.inflight", 0.99),
            self.value_quantile("serve.fleet.queue_depth", 0.99),
        );
        out.push_str(&format!(
            "  registry  {} hits {} misses, {loads} loads, {} evictions, \
             resident p99={} models\n",
            self.counter("serve.fleet.hits"),
            self.counter("serve.fleet.misses"),
            self.counter("serve.fleet.evictions"),
            self.value_quantile("serve.fleet.resident_models", 0.99),
        ));
        let conns = self.counter("serve.fleet.conns");
        let conns_rejected = self.counter("serve.fleet.conns_rejected");
        if conns > 0 || conns_rejected > 0 {
            out.push_str(&format!(
                "  conns     {conns} accepted, {conns_rejected} rejected at \
                 capacity, {} closed\n",
                self.counter("serve.fleet.conns_closed"),
            ));
        }
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    pub fn timer(&self, name: &str) -> TimerStats {
        self.timers
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    /// Human-readable dump, sorted by name.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in self.timers.lock().unwrap().iter() {
            out.push_str(&format!(
                "timer   {k}: n={} total={:.3}s mean={:.4}s max={:.4}s\n",
                v.count,
                v.total_s,
                v.total_s / v.count.max(1) as f64,
                v.max_s
            ));
        }
        for (k, h) in self.latencies.lock().unwrap().iter() {
            let s = h.snapshot();
            out.push_str(&format!(
                "latency {k}: n={} p50={:.1}µs p99={:.1}µs max={:.1}µs\n",
                s.count,
                s.p50_s * 1e6,
                s.p99_s * 1e6,
                s.max_s * 1e6
            ));
        }
        for (k, hist) in self.values.lock().unwrap().iter() {
            let cells: Vec<String> =
                hist.iter().map(|(v, c)| format!("{v}:{c}")).collect();
            out.push_str(&format!("values  {k}: {}\n", cells.join(" ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("mvm", 3);
        m.incr("mvm", 2);
        assert_eq!(m.counter("mvm"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_aggregate() {
        let m = Metrics::new();
        m.record("cg", 0.5);
        m.record("cg", 1.5);
        let t = m.timer("cg");
        assert_eq!(t.count, 2);
        assert!((t.total_s - 2.0).abs() < 1e-12);
        assert!((t.max_s - 1.5).abs() < 1e-12);
    }

    #[test]
    fn time_returns_value() {
        let m = Metrics::new();
        let v = m.time("op", || 7);
        assert_eq!(v, 7);
        assert_eq!(m.timer("op").count, 1);
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.record("b", 0.1);
        m.record_latency("c", 1e-4);
        m.observe("d", 8);
        let r = m.report();
        assert!(r.contains("counter a = 1"));
        assert!(r.contains("timer   b"));
        assert!(r.contains("latency c"));
        assert!(r.contains("values  d: 8:1"));
    }

    #[test]
    fn latency_quantiles_bracket_observations() {
        let mut h = LatencyHistogram::default();
        // 99 fast (10 µs) + 1 slow (10 ms) observation.
        for _ in 0..99 {
            h.record(10e-6);
        }
        h.record(10e-3);
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        assert!(p50 > 5e-6 && p50 < 20e-6, "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 < 20e-6, "p99 covers the fast mass: {p99}");
        let p999 = h.quantile(0.9999);
        assert!(p999 > 5e-3, "tail quantile sees the slow outlier: {p999}");
        let s = h.snapshot();
        assert!((s.max_s - 10e-3).abs() < 1e-12);
        assert!(s.mean_s > 10e-6 && s.mean_s < 10e-3);
    }

    #[test]
    fn latency_histogram_edge_cases() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.quantile(0.5), 0.0);
        h.record(0.0); // below the first bucket edge
        h.record(1e9); // far above the last bucket edge
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) <= 1e9);
    }

    #[test]
    fn batched_latency_recording_matches_loop() {
        let m = Metrics::new();
        m.record_latency_many("x", &[1e-5, 2e-5, 3e-5]);
        m.record_latency_many("x", &[]);
        assert_eq!(m.latency_snapshot("x").count, 3);
        assert_eq!(m.latency_snapshot("missing").count, 0);
    }

    #[test]
    fn value_quantiles_are_exact() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.observe("it", i);
        }
        assert_eq!(m.value_quantile("it", 0.50), 50);
        assert_eq!(m.value_quantile("it", 0.99), 99);
        assert_eq!(m.value_quantile("it", 1.0), 100);
        assert_eq!(m.value_quantile("missing", 0.5), 0);
    }

    #[test]
    fn solver_report_lists_recorded_solvers() {
        let m = Metrics::new();
        assert!(m.solver_report().is_empty());
        m.observe("solver.cg.iters", 12);
        m.observe("solver.cg.iters", 40);
        m.incr("solver.cg.fail", 1);
        let r = m.solver_report();
        assert!(r.contains("solver cg"), "{r}");
        assert!(r.contains("p99=40"), "{r}");
        assert!(r.contains("failures=1"), "{r}");
    }

    #[test]
    fn solver_report_includes_precond_and_warm_lines() {
        let m = Metrics::new();
        m.observe("solver.pcg.iters", 5);
        m.observe("solver.precond.setup_matvecs", 50);
        m.incr("solver.warm.seeded", 3);
        m.incr("solver.warm.hit", 2);
        let r = m.solver_report();
        assert!(r.contains("solver pcg"), "{r}");
        assert!(r.contains("setup mvms=50"), "{r}");
        assert!(r.contains("3 solves seeded"), "{r}");
        assert!(r.contains("2 converged at the seed"), "{r}");
    }

    #[test]
    fn solver_report_includes_space_line() {
        let m = Metrics::new();
        m.observe("solver.gridcg.iters", 11);
        m.incr("solver.space.grid", 5);
        m.incr("solver.space.data", 2);
        m.incr("solver.space.fallback", 1);
        let r = m.solver_report();
        assert!(r.contains("solver gridcg"), "{r}");
        assert!(r.contains("grid=5 data=2"), "{r}");
        assert!(r.contains("fallbacks=1"), "{r}");
    }

    #[test]
    fn solver_report_includes_refine_line() {
        let m = Metrics::new();
        m.observe("solver.refine.iters", 18);
        m.incr("solver.refine.sweeps", 6);
        m.incr("solver.refine.fallback.stall", 1);
        m.incr("solver.refine.fallback.sweep_budget", 2);
        let r = m.solver_report();
        assert!(r.contains("solver refine"), "{r}");
        assert!(r.contains("6 f64 correction sweeps"), "{r}");
        assert!(r.contains("f64 fallbacks=3"), "{r}");
        assert!(r.contains("no-f32=0 stall=1 budget=2"), "{r}");
    }

    #[test]
    fn stream_report_summarizes_ingest_counters() {
        let m = Metrics::new();
        assert!(m.stream_report().is_empty());
        m.incr("stream.points", 64);
        m.incr("stream.duplicates", 2);
        m.record_latency("stream.ingest", 250e-6);
        m.observe("stream.solve.iters", 4);
        m.observe("stream.solve.iters_saved", 38);
        m.incr("stream.cache.mean_patches", 64);
        m.incr("stream.cache.var_rebuilds", 3);
        m.incr("stream.refreshes", 1);
        let r = m.stream_report();
        assert!(r.contains("64 points ingested"), "{r}");
        assert!(r.contains("2 duplicates"), "{r}");
        assert!(r.contains("saved p50=38"), "{r}");
        assert!(r.contains("3 variance rebuilds"), "{r}");
        assert!(r.contains("1 full refreshes"), "{r}");
    }

    #[test]
    fn fleet_report_summarizes_fleet_counters() {
        let m = Metrics::new();
        assert!(m.fleet_report().is_empty());
        m.incr("serve.fleet.requests", 120);
        m.incr("serve.fleet.rejected", 4);
        m.observe("serve.fleet.inflight", 3);
        m.observe("serve.fleet.inflight", 7);
        m.observe("serve.fleet.queue_depth", 2);
        m.incr("serve.fleet.hits", 110);
        m.incr("serve.fleet.misses", 10);
        m.incr("serve.fleet.loads", 10);
        m.incr("serve.fleet.evictions", 6);
        m.observe("serve.fleet.resident_models", 4);
        m.incr("serve.fleet.conns", 40);
        m.incr("serve.fleet.conns_rejected", 2);
        m.incr("serve.fleet.conns_closed", 38);
        let r = m.fleet_report();
        assert!(r.contains("120 requests routed"), "{r}");
        assert!(r.contains("4 rejected busy"), "{r}");
        assert!(r.contains("inflight p99=7"), "{r}");
        assert!(r.contains("queue depth p99=2"), "{r}");
        assert!(r.contains("110 hits 10 misses"), "{r}");
        assert!(r.contains("6 evictions"), "{r}");
        assert!(r.contains("40 accepted, 2 rejected"), "{r}");
    }

    #[test]
    fn global_record_solver_accumulates() {
        super::record_solver("unit_test_solver", 7, false);
        super::record_solver("unit_test_solver", 9, true);
        let g = super::global();
        let h = g.value_histogram("solver.unit_test_solver.iters");
        assert!(h.get(&7).copied().unwrap_or(0) >= 1);
        assert!(g.counter("solver.unit_test_solver.fail") >= 1);
    }

    #[test]
    fn value_histogram_counts() {
        let m = Metrics::new();
        m.observe("batch", 1);
        m.observe("batch", 64);
        m.observe("batch", 64);
        let h = m.value_histogram("batch");
        assert_eq!(h.get(&1), Some(&1));
        assert_eq!(h.get(&64), Some(&2));
        assert!(m.value_histogram("missing").is_empty());
    }
}
