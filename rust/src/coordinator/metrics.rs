//! Lightweight metrics registry: counters and timers keyed by name.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Aggregated timer statistics.
#[derive(Clone, Debug, Default)]
pub struct TimerStats {
    pub count: usize,
    pub total_s: f64,
    pub max_s: f64,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    timers: Mutex<BTreeMap<String, TimerStats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str, by: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record a duration under `name`.
    pub fn record(&self, name: &str, seconds: f64) {
        let mut t = self.timers.lock().unwrap();
        let e = t.entry(name.to_string()).or_default();
        e.count += 1;
        e.total_s += seconds;
        e.max_s = e.max_s.max(seconds);
    }

    /// Time a closure under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    pub fn timer(&self, name: &str) -> TimerStats {
        self.timers
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    /// Human-readable dump, sorted by name.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in self.timers.lock().unwrap().iter() {
            out.push_str(&format!(
                "timer   {k}: n={} total={:.3}s mean={:.4}s max={:.4}s\n",
                v.count,
                v.total_s,
                v.total_s / v.count.max(1) as f64,
                v.max_s
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("mvm", 3);
        m.incr("mvm", 2);
        assert_eq!(m.counter("mvm"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_aggregate() {
        let m = Metrics::new();
        m.record("cg", 0.5);
        m.record("cg", 1.5);
        let t = m.timer("cg");
        assert_eq!(t.count, 2);
        assert!((t.total_s - 2.0).abs() < 1e-12);
        assert!((t.max_s - 1.5).abs() < 1e-12);
    }

    #[test]
    fn time_returns_value() {
        let m = Metrics::new();
        let v = m.time("op", || 7);
        assert_eq!(v, 7);
        assert_eq!(m.timer("op").count, 1);
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.record("b", 0.1);
        let r = m.report();
        assert!(r.contains("counter a = 1"));
        assert!(r.contains("timer   b"));
    }
}
