//! Library-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: no derive-macro crates are
//! available in the offline build environment.

use std::fmt;

/// Errors produced by skip-gp.
#[derive(Debug)]
pub enum Error {
    /// Cholesky hit a non-positive pivot.
    NotPositiveDefinite { pivot: usize, value: f64 },

    /// Tridiagonal eigensolver failed to converge.
    EigFailed { index: usize },

    /// CG failed to reach tolerance.
    CgDidNotConverge { iters: usize, residual: f64 },

    /// Shape mismatch in an operator composition.
    DimMismatch { context: &'static str, expected: usize, got: usize },

    /// Inducing-grid construction problems (degenerate data bounds, too
    /// few points for the stencil, infeasible dense tensor grids).
    Grid(String),

    /// Runtime artifact problems (missing/corrupt AOT artifact).
    Artifact(String),

    /// Model snapshot problems (bad magic/version/checksum, missing
    /// predictive caches, serving-grid budget exceeded).
    Snapshot(String),

    /// Streaming-ingestion problems (non-finite observations, a model
    /// family that cannot be updated online, a stalled incremental
    /// solve).
    Stream(String),

    /// PJRT/XLA runtime failure (or the `xla` feature is not compiled in).
    Xla(String),

    /// Serving-fleet problems (unknown model id, registry budget
    /// impossible to satisfy, shard/live conflicts, reactor overload).
    Fleet(String),

    /// I/O error.
    Io(std::io::Error),

    /// Configuration / CLI errors.
    Config(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::NotPositiveDefinite { pivot, value } => write!(
                f,
                "matrix not positive definite at pivot {pivot} (value {value})"
            ),
            Error::EigFailed { index } => write!(
                f,
                "tridiagonal eigensolver failed to converge at index {index}"
            ),
            Error::CgDidNotConverge { iters, residual } => write!(
                f,
                "conjugate gradients did not converge: residual {residual} after {iters} iterations"
            ),
            Error::DimMismatch { context, expected, got } => write!(
                f,
                "dimension mismatch: {context} (expected {expected}, got {got})"
            ),
            Error::Grid(msg) => write!(f, "grid error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Snapshot(msg) => write!(f, "snapshot error: {msg}"),
            Error::Stream(msg) => write!(f, "stream error: {msg}"),
            Error::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            Error::Fleet(msg) => write!(f, "fleet error: {msg}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = Error::NotPositiveDefinite { pivot: 3, value: -0.5 };
        assert!(e.to_string().contains("pivot 3"));
        let e = Error::Config("bad flag".into());
        assert_eq!(e.to_string(), "config error: bad flag");
    }

    #[test]
    fn io_error_converts_and_chains() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
