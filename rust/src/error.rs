//! Library-wide error type.

use thiserror::Error;

/// Errors produced by skip-gp.
#[derive(Error, Debug)]
pub enum Error {
    /// Cholesky hit a non-positive pivot.
    #[error("matrix not positive definite at pivot {pivot} (value {value})")]
    NotPositiveDefinite { pivot: usize, value: f64 },

    /// Tridiagonal eigensolver failed to converge.
    #[error("tridiagonal eigensolver failed to converge at index {index}")]
    EigFailed { index: usize },

    /// CG failed to reach tolerance.
    #[error("conjugate gradients did not converge: residual {residual} after {iters} iterations")]
    CgDidNotConverge { iters: usize, residual: f64 },

    /// Shape mismatch in an operator composition.
    #[error("dimension mismatch: {context} (expected {expected}, got {got})")]
    DimMismatch { context: &'static str, expected: usize, got: usize },

    /// Runtime artifact problems (missing/corrupt AOT artifact).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT/XLA runtime failure.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Configuration / CLI errors.
    #[error("config error: {0}")]
    Config(String),
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;
