//! Dense and structured linear-algebra substrate.
//!
//! Everything here is built from scratch (no external LA crates are
//! available offline): dense matrices, Cholesky, radix-2 FFT, symmetric
//! Toeplitz fast MVMs, and a symmetric tridiagonal eigensolver.

pub mod chol;
pub mod fft;
pub mod matrix;
pub mod toeplitz;
pub mod tridiag;

pub use chol::Cholesky;
pub use matrix::{axpy, dot, norm2, scale_in_place, Matrix};
pub use toeplitz::SymToeplitz;
pub use tridiag::{tridiag_eig, TridiagEig};
