//! Symmetric Toeplitz matrices with O(m log m) MVMs via circulant embedding.
//!
//! In SKI (paper §2.3) a 1-D regular grid of inducing points makes
//! `K_UU` symmetric Toeplitz: entry (i,j) depends only on |i−j|. Embedding
//! the first column into a circulant of power-of-two size N ≥ 2m−1 lets the
//! FFT diagonalize the action, so `K_UU v` costs two FFTs.

use super::fft::{circ_mul, circ_mul_pair, fft_real, next_pow2, C};
use super::matrix::Matrix;
use crate::util::parallel::par_map_range;

/// Symmetric Toeplitz matrix represented by its first column, with the
/// eigen-spectrum of its circulant embedding precomputed.
#[derive(Clone, Debug)]
pub struct SymToeplitz {
    /// First column `t[0..m]`; entry (i,j) = t[|i-j|].
    pub col: Vec<f64>,
    /// FFT of the circulant embedding's first column.
    c_hat: Vec<C>,
}

impl SymToeplitz {
    /// Build from the first column.
    pub fn new(col: Vec<f64>) -> Self {
        let m = col.len();
        assert!(m > 0);
        // Circulant first column: [t0, t1, …, t_{m-1}, 0…0, t_{m-1}, …, t1]
        // of any length N ≥ 2m−1; choose next power of two for radix-2 FFT.
        let n = next_pow2((2 * m).saturating_sub(1).max(1));
        let mut c = vec![0.0; n];
        c[..m].copy_from_slice(&col);
        for k in 1..m {
            c[n - k] = col[k];
        }
        let c_hat = fft_real(&c, n);
        SymToeplitz { col, c_hat }
    }

    /// Matrix dimension m.
    pub fn dim(&self) -> usize {
        self.col.len()
    }

    /// `K v` in O(m log m) via the circulant embedding.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let m = self.dim();
        assert_eq!(v.len(), m);
        circ_mul(&self.c_hat, v, m)
    }

    /// `K M` for an m×t block in O(t·m log m), batched two columns per
    /// complex FFT (`circ_mul_pair`) and parallel across column pairs.
    ///
    /// This is the grid-level fast path of the batched MVM engine: a SKI
    /// `matmat` funnels all t right-hand sides through here so the
    /// circulant spectrum `c_hat` is read once per pair instead of once
    /// per column.
    pub fn matmat(&self, m: &Matrix) -> Matrix {
        let dim = self.dim();
        assert_eq!(m.rows, dim);
        let t = m.cols;
        let mut out = Matrix::zeros(dim, t);
        // Process columns in pairs: ~2 FFTs per pair instead of 4. Thread
        // fan-out only pays off when each pair's FFT work is substantial,
        // so gate it on the embedding size (small grids stay serial — this
        // runs inside CG-iteration hot loops).
        let pairs = t / 2;
        let min_pairs = ((1usize << 15) / self.c_hat.len().max(1)).max(2);
        let results = par_map_range(pairs, min_pairs, |p| {
            let (j1, j2) = (2 * p, 2 * p + 1);
            circ_mul_pair(&self.c_hat, &m.col(j1), &m.col(j2), dim)
        });
        for (p, (c1, c2)) in results.into_iter().enumerate() {
            out.set_col(2 * p, &c1);
            out.set_col(2 * p + 1, &c2);
        }
        if t % 2 == 1 {
            out.set_col(t - 1, &self.matvec(&m.col(t - 1)));
        }
        out
    }

    /// Dense materialization (tests / tiny problems only).
    pub fn to_dense(&self) -> Matrix {
        let m = self.dim();
        Matrix::from_fn(m, m, |i, j| self.col[i.abs_diff(j)])
    }

    /// Naive O(m²) MVM (oracle for tests).
    pub fn matvec_naive(&self, v: &[f64]) -> Vec<f64> {
        let m = self.dim();
        (0..m)
            .map(|i| (0..m).map(|j| self.col[i.abs_diff(j)] * v[j]).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fft_mvm_matches_naive() {
        let mut rng = Rng::new(10);
        for m in [1usize, 2, 3, 7, 16, 33, 100] {
            let col: Vec<f64> = (0..m).map(|k| (-(k as f64) * 0.1).exp()).collect();
            let t = SymToeplitz::new(col);
            let v = rng.normal_vec(m);
            let fast = t.matvec(&v);
            let slow = t.matvec_naive(&v);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-9, "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn matches_dense_matvec() {
        let col = vec![2.0, 1.0, 0.5, 0.25];
        let t = SymToeplitz::new(col);
        let v = [1.0, -1.0, 2.0, 0.0];
        let dense = t.to_dense().matvec(&v);
        let fast = t.matvec(&v);
        for (a, b) in fast.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn matmat_matches_per_column_matvec() {
        let mut rng = Rng::new(11);
        for m in [3usize, 16, 65] {
            let col: Vec<f64> = (0..m).map(|k| 1.0 / (1.0 + k as f64)).collect();
            let t = SymToeplitz::new(col);
            for cols in [1usize, 2, 5, 8] {
                let b = Matrix::from_fn(m, cols, |_, _| rng.normal());
                let got = t.matmat(&b);
                for j in 0..cols {
                    let want = t.matvec(&b.col(j));
                    let gcol = got.col(j);
                    for (a, w) in gcol.iter().zip(&want) {
                        assert!((a - w).abs() < 1e-9, "m={m} cols={cols} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn identity_toeplitz() {
        let mut col = vec![0.0; 8];
        col[0] = 1.0;
        let t = SymToeplitz::new(col);
        let v: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let out = t.matvec(&v);
        for (a, b) in out.iter().zip(&v) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
