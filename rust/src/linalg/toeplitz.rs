//! Symmetric Toeplitz matrices with O(m log m) MVMs via circulant embedding.
//!
//! In SKI (paper §2.3) a 1-D regular grid of inducing points makes
//! `K_UU` symmetric Toeplitz: entry (i,j) depends only on |i−j|. Embedding
//! the first column into a circulant of power-of-two size N ≥ 2m−1 lets the
//! FFT diagonalize the action, so `K_UU v` costs two FFTs.
//!
//! The FFTs run through a shared, cached [`FftPlan`] (bit-reversal and
//! twiddle tables precomputed once per length — bitwise identical to the
//! direct transform), and every apply reuses a per-instance scratch
//! buffer, so steady-state `matvec`/[`SymToeplitz::matvec_into`] allocate
//! nothing. A lazily-built f32 spectrum mirror backs the mixed-precision
//! path ([`SymToeplitz::matvec_f32`], consumed by `solvers::refine`).

use super::fft::{fft_real, next_pow2, C, C32, FftPlan};
use super::matrix::Matrix;
use crate::util::parallel::par_map_range;
use std::sync::{Arc, Mutex, OnceLock};

/// Symmetric Toeplitz matrix represented by its first column, with the
/// eigen-spectrum of its circulant embedding precomputed.
#[derive(Debug)]
pub struct SymToeplitz {
    /// First column `t[0..m]`; entry (i,j) = t[|i-j|].
    pub col: Vec<f64>,
    /// FFT of the circulant embedding's first column.
    c_hat: Vec<C>,
    /// Shared FFT plan for the embedding length (`c_hat.len()`).
    plan: Arc<FftPlan>,
    /// Reusable complex work buffer for the apply hot path. `try_lock`
    /// with an allocate-on-contention fallback, so concurrent column
    /// applies (the parallel `matmat`) stay correct without serializing.
    scratch: Mutex<Vec<C>>,
    /// Lazily-converted f32 spectrum for the mixed-precision path.
    spec32: OnceLock<Vec<C32>>,
    scratch32: Mutex<Vec<C32>>,
}

impl Clone for SymToeplitz {
    fn clone(&self) -> Self {
        SymToeplitz {
            col: self.col.clone(),
            c_hat: self.c_hat.clone(),
            plan: Arc::clone(&self.plan),
            scratch: Mutex::new(Vec::new()),
            spec32: self.spec32.clone(),
            scratch32: Mutex::new(Vec::new()),
        }
    }
}

impl SymToeplitz {
    /// Build from the first column.
    pub fn new(col: Vec<f64>) -> Self {
        let m = col.len();
        assert!(m > 0);
        // Circulant first column: [t0, t1, …, t_{m-1}, 0…0, t_{m-1}, …, t1]
        // of any length N ≥ 2m−1; choose next power of two for radix-2 FFT.
        let n = next_pow2((2 * m).saturating_sub(1).max(1));
        let mut c = vec![0.0; n];
        c[..m].copy_from_slice(&col);
        for k in 1..m {
            c[n - k] = col[k];
        }
        let c_hat = fft_real(&c, n);
        let plan = FftPlan::shared(n);
        SymToeplitz {
            col,
            c_hat,
            plan,
            scratch: Mutex::new(Vec::new()),
            spec32: OnceLock::new(),
            scratch32: Mutex::new(Vec::new()),
        }
    }

    /// Matrix dimension m.
    pub fn dim(&self) -> usize {
        self.col.len()
    }

    /// The f32 circulant spectrum, converted from `c_hat` on first use.
    fn spec32(&self) -> &[C32] {
        self.spec32.get_or_init(|| {
            self.c_hat
                .iter()
                .map(|&(re, im)| (re as f32, im as f32))
                .collect()
        })
    }

    /// `K v` in O(m log m) via the circulant embedding. Allocates only
    /// the output; the FFT work buffer is reused across calls.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.matvec_into(v, &mut out);
        out
    }

    /// `K v` written into `out` (length m) with zero steady-state
    /// allocation: the complex work buffer is the cached scratch when
    /// uncontended, a transient local one otherwise.
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        let m = self.dim();
        assert_eq!(v.len(), m);
        assert_eq!(out.len(), m);
        let mut local = Vec::new();
        let mut guard = self.scratch.try_lock().ok();
        let buf: &mut Vec<C> = match guard.as_deref_mut() {
            Some(b) => b,
            None => &mut local,
        };
        buf.clear();
        buf.extend(v.iter().map(|&x| (x, 0.0)));
        buf.resize(self.c_hat.len(), (0.0, 0.0));
        self.plan.process(buf, false);
        for (b, &a) in buf.iter_mut().zip(&self.c_hat) {
            let re = b.0 * a.0 - b.1 * a.1;
            let im = b.0 * a.1 + b.1 * a.0;
            *b = (re, im);
        }
        self.plan.inverse_norm(buf);
        for (o, c) in out.iter_mut().zip(buf.iter()) {
            *o = c.0;
        }
    }

    /// `K v` in f32 storage and arithmetic: the f32 spectrum mirror and
    /// f32 twiddles halve the operand bytes of this bandwidth-bound
    /// transform. Accuracy is f32-level — callers wrap it in the f64
    /// iterative-refinement loop (`solvers::refine`).
    pub fn matvec_f32(&self, v: &[f32]) -> Vec<f32> {
        let m = self.dim();
        assert_eq!(v.len(), m);
        let spec = self.spec32();
        let mut local = Vec::new();
        let mut guard = self.scratch32.try_lock().ok();
        let buf: &mut Vec<C32> = match guard.as_deref_mut() {
            Some(b) => b,
            None => &mut local,
        };
        buf.clear();
        buf.extend(v.iter().map(|&x| (x, 0.0)));
        buf.resize(spec.len(), (0.0, 0.0));
        self.plan.process_f32(buf, false);
        for (b, &a) in buf.iter_mut().zip(spec) {
            let re = b.0 * a.0 - b.1 * a.1;
            let im = b.0 * a.1 + b.1 * a.0;
            *b = (re, im);
        }
        self.plan.inverse_norm_f32(buf);
        buf[..m].iter().map(|c| c.0).collect()
    }

    /// Two columns for the price of one complex FFT pair: packs
    /// `b1 + i·b2`, so the real/imaginary parts of the inverse transform
    /// carry the two products (see `circ_mul_pair` for the algebra).
    fn matvec_pair(&self, b1: &[f64], b2: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let m = self.dim();
        let n = self.c_hat.len();
        assert!(m >= b1.len() && m >= b2.len());
        let top = b1.len().max(b2.len());
        let mut local = Vec::new();
        let mut guard = self.scratch.try_lock().ok();
        let buf: &mut Vec<C> = match guard.as_deref_mut() {
            Some(b) => b,
            None => &mut local,
        };
        buf.clear();
        buf.extend((0..top).map(|i| {
            (
                b1.get(i).copied().unwrap_or(0.0),
                b2.get(i).copied().unwrap_or(0.0),
            )
        }));
        buf.resize(n, (0.0, 0.0));
        self.plan.process(buf, false);
        for (b, &a) in buf.iter_mut().zip(&self.c_hat) {
            let re = b.0 * a.0 - b.1 * a.1;
            let im = b.0 * a.1 + b.1 * a.0;
            *b = (re, im);
        }
        self.plan.inverse_norm(buf);
        let out1 = buf[..m].iter().map(|c| c.0).collect();
        let out2 = buf[..m].iter().map(|c| c.1).collect();
        (out1, out2)
    }

    /// `K M` for an m×t block in O(t·m log m), batched two columns per
    /// complex FFT and parallel across column pairs.
    ///
    /// This is the grid-level fast path of the batched MVM engine: a SKI
    /// `matmat` funnels all t right-hand sides through here so the
    /// circulant spectrum `c_hat` is read once per pair instead of once
    /// per column.
    pub fn matmat(&self, m: &Matrix) -> Matrix {
        let dim = self.dim();
        assert_eq!(m.rows, dim);
        let t = m.cols;
        let mut out = Matrix::zeros(dim, t);
        // Process columns in pairs: ~2 FFTs per pair instead of 4. Thread
        // fan-out only pays off when each pair's FFT work is substantial,
        // so gate it on the embedding size (small grids stay serial — this
        // runs inside CG-iteration hot loops).
        let pairs = t / 2;
        let min_pairs = ((1usize << 15) / self.c_hat.len().max(1)).max(2);
        let results = par_map_range(pairs, min_pairs, |p| {
            let (j1, j2) = (2 * p, 2 * p + 1);
            self.matvec_pair(&m.col(j1), &m.col(j2))
        });
        for (p, (c1, c2)) in results.into_iter().enumerate() {
            out.set_col(2 * p, &c1);
            out.set_col(2 * p + 1, &c2);
        }
        if t % 2 == 1 {
            out.set_col(t - 1, &self.matvec(&m.col(t - 1)));
        }
        out
    }

    /// Dense materialization (tests / tiny problems only).
    pub fn to_dense(&self) -> Matrix {
        let m = self.dim();
        Matrix::from_fn(m, m, |i, j| self.col[i.abs_diff(j)])
    }

    /// Naive O(m²) MVM (oracle for tests).
    pub fn matvec_naive(&self, v: &[f64]) -> Vec<f64> {
        let m = self.dim();
        (0..m)
            .map(|i| (0..m).map(|j| self.col[i.abs_diff(j)] * v[j]).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fft::circ_mul;
    use crate::util::Rng;

    #[test]
    fn fft_mvm_matches_naive() {
        let mut rng = Rng::new(10);
        for m in [1usize, 2, 3, 7, 16, 33, 100] {
            let col: Vec<f64> = (0..m).map(|k| (-(k as f64) * 0.1).exp()).collect();
            let t = SymToeplitz::new(col);
            let v = rng.normal_vec(m);
            let fast = t.matvec(&v);
            let slow = t.matvec_naive(&v);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-9, "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn planned_matvec_is_bitwise_identical_to_circ_mul() {
        // The plan-based apply must reproduce the free-function circulant
        // path bit for bit — this is the "default f64 behavior unchanged"
        // contract of the FftPlan refactor.
        let mut rng = Rng::new(12);
        for m in [1usize, 4, 7, 33, 100] {
            let col: Vec<f64> = (0..m).map(|k| 1.0 / (1.0 + k as f64)).collect();
            let t = SymToeplitz::new(col.clone());
            let n = next_pow2((2 * m).saturating_sub(1).max(1));
            let mut c = vec![0.0; n];
            c[..m].copy_from_slice(&col);
            for k in 1..m {
                c[n - k] = col[k];
            }
            let c_hat = fft_real(&c, n);
            let v = rng.normal_vec(m);
            assert_eq!(t.matvec(&v), circ_mul(&c_hat, &v, m), "m={m}");
        }
    }

    #[test]
    fn matvec_f32_tracks_f64_to_single_precision() {
        let mut rng = Rng::new(13);
        for m in [3usize, 16, 65, 257] {
            let col: Vec<f64> = (0..m).map(|k| (-(k as f64) * 0.05).exp()).collect();
            let t = SymToeplitz::new(col);
            let v = rng.normal_vec(m);
            let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            let want = t.matvec(&v);
            let got = t.matvec_f32(&v32);
            let scale: f64 = want.iter().map(|x| x.abs()).fold(1.0, f64::max);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (*g as f64 - w).abs() < 1e-4 * scale,
                    "m={m}: {g} vs {w} (scale {scale})"
                );
            }
        }
    }

    #[test]
    fn clone_shares_plan_but_not_scratch() {
        let t = SymToeplitz::new(vec![2.0, 1.0, 0.5]);
        let mut rng = Rng::new(14);
        let v = rng.normal_vec(3);
        let _ = t.matvec(&v); // populate the scratch
        let u = t.clone();
        assert_eq!(t.matvec(&v), u.matvec(&v));
        assert_eq!(u.col, t.col);
    }

    #[test]
    fn matches_dense_matvec() {
        let col = vec![2.0, 1.0, 0.5, 0.25];
        let t = SymToeplitz::new(col);
        let v = [1.0, -1.0, 2.0, 0.0];
        let dense = t.to_dense().matvec(&v);
        let fast = t.matvec(&v);
        for (a, b) in fast.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn matmat_matches_per_column_matvec() {
        let mut rng = Rng::new(11);
        for m in [3usize, 16, 65] {
            let col: Vec<f64> = (0..m).map(|k| 1.0 / (1.0 + k as f64)).collect();
            let t = SymToeplitz::new(col);
            for cols in [1usize, 2, 5, 8] {
                let b = Matrix::from_fn(m, cols, |_, _| rng.normal());
                let got = t.matmat(&b);
                for j in 0..cols {
                    let want = t.matvec(&b.col(j));
                    let gcol = got.col(j);
                    for (a, w) in gcol.iter().zip(&want) {
                        assert!((a - w).abs() < 1e-9, "m={m} cols={cols} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn identity_toeplitz() {
        let mut col = vec![0.0; 8];
        col[0] = 1.0;
        let t = SymToeplitz::new(col);
        let v: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let out = t.matvec(&v);
        for (a, b) in out.iter().zip(&v) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
