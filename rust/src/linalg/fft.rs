//! Iterative radix-2 complex FFT.
//!
//! Powers the O(m log m) Toeplitz/circulant MVMs at the heart of SKI
//! (paper §2.3): a symmetric Toeplitz `K_UU` embeds in a circulant whose
//! action diagonalizes under the DFT.

use std::f64::consts::PI;

/// Complex number as (re, im) — small enough that a bespoke type beats
/// pulling in a dependency.
pub type C = (f64, f64);

#[inline]
fn c_add(a: C, b: C) -> C {
    (a.0 + b.0, a.1 + b.1)
}

#[inline]
fn c_sub(a: C, b: C) -> C {
    (a.0 - b.0, a.1 - b.1)
}

#[inline]
fn c_mul(a: C, b: C) -> C {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

/// Next power of two ≥ n.
pub fn next_pow2(n: usize) -> usize {
    let mut p = 1;
    while p < n {
        p <<= 1;
    }
    p
}

/// In-place forward FFT. `x.len()` must be a power of two.
pub fn fft(x: &mut [C]) {
    fft_dir(x, false);
}

/// In-place inverse FFT (includes the 1/n normalization).
pub fn ifft(x: &mut [C]) {
    fft_dir(x, true);
    let n = x.len() as f64;
    for v in x.iter_mut() {
        v.0 /= n;
        v.1 /= n;
    }
}

fn fft_dir(x: &mut [C], inverse: bool) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            x.swap(i, j);
        }
    }
    // Cooley–Tukey butterflies.
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen: C = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w: C = (1.0, 0.0);
            for k in 0..len / 2 {
                let u = x[i + k];
                let v = c_mul(x[i + k + len / 2], w);
                x[i + k] = c_add(u, v);
                x[i + k + len / 2] = c_sub(u, v);
                w = c_mul(w, wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Real-input convenience: FFT of a real slice zero-padded to `n` (power of 2).
pub fn fft_real(x: &[f64], n: usize) -> Vec<C> {
    assert!(n.is_power_of_two() && n >= x.len());
    let mut buf: Vec<C> = x.iter().map(|&v| (v, 0.0)).collect();
    buf.resize(n, (0.0, 0.0));
    fft(&mut buf);
    buf
}

/// Circular convolution via FFT: returns the first `out_len` entries of
/// `ifft(fft(a) ⊙ fft(b))` where both inputs are zero-padded to `n`.
pub fn circ_mul(a_hat: &[C], b: &[f64], out_len: usize) -> Vec<f64> {
    let n = a_hat.len();
    let mut bh = fft_real(b, n);
    for (v, &a) in bh.iter_mut().zip(a_hat) {
        *v = c_mul(*v, a);
    }
    ifft(&mut bh);
    bh[..out_len].iter().map(|c| c.0).collect()
}

/// Two circular convolutions for the price of one complex FFT pair.
///
/// Packs the real inputs as `x = b1 + i·b2`; since the circulant action is
/// a *real* linear map, `ifft(a_hat ⊙ fft(x))` carries `circ(a)·b1` in its
/// real part and `circ(a)·b2` in its imaginary part. This is the column
/// batching used by `SymToeplitz::matmat`: 2 FFTs per RHS pair (one
/// forward on the packed pair, one inverse) instead of the 4 that two
/// `circ_mul` calls pay (a forward + inverse per RHS).
pub fn circ_mul_pair(
    a_hat: &[C],
    b1: &[f64],
    b2: &[f64],
    out_len: usize,
) -> (Vec<f64>, Vec<f64>) {
    let n = a_hat.len();
    assert!(n >= b1.len() && n >= b2.len());
    let m = b1.len().max(b2.len());
    let mut buf: Vec<C> = (0..m)
        .map(|i| {
            (
                b1.get(i).copied().unwrap_or(0.0),
                b2.get(i).copied().unwrap_or(0.0),
            )
        })
        .collect();
    buf.resize(n, (0.0, 0.0));
    fft(&mut buf);
    for (v, &a) in buf.iter_mut().zip(a_hat) {
        *v = c_mul(*v, a);
    }
    ifft(&mut buf);
    let out1 = buf[..out_len].iter().map(|c| c.0).collect();
    let out2 = buf[..out_len].iter().map(|c| c.1).collect();
    (out1, out2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dft(x: &[C]) -> Vec<C> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut acc = (0.0, 0.0);
                for (j, &v) in x.iter().enumerate() {
                    let ang = -2.0 * PI * (k * j) as f64 / n as f64;
                    acc = c_add(acc, c_mul(v, (ang.cos(), ang.sin())));
                }
                acc
            })
            .collect()
    }

    #[test]
    fn fft_matches_naive_dft() {
        let mut x: Vec<C> = (0..16)
            .map(|i| ((i as f64 * 0.3).sin(), (i as f64 * 0.7).cos()))
            .collect();
        let expect = naive_dft(&x);
        fft(&mut x);
        for (a, e) in x.iter().zip(&expect) {
            assert!((a.0 - e.0).abs() < 1e-10 && (a.1 - e.1).abs() < 1e-10);
        }
    }

    #[test]
    fn fft_ifft_roundtrip() {
        let orig: Vec<C> = (0..64).map(|i| (i as f64, -(i as f64) * 0.5)).collect();
        let mut x = orig.clone();
        fft(&mut x);
        ifft(&mut x);
        for (a, e) in x.iter().zip(&orig) {
            assert!((a.0 - e.0).abs() < 1e-9 && (a.1 - e.1).abs() < 1e-9);
        }
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut x = vec![(0.0, 0.0); 8];
        x[0] = (1.0, 0.0);
        fft(&mut x);
        for v in x {
            assert!((v.0 - 1.0).abs() < 1e-12 && v.1.abs() < 1e-12);
        }
    }

    #[test]
    fn circ_mul_matches_naive_circular_convolution() {
        let a = [1.0, 2.0, 0.0, -1.0, 0.5, 0.0, 0.0, 0.0];
        let b = [0.5, 0.0, 3.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let n = 8;
        let a_hat = fft_real(&a, n);
        let got = circ_mul(&a_hat, &b, n);
        // naive circular convolution
        for k in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += a[j] * b[(k + n - j) % n];
            }
            assert!((got[k] - acc).abs() < 1e-10, "k={k}: {} vs {acc}", got[k]);
        }
    }

    #[test]
    fn circ_mul_pair_matches_two_circ_muls() {
        let a = [1.0, -0.5, 0.25, 0.0, 0.0, 0.0, 0.0, 0.0];
        let b1 = [1.0, 2.0, 3.0, 4.0, 0.0, -1.0, 0.5, 2.5];
        let b2 = [0.0, 1.0, -1.0, 0.5, 2.0, 0.0, 0.0, -3.0];
        let a_hat = fft_real(&a, 8);
        let (g1, g2) = circ_mul_pair(&a_hat, &b1, &b2, 8);
        let w1 = circ_mul(&a_hat, &b1, 8);
        let w2 = circ_mul(&a_hat, &b2, 8);
        for k in 0..8 {
            assert!((g1[k] - w1[k]).abs() < 1e-10, "k={k}");
            assert!((g2[k] - w2[k]).abs() < 1e-10, "k={k}");
        }
    }

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1000), 1024);
    }
}
