//! Dense row-major matrix type.
//!
//! No external linear-algebra crates are available offline, so this module
//! carries the dense substrate the rest of the library builds on. The
//! multiply kernels are written for cache friendliness (ikj loop order with
//! the inner loop over contiguous rows) — good enough that the *structured*
//! operators (Toeplitz, SKI, SKIP), not dense gemm, dominate runtime.

/// Dense row-major `rows × cols` matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Matrix { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    /// Diagonal matrix from a slice.
    pub fn diag(v: &[f64]) -> Self {
        let n = v.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = v[i];
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row i.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column j.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Set column j from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self.set(i, j, v[i]);
        }
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Matrix–matrix product `self * other` (ikj order, contiguous inner
    /// loop), parallel across disjoint output-row chunks when the product
    /// is large enough to amortize thread spawn. Per-row summation order
    /// is fixed, so results are identical at any thread count.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        if n == 0 {
            return out;
        }
        let a_data = &self.data;
        let b_data = &other.data;
        crate::util::parallel::par_row_chunks(
            &mut out.data,
            n,
            par_min_rows(k, n),
            |first_row, chunk| {
                for (r, o_row) in chunk.chunks_mut(n).enumerate() {
                    let i = first_row + r;
                    let a_row = &a_data[i * k..(i + 1) * k];
                    for (p, &a) in a_row.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let b_row = &b_data[p * n..(p + 1) * n];
                        for (o, &b) in o_row.iter_mut().zip(b_row) {
                            *o += a * b;
                        }
                    }
                }
            },
        );
        out
    }

    /// `selfᵀ * other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul dim mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for p in 0..k {
            let a_row = &self.data[p * m..(p + 1) * m];
            let b_row = &other.data[p * n..(p + 1) * n];
            for (i, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let o_row = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self * otherᵀ` without materializing the transpose; parallel
    /// across output-row chunks like [`Matrix::matmul`].
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(m, n);
        if n == 0 {
            return out;
        }
        let a_data = &self.data;
        let b_data = &other.data;
        crate::util::parallel::par_row_chunks(
            &mut out.data,
            n,
            par_min_rows(k, n),
            |first_row, chunk| {
                for (r, o_row) in chunk.chunks_mut(n).enumerate() {
                    let i = first_row + r;
                    let a_row = &a_data[i * k..(i + 1) * k];
                    for (j, o) in o_row.iter_mut().enumerate() {
                        let b_row = &b_data[j * k..(j + 1) * k];
                        let mut acc = 0.0;
                        for (&a, &b) in a_row.iter().zip(b_row) {
                            acc += a * b;
                        }
                        *o = acc;
                    }
                }
            },
        );
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec dim mismatch");
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (&a, &x) in row.iter().zip(v) {
                acc += a * x;
            }
            out[i] = acc;
        }
        out
    }

    /// `selfᵀ v` without materializing the transpose.
    pub fn t_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "t_matvec dim mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let x = v[i];
            if x == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(row) {
                *o += a * x;
            }
        }
        out
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise (Hadamard) product — the paper's `∘`.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a * b)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Scale by a constant.
    pub fn scale(&self, c: f64) -> Matrix {
        let data = self.data.iter().map(|a| a * c).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Add `c` to the diagonal in place (jitter / noise term).
    pub fn add_diag(&mut self, c: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += c;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|a| a * a).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.get(i, i)).sum()
    }

    /// Extract the diagonal.
    pub fn diagonal(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.get(i, i)).collect()
    }

    /// Symmetrize in place: `(A + Aᵀ)/2` (fights numerical drift).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = v;
                self.data[j * n + i] = v;
            }
        }
    }
}

/// Minimum output rows per thread chunk so each worker gets ≥ ~64k MACs
/// (below that, spawn latency beats the speedup and gemms stay serial).
#[inline]
fn par_min_rows(k: usize, n: usize) -> usize {
    ((1usize << 16) / (k * n).max(1)).max(8)
}

/// Dot product of two slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y ← y + alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm of a slice.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Scale a slice in place.
#[inline]
pub fn scale_in_place(a: &mut [f64], c: f64) {
    for x in a {
        *x *= c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let i = Matrix::eye(4);
        assert_eq!(a.matmul(&i).data, a.data);
        assert_eq!(i.matmul(&a).data, a.data);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Matrix::from_fn(5, 3, |i, j| (i + 2 * j) as f64);
        let b = Matrix::from_fn(5, 4, |i, j| (i as f64 - j as f64) * 0.5);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!(fast.max_abs_diff(&slow) < 1e-12);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let a = Matrix::from_fn(4, 3, |i, j| (i * j) as f64 + 1.0);
        let b = Matrix::from_fn(6, 3, |i, j| i as f64 - 0.3 * j as f64);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        assert!(fast.max_abs_diff(&slow) < 1e-12);
    }

    #[test]
    fn matvec_and_t_matvec() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.matvec(&[1., 0., -1.]), vec![-2., -2.]);
        assert_eq!(a.t_matvec(&[1., -1.]), vec![-3., -3., -3.]);
    }

    #[test]
    fn hadamard_elementwise() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![5., 6., 7., 8.]);
        assert_eq!(a.hadamard(&b).data, vec![5., 12., 21., 32.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_fn(3, 5, |i, j| (i * 7 + j) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn add_diag_and_trace() {
        let mut a = Matrix::zeros(3, 3);
        a.add_diag(2.5);
        assert!((a.trace() - 7.5).abs() < 1e-15);
    }

    #[test]
    fn diag_constructor() {
        let d = Matrix::diag(&[1., 2., 3.]);
        assert_eq!(d.diagonal(), vec![1., 2., 3.]);
        assert_eq!(d.get(0, 1), 0.0);
    }

    #[test]
    fn symmetrize_symmetric() {
        let mut a = Matrix::from_vec(2, 2, vec![1., 2., 4., 3.]);
        a.symmetrize();
        assert_eq!(a.get(0, 1), 3.0);
        assert_eq!(a.get(1, 0), 3.0);
    }

    #[test]
    fn blas_helpers() {
        assert_eq!(dot(&[1., 2.], &[3., 4.]), 11.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, -1.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
