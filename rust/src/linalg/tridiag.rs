//! Symmetric tridiagonal eigensolver (implicit-shift QL).
//!
//! Stochastic Lanczos quadrature (paper §2.2; Dong et al. 2017; Ubaru et
//! al. 2017) needs the eigenvalues θᵢ of the Lanczos tridiagonal T and the
//! *first components* τᵢ of its eigenvectors — the Gauss quadrature nodes
//! and weights. We adapt the classic EISPACK `tql2` routine, tracking only
//! the first row of the accumulated eigenvector matrix.

use crate::error::{Error, Result};

/// Eigen-decomposition of a symmetric tridiagonal matrix.
#[derive(Clone, Debug)]
pub struct TridiagEig {
    /// Eigenvalues, ascending.
    pub eigenvalues: Vec<f64>,
    /// First component of each (unit-norm) eigenvector, same order.
    pub first_components: Vec<f64>,
}

/// Compute eigenvalues and eigenvector first-components of the symmetric
/// tridiagonal matrix with diagonal `d` and off-diagonal `e` (len n−1).
pub fn tridiag_eig(d: &[f64], e: &[f64]) -> Result<TridiagEig> {
    let n = d.len();
    assert!(n > 0);
    assert_eq!(e.len(), n.saturating_sub(1), "off-diagonal length must be n-1");
    let mut d = d.to_vec();
    // Shifted off-diagonal buffer with trailing zero, as in tql2.
    let mut e2 = vec![0.0; n];
    e2[..n - 1].copy_from_slice(e);
    // First row of the eigenvector matrix (starts as e₁ᵀ of identity).
    let mut z = vec![0.0; n];
    z[0] = 1.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small subdiagonal element.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e2[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(Error::EigFailed { index: l });
            }
            // Wilkinson shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e2[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r } else { -r };
            g = d[m] - d[l] + e2[l] / (g + sign_r);
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            // Implicit QL sweep from m-1 down to l.
            for i in (l..m).rev() {
                let mut f = s * e2[i];
                let b = c * e2[i];
                r = f.hypot(g);
                e2[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e2[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into the tracked first row.
                f = z[i + 1];
                z[i + 1] = s * z[i] + c * f;
                z[i] = c * z[i] - s * f;
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e2[l] = g;
            e2[m] = 0.0;
        }
    }

    // Sort ascending, permuting first-components alongside.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let eigenvalues: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let first_components: Vec<f64> = idx.iter().map(|&i| z[i]).collect();
    Ok(TridiagEig { eigenvalues, first_components })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::Rng;

    fn tridiag_dense(d: &[f64], e: &[f64]) -> Matrix {
        let n = d.len();
        Matrix::from_fn(n, n, |i, j| {
            if i == j {
                d[i]
            } else if i.abs_diff(j) == 1 {
                e[i.min(j)]
            } else {
                0.0
            }
        })
    }

    #[test]
    fn diagonal_matrix_eigs() {
        let d = [3.0, 1.0, 2.0];
        let e = [0.0, 0.0];
        let eig = tridiag_eig(&d, &e).unwrap();
        assert_eq!(eig.eigenvalues, vec![1.0, 2.0, 3.0]);
        // e1 is the eigenvector of eigenvalue 3 ⇒ |first comp| = 1 there.
        assert!((eig.first_components[2].abs() - 1.0).abs() < 1e-12);
        assert!(eig.first_components[0].abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigs 1, 3; eigvecs (1,∓1)/√2.
        let eig = tridiag_eig(&[2.0, 2.0], &[1.0]).unwrap();
        assert!((eig.eigenvalues[0] - 1.0).abs() < 1e-12);
        assert!((eig.eigenvalues[1] - 3.0).abs() < 1e-12);
        for fc in &eig.first_components {
            assert!((fc.abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-12);
        }
    }

    #[test]
    fn trace_and_weights_identities() {
        // Σθᵢ = trace, Στᵢ² = 1 (first row of an orthogonal matrix).
        let mut rng = Rng::new(77);
        for n in [1usize, 2, 5, 20, 50] {
            let d: Vec<f64> = rng.normal_vec(n).iter().map(|x| x + 3.0).collect();
            let e: Vec<f64> = rng.normal_vec(n.saturating_sub(1));
            let eig = tridiag_eig(&d, &e).unwrap();
            let tr: f64 = d.iter().sum();
            let tr_eig: f64 = eig.eigenvalues.iter().sum();
            assert!((tr - tr_eig).abs() < 1e-8 * (1.0 + tr.abs()));
            let w: f64 = eig.first_components.iter().map(|t| t * t).sum();
            assert!((w - 1.0).abs() < 1e-10, "n={n} w={w}");
        }
    }

    #[test]
    fn quadrature_reproduces_matrix_function() {
        // e₁ᵀ f(T) e₁ = Σ τᵢ² f(θᵢ). Check with f = exp against a dense
        // eigendecomposition by series (small matrix, f(T) via scaling).
        let d = [1.0, 0.5, 0.25, 0.8];
        let e = [0.3, 0.2, 0.1];
        let eig = tridiag_eig(&d, &e).unwrap();
        // f(x) = x²: e₁ᵀ T² e₁ = (T²)₀₀ = d₀² + e₀².
        let got: f64 = eig
            .first_components
            .iter()
            .zip(&eig.eigenvalues)
            .map(|(t, th)| t * t * th * th)
            .sum();
        let expect = d[0] * d[0] + e[0] * e[0];
        assert!((got - expect).abs() < 1e-10, "{got} vs {expect}");
    }

    #[test]
    fn eigenvalues_match_dense_characteristic() {
        // Verify eigenvalues by checking det(T - θI) ≈ 0 via recurrence.
        let d = [2.0, -1.0, 0.5, 3.0, 1.0];
        let e = [0.7, 0.4, 0.9, 0.2];
        let eig = tridiag_eig(&d, &e).unwrap();
        let a = tridiag_dense(&d, &e);
        for &theta in &eig.eigenvalues {
            // char poly via tridiagonal determinant recurrence
            let n = d.len();
            let mut p_prev = 1.0;
            let mut p = a.get(0, 0) - theta;
            for i in 1..n {
                let next = (d[i] - theta) * p - e[i - 1] * e[i - 1] * p_prev;
                p_prev = p;
                p = next;
            }
            assert!(p.abs() < 1e-6, "det at eigenvalue {theta} = {p}");
        }
    }
}
