//! Cholesky decomposition and triangular solves.
//!
//! The O(n³) backbone of the Exact-GP baseline (paper §2.2 "traditionally…
//! Cholesky") and of the SGPR baseline's m×m solves.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    /// Lower-triangular factor, stored dense.
    pub l: Matrix,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix. Returns an error if a
    /// non-positive pivot is hit (matrix not PD to working precision).
    pub fn new(a: &Matrix) -> Result<Self> {
        assert_eq!(a.rows, a.cols, "cholesky: square matrix required");
        let n = a.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a.get(i, j);
                // sum -= Σ_k<j L[i,k] L[j,k]  (rows are contiguous)
                let (li, lj) = (l.row(i), l.row(j));
                for k in 0..j {
                    sum -= li[k] * lj[k];
                }
                if i == j {
                    if sum <= 0.0 {
                        return Err(Error::NotPositiveDefinite { pivot: i, value: sum });
                    }
                    l.set(i, j, sum.sqrt());
                } else {
                    l.set(i, j, sum / l.get(j, j));
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Factor with added diagonal jitter, retrying with growing jitter.
    pub fn new_with_jitter(a: &Matrix, mut jitter: f64) -> Result<Self> {
        for _ in 0..8 {
            let mut aj = a.clone();
            if jitter > 0.0 {
                aj.add_diag(jitter);
            }
            match Cholesky::new(&aj) {
                Ok(c) => return Ok(c),
                Err(_) => jitter = if jitter == 0.0 { 1e-10 } else { jitter * 10.0 },
            }
        }
        Err(Error::NotPositiveDefinite { pivot: 0, value: f64::NAN })
    }

    /// Solve `A x = b` via forward + backward substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = self.solve_lower(b);
        self.solve_upper(&y)
    }

    /// Solve `L y = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let row = self.l.row(i);
            let mut sum = b[i];
            for k in 0..i {
                sum -= row[k] * y[k];
            }
            y[i] = sum / row[i];
        }
        y
    }

    /// Solve `Lᵀ x = y` (backward substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(y.len(), n);
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l.get(k, i) * x[k];
            }
            x[i] = sum / self.l.get(i, i);
        }
        x
    }

    /// Solve `A X = B` against all columns of `B` in one blocked forward +
    /// backward substitution (part of the batched multi-RHS engine: `L` is
    /// streamed once for the whole block instead of once per column).
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        self.solve_upper_mat(&self.solve_lower_mat(b))
    }

    /// Solve `L Y = B` for all columns at once. Row-major layout makes the
    /// inner update a contiguous length-t axpy, so the per-column
    /// subtraction order matches [`Cholesky::solve_lower`] exactly.
    pub fn solve_lower_mat(&self, b: &Matrix) -> Matrix {
        let n = self.l.rows;
        assert_eq!(b.rows, n);
        let t = b.cols;
        let mut y = b.clone();
        for i in 0..n {
            let lrow = self.l.row(i);
            let (done, rest) = y.data.split_at_mut(i * t);
            let yi = &mut rest[..t];
            for k in 0..i {
                let c = lrow[k];
                if c == 0.0 {
                    continue;
                }
                let yk = &done[k * t..(k + 1) * t];
                for (a, &v) in yi.iter_mut().zip(yk) {
                    *a -= c * v;
                }
            }
            let d = lrow[i];
            for a in yi.iter_mut() {
                *a /= d;
            }
        }
        y
    }

    /// Solve `Lᵀ X = Y` for all columns at once (blocked backward
    /// substitution; see [`Cholesky::solve_lower_mat`]).
    pub fn solve_upper_mat(&self, yb: &Matrix) -> Matrix {
        let n = self.l.rows;
        assert_eq!(yb.rows, n);
        let t = yb.cols;
        let mut x = yb.clone();
        for i in (0..n).rev() {
            let (head, tail) = x.data.split_at_mut((i + 1) * t);
            let xi = &mut head[i * t..];
            for k in (i + 1)..n {
                let c = self.l.get(k, i);
                if c == 0.0 {
                    continue;
                }
                let xk = &tail[(k - i - 1) * t..(k - i) * t];
                for (a, &v) in xi.iter_mut().zip(xk) {
                    *a -= c * v;
                }
            }
            let d = self.l.get(i, i);
            for a in xi.iter_mut() {
                *a /= d;
            }
        }
        x
    }

    /// log |A| = 2 Σ log L[i,i].
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }

    /// Explicit inverse (used only in small m×m contexts, e.g. SGPR).
    pub fn inverse(&self) -> Matrix {
        self.solve_mat(&Matrix::eye(self.l.rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul_t(&b); // B Bᵀ ⪰ 0
        a.add_diag(n as f64 * 0.1);
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(20, 1);
        let c = Cholesky::new(&a).unwrap();
        let rec = c.l.matmul_t(&c.l);
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn solve_matches_direct() {
        let a = random_spd(15, 2);
        let c = Cholesky::new(&a).unwrap();
        let b: Vec<f64> = (0..15).map(|i| (i as f64).sin()).collect();
        let x = c.solve(&b);
        let back = a.matvec(&x);
        for (u, v) in back.iter().zip(&b) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let c = Cholesky::new(&a).unwrap();
        // det = 11
        assert!((c.logdet() - 11f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigs 3, -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn jitter_recovers_semidefinite() {
        // Rank-1 PSD matrix — plain Cholesky fails, jitter succeeds.
        let a = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert!(Cholesky::new(&a).is_err());
        assert!(Cholesky::new_with_jitter(&a, 1e-8).is_ok());
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(8, 3);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Matrix::eye(8)) < 1e-8);
    }

    #[test]
    fn solve_mat_columns() {
        let a = random_spd(6, 4);
        let c = Cholesky::new(&a).unwrap();
        let b = Matrix::from_fn(6, 3, |i, j| (i + j) as f64 * 0.25);
        let x = c.solve_mat(&b);
        assert!(a.matmul(&x).max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn blocked_triangular_solves_match_per_column() {
        let a = random_spd(12, 5);
        let c = Cholesky::new(&a).unwrap();
        let b = Matrix::from_fn(12, 4, |i, j| ((i * 7 + j * 3) as f64).sin());
        let y = c.solve_lower_mat(&b);
        let x = c.solve_upper_mat(&y);
        for j in 0..4 {
            let col = b.col(j);
            let y_col = c.solve_lower(&col);
            let x_col = c.solve_upper(&y_col);
            for i in 0..12 {
                assert_eq!(y.get(i, j), y_col[i], "lower ({i},{j})");
                assert_eq!(x.get(i, j), x_col[i], "upper ({i},{j})");
            }
        }
    }
}
