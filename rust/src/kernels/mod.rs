//! Covariance kernels.
//!
//! The paper's models are built from *products of one-dimensional
//! stationary kernels* (§3, §5): a d-dimensional RBF/ARD kernel factors
//! exactly as `k(x,x′) = Π_i k⁽ⁱ⁾(x_i, x′_i)`, and the multi-task kernel
//! (§6) is a product of a data kernel and a task (coregionalization)
//! kernel.

pub mod product;
pub mod stationary;
pub mod task;

pub use product::{deriv_layout, ProductKernel};
pub use stationary::Stationary1d;
pub use task::TaskKernel;
