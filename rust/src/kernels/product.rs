//! Product kernels over multi-dimensional inputs.
//!
//! `k(x, x′) = σ² · Π_i k⁽ⁱ⁾(x_i, x′_i)` — the object the whole paper is
//! about. With all factors RBF and a shared lengthscale this *is* the
//! d-dimensional RBF kernel; with per-dimension lengthscales it is ARD.

use super::stationary::Stationary1d;
use crate::linalg::Matrix;

/// Product of 1-D stationary kernels with a single output scale σ².
#[derive(Clone, Debug)]
pub struct ProductKernel {
    /// One factor per input dimension (factor i consumes coordinate i).
    pub factors: Vec<Stationary1d>,
    /// Output scale σ² applied to the whole product.
    pub outputscale: f64,
}

impl ProductKernel {
    /// d-dimensional RBF kernel with shared lengthscale.
    pub fn rbf(d: usize, lengthscale: f64, outputscale: f64) -> Self {
        ProductKernel {
            factors: vec![Stationary1d::rbf(lengthscale); d],
            outputscale,
        }
    }

    /// ARD RBF with per-dimension lengthscales.
    pub fn ard(lengthscales: &[f64], outputscale: f64) -> Self {
        ProductKernel {
            factors: lengthscales.iter().map(|&l| Stationary1d::rbf(l)).collect(),
            outputscale,
        }
    }

    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        self.factors.len()
    }

    /// Evaluate on two points (slices of length d).
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.factors.len());
        debug_assert_eq!(y.len(), self.factors.len());
        let mut p = self.outputscale;
        for (k, (&xi, &yi)) in self.factors.iter().zip(x.iter().zip(y)) {
            p *= k.eval(xi, yi);
        }
        p
    }

    /// Dense Gram matrix between two point sets (rows of `xs`, `ys`);
    /// each is a row-major (n × d) matrix. O(n·m·d) — baselines only.
    pub fn gram(&self, xs: &Matrix, ys: &Matrix) -> Matrix {
        assert_eq!(xs.cols, self.dim());
        assert_eq!(ys.cols, self.dim());
        Matrix::from_fn(xs.rows, ys.rows, |i, j| self.eval(xs.row(i), ys.row(j)))
    }

    /// Symmetric training Gram matrix.
    pub fn gram_sym(&self, xs: &Matrix) -> Matrix {
        let n = xs.rows;
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.eval(xs.row(i), xs.row(j));
                g.set(i, j, v);
                g.set(j, i, v);
            }
        }
        g
    }

    /// Replace all lengthscales with a shared value (RBF training).
    pub fn with_shared_lengthscale(&self, lengthscale: f64) -> Self {
        ProductKernel {
            factors: self
                .factors
                .iter()
                .map(|f| f.with_lengthscale(lengthscale))
                .collect(),
            outputscale: self.outputscale,
        }
    }

    /// With a new output scale.
    pub fn with_outputscale(&self, outputscale: f64) -> Self {
        ProductKernel { factors: self.factors.clone(), outputscale }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn product_of_rbfs_is_multidim_rbf() {
        let k = ProductKernel::rbf(3, 1.5, 2.0);
        let x = [0.1, -0.4, 0.9];
        let y = [1.0, 0.0, 0.5];
        let sq: f64 = x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
        let expect = 2.0 * (-0.5 * sq / (1.5 * 1.5)).exp();
        assert!((k.eval(&x, &y) - expect).abs() < 1e-14);
    }

    #[test]
    fn ard_uses_per_dim_lengthscales() {
        let k = ProductKernel::ard(&[1.0, 2.0], 1.0);
        let x = [0.0, 0.0];
        let y = [1.0, 2.0];
        // exp(-0.5·1) · exp(-0.5·1)
        assert!((k.eval(&x, &y) - (-1.0f64).exp()).abs() < 1e-14);
    }

    #[test]
    fn gram_is_symmetric_unit_diag() {
        let k = ProductKernel::rbf(2, 1.0, 3.0);
        let xs = Matrix::from_vec(3, 2, vec![0., 0., 1., 0., 0.5, -0.5]);
        let g = k.gram_sym(&xs);
        for i in 0..3 {
            assert!((g.get(i, i) - 3.0).abs() < 1e-14);
            for j in 0..3 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
        }
        // cross-gram agrees
        let g2 = k.gram(&xs, &xs);
        assert!(g.max_abs_diff(&g2) < 1e-14);
    }

    #[test]
    fn hadamard_factorization_identity() {
        // The paper's Eq. 7: full Gram = elementwise product of per-dim Grams.
        let k = ProductKernel::ard(&[0.8, 1.3], 1.0);
        let xs = Matrix::from_vec(4, 2, vec![0., 1., 0.3, -0.2, 1.1, 0.7, -0.5, 0.4]);
        let full = k.gram_sym(&xs);
        let mut had = Matrix::from_fn(4, 4, |_, _| 1.0);
        for (d, f) in k.factors.iter().enumerate() {
            let gd = Matrix::from_fn(4, 4, |i, j| f.eval(xs.get(i, d), xs.get(j, d)));
            had = had.hadamard(&gd);
        }
        assert!(full.max_abs_diff(&had) < 1e-14);
    }
}
