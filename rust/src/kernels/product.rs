//! Product kernels over multi-dimensional inputs.
//!
//! `k(x, x′) = σ² · Π_i k⁽ⁱ⁾(x_i, x′_i)` — the object the whole paper is
//! about. With all factors RBF and a shared lengthscale this *is* the
//! d-dimensional RBF kernel; with per-dimension lengthscales it is ARD.

use super::stationary::{KernelFamily, Stationary1d};
use crate::linalg::Matrix;

/// Enumerate the interleaved derivative-extended row layout: for each
/// point, one value row, followed — when its `has_grad` flag is set — by
/// `d` gradient rows (axis 0..d). Returns `(point index, None)` for value
/// rows and `(point index, Some(axis))` for gradient rows. This is the
/// row order D-SKI uses everywhere: the extended interpolation operator
/// ([`crate::operators::KroneckerSkiOp::with_grids_grad`]), the dense
/// derivative Grams below, and the streamed `(y, ∇y)` target vectors.
pub fn deriv_layout(has_grad: &[bool], d: usize) -> Vec<(usize, Option<usize>)> {
    let mut rows = Vec::with_capacity(
        has_grad.len() + d * has_grad.iter().filter(|&&g| g).count(),
    );
    for (i, &g) in has_grad.iter().enumerate() {
        rows.push((i, None));
        if g {
            for a in 0..d {
                rows.push((i, Some(a)));
            }
        }
    }
    rows
}

/// Product of 1-D stationary kernels with a single output scale σ².
#[derive(Clone, Debug)]
pub struct ProductKernel {
    /// One factor per input dimension (factor i consumes coordinate i).
    pub factors: Vec<Stationary1d>,
    /// Output scale σ² applied to the whole product.
    pub outputscale: f64,
}

impl ProductKernel {
    /// d-dimensional RBF kernel with shared lengthscale.
    pub fn rbf(d: usize, lengthscale: f64, outputscale: f64) -> Self {
        ProductKernel {
            factors: vec![Stationary1d::rbf(lengthscale); d],
            outputscale,
        }
    }

    /// ARD RBF with per-dimension lengthscales.
    pub fn ard(lengthscales: &[f64], outputscale: f64) -> Self {
        ProductKernel {
            factors: lengthscales.iter().map(|&l| Stationary1d::rbf(l)).collect(),
            outputscale,
        }
    }

    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        self.factors.len()
    }

    /// Evaluate on two points (slices of length d).
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.factors.len());
        debug_assert_eq!(y.len(), self.factors.len());
        let mut p = self.outputscale;
        for (k, (&xi, &yi)) in self.factors.iter().zip(x.iter().zip(y)) {
            p *= k.eval(xi, yi);
        }
        p
    }

    /// Derivative covariances of the RBF product kernel (D-SKI, Eriksson
    /// et al. 2018). With `r_a = x_a − y_a` and per-factor lengthscales
    /// `ℓ_a`:
    ///
    /// - `(None, None)`      → `k(x, y)`
    /// - `(Some(a), None)`   → `∂k/∂x_a = −(r_a/ℓ_a²)·k`
    /// - `(None, Some(b))`   → `∂k/∂y_b = +(r_b/ℓ_b²)·k`
    /// - `(Some(a), Some(b))`→ `∂²k/∂x_a∂y_b
    ///                          = (δ_ab/ℓ_a² − r_a r_b/(ℓ_a²ℓ_b²))·k`
    ///
    /// Only RBF factors are differentiable here — Matérn-1/2 kernels are
    /// not differentiable at zero and the higher Matérns need different
    /// algebra; gradient observations are an RBF-only feature.
    pub fn eval_deriv(
        &self,
        x: &[f64],
        y: &[f64],
        dx: Option<usize>,
        dy: Option<usize>,
    ) -> f64 {
        debug_assert!(
            self.factors.iter().all(|f| f.family == KernelFamily::Rbf),
            "derivative covariances are defined for RBF factors only"
        );
        let k = self.eval(x, y);
        let scaled = |a: usize| -> f64 {
            let ell2 = self.factors[a].lengthscale * self.factors[a].lengthscale;
            (x[a] - y[a]) / ell2
        };
        match (dx, dy) {
            (None, None) => k,
            (Some(a), None) => -scaled(a) * k,
            (None, Some(b)) => scaled(b) * k,
            (Some(a), Some(b)) => {
                let ell_a2 =
                    self.factors[a].lengthscale * self.factors[a].lengthscale;
                let delta = if a == b { 1.0 / ell_a2 } else { 0.0 };
                (delta - scaled(a) * scaled(b)) * k
            }
        }
    }

    /// Dense derivative-extended Gram between two point sets, rows
    /// differentiating the first argument and columns the second, in the
    /// interleaved [`deriv_layout`] row order on both sides. O(N·M·d) —
    /// D-SKI oracles and exact-variance factors only.
    pub fn gram_deriv(
        &self,
        xs: &Matrix,
        xs_grad: &[bool],
        ys: &Matrix,
        ys_grad: &[bool],
    ) -> Matrix {
        assert_eq!(xs.cols, self.dim());
        assert_eq!(ys.cols, self.dim());
        assert_eq!(xs.rows, xs_grad.len());
        assert_eq!(ys.rows, ys_grad.len());
        let rows = deriv_layout(xs_grad, self.dim());
        let cols = deriv_layout(ys_grad, self.dim());
        Matrix::from_fn(rows.len(), cols.len(), |i, j| {
            let (pi, da) = rows[i];
            let (pj, db) = cols[j];
            self.eval_deriv(xs.row(pi), ys.row(pj), da, db)
        })
    }

    /// Symmetric derivative-extended training Gram (`gram_deriv` of a
    /// point set against itself).
    pub fn gram_deriv_sym(&self, xs: &Matrix, has_grad: &[bool]) -> Matrix {
        self.gram_deriv(xs, has_grad, xs, has_grad)
    }

    /// Dense Gram matrix between two point sets (rows of `xs`, `ys`);
    /// each is a row-major (n × d) matrix. O(n·m·d) — baselines only.
    pub fn gram(&self, xs: &Matrix, ys: &Matrix) -> Matrix {
        assert_eq!(xs.cols, self.dim());
        assert_eq!(ys.cols, self.dim());
        Matrix::from_fn(xs.rows, ys.rows, |i, j| self.eval(xs.row(i), ys.row(j)))
    }

    /// Symmetric training Gram matrix.
    pub fn gram_sym(&self, xs: &Matrix) -> Matrix {
        let n = xs.rows;
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.eval(xs.row(i), xs.row(j));
                g.set(i, j, v);
                g.set(j, i, v);
            }
        }
        g
    }

    /// Replace all lengthscales with a shared value (RBF training).
    pub fn with_shared_lengthscale(&self, lengthscale: f64) -> Self {
        ProductKernel {
            factors: self
                .factors
                .iter()
                .map(|f| f.with_lengthscale(lengthscale))
                .collect(),
            outputscale: self.outputscale,
        }
    }

    /// With a new output scale.
    pub fn with_outputscale(&self, outputscale: f64) -> Self {
        ProductKernel { factors: self.factors.clone(), outputscale }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn product_of_rbfs_is_multidim_rbf() {
        let k = ProductKernel::rbf(3, 1.5, 2.0);
        let x = [0.1, -0.4, 0.9];
        let y = [1.0, 0.0, 0.5];
        let sq: f64 = x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
        let expect = 2.0 * (-0.5 * sq / (1.5 * 1.5)).exp();
        assert!((k.eval(&x, &y) - expect).abs() < 1e-14);
    }

    #[test]
    fn ard_uses_per_dim_lengthscales() {
        let k = ProductKernel::ard(&[1.0, 2.0], 1.0);
        let x = [0.0, 0.0];
        let y = [1.0, 2.0];
        // exp(-0.5·1) · exp(-0.5·1)
        assert!((k.eval(&x, &y) - (-1.0f64).exp()).abs() < 1e-14);
    }

    #[test]
    fn gram_is_symmetric_unit_diag() {
        let k = ProductKernel::rbf(2, 1.0, 3.0);
        let xs = Matrix::from_vec(3, 2, vec![0., 0., 1., 0., 0.5, -0.5]);
        let g = k.gram_sym(&xs);
        for i in 0..3 {
            assert!((g.get(i, i) - 3.0).abs() < 1e-14);
            for j in 0..3 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
        }
        // cross-gram agrees
        let g2 = k.gram(&xs, &xs);
        assert!(g.max_abs_diff(&g2) < 1e-14);
    }

    #[test]
    fn eval_deriv_matches_finite_differences() {
        let k = ProductKernel::ard(&[0.8, 1.3, 0.6], 1.7);
        let x = [0.3, -0.4, 0.9];
        let y = [-0.2, 0.5, 0.1];
        let h = 1e-5;
        let perturb = |p: &[f64; 3], a: usize, eps: f64| -> [f64; 3] {
            let mut q = *p;
            q[a] += eps;
            q
        };
        for a in 0..3 {
            // ∂k/∂x_a by central difference.
            let fd = (k.eval(&perturb(&x, a, h), &y)
                - k.eval(&perturb(&x, a, -h), &y))
                / (2.0 * h);
            let an = k.eval_deriv(&x, &y, Some(a), None);
            assert!((fd - an).abs() < 1e-8, "dx axis {a}: {fd} vs {an}");
            // ∂k/∂y_a by central difference.
            let fd = (k.eval(&x, &perturb(&y, a, h))
                - k.eval(&x, &perturb(&y, a, -h)))
                / (2.0 * h);
            let an = k.eval_deriv(&x, &y, None, Some(a));
            assert!((fd - an).abs() < 1e-8, "dy axis {a}: {fd} vs {an}");
            for b in 0..3 {
                // ∂²k/∂x_a∂y_b by nested central differences.
                let g = |xp: &[f64; 3]| {
                    (k.eval(xp, &perturb(&y, b, h))
                        - k.eval(xp, &perturb(&y, b, -h)))
                        / (2.0 * h)
                };
                let fd = (g(&perturb(&x, a, h)) - g(&perturb(&x, a, -h)))
                    / (2.0 * h);
                let an = k.eval_deriv(&x, &y, Some(a), Some(b));
                assert!(
                    (fd - an).abs() < 1e-6,
                    "dxdy axes ({a},{b}): {fd} vs {an}"
                );
            }
        }
    }

    #[test]
    fn gram_deriv_is_symmetric_and_embeds_plain_gram() {
        let k = ProductKernel::ard(&[0.9, 1.1], 2.0);
        let xs = Matrix::from_vec(3, 2, vec![0., 0., 0.7, -0.3, -0.5, 0.4]);
        let mask = [true, false, true];
        let g = k.gram_deriv_sym(&xs, &mask);
        let n_ext = 3 + 2 * 2;
        assert_eq!(g.rows, n_ext);
        assert_eq!(g.cols, n_ext);
        for i in 0..n_ext {
            for j in 0..n_ext {
                assert!(
                    (g.get(i, j) - g.get(j, i)).abs() < 1e-13,
                    "asymmetry at ({i},{j})"
                );
            }
        }
        // Value rows sit at layout offsets 0, 3, 4 and reproduce the
        // plain Gram exactly.
        let plain = k.gram_sym(&xs);
        let value_rows = [0usize, 3, 4];
        for (pi, &ri) in value_rows.iter().enumerate() {
            for (pj, &rj) in value_rows.iter().enumerate() {
                assert_eq!(g.get(ri, rj), plain.get(pi, pj));
            }
        }
        // Layout enumerates value-then-gradient rows per flagged point.
        assert_eq!(
            deriv_layout(&mask, 2),
            vec![
                (0, None),
                (0, Some(0)),
                (0, Some(1)),
                (1, None),
                (2, None),
                (2, Some(0)),
                (2, Some(1)),
            ]
        );
    }

    #[test]
    fn hadamard_factorization_identity() {
        // The paper's Eq. 7: full Gram = elementwise product of per-dim Grams.
        let k = ProductKernel::ard(&[0.8, 1.3], 1.0);
        let xs = Matrix::from_vec(4, 2, vec![0., 1., 0.3, -0.2, 1.1, 0.7, -0.5, 0.4]);
        let full = k.gram_sym(&xs);
        let mut had = Matrix::from_fn(4, 4, |_, _| 1.0);
        for (d, f) in k.factors.iter().enumerate() {
            let gd = Matrix::from_fn(4, 4, |i, j| f.eval(xs.get(i, d), xs.get(j, d)));
            had = had.hadamard(&gd);
        }
        assert!(full.max_abs_diff(&had) < 1e-14);
    }
}
