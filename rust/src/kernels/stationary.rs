//! One-dimensional stationary kernels.
//!
//! These are the atoms of every model in the paper: the RBF kernel (whose
//! d-dimensional form factors exactly into d of these), and the Matérn
//! family used by the cluster multi-task model (§6, ν = 5/2).

/// Family of a 1-D stationary kernel `k(x, x′) = κ(|x − x′| / ℓ)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelFamily {
    /// Squared exponential: κ(u) = exp(−u²/2).
    Rbf,
    /// Matérn ν=1/2 (exponential): κ(u) = exp(−u).
    Matern12,
    /// Matérn ν=3/2.
    Matern32,
    /// Matérn ν=5/2 — the paper's choice for k_cluster / k_indiv.
    Matern52,
}

/// A 1-D stationary kernel with a lengthscale.
///
/// The output scale lives on the *product* kernel (one σ² per product, not
/// per factor) to keep hyperparameters identifiable.
#[derive(Clone, Copy, Debug)]
pub struct Stationary1d {
    pub family: KernelFamily,
    pub lengthscale: f64,
}

impl Stationary1d {
    pub fn rbf(lengthscale: f64) -> Self {
        Stationary1d { family: KernelFamily::Rbf, lengthscale }
    }

    pub fn matern52(lengthscale: f64) -> Self {
        Stationary1d { family: KernelFamily::Matern52, lengthscale }
    }

    pub fn matern32(lengthscale: f64) -> Self {
        Stationary1d { family: KernelFamily::Matern32, lengthscale }
    }

    pub fn matern12(lengthscale: f64) -> Self {
        Stationary1d { family: KernelFamily::Matern12, lengthscale }
    }

    /// Evaluate κ at distance `r ≥ 0` (lengthscale applied inside).
    #[inline]
    pub fn eval_dist(&self, r: f64) -> f64 {
        let u = r.abs() / self.lengthscale;
        match self.family {
            KernelFamily::Rbf => (-0.5 * u * u).exp(),
            KernelFamily::Matern12 => (-u).exp(),
            KernelFamily::Matern32 => {
                let s = 3.0f64.sqrt() * u;
                (1.0 + s) * (-s).exp()
            }
            KernelFamily::Matern52 => {
                let s = 5.0f64.sqrt() * u;
                (1.0 + s + s * s / 3.0) * (-s).exp()
            }
        }
    }

    /// k(x, x′) for scalar inputs.
    #[inline]
    pub fn eval(&self, x: f64, y: f64) -> f64 {
        self.eval_dist(x - y)
    }

    /// First column of the (symmetric Toeplitz) Gram matrix on a regular
    /// grid with spacing `h`: entry j = κ(j·h). This is what SKI's
    /// `K_UU` needs.
    pub fn toeplitz_column(&self, m: usize, h: f64) -> Vec<f64> {
        (0..m).map(|j| self.eval_dist(j as f64 * h)).collect()
    }

    /// With a new lengthscale (hyperparameter updates).
    pub fn with_lengthscale(&self, lengthscale: f64) -> Self {
        Stationary1d { family: self.family, lengthscale }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_at_zero_distance() {
        for fam in [
            KernelFamily::Rbf,
            KernelFamily::Matern12,
            KernelFamily::Matern32,
            KernelFamily::Matern52,
        ] {
            let k = Stationary1d { family: fam, lengthscale: 0.7 };
            assert!((k.eval(1.3, 1.3) - 1.0).abs() < 1e-15);
        }
    }

    #[test]
    fn monotone_decreasing() {
        for fam in [
            KernelFamily::Rbf,
            KernelFamily::Matern12,
            KernelFamily::Matern32,
            KernelFamily::Matern52,
        ] {
            let k = Stationary1d { family: fam, lengthscale: 1.0 };
            let mut prev = 1.0;
            for i in 1..20 {
                let v = k.eval_dist(i as f64 * 0.3);
                assert!(v < prev, "{fam:?} not decreasing");
                assert!(v > 0.0);
                prev = v;
            }
        }
    }

    #[test]
    fn rbf_known_value() {
        let k = Stationary1d::rbf(2.0);
        // exp(-0.5 * (1/2)^2) = exp(-1/8)
        assert!((k.eval(0.0, 1.0) - (-0.125f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn lengthscale_scales_distance() {
        let k1 = Stationary1d::matern52(1.0);
        let k2 = Stationary1d::matern52(2.0);
        assert!((k1.eval_dist(1.0) - k2.eval_dist(2.0)).abs() < 1e-15);
    }

    #[test]
    fn toeplitz_column_values() {
        let k = Stationary1d::rbf(1.0);
        let col = k.toeplitz_column(4, 0.5);
        for (j, &c) in col.iter().enumerate() {
            assert!((c - k.eval_dist(j as f64 * 0.5)).abs() < 1e-15);
        }
    }

    #[test]
    fn symmetry() {
        let k = Stationary1d::matern32(0.9);
        assert_eq!(k.eval(0.2, 1.7), k.eval(1.7, 0.2));
    }
}
