//! Coregionalization (task) kernel for multi-task GPs (paper §6).
//!
//! `k_task(i, j) = [B Bᵀ]_{ij}` with `B ∈ ℝ^{s×q}` low rank. The induced
//! n×n factor of the multi-task covariance is `V B Bᵀ Vᵀ` where `V` is the
//! one-hot task-membership matrix; its MVM costs O(n + s·q) because V has
//! one nonzero per row.

use crate::linalg::Matrix;

/// Low-rank coregionalization kernel over `s` tasks.
#[derive(Clone, Debug)]
pub struct TaskKernel {
    /// s × q low-rank factor B.
    pub b: Matrix,
    /// Optional per-task diagonal (task-specific variance), length s.
    pub diag: Vec<f64>,
}

impl TaskKernel {
    /// Random-ish init: B = small values, diag = 1 (caller trains B).
    pub fn new(b: Matrix, diag: Vec<f64>) -> Self {
        assert_eq!(b.rows, diag.len());
        TaskKernel { b, diag }
    }

    /// Identity task kernel (independent tasks).
    pub fn independent(s: usize) -> Self {
        TaskKernel { b: Matrix::zeros(s, 1), diag: vec![1.0; s] }
    }

    /// Number of tasks.
    pub fn num_tasks(&self) -> usize {
        self.b.rows
    }

    /// k_task(i, j) = (B Bᵀ)_{ij} + δ_{ij}·diag_i.
    pub fn eval(&self, i: usize, j: usize) -> f64 {
        let mut v = 0.0;
        for k in 0..self.b.cols {
            v += self.b.get(i, k) * self.b.get(j, k);
        }
        if i == j {
            v += self.diag[i];
        }
        v
    }

    /// Dense s×s task covariance M = B Bᵀ + diag.
    pub fn to_dense(&self) -> Matrix {
        let s = self.num_tasks();
        Matrix::from_fn(s, s, |i, j| self.eval(i, j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_matches_dense() {
        let b = Matrix::from_vec(3, 2, vec![1., 0., 0.5, 0.5, 0., 1.]);
        let k = TaskKernel::new(b, vec![0.1, 0.2, 0.3]);
        let d = k.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert!((k.eval(i, j) - d.get(i, j)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn dense_is_psd_diag_dominant() {
        let b = Matrix::from_vec(2, 1, vec![1.0, -1.0]);
        let k = TaskKernel::new(b, vec![0.5, 0.5]);
        let d = k.to_dense();
        // 2x2 PSD check: diag > 0, det > 0
        assert!(d.get(0, 0) > 0.0);
        assert!(d.get(0, 0) * d.get(1, 1) - d.get(0, 1) * d.get(1, 0) > 0.0);
    }

    #[test]
    fn independent_is_identity() {
        let k = TaskKernel::independent(4);
        let d = k.to_dense();
        assert!(d.max_abs_diff(&Matrix::eye(4)) < 1e-15);
    }
}
