//! Coregionalization (task) kernel for multi-task GPs (paper §6).
//!
//! `k_task(i, j) = [B Bᵀ]_{ij}` with `B ∈ ℝ^{s×q}` low rank. The induced
//! n×n factor of the multi-task covariance is `V B Bᵀ Vᵀ` where `V` is the
//! one-hot task-membership matrix; its MVM costs O(n + s·q) because V has
//! one nonzero per row.

use crate::linalg::Matrix;

/// Low-rank coregionalization kernel over `s` tasks.
#[derive(Clone, Debug)]
pub struct TaskKernel {
    /// s × q low-rank factor B.
    pub b: Matrix,
    /// Optional per-task diagonal (task-specific variance), length s.
    pub diag: Vec<f64>,
}

impl TaskKernel {
    /// Random-ish init: B = small values, diag = 1 (caller trains B).
    pub fn new(b: Matrix, diag: Vec<f64>) -> Self {
        assert_eq!(b.rows, diag.len());
        TaskKernel { b, diag }
    }

    /// Identity task kernel (independent tasks).
    pub fn independent(s: usize) -> Self {
        TaskKernel { b: Matrix::zeros(s, 1), diag: vec![1.0; s] }
    }

    /// Number of tasks.
    pub fn num_tasks(&self) -> usize {
        self.b.rows
    }

    /// k_task(i, j) = (B Bᵀ)_{ij} + δ_{ij}·diag_i.
    pub fn eval(&self, i: usize, j: usize) -> f64 {
        let mut v = 0.0;
        for k in 0..self.b.cols {
            v += self.b.get(i, k) * self.b.get(j, k);
        }
        if i == j {
            v += self.diag[i];
        }
        v
    }

    /// Cross-covariance of `task` against each observation's task:
    /// `c_t[i] = k_task(task, task_of[i])` — the per-row mask that turns
    /// single-task grid caches into task-t caches
    /// ([`crate::serve::build_task_cache`]).
    pub fn row_mask(&self, task: usize, task_of: &[usize]) -> Vec<f64> {
        task_of.iter().map(|&t| self.eval(task, t)).collect()
    }

    /// Dense s×s task covariance M = B Bᵀ + diag.
    pub fn to_dense(&self) -> Matrix {
        let s = self.num_tasks();
        Matrix::from_fn(s, s, |i, j| self.eval(i, j))
    }

    /// Enroll a new task online: append a zero row to `B` (no learned
    /// cross-task coupling yet) and give the newcomer the mean of the
    /// existing task-specific variances (1.0 when starting from an empty
    /// kernel or all-nonpositive diagonals). The zero `B` row keeps every
    /// existing entry of `B Bᵀ + D` bitwise-unchanged, so enrollment never
    /// perturbs the tasks already being served. Returns the new task id.
    pub fn enroll(&mut self) -> usize {
        let s = self.num_tasks();
        let mut d_new = if s == 0 {
            1.0
        } else {
            self.diag.iter().sum::<f64>() / s as f64
        };
        if d_new <= 0.0 || !d_new.is_finite() {
            d_new = 1.0;
        }
        self.b = Matrix::from_fn(s + 1, self.b.cols, |i, j| {
            if i < s {
                self.b.get(i, j)
            } else {
                0.0
            }
        });
        self.diag.push(d_new);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_matches_dense() {
        let b = Matrix::from_vec(3, 2, vec![1., 0., 0.5, 0.5, 0., 1.]);
        let k = TaskKernel::new(b, vec![0.1, 0.2, 0.3]);
        let d = k.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert!((k.eval(i, j) - d.get(i, j)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn dense_is_psd_diag_dominant() {
        let b = Matrix::from_vec(2, 1, vec![1.0, -1.0]);
        let k = TaskKernel::new(b, vec![0.5, 0.5]);
        let d = k.to_dense();
        // 2x2 PSD check: diag > 0, det > 0
        assert!(d.get(0, 0) > 0.0);
        assert!(d.get(0, 0) * d.get(1, 1) - d.get(0, 1) * d.get(1, 0) > 0.0);
    }

    #[test]
    fn independent_is_identity() {
        let k = TaskKernel::independent(4);
        let d = k.to_dense();
        assert!(d.max_abs_diff(&Matrix::eye(4)) < 1e-15);
    }

    #[test]
    fn enroll_appends_a_decoupled_task() {
        let b = Matrix::from_vec(2, 2, vec![1.0, 0.5, -0.25, 2.0]);
        let mut k = TaskKernel::new(b, vec![0.5, 0.3]);
        let before = k.to_dense();
        let id = k.enroll();
        assert_eq!(id, 2);
        assert_eq!(k.num_tasks(), 3);
        // Existing entries are bitwise-unchanged; the new task has no
        // cross-task covariance and the mean of the old diagonals.
        let after = k.to_dense();
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(before.get(i, j).to_bits(), after.get(i, j).to_bits());
            }
            assert_eq!(after.get(2, i), 0.0);
            assert_eq!(after.get(i, 2), 0.0);
        }
        assert!((after.get(2, 2) - 0.4).abs() < 1e-15);
    }

    #[test]
    fn enroll_falls_back_to_unit_variance() {
        let mut k = TaskKernel::new(Matrix::zeros(1, 1), vec![0.0]);
        k.enroll();
        assert_eq!(k.diag[1], 1.0);
    }
}
