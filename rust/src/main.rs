//! skip-gp CLI — Layer-3 entrypoint.
//!
//! ```text
//! skip-gp bench <experiment> [options]   regenerate a paper table/figure
//! skip-gp bench all [options]            run every experiment
//! skip-gp train [options]                train a SKIP GP on a dataset
//! skip-gp snapshot [options]             train + freeze a model snapshot
//! skip-gp serve --snapshot F [options]   serve a frozen snapshot over TCP
//! skip-gp serve --live [options]         serve a LIVE model (accepts observe)
//! skip-gp serve --fleet K [options]      sharded multi-model serving plane
//! skip-gp observe [--addr A] [options]   stream observations to a live server
//! skip-gp artifacts [--dir D]            inspect / smoke-test AOT artifacts
//! skip-gp list                           list datasets and experiments
//! ```
//!
//! (Argument parsing is hand-rolled: no CLI crates are available in this
//! offline build environment.)

#![allow(clippy::needless_range_loop)] // index-heavy numeric test/bench loops

use skip_gp::coordinator::{print_summary, Scheduler};
use skip_gp::data::{dataset_by_name, generate, DATASETS};
use skip_gp::gp::{GpHypers, MvmGp, MvmGpConfig, MvmVariant};
use skip_gp::grid::GridSpec;
use skip_gp::harness::{fig2, fig3, fig4, mtgp_speed, table1, table2};
use skip_gp::runtime::PjrtBackend;
use skip_gp::coordinator::Metrics;
use skip_gp::serve::{
    BatcherConfig, FleetConfig, FleetServer, ModelRegistry, ModelSnapshot,
    RegistryConfig, ServeEngine, Server, ServerConfig, ShardedModel, SnapshotConfig,
    VarianceMode,
};
use skip_gp::solvers::SolverPolicy;
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::{mae, Timer};
use skip_gp::{Error, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// Parsed `--key value` / `--flag` options.
struct Opts {
    map: HashMap<String, String>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Self> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let is_flag = i + 1 >= args.len() || args[i + 1].starts_with("--");
                if is_flag {
                    map.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else {
                    map.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                }
            } else {
                return Err(Error::Config(format!("unexpected argument '{a}'")));
            }
        }
        Ok(Opts { map })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("bad value for --{key}: '{v}'"))),
        }
    }

    fn get_str(&self, key: &str) -> Option<String> {
        self.map.get(key).cloned()
    }

    fn flag(&self, key: &str) -> bool {
        self.map.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

/// Parse a `--grid` value into a [`GridSpec`]:
/// `"64"` → uniform 64/dim, `"32x16x8"` → per-dimension sizes,
/// `"sparse:3"` → combination-technique sparse grid at level 3.
fn parse_grid_spec(s: &str) -> Result<GridSpec> {
    if let Some(level) = s.strip_prefix("sparse:") {
        let level: usize = level
            .parse()
            .map_err(|_| Error::Config(format!("bad sparse level in --grid '{s}'")))?;
        return Ok(GridSpec::sparse(level));
    }
    if s.contains('x') {
        let sizes = s
            .split('x')
            .map(|tok| {
                tok.parse::<usize>()
                    .map_err(|_| Error::Config(format!("bad size '{tok}' in --grid '{s}'")))
            })
            .collect::<Result<Vec<usize>>>()?;
        return Ok(GridSpec::Rectilinear(sizes));
    }
    let m: usize = s
        .parse()
        .map_err(|_| Error::Config(format!("bad value for --grid: '{s}'")))?;
    Ok(GridSpec::uniform(m))
}

/// Parse the `--precond` / `--space` / `--precision` flags into the
/// shared [`SolverPolicy`] — the one solver-flag parser every
/// subcommand (`train`, `snapshot`, `serve --live`) routes through, so
/// grammar and error wordings cannot drift between entrypoints.
fn parse_policy(opts: &Opts) -> Result<SolverPolicy> {
    SolverPolicy::from_cli(
        opts.get_str("precond").as_deref(),
        opts.get_str("space").as_deref(),
        opts.get_str("precision").as_deref(),
    )
}

fn usage() -> ! {
    eprintln!(
        "skip-gp — Product Kernel Interpolation for Scalable Gaussian Processes

USAGE:
  skip-gp bench <fig2-left|fig2-right|table1|table2|fig3|fig4|mtgp-speedup|all>
                [--out-dir D] [--scale F] [--steps N] [--rank R] [--seed S]
                [--dataset NAME] [--trials N] [--n N] [--full]
  skip-gp train  [--dataset NAME] [--scale F] [--steps N] [--rank R]
                 [--grid M|M1xM2x…|sparse:L] [--variant skip|kiss]
                 [--precond rank:K|jacobi|none] [--space auto|data|grid]
                 [--precision f64|mixed] [--pjrt]
  skip-gp snapshot [--dataset NAME] [--scale F] [--steps N] [--rank R]
                   [--grid M|M1xM2x…|sparse:L] [--variant skip|kiss] [--out F]
                   [--serve-grid M|M1xM2x…|sparse:L]
                   [--precond rank:K|jacobi|none] [--space auto|data|grid]
                   [--precision f64|mixed]
                   [--var exact|lanczos|none] [--var-rank R]
  skip-gp serve  --snapshot F [--bind ADDR] [--max-batch N] [--max-wait-ms F]
  skip-gp serve  --live [--dataset NAME] [--scale F] [--steps N]
                 [--grid M|M1xM2x…] [--precond rank:K|jacobi|none]
                 [--space auto|data|grid] [--precision f64|mixed]
                 [--var exact|lanczos|none] [--var-rank R]
                 [--refresh-every N] [--var-drift N] [--error-z F]
                 [--log-capacity N] [--snapshot-out F] [--replay F]
                 [--bind ADDR] [--max-batch N] [--max-wait-ms F]
  skip-gp serve  --fleet K [--models DIR] [--snapshot F] [--model-id ID]
                 [--bind ADDR] [--workers N] [--max-inflight N] [--max-conns N]
                 [--mem-budget-mb N] [--grace-ms N]
                 [--max-batch N] [--max-wait-ms F]
                 (K shards per model; add --live for a single-shard live
                  model. Wire verbs grow `model <id>` prefixes + `models`.)
  skip-gp observe [--addr HOST:PORT] [--file F | --point \"x1 … xd y\"]
                 (default: reads `[task] x1 … xd y [grad g1 … gd]` lines
                  from stdin — the task id when the server is multi-task,
                  the grad clause for derivative observations, D-SKI)
  skip-gp artifacts [--dir D]
  skip-gp list"
    );
    std::process::exit(2)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let cmd = args[0].as_str();
    let rest = &args[1..];
    let code = match cmd {
        "bench" => cmd_bench(rest),
        "train" => cmd_train(rest),
        "snapshot" => cmd_snapshot(rest),
        "serve" => cmd_serve(rest),
        "observe" => cmd_observe(rest),
        "artifacts" => cmd_artifacts(rest),
        "list" => cmd_list(),
        "-h" | "--help" | "help" => usage(),
        other => {
            eprintln!("unknown command '{other}'");
            usage();
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_list() -> Result<()> {
    println!("datasets (synthetic surrogates, paper shapes):");
    for s in DATASETS {
        println!("  {:<14} n={:<7} d={}", s.name, s.n, s.d);
    }
    println!("\nexperiments: fig2-left fig2-right table1 table2 fig3 fig4 mtgp-speedup all");
    Ok(())
}

fn cmd_artifacts(rest: &[String]) -> Result<()> {
    let opts = Opts::parse(rest)?;
    let dir = PathBuf::from(
        opts.get_str("dir").unwrap_or_else(|| "artifacts".to_string()),
    );
    let entries = skip_gp::runtime::load_manifest(&dir)?;
    println!("{} artifacts in {}:", entries.len(), dir.display());
    for e in &entries {
        println!("  {:<28} op={:<14} dims={:?}", e.name, e.op, e.dims);
    }
    // Smoke-test: compile + run the hadamard artifacts against native.
    let backend = PjrtBackend::load(&dir)?;
    use skip_gp::linalg::Matrix;
    use skip_gp::operators::lowrank::{
        hadamard_pair_matvec_native, ContractionBackend, LanczosFactor,
    };
    use skip_gp::util::{rel_err, Rng};
    let mut rng = Rng::new(0);
    let (n, r) = (1024, 16);
    let q = Matrix::from_fn(n, r, |_, _| rng.normal());
    let mut t = Matrix::from_fn(r, r, |_, _| rng.normal());
    t.symmetrize();
    let f = LanczosFactor { q, t };
    let v = rng.normal_vec(n);
    let got = backend.hadamard_pair_matvec(&f, &f, &v);
    let want = hadamard_pair_matvec_native(&f, &f, &v);
    let err = rel_err(&got, &want);
    let (pjrt, native) = backend.call_counts();
    println!("smoke test: rel_err={err:.2e} (pjrt calls {pjrt}, native {native})");
    if err > 1e-8 || pjrt == 0 {
        return Err(Error::Artifact("artifact smoke test failed".into()));
    }
    println!("artifacts OK");
    Ok(())
}

fn cmd_train(rest: &[String]) -> Result<()> {
    let opts = Opts::parse(rest)?;
    let name = opts.get_str("dataset").unwrap_or_else(|| "protein".into());
    let spec = dataset_by_name(&name)
        .ok_or_else(|| Error::Config(format!("unknown dataset '{name}'")))?;
    let scale: f64 = opts.get("scale", 0.05)?;
    let steps: usize = opts.get("steps", 10)?;
    let rank: usize = opts.get("rank", 15)?;
    let grid = parse_grid_spec(&opts.get_str("grid").unwrap_or_else(|| "100".into()))?;
    let policy = parse_policy(&opts)?;
    let variant = match opts.get_str("variant").as_deref() {
        None | Some("skip") => MvmVariant::Skip,
        Some("kiss") => MvmVariant::Kiss,
        Some(v) => return Err(Error::Config(format!("unknown variant '{v}'"))),
    };
    let data = generate(spec, scale);
    println!(
        "training {} GP on {} (n={}, d={}, grid {}, steps={steps}, precond {})",
        if variant == MvmVariant::Skip { "SKIP" } else { "KISS" },
        name,
        data.n(),
        data.d(),
        grid.describe(),
        policy.precond.describe()
    );
    let cfg = MvmGpConfig { variant, grid, rank, policy, ..Default::default() };
    let mut gp = MvmGp::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        cfg,
    );
    if opts.flag("pjrt") {
        let backend = Arc::new(PjrtBackend::load(&PathBuf::from("artifacts"))?);
        gp = gp.with_backend(backend);
        println!("using PJRT contraction backend");
    }
    let t = Timer::start();
    let trace = gp.fit(steps, 0.1)?;
    let train_s = t.elapsed_s();
    for (i, mll) in trace.iter().enumerate() {
        println!("  step {i:>3}  mll/n = {:.4}", mll / data.n() as f64);
    }
    let pred = gp.predict_mean(&data.xtest);
    println!(
        "train {train_s:.1}s   test MAE {:.4}   hypers: ell={:.3} sf2={:.3} sn2={:.4}",
        mae(&pred, &data.ytest),
        gp.hypers.ell(),
        gp.hypers.sf2(),
        gp.hypers.sn2()
    );
    let solvers = skip_gp::coordinator::metrics::global().solver_report();
    if !solvers.is_empty() {
        println!("solver effort:\n{solvers}");
    }
    Ok(())
}

/// Train a model (like `train`) and freeze it into a snapshot file.
fn cmd_snapshot(rest: &[String]) -> Result<()> {
    let opts = Opts::parse(rest)?;
    let name = opts.get_str("dataset").unwrap_or_else(|| "power".into());
    let spec = dataset_by_name(&name)
        .ok_or_else(|| Error::Config(format!("unknown dataset '{name}'")))?;
    let scale: f64 = opts.get("scale", 0.05)?;
    let steps: usize = opts.get("steps", 10)?;
    let rank: usize = opts.get("rank", 15)?;
    let grid = parse_grid_spec(&opts.get_str("grid").unwrap_or_else(|| "64".into()))?;
    let out = PathBuf::from(opts.get_str("out").unwrap_or_else(|| "model.snap".into()));
    let variant = match opts.get_str("variant").as_deref() {
        None | Some("skip") => MvmVariant::Skip,
        Some("kiss") => MvmVariant::Kiss,
        Some(v) => return Err(Error::Config(format!("unknown variant '{v}'"))),
    };
    let var_rank: usize = opts.get("var-rank", 64)?;
    let variance = match opts.get_str("var").as_deref() {
        None | Some("lanczos") => VarianceMode::Lanczos(var_rank),
        Some("exact") => VarianceMode::Exact,
        Some("none") => VarianceMode::None,
        Some(v) => return Err(Error::Config(format!("unknown variance mode '{v}'"))),
    };
    let policy = parse_policy(&opts)?;
    let data = generate(spec, scale);
    println!(
        "training {} GP on {} (n={}, d={}, grid {}, steps={steps}, precond {})",
        if variant == MvmVariant::Skip { "SKIP" } else { "KISS" },
        name,
        data.n(),
        data.d(),
        grid.describe(),
        policy.precond.describe()
    );
    let cfg = MvmGpConfig { variant, grid, rank, policy, ..Default::default() };
    let mut gp = MvmGp::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        cfg,
    );
    let t = Timer::start();
    gp.fit(steps, 0.1)?;
    let train_s = t.elapsed_s();
    let pred = gp.predict_mean(&data.xtest);
    println!(
        "trained in {train_s:.1}s, test MAE {:.4}; building predictive caches…",
        mae(&pred, &data.ytest)
    );
    let t = Timer::start();
    let serve_grid = match opts.get_str("serve-grid") {
        None => None,
        Some(s) => Some(parse_grid_spec(&s)?),
    };
    let snap = ModelSnapshot::from_mvm(
        &gp,
        &SnapshotConfig {
            grid: serve_grid,
            variance,
            policy: Some(policy),
            ..Default::default()
        },
    )?;
    snap.save(&out)?;
    let bytes = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!(
        "wrote {} ({} grid cells, variance rank {}, cache built in {:.2}s, {} bytes)",
        out.display(),
        snap.cache.total_grid(),
        snap.cache.var_rank(),
        t.elapsed_s(),
        bytes
    );
    let solvers = skip_gp::coordinator::metrics::global().solver_report();
    if !solvers.is_empty() {
        println!("solver effort:\n{solvers}");
    }
    Ok(())
}

/// Train (or just refresh) a KISS model and put it behind the streaming
/// layer, honoring the `serve --live` options; `observe` requests ingest
/// into the returned state online.
fn build_live_state(opts: &Opts) -> Result<IncrementalState> {
    let name = opts.get_str("dataset").unwrap_or_else(|| "power".into());
    let spec = dataset_by_name(&name)
        .ok_or_else(|| Error::Config(format!("unknown dataset '{name}'")))?;
    let scale: f64 = opts.get("scale", 0.05)?;
    let steps: usize = opts.get("steps", 10)?;
    let grid = parse_grid_spec(&opts.get_str("grid").unwrap_or_else(|| "32".into()))?;
    let policy = parse_policy(opts)?;
    let var_rank: usize = opts.get("var-rank", 64)?;
    let variance = match opts.get_str("var").as_deref() {
        None | Some("lanczos") => VarianceMode::Lanczos(var_rank),
        Some("exact") => VarianceMode::Exact,
        Some("none") => VarianceMode::None,
        Some(v) => return Err(Error::Config(format!("unknown variance mode '{v}'"))),
    };
    let data = generate(spec, scale);
    let cfg = MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid,
        policy,
        ..Default::default()
    };
    let mut gp = MvmGp::new(
        data.xtrain.clone(),
        data.ytrain.clone(),
        GpHypers::init_for_dim(data.d()),
        cfg,
    );
    if steps > 0 {
        println!("training on {name} for {steps} steps before going live…");
        gp.fit(steps, 0.1)?;
    }
    let scfg = StreamConfig {
        refresh_every: opts.get("refresh-every", 256)?,
        var_drift_budget: opts.get("var-drift", 32)?,
        error_z: opts.get("error-z", 8.0)?,
        log_capacity: opts.get("log-capacity", 1024)?,
        variance,
        policy,
        ..Default::default()
    };
    let mut live = IncrementalState::from_mvm(&gp, scfg)?;
    // Resume a previous live session: replay the pending log of a
    // checkpoint taken over the same base dataset. (The base model
    // above does not contain those streamed points, so replay is
    // exactly once; see the snapshot-format docs.) The replay window
    // is the last refresh — points a full refresh absorbed before
    // the checkpoint are not recoverable from the snapshot alone.
    if let Some(replay) = opts.get_str("replay") {
        let ckpt = ModelSnapshot::load(&PathBuf::from(&replay))?;
        let report = live.ingest_observations(&ckpt.pending)?;
        println!(
            "replayed {} of {} pending observations from {replay} \
             ({} duplicates)",
            report.accepted,
            ckpt.pending.len(),
            report.duplicates
        );
    }
    println!(
        "live model on {name}: n={}, d={}, grid {}, precond {} \
         (observe verb enabled)",
        live.n(),
        live.dim(),
        gp.cfg.grid.describe(),
        policy.precond.describe()
    );
    Ok(live)
}

/// Serve a snapshot (frozen) or a live model over the TCP line protocol
/// until interrupted.
fn cmd_serve(rest: &[String]) -> Result<()> {
    let opts = Opts::parse(rest)?;
    // `--fleet K` (bare `--fleet` means 4 shards) switches to the
    // sharded multi-model serving plane.
    match opts.get_str("fleet") {
        None => {}
        Some(v) if v == "true" => return cmd_serve_fleet(&opts, 4),
        Some(v) => {
            let k: usize = v.parse().map_err(|_| {
                Error::Config(format!("bad value for --fleet: '{v}'"))
            })?;
            return cmd_serve_fleet(&opts, k);
        }
    }
    let bind = opts.get_str("bind").unwrap_or_else(|| "127.0.0.1:7470".into());
    let max_batch: usize = opts.get("max-batch", 64)?;
    let max_wait_ms: f64 = opts.get("max-wait-ms", 2.0)?;
    let snapshot_out = opts.get_str("snapshot-out").map(PathBuf::from);

    let engine = if opts.flag("live") {
        Arc::new(ServeEngine::new_live(build_live_state(&opts)?)?)
    } else {
        let path = PathBuf::from(opts.get_str("snapshot").ok_or_else(|| {
            Error::Config("serve requires --snapshot FILE (or --live)".into())
        })?);
        let snap = ModelSnapshot::load(&path)?;
        println!(
            "loaded {} (d={}, {} grid cells, variance rank {}, format v{}, \
             {} pending observations)",
            path.display(),
            snap.cache.dim(),
            snap.cache.total_grid(),
            snap.cache.var_rank(),
            snap.version,
            snap.pending.len()
        );
        Arc::new(ServeEngine::new(snap)?)
    };

    let server = Server::start(
        engine.clone(),
        ServerConfig {
            bind,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_secs_f64(max_wait_ms / 1e3),
            },
        },
    )?;
    println!(
        "serving on {} (line protocol: `predict x1 … xd`, `observe x1 … xd y \
         [grad g1 … gd]`, `stats`, `quit` — see docs/PROTOCOL.md)",
        server.addr()
    );
    // Foreground serving loop: periodic stats (and, for live engines,
    // snapshot checkpoints) until the process is killed.
    loop {
        std::thread::sleep(Duration::from_secs(30));
        println!("stats: {}", engine.stats_line());
        let streams = engine.metrics.stream_report();
        if !streams.is_empty() {
            print!("{streams}");
        }
        if let Some(out) = &snapshot_out {
            // A failed checkpoint (disk full, directory vanished) must
            // not take the live server down — log it and retry on the
            // next tick.
            match engine.save_snapshot(out) {
                Ok(()) => println!("checkpointed {}", out.display()),
                Err(e) => eprintln!("checkpoint to {} failed: {e}", out.display()),
            }
        }
    }
}

/// `serve --fleet K`: the sharded multi-model serving plane — a model
/// registry (lazy loads from `--models DIR`, LRU eviction under
/// `--mem-budget-mb`), k replica shards per model, and the bounded
/// reactor front-end with admission control.
fn cmd_serve_fleet(opts: &Opts, k: usize) -> Result<()> {
    let k = k.max(1);
    let bind = opts.get_str("bind").unwrap_or_else(|| "127.0.0.1:7470".into());
    let max_batch: usize = opts.get("max-batch", 64)?;
    let max_wait_ms: f64 = opts.get("max-wait-ms", 2.0)?;
    let snapshot_out = opts.get_str("snapshot-out").map(PathBuf::from);
    let batcher = BatcherConfig {
        max_batch,
        max_wait: Duration::from_secs_f64(max_wait_ms / 1e3),
    };
    let models_dir = opts.get_str("models").map(PathBuf::from);
    let mem_budget_mb: usize = opts.get("mem-budget-mb", 0)?;
    let metrics = Arc::new(Metrics::new());
    let registry = Arc::new(ModelRegistry::new(
        RegistryConfig {
            dir: models_dir.clone(),
            memory_budget: mem_budget_mb << 20,
            shards: k,
            batcher,
        },
        metrics.clone(),
    ));

    // Pre-place the explicitly named model (if any); it becomes the
    // default for requests without a `model <id>` prefix.
    let mut default_model: Option<String> = None;
    let mut checkpoint_model: Option<Arc<ShardedModel>> = None;
    if opts.flag("live") {
        if k > 1 {
            return Err(Error::Config(
                "--live models are single-shard (replicated incremental \
                 state would need cross-shard write fan-out); use \
                 --fleet 1 --live"
                    .into(),
            ));
        }
        let id = opts.get_str("model-id").unwrap_or_else(|| "live".into());
        let model = ShardedModel::live(&id, build_live_state(opts)?, batcher, metrics.clone())?;
        // Pinned: evicting a live model would discard un-checkpointed
        // observations.
        checkpoint_model = Some(registry.insert(model, true));
        default_model = Some(id);
    } else if let Some(path) = opts.get_str("snapshot") {
        let path = PathBuf::from(path);
        let id = opts.get_str("model-id").unwrap_or_else(|| {
            match path.file_stem().map(|s| s.to_string_lossy().into_owned()) {
                Some(stem) if skip_gp::serve::fleet::registry::valid_id(&stem) => stem,
                _ => "default".to_string(),
            }
        });
        let snap = ModelSnapshot::load(&path)?;
        println!(
            "loaded {} as model '{id}' (d={}, {} grid cells, {k} shards)",
            path.display(),
            snap.cache.dim(),
            snap.cache.total_grid(),
        );
        let model = ShardedModel::from_snapshot(&id, snap, k, batcher, metrics.clone())?;
        registry.insert(model, true);
        default_model = Some(id);
    } else if models_dir.is_none() {
        return Err(Error::Config(
            "serve --fleet needs a model source: --snapshot FILE, \
             --models DIR, or --live"
                .into(),
        ));
    }
    if default_model.is_none() {
        if let Some(id) = opts.get_str("model-id") {
            default_model = Some(id); // lazily loaded on first request
        }
    }

    let server = FleetServer::start(
        registry.clone(),
        FleetConfig {
            bind,
            workers: opts.get("workers", 0)?,
            max_inflight: opts.get("max-inflight", 1024)?,
            max_conns: opts.get("max-conns", 16384)?,
            grace: Duration::from_millis(opts.get("grace-ms", 500u64)?),
            default_model,
        },
    )?;
    println!(
        "fleet serving on {} ({k} shards/model; verbs: \
         `[model <id>] predict x1 … xd`, `[model <id>] observe x1 … xd y`, \
         `models`, `stats`, `quit`)",
        server.addr()
    );
    // Foreground serving loop: periodic fleet stats (and, for a live
    // model, snapshot checkpoints) until the process is killed.
    loop {
        std::thread::sleep(Duration::from_secs(30));
        println!("stats: {}", server.stats_line());
        let fleet = metrics.fleet_report();
        if !fleet.is_empty() {
            print!("{fleet}");
        }
        if let (Some(out), Some(model)) = (&snapshot_out, &checkpoint_model) {
            // Same policy as the legacy loop: a failed checkpoint must
            // not take the live server down.
            match model.engine(0).save_snapshot(out) {
                Ok(()) => println!("checkpointed {}", out.display()),
                Err(e) => eprintln!("checkpoint to {} failed: {e}", out.display()),
            }
        }
    }
}

/// Ask the server a single-number question (`dim` / `tasks`) and parse
/// the `ok <n>` answer.
fn wire_query(
    writer: &mut impl std::io::Write,
    reader: &mut impl std::io::BufRead,
    verb: &str,
) -> Result<usize> {
    writeln!(writer, "{verb}")?;
    let mut resp = String::new();
    if reader.read_line(&mut resp)? == 0 {
        return Err(Error::Config("server closed the connection".into()));
    }
    let r = resp.trim();
    r.strip_prefix("ok ")
        .and_then(|v| v.trim().parse::<usize>().ok())
        .ok_or_else(|| Error::Config(format!("unexpected `{verb}` response: {r}")))
}

/// Stream observations from stdin / a file / a single `--point` to a
/// running live server, printing each ack. Input lines are
/// `[task] x1 … xd y [grad g1 … gd]` — validated and formatted through
/// the shared wire parser ([`skip_gp::serve::protocol`]), so a malformed
/// line is reported locally without costing a round-trip.
fn cmd_observe(rest: &[String]) -> Result<()> {
    use skip_gp::serve::protocol::{self, ModelShape, Request};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let opts = Opts::parse(rest)?;
    let addr = opts.get_str("addr").unwrap_or_else(|| "127.0.0.1:7470".into());
    let stream = TcpStream::connect(&addr)
        .map_err(|e| Error::Config(format!("cannot connect to {addr}: {e}")))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    // Handshake: learn the model's shape so lines parse the same way
    // they will on the server (task-led forms on multi-task models).
    let dim = wire_query(&mut writer, &mut reader, "dim")?;
    let num_tasks = wire_query(&mut writer, &mut reader, "tasks")?;
    let shape = ModelShape { dim, num_tasks, multitask: num_tasks > 1 };

    let input: Box<dyn BufRead> = match (opts.get_str("file"), opts.get_str("point")) {
        (Some(f), _) => Box::new(BufReader::new(std::fs::File::open(f)?)),
        (None, Some(p)) => Box::new(std::io::Cursor::new(p.into_bytes())),
        (None, None) => {
            eprintln!(
                "reading `[task] x1 … x{dim} y [grad g1 … g{dim}]` lines \
                 from stdin (^D to finish)"
            );
            Box::new(BufReader::new(std::io::stdin()))
        }
    };

    let (mut sent, mut acked, mut dups, mut errs) = (0u64, 0u64, 0u64, 0u64);
    let mut resp = String::new();
    for line in input.lines() {
        let line = line?;
        let obs = line.trim();
        if obs.is_empty() || obs.starts_with('#') {
            continue;
        }
        let req = match protocol::parse_observe(obs, &shape) {
            Ok(o) => Request::Observe(o),
            Err(msg) => {
                println!("err {msg}");
                errs += 1;
                continue;
            }
        };
        writeln!(writer, "{}", protocol::format_request(&req, shape.multitask))?;
        sent += 1;
        resp.clear();
        if reader.read_line(&mut resp)? == 0 {
            return Err(Error::Config("server closed the connection".into()));
        }
        let r = resp.trim();
        println!("{r}");
        if r.starts_with("ok dup") {
            dups += 1;
        } else if r.starts_with("ok") {
            acked += 1;
        } else {
            errs += 1;
        }
    }
    writeln!(writer, "quit").ok();
    println!("observed {acked}/{sent} points ({dups} duplicates, {errs} errors)");
    Ok(())
}

fn cmd_bench(rest: &[String]) -> Result<()> {
    if rest.is_empty() {
        usage();
    }
    let exp = rest[0].as_str();
    let opts = Opts::parse(&rest[1..])?;
    let out_dir = PathBuf::from(
        opts.get_str("out-dir").unwrap_or_else(|| "results".to_string()),
    );
    let seed: u64 = opts.get("seed", 0)?;
    let full = opts.flag("full");

    let run_fig2_left = {
        let out = out_dir.clone();
        let n: usize = opts.get("n", if full { 2500 } else { 1200 })?;
        let trials: usize = opts.get("trials", if full { 10 } else { 4 })?;
        move || {
            fig2::fig2_left(
                &fig2::Fig2LeftConfig { n, trials, seed, ..Default::default() },
                &out,
            )
        }
    };
    let run_fig2_right = {
        let out = out_dir.clone();
        let n: usize = opts.get("n", if full { 2500 } else { 1500 })?;
        move || {
            fig2::fig2_right(
                &fig2::Fig2RightConfig { n, seed, ..Default::default() },
                &out,
            )
        }
    };
    let run_table1 = {
        let out = out_dir.clone();
        let cfg = table1::Table1Config {
            scale: opts.get("scale", if full { 0.25 } else { 0.06 })?,
            steps: opts.get("steps", if full { 20 } else { 8 })?,
            rank: opts.get("rank", 30)?,
            only: opts.get_str("dataset"),
            seed,
            ..Default::default()
        };
        move || table1::table1(&cfg, &out).map(|_| ())
    };
    let run_table2 = {
        let out = out_dir.clone();
        let cfg = table2::Table2Config {
            ns: if full {
                vec![512, 1024, 2048, 4096]
            } else {
                vec![256, 512, 1024, 2048]
            },
            seed,
            ..Default::default()
        };
        move || table2::table2(&cfg, &out).map(|_| ())
    };
    let run_fig3 = {
        let out = out_dir.clone();
        let cfg = fig3::Fig3Config {
            num_children: opts.get("n", if full { 30 } else { 20 })?,
            gibbs_sweeps: opts.get("steps", if full { 8 } else { 5 })?,
            seed,
            ..Default::default()
        };
        move || fig3::fig3(&cfg, &out).map(|_| ())
    };
    let run_fig4 = {
        let out = out_dir.clone();
        let cfg = fig4::Fig4Config {
            task_counts: if full {
                vec![16, 24, 36, 48, 64]
            } else {
                vec![16, 24, 36]
            },
            mtgp_steps: opts.get("steps", if full { 15 } else { 10 })?,
            seed,
            ..Default::default()
        };
        move || fig4::fig4(&cfg, &out).map(|_| ())
    };
    let run_speedup = {
        let out = out_dir.clone();
        let cfg = mtgp_speed::MtgpSpeedConfig {
            ns: if full {
                vec![500, 1000, 2000, 4000]
            } else {
                vec![500, 1000, 2000]
            },
            seed,
        };
        move || mtgp_speed::mtgp_speedup(&cfg, &out).map(|_| ())
    };

    let mut sched = Scheduler::new();
    match exp {
        "fig2-left" => sched.add("fig2-left", run_fig2_left),
        "fig2-right" => sched.add("fig2-right", run_fig2_right),
        "table1" => sched.add("table1", run_table1),
        "table2" => sched.add("table2", run_table2),
        "fig3" => sched.add("fig3", run_fig3),
        "fig4" => sched.add("fig4", run_fig4),
        "mtgp-speedup" => sched.add("mtgp-speedup", run_speedup),
        "all" => {
            sched.add("fig2-left", run_fig2_left);
            sched.add("fig2-right", run_fig2_right);
            sched.add("table1", run_table1);
            sched.add("table2", run_table2);
            sched.add("fig3", run_fig3);
            sched.add("fig4", run_fig4);
            sched.add("mtgp-speedup", run_speedup);
        }
        other => {
            eprintln!("unknown experiment '{other}'");
            usage();
        }
    }
    let reports = sched.run_all();
    print_summary(&reports);
    if reports
        .iter()
        .any(|r| matches!(r.status, skip_gp::coordinator::JobStatus::Failed(_)))
    {
        return Err(Error::Config("one or more experiments failed".into()));
    }
    Ok(())
}
