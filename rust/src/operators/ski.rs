//! SKI operator: `K_XX ≈ W K_UU Wᵀ` on a 1-D regular grid (paper §2.3).
//!
//! With `W` 4-sparse per row and `K_UU` symmetric Toeplitz, `matvec` costs
//! O(n + m log m) — the building block SKIP multiplies together.

use super::interp::{Grid1d, InterpMatrix};
use super::{LinearOp, LinearOpF32};
use crate::kernels::Stationary1d;
use crate::linalg::{Matrix, SymToeplitz};
use crate::Result;

/// 1-D structured-kernel-interpolation operator.
pub struct SkiOp {
    pub w: InterpMatrix,
    pub kuu: SymToeplitz,
    pub grid: Grid1d,
}

impl SkiOp {
    /// Build for 1-D inputs `xs` under kernel `kern` on an m-point grid.
    /// Degenerate inputs (constant column, m too small for the margin
    /// fit) surface as [`crate::Error::Grid`].
    pub fn new(xs: &[f64], kern: &Stationary1d, m: usize) -> Result<Self> {
        let (lo, hi) = xs
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &x| {
                (a.min(x), b.max(x))
            });
        let grid = Grid1d::fit(lo, hi, m)?;
        let w = InterpMatrix::new(xs, &grid);
        let kuu = SymToeplitz::new(kern.toeplitz_column(grid.m, grid.h));
        Ok(SkiOp { w, kuu, grid })
    }

    /// Build with an existing grid (cross-covariance for prediction reuses
    /// the training grid).
    pub fn with_grid(xs: &[f64], kern: &Stationary1d, grid: Grid1d) -> Self {
        let w = InterpMatrix::new(xs, &grid);
        let kuu = SymToeplitz::new(kern.toeplitz_column(grid.m, grid.h));
        SkiOp { w, kuu, grid }
    }

    /// Number of inducing points.
    pub fn num_inducing(&self) -> usize {
        self.grid.m
    }

    /// Cross-MVM `W_a K_UU W_bᵀ v` against another point set's
    /// interpolation matrix (for test-train covariances).
    pub fn cross_matvec(&self, other_w: &InterpMatrix, v: &[f64]) -> Vec<f64> {
        let t = other_w.t_matvec(v);
        let t = self.kuu.matvec(&t);
        self.w.matvec(&t)
    }
}

/// Per-solve f32 mirror of [`SkiOp`]: owned f32 stencil weights plus the
/// Toeplitz factor's lazily cached f32 spectrum. Built fresh by
/// [`LinearOp::as_f32`] so there is no cache to invalidate when operators
/// are rebuilt.
struct SkiF32<'a> {
    op: &'a SkiOp,
    w32: Vec<f32>,
}

impl LinearOpF32 for SkiF32<'_> {
    fn dim(&self) -> usize {
        self.op.w.n
    }

    fn matvec_f32(&self, v: &[f32]) -> Vec<f32> {
        let t = self.op.w.t_matvec_f32_with(&self.w32, v);
        let t = self.op.kuu.matvec_f32(&t);
        self.op.w.matvec_f32_with(&self.w32, &t)
    }
}

impl LinearOp for SkiOp {
    fn dim(&self) -> usize {
        self.w.n
    }

    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        Some(Box::new(SkiF32 { op: self, w32: self.w.weights_f32() }))
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        // Wᵀ v: O(n) → K_UU ·: O(m log m) → W ·: O(n)
        let t = self.w.t_matvec(v);
        let t = self.kuu.matvec(&t);
        self.w.matvec(&t)
    }

    /// Fast path: the whole n×t block rides through the structure in one
    /// pass — `Wᵀ M` (streaming scatter, all columns per touch), a
    /// pair-batched Toeplitz `matmat` (2 columns per complex FFT, parallel
    /// across pairs), then `W ·`. O(n·t + t·m log m) with roughly half the
    /// FFTs and 1/t the stencil-index traffic of the serial column loop.
    fn matmat(&self, m: &Matrix) -> Matrix {
        let t = self.w.t_matmat(m);
        let t = self.kuu.matmat(&t);
        self.w.matmat(&t)
    }

    /// Exact diagonal in O(n): `diag_i = w_i K_UU w_iᵀ` contracts each
    /// row's 4-wide stencil against the Toeplitz column
    /// (`K_UU[a,b] = t[|a−b|]`) — no MVMs, which is what makes adaptive
    /// pivoted-Cholesky preconditioning of SKI-backed covariances cheap.
    fn diag(&self) -> Option<Vec<f64>> {
        use super::interp::STENCIL;
        let mut out = Vec::with_capacity(self.w.n);
        for i in 0..self.w.n {
            let base = i * STENCIL;
            let idx = &self.w.idx[base..base + STENCIL];
            let wts = &self.w.w[base..base + STENCIL];
            let mut acc = 0.0;
            for (a, &wa) in wts.iter().enumerate() {
                for (b, &wb) in wts.iter().enumerate() {
                    let lag = idx[a].abs_diff(idx[b]) as usize;
                    acc += wa * wb * self.kuu.col[lag];
                }
            }
            out.push(acc);
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::{rel_err, Rng};

    #[test]
    fn ski_mvm_close_to_exact_kernel_mvm() {
        let kern = Stationary1d::rbf(0.4);
        let mut rng = Rng::new(8);
        let xs = rng.uniform_vec(200, -1.0, 1.0);
        let op = SkiOp::new(&xs, &kern, 128).unwrap();
        let exact = Matrix::from_fn(200, 200, |i, j| kern.eval(xs[i], xs[j]));
        let v = rng.normal_vec(200);
        let got = op.matvec(&v);
        let want = exact.matvec(&v);
        assert!(rel_err(&got, &want) < 1e-3, "rel err {}", rel_err(&got, &want));
    }

    #[test]
    fn error_decreases_with_grid_size() {
        let kern = Stationary1d::rbf(0.5);
        let mut rng = Rng::new(9);
        let xs = rng.uniform_vec(100, 0.0, 1.0);
        let exact = Matrix::from_fn(100, 100, |i, j| kern.eval(xs[i], xs[j]));
        let v = rng.normal_vec(100);
        let want = exact.matvec(&v);
        let mut last = f64::INFINITY;
        for m in [16usize, 32, 64, 128] {
            let op = SkiOp::new(&xs, &kern, m).unwrap();
            let err = rel_err(&op.matvec(&v), &want);
            assert!(err < last * 1.5, "m={m} err={err} last={last}");
            last = err;
        }
        assert!(last < 1e-4, "finest grid err {last}");
    }

    #[test]
    fn operator_is_symmetric() {
        let kern = Stationary1d::matern52(0.7);
        let mut rng = Rng::new(10);
        let xs = rng.uniform_vec(50, 0.0, 3.0);
        let op = SkiOp::new(&xs, &kern, 40).unwrap();
        let u = rng.normal_vec(50);
        let v = rng.normal_vec(50);
        let lhs: f64 = op.matvec(&u).iter().zip(&v).map(|(a, b)| a * b).sum();
        let rhs: f64 = op.matvec(&v).iter().zip(&u).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn diag_matches_dense_materialization() {
        let kern = Stationary1d::rbf(0.5);
        let mut rng = Rng::new(12);
        let xs = rng.uniform_vec(60, -1.0, 1.0);
        let op = SkiOp::new(&xs, &kern, 32).unwrap();
        let want = op.to_dense().diagonal();
        let got = op.diag().unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10, "{g} vs {w}");
        }
    }

    #[test]
    fn cross_matvec_matches_dense() {
        let kern = Stationary1d::rbf(0.6);
        let mut rng = Rng::new(11);
        let xs = rng.uniform_vec(40, 0.0, 1.0);
        let ts = rng.uniform_vec(15, 0.1, 0.9);
        let op = SkiOp::new(&xs, &kern, 64).unwrap();
        let wt = InterpMatrix::new(&ts, &op.grid);
        // test-train covariance applied to a vector over test points? No:
        // cross_matvec computes W_train K W_testᵀ v with v over tests.
        let v = rng.normal_vec(15);
        let got = op.cross_matvec(&wt, &v);
        let exact = Matrix::from_fn(40, 15, |i, j| kern.eval(xs[i], ts[j]));
        let want = exact.matvec(&v);
        assert!(rel_err(&got, &want) < 1e-3);
    }
}
