//! Fixed-width sparse interpolation matrices over 1-D inducing grids
//! (paper §2.3).
//!
//! SKI approximates `k(x, z) ≈ w_x K_UU w_zᵀ` where `w_x` holds the local
//! cubic convolution interpolation weights of Keys (1981): exactly four
//! nonzeros per point. We store the interpolation matrix `W` in a
//! fixed-width sparse layout (4 index/weight pairs per row), which makes
//! `W v` and `Wᵀ v` allocation-free streaming loops.
//!
//! The grid axes and stencil primitives themselves live in
//! [`crate::grid`] (re-exported here for compatibility) — this module
//! keeps only the 1-D `W` matrix the [`super::ski::SkiOp`] pipeline uses.

pub use crate::grid::{
    cubic_stencil, cubic_stencil_deriv, tensor_stencil, tensor_strides, Grid1d,
    MAX_TENSOR_DIM, STENCIL,
};
use crate::linalg::Matrix;

/// Fixed-width sparse interpolation matrix W (n × m, 4 nnz per row).
#[derive(Clone, Debug)]
pub struct InterpMatrix {
    pub n: usize,
    pub m: usize,
    /// 4 column indices per row, row-major.
    pub idx: Vec<u32>,
    /// 4 weights per row, row-major.
    pub w: Vec<f64>,
}

impl InterpMatrix {
    /// Interpolation weights of 1-D points `xs` onto `grid` (m ≥ 4).
    pub fn new(xs: &[f64], grid: &Grid1d) -> Self {
        assert!(grid.m >= STENCIL, "InterpMatrix needs a cubic axis (m >= {STENCIL})");
        let n = xs.len();
        let m = grid.m;
        let mut idx = Vec::with_capacity(n * STENCIL);
        let mut w = Vec::with_capacity(n * STENCIL);
        for &x in xs {
            let (base, row_w) = cubic_stencil(x, grid);
            for (k, &rw) in row_w.iter().enumerate() {
                idx.push((base + k) as u32);
                w.push(rw);
            }
        }
        InterpMatrix { n, m, idx, w }
    }

    /// D-SKI layout: value **and** derivative rows, interleaved per point
    /// (row 2i is the value stencil of `xs[i]`, row 2i+1 its derivative
    /// stencil `∂w/∂x` from [`cubic_stencil_deriv`]). The 2n × m result is
    /// an ordinary [`InterpMatrix`] — every matvec/matmat path is
    /// row-generic, so gradient observations ride the same machinery.
    pub fn new_with_grad(xs: &[f64], grid: &Grid1d) -> Self {
        assert!(grid.m >= STENCIL, "InterpMatrix needs a cubic axis (m >= {STENCIL})");
        let n = 2 * xs.len();
        let m = grid.m;
        let mut idx = Vec::with_capacity(n * STENCIL);
        let mut w = Vec::with_capacity(n * STENCIL);
        for &x in xs {
            let (base, row_w) = cubic_stencil(x, grid);
            for (k, &rw) in row_w.iter().enumerate() {
                idx.push((base + k) as u32);
                w.push(rw);
            }
            let (dbase, row_dw) = cubic_stencil_deriv(x, grid);
            debug_assert_eq!(dbase, base);
            for (k, &rw) in row_dw.iter().enumerate() {
                idx.push((dbase + k) as u32);
                w.push(rw);
            }
        }
        InterpMatrix { n, m, idx, w }
    }

    /// `W v` — (n×m)(m) in O(n).
    ///
    /// The fixed-width rows walk as `chunks_exact(STENCIL)`, so the inner
    /// gather runs bounds-check-free over each 4-wide stencil (same
    /// accumulation order as the indexed loop it replaced).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.m);
        self.idx
            .chunks_exact(STENCIL)
            .zip(self.w.chunks_exact(STENCIL))
            .map(|(idx, w)| {
                w.iter()
                    .zip(idx)
                    .map(|(&wk, &g)| wk * v[g as usize])
                    .sum::<f64>()
            })
            .collect()
    }

    /// `Wᵀ v` — (m×n)(n) in O(n), the scatter mirror of
    /// [`InterpMatrix::matvec`] (fixed-width `chunks_exact` rows; only
    /// the scattered store stays indexed).
    pub fn t_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n);
        let mut out = vec![0.0; self.m];
        let rows = self.idx.chunks_exact(STENCIL).zip(self.w.chunks_exact(STENCIL));
        for ((idx, w), &x) in rows.zip(v) {
            for (&g, &wk) in idx.iter().zip(w) {
                out[g as usize] += wk * x;
            }
        }
        out
    }

    /// The stencil weights converted to f32, for the mixed-precision SKI
    /// view (`SkiOp::as_f32`): built once per solve, streamed every inner
    /// iteration.
    pub fn weights_f32(&self) -> Vec<f32> {
        self.w.iter().map(|&x| x as f32).collect()
    }

    /// `W v` over f32 operands, against caller-held f32 weights (from
    /// [`InterpMatrix::weights_f32`] — same length/layout as `w`).
    pub fn matvec_f32_with(&self, w32: &[f32], v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.m);
        assert_eq!(w32.len(), self.w.len());
        self.idx
            .chunks_exact(STENCIL)
            .zip(w32.chunks_exact(STENCIL))
            .map(|(idx, w)| {
                w.iter()
                    .zip(idx)
                    .map(|(&wk, &g)| wk * v[g as usize])
                    .sum::<f32>()
            })
            .collect()
    }

    /// `Wᵀ v` over f32 operands (see [`InterpMatrix::matvec_f32_with`]).
    pub fn t_matvec_f32_with(&self, w32: &[f32], v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.n);
        assert_eq!(w32.len(), self.w.len());
        let mut out = vec![0.0f32; self.m];
        let rows = self.idx.chunks_exact(STENCIL).zip(w32.chunks_exact(STENCIL));
        for ((idx, w), &x) in rows.zip(v) {
            for (&g, &wk) in idx.iter().zip(w) {
                out[g as usize] += wk * x;
            }
        }
        out
    }

    /// `W M` for an m×t block — one streaming pass over the stencil, with
    /// each update a contiguous length-t row axpy (the block analogue of
    /// [`InterpMatrix::matvec`]). O(n·t).
    pub fn matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.m);
        let t = m.cols;
        let mut out = Matrix::zeros(self.n, t);
        for i in 0..self.n {
            let base = i * STENCIL;
            let o_row = out.row_mut(i);
            for k in 0..STENCIL {
                let w = self.w[base + k];
                let src = m.row(self.idx[base + k] as usize);
                for (o, &x) in o_row.iter_mut().zip(src) {
                    *o += w * x;
                }
            }
        }
        out
    }

    /// `Wᵀ M` for an n×t block — scatter rows of `M` into grid rows, all t
    /// columns per touch. O(n·t).
    pub fn t_matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.n);
        let t = m.cols;
        let mut out = Matrix::zeros(self.m, t);
        for i in 0..self.n {
            let base = i * STENCIL;
            let src = m.row(i);
            for k in 0..STENCIL {
                let w = self.w[base + k];
                let o_row = out.row_mut(self.idx[base + k] as usize);
                for (o, &x) in o_row.iter_mut().zip(src) {
                    *o += w * x;
                }
            }
        }
        out
    }

    /// Dense materialization (tests only).
    pub fn to_dense(&self) -> Matrix {
        let mut d = Matrix::zeros(self.n, self.m);
        for i in 0..self.n {
            let base = i * STENCIL;
            for k in 0..STENCIL {
                let j = self.idx[base + k] as usize;
                d.set(i, j, d.get(i, j) + self.w[base + k]);
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Stationary1d;
    use crate::util::Rng;

    #[test]
    fn weights_partition_unity() {
        let g = Grid1d::fit(0.0, 1.0, 16).unwrap();
        let xs: Vec<f64> = (0..50).map(|i| i as f64 / 49.0).collect();
        let w = InterpMatrix::new(&xs, &g);
        let ones = vec![1.0; g.m];
        for v in w.matvec(&ones) {
            assert!((v - 1.0).abs() < 1e-10, "partition of unity violated: {v}");
        }
    }

    #[test]
    fn interpolates_grid_points_exactly() {
        let g = Grid1d::fit(0.0, 1.0, 16).unwrap();
        // Data exactly on interior grid points → weight 1 on that point.
        let xs = vec![g.point(5), g.point(8)];
        let w = InterpMatrix::new(&xs, &g);
        let f: Vec<f64> = (0..g.m).map(|i| (i as f64).powi(2)).collect();
        let got = w.matvec(&f);
        assert!((got[0] - 25.0).abs() < 1e-10);
        assert!((got[1] - 64.0).abs() < 1e-10);
    }

    #[test]
    fn cubic_reproduces_cubics() {
        // Cubic convolution interpolation is exact for polynomials ≤ deg 2
        // and O(h³) otherwise; test quadratic exactness on interior points.
        let g = Grid1d::fit(0.0, 1.0, 32).unwrap();
        let xs: Vec<f64> = (1..20).map(|i| 0.05 * i as f64).collect();
        let w = InterpMatrix::new(&xs, &g);
        let f: Vec<f64> = g.points().iter().map(|&u| 2.0 * u * u - u + 0.3).collect();
        let got = w.matvec(&f);
        for (x, v) in xs.iter().zip(got) {
            let expect = 2.0 * x * x - x + 0.3;
            assert!((v - expect).abs() < 1e-9, "at {x}: {v} vs {expect}");
        }
    }

    #[test]
    fn ski_kernel_approximation_quality() {
        // w_x K_UU w_zᵀ ≈ k(x,z) (paper Eq. 4) — dense check on a fine grid.
        let kern = Stationary1d::rbf(0.5);
        let g = Grid1d::fit(-1.0, 1.0, 64).unwrap();
        let mut rng = Rng::new(5);
        let xs = rng.uniform_vec(30, -1.0, 1.0);
        let w = InterpMatrix::new(&xs, &g);
        let kuu = Matrix::from_fn(g.m, g.m, |i, j| kern.eval(g.point(i), g.point(j)));
        let wd = w.to_dense();
        let approx = wd.matmul(&kuu).matmul_t(&wd);
        let exact = Matrix::from_fn(30, 30, |i, j| kern.eval(xs[i], xs[j]));
        assert!(approx.max_abs_diff(&exact) < 1e-3);
    }

    #[test]
    fn block_ops_match_per_column() {
        let g = Grid1d::fit(0.0, 1.0, 16).unwrap();
        let mut rng = Rng::new(7);
        let xs = rng.uniform_vec(30, 0.0, 1.0);
        let w = InterpMatrix::new(&xs, &g);
        for t in [1usize, 3, 8] {
            let mg = Matrix::from_fn(g.m, t, |_, _| rng.normal());
            let got = w.matmat(&mg);
            for j in 0..t {
                assert_eq!(got.col(j), w.matvec(&mg.col(j)), "matmat col {j}");
            }
            let mn = Matrix::from_fn(30, t, |_, _| rng.normal());
            let got_t = w.t_matmat(&mn);
            for j in 0..t {
                let want = w.t_matvec(&mn.col(j));
                let gcol = got_t.col(j);
                for (a, b) in gcol.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-14, "t_matmat col {j}");
                }
            }
        }
    }

    #[test]
    fn tensor_stencil_matches_1d_interp_matrix() {
        let g = Grid1d::fit(0.0, 1.0, 16).unwrap();
        let mut rng = Rng::new(12);
        let xs = rng.uniform_vec(20, 0.0, 1.0);
        let w = InterpMatrix::new(&xs, &g);
        let grids = [g];
        let strides = tensor_strides(&[16]);
        for (i, &x) in xs.iter().enumerate() {
            let mut got: Vec<(usize, f64)> = Vec::new();
            tensor_stencil(&[x], &grids, &strides, |g, wt| got.push((g, wt)));
            assert_eq!(got.len(), STENCIL);
            for (k, (gi, wt)) in got.iter().enumerate() {
                assert_eq!(*gi, w.idx[i * STENCIL + k] as usize);
                assert_eq!(*wt, w.w[i * STENCIL + k]);
            }
        }
    }

    #[test]
    fn f32_matvec_and_adjoint_track_f64() {
        let g = Grid1d::fit(0.0, 1.0, 16).unwrap();
        let mut rng = Rng::new(9);
        let xs = rng.uniform_vec(40, 0.0, 1.0);
        let w = InterpMatrix::new(&xs, &g);
        let w32 = w.weights_f32();
        let u = rng.normal_vec(g.m);
        let u32: Vec<f32> = u.iter().map(|&x| x as f32).collect();
        for (a, b) in w.matvec_f32_with(&w32, &u32).iter().zip(w.matvec(&u)) {
            assert!((*a as f64 - b).abs() < 1e-5, "{a} vs {b}");
        }
        let v = rng.normal_vec(40);
        let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        for (a, b) in w.t_matvec_f32_with(&w32, &v32).iter().zip(w.t_matvec(&v)) {
            assert!((*a as f64 - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn grad_rows_differentiate_the_interpolant() {
        // Row 2i+1 of the D-SKI matrix applied to grid values must equal
        // d/dx of the row-2i interpolant: check against a quadratic, for
        // which cubic convolution is exact (value AND derivative).
        let g = Grid1d::fit(0.0, 1.0, 32).unwrap();
        let xs: Vec<f64> = (1..20).map(|i| 0.05 * i as f64).collect();
        let w = InterpMatrix::new_with_grad(&xs, &g);
        assert_eq!(w.n, 2 * xs.len());
        let f: Vec<f64> = g.points().iter().map(|&u| 2.0 * u * u - u + 0.3).collect();
        let got = w.matvec(&f);
        for (i, &x) in xs.iter().enumerate() {
            let val = 2.0 * x * x - x + 0.3;
            let slope = 4.0 * x - 1.0;
            assert!((got[2 * i] - val).abs() < 1e-9, "value at {x}");
            assert!((got[2 * i + 1] - slope).abs() < 1e-8, "slope at {x}: {}", got[2 * i + 1]);
        }
    }

    #[test]
    fn t_matvec_is_adjoint() {
        let g = Grid1d::fit(0.0, 2.0, 12).unwrap();
        let mut rng = Rng::new(6);
        let xs = rng.uniform_vec(25, 0.0, 2.0);
        let w = InterpMatrix::new(&xs, &g);
        let u = rng.normal_vec(g.m);
        let v = rng.normal_vec(25);
        // ⟨Wu, v⟩ = ⟨u, Wᵀv⟩
        let lhs: f64 = w.matvec(&u).iter().zip(&v).map(|(a, b)| a * b).sum();
        let rhs: f64 = u.iter().zip(w.t_matvec(&v)).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-10);
    }
}
