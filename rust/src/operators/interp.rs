//! Regular 1-D inducing grids and local cubic interpolation (paper §2.3).
//!
//! SKI approximates `k(x, z) ≈ w_x K_UU w_zᵀ` where `w_x` holds the local
//! cubic convolution interpolation weights of Keys (1981): exactly four
//! nonzeros per point. We store the interpolation matrix `W` in a
//! fixed-width sparse layout (4 index/weight pairs per row), which makes
//! `W v` and `Wᵀ v` allocation-free streaming loops.

use crate::linalg::Matrix;

/// Number of interpolation weights per point (cubic convolution).
pub const STENCIL: usize = 4;

/// A regular 1-D grid of inducing points.
#[derive(Clone, Debug)]
pub struct Grid1d {
    /// Left-most grid point.
    pub min: f64,
    /// Grid spacing h.
    pub h: f64,
    /// Number of grid points m.
    pub m: usize,
}

impl Grid1d {
    /// Build a grid of `m ≥ 4` points covering `[lo, hi]` with enough
    /// margin that every data point has a full interior cubic stencil.
    pub fn fit(lo: f64, hi: f64, m: usize) -> Self {
        assert!(m >= STENCIL, "grid needs at least {STENCIL} points");
        assert!(hi >= lo);
        let span = (hi - lo).max(1e-8);
        // Reserve 2 grid cells of margin on each side for the stencil.
        let h = span / (m - 5) as f64;
        let min = lo - 2.0 * h;
        Grid1d { min, h, m }
    }

    /// Grid point i.
    #[inline]
    pub fn point(&self, i: usize) -> f64 {
        self.min + i as f64 * self.h
    }

    /// All grid points.
    pub fn points(&self) -> Vec<f64> {
        (0..self.m).map(|i| self.point(i)).collect()
    }
}

/// Keys (1981) cubic convolution kernel, a = −1/2, support |s| < 2.
#[inline]
fn cubic_weight(s: f64) -> f64 {
    let a = -0.5;
    let s = s.abs();
    if s < 1.0 {
        ((a + 2.0) * s - (a + 3.0)) * s * s + 1.0
    } else if s < 2.0 {
        a * (((s - 5.0) * s + 8.0) * s - 4.0)
    } else {
        0.0
    }
}

/// Stencil of point `x` on `grid`: left-most grid index plus the four
/// (renormalized) cubic convolution weights. Shared by the 1-D
/// `InterpMatrix` and the tensor-product weights of KISS-GP.
pub fn cubic_stencil(x: f64, grid: &Grid1d) -> (usize, [f64; STENCIL]) {
    let u = (x - grid.min) / grid.h;
    let fi = u.floor() as isize;
    let base = (fi - 1).clamp(0, grid.m as isize - STENCIL as isize) as usize;
    let mut row_w = [0.0; STENCIL];
    let mut wsum = 0.0;
    for (k, rw) in row_w.iter_mut().enumerate() {
        *rw = cubic_weight(u - (base + k) as f64);
        wsum += *rw;
    }
    // Renormalize: guards partition-of-unity at clamped boundaries.
    if wsum.abs() > 1e-12 {
        for rw in row_w.iter_mut() {
            *rw /= wsum;
        }
    }
    (base, row_w)
}

/// Row-major strides of a tensor-product grid with per-dimension sizes
/// `dims` (dimension 0 slowest — the layout shared by [`super::kronecker`]
/// and the serving layer's grid-side predictive caches).
pub fn tensor_strides(dims: &[usize]) -> Vec<usize> {
    let d = dims.len();
    let mut strides = vec![1usize; d];
    for k in (0..d.saturating_sub(1)).rev() {
        strides[k] = strides[k + 1] * dims[k + 1];
    }
    strides
}

/// Maximum tensor-stencil dimensionality (4ᵈ weights per point becomes
/// astronomically large long before this bound binds).
pub const MAX_TENSOR_DIM: usize = 16;

/// Tensor-product cubic stencil of the d-dimensional point `x` on the
/// per-dimension grids `grids`: calls `emit(flat_index, weight)` for each
/// of the 4ᵈ (flat grid index, product weight) pairs, in the fixed order
/// where the last dimension's offset varies fastest. `strides` must be
/// [`tensor_strides`] of the grid sizes.
///
/// This is the single-point stencil-extraction primitive shared by the
/// KISS-GP operator's interpolation matrix and the O(1)-per-point
/// predictive caches in `crate::serve::cache`.
pub fn tensor_stencil<F: FnMut(usize, f64)>(
    x: &[f64],
    grids: &[Grid1d],
    strides: &[usize],
    mut emit: F,
) {
    let d = grids.len();
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(strides.len(), d);
    assert!(d <= MAX_TENSOR_DIM, "tensor stencil supports d <= {MAX_TENSOR_DIM}");
    let mut bases = [0usize; MAX_TENSOR_DIM];
    let mut wts = [[0.0f64; STENCIL]; MAX_TENSOR_DIM];
    for k in 0..d {
        let (b, ws) = cubic_stencil(x[k], &grids[k]);
        bases[k] = b;
        wts[k] = ws;
    }
    let size = STENCIL.pow(d as u32);
    for c in 0..size {
        let mut flat = 0usize;
        let mut weight = 1.0;
        let mut cc = c;
        for k in (0..d).rev() {
            let o = cc % STENCIL;
            cc /= STENCIL;
            flat += (bases[k] + o) * strides[k];
            weight *= wts[k][o];
        }
        emit(flat, weight);
    }
}

/// Fixed-width sparse interpolation matrix W (n × m, 4 nnz per row).
#[derive(Clone, Debug)]
pub struct InterpMatrix {
    pub n: usize,
    pub m: usize,
    /// 4 column indices per row, row-major.
    pub idx: Vec<u32>,
    /// 4 weights per row, row-major.
    pub w: Vec<f64>,
}

impl InterpMatrix {
    /// Interpolation weights of 1-D points `xs` onto `grid`.
    pub fn new(xs: &[f64], grid: &Grid1d) -> Self {
        let n = xs.len();
        let m = grid.m;
        let mut idx = Vec::with_capacity(n * STENCIL);
        let mut w = Vec::with_capacity(n * STENCIL);
        for &x in xs {
            let (base, row_w) = cubic_stencil(x, grid);
            for (k, &rw) in row_w.iter().enumerate() {
                idx.push((base + k) as u32);
                w.push(rw);
            }
        }
        InterpMatrix { n, m, idx, w }
    }

    /// `W v` — (n×m)(m) in O(n).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.m);
        let mut out = vec![0.0; self.n];
        for i in 0..self.n {
            let o = &mut out[i];
            let base = i * STENCIL;
            for k in 0..STENCIL {
                *o += self.w[base + k] * v[self.idx[base + k] as usize];
            }
        }
        out
    }

    /// `Wᵀ v` — (m×n)(n) in O(n).
    pub fn t_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n);
        let mut out = vec![0.0; self.m];
        for i in 0..self.n {
            let base = i * STENCIL;
            let x = v[i];
            for k in 0..STENCIL {
                out[self.idx[base + k] as usize] += self.w[base + k] * x;
            }
        }
        out
    }

    /// `W M` for an m×t block — one streaming pass over the stencil, with
    /// each update a contiguous length-t row axpy (the block analogue of
    /// [`InterpMatrix::matvec`]). O(n·t).
    pub fn matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.m);
        let t = m.cols;
        let mut out = Matrix::zeros(self.n, t);
        for i in 0..self.n {
            let base = i * STENCIL;
            let o_row = out.row_mut(i);
            for k in 0..STENCIL {
                let w = self.w[base + k];
                let src = m.row(self.idx[base + k] as usize);
                for (o, &x) in o_row.iter_mut().zip(src) {
                    *o += w * x;
                }
            }
        }
        out
    }

    /// `Wᵀ M` for an n×t block — scatter rows of `M` into grid rows, all t
    /// columns per touch. O(n·t).
    pub fn t_matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.n);
        let t = m.cols;
        let mut out = Matrix::zeros(self.m, t);
        for i in 0..self.n {
            let base = i * STENCIL;
            let src = m.row(i);
            for k in 0..STENCIL {
                let w = self.w[base + k];
                let o_row = out.row_mut(self.idx[base + k] as usize);
                for (o, &x) in o_row.iter_mut().zip(src) {
                    *o += w * x;
                }
            }
        }
        out
    }

    /// Dense materialization (tests only).
    pub fn to_dense(&self) -> Matrix {
        let mut d = Matrix::zeros(self.n, self.m);
        for i in 0..self.n {
            let base = i * STENCIL;
            for k in 0..STENCIL {
                let j = self.idx[base + k] as usize;
                d.set(i, j, d.get(i, j) + self.w[base + k]);
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Stationary1d;
    use crate::util::Rng;

    #[test]
    fn grid_covers_data_with_margin() {
        let g = Grid1d::fit(-1.0, 1.0, 20);
        assert!(g.point(0) < -1.0);
        assert!(g.point(g.m - 1) > 1.0);
        // Interior stencil for boundary data points.
        let u = (-1.0 - g.min) / g.h;
        assert!(u >= 1.0);
        let u = (1.0 - g.min) / g.h;
        assert!(u <= (g.m - 3) as f64 + 1.0);
    }

    #[test]
    fn weights_partition_unity() {
        let g = Grid1d::fit(0.0, 1.0, 16);
        let xs: Vec<f64> = (0..50).map(|i| i as f64 / 49.0).collect();
        let w = InterpMatrix::new(&xs, &g);
        let ones = vec![1.0; g.m];
        for v in w.matvec(&ones) {
            assert!((v - 1.0).abs() < 1e-10, "partition of unity violated: {v}");
        }
    }

    #[test]
    fn interpolates_grid_points_exactly() {
        let g = Grid1d::fit(0.0, 1.0, 16);
        // Data exactly on interior grid points → weight 1 on that point.
        let xs = vec![g.point(5), g.point(8)];
        let w = InterpMatrix::new(&xs, &g);
        let f: Vec<f64> = (0..g.m).map(|i| (i as f64).powi(2)).collect();
        let got = w.matvec(&f);
        assert!((got[0] - 25.0).abs() < 1e-10);
        assert!((got[1] - 64.0).abs() < 1e-10);
    }

    #[test]
    fn cubic_reproduces_cubics() {
        // Cubic convolution interpolation is exact for polynomials ≤ deg 2
        // and O(h³) otherwise; test quadratic exactness on interior points.
        let g = Grid1d::fit(0.0, 1.0, 32);
        let xs: Vec<f64> = (1..20).map(|i| 0.05 * i as f64).collect();
        let w = InterpMatrix::new(&xs, &g);
        let f: Vec<f64> = g.points().iter().map(|&u| 2.0 * u * u - u + 0.3).collect();
        let got = w.matvec(&f);
        for (x, v) in xs.iter().zip(got) {
            let expect = 2.0 * x * x - x + 0.3;
            assert!((v - expect).abs() < 1e-9, "at {x}: {v} vs {expect}");
        }
    }

    #[test]
    fn ski_kernel_approximation_quality() {
        // w_x K_UU w_zᵀ ≈ k(x,z) (paper Eq. 4) — dense check on a fine grid.
        let kern = Stationary1d::rbf(0.5);
        let g = Grid1d::fit(-1.0, 1.0, 64);
        let mut rng = Rng::new(5);
        let xs = rng.uniform_vec(30, -1.0, 1.0);
        let w = InterpMatrix::new(&xs, &g);
        let kuu = Matrix::from_fn(g.m, g.m, |i, j| kern.eval(g.point(i), g.point(j)));
        let wd = w.to_dense();
        let approx = wd.matmul(&kuu).matmul_t(&wd);
        let exact = Matrix::from_fn(30, 30, |i, j| kern.eval(xs[i], xs[j]));
        assert!(approx.max_abs_diff(&exact) < 1e-3);
    }

    #[test]
    fn block_ops_match_per_column() {
        let g = Grid1d::fit(0.0, 1.0, 16);
        let mut rng = Rng::new(7);
        let xs = rng.uniform_vec(30, 0.0, 1.0);
        let w = InterpMatrix::new(&xs, &g);
        for t in [1usize, 3, 8] {
            let mg = Matrix::from_fn(g.m, t, |_, _| rng.normal());
            let got = w.matmat(&mg);
            for j in 0..t {
                assert_eq!(got.col(j), w.matvec(&mg.col(j)), "matmat col {j}");
            }
            let mn = Matrix::from_fn(30, t, |_, _| rng.normal());
            let got_t = w.t_matmat(&mn);
            for j in 0..t {
                let want = w.t_matvec(&mn.col(j));
                let gcol = got_t.col(j);
                for (a, b) in gcol.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-14, "t_matmat col {j}");
                }
            }
        }
    }

    #[test]
    fn tensor_stencil_matches_1d_interp_matrix() {
        let g = Grid1d::fit(0.0, 1.0, 16);
        let mut rng = Rng::new(12);
        let xs = rng.uniform_vec(20, 0.0, 1.0);
        let w = InterpMatrix::new(&xs, &g);
        let grids = [g];
        let strides = tensor_strides(&[16]);
        for (i, &x) in xs.iter().enumerate() {
            let mut got: Vec<(usize, f64)> = Vec::new();
            tensor_stencil(&[x], &grids, &strides, |g, wt| got.push((g, wt)));
            assert_eq!(got.len(), STENCIL);
            for (k, (gi, wt)) in got.iter().enumerate() {
                assert_eq!(*gi, w.idx[i * STENCIL + k] as usize);
                assert_eq!(*wt, w.w[i * STENCIL + k]);
            }
        }
    }

    #[test]
    fn tensor_stencil_partition_of_unity_2d() {
        let gx = Grid1d::fit(-1.0, 1.0, 12);
        let gy = Grid1d::fit(0.0, 2.0, 9);
        let strides = tensor_strides(&[12, 9]);
        assert_eq!(strides, vec![9, 1]);
        let mut rng = Rng::new(13);
        for _ in 0..25 {
            let x = [rng.uniform_in(-1.0, 1.0), rng.uniform_in(0.0, 2.0)];
            let mut sum = 0.0;
            let mut count = 0;
            tensor_stencil(&x, &[gx.clone(), gy.clone()], &strides, |flat, w| {
                assert!(flat < 12 * 9);
                sum += w;
                count += 1;
            });
            assert_eq!(count, STENCIL * STENCIL);
            assert!((sum - 1.0).abs() < 1e-10, "2-D partition of unity: {sum}");
        }
    }

    #[test]
    fn t_matvec_is_adjoint() {
        let g = Grid1d::fit(0.0, 2.0, 12);
        let mut rng = Rng::new(6);
        let xs = rng.uniform_vec(25, 0.0, 2.0);
        let w = InterpMatrix::new(&xs, &g);
        let u = rng.normal_vec(g.m);
        let v = rng.normal_vec(25);
        // ⟨Wu, v⟩ = ⟨u, Wᵀv⟩
        let lhs: f64 = w.matvec(&u).iter().zip(&v).map(|(a, b)| a * b).sum();
        let rhs: f64 = u.iter().zip(w.t_matvec(&v)).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-10);
    }
}
