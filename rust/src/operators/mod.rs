//! Linear-operator abstraction and structured operators.
//!
//! The paper's central argument (§1, §7) is that GP inference should be
//! *modular in MVMs*: a model only needs `v ↦ K v`. This module provides
//! that abstraction plus every structured operator the paper uses:
//! SKI (`W K_UU Wᵀ`), Kronecker-grid SKI (KISS-GP), low-rank Lanczos
//! factors with the Lemma-3.1 Hadamard MVM, the SKIP merge tree, and the
//! multi-task coregionalization operator.

pub mod interp;
pub mod kronecker;
pub mod lowrank;
pub mod ski;
pub mod skip;
pub mod task;

pub use interp::{Grid1d, InterpMatrix};
pub use kronecker::KroneckerSkiOp;
pub use lowrank::{ContractionBackend, LanczosFactor, NativeBackend};
pub use ski::SkiOp;
pub use skip::{SkipComponent, SkipOp};
pub use task::TaskOp;

use crate::linalg::Matrix;

/// A square linear operator exposing matrix-vector multiplication.
///
/// `μ(K)` in the paper's notation is the cost of one `matvec`.
pub trait LinearOp: Send + Sync {
    /// Operator dimension n (operators here are square n×n).
    fn dim(&self) -> usize;

    /// Compute `K v`.
    fn matvec(&self, v: &[f64]) -> Vec<f64>;

    /// Compute `K M` column-by-column (override when a faster path exists).
    fn matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.dim());
        let mut out = Matrix::zeros(self.dim(), m.cols);
        for j in 0..m.cols {
            out.set_col(j, &self.matvec(&m.col(j)));
        }
        out
    }

    /// Materialize densely (tests / small problems only).
    fn to_dense(&self) -> Matrix {
        let n = self.dim();
        let mut out = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            out.set_col(j, &self.matvec(&e));
            e[j] = 0.0;
        }
        out
    }
}

/// Dense matrix as an operator.
pub struct DenseOp(pub Matrix);

impl LinearOp for DenseOp {
    fn dim(&self) -> usize {
        assert_eq!(self.0.rows, self.0.cols);
        self.0.rows
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        self.0.matvec(v)
    }

    fn to_dense(&self) -> Matrix {
        self.0.clone()
    }
}

/// Diagonal operator.
pub struct DiagOp(pub Vec<f64>);

impl LinearOp for DiagOp {
    fn dim(&self) -> usize {
        self.0.len()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.0.len());
        self.0.iter().zip(v).map(|(d, x)| d * x).collect()
    }
}

/// `A + σ² I` — the noise-shifted covariance `K̂` of Eq. (1)–(3).
pub struct ShiftedOp<'a> {
    pub inner: &'a dyn LinearOp,
    pub shift: f64,
}

impl<'a> ShiftedOp<'a> {
    pub fn new(inner: &'a dyn LinearOp, shift: f64) -> Self {
        ShiftedOp { inner, shift }
    }
}

impl<'a> LinearOp for ShiftedOp<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.inner.matvec(v);
        for (o, &x) in out.iter_mut().zip(v) {
            *o += self.shift * x;
        }
        out
    }
}

/// `c · A`.
pub struct ScaledOp<'a> {
    pub inner: &'a dyn LinearOp,
    pub scale: f64,
}

impl<'a> LinearOp for ScaledOp<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.inner.matvec(v);
        for o in out.iter_mut() {
            *o *= self.scale;
        }
        out
    }
}

/// Owned affine wrapper `scale·A + shift·I` — the covariance
/// `K̂ = σ_f² K + σ_n² I` of Eqs. (1)–(3) as a self-contained operator.
pub struct AffineOp {
    pub inner: Box<dyn LinearOp>,
    pub scale: f64,
    pub shift: f64,
}

impl LinearOp for AffineOp {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.inner.matvec(v);
        for (o, &x) in out.iter_mut().zip(v) {
            *o = self.scale * *o + self.shift * x;
        }
        out
    }
}

/// `A + B` (owned boxed summands; used by the cluster-MTGP kernel).
pub struct SumOp {
    pub terms: Vec<Box<dyn LinearOp>>,
}

impl LinearOp for SumOp {
    fn dim(&self) -> usize {
        self.terms[0].dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; v.len()];
        for t in &self.terms {
            debug_assert_eq!(t.dim(), v.len());
            let tv = t.matvec(v);
            for (o, x) in out.iter_mut().zip(tv) {
                *o += x;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_op_roundtrip() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let op = DenseOp(m.clone());
        assert_eq!(op.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert!(op.to_dense().max_abs_diff(&m) < 1e-15);
    }

    #[test]
    fn shifted_adds_identity() {
        let op = DenseOp(Matrix::zeros(3, 3));
        let sh = ShiftedOp::new(&op, 2.5);
        assert_eq!(sh.matvec(&[1.0, 2.0, 3.0]), vec![2.5, 5.0, 7.5]);
    }

    #[test]
    fn diag_op() {
        let op = DiagOp(vec![1.0, -2.0, 3.0]);
        assert_eq!(op.matvec(&[1.0, 1.0, 1.0]), vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn sum_and_scale() {
        let a = DenseOp(Matrix::eye(2));
        let scaled = ScaledOp { inner: &a, scale: 3.0 };
        assert_eq!(scaled.matvec(&[1.0, 2.0]), vec![3.0, 6.0]);
        let sum = SumOp {
            terms: vec![
                Box::new(DenseOp(Matrix::eye(2))),
                Box::new(DiagOp(vec![1.0, 2.0])),
            ],
        };
        assert_eq!(sum.matvec(&[1.0, 1.0]), vec![2.0, 3.0]);
    }

    #[test]
    fn matmat_matches_matvec_columns() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let op = DenseOp(m.clone());
        let b = Matrix::from_vec(2, 3, vec![1., 0., 2., 0., 1., -1.]);
        let got = op.matmat(&b);
        let expect = m.matmul(&b);
        assert!(got.max_abs_diff(&expect) < 1e-14);
    }
}
