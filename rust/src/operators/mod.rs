//! Linear-operator abstraction and structured operators.
//!
//! The paper's central argument (§1, §7) is that GP inference should be
//! *modular in MVMs*: a model only needs `v ↦ K v`. This module provides
//! that abstraction plus every structured operator the paper uses:
//! SKI (`W K_UU Wᵀ`), Kronecker-grid SKI (KISS-GP), low-rank Lanczos
//! factors with the Lemma-3.1 Hadamard MVM, the SKIP merge tree, and the
//! multi-task coregionalization operator.

pub mod interp;
pub mod kronecker;
pub mod lowrank;
pub mod ski;
pub mod skip;
pub mod task;

pub use interp::{tensor_stencil, tensor_strides, Grid1d, InterpMatrix};
pub use kronecker::{
    kron_toeplitz_matvec, kron_toeplitz_matvec_with, KronScratch, KronSkiF32, KroneckerSkiOp,
};
pub use lowrank::{ContractionBackend, LanczosFactor, NativeBackend};
pub use ski::SkiOp;
pub use skip::{SkipComponent, SkipOp};
pub use task::{TaskHadamardRef, TaskOp};

use crate::linalg::Matrix;

/// A square linear operator exposing matrix-vector and matrix-matrix
/// multiplication.
///
/// `μ(K)` in the paper's notation is the cost of one [`matvec`]
/// (Theorem 3.3 counts everything in these units). The batched engine —
/// [`crate::solvers::block_cg_solve`], [`crate::solvers::lanczos_batch`],
/// SLQ probes — drives operators exclusively through [`matmat`], so every
/// structured operator overrides it with a fast path that carries the
/// whole n×t block through its structure in one pass instead of t
/// independent traversals.
///
/// [`matvec`]: LinearOp::matvec
/// [`matmat`]: LinearOp::matmat
///
/// ```
/// use skip_gp::linalg::Matrix;
/// use skip_gp::operators::{DenseOp, LinearOp};
///
/// let a = DenseOp(Matrix::from_vec(2, 2, vec![2.0, 0.0, 0.0, 3.0]));
/// assert_eq!(a.dim(), 2);
/// assert_eq!(a.matvec(&[1.0, 1.0]), vec![2.0, 3.0]);
///
/// // One matmat call multiplies a whole block of right-hand sides.
/// let block = Matrix::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 1.0, -1.0]);
/// let out = a.matmat(&block);
/// assert_eq!(out.data, vec![2.0, 0.0, 4.0, 0.0, 3.0, -3.0]);
/// ```
pub trait LinearOp: Send + Sync {
    /// Operator dimension n (operators here are square n×n).
    fn dim(&self) -> usize;

    /// Compute `K v`.
    fn matvec(&self, v: &[f64]) -> Vec<f64>;

    /// Compute `K M` for an n×t block `M`.
    ///
    /// The default falls back to column-by-column [`LinearOp::matvec`];
    /// structured operators override it (see [`matmat_via_matvec`] for the
    /// reference semantics every override must match).
    fn matmat(&self, m: &Matrix) -> Matrix {
        matmat_via_matvec(self, m)
    }

    /// Column j, `K e_j` — the column-sampling primitive preconditioner
    /// setup uses ([`crate::solvers::precond`]: a rank-k pivoted Cholesky
    /// fetches k columns). The default pays one [`matvec`] on a unit
    /// vector, so sampling k columns costs k MVMs; operators with random
    /// access (dense) override it.
    ///
    /// [`matvec`]: LinearOp::matvec
    fn col_at(&self, j: usize) -> Vec<f64> {
        let n = self.dim();
        assert!(j < n, "column index {j} out of range for dim {n}");
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        self.matvec(&e)
    }

    /// The operator's diagonal, when the structure makes it cheap —
    /// `None` otherwise (never approximate: callers fall back rather
    /// than silently precondition with a wrong diagonal). Drives the
    /// Jacobi preconditioner and the adaptive pivot selection of the
    /// pivoted-Cholesky preconditioner.
    ///
    /// Wrappers compose (`ShiftedOp`/`ScaledOp`/`AffineOp`/`SumOp`);
    /// structured operators whose diagonal is a per-row stencil/factor
    /// contraction (SKI, Kronecker-SKI, Lanczos factors, SKIP, task)
    /// override it with O(n·small) computations.
    fn diag(&self) -> Option<Vec<f64>> {
        None
    }

    /// Materialize densely (tests / small problems only).
    fn to_dense(&self) -> Matrix {
        let n = self.dim();
        let mut out = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            out.set_col(j, &self.matvec(&e));
            e[j] = 0.0;
        }
        out
    }

    /// A single-precision *view* of this operator for the mixed-precision
    /// inner solves of `solvers::refine`: f32 storage (spectra, stencil
    /// weights, dense entries) and f32 apply arithmetic, at f32 accuracy.
    ///
    /// `None` (the default) means the operator has no f32 mirror and a
    /// `Precision::Mixed` solve falls back to full f64 — never approximate
    /// silently at call sites; the solver meters the fallback. Wrappers
    /// compose: an affine/sum view exists iff every inner view does.
    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        None
    }
}

/// The single-precision mirror of [`LinearOp`]: `v ↦ K v` over f32
/// operands. Implementations store their structure (circulant spectra,
/// stencil weights, dense entries) in f32 — halving the bytes the
/// memory-bandwidth-bound MVM kernels stream — and run f32 arithmetic;
/// the f64 iterative-refinement loop around them (`solvers::refine`)
/// restores full-precision solutions.
///
/// Obtained through [`LinearOp::as_f32`]; views borrow the f64 operator
/// and are built once per solve, so conversion cost amortizes over all
/// inner iterations.
pub trait LinearOpF32: Send + Sync {
    /// Operator dimension n.
    fn dim(&self) -> usize;

    /// Compute `K v` in f32.
    fn matvec_f32(&self, v: &[f32]) -> Vec<f32>;
}

/// Reference `K M`: the serial column-by-column loop every `matmat` fast
/// path must reproduce. Public so property tests and benches can compare
/// overridden fast paths against the exact semantics they promise.
pub fn matmat_via_matvec<A: LinearOp + ?Sized>(a: &A, m: &Matrix) -> Matrix {
    assert_eq!(m.rows, a.dim());
    let mut out = Matrix::zeros(a.dim(), m.cols);
    for j in 0..m.cols {
        out.set_col(j, &a.matvec(&m.col(j)));
    }
    out
}

/// Dense matrix as an operator.
pub struct DenseOp(pub Matrix);

impl LinearOp for DenseOp {
    fn dim(&self) -> usize {
        assert_eq!(self.0.rows, self.0.cols);
        self.0.rows
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        self.0.matvec(v)
    }

    /// Fast path: one (row-parallel) gemm instead of t gemvs.
    fn matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.dim());
        self.0.matmul(m)
    }

    /// Random access: no MVM needed.
    fn col_at(&self, j: usize) -> Vec<f64> {
        self.0.col(j)
    }

    fn diag(&self) -> Option<Vec<f64>> {
        Some(self.0.diagonal())
    }

    fn to_dense(&self) -> Matrix {
        self.0.clone()
    }

    /// Owned f32 copy of the dense entries (one conversion per solve).
    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        let n = self.dim();
        Some(Box::new(DenseF32 {
            n,
            data: self.0.data.iter().map(|&x| x as f32).collect(),
        }))
    }
}

/// f32 mirror of [`DenseOp`]: row-major f32 entries, row-dot apply.
struct DenseF32 {
    n: usize,
    data: Vec<f32>,
}

impl LinearOpF32 for DenseF32 {
    fn dim(&self) -> usize {
        self.n
    }

    fn matvec_f32(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.n);
        self.data
            .chunks_exact(self.n)
            .map(|row| row.iter().zip(v).map(|(&a, &x)| a * x).sum::<f32>())
            .collect()
    }
}

/// Shared f32 affine wrapper `scale·(A·) + shift·(·)` backing the
/// [`LinearOp::as_f32`] views of [`ShiftedOp`], [`ScaledOp`],
/// [`AffineOp`], and [`AffineRef`].
struct AffineF32<'a> {
    inner: Box<dyn LinearOpF32 + 'a>,
    scale: f32,
    shift: f32,
}

impl LinearOpF32 for AffineF32<'_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn matvec_f32(&self, v: &[f32]) -> Vec<f32> {
        let mut out = self.inner.matvec_f32(v);
        for (o, &x) in out.iter_mut().zip(v) {
            *o = self.scale * *o + self.shift * x;
        }
        out
    }
}

/// Diagonal operator.
pub struct DiagOp(pub Vec<f64>);

impl LinearOp for DiagOp {
    fn dim(&self) -> usize {
        self.0.len()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.0.len());
        self.0.iter().zip(v).map(|(d, x)| d * x).collect()
    }

    /// Fast path: scale whole rows (contiguous in row-major layout).
    fn matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.0.len());
        let mut out = m.clone();
        for (i, &d) in self.0.iter().enumerate() {
            for v in out.row_mut(i) {
                *v *= d;
            }
        }
        out
    }

    fn col_at(&self, j: usize) -> Vec<f64> {
        let mut e = vec![0.0; self.0.len()];
        e[j] = self.0[j];
        e
    }

    fn diag(&self) -> Option<Vec<f64>> {
        Some(self.0.clone())
    }
}

/// `A + σ² I` — the noise-shifted covariance `K̂` of Eq. (1)–(3).
pub struct ShiftedOp<'a> {
    pub inner: &'a dyn LinearOp,
    pub shift: f64,
}

impl<'a> ShiftedOp<'a> {
    pub fn new(inner: &'a dyn LinearOp, shift: f64) -> Self {
        ShiftedOp { inner, shift }
    }
}

impl<'a> LinearOp for ShiftedOp<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.inner.matvec(v);
        for (o, &x) in out.iter_mut().zip(v) {
            *o += self.shift * x;
        }
        out
    }

    /// Fast path: one inner `matmat` plus an elementwise block axpy.
    fn matmat(&self, m: &Matrix) -> Matrix {
        let mut out = self.inner.matmat(m);
        for (o, &x) in out.data.iter_mut().zip(&m.data) {
            *o += self.shift * x;
        }
        out
    }

    fn col_at(&self, j: usize) -> Vec<f64> {
        let mut c = self.inner.col_at(j);
        c[j] += self.shift;
        c
    }

    fn diag(&self) -> Option<Vec<f64>> {
        let mut d = self.inner.diag()?;
        for v in d.iter_mut() {
            *v += self.shift;
        }
        Some(d)
    }

    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        Some(Box::new(AffineF32 {
            inner: self.inner.as_f32()?,
            scale: 1.0,
            shift: self.shift as f32,
        }))
    }
}

/// `c · A`.
pub struct ScaledOp<'a> {
    pub inner: &'a dyn LinearOp,
    pub scale: f64,
}

impl<'a> LinearOp for ScaledOp<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.inner.matvec(v);
        for o in out.iter_mut() {
            *o *= self.scale;
        }
        out
    }

    /// Fast path: one inner `matmat` plus an elementwise block scale.
    fn matmat(&self, m: &Matrix) -> Matrix {
        let mut out = self.inner.matmat(m);
        for o in out.data.iter_mut() {
            *o *= self.scale;
        }
        out
    }

    fn col_at(&self, j: usize) -> Vec<f64> {
        let mut c = self.inner.col_at(j);
        for v in c.iter_mut() {
            *v *= self.scale;
        }
        c
    }

    fn diag(&self) -> Option<Vec<f64>> {
        let mut d = self.inner.diag()?;
        for v in d.iter_mut() {
            *v *= self.scale;
        }
        Some(d)
    }

    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        Some(Box::new(AffineF32 {
            inner: self.inner.as_f32()?,
            scale: self.scale as f32,
            shift: 0.0,
        }))
    }
}

/// Shared affine arithmetic `scale·(A·) + shift·(·)` behind both
/// [`AffineOp`] (owned) and [`AffineRef`] (borrowed) — one
/// implementation, so the two wrappers can never drift float-for-float
/// (the streaming layer's incremental solves are pinned bitwise against
/// the batch path's operator).
fn affine_matvec(inner: &dyn LinearOp, scale: f64, shift: f64, v: &[f64]) -> Vec<f64> {
    let mut out = inner.matvec(v);
    for (o, &x) in out.iter_mut().zip(v) {
        *o = scale * *o + shift * x;
    }
    out
}

fn affine_matmat(inner: &dyn LinearOp, scale: f64, shift: f64, m: &Matrix) -> Matrix {
    let mut out = inner.matmat(m);
    for (o, &x) in out.data.iter_mut().zip(&m.data) {
        *o = scale * *o + shift * x;
    }
    out
}

fn affine_col_at(inner: &dyn LinearOp, scale: f64, shift: f64, j: usize) -> Vec<f64> {
    let mut c = inner.col_at(j);
    for v in c.iter_mut() {
        *v *= scale;
    }
    c[j] += shift;
    c
}

fn affine_diag(inner: &dyn LinearOp, scale: f64, shift: f64) -> Option<Vec<f64>> {
    let mut d = inner.diag()?;
    for v in d.iter_mut() {
        *v = scale * *v + shift;
    }
    Some(d)
}

/// Owned affine wrapper `scale·A + shift·I` — the covariance
/// `K̂ = σ_f² K + σ_n² I` of Eqs. (1)–(3) as a self-contained operator.
pub struct AffineOp {
    pub inner: Box<dyn LinearOp>,
    pub scale: f64,
    pub shift: f64,
}

impl LinearOp for AffineOp {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        affine_matvec(self.inner.as_ref(), self.scale, self.shift, v)
    }

    /// Fast path: the covariance solve `K̂ X = B` of the batched engine
    /// funnels through here — one inner `matmat` for the whole block,
    /// then a fused scale-and-shift over the contiguous buffer.
    fn matmat(&self, m: &Matrix) -> Matrix {
        affine_matmat(self.inner.as_ref(), self.scale, self.shift, m)
    }

    fn col_at(&self, j: usize) -> Vec<f64> {
        affine_col_at(self.inner.as_ref(), self.scale, self.shift, j)
    }

    /// Composes from the inner diagonal: `scale·diag(A) + shift` — this is
    /// what hands the pivoted-Cholesky preconditioner its adaptive pivots
    /// on the covariance `K̂ = σ_f²K + σ_n²I`.
    fn diag(&self) -> Option<Vec<f64>> {
        affine_diag(self.inner.as_ref(), self.scale, self.shift)
    }

    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        Some(Box::new(AffineF32 {
            inner: self.inner.as_f32()?,
            scale: self.scale as f32,
            shift: self.shift as f32,
        }))
    }
}

/// Borrowed [`AffineOp`]: `scale·A + shift·I` over an operator the
/// caller keeps owning and mutating between solves — the streaming
/// layer's covariance view over its in-place-growing SKI operator
/// (`crate::stream`). Identical arithmetic to `AffineOp` by
/// construction (both delegate to the same helpers).
pub struct AffineRef<'a> {
    pub inner: &'a dyn LinearOp,
    pub scale: f64,
    pub shift: f64,
}

impl LinearOp for AffineRef<'_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        affine_matvec(self.inner, self.scale, self.shift, v)
    }

    fn matmat(&self, m: &Matrix) -> Matrix {
        affine_matmat(self.inner, self.scale, self.shift, m)
    }

    fn col_at(&self, j: usize) -> Vec<f64> {
        affine_col_at(self.inner, self.scale, self.shift, j)
    }

    fn diag(&self) -> Option<Vec<f64>> {
        affine_diag(self.inner, self.scale, self.shift)
    }

    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        Some(Box::new(AffineF32 {
            inner: self.inner.as_f32()?,
            scale: self.scale as f32,
            shift: self.shift as f32,
        }))
    }
}

/// Shared-ownership view of a concrete operator: pure delegation through
/// an `Arc`, so one operator can back several compositions at once — the
/// KISS model hands the *same* `KroneckerSkiOp`s to both its data-space
/// covariance view and the grid-space normal-equations system
/// (`crate::solvers::gridspace`), guaranteeing the two solve spaces see
/// float-identical kernel arithmetic. Every method delegates, so wrapping
/// changes nothing numerically.
pub struct ArcOp<T: LinearOp>(pub std::sync::Arc<T>);

impl<T: LinearOp> LinearOp for ArcOp<T> {
    fn dim(&self) -> usize {
        self.0.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        self.0.matvec(v)
    }

    fn matmat(&self, m: &Matrix) -> Matrix {
        self.0.matmat(m)
    }

    fn col_at(&self, j: usize) -> Vec<f64> {
        self.0.col_at(j)
    }

    fn diag(&self) -> Option<Vec<f64>> {
        self.0.diag()
    }

    fn to_dense(&self) -> Matrix {
        self.0.to_dense()
    }

    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        self.0.as_f32()
    }
}

/// `A + B` (owned boxed summands; used by the cluster-MTGP kernel).
pub struct SumOp {
    pub terms: Vec<Box<dyn LinearOp>>,
}

impl LinearOp for SumOp {
    fn dim(&self) -> usize {
        self.terms[0].dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; v.len()];
        for t in &self.terms {
            debug_assert_eq!(t.dim(), v.len());
            let tv = t.matvec(v);
            for (o, x) in out.iter_mut().zip(tv) {
                *o += x;
            }
        }
        out
    }

    /// Fast path: one block product per summand, accumulated in term
    /// order. The terms run *sequentially* on purpose: each term's own
    /// `matmat` (fused contraction, row-chunked gemm, paired FFTs)
    /// already fans out across the machine, and nesting another per-term
    /// thread layer on top would oversubscribe cores in the block-CG hot
    /// loop.
    fn matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.dim());
        let mut out = Matrix::zeros(m.rows, m.cols);
        for t in &self.terms {
            debug_assert_eq!(t.dim(), m.rows);
            let p = t.matmat(m);
            for (o, x) in out.data.iter_mut().zip(p.data) {
                *o += x;
            }
        }
        out
    }

    fn col_at(&self, j: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        for t in &self.terms {
            for (o, x) in out.iter_mut().zip(t.col_at(j)) {
                *o += x;
            }
        }
        out
    }

    /// Available iff every summand's diagonal is.
    fn diag(&self) -> Option<Vec<f64>> {
        let mut out = vec![0.0; self.dim()];
        for t in &self.terms {
            let d = t.diag()?;
            for (o, x) in out.iter_mut().zip(d) {
                *o += x;
            }
        }
        Some(out)
    }

    /// Available iff every summand has an f32 view (all-or-nothing: a
    /// partially-f32 sum would silently mix precisions term by term).
    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        let views: Option<Vec<_>> = self.terms.iter().map(|t| t.as_f32()).collect();
        Some(Box::new(SumF32 { n: self.dim(), terms: views? }))
    }
}

/// f32 mirror of [`SumOp`]: summand views accumulated in term order.
struct SumF32<'a> {
    n: usize,
    terms: Vec<Box<dyn LinearOpF32 + 'a>>,
}

impl LinearOpF32 for SumF32<'_> {
    fn dim(&self) -> usize {
        self.n
    }

    fn matvec_f32(&self, v: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; v.len()];
        for t in &self.terms {
            debug_assert_eq!(t.dim(), v.len());
            let tv = t.matvec_f32(v);
            for (o, x) in out.iter_mut().zip(tv) {
                *o += x;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_op_roundtrip() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let op = DenseOp(m.clone());
        assert_eq!(op.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert!(op.to_dense().max_abs_diff(&m) < 1e-15);
    }

    #[test]
    fn shifted_adds_identity() {
        let op = DenseOp(Matrix::zeros(3, 3));
        let sh = ShiftedOp::new(&op, 2.5);
        assert_eq!(sh.matvec(&[1.0, 2.0, 3.0]), vec![2.5, 5.0, 7.5]);
    }

    #[test]
    fn diag_op() {
        let op = DiagOp(vec![1.0, -2.0, 3.0]);
        assert_eq!(op.matvec(&[1.0, 1.0, 1.0]), vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn sum_and_scale() {
        let a = DenseOp(Matrix::eye(2));
        let scaled = ScaledOp { inner: &a, scale: 3.0 };
        assert_eq!(scaled.matvec(&[1.0, 2.0]), vec![3.0, 6.0]);
        let sum = SumOp {
            terms: vec![
                Box::new(DenseOp(Matrix::eye(2))),
                Box::new(DiagOp(vec![1.0, 2.0])),
            ],
        };
        assert_eq!(sum.matvec(&[1.0, 1.0]), vec![2.0, 3.0]);
    }

    #[test]
    fn matmat_matches_matvec_columns() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let op = DenseOp(m.clone());
        let b = Matrix::from_vec(2, 3, vec![1., 0., 2., 0., 1., -1.]);
        let got = op.matmat(&b);
        let expect = m.matmul(&b);
        assert!(got.max_abs_diff(&expect) < 1e-14);
    }

    #[test]
    fn wrapper_matmat_fast_paths_match_reference() {
        let inner = DenseOp(Matrix::from_vec(3, 3, vec![1., 2., 0., -1., 3., 1., 0.5, 0., 2.]));
        let block = Matrix::from_vec(3, 2, vec![1., -2., 0., 1., 3., 0.5]);
        let shifted = ShiftedOp::new(&inner, 0.7);
        assert!(shifted
            .matmat(&block)
            .max_abs_diff(&matmat_via_matvec(&shifted, &block))
            < 1e-14);
        let scaled = ScaledOp { inner: &inner, scale: -2.0 };
        assert!(scaled
            .matmat(&block)
            .max_abs_diff(&matmat_via_matvec(&scaled, &block))
            < 1e-14);
        let affine = AffineOp {
            inner: Box::new(DenseOp(Matrix::eye(3))),
            scale: 1.5,
            shift: 0.25,
        };
        assert!(affine
            .matmat(&block)
            .max_abs_diff(&matmat_via_matvec(&affine, &block))
            < 1e-14);
        let diag = DiagOp(vec![1.0, -2.0, 0.5]);
        assert!(diag
            .matmat(&block)
            .max_abs_diff(&matmat_via_matvec(&diag, &block))
            < 1e-14);
    }

    #[test]
    fn diag_and_col_accessors_match_dense() {
        let base = Matrix::from_vec(3, 3, vec![2., 1., 0., 1., 3., 0.5, 0., 0.5, 4.]);
        let inner = DenseOp(base.clone());
        let affine = AffineOp {
            inner: Box::new(DenseOp(base.clone())),
            scale: 2.0,
            shift: 0.25,
        };
        let shifted = ShiftedOp::new(&inner, 0.7);
        let scaled = ScaledOp { inner: &inner, scale: -1.5 };
        let sum = SumOp {
            terms: vec![
                Box::new(DenseOp(base.clone())),
                Box::new(DiagOp(vec![1.0, 2.0, 3.0])),
            ],
        };
        let ops: Vec<&dyn LinearOp> = vec![&inner, &affine, &shifted, &scaled, &sum];
        for op in ops {
            let dense = op.to_dense();
            let diag = op.diag().expect("wrapper diagonals compose");
            for i in 0..3 {
                assert!((diag[i] - dense.get(i, i)).abs() < 1e-12);
            }
            for j in 0..3 {
                let col = op.col_at(j);
                for i in 0..3 {
                    assert!((col[i] - dense.get(i, j)).abs() < 1e-12);
                }
            }
        }
        // An operator without structure reports no diagonal rather than
        // guessing one.
        struct Opaque;
        impl LinearOp for Opaque {
            fn dim(&self) -> usize {
                2
            }
            fn matvec(&self, v: &[f64]) -> Vec<f64> {
                v.to_vec()
            }
        }
        assert!(Opaque.diag().is_none());
        assert_eq!(Opaque.col_at(1), vec![0.0, 1.0]);
    }

    #[test]
    fn sum_op_matmat_parallel_matches_reference() {
        let sum = SumOp {
            terms: vec![
                Box::new(DenseOp(Matrix::eye(4))),
                Box::new(DiagOp(vec![1.0, 2.0, 3.0, 4.0])),
                Box::new(DenseOp(Matrix::from_fn(4, 4, |i, j| (i + j) as f64))),
            ],
        };
        let block = Matrix::from_fn(4, 5, |i, j| (i as f64 - j as f64) * 0.5);
        assert!(sum
            .matmat(&block)
            .max_abs_diff(&matmat_via_matvec(&sum, &block))
            < 1e-12);
    }
}
