//! SKIP: the paper's product-kernel MVM algorithm (§3, Theorem 3.3).
//!
//! Given d component operators with fast MVMs, build rank-r Lanczos
//! decompositions of each (Lemma 3.2), then merge them pairwise in a
//! divide-and-conquer tree (Eqs. 12–14): each merge Lanczos-decomposes the
//! Hadamard product of two already-decomposed halves, whose MVMs cost
//! O(r²n) by Lemma 3.1. The root is kept as a *pair* of factors, so root
//! MVMs also run through Lemma 3.1 — total O(d·r·μ(K⁽ⁱ⁾) + r³ n log d)
//! build, O(r²n) per subsequent MVM (Corollary 3.4: the tree is cached).

use super::lowrank::{ContractionBackend, HadamardPairOp, LanczosFactor, NativeBackend};
use super::LinearOp;
use crate::linalg::Matrix;
use crate::solvers::lanczos::lanczos;
use crate::util::parallel::par_map;
use crate::util::Rng;
use std::sync::Arc;

/// A component of the product kernel: either an operator to be
/// Lanczos-decomposed, or an exact low-rank factorization supplied
/// directly (e.g. the multi-task `V B Bᵀ Vᵀ = (VB)(VB)ᵀ`, §6, or the
/// §7 "exact algorithm" variant with Q = W, T = K_UU).
pub enum SkipComponent<'a> {
    /// Fast-MVM operator; SKIP will Lanczos-decompose it.
    Op(&'a dyn LinearOp),
    /// Exact factorization Q T Qᵀ (Q need not be orthonormal — Lemma 3.1
    /// never uses orthogonality).
    Factor(LanczosFactor),
}

/// Diagnostics from building the merge tree, phrased in the cost model of
/// Theorem 3.3: build cost `O(d·r·μ(K⁽ⁱ⁾) + r³·n·log d)`.
///
/// - The **first term** is the leaf work: each of the d component
///   operators pays one component MVM (cost `μ(K⁽ⁱ⁾)`) per Lanczos
///   iteration. [`leaf_mvms`](SkipBuildStats::leaf_mvms) is that count
///   *as actually incurred* — the sum of achieved leaf ranks, which
///   equals `d·r` exactly when no leaf breaks down early and is smaller
///   when a component's Krylov space exhausts below r (common for smooth
///   kernels; it is why SKIP beats the worst-case bound in practice).
/// - The **second term** is the merge work: `⌈log₂ d⌉` tree levels, each
///   merge running r Lanczos iterations whose MVMs are Lemma-3.1
///   contractions of cost `O(r²n)` — hence `r·r²·n` per merge.
///   [`merge_ranks`](SkipBuildStats::merge_ranks) records the rank each
///   internal merge actually reached (tree order, level by level). These
///   are capped at the requested r even though the exact Hadamard product
///   has rank up to `rank(A)·rank(B)` (the §7 caveat); comparing
///   `merge_ranks` against r shows whether the cap — rather than spectral
///   decay — is what truncated each node.
///
/// Surfaced by the `rank_ablation` example to make the r-vs-accuracy
/// trade measurable next to these costs.
#[derive(Clone, Debug, Default)]
pub struct SkipBuildStats {
    /// Achieved rank of each leaf decomposition, in component order.
    /// (Exact `Factor` components report their factor's rank and cost no
    /// MVMs.)
    pub leaf_ranks: Vec<usize>,
    /// Achieved rank of each internal merge, in merge order.
    pub merge_ranks: Vec<usize>,
    /// Total component-operator MVMs spent on leaf decompositions — the
    /// realized `d·r` of Theorem 3.3's first term.
    pub leaf_mvms: usize,
}

enum Root {
    /// d = 1: single factor, MVM in O(rn).
    Single(LanczosFactor),
    /// d ≥ 2: Hadamard pair, MVM via Lemma 3.1 in O(r²n).
    Pair(LanczosFactor, LanczosFactor),
}

/// The SKIP operator: `K⁽¹⁾ ∘ ⋯ ∘ K⁽ᵈ⁾` with cached decompositions.
pub struct SkipOp {
    n: usize,
    root: Root,
    backend: Arc<dyn ContractionBackend>,
    /// Build diagnostics (ranks reached at each node).
    pub stats: SkipBuildStats,
}

impl SkipOp {
    /// Build the merge tree for `components` with target rank `rank`.
    ///
    /// `rank` is the paper's r: Lanczos iterations per decomposition.
    /// Probe vectors are drawn from `rng` (Gaussian).
    pub fn build(
        components: Vec<SkipComponent<'_>>,
        rank: usize,
        backend: Arc<dyn ContractionBackend>,
        rng: &mut Rng,
    ) -> Self {
        assert!(!components.is_empty());
        let n = match &components[0] {
            SkipComponent::Op(op) => op.dim(),
            SkipComponent::Factor(f) => f.dim(),
        };
        for c in &components {
            let cn = match c {
                SkipComponent::Op(op) => op.dim(),
                SkipComponent::Factor(f) => f.dim(),
            };
            assert_eq!(cn, n, "SKIP components must share dimension");
        }
        let mut stats = SkipBuildStats::default();
        // Decompose leaves. Probes are drawn up front in component order —
        // the same stream the sequential build consumed — so the leaf
        // Lanczos runs can fan out across threads (they touch disjoint
        // operators) without changing any result. Exact `Factor` leaves do
        // no Lanczos work: they are moved straight into their slot (no
        // copy) and skip the parallel stage entirely.
        let mut slots: Vec<Option<LanczosFactor>> = Vec::with_capacity(components.len());
        let mut op_jobs: Vec<(usize, &dyn LinearOp, Vec<f64>)> = Vec::new();
        for c in components {
            match c {
                SkipComponent::Op(op) => {
                    op_jobs.push((slots.len(), op, rng.normal_vec(n)));
                    slots.push(None);
                }
                SkipComponent::Factor(f) => slots.push(Some(f)),
            }
        }
        let decomposed = par_map(&op_jobs, 2, |(_, op, probe)| {
            let res = lanczos(*op, probe, rank, 1e-10);
            let mvms = res.rank();
            (res.into_factor(), mvms)
        });
        for ((idx, _, _), (f, mvms)) in op_jobs.iter().zip(decomposed) {
            stats.leaf_mvms += mvms;
            slots[*idx] = Some(f);
        }
        let mut factors: Vec<LanczosFactor> = slots
            .into_iter()
            .map(|s| s.expect("every leaf slot filled"))
            .collect();
        for f in &factors {
            stats.leaf_ranks.push(f.rank());
        }
        // Pairwise merges until two (or one) factors remain. Merging
        // adjacent pairs level-by-level realizes Eqs. (13)–(14); merges
        // within one level are independent, so each level fans out in
        // parallel (probes pre-drawn in pair order, stream-identical to
        // the sequential build).
        while factors.len() > 2 {
            let mut pairs = Vec::with_capacity(factors.len() / 2);
            let mut carry = None;
            let mut iter = factors.into_iter();
            while let Some(a) = iter.next() {
                match iter.next() {
                    Some(b) => pairs.push((a, b, rng.normal_vec(n))),
                    None => carry = Some(a), // odd one out rides up a level
                }
            }
            let mut next = par_map(&pairs, 2, |(a, b, probe)| {
                merge_pair(a, b, probe, rank, backend.as_ref())
            });
            for f in &next {
                stats.merge_ranks.push(f.rank());
            }
            if let Some(c) = carry {
                next.push(c);
            }
            factors = next;
        }
        let root = if factors.len() == 1 {
            Root::Single(factors.pop().unwrap())
        } else {
            let b = factors.pop().unwrap();
            let a = factors.pop().unwrap();
            Root::Pair(a, b)
        };
        SkipOp { n, root, backend, stats }
    }

    /// Convenience: build with the native backend.
    pub fn build_native(
        components: Vec<SkipComponent<'_>>,
        rank: usize,
        rng: &mut Rng,
    ) -> Self {
        SkipOp::build(components, rank, Arc::new(NativeBackend), rng)
    }

    /// The backend in use (for metrics/logging).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

/// Lanczos-decompose the Hadamard product of two decomposed halves.
fn merge_pair(
    a: &LanczosFactor,
    b: &LanczosFactor,
    probe: &[f64],
    rank: usize,
    backend: &dyn ContractionBackend,
) -> LanczosFactor {
    let op = HadamardPairOp { a, b, backend };
    lanczos(&op, probe, rank, 1e-10).into_factor()
}

impl LinearOp for SkipOp {
    fn dim(&self) -> usize {
        self.n
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        match &self.root {
            Root::Single(f) => f.matvec(v),
            Root::Pair(a, b) => self.backend.hadamard_pair_matvec(a, b, v),
        }
    }

    /// Fast path of the batched MVM engine: the cached root decomposition
    /// carries the whole n×t block in one pass — a three-gemm factor
    /// product for d = 1, the backend's fused Lemma-3.1 block contraction
    /// (`hadamard_pair_matmat`) for d ≥ 2. Corollary 3.4 amortization now
    /// applies per *block*, not per vector.
    fn matmat(&self, m: &Matrix) -> Matrix {
        match &self.root {
            Root::Single(f) => f.matmat(m),
            Root::Pair(a, b) => self.backend.hadamard_pair_matmat(a, b, m),
        }
    }

    /// Exact diagonal of the cached root decomposition in O(nr²): the
    /// per-factor `q_i T q_iᵀ` rows, multiplied elementwise at a Hadamard
    /// root. (This is the diagonal of the *approximate* operator the
    /// solves actually see — exactly what its preconditioner must match.)
    fn diag(&self) -> Option<Vec<f64>> {
        match &self.root {
            Root::Single(f) => Some(f.diag()),
            Root::Pair(a, b) => {
                let da = a.diag();
                let db = b.diag();
                Some(da.iter().zip(&db).map(|(x, y)| x * y).collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{ProductKernel, Stationary1d};
    use crate::linalg::Matrix;
    use crate::operators::{DenseOp, SkiOp};
    use crate::util::{rel_err, Rng};

    /// Exact dense Gram of a product kernel (oracle).
    fn dense_product_gram(xs: &Matrix, k: &ProductKernel) -> Matrix {
        k.gram_sym(xs)
    }

    #[test]
    fn single_component_degenerates_to_lanczos() {
        let mut rng = Rng::new(1);
        let xs = Matrix::from_fn(50, 1, |_, _| rng.normal());
        let k = ProductKernel::rbf(1, 1.0, 1.0);
        let dense = dense_product_gram(&xs, &k);
        let op = DenseOp(dense.clone());
        let skip = SkipOp::build_native(vec![SkipComponent::Op(&op)], 25, &mut rng);
        let v = rng.normal_vec(50);
        assert!(rel_err(&skip.matvec(&v), &dense.matvec(&v)) < 1e-4);
    }

    #[test]
    fn diag_matches_dense_materialization() {
        let mut rng = Rng::new(21);
        let n = 40;
        let xs = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let k = ProductKernel::rbf(2, 1.0, 1.0);
        let g0 = Matrix::from_fn(n, n, |i, j| {
            k.factors[0].eval(xs.get(i, 0), xs.get(j, 0))
        });
        let g1 = Matrix::from_fn(n, n, |i, j| {
            k.factors[1].eval(xs.get(i, 1), xs.get(j, 1))
        });
        let (o0, o1) = (DenseOp(g0), DenseOp(g1));
        let skip = SkipOp::build_native(
            vec![SkipComponent::Op(&o0), SkipComponent::Op(&o1)],
            30,
            &mut rng,
        );
        // The diagonal of the *decomposed* operator (what solves see),
        // checked against its own dense materialization.
        let want = skip.to_dense().diagonal();
        let got = skip.diag().unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn two_component_product_matches_dense() {
        let mut rng = Rng::new(2);
        let n = 60;
        let xs = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let k = ProductKernel::rbf(2, 1.0, 1.0);
        let full = dense_product_gram(&xs, &k);
        // Components: per-dimension dense Grams (exact component MVMs).
        let g0 = Matrix::from_fn(n, n, |i, j| {
            k.factors[0].eval(xs.get(i, 0), xs.get(j, 0))
        });
        let g1 = Matrix::from_fn(n, n, |i, j| {
            k.factors[1].eval(xs.get(i, 1), xs.get(j, 1))
        });
        let (o0, o1) = (DenseOp(g0), DenseOp(g1));
        let skip = SkipOp::build_native(
            vec![SkipComponent::Op(&o0), SkipComponent::Op(&o1)],
            30,
            &mut rng,
        );
        let v = rng.normal_vec(n);
        let err = rel_err(&skip.matvec(&v), &full.matvec(&v));
        assert!(err < 1e-3, "rel err {err}");
    }

    #[test]
    fn four_component_merge_tree() {
        let mut rng = Rng::new(3);
        let n = 50;
        let xs = Matrix::from_fn(n, 4, |_, _| rng.normal());
        let k = ProductKernel::rbf(4, 1.5, 1.0);
        let full = dense_product_gram(&xs, &k);
        let grams: Vec<Matrix> = (0..4)
            .map(|d| {
                Matrix::from_fn(n, n, |i, j| {
                    k.factors[d].eval(xs.get(i, d), xs.get(j, d))
                })
            })
            .collect();
        let ops: Vec<DenseOp> = grams.into_iter().map(DenseOp).collect();
        let comps: Vec<SkipComponent> =
            ops.iter().map(|o| SkipComponent::Op(o as &dyn LinearOp)).collect();
        let skip = SkipOp::build_native(comps, 30, &mut rng);
        assert_eq!(skip.stats.leaf_ranks.len(), 4);
        let v = rng.normal_vec(n);
        let err = rel_err(&skip.matvec(&v), &full.matvec(&v));
        assert!(err < 5e-3, "rel err {err}");
    }

    #[test]
    fn odd_component_count() {
        let mut rng = Rng::new(4);
        let n = 40;
        let xs = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let k = ProductKernel::rbf(3, 1.2, 1.0);
        let full = dense_product_gram(&xs, &k);
        let grams: Vec<Matrix> = (0..3)
            .map(|d| {
                Matrix::from_fn(n, n, |i, j| {
                    k.factors[d].eval(xs.get(i, d), xs.get(j, d))
                })
            })
            .collect();
        let ops: Vec<DenseOp> = grams.into_iter().map(DenseOp).collect();
        let comps: Vec<SkipComponent> =
            ops.iter().map(|o| SkipComponent::Op(o as &dyn LinearOp)).collect();
        let skip = SkipOp::build_native(comps, 30, &mut rng);
        let v = rng.normal_vec(n);
        let err = rel_err(&skip.matvec(&v), &full.matvec(&v));
        assert!(err < 5e-3, "rel err {err}");
    }

    #[test]
    fn ski_components_full_skip_pipeline() {
        // The real §3.1 configuration: SKI per dimension + merge tree.
        let mut rng = Rng::new(5);
        let n = 80;
        let d = 3;
        let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let k = ProductKernel::rbf(d, 0.8, 1.0);
        let full = dense_product_gram(&xs, &k);
        let skis: Vec<SkiOp> = (0..d)
            .map(|dd| SkiOp::new(&xs.col(dd), &k.factors[dd], 64).unwrap())
            .collect();
        let comps: Vec<SkipComponent> =
            skis.iter().map(|o| SkipComponent::Op(o as &dyn LinearOp)).collect();
        let skip = SkipOp::build_native(comps, 40, &mut rng);
        let v = rng.normal_vec(n);
        let err = rel_err(&skip.matvec(&v), &full.matvec(&v));
        assert!(err < 1e-2, "rel err {err}");
    }

    #[test]
    fn exact_factor_component_bypasses_lanczos() {
        // Supplying a Factor leaf must use it verbatim.
        let mut rng = Rng::new(6);
        let n = 30;
        // Exact rank-2 component A = G Gᵀ with factor (Q=G, T=I).
        let g = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let a_dense = g.matmul_t(&g);
        let fac = LanczosFactor { q: g.clone(), t: Matrix::eye(2) };
        // Other component: 1-D RBF Gram.
        let xs: Vec<f64> = rng.normal_vec(n);
        let kern = Stationary1d::rbf(1.0);
        let b_dense = Matrix::from_fn(n, n, |i, j| kern.eval(xs[i], xs[j]));
        let b_op = DenseOp(b_dense.clone());
        let skip = SkipOp::build_native(
            vec![SkipComponent::Factor(fac), SkipComponent::Op(&b_op)],
            25,
            &mut rng,
        );
        let v = rng.normal_vec(n);
        let want = a_dense.hadamard(&b_dense).matvec(&v);
        assert!(rel_err(&skip.matvec(&v), &want) < 1e-4);
    }

    #[test]
    fn error_improves_with_rank() {
        // Engine behind Fig. 2 (left): error decays as r grows.
        let mut rng = Rng::new(7);
        let n = 60;
        let d = 4;
        let xs = Matrix::from_fn(n, d, |_, _| rng.normal());
        let k = ProductKernel::rbf(d, 1.0, 1.0);
        let full = dense_product_gram(&xs, &k);
        let grams: Vec<Matrix> = (0..d)
            .map(|dd| {
                Matrix::from_fn(n, n, |i, j| {
                    k.factors[dd].eval(xs.get(i, dd), xs.get(j, dd))
                })
            })
            .collect();
        let ops: Vec<DenseOp> = grams.into_iter().map(DenseOp).collect();
        let v = rng.normal_vec(n);
        let want = full.matvec(&v);
        let mut errs = Vec::new();
        for r in [5usize, 15, 40] {
            let comps: Vec<SkipComponent> = ops
                .iter()
                .map(|o| SkipComponent::Op(o as &dyn LinearOp))
                .collect();
            let skip = SkipOp::build_native(comps, r, &mut rng);
            errs.push(rel_err(&skip.matvec(&v), &want));
        }
        assert!(errs[2] < errs[0], "errors {errs:?} should decrease");
        assert!(errs[2] < 1e-2, "finest err {}", errs[2]);
    }
}
