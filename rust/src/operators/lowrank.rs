//! Low-rank Lanczos factors and the Lemma-3.1 Hadamard-product MVM.
//!
//! `LanczosFactor` holds `K ≈ Q T Qᵀ` (Q: n×r orthonormal, T: r×r). The
//! key identity (paper Eq. 10–11):
//!
//! ```text
//! (K⁽¹⁾ ∘ K⁽²⁾) v = Δ(K⁽¹⁾ D_v K⁽²⁾ᵀ)
//!                 = rowwise⟨Q₁, (Q₂ Mᵀ)⟩,  M = T₁ (Q₁ᵀ D_v Q₂) T₂ᵀ
//! ```
//!
//! which costs O(r²n) (Lemma 3.1). The contraction is the *compute
//! hot-spot* of the whole system; it is expressed behind
//! [`ContractionBackend`] so the rust-native implementation and the
//! AOT-compiled Pallas/XLA artifact (loaded via PJRT in `crate::runtime`)
//! are interchangeable.

use super::LinearOp;
use crate::linalg::Matrix;
use crate::util::parallel::{par_map_range, par_row_chunks};

/// Rank-r approximation `K ≈ Q T Qᵀ`.
#[derive(Clone, Debug)]
pub struct LanczosFactor {
    /// n × r, orthonormal columns.
    pub q: Matrix,
    /// r × r symmetric (tridiagonal when produced by Lanczos).
    pub t: Matrix,
}

impl LanczosFactor {
    pub fn rank(&self) -> usize {
        self.q.cols
    }

    pub fn dim(&self) -> usize {
        self.q.rows
    }

    /// Dense reconstruction Q T Qᵀ (tests only).
    pub fn to_dense(&self) -> Matrix {
        self.q.matmul(&self.t).matmul_t(&self.q)
    }

    /// `(Q T Qᵀ) v` in O(nr).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let a = self.q.t_matvec(v);
        let b = self.t.matvec(&a);
        self.q.matvec(&b)
    }

    /// `(Q T Qᵀ) M` for an n×t block in O(nrt) — three gemms instead of t
    /// gemv chains, so `Q` streams through cache once per stage for the
    /// whole block (and the big `Q ·` stage is row-parallel).
    pub fn matmat(&self, m: &Matrix) -> Matrix {
        let a = self.q.t_matmul(m);
        let b = self.t.matmul(&a);
        self.q.matmul(&b)
    }

    /// Exact diagonal in O(nr²): `diag_i = q_i T q_iᵀ` per row, via
    /// `B = Q T` once and then row dot-products.
    pub fn diag(&self) -> Vec<f64> {
        let b = self.q.matmul(&self.t);
        (0..self.q.rows)
            .map(|i| {
                self.q
                    .row(i)
                    .iter()
                    .zip(b.row(i))
                    .map(|(qi, bi)| qi * bi)
                    .sum()
            })
            .collect()
    }
}

impl LinearOp for LanczosFactor {
    fn dim(&self) -> usize {
        self.q.rows
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        LanczosFactor::matvec(self, v)
    }

    fn matmat(&self, m: &Matrix) -> Matrix {
        LanczosFactor::matmat(self, m)
    }

    fn diag(&self) -> Option<Vec<f64>> {
        Some(LanczosFactor::diag(self))
    }
}

/// Pluggable executor for the Lemma-3.1 contraction.
///
/// Implementations: [`NativeBackend`] (pure rust, any shape) and
/// `runtime::PjrtBackend` (AOT Pallas/XLA artifact for registered shapes,
/// falling back to native otherwise).
pub trait ContractionBackend: Send + Sync {
    /// Compute `(Q₁T₁Q₁ᵀ ∘ Q₂T₂Q₂ᵀ) v` per Lemma 3.1.
    fn hadamard_pair_matvec(
        &self,
        a: &LanczosFactor,
        b: &LanczosFactor,
        v: &[f64],
    ) -> Vec<f64>;

    /// Compute `(Q₁T₁Q₁ᵀ ∘ Q₂T₂Q₂ᵀ) M` for an n×t block — Lemma 3.1
    /// generalizes from vectors to blocks column-wise, which is exactly
    /// what this default does. [`NativeBackend`] overrides it with the
    /// fused single-pass contraction
    /// [`hadamard_pair_matmat_native`], the root fast path of the batched
    /// MVM engine.
    fn hadamard_pair_matmat(
        &self,
        a: &LanczosFactor,
        b: &LanczosFactor,
        m: &Matrix,
    ) -> Matrix {
        assert_eq!(m.rows, a.dim());
        let mut out = Matrix::zeros(a.dim(), m.cols);
        for j in 0..m.cols {
            out.set_col(j, &self.hadamard_pair_matvec(a, b, &m.col(j)));
        }
        out
    }

    /// Human-readable backend name (for logs/metrics).
    fn name(&self) -> &'static str;
}

/// Pure-rust reference backend.
pub struct NativeBackend;

impl ContractionBackend for NativeBackend {
    fn hadamard_pair_matvec(
        &self,
        a: &LanczosFactor,
        b: &LanczosFactor,
        v: &[f64],
    ) -> Vec<f64> {
        hadamard_pair_matvec_native(a, b, v)
    }

    fn hadamard_pair_matmat(
        &self,
        a: &LanczosFactor,
        b: &LanczosFactor,
        m: &Matrix,
    ) -> Matrix {
        hadamard_pair_matmat_native(a, b, m)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Native Lemma-3.1 contraction: O(n·r₁·r₂) time, O(n·max r) extra space.
pub fn hadamard_pair_matvec_native(
    a: &LanczosFactor,
    b: &LanczosFactor,
    v: &[f64],
) -> Vec<f64> {
    let n = a.dim();
    assert_eq!(b.dim(), n);
    assert_eq!(v.len(), n);
    let (r1, r2) = (a.rank(), b.rank());
    // S = Q₁ᵀ D_v Q₂  (r1 × r2), accumulated row-by-row: S += v_i q₁ᵢᵀ q₂ᵢ.
    let mut s = Matrix::zeros(r1, r2);
    for i in 0..n {
        let vi = v[i];
        if vi == 0.0 {
            continue;
        }
        let q1i = a.q.row(i);
        let q2i = b.q.row(i);
        for (p, &q1v) in q1i.iter().enumerate() {
            let c = vi * q1v;
            let srow = &mut s.data[p * r2..(p + 1) * r2];
            for (sv, &q2v) in srow.iter_mut().zip(q2i) {
                *sv += c * q2v;
            }
        }
    }
    // M = T₁ S T₂ᵀ  (r1 × r2): the identity is (A∘B)v = Δ(A D_v Bᵀ) with
    // Bᵀ = Q₂ T₂ᵀ Q₂ᵀ. Lanczos T is symmetric, but exact factors supplied
    // via `SkipComponent::Factor` need not be.
    let m = a.t.matmul(&s.matmul_t(&b.t));
    // out_i = q₁ᵢ M q₂ᵢᵀ, fused row-wise: w = q₁ᵢ M (gathered down M's
    // contiguous rows), then ⟨w, q₂ᵢ⟩. Avoids materializing the n×r
    // intermediate P = Q₂Mᵀ (perf log: −20 % on the n=2048/r=32 bench).
    let mut out = vec![0.0; n];
    let mut w = vec![0.0; r2];
    for i in 0..n {
        let q1i = a.q.row(i);
        let q2i = b.q.row(i);
        w.iter_mut().for_each(|x| *x = 0.0);
        for (p, &q1v) in q1i.iter().enumerate() {
            if q1v == 0.0 {
                continue;
            }
            let mrow = &m.data[p * r2..(p + 1) * r2];
            for (wv, &mv) in w.iter_mut().zip(mrow) {
                *wv += q1v * mv;
            }
        }
        let mut acc = 0.0;
        for (&wv, &q2v) in w.iter().zip(q2i) {
            acc += wv * q2v;
        }
        out[i] = acc;
    }
    out
}

/// Fused native Lemma-3.1 contraction for an n×t block of right-hand
/// sides: `(Q₁T₁Q₁ᵀ ∘ Q₂T₂Q₂ᵀ) M` in O(n·r₁·r₂·t) — flop-identical to t
/// calls of [`hadamard_pair_matvec_native`] but with `Q₁`, `Q₂` streamed
/// through cache **once per pass for the whole block** instead of once per
/// column, which is where the batched-engine wall-clock win comes from
/// (the contraction is memory-bound at SKIP's typical r).
///
/// Three passes, mirroring the single-RHS path:
/// 1. `S⁽ʲ⁾ = Q₁ᵀ D_{m_j} Q₂` for all j in one row sweep (parallel over
///    row chunks with per-thread partials, reduced in chunk order).
/// 2. `M⁽ʲ⁾ = T₁ S⁽ʲ⁾ T₂ᵀ` — t tiny gemms, parallel over j.
/// 3. `out[i, j] = q₁ᵢ M⁽ʲ⁾ q₂ᵢᵀ` in one row sweep (row-parallel).
pub fn hadamard_pair_matmat_native(
    a: &LanczosFactor,
    b: &LanczosFactor,
    m: &Matrix,
) -> Matrix {
    let n = a.dim();
    assert_eq!(b.dim(), n);
    assert_eq!(m.rows, n);
    let t = m.cols;
    let (r1, r2) = (a.rank(), b.rank());
    let mut out = Matrix::zeros(n, t);
    if t == 0 || n == 0 {
        return out;
    }
    // --- Pass 1: all t S-matrices in one sweep over the n rows.
    let block = r1 * r2;
    // Chunk count derives from n alone (NOT the core count): the partials
    // are reduced in chunk order, so the summation grouping — and hence
    // the bitwise result — is machine-independent. par_map spreads the
    // fixed chunks over however many threads exist.
    let chunks = n.div_ceil(1024);
    let chunk_rows = n.div_ceil(chunks);
    let partials = par_map_range(chunks, 2, |c| {
        let lo = c * chunk_rows;
        let hi = ((c + 1) * chunk_rows).min(n);
        let mut s = vec![0.0; t * block];
        for i in lo..hi {
            let vrow = m.row(i);
            let q1i = a.q.row(i);
            let q2i = b.q.row(i);
            for (j, &vj) in vrow.iter().enumerate() {
                if vj == 0.0 {
                    continue;
                }
                let sj = &mut s[j * block..(j + 1) * block];
                for (p, &q1v) in q1i.iter().enumerate() {
                    let c0 = vj * q1v;
                    let srow = &mut sj[p * r2..(p + 1) * r2];
                    for (sv, &q2v) in srow.iter_mut().zip(q2i) {
                        *sv += c0 * q2v;
                    }
                }
            }
        }
        s
    });
    let mut s_all = vec![0.0; t * block];
    for part in partials {
        for (acc, x) in s_all.iter_mut().zip(part) {
            *acc += x;
        }
    }
    // --- Pass 2: M⁽ʲ⁾ = T₁ S⁽ʲ⁾ T₂ᵀ, parallel across the t columns only
    // when the tiny gemms are worth a thread spawn (~2r₁r₂(r₁+r₂) flops
    // each; below the threshold the serial loop wins).
    let gemm_flops = r1 * r2 * (r1 + r2);
    let min_cols = ((1usize << 16) / gemm_flops.max(1)).max(2);
    let ms: Vec<Matrix> = par_map_range(t, min_cols, |j| {
        let sj = Matrix::from_vec(r1, r2, s_all[j * block..(j + 1) * block].to_vec());
        a.t.matmul(&sj.matmul_t(&b.t))
    });
    // --- Pass 3: row-wise bilinear diagonal for all t columns at once.
    let min_rows = ((1usize << 16) / (t * block).max(1)).max(8);
    par_row_chunks(&mut out.data, t, min_rows, |first_row, chunk| {
        let mut w = vec![0.0; r2];
        for (r, o_row) in chunk.chunks_mut(t).enumerate() {
            let i = first_row + r;
            let q1i = a.q.row(i);
            let q2i = b.q.row(i);
            for (o, mj) in o_row.iter_mut().zip(&ms) {
                w.iter_mut().for_each(|x| *x = 0.0);
                for (p, &q1v) in q1i.iter().enumerate() {
                    if q1v == 0.0 {
                        continue;
                    }
                    let mrow = &mj.data[p * r2..(p + 1) * r2];
                    for (wv, &mv) in w.iter_mut().zip(mrow) {
                        *wv += q1v * mv;
                    }
                }
                let mut acc = 0.0;
                for (&wv, &q2v) in w.iter().zip(q2i) {
                    acc += wv * q2v;
                }
                *o = acc;
            }
        }
    });
    out
}

/// A pair of factors exposed as the Hadamard-product operator
/// `A ∘ B` — the root node of SKIP's merge tree.
pub struct HadamardPairOp<'a> {
    pub a: &'a LanczosFactor,
    pub b: &'a LanczosFactor,
    pub backend: &'a dyn ContractionBackend,
}

impl<'a> LinearOp for HadamardPairOp<'a> {
    fn dim(&self) -> usize {
        self.a.dim()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        self.backend.hadamard_pair_matvec(self.a, self.b, v)
    }

    /// Fast path: the backend's fused block contraction.
    fn matmat(&self, m: &Matrix) -> Matrix {
        self.backend.hadamard_pair_matmat(self.a, self.b, m)
    }

    /// Hadamard products multiply diagonals elementwise.
    fn diag(&self) -> Option<Vec<f64>> {
        let da = LanczosFactor::diag(self.a);
        let db = LanczosFactor::diag(self.b);
        Some(da.iter().zip(&db).map(|(x, y)| x * y).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rel_err, Rng};

    fn random_factor(n: usize, r: usize, seed: u64) -> LanczosFactor {
        let mut rng = Rng::new(seed);
        // Orthonormalize a random n×r via Gram–Schmidt.
        let mut q = Matrix::from_fn(n, r, |_, _| rng.normal());
        for j in 0..r {
            for k in 0..j {
                let col_k = q.col(k);
                let col_j = q.col(j);
                let d: f64 = col_k.iter().zip(&col_j).map(|(a, b)| a * b).sum();
                for i in 0..n {
                    let v = q.get(i, j) - d * q.get(i, k);
                    q.set(i, j, v);
                }
            }
            let nrm: f64 = q.col(j).iter().map(|x| x * x).sum::<f64>().sqrt();
            for i in 0..n {
                let v = q.get(i, j) / nrm;
                q.set(i, j, v);
            }
        }
        // Symmetric T.
        let mut t = Matrix::from_fn(r, r, |_, _| rng.normal());
        t.symmetrize();
        LanczosFactor { q, t }
    }

    #[test]
    fn factor_matvec_matches_dense() {
        let f = random_factor(30, 5, 1);
        let mut rng = Rng::new(2);
        let v = rng.normal_vec(30);
        let got = f.matvec(&v);
        let want = f.to_dense().matvec(&v);
        assert!(rel_err(&got, &want) < 1e-10);
    }

    #[test]
    fn factor_and_pair_diag_match_dense() {
        let a = random_factor(30, 5, 11);
        let b = random_factor(30, 4, 12);
        let da = LinearOp::diag(&a).unwrap();
        let want_a = a.to_dense().diagonal();
        for (g, w) in da.iter().zip(&want_a) {
            assert!((g - w).abs() < 1e-10);
        }
        let backend = NativeBackend;
        let op = HadamardPairOp { a: &a, b: &b, backend: &backend };
        let dab = op.diag().unwrap();
        let want = a.to_dense().hadamard(&b.to_dense()).diagonal();
        for (g, w) in dab.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn lemma31_matches_explicit_hadamard() {
        let a = random_factor(40, 6, 3);
        let b = random_factor(40, 4, 4);
        let mut rng = Rng::new(5);
        let v = rng.normal_vec(40);
        let got = hadamard_pair_matvec_native(&a, &b, &v);
        let want = a.to_dense().hadamard(&b.to_dense()).matvec(&v);
        assert!(rel_err(&got, &want) < 1e-10, "err {}", rel_err(&got, &want));
    }

    #[test]
    fn lemma31_rank_one_analytic() {
        // Q = col of ones/√n, T = [c] → QTQᵀ = (c/n) 11ᵀ.
        let n = 8;
        let q = Matrix::from_fn(n, 1, |_, _| 1.0 / (n as f64).sqrt());
        let a = LanczosFactor { q: q.clone(), t: Matrix::from_vec(1, 1, vec![2.0]) };
        let b = LanczosFactor { q, t: Matrix::from_vec(1, 1, vec![3.0]) };
        // A = (2/8)·1, B = (3/8)·1 → A∘B = (6/64)·11ᵀ; (A∘B)v = 6/64 Σv.
        let v: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let got = hadamard_pair_matvec_native(&a, &b, &v);
        let sum: f64 = v.iter().sum();
        for g in got {
            assert!((g - 6.0 / 64.0 * sum).abs() < 1e-12);
        }
    }

    #[test]
    fn hadamard_op_symmetric() {
        let a = random_factor(25, 3, 7);
        let b = random_factor(25, 3, 8);
        let backend = NativeBackend;
        let op = HadamardPairOp { a: &a, b: &b, backend: &backend };
        let mut rng = Rng::new(9);
        let u = rng.normal_vec(25);
        let v = rng.normal_vec(25);
        let lhs: f64 = op.matvec(&u).iter().zip(&v).map(|(a, b)| a * b).sum();
        let rhs: f64 = op.matvec(&v).iter().zip(&u).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn lemma31_nonsymmetric_t_matrices() {
        // Regression test for the T₂ᵀ subtlety: with non-symmetric T the
        // contraction must still match the dense Hadamard oracle.
        let mut rng = Rng::new(42);
        let n = 30;
        let a = LanczosFactor {
            q: Matrix::from_fn(n, 3, |_, _| rng.normal()),
            t: Matrix::from_fn(3, 3, |_, _| rng.normal()),
        };
        let b = LanczosFactor {
            q: Matrix::from_fn(n, 4, |_, _| rng.normal()),
            t: Matrix::from_fn(4, 4, |_, _| rng.normal()),
        };
        let v = rng.normal_vec(n);
        let got = hadamard_pair_matvec_native(&a, &b, &v);
        let want = a.to_dense().hadamard(&b.to_dense()).matvec(&v);
        assert!(rel_err(&got, &want) < 1e-10, "err {}", rel_err(&got, &want));
    }

    #[test]
    fn block_contraction_matches_per_column() {
        let a = random_factor(50, 6, 20);
        let b = random_factor(50, 4, 21);
        let mut rng = Rng::new(22);
        for t in [1usize, 3, 8] {
            let m = Matrix::from_fn(50, t, |_, _| rng.normal());
            let got = hadamard_pair_matmat_native(&a, &b, &m);
            for j in 0..t {
                let want = hadamard_pair_matvec_native(&a, &b, &m.col(j));
                let gcol = got.col(j);
                for (g, w) in gcol.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-10, "t={t} col {j}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn factor_matmat_matches_dense() {
        let f = random_factor(35, 5, 23);
        let mut rng = Rng::new(24);
        let m = Matrix::from_fn(35, 4, |_, _| rng.normal());
        let got = f.matmat(&m);
        let want = f.to_dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn backend_default_matmat_agrees_with_native_override() {
        let a = random_factor(30, 3, 25);
        let b = random_factor(30, 5, 26);
        let mut rng = Rng::new(27);
        let m = Matrix::from_fn(30, 6, |_, _| rng.normal());
        // Default (column loop over matvec) vs the fused override.
        struct ColumnLoop;
        impl ContractionBackend for ColumnLoop {
            fn hadamard_pair_matvec(
                &self,
                a: &LanczosFactor,
                b: &LanczosFactor,
                v: &[f64],
            ) -> Vec<f64> {
                hadamard_pair_matvec_native(a, b, v)
            }
            fn name(&self) -> &'static str {
                "column-loop"
            }
        }
        let serial = ColumnLoop.hadamard_pair_matmat(&a, &b, &m);
        let fused = NativeBackend.hadamard_pair_matmat(&a, &b, &m);
        assert!(serial.max_abs_diff(&fused) < 1e-10);
    }

    #[test]
    fn mismatched_rank_pairs_work() {
        let a = random_factor(20, 2, 10);
        let b = random_factor(20, 7, 11);
        let mut rng = Rng::new(12);
        let v = rng.normal_vec(20);
        let got = hadamard_pair_matvec_native(&a, &b, &v);
        let want = a.to_dense().hadamard(&b.to_dense()).matvec(&v);
        assert!(rel_err(&got, &want) < 1e-10);
    }
}
