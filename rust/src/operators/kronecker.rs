//! KISS-GP operator: SKI with a d-dimensional Kronecker grid (paper §2.3,
//! §5 — the baseline SKIP improves on).
//!
//! `K_XX ≈ W (T₁ ⊗ ⋯ ⊗ T_d) Wᵀ` where the grid is the Cartesian product
//! of d regular 1-D grids (per-dimension sizes m_k → M = Π m_k inducing
//! points) and `W` carries the tensor-product interpolation weights per
//! row. For the uniform dense grid, MVM cost is O(4ᵈ n + d M log m):
//! *exponential in d* — the curse of dimensionality that both SKIP and the
//! sparse combination-technique grid (`crate::grid::SparseGrid`, which
//! sums anisotropic instances of this very operator) remove.

use super::{LinearOp, LinearOpF32};
use crate::grid::{
    tensor_stencil, tensor_stencil_grad, tensor_stencil_size, Grid1d, InducingGrid,
    RectilinearGrid,
};
use crate::kernels::ProductKernel;
use crate::linalg::{Matrix, SymToeplitz};
use crate::util::parallel::par_map_range;
use crate::{Error, Result};
use std::sync::{Mutex, OnceLock};

/// Precomputed stencil-overlap structure `G = WᵀW` (m × m, sparse) for one
/// SKI interpolation matrix — the matrix the grid-space normal equations
/// (`solvers::gridspace`) apply once or twice per iteration.
///
/// Every per-axis stencil emits **consecutive** grid indices (cubic: 4,
/// base-clamped to `[0, m−4]`; linear: 2; constant: 1 — see
/// `grid::axis`), so two stencil entries of the same data row differ by at
/// most `w_k − 1 ≤ 3` along axis k. `G[a, b]` is therefore nonzero only
/// when `b − a` decomposes into per-axis deltas within `±(w_k − 1)`: a
/// *banded* structure with `Π_k (2w_k − 1)` (≤ 7ᵈ) offsets per grid
/// point, stored dense per offset. Build cost is O(n·s²) arithmetic
/// (s = stencil entries per row); apply cost is O(m·7ᵈ) — independent
/// of n, which is the whole point.
#[derive(Clone, Debug)]
pub struct StencilGram {
    /// Per-dimension grid sizes (dim 0 slowest, row-major flat indices).
    dims: Vec<usize>,
    strides: Vec<usize>,
    /// Per-axis offset counts `2w_k − 1` and their mixed-radix strides.
    ocounts: Vec<usize>,
    ostrides: Vec<usize>,
    /// Per-offset per-axis deltas (o × d, values in `−3..=3`) and the
    /// corresponding flat-index shifts.
    odeltas: Vec<i32>,
    oshifts: Vec<isize>,
    /// Band values, m × o row-major: `band[g·o + t] = G[g, g + shift_t]`.
    band: Vec<f64>,
    m: usize,
    o: usize,
}

impl StencilGram {
    /// Build from the stencil rows of `idx`/`w` (n rows × s entries).
    fn build(grids: &[Grid1d], idx: &[u32], w: &[f64], s: usize) -> Self {
        let dims: Vec<usize> = grids.iter().map(|g| g.m).collect();
        let strides = crate::grid::tensor_strides(&dims);
        let widths: Vec<usize> = grids.iter().map(|g| g.stencil_width()).collect();
        let ocounts: Vec<usize> = widths.iter().map(|&w| 2 * w - 1).collect();
        let ostrides = crate::grid::tensor_strides(&ocounts);
        let o: usize = ocounts.iter().product();
        let d = dims.len();
        let m: usize = dims.iter().product();
        // Per-offset delta vectors and flat shifts, decoded once.
        let mut odeltas = Vec::with_capacity(o * d);
        let mut oshifts = Vec::with_capacity(o);
        for t in 0..o {
            let mut shift = 0isize;
            for k in 0..d {
                let delta = ((t / ostrides[k]) % ocounts[k]) as i32 - (widths[k] as i32 - 1);
                odeltas.push(delta);
                shift += delta as isize * strides[k] as isize;
            }
            oshifts.push(shift);
        }
        let mut gram = StencilGram {
            dims,
            strides,
            ocounts,
            ostrides,
            odeltas,
            oshifts,
            band: vec![0.0; m * o],
            m,
            o,
        };
        debug_assert_eq!(idx.len(), w.len());
        let n = idx.len() / s;
        let mut scratch = vec![0usize; s * gram.dims.len()];
        for i in 0..n {
            gram.accumulate_row(&idx[i * s..(i + 1) * s], &w[i * s..(i + 1) * s], &mut scratch);
        }
        gram
    }

    /// Fold one more stencil row into the band — the streaming path's
    /// incremental `WᵀW` update (`G += wᵀw` for the new row's sparse
    /// stencil vector `w`), O(s²·d) independent of both n and m.
    pub fn append_row(&mut self, idx: &[u32], w: &[f64]) {
        let mut scratch = vec![0usize; idx.len() * self.dims.len()];
        self.accumulate_row(idx, w, &mut scratch);
    }

    /// Fold one stencil row's `s × s` overlap products into the band.
    /// `coords` is caller-provided scratch of length ≥ s·d.
    fn accumulate_row(&mut self, idx: &[u32], w: &[f64], coords: &mut [usize]) {
        let d = self.dims.len();
        let s = idx.len();
        // Decode this row's stencil coordinates once.
        debug_assert!(s * d <= coords.len(), "stencil × dim exceeds decode buffer");
        for a in 0..s {
            let flat = idx[a] as usize;
            for k in 0..d {
                coords[a * d + k] = (flat / self.strides[k]) % self.dims[k];
            }
        }
        for a in 0..s {
            let wa = w[a];
            let ga = idx[a] as usize;
            let base = ga * self.o;
            for b in 0..s {
                let mut t = 0usize;
                for k in 0..d {
                    let delta = coords[b * d + k] as i32 - coords[a * d + k] as i32
                        + (self.ocounts[k] as i32 - 1) / 2;
                    t += delta as usize * self.ostrides[k];
                }
                self.band[base + t] += wa * w[b];
            }
        }
    }

    /// `G u` — O(m·o), independent of the number of data rows folded in.
    ///
    /// Interior grid points (the vast majority) take a check-free fast
    /// path: every offset provably lands inside the grid, so the band row
    /// streams as a zipped slice pair. Only boundary points pay the
    /// per-axis wrap check. Both paths visit offsets in the same order
    /// with the same zero-skips, so the result is bitwise independent of
    /// which path ran.
    pub fn apply(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.m);
        let d = self.dims.len();
        let mut out = vec![0.0; self.m];
        let mut coords = vec![0usize; d];
        let rows = self.band.chunks_exact(self.o).zip(out.iter_mut());
        for (g, (row, og)) in rows.enumerate() {
            let mut interior = true;
            for k in 0..d {
                coords[k] = (g / self.strides[k]) % self.dims[k];
                let w1 = (self.ocounts[k] - 1) / 2;
                if coords[k] < w1 || coords[k] + w1 >= self.dims[k] {
                    interior = false;
                }
            }
            let mut acc = 0.0;
            if interior {
                for (&val, &shift) in row.iter().zip(&self.oshifts) {
                    if val == 0.0 {
                        continue;
                    }
                    acc += val * u[(g as isize + shift) as usize];
                }
            } else {
                for (t, &val) in row.iter().enumerate() {
                    if val == 0.0 {
                        continue;
                    }
                    // Per-axis bound check: the flat shift alone can wrap
                    // into a neighboring fiber.
                    let deltas = &self.odeltas[t * d..(t + 1) * d];
                    let mut ok = true;
                    for k in 0..d {
                        let c = coords[k] as i32 + deltas[k];
                        if c < 0 || c >= self.dims[k] as i32 {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        let nb = (g as isize + self.oshifts[t]) as usize;
                        acc += val * u[nb];
                    }
                }
            }
            *og = acc;
        }
        out
    }

    /// Grid size m (the operator is m × m).
    pub fn dim(&self) -> usize {
        self.m
    }

    /// Stored band entries per grid point (`Π_k (2w_k − 1)` ≤ 7ᵈ).
    pub fn band_width(&self) -> usize {
        self.o
    }

    /// Per-solve f32 view of the band (same offsets, converted values)
    /// for mixed-precision grid-space inner iterations. Built fresh each
    /// solve so there is nothing to invalidate when
    /// [`KroneckerSkiOp::append_rows`] folds new rows into the f64 band.
    pub fn f32_view(&self) -> GramF32<'_> {
        GramF32 { gram: self, band: self.band.iter().map(|&x| x as f32).collect() }
    }
}

/// Borrowed f32 mirror of a [`StencilGram`]: the f64 band converted once,
/// offset/stride structure shared with the parent.
pub struct GramF32<'a> {
    gram: &'a StencilGram,
    band: Vec<f32>,
}

impl GramF32<'_> {
    /// Grid size m (the operator is m × m).
    pub fn dim(&self) -> usize {
        self.gram.m
    }

    /// `G u` over f32 operands — same traversal as [`StencilGram::apply`]
    /// (interior fast path + boundary checks), f32 arithmetic.
    pub fn apply_f32(&self, u: &[f32]) -> Vec<f32> {
        let g64 = self.gram;
        assert_eq!(u.len(), g64.m);
        let d = g64.dims.len();
        let mut out = vec![0.0f32; g64.m];
        let mut coords = vec![0usize; d];
        let rows = self.band.chunks_exact(g64.o).zip(out.iter_mut());
        for (g, (row, og)) in rows.enumerate() {
            let mut interior = true;
            for k in 0..d {
                coords[k] = (g / g64.strides[k]) % g64.dims[k];
                let w1 = (g64.ocounts[k] - 1) / 2;
                if coords[k] < w1 || coords[k] + w1 >= g64.dims[k] {
                    interior = false;
                }
            }
            let mut acc = 0.0f32;
            if interior {
                for (&val, &shift) in row.iter().zip(&g64.oshifts) {
                    if val == 0.0 {
                        continue;
                    }
                    acc += val * u[(g as isize + shift) as usize];
                }
            } else {
                for (t, &val) in row.iter().enumerate() {
                    if val == 0.0 {
                        continue;
                    }
                    let deltas = &g64.odeltas[t * d..(t + 1) * d];
                    let mut ok = true;
                    for k in 0..d {
                        let c = coords[k] as i32 + deltas[k];
                        if c < 0 || c >= g64.dims[k] as i32 {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        acc += val * u[(g as isize + g64.oshifts[t]) as usize];
                    }
                }
            }
            *og = acc;
        }
        out
    }
}

/// Reusable buffers for [`kron_toeplitz_matvec_with`]: the mode-wise
/// sweep's ping-pong tensors plus per-fiber staging. One workspace per
/// concurrent caller; buffers grow to the largest tensor seen and stay
/// warm, so repeated applies (every CG iteration) allocate only the
/// returned vector.
#[derive(Debug, Default)]
pub struct KronScratch {
    cur: Vec<f64>,
    next: Vec<f64>,
    fiber_in: Vec<f64>,
    fiber_out: Vec<f64>,
}

/// `(T₁ ⊗ ⋯ ⊗ T_d) u` via mode-wise Toeplitz application, for a
/// row-major tensor grid with per-dimension sizes `dims` (dimension 0
/// slowest). Shared by the KISS-GP operator and the serving layer's
/// grid-side predictive caches (`crate::serve::cache`), which apply the
/// same grid kernel to mean/variance caches at snapshot-build time.
///
/// Allocates a fresh workspace per call; iterative callers should hold a
/// [`KronScratch`] and use [`kron_toeplitz_matvec_with`] instead.
pub fn kron_toeplitz_matvec(factors: &[SymToeplitz], dims: &[usize], u: &[f64]) -> Vec<f64> {
    let mut ws = KronScratch::default();
    kron_toeplitz_matvec_with(factors, dims, u, &mut ws)
}

/// [`kron_toeplitz_matvec`] with caller-held scratch: the per-mode
/// ping-pong tensor and fiber buffers come from `ws`, and the Toeplitz
/// factors run through [`SymToeplitz::matvec_into`], so steady-state
/// applies allocate nothing but the returned vector.
pub fn kron_toeplitz_matvec_with(
    factors: &[SymToeplitz],
    dims: &[usize],
    u: &[f64],
    ws: &mut KronScratch,
) -> Vec<f64> {
    let d = dims.len();
    assert_eq!(factors.len(), d);
    debug_assert_eq!(u.len(), dims.iter().product::<usize>());
    ws.cur.clear();
    ws.cur.extend_from_slice(u);
    for k in 0..d {
        let mk = dims[k];
        if mk == 1 {
            // A 1-point axis applies a 1×1 kernel: a scalar scale.
            let s = factors[k].col[0];
            if s != 1.0 {
                for v in ws.cur.iter_mut() {
                    *v *= s;
                }
            }
            continue;
        }
        // Stride between consecutive indices along mode k.
        let stride: usize = dims[k + 1..].iter().product();
        let outer: usize = dims[..k].iter().product();
        ws.next.clear();
        ws.next.resize(ws.cur.len(), 0.0);
        ws.fiber_in.clear();
        ws.fiber_in.resize(mk, 0.0);
        ws.fiber_out.clear();
        ws.fiber_out.resize(mk, 0.0);
        for o in 0..outer {
            for s in 0..stride {
                let start = o * mk * stride + s;
                for t in 0..mk {
                    ws.fiber_in[t] = ws.cur[start + t * stride];
                }
                factors[k].matvec_into(&ws.fiber_in, &mut ws.fiber_out);
                for t in 0..mk {
                    ws.next[start + t * stride] = ws.fiber_out[t];
                }
            }
        }
        std::mem::swap(&mut ws.cur, &mut ws.next);
    }
    std::mem::take(&mut ws.cur)
}

/// `(T₁ ⊗ ⋯ ⊗ T_d) u` over f32 operands — the mixed-precision mirror of
/// [`kron_toeplitz_matvec`], applying each factor through its cached f32
/// spectrum ([`SymToeplitz::matvec_f32`]).
pub fn kron_toeplitz_matvec_f32(
    factors: &[SymToeplitz],
    dims: &[usize],
    u: &[f32],
) -> Vec<f32> {
    let d = dims.len();
    assert_eq!(factors.len(), d);
    debug_assert_eq!(u.len(), dims.iter().product::<usize>());
    let mut cur = u.to_vec();
    for k in 0..d {
        let mk = dims[k];
        if mk == 1 {
            let s = factors[k].col[0] as f32;
            if s != 1.0 {
                for v in cur.iter_mut() {
                    *v *= s;
                }
            }
            continue;
        }
        let stride: usize = dims[k + 1..].iter().product();
        let outer: usize = dims[..k].iter().product();
        let mut next = vec![0.0f32; cur.len()];
        let mut fiber = vec![0.0f32; mk];
        for o in 0..outer {
            for s in 0..stride {
                let start = o * mk * stride + s;
                for t in 0..mk {
                    fiber[t] = cur[start + t * stride];
                }
                let res = factors[k].matvec_f32(&fiber);
                for t in 0..mk {
                    next[start + t * stride] = res[t];
                }
            }
        }
        cur = next;
    }
    cur
}

/// Tensor-product SKI operator over a d-dimensional rectilinear grid
/// (uniform dense KISS-GP grids and the anisotropic terms of
/// `crate::grid::SparseGrid` alike).
pub struct KroneckerSkiOp {
    /// Per-dimension grids (m_k points each).
    pub grids: Vec<Grid1d>,
    /// Per-dimension Toeplitz grid-kernel factors.
    pub factors: Vec<SymToeplitz>,
    /// Sparse W: for each data row, `stencil` (flat grid index, weight)
    /// pairs.
    idx: Vec<u32>,
    w: Vec<f64>,
    n: usize,
    /// Total grid size M = Π m_k.
    pub total_grid: usize,
    /// Stencil entries per data row (Π per-axis widths — 4ᵈ on a dense
    /// cubic grid, far less on anisotropic sparse-grid terms).
    stencil: usize,
    /// Output scale σ² of the product kernel.
    outputscale: f64,
    /// Lazily-built `WᵀW` stencil Gram (see [`StencilGram`]); built on
    /// first [`Self::grid_space_op`] call, then updated incrementally by
    /// [`Self::append_rows`].
    gram: OnceLock<StencilGram>,
    /// Mode-sweep workspace for [`Self::kron_matvec`] — `try_lock` per
    /// apply, so the serial CG hot loop reuses warm buffers while
    /// parallel `matmat` columns that lose the race fall back to a local
    /// workspace instead of blocking.
    scratch: Mutex<KronScratch>,
}

/// Band entries `m × Π(2w_k − 1)` above which [`KroneckerSkiOp::grid_space_op`]
/// refuses to materialize `WᵀW` (≈ 0.5 GB of f64 band storage) — dense
/// d ≥ 4 grids, where the data-space path is the right tool anyway.
const MAX_GRAM_ENTRIES: usize = 1 << 26;

impl KroneckerSkiOp {
    /// Build for data `xs` (n × d) under a product kernel with `m` grid
    /// points per dimension (the classic uniform KISS-GP grid).
    pub fn new(xs: &Matrix, kernel: &ProductKernel, m: usize) -> Result<Self> {
        let grid = RectilinearGrid::fit_uniform(xs, m)?;
        Ok(Self::with_grids(xs, kernel, grid.terms()[0].axes.clone()))
    }

    /// Build on explicit per-dimension grids (per-dimension sizes and
    /// bounds; axes of any size ≥ 1 — tiny axes get linear/constant
    /// stencils, see `crate::grid::axis`).
    pub fn with_grids(xs: &Matrix, kernel: &ProductKernel, grids: Vec<Grid1d>) -> Self {
        let d = kernel.dim();
        assert_eq!(xs.cols, d);
        assert_eq!(grids.len(), d);
        let n = xs.rows;
        let mut factors = Vec::with_capacity(d);
        for (k, grid) in grids.iter().enumerate() {
            factors.push(SymToeplitz::new(
                kernel.factors[k].toeplitz_column(grid.m, grid.h),
            ));
        }
        let total_grid = grids
            .iter()
            .try_fold(1usize, |acc, g| acc.checked_mul(g.m))
            .expect("grid size overflows usize — use a sparse spec");
        // Tensor-product interpolation weights via the shared single-point
        // stencil primitive (row-major flat index, dim 0 slowest).
        let dims: Vec<usize> = grids.iter().map(|g| g.m).collect();
        let strides = crate::grid::tensor_strides(&dims);
        let stencil = tensor_stencil_size(&grids);
        let mut idx = Vec::with_capacity(n * stencil);
        let mut w = Vec::with_capacity(n * stencil);
        for i in 0..n {
            tensor_stencil(xs.row(i), &grids, &strides, |flat, weight| {
                idx.push(flat as u32);
                w.push(weight);
            });
        }
        debug_assert_eq!(idx.len(), n * stencil);
        KroneckerSkiOp {
            grids,
            factors,
            idx,
            w,
            n,
            total_grid,
            stencil,
            outputscale: kernel.outputscale,
            gram: OnceLock::new(),
            scratch: Mutex::new(KronScratch::default()),
        }
    }

    /// Build with D-SKI gradient rows (Eriksson et al. 2018): each data
    /// point contributes its value stencil row followed by d gradient
    /// rows (∂W/∂x_k, axis order k = 0..d), so the operator has
    /// `n·(1+d)` rows and `W_ext (⊗K) W_extᵀ` approximates the full
    /// derivative kernel `[[K, ∂K], [∂K, ∂²K]]` in interleaved row order.
    /// Every MVM/Gram/diag path is row-generic, so the extended operator
    /// rides the existing machinery unchanged.
    pub fn with_grids_grad(xs: &Matrix, kernel: &ProductKernel, grids: Vec<Grid1d>) -> Self {
        let mut op = Self::with_grids(xs, kernel, grids);
        let d = op.grids.len();
        let s = op.stencil;
        let n_points = op.n;
        let dims: Vec<usize> = op.grids.iter().map(|g| g.m).collect();
        let strides = crate::grid::tensor_strides(&dims);
        // Re-emit in interleaved order: value row, then d gradient rows.
        let mut idx = Vec::with_capacity(n_points * (1 + d) * s);
        let mut w = Vec::with_capacity(n_points * (1 + d) * s);
        for i in 0..n_points {
            idx.extend_from_slice(&op.idx[i * s..(i + 1) * s]);
            w.extend_from_slice(&op.w[i * s..(i + 1) * s]);
            for axis in 0..d {
                tensor_stencil_grad(xs.row(i), axis, &op.grids, &strides, |flat, weight| {
                    idx.push(flat as u32);
                    w.push(weight);
                });
            }
        }
        op.idx = idx;
        op.w = w;
        op.n = n_points * (1 + d);
        debug_assert_eq!(op.idx.len(), op.n * s);
        op
    }

    /// Append the stencil row(s) of one data point: the value row, then —
    /// when `with_grad` — d gradient rows in axis order (the D-SKI row
    /// layout of [`Self::with_grids_grad`]). Returns the number of rows
    /// appended (1 or 1+d). Like [`Self::append_rows`], an already-built
    /// `WᵀW` Gram is kept current incrementally, so the grown operator is
    /// bitwise identical to a from-scratch build over the same row list.
    pub fn append_point(&mut self, x: &[f64], with_grad: bool) -> usize {
        assert_eq!(x.len(), self.grids.len(), "point must match operator dimensionality");
        let d = self.grids.len();
        let dims: Vec<usize> = self.grids.iter().map(|g| g.m).collect();
        let strides = crate::grid::tensor_strides(&dims);
        let s = self.stencil;
        let rows = if with_grad { 1 + d } else { 1 };
        let old_n = self.n;
        self.idx.reserve(rows * s);
        self.w.reserve(rows * s);
        tensor_stencil(x, &self.grids, &strides, |flat, weight| {
            self.idx.push(flat as u32);
            self.w.push(weight);
        });
        if with_grad {
            for axis in 0..d {
                tensor_stencil_grad(x, axis, &self.grids, &strides, |flat, weight| {
                    self.idx.push(flat as u32);
                    self.w.push(weight);
                });
            }
        }
        self.n += rows;
        debug_assert_eq!(self.idx.len(), self.n * s);
        if let Some(gram) = self.gram.get_mut() {
            let mut scratch = vec![0usize; s * dims.len()];
            for i in old_n..self.n {
                gram.accumulate_row(
                    &self.idx[i * s..(i + 1) * s],
                    &self.w[i * s..(i + 1) * s],
                    &mut scratch,
                );
            }
        }
        rows
    }

    fn stencil_size(&self) -> usize {
        self.stencil
    }

    /// Stencil layout: `(s, idx, w)` — each data row i owns the s
    /// `(flat grid index, weight)` pairs at `idx[i·s..(i+1)·s]` /
    /// `w[i·s..(i+1)·s]`. The raw `W` matrix, for callers that project
    /// data through it themselves (`solvers::gridspace`).
    pub fn stencil_entries(&self) -> (usize, &[u32], &[f64]) {
        (self.stencil, &self.idx, &self.w)
    }

    /// Per-dimension grid sizes (dim 0 slowest, row-major flat indices).
    pub fn grid_dims(&self) -> Vec<usize> {
        self.grids.iter().map(|g| g.m).collect()
    }

    /// Output scale σ_f² baked into [`LinearOp::matvec`].
    pub fn outputscale(&self) -> f64 {
        self.outputscale
    }

    /// The m × m grid-space building blocks for normal-equations solves:
    /// validates the grid axes, then returns the (lazily built, cached)
    /// `WᵀW` stencil Gram. Combined with [`Self::kron_matvec`] this gives
    /// the grid-space operator `B = σ_f²·(WᵀW)·(⊗K) + σ_n²·I` whose
    /// per-iteration cost is independent of n — see `solvers::gridspace`.
    ///
    /// Returns [`Error::Grid`] for degenerate axes (non-positive or
    /// non-finite spacing — a hand-built constant-feature grid) and for
    /// dense high-d grids whose band storage would exceed
    /// ~0.5 GB (`m · Π(2w_k − 1)` entries), where data-space CG is the
    /// right tool anyway.
    pub fn grid_space_op(&self) -> Result<&StencilGram> {
        for (k, g) in self.grids.iter().enumerate() {
            if g.m == 0 || !g.h.is_finite() || g.h <= 0.0 {
                return Err(Error::Grid(format!(
                    "degenerate axis {k} (m={}, h={}): grid-space solves \
                     need positive, finite grid spacings",
                    g.m, g.h
                )));
            }
        }
        let o: usize = self.grids.iter().map(|g| 2 * g.stencil_width() - 1).product();
        let entries = self.total_grid.checked_mul(o);
        if !matches!(entries, Some(e) if e <= MAX_GRAM_ENTRIES) {
            return Err(Error::Grid(format!(
                "WᵀW band for m={} with {o} offsets per point exceeds the \
                 {MAX_GRAM_ENTRIES}-entry budget; solve in data space instead",
                self.total_grid
            )));
        }
        Ok(self
            .gram
            .get_or_init(|| StencilGram::build(&self.grids, &self.idx, &self.w, self.stencil)))
    }

    /// Extend `W` in place with the stencil rows of `xs_new` (k × d new
    /// data rows on the **same, fixed** grid axes). This is the streaming
    /// path's core cheap step (`crate::stream`): ingesting a point only
    /// appends one sparse stencil row — the grid, its Toeplitz factors,
    /// and every existing row are untouched, so the extended operator is
    /// bitwise identical to a from-scratch build over the concatenated
    /// data.
    pub fn append_rows(&mut self, xs_new: &Matrix) {
        assert_eq!(
            xs_new.cols,
            self.grids.len(),
            "appended rows must match the operator dimensionality"
        );
        let dims: Vec<usize> = self.grids.iter().map(|g| g.m).collect();
        let strides = crate::grid::tensor_strides(&dims);
        let s = self.stencil;
        let old_n = self.n;
        self.idx.reserve(xs_new.rows * s);
        self.w.reserve(xs_new.rows * s);
        for i in 0..xs_new.rows {
            tensor_stencil(xs_new.row(i), &self.grids, &strides, |flat, weight| {
                self.idx.push(flat as u32);
                self.w.push(weight);
            });
        }
        self.n += xs_new.rows;
        debug_assert_eq!(self.idx.len(), self.n * s);
        // Keep an already-built WᵀW current: fold in just the new rows —
        // the Gram is a sum of per-row outer products, so this is exactly
        // the from-scratch build over the concatenated data.
        if let Some(gram) = self.gram.get_mut() {
            let mut scratch = vec![0usize; s * dims.len()];
            for i in old_n..self.n {
                gram.accumulate_row(
                    &self.idx[i * s..(i + 1) * s],
                    &self.w[i * s..(i + 1) * s],
                    &mut scratch,
                );
            }
        }
    }

    /// `Wᵀ v` (grid-sized output) — fixed-width scatter over
    /// `chunks_exact` stencil rows (only the scattered store stays
    /// indexed).
    pub fn wt_matvec(&self, v: &[f64]) -> Vec<f64> {
        let s = self.stencil_size();
        let mut out = vec![0.0; self.total_grid];
        let rows = self.idx.chunks_exact(s).zip(self.w.chunks_exact(s));
        for ((idx, w), &x) in rows.zip(v) {
            for (&g, &wk) in idx.iter().zip(w) {
                out[g as usize] += wk * x;
            }
        }
        out
    }

    /// `W u` (data-sized output) — fixed-width gather over `chunks_exact`
    /// stencil rows (same accumulation order as the indexed loop it
    /// replaced).
    pub fn w_matvec(&self, u: &[f64]) -> Vec<f64> {
        let s = self.stencil_size();
        self.idx
            .chunks_exact(s)
            .zip(self.w.chunks_exact(s))
            .map(|(idx, w)| {
                w.iter()
                    .zip(idx)
                    .map(|(&wk, &g)| wk * u[g as usize])
                    .sum::<f64>()
            })
            .collect()
    }

    /// `(T₁ ⊗ ⋯ ⊗ T_d) u` via mode-wise Toeplitz application
    /// (grid-sized in and out, O(M log m)-shaped work). Reuses the
    /// operator's warm [`KronScratch`] when uncontended.
    pub fn kron_matvec(&self, u: &[f64]) -> Vec<f64> {
        let dims: Vec<usize> = self.grids.iter().map(|g| g.m).collect();
        let mut local = KronScratch::default();
        let mut guard = self.scratch.try_lock().ok();
        let ws: &mut KronScratch = match guard.as_deref_mut() {
            Some(b) => b,
            None => &mut local,
        };
        kron_toeplitz_matvec_with(&self.factors, &dims, u, ws)
    }

    /// `(T₁ ⊗ ⋯ ⊗ T_d) u` over f32 operands, through each factor's cached
    /// f32 spectrum — the grid-space mixed-precision inner kernel.
    pub fn kron_matvec_f32(&self, u: &[f32]) -> Vec<f32> {
        let dims: Vec<usize> = self.grids.iter().map(|g| g.m).collect();
        kron_toeplitz_matvec_f32(&self.factors, &dims, u)
    }

    /// Per-solve f32 mirror of the whole data-space operator
    /// (`σ² W (⊗K) Wᵀ` with f32 stencil weights and f32 FFT spectra).
    /// Also reachable through [`LinearOp::as_f32`]; public so benches and
    /// the grid-space solver can build it directly.
    pub fn f32_view(&self) -> KronSkiF32<'_> {
        KronSkiF32 {
            op: self,
            w32: self.w.iter().map(|&x| x as f32).collect(),
            outputscale: self.outputscale as f32,
        }
    }
}

/// Per-solve f32 mirror of [`KroneckerSkiOp`]: converted stencil weights
/// plus the per-factor f32 spectra cached inside each [`SymToeplitz`].
/// Built fresh by [`KroneckerSkiOp::f32_view`] per solve, so
/// [`KroneckerSkiOp::append_rows`] never has a stale mirror to
/// invalidate.
pub struct KronSkiF32<'a> {
    op: &'a KroneckerSkiOp,
    w32: Vec<f32>,
    outputscale: f32,
}

impl KronSkiF32<'_> {
    /// `Wᵀ v` over f32 operands (grid-sized output).
    pub fn wt_matvec_f32(&self, v: &[f32]) -> Vec<f32> {
        let s = self.op.stencil;
        let mut out = vec![0.0f32; self.op.total_grid];
        let rows = self.op.idx.chunks_exact(s).zip(self.w32.chunks_exact(s));
        for ((idx, w), &x) in rows.zip(v) {
            for (&g, &wk) in idx.iter().zip(w) {
                out[g as usize] += wk * x;
            }
        }
        out
    }

    /// `W u` over f32 operands (data-sized output).
    pub fn w_matvec_f32(&self, u: &[f32]) -> Vec<f32> {
        let s = self.op.stencil;
        self.op
            .idx
            .chunks_exact(s)
            .zip(self.w32.chunks_exact(s))
            .map(|(idx, w)| {
                w.iter()
                    .zip(idx)
                    .map(|(&wk, &g)| wk * u[g as usize])
                    .sum::<f32>()
            })
            .collect()
    }
}

impl LinearOpF32 for KronSkiF32<'_> {
    fn dim(&self) -> usize {
        self.op.n
    }

    fn matvec_f32(&self, v: &[f32]) -> Vec<f32> {
        let t = self.wt_matvec_f32(v);
        let t = self.op.kron_matvec_f32(&t);
        let mut out = self.w_matvec_f32(&t);
        for o in out.iter_mut() {
            *o *= self.outputscale;
        }
        out
    }
}

impl LinearOp for KroneckerSkiOp {
    fn dim(&self) -> usize {
        self.n
    }

    fn as_f32(&self) -> Option<Box<dyn LinearOpF32 + '_>> {
        Some(Box::new(self.f32_view()))
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let t = self.wt_matvec(v);
        let t = self.kron_matvec(&t);
        let mut out = self.w_matvec(&t);
        for o in out.iter_mut() {
            *o *= self.outputscale;
        }
        out
    }

    /// Fast path: one scatter pass lifts all t right-hand sides onto the
    /// grid (the stencil indices are decoded once per data row instead
    /// of once per row *per column*), the Kronecker–Toeplitz apply runs
    /// parallel across columns, and one gather pass drops the block back
    /// to data space.
    fn matmat(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.n);
        let t = m.cols;
        let s = self.stencil_size();
        // Wᵀ M — scatter, all t columns per stencil touch.
        let mut grid = Matrix::zeros(self.total_grid, t);
        for i in 0..self.n {
            let src = m.row(i);
            let base = i * s;
            for k in 0..s {
                let w = self.w[base + k];
                let g_row = grid.row_mut(self.idx[base + k] as usize);
                for (g, &x) in g_row.iter_mut().zip(src) {
                    *g += w * x;
                }
            }
        }
        // (T₁ ⊗ ⋯ ⊗ T_d) per column — embarrassingly parallel.
        let cols = par_map_range(t, 2, |j| self.kron_matvec(&grid.col(j)));
        // W · — gather, all t columns per stencil touch.
        let mut out = Matrix::zeros(self.n, t);
        for i in 0..self.n {
            let base = i * s;
            let o_row = out.row_mut(i);
            for k in 0..s {
                let w = self.w[base + k];
                let gi = self.idx[base + k] as usize;
                for (o, col) in o_row.iter_mut().zip(&cols) {
                    *o += w * col[gi];
                }
            }
        }
        for o in out.data.iter_mut() {
            *o *= self.outputscale;
        }
        out
    }

    /// Exact diagonal: `diag_i = σ² w_i (⊗K) w_iᵀ`, contracting each
    /// row's stencil against the Kronecker kernel entry-wise —
    /// `(⊗K)[a,b] = Π_k t_k[|a_k − b_k|]` after decoding the flat grid
    /// indices. O(n·s²·d) with s the stencil width; returns `None` for
    /// stencils wider than 4³ = 64 (dense d ≥ 4 grids), where the
    /// contraction would no longer be "cheap" as the trait promises.
    fn diag(&self) -> Option<Vec<f64>> {
        let s = self.stencil;
        if s > 64 {
            return None;
        }
        let dims: Vec<usize> = self.grids.iter().map(|g| g.m).collect();
        let strides = crate::grid::tensor_strides(&dims);
        let d = dims.len();
        let mut out = Vec::with_capacity(self.n);
        let mut coords = vec![0usize; s * d];
        for i in 0..self.n {
            let base = i * s;
            // Decode this row's stencil indices once.
            for a in 0..s {
                let flat = self.idx[base + a] as usize;
                for k in 0..d {
                    coords[a * d + k] = (flat / strides[k]) % dims[k];
                }
            }
            let mut acc = 0.0;
            for a in 0..s {
                let wa = self.w[base + a];
                let ca = &coords[a * d..(a + 1) * d];
                for b in 0..s {
                    let cb = &coords[b * d..(b + 1) * d];
                    let mut kab = self.w[base + b] * wa;
                    for k in 0..d {
                        kab *= self.factors[k].col[ca[k].abs_diff(cb[k])];
                    }
                    acc += kab;
                }
            }
            out.push(self.outputscale * acc);
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rel_err, Rng};

    fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0))
    }

    #[test]
    fn diag_matches_dense_materialization() {
        // Deliberately anisotropic — different per-axis sizes AND
        // lengthscales — so a flat-index decode that confused the axis
        // order could not cancel out and pass by symmetry.
        let xs = random_points(50, 2, 31);
        let kern = ProductKernel::ard(&[0.8, 0.45], 1.7);
        let grids = vec![
            crate::grid::Grid1d::fit(-1.0, 1.0, 12).unwrap(),
            crate::grid::Grid1d::fit(-1.0, 1.0, 17).unwrap(),
        ];
        let op = KroneckerSkiOp::with_grids(&xs, &kern, grids);
        let want = op.to_dense().diagonal();
        let got = op.diag().unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10, "{g} vs {w}");
        }
    }

    #[test]
    fn matches_exact_kernel_mvm_2d() {
        let xs = random_points(80, 2, 20);
        let kern = ProductKernel::rbf(2, 0.7, 1.3);
        let op = KroneckerSkiOp::new(&xs, &kern, 32).unwrap();
        let exact = kern.gram_sym(&xs);
        let mut rng = Rng::new(21);
        let v = rng.normal_vec(80);
        let err = rel_err(&op.matvec(&v), &exact.matvec(&v));
        assert!(err < 1e-3, "rel err {err}");
    }

    #[test]
    fn matches_exact_kernel_mvm_3d() {
        let xs = random_points(50, 3, 22);
        let kern = ProductKernel::ard(&[0.8, 1.0, 1.2], 0.9);
        let op = KroneckerSkiOp::new(&xs, &kern, 20).unwrap();
        let exact = kern.gram_sym(&xs);
        let mut rng = Rng::new(23);
        let v = rng.normal_vec(50);
        let err = rel_err(&op.matvec(&v), &exact.matvec(&v));
        assert!(err < 5e-3, "rel err {err}");
    }

    #[test]
    fn kron_matvec_matches_dense_kronecker_2d() {
        // Direct check of the mode-wise Kronecker application.
        let xs = random_points(10, 2, 24);
        let kern = ProductKernel::rbf(2, 1.0, 1.0);
        let op = KroneckerSkiOp::new(&xs, &kern, 6).unwrap();
        let (m1, m2) = (op.grids[0].m, op.grids[1].m);
        let t1 = op.factors[0].to_dense();
        let t2 = op.factors[1].to_dense();
        // Dense Kronecker product, dim 0 slowest (row-major flat).
        let big = Matrix::from_fn(m1 * m2, m1 * m2, |a, b| {
            let (i1, i2) = (a / m2, a % m2);
            let (j1, j2) = (b / m2, b % m2);
            t1.get(i1, j1) * t2.get(i2, j2)
        });
        let mut rng = Rng::new(25);
        let v = rng.normal_vec(m1 * m2);
        let got = op.kron_matvec(&v);
        let want = big.matvec(&v);
        assert!(rel_err(&got, &want) < 1e-10);
    }

    #[test]
    fn operator_symmetric() {
        let xs = random_points(30, 2, 26);
        let kern = ProductKernel::rbf(2, 0.5, 2.0);
        let op = KroneckerSkiOp::new(&xs, &kern, 16).unwrap();
        let mut rng = Rng::new(27);
        let u = rng.normal_vec(30);
        let v = rng.normal_vec(30);
        let lhs: f64 = op.matvec(&u).iter().zip(&v).map(|(a, b)| a * b).sum();
        let rhs: f64 = op.matvec(&v).iter().zip(&u).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn append_rows_matches_from_scratch_build_bitwise() {
        let xs_all = random_points(60, 2, 33);
        let kern = ProductKernel::rbf(2, 0.7, 1.3);
        let grids = vec![
            Grid1d::fit(-1.0, 1.0, 14).unwrap(),
            Grid1d::fit(-1.0, 1.0, 11).unwrap(),
        ];
        // Build on the first 45 rows, then append the remaining 15 in two
        // uneven chunks.
        let head = Matrix::from_fn(45, 2, |i, j| xs_all.get(i, j));
        let mid = Matrix::from_fn(9, 2, |i, j| xs_all.get(45 + i, j));
        let tail = Matrix::from_fn(6, 2, |i, j| xs_all.get(54 + i, j));
        let mut grown = KroneckerSkiOp::with_grids(&head, &kern, grids.clone());
        grown.append_rows(&mid);
        grown.append_rows(&tail);
        let scratch = KroneckerSkiOp::with_grids(&xs_all, &kern, grids);
        assert_eq!(grown.dim(), 60);
        let mut rng = Rng::new(34);
        let v = rng.normal_vec(60);
        // Same stencils in the same order ⇒ bitwise-identical MVMs.
        assert_eq!(grown.matvec(&v), scratch.matvec(&v));
        assert_eq!(grown.diag().unwrap(), scratch.diag().unwrap());
    }

    #[test]
    fn scratch_reuse_is_bitwise_identical_to_fresh_buffers() {
        let xs = random_points(30, 3, 61);
        let kern = ProductKernel::ard(&[0.8, 1.0, 1.2], 0.9);
        let op = KroneckerSkiOp::new(&xs, &kern, 10).unwrap();
        let dims = op.grid_dims();
        let mut rng = Rng::new(62);
        let u = rng.normal_vec(op.total_grid);
        let fresh = kron_toeplitz_matvec(&op.factors, &dims, &u);
        let mut ws = KronScratch::default();
        // Warm the workspace, then re-apply: identical mode sweep, so
        // bitwise-identical output, and repeated applies stay identical.
        let first = kron_toeplitz_matvec_with(&op.factors, &dims, &u, &mut ws);
        let second = kron_toeplitz_matvec_with(&op.factors, &dims, &u, &mut ws);
        assert_eq!(fresh, first);
        assert_eq!(fresh, second);
        assert_eq!(fresh, op.kron_matvec(&u));
    }

    #[test]
    fn f32_view_tracks_f64_operator() {
        let xs = random_points(60, 2, 63);
        let kern = ProductKernel::rbf(2, 0.7, 1.3);
        let op = KroneckerSkiOp::new(&xs, &kern, 24).unwrap();
        let mut rng = Rng::new(64);
        let v = rng.normal_vec(60);
        let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        let want = op.matvec(&v);
        let view = op.f32_view();
        let got = view.matvec_f32(&v32);
        let scale = want.iter().fold(1.0f64, |a, &x| a.max(x.abs()));
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (*g as f64 - w).abs() < 1e-4 * scale,
                "f32 view drifted: {g} vs {w}"
            );
        }
    }

    #[test]
    fn gram_f32_view_tracks_f64_band() {
        let xs = random_points(40, 2, 65);
        let kern = ProductKernel::ard(&[0.8, 0.5], 1.1);
        let grids = vec![
            Grid1d::fit(-1.0, 1.0, 9).unwrap(),
            Grid1d::fit(-1.0, 1.0, 7).unwrap(),
        ];
        let op = KroneckerSkiOp::with_grids(&xs, &kern, grids);
        let gram = op.grid_space_op().unwrap();
        let view = gram.f32_view();
        assert_eq!(view.dim(), gram.dim());
        let mut rng = Rng::new(66);
        let u = rng.normal_vec(op.total_grid);
        let u32: Vec<f32> = u.iter().map(|&x| x as f32).collect();
        let want = gram.apply(&u);
        let got = view.apply_f32(&u32);
        let scale = want.iter().fold(1.0f64, |a, &x| a.max(x.abs()));
        for (g, w) in got.iter().zip(&want) {
            assert!((*g as f64 - w).abs() < 1e-4 * scale, "{g} vs {w}");
        }
    }

    /// Dense `WᵀW` oracle from the operator's own stencil rows.
    fn dense_gram(op: &KroneckerSkiOp, n: usize) -> Matrix {
        let (s, idx, w) = op.stencil_entries();
        let total = op.total_grid;
        let mut wd = Matrix::zeros(n, total);
        for i in 0..n {
            for k in 0..s {
                let g = idx[i * s + k] as usize;
                wd.set(i, g, wd.get(i, g) + w[i * s + k]);
            }
        }
        wd.transpose().matmul(&wd)
    }

    #[test]
    fn stencil_gram_matches_dense_wtw() {
        // Anisotropic axis sizes so a stride/axis mix-up cannot cancel.
        let xs = random_points(40, 2, 41);
        let kern = ProductKernel::ard(&[0.8, 0.5], 1.1);
        let grids = vec![
            Grid1d::fit(-1.0, 1.0, 9).unwrap(),
            Grid1d::fit(-1.0, 1.0, 7).unwrap(),
        ];
        let op = KroneckerSkiOp::with_grids(&xs, &kern, grids);
        let gram = op.grid_space_op().unwrap();
        assert_eq!(gram.dim(), op.total_grid);
        let dense = dense_gram(&op, 40);
        // Elementwise via unit vectors: column g of G.
        for g in 0..op.total_grid {
            let mut e = vec![0.0; op.total_grid];
            e[g] = 1.0;
            let col = gram.apply(&e);
            for r in 0..op.total_grid {
                let want = dense.get(r, g);
                assert!(
                    (col[r] - want).abs() < 1e-12,
                    "G[{r},{g}] = {} want {want}",
                    col[r]
                );
            }
        }
    }

    #[test]
    fn stencil_gram_incremental_append_matches_scratch() {
        let xs_all = random_points(50, 2, 42);
        let kern = ProductKernel::rbf(2, 0.7, 1.3);
        let grids = vec![
            Grid1d::fit(-1.0, 1.0, 10).unwrap(),
            Grid1d::fit(-1.0, 1.0, 8).unwrap(),
        ];
        let head = Matrix::from_fn(35, 2, |i, j| xs_all.get(i, j));
        let tail = Matrix::from_fn(15, 2, |i, j| xs_all.get(35 + i, j));
        let mut grown = KroneckerSkiOp::with_grids(&head, &kern, grids.clone());
        grown.grid_space_op().unwrap(); // force the build, then grow it
        grown.append_rows(&tail);
        let scratch = KroneckerSkiOp::with_grids(&xs_all, &kern, grids);
        let ga = grown.grid_space_op().unwrap();
        let gb = scratch.grid_space_op().unwrap();
        let mut rng = Rng::new(43);
        let v = rng.normal_vec(grown.total_grid);
        let a = ga.apply(&v);
        let b = gb.apply(&v);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn stencil_gram_tiny_axes_and_degenerate_guard() {
        // Mixed cubic × constant × linear axes flow through the banded
        // Gram too (sparse-grid term shape).
        let xs = random_points(20, 3, 44);
        let kern = ProductKernel::rbf(3, 0.8, 1.0);
        let grids = vec![
            Grid1d::fit(-1.0, 1.0, 12).unwrap(),
            Grid1d::fit_any(-1.0, 1.0, 1).unwrap(),
            Grid1d::fit_any(-1.0, 1.0, 3).unwrap(),
        ];
        let op = KroneckerSkiOp::with_grids(&xs, &kern, grids);
        let gram = op.grid_space_op().unwrap();
        let dense = dense_gram(&op, 20);
        let mut rng = Rng::new(45);
        let v = rng.normal_vec(op.total_grid);
        let got = gram.apply(&v);
        let want = dense.matvec(&v);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }

        // A hand-built degenerate axis (h = 0) is a typed grid error.
        let xs1 = random_points(10, 1, 46);
        let k1 = ProductKernel::rbf(1, 0.8, 1.0);
        let mut bad = KroneckerSkiOp::with_grids(
            &xs1,
            &k1,
            vec![Grid1d::fit(-1.0, 1.0, 8).unwrap()],
        );
        bad.grids[0].h = 0.0;
        let err = bad.grid_space_op().unwrap_err();
        assert!(matches!(err, Error::Grid(_)), "{err}");
    }

    #[test]
    fn grad_op_matches_dense_extended_oracle() {
        // W_ext (⊗K) W_extᵀ with interleaved value/gradient rows must
        // equal the dense oracle assembled from the same stencils.
        let xs = random_points(18, 2, 71);
        let kern = ProductKernel::ard(&[0.8, 0.5], 1.4);
        let grids = vec![
            Grid1d::fit(-1.0, 1.0, 11).unwrap(),
            Grid1d::fit(-1.0, 1.0, 9).unwrap(),
        ];
        let op = KroneckerSkiOp::with_grids_grad(&xs, &kern, grids.clone());
        let rows = 18 * 3;
        assert_eq!(op.dim(), rows);
        let dims: Vec<usize> = grids.iter().map(|g| g.m).collect();
        let strides = crate::grid::tensor_strides(&dims);
        let total = op.total_grid;
        let mut wd = Matrix::zeros(rows, total);
        for i in 0..18 {
            tensor_stencil(xs.row(i), &grids, &strides, |g, wt| {
                let r = 3 * i;
                wd.set(r, g, wd.get(r, g) + wt);
            });
            for axis in 0..2 {
                crate::grid::tensor_stencil_grad(xs.row(i), axis, &grids, &strides, |g, wt| {
                    let r = 3 * i + 1 + axis;
                    wd.set(r, g, wd.get(r, g) + wt);
                });
            }
        }
        let kron = Matrix::from_fn(total, total, |a, b| {
            let (a1, a2) = (a / 9, a % 9);
            let (b1, b2) = (b / 9, b % 9);
            op.factors[0].to_dense().get(a1, b1) * op.factors[1].to_dense().get(a2, b2)
        });
        let dense = wd.matmul(&kron).matmul_t(&wd);
        let mut rng = Rng::new(72);
        let v = rng.normal_vec(rows);
        let got = op.matvec(&v);
        let mut want = dense.matvec(&v);
        for x in want.iter_mut() {
            *x *= kern.outputscale;
        }
        assert!(rel_err(&got, &want) < 1e-10, "{}", rel_err(&got, &want));
        // diag agrees too (row-generic contraction).
        let dg = op.diag().unwrap();
        for (i, g) in dg.iter().enumerate() {
            let w = kern.outputscale * dense.get(i, i);
            assert!((g - w).abs() < 1e-10, "diag[{i}]: {g} vs {w}");
        }
    }

    #[test]
    fn append_point_matches_from_scratch_grad_build() {
        let xs_all = random_points(20, 2, 73);
        let kern = ProductKernel::rbf(2, 0.7, 1.3);
        let grids = vec![
            Grid1d::fit(-1.0, 1.0, 12).unwrap(),
            Grid1d::fit(-1.0, 1.0, 10).unwrap(),
        ];
        let head = Matrix::from_fn(16, 2, |i, j| xs_all.get(i, j));
        let mut grown = KroneckerSkiOp::with_grids_grad(&head, &kern, grids.clone());
        grown.grid_space_op().unwrap(); // force Gram build, then grow it
        for i in 16..20 {
            assert_eq!(grown.append_point(xs_all.row(i), true), 3);
        }
        let scratch = KroneckerSkiOp::with_grids_grad(&xs_all, &kern, grids.clone());
        assert_eq!(grown.dim(), scratch.dim());
        let mut rng = Rng::new(74);
        let v = rng.normal_vec(grown.dim());
        assert_eq!(grown.matvec(&v), scratch.matvec(&v));
        let u = rng.normal_vec(grown.total_grid);
        let ga = grown.grid_space_op().unwrap().apply(&u);
        let gb = scratch.grid_space_op().unwrap().apply(&u);
        for (x, y) in ga.iter().zip(&gb) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
        // Value-only appends reduce to the legacy append_rows path.
        let mut plain = KroneckerSkiOp::with_grids(&head, &kern, grids.clone());
        for i in 16..20 {
            assert_eq!(plain.append_point(xs_all.row(i), false), 1);
        }
        let plain_scratch = KroneckerSkiOp::with_grids(&xs_all, &kern, grids);
        let v = rng.normal_vec(20);
        assert_eq!(plain.matvec(&v), plain_scratch.matvec(&v));
    }

    #[test]
    fn anisotropic_grids_with_tiny_axes() {
        // A sparse-grid-style term: cubic × constant × linear axes. The
        // operator must stay symmetric and match the dense
        // W (T₁⊗T₂⊗T₃) Wᵀ oracle built from the same stencils.
        let xs = random_points(25, 3, 28);
        let kern = ProductKernel::rbf(3, 0.8, 1.0);
        let grids = vec![
            Grid1d::fit(-1.0, 1.0, 12).unwrap(),
            Grid1d::fit_any(-1.0, 1.0, 1).unwrap(),
            Grid1d::fit_any(-1.0, 1.0, 3).unwrap(),
        ];
        let op = KroneckerSkiOp::with_grids(&xs, &kern, grids.clone());
        assert_eq!(op.total_grid, 12 * 3);
        // Dense oracle.
        let dims: Vec<usize> = grids.iter().map(|g| g.m).collect();
        let strides = crate::grid::tensor_strides(&dims);
        let total = op.total_grid;
        let mut wd = Matrix::zeros(25, total);
        for i in 0..25 {
            tensor_stencil(xs.row(i), &grids, &strides, |g, wt| {
                wd.set(i, g, wd.get(i, g) + wt);
            });
        }
        let kron = Matrix::from_fn(total, total, |a, b| {
            let (a1, ar) = (a / 3, a % 3);
            let (b1, br) = (b / 3, b % 3);
            op.factors[0].to_dense().get(a1, b1)
                * op.factors[2].to_dense().get(ar, br)
        });
        let dense = wd.matmul(&kron).matmul_t(&wd);
        let mut rng = Rng::new(29);
        let v = rng.normal_vec(25);
        let got = op.matvec(&v);
        let want = dense.matvec(&v);
        assert!(rel_err(&got, &want) < 1e-10, "{}", rel_err(&got, &want));
        // Symmetry.
        let u = rng.normal_vec(25);
        let lhs: f64 = got.iter().zip(&u).map(|(a, b)| a * b).sum();
        let rhs: f64 = op.matvec(&u).iter().zip(&v).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9);
    }
}
