//! Multi-task coregionalization operator `V (B Bᵀ + D) Vᵀ` (paper §6).
//!
//! `V` is the n×s one-hot task-membership matrix (row i has a single 1 in
//! the column of observation i's task), so MVMs cost O(n + s·q): gather,
//! multiply by the small s×q factor, scatter. The paper's footnote 2.

use super::kronecker::KroneckerSkiOp;
use super::lowrank::LanczosFactor;
use super::LinearOp;
use crate::kernels::TaskKernel;
use crate::linalg::Matrix;

/// `V M Vᵀ` with `M = B Bᵀ + diag` the s×s task covariance.
pub struct TaskOp {
    /// Task index of each observation (values in [0, s)).
    pub task_of: Vec<usize>,
    /// The task kernel (B and per-task diagonal).
    pub kernel: TaskKernel,
}

impl TaskOp {
    pub fn new(task_of: Vec<usize>, kernel: TaskKernel) -> Self {
        let s = kernel.num_tasks();
        assert!(task_of.iter().all(|&t| t < s), "task index out of range");
        TaskOp { task_of, kernel }
    }

    /// Exact factorization for SKIP: `V B Bᵀ Vᵀ = (VB)(VB)ᵀ`, i.e.
    /// Q = VB (n×q, rows gathered from B), T = I — plus the diagonal term
    /// folded in by augmenting Q with per-task indicator columns scaled by
    /// √diag. Lemma 3.1 never needs Q orthonormal, so this is exact.
    pub fn factor(&self) -> LanczosFactor {
        let n = self.task_of.len();
        let s = self.kernel.num_tasks();
        let q_rank = self.kernel.b.cols;
        // Columns: q columns of VB, then s columns of √diag indicators.
        let total = q_rank + s;
        let mut q = Matrix::zeros(n, total);
        for (i, &t) in self.task_of.iter().enumerate() {
            for k in 0..q_rank {
                q.set(i, k, self.kernel.b.get(t, k));
            }
            q.set(i, q_rank + t, self.kernel.diag[t].max(0.0).sqrt());
        }
        LanczosFactor { q, t: Matrix::eye(total) }
    }
}

impl LinearOp for TaskOp {
    fn dim(&self) -> usize {
        self.task_of.len()
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let s = self.kernel.num_tasks();
        let q = self.kernel.b.cols;
        // u = Vᵀ v  (s): scatter-sum per task. O(n)
        let mut u = vec![0.0; s];
        for (i, &t) in self.task_of.iter().enumerate() {
            u[t] += v[i];
        }
        // w = (B Bᵀ + D) u. O(sq)
        let bt_u = self.kernel.b.t_matvec(&u); // q
        let mut w = self.kernel.b.matvec(&bt_u); // s
        for t in 0..s {
            w[t] += self.kernel.diag[t] * u[t];
        }
        let _ = q;
        // out = V w: gather. O(n)
        self.task_of.iter().map(|&t| w[t]).collect()
    }

    /// Fast path: scatter/gather move whole rows of the block (contiguous
    /// length-t slices), and the small task-space product becomes two
    /// s×q-by-s×t gemms — O(n·t + s·q·t) for the entire block, one pass
    /// over the task indices instead of t.
    fn matmat(&self, m: &Matrix) -> Matrix {
        let n = self.task_of.len();
        assert_eq!(m.rows, n);
        let t = m.cols;
        let s = self.kernel.num_tasks();
        // U = Vᵀ M  (s×t): row scatter-sum per task.
        let mut u = Matrix::zeros(s, t);
        for (i, &task) in self.task_of.iter().enumerate() {
            let src = m.row(i);
            let dst = u.row_mut(task);
            for (d, &x) in dst.iter_mut().zip(src) {
                *d += x;
            }
        }
        // W = (B Bᵀ + D) U  (s×t).
        let bt_u = self.kernel.b.t_matmul(&u); // q×t
        let mut w = self.kernel.b.matmul(&bt_u); // s×t
        for (task, wrow) in w.data.chunks_mut(t.max(1)).enumerate().take(s) {
            let d = self.kernel.diag[task];
            for (wv, &uv) in wrow.iter_mut().zip(u.row(task)) {
                *wv += d * uv;
            }
        }
        // out = V W: row gather.
        let mut out = Matrix::zeros(n, t);
        for (i, &task) in self.task_of.iter().enumerate() {
            out.row_mut(i).copy_from_slice(w.row(task));
        }
        out
    }

    /// Exact diagonal in O(s·q + n): `diag_i = ‖b_{tᵢ}‖² + d_{tᵢ}`
    /// depends only on observation i's task.
    fn diag(&self) -> Option<Vec<f64>> {
        let s = self.kernel.num_tasks();
        let per_task: Vec<f64> = (0..s)
            .map(|task| {
                let row = self.kernel.b.row(task);
                row.iter().map(|v| v * v).sum::<f64>() + self.kernel.diag[task]
            })
            .collect();
        Some(self.task_of.iter().map(|&t| per_task[t]).collect())
    }
}

/// Borrowed multi-task SKI covariance `(W(⊗K)Wᵀ) ∘ (V M Vᵀ)` — the
/// normal-equations operator the streaming layer solves against for
/// `TaskOp`-backed models (paper §6 composed with KISS-GP).
///
/// The task factor is exact low-rank plus diagonal: with the columns
/// `q_k` of [`TaskOp::factor`]'s Q (q columns of VB, then s scaled
/// indicator columns), `V M Vᵀ = Q Qᵀ` exactly, so the Hadamard identity
/// behind Lemma 3.1 applies with no Lanczos truncation:
///
/// ```text
/// (A ∘ Q Qᵀ) v  =  Σ_k diag(q_k) · A · diag(q_k) · v
/// ```
///
/// One [`KroneckerSkiOp::matmat`] over the n×(q+s) block of masked
/// right-hand sides carries all k terms through the grid at once, so an
/// MVM costs (q+s) SKI columns — O((q+s)·(n + m log m)) — and the whole
/// operator composes with `AffineRef` (σ_f² scale + σ_n² shift), CG /
/// block-CG, preconditioners, and warm starts exactly like the
/// single-task covariance. There is no f32 mirror yet, so
/// `--precision mixed` takes the metered f64 fallback, and the operator
/// has no grid-space normal form (`--space grid` falls back to data
/// space, metered under `solver.space.fallback`).
///
/// Borrowed by design: the streaming layer keeps owning and growing the
/// SKI operator (`append_rows`) and the task kernel (`enroll`) between
/// solves; a fresh view is built per solve, like
/// [`super::AffineRef`].
pub struct TaskHadamardRef<'a> {
    ski: &'a KroneckerSkiOp,
    /// Exact factor columns of `V M Vᵀ` (n×(q+s); see [`TaskOp::factor`]).
    q: Matrix,
    /// Per-row task self-covariance `k_task(tᵢ, tᵢ)` for [`LinearOp::diag`].
    task_var: Vec<f64>,
}

impl<'a> TaskHadamardRef<'a> {
    pub fn new(ski: &'a KroneckerSkiOp, task_of: &[usize], kernel: &TaskKernel) -> Self {
        let n = ski.dim();
        assert_eq!(task_of.len(), n, "task assignments must cover every row");
        let s = kernel.num_tasks();
        assert!(task_of.iter().all(|&t| t < s), "task index out of range");
        let q_rank = kernel.b.cols;
        let mut q = Matrix::zeros(n, q_rank + s);
        let mut task_var = Vec::with_capacity(n);
        for (i, &t) in task_of.iter().enumerate() {
            for k in 0..q_rank {
                q.set(i, k, kernel.b.get(t, k));
            }
            q.set(i, q_rank + t, kernel.diag[t].max(0.0).sqrt());
            task_var.push(kernel.eval(t, t));
        }
        TaskHadamardRef { ski, q, task_var }
    }
}

impl LinearOp for TaskHadamardRef<'_> {
    fn dim(&self) -> usize {
        self.q.rows
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let n = self.q.rows;
        assert_eq!(v.len(), n);
        let k = self.q.cols;
        // U[:,k] = q_k ∘ v — all masked RHS in one block.
        let mut u = Matrix::zeros(n, k);
        for i in 0..n {
            let qi = self.q.row(i);
            let urow = u.row_mut(i);
            for (uv, &qv) in urow.iter_mut().zip(qi) {
                *uv = qv * v[i];
            }
        }
        // One batched pass through the grid for every Hadamard term.
        let y = self.ski.matmat(&u);
        // out_i = Σ_k q_k[i] · Y[i,k] — a row dot against the factor.
        (0..n)
            .map(|i| {
                self.q
                    .row(i)
                    .iter()
                    .zip(y.row(i))
                    .map(|(&a, &b)| a * b)
                    .sum::<f64>()
            })
            .collect()
    }

    /// Exact diagonal when the SKI diagonal is available:
    /// `diag_i = [W(⊗K)Wᵀ]_{ii} · k_task(tᵢ, tᵢ)` (the Hadamard product's
    /// diagonal is the elementwise product of the diagonals).
    fn diag(&self) -> Option<Vec<f64>> {
        let ski_diag = self.ski.diag()?;
        Some(
            ski_diag
                .iter()
                .zip(&self.task_var)
                .map(|(&a, &t)| a * t)
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rel_err, Rng};

    fn setup(n: usize, s: usize, q: usize, seed: u64) -> (TaskOp, Matrix) {
        let mut rng = Rng::new(seed);
        let task_of: Vec<usize> = (0..n).map(|_| rng.below(s)).collect();
        let b = Matrix::from_fn(s, q, |_, _| rng.normal() * 0.5);
        let diag: Vec<f64> = (0..s).map(|_| rng.uniform_in(0.1, 0.5)).collect();
        let kern = TaskKernel::new(b, diag);
        // Dense oracle: K[i,j] = k_task(task_i, task_j).
        let dense = Matrix::from_fn(n, n, |i, j| kern.eval(task_of[i], task_of[j]));
        (TaskOp::new(task_of, kern), dense)
    }

    #[test]
    fn matvec_matches_dense() {
        let (op, dense) = setup(50, 7, 2, 1);
        let mut rng = Rng::new(2);
        let v = rng.normal_vec(50);
        assert!(rel_err(&op.matvec(&v), &dense.matvec(&v)) < 1e-12);
    }

    // `diag_matches_dense` (TaskOp::diag pinned against the dense oracle)
    // lives in rust/tests/mtgp_props.rs with the other promoted
    // multi-task property tests.

    #[test]
    fn factor_is_exact() {
        let (op, dense) = setup(40, 5, 3, 3);
        let f = op.factor();
        let mut rng = Rng::new(4);
        let v = rng.normal_vec(40);
        assert!(rel_err(&f.matvec(&v), &dense.matvec(&v)) < 1e-12);
        // Dense reconstruction too.
        assert!(f.to_dense().max_abs_diff(&dense) < 1e-12);
    }

    #[test]
    fn single_task_is_constant_block() {
        let kern = TaskKernel::new(Matrix::from_vec(1, 1, vec![2.0]), vec![0.0]);
        let op = TaskOp::new(vec![0; 10], kern);
        let v = vec![1.0; 10];
        // K = 4·11ᵀ → Kv = 40·1
        for o in op.matvec(&v) {
            assert!((o - 40.0).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "task index out of range")]
    fn rejects_bad_task_index() {
        let kern = TaskKernel::independent(2);
        TaskOp::new(vec![0, 1, 2], kern);
    }

    #[test]
    fn hadamard_matches_dense_oracle() {
        use crate::grid::Grid1d;
        use crate::kernels::ProductKernel;
        use crate::operators::KroneckerSkiOp;

        let n = 40;
        let s = 3;
        let mut rng = Rng::new(11);
        let xs = Matrix::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let axes = vec![
            Grid1d::fit(-1.0, 1.0, 10).unwrap(),
            Grid1d::fit(-1.0, 1.0, 10).unwrap(),
        ];
        let ski = KroneckerSkiOp::with_grids(&xs, &ProductKernel::rbf(2, 0.7, 1.0), axes);
        let task_of: Vec<usize> = (0..n).map(|_| rng.below(s)).collect();
        let b = Matrix::from_fn(s, 2, |_, _| rng.normal() * 0.5);
        let diag: Vec<f64> = (0..s).map(|_| rng.uniform_in(0.1, 0.5)).collect();
        let kern = TaskKernel::new(b, diag);

        let op = TaskHadamardRef::new(&ski, &task_of, &kern);
        let ski_dense = ski.to_dense();
        let dense = Matrix::from_fn(n, n, |i, j| {
            ski_dense.get(i, j) * kern.eval(task_of[i], task_of[j])
        });

        let v = rng.normal_vec(n);
        assert!(rel_err(&op.matvec(&v), &dense.matvec(&v)) < 1e-10);

        // The exact diagonal composes elementwise.
        let got = op.diag().expect("2-D cubic stencil keeps diag available");
        for (i, g) in got.iter().enumerate() {
            assert!((g - dense.get(i, i)).abs() < 1e-10);
        }
    }
}
