//! Versioned model snapshots: a trained GP frozen into its predictive
//! caches, serialized to a zero-dependency binary format.
//!
//! A snapshot is everything prediction needs and nothing more: the
//! hyperparameters, the inducing-grid spec with its fitted per-term axes,
//! the cached solve `α = K̂⁻¹y`, the grid-side mean cache(s), and the
//! low-rank variance factor(s) `R` (see [`super::cache`]). The training
//! inputs are **not** stored — reload and serve without touching training
//! data.
//!
//! # Format (version 6)
//!
//! Little-endian throughout:
//!
//! ```text
//! magic      8 bytes  "SKGPSNAP"
//! version    u32      format version (this file documents versions 1–6)
//! d          u32      input dimensionality
//! n          u32      training-set size (length of α)
//! r          u32      variance-cache rank (0 ⇒ mean-only snapshot)
//! variant    u32      provenance tag: 0 SKIP, 1 KISS, 2 exact
//! train_rank u32      Lanczos rank used during training (provenance)
//! refresh_rank u32    Lanczos rank of the final predictive solve
//! alpha_space u32     provenance: which engine solved α — 0 data-space
//!                     CG/PCG, 1 grid-space normal equations
//!                     (`crate::solvers::gridspace`, α back-projected)
//! hypers     3 × f64  log ℓ, log σ_f², log σ_n²
//! spec_kind  u32      0 uniform, 1 rectilinear, 2 sparse
//!   uniform:      u32 m
//!   rectilinear:  d × u32 sizes
//!   sparse:       u32 level
//! n_terms    u32      grid terms (1 for dense grids)
//! terms      n_terms × [f64 coeff, d × (f64 min, f64 h, u32 m)]
//! alpha      n × f64
//! means      per term, M_t × f64 with M_t = Π m_k of that term
//! var_rs     per term, (M_t·r) × f64, row-major M_t × r
//! pending    u32 count, count × [u64 seq, u32 task, d × f64 x, f64 y,
//!              u32 grad flag (0 or 1); if 1: d × f64 ∇y]
//! tasks      u32 flag: 0 single-task, 1 multi-task; if 1:
//!              u32 s, u32 q
//!              B       (s·q) × f64, row-major s × q
//!              diag    s × f64
//!              task_of n × u32 (task of every training row, < s)
//!              heads   (s−1) × [f64 prior_var,
//!                               per term: M_t × f64 mean,
//!                                         (M_t·r) × f64 var_r]
//! checksum   u64      FNV-1a over every preceding byte
//! ```
//!
//! The `pending` section (new in v3) persists the streaming layer's
//! observation log ([`crate::stream::ObservationLog`]): the points a
//! *live* model ingested since its last full refresh, in chronological
//! sequence order. Frozen snapshots (the `skip-gp snapshot` path) write
//! an empty section. Note the checkpoint's `α` and caches **already
//! include** these points — the log is carried so the streamed
//! observations survive the checkpoint as data: to reconstruct a live
//! model, rebuild the base [`crate::stream::IncrementalState`] from the
//! original training set (which does *not* contain them) and replay the
//! pending section into it
//! ([`crate::stream::IncrementalState::ingest_observations`]). Replaying
//! it on top of the checkpoint itself would double-count.
//!
//! The `tasks` section (new in v5, with the per-entry `task` id in
//! `pending`) persists a multi-task model's head ([`TaskHead`]): the
//! coregionalization kernel `B Bᵀ + D` (paper §6), each training row's
//! task assignment, and one serving cache per task — task 0's cache *is*
//! the base `means`/`var_rs` payload, so only tasks 1..s store extra
//! grid buffers, and they share the base cache's spec, term axes,
//! coefficients, and variance rank (per-head payloads carry only what
//! differs: the prior variance `σ_f²·k_task(t,t)` and the masked
//! mean/variance buffers). Single-task snapshots write flag 0 and their
//! pending entries carry task 0, keeping the format overhead at 4 bytes.
//!
//! # Version 5 (read-only, migrated on load)
//!
//! Version 5 is version 6 without the pending-entry gradient payload:
//! each entry's `y` is followed directly by the next entry (no grad
//! flag). Loading a v5 file migrates every pending entry to `grad =
//! None` — exactly right, because derivative observations (D-SKI) could
//! not be persisted before v6. Every other field decodes identically.
//!
//! # Version 4 (read-only, migrated on load)
//!
//! Version 4 is version 5 without the multi-task payload: pending
//! entries have no `task` field (`seq` is followed directly by `x`) and
//! there is no `tasks` section (`pending` is followed directly by the
//! checksum). Loading a v4 file migrates it to task-0 pending entries
//! and no task head — exactly right, because multi-task models could
//! not be persisted before v5.
//!
//! # Version 3 (read-only, migrated on load)
//!
//! Version 3 is version 4 without the `alpha_space` field:
//! `refresh_rank` is followed directly by `hypers`. Loading a v3 file
//! migrates it with `alpha_space = 0` (data-space), which is exactly
//! right — grid-space solves did not exist when v3 files were written.
//! Every other field decodes identically.
//!
//! # Version 2 (read-only, migrated on load)
//!
//! Version 2 is version 3 without the `pending` section: `var_rs` is
//! followed directly by the checksum. Loading a v2 file migrates it to
//! an empty pending log — predictions are bitwise identical (pinned by
//! the checked-in `rust/tests/fixtures/snapshot_v2.bin` fixture test,
//! the same pin the v1→v2 migration carries).
//!
//! # Version 1 (read-only, migrated on load)
//!
//! Version 1 had no grid spec and exactly one implicit term: after
//! `hypers` it stored `d × (f64 min, f64 h, u32 m)` grids followed by
//! `alpha`, one `mean`, one `var_r`, and the checksum. Loading a v1 file
//! migrates it in memory to a single-term cache with coefficient 1 and a
//! rectilinear spec derived from the stored axis sizes — predictions are
//! bitwise identical to what the v1 reader produced (pinned by the
//! checked-in `rust/tests/fixtures/snapshot_v1.bin` fixture test).
//!
//! # Versioning rules
//!
//! - The version is a single monotonically increasing `u32`. Readers
//!   accept **exactly** the versions they know; an unknown version is a
//!   hard error (`Error::Snapshot`), never a best-effort parse.
//! - Any layout change — field added, removed, reordered, or re-typed —
//!   bumps the version. There are no optional/variable fields within a
//!   version (counts are always explicit).
//! - Writers always emit the newest version. Old snapshots are migrated
//!   on load (in memory) and persist as the newest version on the next
//!   save; files are never rewritten in place.
//! - The trailing checksum covers the full payload; readers verify it
//!   before trusting any field. Corrupt files fail loudly.

use super::cache::{
    build_grad_cache, inverse_root_exact, inverse_root_lanczos, PredictCache,
    TermCache, VarianceMode,
};
use crate::gp::{ExactGp, GpHypers, MvmGp, MvmVariant};
use crate::grid::{build_grid, Grid1d, GridSpec, InducingGrid, RectilinearGrid};
use crate::kernels::{ProductKernel, TaskKernel};
use crate::linalg::{Cholesky, Matrix};
use crate::operators::AffineOp;
use crate::solvers::{build_preconditioner, cg_solve_with, CgConfig, SolverPolicy};
use crate::stream::Observation;
use crate::{Error, Result};
use std::fs;
use std::io::Write;
use std::path::Path;

/// File magic.
pub const SNAPSHOT_MAGIC: &[u8; 8] = b"SKGPSNAP";
/// Current (newest) format version; see the module docs for the rules.
pub const SNAPSHOT_VERSION: u32 = 6;
/// Oldest format version this build still reads (migrating on load).
pub const SNAPSHOT_MIN_VERSION: u32 = 1;

/// Default cap on stored cache cells — the mean caches' Σ_t M_t plus the
/// variance factors' Σ_t M_t·r, i.e. M·(1+r) ≤ this; beyond it the
/// snapshot builder refuses (or, for the grid-reuse default, shrinks the
/// serving grid) rather than silently allocating gigabytes.
/// 2²² cells = 32 MB.
pub const DEFAULT_MAX_GRID_CELLS: usize = 1 << 22;

/// Sanity cap on the persisted pending-log length: far above any real
/// ring (the streaming default is 1024) but small enough that a corrupt
/// count field cannot drive a huge allocation.
pub const MAX_PENDING_OBSERVATIONS: usize = 1 << 20;

/// Sanity cap on the persisted task count (and task-kernel rank): far
/// above any real fleet (the nightly scale lane runs T = 1024) but small
/// enough that a corrupt count field cannot drive a huge allocation.
pub const MAX_TASKS: usize = 1 << 16;

/// Variance rank a [`VarianceMode`] will produce for an n-point model.
fn variance_rank(mode: &VarianceMode, n: usize) -> usize {
    match mode {
        VarianceMode::None => 0,
        VarianceMode::Exact => n,
        VarianceMode::Lanczos(r) => (*r).min(n),
    }
}

/// Resolve the serving-grid spec for a d-dimensional, n-point model: an
/// explicit `cfg.grid` is validated as-is, while the grid-reuse default
/// (`cfg.grid == None`) starts from the model's own spec and shrinks it
/// until the stored cells M·(1+r) fit `cfg.max_grid_cells` (a coarser
/// serving grid only costs a little interpolation accuracy).
fn resolve_serving_spec(
    cfg: &SnapshotConfig,
    d: usize,
    n: usize,
    model_spec: &GridSpec,
) -> Result<GridSpec> {
    let r = variance_rank(&cfg.variance, n);
    let per_grid_budget = (cfg.max_grid_cells / (1 + r)).max(1);
    let fits = |spec: &GridSpec| {
        matches!(spec.total_points(d), Some(cells) if cells <= per_grid_budget)
    };
    match &cfg.grid {
        Some(spec) => {
            spec.validate_for_dim(d)?;
            if fits(spec) {
                Ok(spec.clone())
            } else {
                Err(Error::Snapshot(format!(
                    "serving grid {} in d={d} with variance rank {r} exceeds the \
                     {}-cell budget — reduce the grid size or the variance rank",
                    spec.describe(),
                    cfg.max_grid_cells
                )))
            }
        }
        None => {
            let mut spec = model_spec.clone();
            loop {
                if fits(&spec) {
                    return Ok(spec);
                }
                spec = spec.shrink().ok_or_else(|| {
                    Error::Snapshot(format!(
                        "cannot shrink serving grid {} in d={d} under the \
                         {}-cell budget (variance rank {r}) — use a sparse \
                         spec or a lower variance rank",
                        model_spec.describe(),
                        cfg.max_grid_cells
                    ))
                })?;
            }
        }
    }
}

/// Provenance tag: which model family produced the snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotVariant {
    Skip,
    Kiss,
    Exact,
}

impl SnapshotVariant {
    fn to_u32(self) -> u32 {
        match self {
            SnapshotVariant::Skip => 0,
            SnapshotVariant::Kiss => 1,
            SnapshotVariant::Exact => 2,
        }
    }

    fn from_u32(v: u32) -> Result<Self> {
        match v {
            0 => Ok(SnapshotVariant::Skip),
            1 => Ok(SnapshotVariant::Kiss),
            2 => Ok(SnapshotVariant::Exact),
            other => Err(Error::Snapshot(format!("unknown variant tag {other}"))),
        }
    }
}

/// Options for building a snapshot from a trained model.
#[derive(Clone, Debug)]
pub struct SnapshotConfig {
    /// Serving-grid spec (None ⇒ reuse the model's training-grid spec,
    /// shrinking it under `max_grid_cells` if needed).
    pub grid: Option<GridSpec>,
    /// How to build the variance factor.
    pub variance: VarianceMode,
    /// Refuse grids larger than this many stored cells.
    pub max_grid_cells: usize,
    /// Solver policy for any solve the snapshot build itself performs —
    /// today the α = K̂⁻¹y recompute when [`ModelSnapshot::from_mvm`] is
    /// given a model with externally-set hypers and no cached α (the
    /// CLI's `--precond`/`--space`/`--precision` flags feed both this
    /// and the training config through one
    /// [`SolverPolicy::from_cli`] parse). `None` (the default) inherits
    /// the model's own folded `cfg.cg.precond`; `Some(policy)` forces
    /// `policy.precond` — including a policy whose preconditioner is
    /// `PrecondSpec::None` for an explicitly unpreconditioned solve.
    pub policy: Option<SolverPolicy>,
}

impl Default for SnapshotConfig {
    fn default() -> Self {
        SnapshotConfig {
            grid: None,
            variance: VarianceMode::Lanczos(64),
            max_grid_cells: DEFAULT_MAX_GRID_CELLS,
            policy: None,
        }
    }
}

/// The multi-task head of a snapshot (new in format v5): the
/// coregionalization kernel, each training row's task assignment, and
/// the per-task serving caches for tasks `1..s` — task 0 is served from
/// the base [`ModelSnapshot::cache`], so single-task models pay nothing
/// for the multi-task format beyond a 4-byte flag.
#[derive(Clone, Debug)]
pub struct TaskHead {
    /// Coregionalization kernel `B Bᵀ + D` over the `s` tasks (paper §6).
    pub kernel: TaskKernel,
    /// Task of every training row (length n, values < s).
    pub task_of: Vec<usize>,
    /// Serving caches for tasks `1..s` (length `s − 1`, indexed by
    /// `task − 1`): structurally identical to the base cache — same grid
    /// spec, term axes, coefficients, and variance rank — differing only
    /// in the task-masked mean/variance buffers and the prior variance
    /// `σ_f²·k_task(t,t)` (see [`super::cache::build_task_cache`]).
    pub caches: Vec<PredictCache>,
}

/// A trained model frozen into its predictive caches.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// Format version this snapshot was read from (writers always emit
    /// [`SNAPSHOT_VERSION`]).
    pub version: u32,
    pub hypers: GpHypers,
    pub variant: SnapshotVariant,
    /// Lanczos rank used during training (provenance only).
    pub train_rank: u32,
    /// Lanczos rank of the final predictive solve (provenance only).
    pub refresh_rank: u32,
    /// Which engine solved the stored α (provenance only, new in format
    /// v4): 0 — data-space CG/PCG on the n × n system; 1 — grid-space
    /// normal equations ([`crate::solvers::gridspace`]), α recovered by
    /// back-projection. Files older than v4 migrate to 0.
    pub alpha_space: u32,
    /// Cached solve `α = K̂⁻¹ y`.
    pub alpha: Vec<f64>,
    /// The grid-side predictive cache queries are answered from.
    pub cache: PredictCache,
    /// Pending streamed observations (new in format v3): what a live
    /// model ingested since its last full refresh, in sequence order.
    /// Empty for frozen (train-then-snapshot) models and for files
    /// migrated from v1/v2.
    pub pending: Vec<Observation>,
    /// Multi-task head (new in format v5): the task kernel, per-row task
    /// assignments, and the serving caches for tasks `1..s`. `None` for
    /// single-task models and for files migrated from v1–v4 (which could
    /// not persist multi-task models).
    pub tasks: Option<TaskHead>,
}

impl ModelSnapshot {
    /// Freeze a trained [`MvmGp`] (SKIP or KISS-GP, dense or sparse
    /// grid). A model with a cached α (`fit`/`refresh` ran) is frozen
    /// as-is; one without — externally-set hypers, no refresh — gets its
    /// α = K̂⁻¹y computed here with a refresh-grade operator and the
    /// preconditioner [`SnapshotConfig::precond`] describes.
    pub fn from_mvm(gp: &MvmGp, cfg: &SnapshotConfig) -> Result<Self> {
        // Refresh-grade operator, built lazily at most once and shared by
        // the α recompute and the Lanczos variance factor.
        let mut built: Option<AffineOp> = None;
        let build = |built: &mut Option<AffineOp>| -> Result<()> {
            if built.is_none() {
                *built = Some(gp.build_operator_with_rank(
                    &gp.hypers,
                    gp.cfg.seed,
                    gp.refresh_grade_rank(),
                )?);
            }
            Ok(())
        };
        // D-SKI models solve the extended (y, ∇y) system — the recompute
        // targets, the Lanczos probe, and the cache build all switch on
        // this (value-only models borrow `ys` at zero cost).
        let targets = gp.train_targets();
        let (alpha, alpha_space) = match gp.alpha() {
            // A cached α carries its provenance; the recompute below is
            // always a data-space CG solve.
            Some(a) => (a.to_vec(), gp.alpha_solved_in_grid_space() as u32),
            None => {
                build(&mut built)?;
                let op = built.as_ref().expect("just built");
                // An explicit snapshot-level policy wins; the default
                // (None) inherits whatever preconditioner the model
                // itself was configured to solve with (already folded
                // into its CgConfig), so a library caller doesn't
                // silently lose preconditioning the CLI would have kept.
                let spec = cfg
                    .policy
                    .map(|p| p.precond)
                    .unwrap_or(gp.cfg.cg.precond);
                let pre = build_preconditioner(op, Some(gp.hypers.sn2()), spec);
                let cg = CgConfig {
                    max_iters: gp.cfg.cg.max_iters.max(200),
                    ..gp.cfg.cg
                };
                let sol = cg_solve_with(op, &targets, pre.as_ref(), None, cg);
                if !sol.converged {
                    return Err(Error::Snapshot(format!(
                        "α solve did not converge (rel residual {:.2e}) — raise \
                         cg.max_iters or use --precond rank:K",
                        sol.rel_residual
                    )));
                }
                (sol.x, 0)
            }
        };
        let d = gp.xs.cols;
        let spec = resolve_serving_spec(cfg, d, gp.xs.rows, &gp.cfg.grid)?;
        let grid = build_grid(&gp.xs, &spec)?;
        let s = match &cfg.variance {
            VarianceMode::None => None,
            VarianceMode::Exact => {
                // Dense K̂ + Cholesky once at snapshot time (derivative
                // kernel for D-SKI models).
                let kern = ProductKernel::rbf(d, gp.hypers.ell(), gp.hypers.sf2());
                let mut khat = if gp.grads().is_some() {
                    kern.gram_deriv_sym(&gp.xs, &vec![true; gp.xs.rows])
                } else {
                    kern.gram_sym(&gp.xs)
                };
                khat.add_diag(gp.hypers.sn2());
                Some(inverse_root_exact(&Cholesky::new_with_jitter(&khat, 0.0)?))
            }
            VarianceMode::Lanczos(rank) => {
                // High-accuracy operator, same grade as the α refresh —
                // reuse the decomposition `refresh` cached when possible.
                let op = match gp.refresh_operator() {
                    Some(op) => op,
                    None => {
                        build(&mut built)?;
                        built.as_ref().expect("just built")
                    }
                };
                Some(inverse_root_lanczos(op, &targets, *rank)?)
            }
        };
        let cache = if gp.grads().is_some() {
            // The extended α scatters through value + differentiated
            // stencils; the serving spec must be a single-term dense
            // grid, like the training grid `new_with_grads` enforced.
            let terms = grid.terms();
            if terms.len() != 1 || terms[0].coeff != 1.0 {
                return Err(Error::Snapshot(format!(
                    "gradient-observation models need a single-term dense \
                     serving grid, got {} ({} terms)",
                    spec.describe(),
                    terms.len()
                )));
            }
            build_grad_cache(
                &gp.xs,
                &vec![true; gp.xs.rows],
                &alpha,
                &gp.hypers,
                spec.clone(),
                terms[0].axes.clone(),
                s.as_ref(),
            )?
        } else {
            PredictCache::build(&gp.xs, &alpha, &gp.hypers, grid.as_ref(), s.as_ref())?
        };
        Ok(ModelSnapshot {
            version: SNAPSHOT_VERSION,
            hypers: gp.hypers,
            variant: match gp.cfg.variant {
                MvmVariant::Skip => SnapshotVariant::Skip,
                MvmVariant::Kiss => SnapshotVariant::Kiss,
            },
            train_rank: gp.cfg.rank as u32,
            refresh_rank: gp.cfg.refresh_rank as u32,
            alpha_space,
            alpha,
            cache,
            pending: Vec::new(),
            tasks: None,
        })
    }

    /// Freeze a trained [`ExactGp`], fitting grids to its inputs.
    pub fn from_exact(gp: &ExactGp, cfg: &SnapshotConfig) -> Result<Self> {
        let spec = resolve_serving_spec(
            cfg,
            gp.xs.cols,
            gp.xs.rows,
            &GridSpec::Uniform(64),
        )?;
        let grid = build_grid(&gp.xs, &spec)?;
        Self::from_exact_on_grid(gp, grid.as_ref(), &cfg.variance)
    }

    /// Freeze a trained [`ExactGp`] onto explicit per-dimension grids
    /// (tests place training data exactly on grid nodes this way, making
    /// the stencil path exact).
    pub fn from_exact_with_grids(
        gp: &ExactGp,
        grids: Vec<Grid1d>,
        variance: &VarianceMode,
    ) -> Result<Self> {
        let grid = RectilinearGrid::from_axes(grids);
        Self::from_exact_on_grid(gp, &grid, variance)
    }

    /// Freeze a trained [`ExactGp`] onto any [`InducingGrid`].
    pub fn from_exact_on_grid(
        gp: &ExactGp,
        grid: &dyn InducingGrid,
        variance: &VarianceMode,
    ) -> Result<Self> {
        let alpha = gp
            .alpha()
            .ok_or_else(|| Error::Snapshot("model has no cached α — call fit/refresh".into()))?
            .to_vec();
        let chol = gp
            .cholesky()
            .ok_or_else(|| Error::Snapshot("model has no cached Cholesky".into()))?;
        let s = match variance {
            VarianceMode::None => None,
            VarianceMode::Exact => Some(inverse_root_exact(chol)),
            VarianceMode::Lanczos(rank) => {
                let kern = ProductKernel::rbf(gp.xs.cols, gp.hypers.ell(), gp.hypers.sf2());
                let mut khat = kern.gram_sym(&gp.xs);
                khat.add_diag(gp.hypers.sn2());
                let op = crate::operators::DenseOp(khat);
                Some(inverse_root_lanczos(&op, &gp.ys, *rank)?)
            }
        };
        let cache = PredictCache::build(&gp.xs, &alpha, &gp.hypers, grid, s.as_ref())?;
        Ok(ModelSnapshot {
            version: SNAPSHOT_VERSION,
            hypers: gp.hypers,
            variant: SnapshotVariant::Exact,
            train_rank: 0,
            refresh_rank: 0,
            alpha_space: 0,
            alpha,
            cache,
            pending: Vec::new(),
            tasks: None,
        })
    }

    /// Number of tasks this snapshot serves (1 for single-task models).
    pub fn num_tasks(&self) -> usize {
        self.tasks.as_ref().map_or(1, |h| h.kernel.num_tasks())
    }

    /// True iff the snapshot carries a multi-task head.
    pub fn is_multitask(&self) -> bool {
        self.tasks.is_some()
    }

    /// The serving cache that answers `task`'s queries: task 0 is the
    /// base cache, tasks `1..s` live in the head. `None` when out of
    /// range — including any task > 0 on a single-task model.
    pub fn task_cache(&self, task: usize) -> Option<&PredictCache> {
        if task == 0 {
            return Some(&self.cache);
        }
        self.tasks.as_ref()?.caches.get(task - 1)
    }

    /// Serialize to `path` (format version [`SNAPSHOT_VERSION`]).
    ///
    /// Writes to a `.tmp` sibling and renames into place, so a crash
    /// mid-write can never destroy the previous good snapshot — live
    /// servers overwrite their checkpoint in a loop
    /// (`serve --live --snapshot-out`).
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)?;
            }
        }
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Deserialize from `path`, verifying magic, version, and checksum.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = fs::read(path)?;
        Self::from_bytes(&bytes)
    }

    /// Approximate resident size of the snapshot in bytes: the grid-side
    /// predictive cache(s, one per task) plus α, the task kernel, and
    /// the pending observation log. The fleet registry multiplies this
    /// by the shard count when charging a model against its memory
    /// budget.
    pub fn approx_bytes(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        let u = std::mem::size_of::<u32>();
        let pending: usize = self
            .pending
            .iter()
            .map(|o| f * (o.x.len() + 1) + std::mem::size_of::<u64>() + u)
            .sum();
        let tasks = self.tasks.as_ref().map_or(0, |h| {
            h.caches.iter().map(PredictCache::approx_bytes).sum::<usize>()
                + f * (h.kernel.b.data.len() + h.kernel.diag.len())
                + u * h.task_of.len()
        });
        self.cache.approx_bytes() + f * self.alpha.len() + pending + tasks
    }

    /// Encode to the version-5 byte layout (checksum included). Writers
    /// always emit the newest version, whatever `self.version` was read
    /// from.
    pub fn to_bytes(&self) -> Vec<u8> {
        let d = self.cache.dim();
        let n = self.alpha.len();
        let r = self.cache.var_rank();
        let terms = self.cache.terms();
        let m_total = self.cache.total_grid();
        let task_bytes = self.tasks.as_ref().map_or(4, |h| {
            16 + 8 * (h.kernel.b.data.len() + h.kernel.diag.len())
                + 4 * h.task_of.len()
                + h.caches.len() * (8 + m_total * (1 + r) * 8)
        });
        let mut out = Vec::with_capacity(
            64 + d * 24
                + terms.len() * (8 + d * 20)
                + (n + m_total * (1 + r)) * 8
                + self.pending.len() * (24 + 2 * d * 8)
                + task_bytes,
        );
        out.extend_from_slice(SNAPSHOT_MAGIC);
        push_u32(&mut out, SNAPSHOT_VERSION);
        push_u32(&mut out, d as u32);
        push_u32(&mut out, n as u32);
        push_u32(&mut out, r as u32);
        push_u32(&mut out, self.variant.to_u32());
        push_u32(&mut out, self.train_rank);
        push_u32(&mut out, self.refresh_rank);
        push_u32(&mut out, self.alpha_space);
        push_f64(&mut out, self.hypers.log_ell);
        push_f64(&mut out, self.hypers.log_sf2);
        push_f64(&mut out, self.hypers.log_sn2);
        match &self.cache.spec {
            GridSpec::Uniform(m) => {
                push_u32(&mut out, 0);
                push_u32(&mut out, *m as u32);
            }
            GridSpec::Rectilinear(sizes) => {
                push_u32(&mut out, 1);
                debug_assert_eq!(sizes.len(), d);
                for &m in sizes {
                    push_u32(&mut out, m as u32);
                }
            }
            GridSpec::Sparse { level } => {
                push_u32(&mut out, 2);
                push_u32(&mut out, *level as u32);
            }
        }
        push_u32(&mut out, terms.len() as u32);
        for t in terms {
            push_f64(&mut out, t.coeff);
            for g in &t.axes {
                push_f64(&mut out, g.min);
                push_f64(&mut out, g.h);
                push_u32(&mut out, g.m as u32);
            }
        }
        for &a in &self.alpha {
            push_f64(&mut out, a);
        }
        for t in terms {
            for &v in &t.mean {
                push_f64(&mut out, v);
            }
        }
        for t in terms {
            for &v in &t.var_r.data {
                push_f64(&mut out, v);
            }
        }
        push_u32(&mut out, self.pending.len() as u32);
        for o in &self.pending {
            debug_assert_eq!(o.x.len(), d, "pending observation dimensionality");
            push_u64(&mut out, o.seq);
            push_u32(&mut out, o.task as u32);
            for &v in &o.x {
                push_f64(&mut out, v);
            }
            push_f64(&mut out, o.y);
            match &o.grad {
                None => push_u32(&mut out, 0),
                Some(g) => {
                    debug_assert_eq!(g.len(), d, "pending gradient dimensionality");
                    push_u32(&mut out, 1);
                    for &v in g {
                        push_f64(&mut out, v);
                    }
                }
            }
        }
        match &self.tasks {
            None => push_u32(&mut out, 0),
            Some(head) => {
                push_u32(&mut out, 1);
                let s = head.kernel.num_tasks();
                push_u32(&mut out, s as u32);
                push_u32(&mut out, head.kernel.b.cols as u32);
                for &v in &head.kernel.b.data {
                    push_f64(&mut out, v);
                }
                for &v in &head.kernel.diag {
                    push_f64(&mut out, v);
                }
                debug_assert_eq!(head.task_of.len(), n, "task assignments cover α");
                for &t in &head.task_of {
                    push_u32(&mut out, t as u32);
                }
                debug_assert_eq!(head.caches.len(), s - 1, "one cache per task 1..s");
                for cache in &head.caches {
                    push_f64(&mut out, cache.prior_var);
                    debug_assert_eq!(
                        cache.terms().len(),
                        terms.len(),
                        "task caches share the base cache's grid terms"
                    );
                    for t in cache.terms() {
                        for &v in &t.mean {
                            push_f64(&mut out, v);
                        }
                        for &v in &t.var_r.data {
                            push_f64(&mut out, v);
                        }
                    }
                }
            }
        }
        let sum = fnv1a(&out);
        push_u64(&mut out, sum);
        out
    }

    /// Decode from bytes: version 6 natively, versions 1–5 with an
    /// in-memory migration (v1: single term, coefficient 1, rectilinear
    /// spec; v2: empty pending log; v3: data-space α provenance; v4:
    /// task-0 pending entries and no multi-task head; v5: gradient-free
    /// pending entries).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor { bytes, pos: 0 };
        let magic = c.take(8)?;
        if magic != SNAPSHOT_MAGIC {
            return Err(Error::Snapshot("bad magic (not a skip-gp snapshot)".into()));
        }
        let version = c.u32()?;
        if !(SNAPSHOT_MIN_VERSION..=SNAPSHOT_VERSION).contains(&version) {
            return Err(Error::Snapshot(format!(
                "unsupported snapshot version {version} (this build reads \
                 {SNAPSHOT_MIN_VERSION}..={SNAPSHOT_VERSION})"
            )));
        }
        // Verify the trailing checksum before trusting any field.
        if bytes.len() < 8 {
            return Err(Error::Snapshot("truncated snapshot".into()));
        }
        let payload = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        let computed = fnv1a(payload);
        if stored != computed {
            return Err(Error::Snapshot(format!(
                "checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
            )));
        }
        let d = c.u32()? as usize;
        let n = c.u32()? as usize;
        let r = c.u32()? as usize;
        let variant = SnapshotVariant::from_u32(c.u32()?)?;
        let train_rank = c.u32()?;
        let refresh_rank = c.u32()?;
        // α solve-space provenance (v4+; older files predate grid-space
        // solves, so data-space is the correct migration, not a guess).
        let alpha_space = if version >= 4 { c.u32()? } else { 0 };
        if alpha_space > 1 {
            return Err(Error::Snapshot(format!(
                "unknown alpha_space tag {alpha_space} (0 data, 1 grid)"
            )));
        }
        let hypers = GpHypers {
            log_ell: c.f64()?,
            log_sf2: c.f64()?,
            log_sn2: c.f64()?,
        };

        // Grid spec + term axes (v1: no spec, one implicit term).
        let (spec, term_axes): (GridSpec, Vec<(f64, Vec<Grid1d>)>) = if version == 1 {
            let axes = read_axes(&mut c, d)?;
            let spec = GridSpec::Rectilinear(axes.iter().map(|g| g.m).collect());
            (spec, vec![(1.0, axes)])
        } else {
            let spec = match c.u32()? {
                0 => GridSpec::Uniform(c.u32()? as usize),
                1 => {
                    let mut sizes = Vec::with_capacity(d);
                    for _ in 0..d {
                        sizes.push(c.u32()? as usize);
                    }
                    GridSpec::Rectilinear(sizes)
                }
                2 => GridSpec::Sparse { level: c.u32()? as usize },
                other => {
                    return Err(Error::Snapshot(format!(
                        "unknown grid-spec kind {other}"
                    )))
                }
            };
            let n_terms = c.u32()? as usize;
            if n_terms == 0 || n_terms > crate::grid::MAX_SPARSE_TERMS {
                return Err(Error::Snapshot(format!(
                    "implausible grid term count {n_terms}"
                )));
            }
            let mut terms = Vec::with_capacity(n_terms);
            for _ in 0..n_terms {
                let coeff = c.f64()?;
                if !coeff.is_finite() {
                    return Err(Error::Snapshot("non-finite term coefficient".into()));
                }
                terms.push((coeff, read_axes(&mut c, d)?));
            }
            (spec, terms)
        };

        let alpha = c.f64_vec(n)?;
        let mut means = Vec::with_capacity(term_axes.len());
        for (_, axes) in &term_axes {
            let m_t = axes
                .iter()
                .try_fold(1usize, |acc, g| acc.checked_mul(g.m))
                .ok_or_else(|| Error::Snapshot("grid size overflow".into()))?;
            means.push(c.f64_vec(m_t)?);
        }
        let mut vars = Vec::with_capacity(term_axes.len());
        for (_, axes) in &term_axes {
            let m_t: usize = axes.iter().map(|g| g.m).product();
            let mr = m_t
                .checked_mul(r)
                .ok_or_else(|| Error::Snapshot("variance cache size overflow".into()))?;
            let data = c.f64_vec(mr)?;
            vars.push(if r == 0 {
                Matrix::zeros(m_t, 0)
            } else {
                Matrix::from_vec(m_t, r, data)
            });
        }
        // Pending observation log (v3+; earlier versions migrate to an
        // empty log).
        let pending = if version >= 3 {
            let count = c.u32()? as usize;
            if count > MAX_PENDING_OBSERVATIONS {
                return Err(Error::Snapshot(format!(
                    "implausible pending-log length {count}"
                )));
            }
            let mut pending = Vec::with_capacity(count);
            let mut last_seq = None;
            for _ in 0..count {
                let seq = c.u64()?;
                if last_seq.is_some_and(|s| seq <= s) {
                    return Err(Error::Snapshot(
                        "pending log out of sequence order".into(),
                    ));
                }
                last_seq = Some(seq);
                // v5 entries carry their task id; older files predate
                // multi-task streaming, so task 0 is the correct
                // migration, not a guess.
                let task = if version >= 5 { c.u32()? as usize } else { 0 };
                let x = c.f64_vec(d)?;
                let y = c.f64()?;
                if !y.is_finite() || x.iter().any(|v| !v.is_finite()) {
                    return Err(Error::Snapshot(
                        "non-finite pending observation".into(),
                    ));
                }
                // v6 entries may carry a gradient payload; older files
                // predate derivative observations, so `None` is the
                // correct migration, not a guess.
                let grad = if version >= 6 {
                    match c.u32()? {
                        0 => None,
                        1 => {
                            let g = c.f64_vec(d)?;
                            if g.iter().any(|v| !v.is_finite()) {
                                return Err(Error::Snapshot(
                                    "non-finite pending gradient".into(),
                                ));
                            }
                            Some(g)
                        }
                        other => {
                            return Err(Error::Snapshot(format!(
                                "unknown pending gradient flag {other} (0 or 1)"
                            )))
                        }
                    }
                } else {
                    None
                };
                pending.push(Observation { seq, task, x, y, grad });
            }
            pending
        } else {
            Vec::new()
        };
        // Multi-task head (v5+; single-task files write flag 0 and older
        // versions could not persist multi-task models at all).
        let tasks = if version >= 5 {
            match c.u32()? {
                0 => None,
                1 => {
                    let s = c.u32()? as usize;
                    if s == 0 || s > MAX_TASKS {
                        return Err(Error::Snapshot(format!(
                            "implausible task count {s}"
                        )));
                    }
                    let q = c.u32()? as usize;
                    if q > MAX_TASKS {
                        return Err(Error::Snapshot(format!(
                            "implausible task-kernel rank {q}"
                        )));
                    }
                    let sq = s.checked_mul(q).ok_or_else(|| {
                        Error::Snapshot("task kernel size overflow".into())
                    })?;
                    let b_data = c.f64_vec(sq)?;
                    let diag = c.f64_vec(s)?;
                    if b_data.iter().chain(&diag).any(|v| !v.is_finite()) {
                        return Err(Error::Snapshot("non-finite task kernel".into()));
                    }
                    let b = if q == 0 {
                        Matrix::zeros(s, 0)
                    } else {
                        Matrix::from_vec(s, q, b_data)
                    };
                    let kernel = TaskKernel::new(b, diag);
                    let mut task_of = Vec::with_capacity(n);
                    for _ in 0..n {
                        let t = c.u32()? as usize;
                        if t >= s {
                            return Err(Error::Snapshot(format!(
                                "task assignment {t} out of range (model has \
                                 {s} tasks)"
                            )));
                        }
                        task_of.push(t);
                    }
                    // Per-task caches reuse the base cache's term axes,
                    // coefficients, and variance rank — only the masked
                    // buffers and the prior variance are per-task.
                    let mut caches = Vec::with_capacity(s - 1);
                    for _ in 1..s {
                        let prior_var = c.f64()?;
                        if !prior_var.is_finite() {
                            return Err(Error::Snapshot(
                                "non-finite task prior variance".into(),
                            ));
                        }
                        let mut tterms = Vec::with_capacity(term_axes.len());
                        for (coeff, axes) in &term_axes {
                            let m_t: usize = axes.iter().map(|g| g.m).product();
                            let mean = c.f64_vec(m_t)?;
                            let data = c.f64_vec(m_t * r)?;
                            let var_r = if r == 0 {
                                Matrix::zeros(m_t, 0)
                            } else {
                                Matrix::from_vec(m_t, r, data)
                            };
                            tterms.push(TermCache::new(
                                *coeff,
                                axes.clone(),
                                mean,
                                var_r,
                            )?);
                        }
                        caches.push(PredictCache::from_parts(
                            spec.clone(),
                            tterms,
                            prior_var,
                            hypers.sn2(),
                        )?);
                    }
                    Some(TaskHead { kernel, task_of, caches })
                }
                other => {
                    return Err(Error::Snapshot(format!(
                        "unknown task-section flag {other}"
                    )))
                }
            }
        } else {
            None
        };
        let num_tasks = tasks.as_ref().map_or(1, |h| h.kernel.num_tasks());
        if let Some(o) = pending.iter().find(|o| o.task >= num_tasks) {
            return Err(Error::Snapshot(format!(
                "pending observation task {} out of range (model has \
                 {num_tasks} tasks)",
                o.task
            )));
        }
        // Trailing checksum (8 bytes) must be exactly what remains.
        if c.remaining() != 8 {
            return Err(Error::Snapshot(format!(
                "trailing garbage: {} bytes after payload",
                c.remaining().saturating_sub(8)
            )));
        }
        let mut terms = Vec::with_capacity(term_axes.len());
        for (((coeff, axes), mean), var_r) in
            term_axes.into_iter().zip(means).zip(vars)
        {
            terms.push(TermCache::new(coeff, axes, mean, var_r)?);
        }
        let cache = PredictCache::from_parts(spec, terms, hypers.sf2(), hypers.sn2())?;
        Ok(ModelSnapshot {
            version,
            hypers,
            variant,
            train_rank,
            refresh_rank,
            alpha_space,
            alpha,
            cache,
            pending,
            tasks,
        })
    }
}

/// Read `d` serialized axes `(min, h, m)`.
fn read_axes(c: &mut Cursor<'_>, d: usize) -> Result<Vec<Grid1d>> {
    let mut axes = Vec::with_capacity(d);
    for _ in 0..d {
        let min = c.f64()?;
        let h = c.f64()?;
        let m = c.u32()? as usize;
        if m < 1 {
            return Err(Error::Snapshot("grid axis with m=0".into()));
        }
        if !min.is_finite() || !h.is_finite() || h <= 0.0 {
            return Err(Error::Snapshot(format!(
                "invalid grid axis (min={min}, h={h}, m={m})"
            )));
        }
        axes.push(Grid1d { min, h, m });
    }
    Ok(axes)
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// FNV-1a over `bytes` — cheap corruption detection, not cryptography.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(len)
            .ok_or_else(|| Error::Snapshot("field length overflow".into()))?;
        if end > self.bytes.len() {
            return Err(Error::Snapshot("truncated snapshot".into()));
        }
        let s = &self.bytes[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_vec(&mut self, len: usize) -> Result<Vec<f64>> {
        let nbytes = len
            .checked_mul(8)
            .ok_or_else(|| Error::Snapshot("field length overflow".into()))?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::SparseGrid;
    use crate::util::Rng;

    fn small_snapshot(seed: u64) -> ModelSnapshot {
        let mut rng = Rng::new(seed);
        let xs = Matrix::from_fn(40, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..40).map(|i| xs.get(i, 0).sin() + 0.01 * rng.normal()).collect();
        let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.8, 1.0, 0.05));
        gp.refresh().unwrap();
        ModelSnapshot::from_exact(
            &gp,
            &SnapshotConfig {
                grid: Some(GridSpec::uniform(16)),
                variance: VarianceMode::Exact,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn bytes_roundtrip_bitwise() {
        let snap = small_snapshot(1);
        let bytes = snap.to_bytes();
        let back = ModelSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.version, SNAPSHOT_VERSION);
        assert_eq!(back.alpha_space, snap.alpha_space);
        assert_eq!(back.variant, SnapshotVariant::Exact);
        assert_eq!(back.hypers, snap.hypers);
        assert_eq!(back.alpha, snap.alpha);
        assert_eq!(back.cache.spec, snap.cache.spec);
        assert_eq!(back.cache.terms().len(), snap.cache.terms().len());
        for (a, b) in back.cache.terms().iter().zip(snap.cache.terms()) {
            assert_eq!(a.coeff, b.coeff);
            assert_eq!(a.mean, b.mean);
            assert_eq!(a.var_r.data, b.var_r.data);
            assert_eq!(a.axes, b.axes);
        }
    }

    #[test]
    fn sparse_snapshot_roundtrips_and_predicts_identically() {
        let mut rng = Rng::new(9);
        let xs = Matrix::from_fn(60, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> =
            (0..60).map(|i| xs.get(i, 0).sin() + 0.01 * rng.normal()).collect();
        let mut gp = ExactGp::new(xs.clone(), ys, GpHypers::new(0.8, 1.0, 0.05));
        gp.refresh().unwrap();
        let grid = SparseGrid::fit(&xs, 4).unwrap();
        let snap =
            ModelSnapshot::from_exact_on_grid(&gp, &grid, &VarianceMode::Lanczos(16))
                .unwrap();
        assert!(snap.cache.terms().len() > 1);
        assert_eq!(snap.cache.spec, GridSpec::sparse(4));
        let bytes = snap.to_bytes();
        let back = ModelSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.cache.spec, snap.cache.spec);
        let xt = Matrix::from_fn(30, 3, |_, _| rng.uniform_in(-0.9, 0.9));
        assert_eq!(back.cache.predict_mean(&xt), snap.cache.predict_mean(&xt));
        assert_eq!(back.cache.predict_var(&xt), snap.cache.predict_var(&xt));
    }

    #[test]
    fn from_mvm_without_alpha_solves_for_it() {
        use crate::gp::{MvmGp, MvmGpConfig};
        let mut rng = Rng::new(11);
        let xs = Matrix::from_fn(80, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> =
            (0..80).map(|i| xs.get(i, 0).sin() + 0.01 * rng.normal()).collect();
        let h = GpHypers::new(0.8, 1.0, 0.05);
        let cfg = MvmGpConfig {
            grid: GridSpec::uniform(32),
            rank: 30,
            ..Default::default()
        };
        let mut trained = MvmGp::new(xs.clone(), ys.clone(), h, cfg.clone());
        trained.refresh().unwrap();
        let snap_a = ModelSnapshot::from_mvm(
            &trained,
            &SnapshotConfig { variance: VarianceMode::None, ..Default::default() },
        )
        .unwrap();
        // Same model, hypers set externally, never refreshed: the build
        // computes α itself (preconditioned), instead of erroring.
        let cold = MvmGp::new(xs, ys, h, cfg);
        let snap_b = ModelSnapshot::from_mvm(
            &cold,
            &SnapshotConfig {
                variance: VarianceMode::None,
                policy: Some(SolverPolicy {
                    precond: crate::solvers::PrecondSpec::PivChol { rank: 25 },
                    ..Default::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        let xt = Matrix::from_fn(20, 2, |_, _| rng.uniform_in(-0.8, 0.8));
        let pa = snap_a.cache.predict_mean(&xt);
        let pb = snap_b.cache.predict_mean(&xt);
        for (a, b) in pa.iter().zip(&pb) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn pending_log_roundtrips_bitwise() {
        let mut snap = small_snapshot(7);
        snap.pending = vec![
            Observation { seq: 3, task: 0, x: vec![0.25, -0.5], y: 1.125, grad: None },
            Observation {
                seq: 9,
                task: 0,
                x: vec![0.75, 0.0],
                y: -2.25,
                grad: Some(vec![0.5, -1.5]),
            },
        ];
        let bytes = snap.to_bytes();
        let back = ModelSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.pending, snap.pending);
        // Re-encoding a mixed (gradient-free + gradient) pending log
        // reproduces the identical bytes.
        assert_eq!(back.to_bytes(), bytes);
        // Out-of-order sequence numbers are a corrupt file, not a parse.
        let mut bad = snap.clone();
        bad.pending.swap(0, 1);
        let err = ModelSnapshot::from_bytes(&bad.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("sequence"), "{err}");
    }

    #[test]
    fn corruption_detected() {
        let snap = small_snapshot(2);
        let mut bytes = snap.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = ModelSnapshot::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn unknown_version_rejected() {
        let snap = small_snapshot(3);
        let mut bytes = snap.to_bytes();
        bytes[8] = 99; // version field, little-endian low byte
        let err = ModelSnapshot::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn alpha_space_roundtrips_and_v3_migrates_to_data() {
        let mut snap = small_snapshot(8);
        snap.alpha_space = 1;
        let v6 = snap.to_bytes();
        let back = ModelSnapshot::from_bytes(&v6).unwrap();
        assert_eq!(back.alpha_space, 1, "v6 roundtrip keeps grid provenance");

        // Splice the same payload down to version 3: drop the 4-byte
        // alpha_space field at offset 36 (after magic 8 + 7 × u32) and
        // the trailing 4-byte task-section flag (the snapshot is
        // single-task with an empty pending log, so nothing else in the
        // layout differs — no pending entries means no v6 grad flags
        // either), patch the version field to 3, and recompute the
        // FNV-1a checksum.
        let mut v3 = Vec::with_capacity(v6.len() - 8);
        v3.extend_from_slice(&v6[..36]);
        v3.extend_from_slice(&v6[40..v6.len() - 12]);
        v3[8..12].copy_from_slice(&3u32.to_le_bytes());
        let sum = fnv1a(&v3);
        v3.extend_from_slice(&sum.to_le_bytes());

        let migrated = ModelSnapshot::from_bytes(&v3).unwrap();
        assert_eq!(migrated.version, 3);
        assert_eq!(
            migrated.alpha_space, 0,
            "v3 files predate grid-space solves — must migrate to data"
        );
        assert_eq!(migrated.hypers, snap.hypers);
        assert_eq!(migrated.alpha, snap.alpha);
        assert_eq!(migrated.cache.spec, snap.cache.spec);

        // An out-of-range tag is a corrupt file, not a silent default.
        let mut bad = snap.clone();
        bad.alpha_space = 7;
        let err = ModelSnapshot::from_bytes(&bad.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("alpha_space"), "{err}");
    }

    /// A multi-task snapshot: `small_snapshot`'s base model wearing a
    /// 3-task head whose per-task caches are structurally-identical
    /// clones of the base cache with distinguishable payloads.
    fn multitask_snapshot(seed: u64) -> ModelSnapshot {
        let mut snap = small_snapshot(seed);
        let n = snap.alpha.len();
        let kernel = TaskKernel::new(
            Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.5, 0.25, -0.5, 1.0]),
            vec![0.5, 0.25, 0.125],
        );
        let mut c1 = snap.cache.clone();
        c1.prior_var = 2.5;
        for t in c1.terms_mut() {
            for v in &mut t.mean {
                *v *= 0.5;
            }
            for v in &mut t.var_r.data {
                *v *= 0.25;
            }
        }
        let mut c2 = snap.cache.clone();
        c2.prior_var = 1.75;
        snap.tasks = Some(TaskHead {
            kernel,
            task_of: (0..n).map(|i| i % 3).collect(),
            caches: vec![c1, c2],
        });
        snap.pending = vec![
            Observation { seq: 0, task: 2, x: vec![0.5, 0.5], y: 1.0, grad: None },
            Observation {
                seq: 4,
                task: 0,
                x: vec![-0.25, 0.125],
                y: -0.5,
                grad: None,
            },
        ];
        snap
    }

    #[test]
    fn multitask_head_roundtrips_bitwise() {
        let snap = multitask_snapshot(12);
        let bytes = snap.to_bytes();
        let back = ModelSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.num_tasks(), 3);
        assert!(back.is_multitask());
        assert_eq!(back.pending, snap.pending);
        let head = back.tasks.as_ref().unwrap();
        let orig = snap.tasks.as_ref().unwrap();
        assert_eq!(head.task_of, orig.task_of);
        assert_eq!(head.kernel.b.data, orig.kernel.b.data);
        assert_eq!(head.kernel.diag, orig.kernel.diag);
        assert_eq!(head.caches.len(), 2);
        for (a, b) in head.caches.iter().zip(&orig.caches) {
            assert_eq!(a.prior_var, b.prior_var);
            for (ta, tb) in a.terms().iter().zip(b.terms()) {
                assert_eq!(ta.mean, tb.mean);
                assert_eq!(ta.var_r.data, tb.var_r.data);
                assert_eq!(ta.axes, tb.axes);
            }
        }
        // task_cache routes task 0 to the base cache, 1.. to the head,
        // and rejects out-of-range ids.
        assert!(std::ptr::eq(back.task_cache(0).unwrap(), &back.cache));
        assert_eq!(back.task_cache(1).unwrap().prior_var, 2.5);
        assert_eq!(back.task_cache(2).unwrap().prior_var, 1.75);
        assert!(back.task_cache(3).is_none());
        // And re-encoding reproduces the identical bytes.
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn bad_task_payloads_are_rejected() {
        // A task assignment pointing past the task count is a corrupt
        // file, not an index panic later.
        let mut snap = multitask_snapshot(13);
        snap.tasks.as_mut().unwrap().task_of[0] = 3;
        let err = ModelSnapshot::from_bytes(&snap.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("task assignment"), "{err}");

        // So is a pending observation for a task the model doesn't have.
        let mut snap = multitask_snapshot(13);
        snap.pending[0].task = 9;
        let err = ModelSnapshot::from_bytes(&snap.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("pending observation task"), "{err}");

        // Single-task snapshots only carry task-0 pending entries.
        let mut snap = small_snapshot(13);
        snap.pending = vec![Observation {
            seq: 1,
            task: 1,
            x: vec![0.5, 0.5],
            y: 1.0,
            grad: None,
        }];
        let err = ModelSnapshot::from_bytes(&snap.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("pending observation task"), "{err}");
    }

    #[test]
    fn v4_migrates_to_task_free_head() {
        let snap = small_snapshot(14);
        let v6 = snap.to_bytes();
        // Splice down to version 4: the snapshot is single-task with an
        // empty pending log (so no v6 grad flags), and v4 is exactly the
        // current layout minus the trailing 4-byte task-section flag.
        // Patch the version, re-checksum.
        let mut v4 = Vec::with_capacity(v6.len() - 4);
        v4.extend_from_slice(&v6[..v6.len() - 12]);
        v4[8..12].copy_from_slice(&4u32.to_le_bytes());
        let sum = fnv1a(&v4);
        v4.extend_from_slice(&sum.to_le_bytes());

        let migrated = ModelSnapshot::from_bytes(&v4).unwrap();
        assert_eq!(migrated.version, 4);
        assert!(migrated.tasks.is_none());
        assert_eq!(migrated.num_tasks(), 1);
        assert_eq!(migrated.alpha, snap.alpha);
        assert_eq!(migrated.cache.spec, snap.cache.spec);
    }

    #[test]
    fn v5_pending_migrates_gradient_free() {
        let mut snap = small_snapshot(15);
        snap.pending = vec![
            Observation { seq: 2, task: 0, x: vec![0.5, -0.25], y: 1.5, grad: None },
            Observation { seq: 6, task: 0, x: vec![0.0, 0.75], y: -0.5, grad: None },
        ];
        let v6 = snap.to_bytes();
        // Splice down to version 5: drop each pending entry's trailing
        // 4-byte grad flag (both entries above carry none, so v5 is
        // exactly v6 minus one zero u32 per entry). The snapshot is
        // single-task, so the file ends with the 4-byte task flag and
        // the 8-byte checksum. Patch the version, re-checksum.
        let d = 2;
        let entry_v6 = 8 + 4 + d * 8 + 8 + 4; // seq, task, x, y, grad flag
        let pend_start = v6.len() - 12 - 4 - 2 * entry_v6;
        let mut v5 = Vec::with_capacity(v6.len() - 8);
        v5.extend_from_slice(&v6[..pend_start + 4]);
        for i in 0..2 {
            let start = pend_start + 4 + i * entry_v6;
            v5.extend_from_slice(&v6[start..start + entry_v6 - 4]);
        }
        v5.extend_from_slice(&v6[v6.len() - 12..v6.len() - 8]);
        v5[8..12].copy_from_slice(&5u32.to_le_bytes());
        let sum = fnv1a(&v5);
        v5.extend_from_slice(&sum.to_le_bytes());

        let migrated = ModelSnapshot::from_bytes(&v5).unwrap();
        assert_eq!(migrated.version, 5);
        assert_eq!(
            migrated.pending, snap.pending,
            "v5 files predate derivative observations — every entry \
             migrates with grad = None"
        );
        // Re-saving persists as the newest version, bitwise equal to the
        // native v6 encoding of the same snapshot.
        assert_eq!(migrated.to_bytes(), v6);

        // An out-of-range grad flag is a corrupt file, not a bool cast.
        let mut bad = v6.clone();
        let flag_at = pend_start + 4 + entry_v6 - 4;
        bad[flag_at..flag_at + 4].copy_from_slice(&7u32.to_le_bytes());
        let trunc = bad.len() - 8;
        let sum = fnv1a(&bad[..trunc]);
        bad[trunc..].copy_from_slice(&sum.to_le_bytes());
        let err = ModelSnapshot::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("gradient flag"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let snap = small_snapshot(4);
        let bytes = snap.to_bytes();
        let err = ModelSnapshot::from_bytes(&bytes[..bytes.len() - 17]).unwrap_err();
        // Either a length error or a checksum error, never a panic.
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn grid_budget_enforced() {
        let mut rng = Rng::new(5);
        let xs = Matrix::from_fn(30, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.8, 1.0, 0.1));
        gp.refresh().unwrap();
        let err = ModelSnapshot::from_exact(
            &gp,
            &SnapshotConfig {
                grid: Some(GridSpec::uniform(64)),
                variance: VarianceMode::None,
                max_grid_cells: 1000,
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
    }

    #[test]
    fn default_grid_shrinks_under_budget() {
        let mut rng = Rng::new(6);
        let xs = Matrix::from_fn(30, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.8, 1.0, 0.1));
        gp.refresh().unwrap();
        // Default (grid: None) starts from Uniform(64) = 262144 cells and
        // shrinks under the 20k budget instead of erroring.
        let snap = ModelSnapshot::from_exact(
            &gp,
            &SnapshotConfig {
                grid: None,
                variance: VarianceMode::None,
                max_grid_cells: 20_000,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(snap.cache.total_grid() <= 20_000);
    }
}
