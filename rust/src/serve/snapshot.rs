//! Versioned model snapshots: a trained GP frozen into its predictive
//! caches, serialized to a zero-dependency binary format.
//!
//! A snapshot is everything prediction needs and nothing more: the
//! hyperparameters, the per-dimension inducing-grid spec, the cached solve
//! `α = K̂⁻¹y`, the grid-side mean cache, and the low-rank variance factor
//! `R` (see [`super::cache`]). The training inputs are **not** stored —
//! reload and serve without touching training data.
//!
//! # Format (version 1)
//!
//! Little-endian throughout:
//!
//! ```text
//! magic      8 bytes  "SKGPSNAP"
//! version    u32      format version (this file documents version 1)
//! d          u32      input dimensionality
//! n          u32      training-set size (length of α)
//! r          u32      variance-cache rank (0 ⇒ mean-only snapshot)
//! variant    u32      provenance tag: 0 SKIP, 1 KISS, 2 exact
//! train_rank u32      Lanczos rank used during training (provenance)
//! refresh_rank u32    Lanczos rank of the final predictive solve
//! hypers     3 × f64  log ℓ, log σ_f², log σ_n²
//! grids      d × (f64 min, f64 h, u32 m)
//! alpha      n × f64
//! mean       M × f64  with M = Π m_k
//! var_r      (M·r) × f64, row-major M × r
//! checksum   u64      FNV-1a over every preceding byte
//! ```
//!
//! # Versioning rules
//!
//! - The version is a single monotonically increasing `u32`. Readers
//!   accept **exactly** the versions they know; an unknown version is a
//!   hard error (`Error::Snapshot`), never a best-effort parse.
//! - Any layout change — field added, removed, reordered, or re-typed —
//!   bumps the version. There are no optional/variable fields within a
//!   version.
//! - Writers always emit the newest version. Old snapshots are migrated
//!   by re-snapshotting the model, not by in-place rewrites.
//! - The trailing checksum covers the full payload; readers verify it
//!   before trusting any field. Corrupt files fail loudly.

use super::cache::{
    fit_grids, grid_cells_within, inverse_root_exact, inverse_root_lanczos, PredictCache,
    VarianceMode,
};
use crate::gp::{ExactGp, GpHypers, MvmGp, MvmVariant};
use crate::kernels::ProductKernel;
use crate::linalg::{Cholesky, Matrix};
use crate::operators::Grid1d;
use crate::{Error, Result};
use std::fs;
use std::io::Write;
use std::path::Path;

/// File magic.
pub const SNAPSHOT_MAGIC: &[u8; 8] = b"SKGPSNAP";
/// Current (newest) format version; see the module docs for the rules.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Default cap on stored cache cells — the mean cache's M = Π m_k plus
/// the variance factor's M·r, i.e. M·(1+r) ≤ this; beyond it the snapshot
/// builder refuses (or, for the grid-reuse default, shrinks the serving
/// grid) rather than silently allocating gigabytes. 2²² cells = 32 MB.
pub const DEFAULT_MAX_GRID_CELLS: usize = 1 << 22;

/// Variance rank a [`VarianceMode`] will produce for an n-point model.
fn variance_rank(mode: &VarianceMode, n: usize) -> usize {
    match mode {
        VarianceMode::None => 0,
        VarianceMode::Exact => n,
        VarianceMode::Lanczos(r) => (*r).min(n),
    }
}

/// Resolve the per-dimension serving-grid size for a d-dimensional,
/// n-point model: an explicit `cfg.grid_m` is validated as-is, while the
/// grid-reuse default (`cfg.grid_m == 0`) starts from `default_m` and
/// shrinks until the stored cells M·(1+r) fit `cfg.max_grid_cells` (a
/// coarser serving grid only costs a little interpolation accuracy).
fn resolve_serving_grid(
    cfg: &SnapshotConfig,
    d: usize,
    n: usize,
    default_m: usize,
) -> Result<usize> {
    let r = variance_rank(&cfg.variance, n);
    let per_grid_budget = (cfg.max_grid_cells / (1 + r)).max(1);
    let m = if cfg.grid_m == 0 {
        let mut m = default_m.max(8);
        while m > 8 && grid_cells_within(m, d, per_grid_budget).is_none() {
            m = (m * 3 / 4).max(8);
        }
        m
    } else {
        cfg.grid_m
    };
    grid_cells_within(m, d, per_grid_budget).ok_or_else(|| {
        Error::Snapshot(format!(
            "serving grid {m}^{d} with variance rank {r} exceeds the {}-cell budget — \
             reduce the per-dimension grid size or the variance rank",
            cfg.max_grid_cells
        ))
    })?;
    Ok(m)
}

/// Provenance tag: which model family produced the snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotVariant {
    Skip,
    Kiss,
    Exact,
}

impl SnapshotVariant {
    fn to_u32(self) -> u32 {
        match self {
            SnapshotVariant::Skip => 0,
            SnapshotVariant::Kiss => 1,
            SnapshotVariant::Exact => 2,
        }
    }

    fn from_u32(v: u32) -> Result<Self> {
        match v {
            0 => Ok(SnapshotVariant::Skip),
            1 => Ok(SnapshotVariant::Kiss),
            2 => Ok(SnapshotVariant::Exact),
            other => Err(Error::Snapshot(format!("unknown variant tag {other}"))),
        }
    }
}

/// Options for building a snapshot from a trained model.
#[derive(Clone, Debug)]
pub struct SnapshotConfig {
    /// Serving-grid points per dimension (0 ⇒ reuse the model's training
    /// grid size).
    pub grid_m: usize,
    /// How to build the variance factor.
    pub variance: VarianceMode,
    /// Refuse grids larger than this many cells.
    pub max_grid_cells: usize,
}

impl Default for SnapshotConfig {
    fn default() -> Self {
        SnapshotConfig {
            grid_m: 0,
            variance: VarianceMode::Lanczos(64),
            max_grid_cells: DEFAULT_MAX_GRID_CELLS,
        }
    }
}

/// A trained model frozen into its predictive caches.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// Format version this snapshot was read from / will be written as.
    pub version: u32,
    pub hypers: GpHypers,
    pub variant: SnapshotVariant,
    /// Lanczos rank used during training (provenance only).
    pub train_rank: u32,
    /// Lanczos rank of the final predictive solve (provenance only).
    pub refresh_rank: u32,
    /// Cached solve `α = K̂⁻¹ y`.
    pub alpha: Vec<f64>,
    /// The grid-side predictive cache queries are answered from.
    pub cache: PredictCache,
}

impl ModelSnapshot {
    /// Freeze a trained [`MvmGp`] (SKIP or KISS-GP). Requires
    /// `fit`/`refresh` to have produced the cached α.
    pub fn from_mvm(gp: &MvmGp, cfg: &SnapshotConfig) -> Result<Self> {
        let alpha = gp
            .alpha()
            .ok_or_else(|| Error::Snapshot("model has no cached α — call fit/refresh".into()))?
            .to_vec();
        let d = gp.xs.cols;
        let m = resolve_serving_grid(cfg, d, gp.xs.rows, gp.cfg.grid_m)?;
        let grids = fit_grids(&gp.xs, m);
        let s = match &cfg.variance {
            VarianceMode::None => None,
            VarianceMode::Exact => {
                // Dense K̂ + Cholesky once at snapshot time.
                let kern = ProductKernel::rbf(d, gp.hypers.ell(), gp.hypers.sf2());
                let mut khat = kern.gram_sym(&gp.xs);
                khat.add_diag(gp.hypers.sn2());
                Some(inverse_root_exact(&Cholesky::new_with_jitter(&khat, 0.0)?))
            }
            VarianceMode::Lanczos(rank) => {
                // High-accuracy operator, same grade as the α refresh —
                // reuse the decomposition `refresh` cached when possible.
                let built;
                let op = match gp.refresh_operator() {
                    Some(op) => op,
                    None => {
                        built = gp.build_operator_with_rank(
                            &gp.hypers,
                            gp.cfg.seed,
                            gp.refresh_grade_rank(),
                        );
                        &built
                    }
                };
                Some(inverse_root_lanczos(op, &gp.ys, *rank)?)
            }
        };
        let cache = PredictCache::build(&gp.xs, &alpha, &gp.hypers, grids, s.as_ref())?;
        Ok(ModelSnapshot {
            version: SNAPSHOT_VERSION,
            hypers: gp.hypers,
            variant: match gp.cfg.variant {
                MvmVariant::Skip => SnapshotVariant::Skip,
                MvmVariant::Kiss => SnapshotVariant::Kiss,
            },
            train_rank: gp.cfg.rank as u32,
            refresh_rank: gp.cfg.refresh_rank as u32,
            alpha,
            cache,
        })
    }

    /// Freeze a trained [`ExactGp`], fitting grids to its inputs.
    pub fn from_exact(gp: &ExactGp, cfg: &SnapshotConfig) -> Result<Self> {
        let m = resolve_serving_grid(cfg, gp.xs.cols, gp.xs.rows, 64)?;
        Self::from_exact_with_grids(gp, fit_grids(&gp.xs, m), &cfg.variance)
    }

    /// Freeze a trained [`ExactGp`] onto explicit per-dimension grids
    /// (tests place training data exactly on grid nodes this way, making
    /// the stencil path exact).
    pub fn from_exact_with_grids(
        gp: &ExactGp,
        grids: Vec<Grid1d>,
        variance: &VarianceMode,
    ) -> Result<Self> {
        let alpha = gp
            .alpha()
            .ok_or_else(|| Error::Snapshot("model has no cached α — call fit/refresh".into()))?
            .to_vec();
        let chol = gp
            .cholesky()
            .ok_or_else(|| Error::Snapshot("model has no cached Cholesky".into()))?;
        let s = match variance {
            VarianceMode::None => None,
            VarianceMode::Exact => Some(inverse_root_exact(chol)),
            VarianceMode::Lanczos(rank) => {
                let kern = ProductKernel::rbf(gp.xs.cols, gp.hypers.ell(), gp.hypers.sf2());
                let mut khat = kern.gram_sym(&gp.xs);
                khat.add_diag(gp.hypers.sn2());
                let op = crate::operators::DenseOp(khat);
                Some(inverse_root_lanczos(&op, &gp.ys, *rank)?)
            }
        };
        let cache = PredictCache::build(&gp.xs, &alpha, &gp.hypers, grids, s.as_ref())?;
        Ok(ModelSnapshot {
            version: SNAPSHOT_VERSION,
            hypers: gp.hypers,
            variant: SnapshotVariant::Exact,
            train_rank: 0,
            refresh_rank: 0,
            alpha,
            cache,
        })
    }

    /// Serialize to `path` (format version [`SNAPSHOT_VERSION`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)?;
            }
        }
        let mut f = fs::File::create(path)?;
        f.write_all(&bytes)?;
        Ok(())
    }

    /// Deserialize from `path`, verifying magic, version, and checksum.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = fs::read(path)?;
        Self::from_bytes(&bytes)
    }

    /// Encode to the version-1 byte layout (checksum included).
    pub fn to_bytes(&self) -> Vec<u8> {
        let d = self.cache.grids.len();
        let n = self.alpha.len();
        let m_total = self.cache.total_grid();
        let r = self.cache.var_rank();
        let mut out = Vec::with_capacity(
            8 + 7 * 4 + 3 * 8 + d * 20 + (n + m_total + m_total * r) * 8 + 8,
        );
        out.extend_from_slice(SNAPSHOT_MAGIC);
        push_u32(&mut out, SNAPSHOT_VERSION);
        push_u32(&mut out, d as u32);
        push_u32(&mut out, n as u32);
        push_u32(&mut out, r as u32);
        push_u32(&mut out, self.variant.to_u32());
        push_u32(&mut out, self.train_rank);
        push_u32(&mut out, self.refresh_rank);
        push_f64(&mut out, self.hypers.log_ell);
        push_f64(&mut out, self.hypers.log_sf2);
        push_f64(&mut out, self.hypers.log_sn2);
        for g in &self.cache.grids {
            push_f64(&mut out, g.min);
            push_f64(&mut out, g.h);
            push_u32(&mut out, g.m as u32);
        }
        for &a in &self.alpha {
            push_f64(&mut out, a);
        }
        for &v in &self.cache.mean {
            push_f64(&mut out, v);
        }
        for &v in &self.cache.var_r.data {
            push_f64(&mut out, v);
        }
        let sum = fnv1a(&out);
        push_u64(&mut out, sum);
        out
    }

    /// Decode from the version-1 byte layout.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor { bytes, pos: 0 };
        let magic = c.take(8)?;
        if magic != SNAPSHOT_MAGIC {
            return Err(Error::Snapshot("bad magic (not a skip-gp snapshot)".into()));
        }
        let version = c.u32()?;
        if version != SNAPSHOT_VERSION {
            return Err(Error::Snapshot(format!(
                "unsupported snapshot version {version} (this build reads {SNAPSHOT_VERSION})"
            )));
        }
        // Verify the trailing checksum before trusting any field.
        if bytes.len() < 8 {
            return Err(Error::Snapshot("truncated snapshot".into()));
        }
        let payload = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        let computed = fnv1a(payload);
        if stored != computed {
            return Err(Error::Snapshot(format!(
                "checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
            )));
        }
        let d = c.u32()? as usize;
        let n = c.u32()? as usize;
        let r = c.u32()? as usize;
        let variant = SnapshotVariant::from_u32(c.u32()?)?;
        let train_rank = c.u32()?;
        let refresh_rank = c.u32()?;
        let hypers = GpHypers {
            log_ell: c.f64()?,
            log_sf2: c.f64()?,
            log_sn2: c.f64()?,
        };
        let mut grids = Vec::with_capacity(d);
        for _ in 0..d {
            let min = c.f64()?;
            let h = c.f64()?;
            let m = c.u32()? as usize;
            if m < 4 {
                return Err(Error::Snapshot(format!("grid with m={m} < 4")));
            }
            grids.push(Grid1d { min, h, m });
        }
        let m_total = grids
            .iter()
            .try_fold(1usize, |acc, g| acc.checked_mul(g.m))
            .ok_or_else(|| Error::Snapshot("grid size overflow".into()))?;
        let mr = m_total
            .checked_mul(r)
            .ok_or_else(|| Error::Snapshot("variance cache size overflow".into()))?;
        let alpha = c.f64_vec(n)?;
        let mean = c.f64_vec(m_total)?;
        let var_data = c.f64_vec(mr)?;
        let var_r = if r == 0 {
            Matrix::zeros(m_total, 0)
        } else {
            Matrix::from_vec(m_total, r, var_data)
        };
        // Trailing checksum (8 bytes) must be exactly what remains.
        if c.remaining() != 8 {
            return Err(Error::Snapshot(format!(
                "trailing garbage: {} bytes after payload",
                c.remaining().saturating_sub(8)
            )));
        }
        let cache =
            PredictCache::from_parts(grids, mean, var_r, hypers.sf2(), hypers.sn2())?;
        Ok(ModelSnapshot {
            version,
            hypers,
            variant,
            train_rank,
            refresh_rank,
            alpha,
            cache,
        })
    }
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// FNV-1a over `bytes` — cheap corruption detection, not cryptography.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(len)
            .ok_or_else(|| Error::Snapshot("field length overflow".into()))?;
        if end > self.bytes.len() {
            return Err(Error::Snapshot("truncated snapshot".into()));
        }
        let s = &self.bytes[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_vec(&mut self, len: usize) -> Result<Vec<f64>> {
        let nbytes = len
            .checked_mul(8)
            .ok_or_else(|| Error::Snapshot("field length overflow".into()))?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn small_snapshot(seed: u64) -> ModelSnapshot {
        let mut rng = Rng::new(seed);
        let xs = Matrix::from_fn(40, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..40).map(|i| xs.get(i, 0).sin() + 0.01 * rng.normal()).collect();
        let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.8, 1.0, 0.05));
        gp.refresh().unwrap();
        ModelSnapshot::from_exact(
            &gp,
            &SnapshotConfig {
                grid_m: 16,
                variance: VarianceMode::Exact,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn bytes_roundtrip_bitwise() {
        let snap = small_snapshot(1);
        let bytes = snap.to_bytes();
        let back = ModelSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.version, SNAPSHOT_VERSION);
        assert_eq!(back.variant, SnapshotVariant::Exact);
        assert_eq!(back.hypers, snap.hypers);
        assert_eq!(back.alpha, snap.alpha);
        assert_eq!(back.cache.mean, snap.cache.mean);
        assert_eq!(back.cache.var_r.data, snap.cache.var_r.data);
        assert_eq!(back.cache.grids.len(), snap.cache.grids.len());
        for (a, b) in back.cache.grids.iter().zip(&snap.cache.grids) {
            assert_eq!(a.min, b.min);
            assert_eq!(a.h, b.h);
            assert_eq!(a.m, b.m);
        }
    }

    #[test]
    fn corruption_detected() {
        let snap = small_snapshot(2);
        let mut bytes = snap.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = ModelSnapshot::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn unknown_version_rejected() {
        let snap = small_snapshot(3);
        let mut bytes = snap.to_bytes();
        bytes[8] = 99; // version field, little-endian low byte
        let err = ModelSnapshot::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let snap = small_snapshot(4);
        let bytes = snap.to_bytes();
        let err = ModelSnapshot::from_bytes(&bytes[..bytes.len() - 17]).unwrap_err();
        // Either a length error or a checksum error, never a panic.
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn grid_budget_enforced() {
        let mut rng = Rng::new(5);
        let xs = Matrix::from_fn(30, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.8, 1.0, 0.1));
        gp.refresh().unwrap();
        let err = ModelSnapshot::from_exact(
            &gp,
            &SnapshotConfig {
                grid_m: 64,
                variance: VarianceMode::None,
                max_grid_cells: 1000,
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
    }
}
