//! Request batcher: coalesce concurrent predict requests into blocks.
//!
//! Serving cost per query is tiny (one stencil dot + a rank-r gemv, see
//! [`super::cache`]), so at high request rates the *dispatch* — channel
//! hops, thread wake-ups, per-call bookkeeping — dominates. The batcher
//! amortizes it: a worker drains the request queue into blocks of up to
//! `max_batch` points (waiting at most `max_wait` for stragglers once the
//! first request of a batch has arrived), pushes the whole n×t block
//! through [`ServeEngine::predict`] in one call, and fans the answers back
//! out over per-request channels. Under load the queue is never empty, so
//! batches fill instantly and `max_wait` only bounds the latency of a
//! lonely request on an idle server.
//!
//! Per-request latency (enqueue → response ready) is recorded into the
//! engine's [`Metrics`] latency histogram under `"serve.request"`, and the
//! realized batch sizes under `"serve.batch_size"` — the two numbers the
//! throughput bench reports.
//!
//! [`Metrics`]: crate::coordinator::Metrics

use super::server::ServeEngine;
use crate::linalg::Matrix;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Largest block a single [`ServeEngine::predict`] call may carry.
    pub max_batch: usize,
    /// How long the worker waits for stragglers after the first request
    /// of a batch arrives (zero ⇒ never wait; serve whatever is queued).
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
        }
    }
}

struct Request {
    x: Vec<f64>,
    enqueued: Instant,
    resp: Sender<PredictResponse>,
}

/// One served prediction plus its request-level accounting.
#[derive(Clone, Copy, Debug)]
pub struct PredictResponse {
    pub mean: f64,
    /// Latent predictive variance (add the snapshot's σ_n² for y-variance).
    pub var: f64,
    /// Enqueue → response-ready latency.
    pub latency: Duration,
    /// Size of the block this request was served in.
    pub batch_size: usize,
}

/// Cloneable submission endpoint; safe to hand to many client threads.
#[derive(Clone)]
pub struct BatchHandle {
    tx: Sender<Request>,
    dim: usize,
}

impl BatchHandle {
    /// Enqueue a query; the returned receiver yields the response when its
    /// batch completes. Submitting without immediately blocking lets a
    /// client keep a pipeline of outstanding requests.
    pub fn submit(&self, x: &[f64]) -> Receiver<PredictResponse> {
        assert_eq!(x.len(), self.dim, "query dimensionality mismatch");
        let (tx, rx) = channel();
        let req = Request {
            x: x.to_vec(),
            enqueued: Instant::now(),
            resp: tx,
        };
        // A send error means the batcher shut down; the receiver will
        // report it as a disconnect on recv.
        let _ = self.tx.send(req);
        rx
    }

    /// Submit and block for the answer.
    pub fn predict(&self, x: &[f64]) -> PredictResponse {
        self.submit(x)
            .recv()
            .expect("request batcher shut down while a request was in flight")
    }
}

/// The batching worker plus its submission side.
pub struct RequestBatcher {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    dim: usize,
}

impl RequestBatcher {
    /// Spawn the worker thread around `engine`.
    pub fn start(engine: Arc<ServeEngine>, cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        let (tx, rx) = channel::<Request>();
        let dim = engine.dim();
        let worker = std::thread::spawn(move || Self::run(engine, cfg, rx));
        RequestBatcher {
            tx: Some(tx),
            worker: Some(worker),
            dim,
        }
    }

    /// A new submission endpoint.
    pub fn handle(&self) -> BatchHandle {
        BatchHandle {
            tx: self.tx.as_ref().expect("batcher already shut down").clone(),
            dim: self.dim,
        }
    }

    /// Drop the submission side and join the worker. Outstanding handles
    /// keep the worker alive until they are dropped too; requests already
    /// queued are still served.
    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }

    fn run(engine: Arc<ServeEngine>, cfg: BatcherConfig, rx: Receiver<Request>) {
        let d = engine.dim();
        loop {
            // Block for the batch's first request.
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return, // all senders gone — clean shutdown
            };
            let mut batch = Vec::with_capacity(cfg.max_batch);
            batch.push(first);
            let deadline = Instant::now() + cfg.max_wait;
            while batch.len() < cfg.max_batch {
                match rx.try_recv() {
                    Ok(r) => batch.push(r),
                    Err(TryRecvError::Disconnected) => break,
                    Err(TryRecvError::Empty) => {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(r) => batch.push(r),
                            Err(RecvTimeoutError::Timeout)
                            | Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                }
            }

            let t = batch.len();
            let mut block = Matrix::zeros(t, d);
            for (i, r) in batch.iter().enumerate() {
                block.row_mut(i).copy_from_slice(&r.x);
            }
            let (means, vars) = engine.predict(&block);
            let done = Instant::now();
            let mut latencies = Vec::with_capacity(t);
            for (i, r) in batch.into_iter().enumerate() {
                let latency = done.saturating_duration_since(r.enqueued);
                latencies.push(latency.as_secs_f64());
                // A dropped receiver (client gone) is not an error.
                let _ = r.resp.send(PredictResponse {
                    mean: means[i],
                    var: vars[i],
                    latency,
                    batch_size: t,
                });
            }
            engine.metrics.record_latency_many("serve.request", &latencies);
            engine.metrics.observe("serve.batch_size", t as u64);
        }
    }
}

impl Drop for RequestBatcher {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
