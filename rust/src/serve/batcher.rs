//! Request batcher: coalesce concurrent predict *and observe* requests
//! into blocks.
//!
//! Serving cost per query is tiny (one stencil dot + a rank-r gemv, see
//! [`super::cache`]), so at high request rates the *dispatch* — channel
//! hops, thread wake-ups, per-call bookkeeping — dominates. The batcher
//! amortizes it: a worker drains the request queue into blocks of up to
//! `max_batch` requests (waiting at most `max_wait` for stragglers once
//! the first request of a batch has arrived), then serves the whole
//! block:
//!
//! - **observes first** — every observation in the block rides **one**
//!   [`ServeEngine::observe_block`] call (one extended α re-solve for the
//!   whole block, not one per point); derivative observations (D-SKI,
//!   `grad` payloads) are split into their own
//!   [`ServeEngine::observe_block_grads`] block so plain ingest stays
//!   bitwise untouched;
//! - **predicts second** — the remaining queries go through one
//!   [`ServeEngine::predict`] call and therefore see every observation
//!   coalesced into the same block.
//!
//! Under load the queue is never empty, so batches fill instantly and
//! `max_wait` only bounds the latency of a lonely request on an idle
//! server.
//!
//! Per-request latency (enqueue → response ready) is recorded into the
//! engine's [`Metrics`] latency histograms — predictions under
//! `"serve.request"`, ingests under `"stream.ingest"` (the p50/p99 the
//! streaming bench reports) — and the realized batch sizes under
//! `"serve.batch_size"` / `"stream.batch_size"`.
//!
//! [`Metrics`]: crate::coordinator::Metrics

use super::server::{ObserveAck, ServeEngine};
use crate::linalg::Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Largest request block the worker drains at once.
    pub max_batch: usize,
    /// How long the worker waits for stragglers after the first request
    /// of a batch arrives (zero ⇒ never wait; serve whatever is queued).
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
        }
    }
}

enum Request {
    Predict {
        /// Task the query addresses (0 for single-task models).
        task: usize,
        x: Vec<f64>,
        enqueued: Instant,
        resp: Sender<PredictResponse>,
    },
    Observe {
        /// Task the observation belongs to (0 for single-task models).
        task: usize,
        x: Vec<f64>,
        y: f64,
        /// Optional gradient observation ∇y (D-SKI); gradient-carrying
        /// requests ride their own ingest block.
        grad: Option<Vec<f64>>,
        enqueued: Instant,
        resp: Sender<ObserveResponse>,
    },
}

/// One served prediction plus its request-level accounting.
#[derive(Clone, Copy, Debug)]
pub struct PredictResponse {
    pub mean: f64,
    /// Latent predictive variance (add the snapshot's σ_n² for y-variance).
    pub var: f64,
    /// Enqueue → response-ready latency.
    pub latency: Duration,
    /// Number of predictions served in this request's block.
    pub batch_size: usize,
}

/// One acknowledged observation plus its request-level accounting.
#[derive(Clone, Debug)]
pub struct ObserveResponse {
    /// The per-observation ack, or the engine's refusal (e.g. a frozen
    /// snapshot with no live model behind it).
    pub result: Result<ObserveAck, String>,
    /// Enqueue → response-ready latency.
    pub latency: Duration,
    /// Number of observations coalesced into this request's ingest.
    pub batch_size: usize,
}

/// Cloneable submission endpoint; safe to hand to many client threads.
#[derive(Clone)]
pub struct BatchHandle {
    tx: Sender<Request>,
    dim: usize,
    depth: Arc<AtomicUsize>,
}

impl BatchHandle {
    /// Enqueue a query; the returned receiver yields the response when its
    /// batch completes. Submitting without immediately blocking lets a
    /// client keep a pipeline of outstanding requests.
    pub fn submit(&self, x: &[f64]) -> Receiver<PredictResponse> {
        self.submit_predict_task(0, x)
    }

    /// Enqueue a task-addressed query (task 0 on single-task models ≡
    /// [`submit`](Self::submit)). Task ids are validated by the wire
    /// front-ends; a row naming an out-of-range task answers NaN.
    pub fn submit_predict_task(&self, task: usize, x: &[f64]) -> Receiver<PredictResponse> {
        assert_eq!(x.len(), self.dim, "query dimensionality mismatch");
        let (tx, rx) = channel();
        let req = Request::Predict {
            task,
            x: x.to_vec(),
            enqueued: Instant::now(),
            resp: tx,
        };
        // A send error means the batcher shut down; the receiver will
        // report it as a disconnect on recv.
        self.depth.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(req).is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
        }
        rx
    }

    /// Submit and block for the answer.
    pub fn predict(&self, x: &[f64]) -> PredictResponse {
        self.submit(x)
            .recv()
            .expect("request batcher shut down while a request was in flight")
    }

    /// Submit a task-addressed query and block for the answer.
    pub fn predict_task(&self, task: usize, x: &[f64]) -> PredictResponse {
        self.submit_predict_task(task, x)
            .recv()
            .expect("request batcher shut down while a request was in flight")
    }

    /// Enqueue an observation `(x, y)`; coalesced with every other
    /// request in its block (one ingest solve for all of them).
    pub fn submit_observe(&self, x: &[f64], y: f64) -> Receiver<ObserveResponse> {
        self.submit_observe_task(0, x, y)
    }

    /// Enqueue a task-addressed observation (task 0 on single-task models
    /// ≡ [`submit_observe`](Self::submit_observe)); on a multi-task
    /// model, the first unseen task id enrolls a new task online.
    pub fn submit_observe_task(
        &self,
        task: usize,
        x: &[f64],
        y: f64,
    ) -> Receiver<ObserveResponse> {
        assert_eq!(x.len(), self.dim, "observation dimensionality mismatch");
        let (tx, rx) = channel();
        let req = Request::Observe {
            task,
            x: x.to_vec(),
            y,
            grad: None,
            enqueued: Instant::now(),
            resp: tx,
        };
        self.depth.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(req).is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
        }
        rx
    }

    /// Enqueue a derivative observation `(x, y, ∇y)` (D-SKI). Gradient
    /// requests coalesce with each other into one extended-row ingest;
    /// single-task only — the wire parser rejects `grad` on multi-task
    /// models before a request reaches the batcher.
    pub fn submit_observe_grad(
        &self,
        x: &[f64],
        y: f64,
        grad: &[f64],
    ) -> Receiver<ObserveResponse> {
        assert_eq!(x.len(), self.dim, "observation dimensionality mismatch");
        assert_eq!(grad.len(), self.dim, "gradient dimensionality mismatch");
        let (tx, rx) = channel();
        let req = Request::Observe {
            task: 0,
            x: x.to_vec(),
            y,
            grad: Some(grad.to_vec()),
            enqueued: Instant::now(),
            resp: tx,
        };
        self.depth.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(req).is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
        }
        rx
    }

    /// Submit an observation and block for the ack.
    pub fn observe(&self, x: &[f64], y: f64) -> ObserveResponse {
        self.submit_observe(x, y)
            .recv()
            .expect("request batcher shut down while an observation was in flight")
    }

    /// Submit a task-addressed observation and block for the ack.
    pub fn observe_task(&self, task: usize, x: &[f64], y: f64) -> ObserveResponse {
        self.submit_observe_task(task, x, y)
            .recv()
            .expect("request batcher shut down while an observation was in flight")
    }

    /// Submit a derivative observation and block for the ack.
    pub fn observe_grad(&self, x: &[f64], y: f64, grad: &[f64]) -> ObserveResponse {
        self.submit_observe_grad(x, y, grad)
            .recv()
            .expect("request batcher shut down while an observation was in flight")
    }

    /// Requests submitted but not yet drained into a batch — the shard
    /// queue depth the fleet router load-balances and reports on.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

/// The batching worker plus its submission side.
pub struct RequestBatcher {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    dim: usize,
    depth: Arc<AtomicUsize>,
}

impl RequestBatcher {
    /// Spawn the worker thread around `engine`.
    pub fn start(engine: Arc<ServeEngine>, cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        let (tx, rx) = channel::<Request>();
        let dim = engine.dim();
        let depth = Arc::new(AtomicUsize::new(0));
        let worker_depth = depth.clone();
        let worker = std::thread::spawn(move || Self::run(engine, cfg, rx, worker_depth));
        RequestBatcher {
            tx: Some(tx),
            worker: Some(worker),
            dim,
            depth,
        }
    }

    /// A new submission endpoint.
    pub fn handle(&self) -> BatchHandle {
        BatchHandle {
            tx: self.tx.as_ref().expect("batcher already shut down").clone(),
            dim: self.dim,
            depth: self.depth.clone(),
        }
    }

    /// Drop the submission side and join the worker. Outstanding handles
    /// keep the worker alive until they are dropped too; requests already
    /// queued are still served.
    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }

    fn run(
        engine: Arc<ServeEngine>,
        cfg: BatcherConfig,
        rx: Receiver<Request>,
        depth: Arc<AtomicUsize>,
    ) {
        let d = engine.dim();
        loop {
            // Block for the batch's first request.
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return, // all senders gone — clean shutdown
            };
            let mut batch = Vec::with_capacity(cfg.max_batch);
            batch.push(first);
            let deadline = Instant::now() + cfg.max_wait;
            while batch.len() < cfg.max_batch {
                match rx.try_recv() {
                    Ok(r) => batch.push(r),
                    Err(TryRecvError::Disconnected) => break,
                    Err(TryRecvError::Empty) => {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(r) => batch.push(r),
                            Err(RecvTimeoutError::Timeout)
                            | Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                }
            }

            // The drained requests leave the queue in one step; what is
            // left behind is the depth the stats loop reports (p99 via
            // the value histogram).
            let prev = depth.fetch_sub(batch.len(), Ordering::Relaxed);
            let waiting = prev.saturating_sub(batch.len());
            engine.metrics.observe("serve.queue_depth", waiting as u64);

            // Split the block: observations are folded into the model
            // first so the block's predictions see them. A block freely
            // coalesces requests across *tasks* (the task rides each
            // request); the engine — and therefore the model — is fixed
            // per batcher, so blocks never mix models.
            let mut observes = Vec::new();
            let mut grad_observes = Vec::new();
            let mut predicts = Vec::new();
            for r in batch {
                match r {
                    // Gradient-carrying observations ride their own
                    // extended-row ingest; plain observations keep the
                    // legacy block so pre-D-SKI behavior is untouched.
                    Request::Observe { x, y, grad: Some(g), enqueued, resp, .. } => {
                        grad_observes.push((x, y, g, enqueued, resp));
                    }
                    Request::Observe { task, x, y, grad: None, enqueued, resp } => {
                        observes.push((task, x, y, enqueued, resp));
                    }
                    Request::Predict { task, x, enqueued, resp } => {
                        predicts.push((task, x, enqueued, resp));
                    }
                }
            }
            let multi = engine.is_multitask();

            if !observes.is_empty() {
                let k = observes.len();
                let mut xs = Matrix::zeros(k, d);
                let mut ys = Vec::with_capacity(k);
                let mut tasks = Vec::with_capacity(k);
                for (i, (task, x, y, _, _)) in observes.iter().enumerate() {
                    xs.row_mut(i).copy_from_slice(x);
                    ys.push(*y);
                    tasks.push(*task);
                }
                // Multi-task models must be addressed by task; a task-0
                // block on a single-task model keeps the plain path so
                // pre-multi-task behavior is bitwise untouched. (A
                // nonzero task on a single-task engine reaches the typed
                // single-task refusal downstream.)
                let acks = if multi || tasks.iter().any(|&t| t != 0) {
                    engine.observe_block_tasks(&xs, &ys, &tasks)
                } else {
                    engine.observe_block(&xs, &ys)
                };
                let done = Instant::now();
                let mut latencies = Vec::with_capacity(k);
                for (i, (_, _, _, enqueued, resp)) in observes.into_iter().enumerate() {
                    let latency = done.saturating_duration_since(enqueued);
                    latencies.push(latency.as_secs_f64());
                    let result = match &acks {
                        Ok(a) => Ok(a[i]),
                        Err(e) => Err(e.to_string()),
                    };
                    // A dropped receiver (client gone) is not an error.
                    let _ = resp.send(ObserveResponse {
                        result,
                        latency,
                        batch_size: k,
                    });
                }
                engine.metrics.record_latency_many("stream.ingest", &latencies);
                engine.metrics.observe("stream.batch_size", k as u64);
            }

            if !grad_observes.is_empty() {
                let k = grad_observes.len();
                let mut xs = Matrix::zeros(k, d);
                let mut ys = Vec::with_capacity(k);
                let mut gs = Matrix::zeros(k, d);
                for (i, (x, y, g, _, _)) in grad_observes.iter().enumerate() {
                    xs.row_mut(i).copy_from_slice(x);
                    ys.push(*y);
                    gs.row_mut(i).copy_from_slice(g);
                }
                let acks = engine.observe_block_grads(&xs, &ys, &gs);
                let done = Instant::now();
                let mut latencies = Vec::with_capacity(k);
                for (i, (_, _, _, enqueued, resp)) in grad_observes.into_iter().enumerate() {
                    let latency = done.saturating_duration_since(enqueued);
                    latencies.push(latency.as_secs_f64());
                    let result = match &acks {
                        Ok(a) => Ok(a[i]),
                        Err(e) => Err(e.to_string()),
                    };
                    let _ = resp.send(ObserveResponse {
                        result,
                        latency,
                        batch_size: k,
                    });
                }
                engine.metrics.record_latency_many("stream.ingest", &latencies);
                engine.metrics.observe("stream.batch_size", k as u64);
            }

            if !predicts.is_empty() {
                let t = predicts.len();
                let mut block = Matrix::zeros(t, d);
                let mut tasks = Vec::with_capacity(t);
                for (i, (task, x, _, _)) in predicts.iter().enumerate() {
                    block.row_mut(i).copy_from_slice(x);
                    tasks.push(*task);
                }
                let (means, vars) = if multi || tasks.iter().any(|&t| t != 0) {
                    engine.predict_tasks(&block, &tasks)
                } else {
                    engine.predict(&block)
                };
                let done = Instant::now();
                let mut latencies = Vec::with_capacity(t);
                for (i, (_, _, enqueued, resp)) in predicts.into_iter().enumerate() {
                    let latency = done.saturating_duration_since(enqueued);
                    latencies.push(latency.as_secs_f64());
                    let _ = resp.send(PredictResponse {
                        mean: means[i],
                        var: vars[i],
                        latency,
                        batch_size: t,
                    });
                }
                engine.metrics.record_latency_many("serve.request", &latencies);
                engine.metrics.observe("serve.batch_size", t as u64);
            }
        }
    }
}

impl Drop for RequestBatcher {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
