//! The serving front-end: an in-process [`ServeEngine`] plus a
//! `std::net` TCP line-protocol server (`skip-gp serve`).
//!
//! The engine owns a loaded [`ModelSnapshot`] and a [`Metrics`] registry;
//! every prediction — one-at-a-time or batched — goes through
//! [`ServeEngine::predict`], which is where QPS counters and per-batch
//! timers accumulate. The TCP server accepts any number of concurrent
//! connections, forwards each request line into a shared
//! [`RequestBatcher`], and therefore coalesces traffic *across*
//! connections into blocks.
//!
//! # Wire protocol
//!
//! One request per line, whitespace-separated; one response line per
//! request (no HTTP — the offline build has no networking crates, and a
//! line protocol is trivially scriptable with `nc`):
//!
//! ```text
//! → predict <x1> <x2> … <xd>     (the word `predict` is optional)
//! ← ok <mean> <variance> <latency_us> <batch_size>
//! → ping                          ← ok pong
//! → dim                           ← ok <d>
//! → stats                         ← ok qps=… p50_us=… p99_us=… served=…
//! → quit                          (closes the connection)
//! ← err <message>                 (malformed input; connection stays open)
//! ```
//!
//! Floats are printed with Rust's shortest-round-trip formatting, so a
//! client parsing them back gets bit-identical values.

use super::batcher::{BatcherConfig, RequestBatcher};
use super::cache::PredictCache;
use super::snapshot::ModelSnapshot;
use crate::coordinator::Metrics;
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// In-process prediction engine over a loaded snapshot.
pub struct ServeEngine {
    snapshot: ModelSnapshot,
    /// QPS counters, per-batch timers, and the request-latency histogram
    /// (fed by the batcher).
    pub metrics: Metrics,
    started: Instant,
}

impl ServeEngine {
    /// Wrap a snapshot for serving. Requires a variance cache — a serving
    /// endpoint that silently returns no uncertainty is a footgun — and
    /// reports its absence as [`Error::Snapshot`] so CLI callers fail
    /// cleanly instead of panicking.
    pub fn new(snapshot: ModelSnapshot) -> Result<Self> {
        if !snapshot.cache.has_variance() {
            return Err(Error::Snapshot(
                "snapshot has no variance cache — rebuild with \
                 VarianceMode::Exact or VarianceMode::Lanczos (--var exact|lanczos)"
                    .into(),
            ));
        }
        Ok(ServeEngine {
            snapshot,
            metrics: Metrics::new(),
            started: Instant::now(),
        })
    }

    /// Input dimensionality d.
    pub fn dim(&self) -> usize {
        self.snapshot.cache.dim()
    }

    /// The underlying predictive cache.
    pub fn cache(&self) -> &PredictCache {
        &self.snapshot.cache
    }

    /// The snapshot being served.
    pub fn snapshot(&self) -> &ModelSnapshot {
        &self.snapshot
    }

    /// Serve a block of queries: (means, latent variances).
    pub fn predict(&self, xtest: &Matrix) -> (Vec<f64>, Vec<f64>) {
        let out = self
            .metrics
            .time("serve.predict_block", || self.snapshot.cache.predict(xtest));
        self.metrics.incr("serve.points", xtest.rows as u64);
        self.metrics.incr("serve.batches", 1);
        out
    }

    /// Points served per wall-clock second since the engine was created.
    pub fn lifetime_qps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        self.metrics.counter("serve.points") as f64 / secs
    }

    /// One-line human summary (the `stats` wire command).
    pub fn stats_line(&self) -> String {
        let lat = self.metrics.latency_snapshot("serve.request");
        format!(
            "qps={:.0} p50_us={:.1} p99_us={:.1} served={} batches={}",
            self.lifetime_qps(),
            lat.p50_s * 1e6,
            lat.p99_s * 1e6,
            self.metrics.counter("serve.points"),
            self.metrics.counter("serve.batches"),
        )
    }
}

/// TCP server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. `"127.0.0.1:7470"` (port 0 picks a free port).
    pub bind: String,
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            bind: "127.0.0.1:7470".to_string(),
            batcher: BatcherConfig::default(),
        }
    }
}

/// A running TCP serving endpoint.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    engine: Arc<ServeEngine>,
}

impl Server {
    /// Bind and start accepting connections. Each connection gets a
    /// handler thread; all handlers share one [`RequestBatcher`].
    pub fn start(engine: Arc<ServeEngine>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.bind)?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Config(format!("no local addr: {e}")))?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let eng = engine.clone();
        // Live-connection registry: handlers deregister (closing the
        // clone's fd) when their client hangs up; shutdown force-closes
        // whatever is left so no blocking read can outlive the server.
        let conn_reg: Arc<Mutex<Vec<(u64, TcpStream)>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = std::thread::spawn(move || {
            let batcher = RequestBatcher::start(eng.clone(), cfg.batcher);
            let mut next_id = 0u64;
            while !flag.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let id = next_id;
                        next_id += 1;
                        // Every served connection MUST be registered, or
                        // shutdown could wait forever on its blocking
                        // read. If the registry clone fails (fd
                        // exhaustion), reject the connection instead.
                        let clone = match stream.try_clone() {
                            Ok(c) => c,
                            Err(_) => continue, // drops `stream`, closing it
                        };
                        conn_reg.lock().unwrap().push((id, clone));
                        let handle = batcher.handle();
                        let engine = eng.clone();
                        let reg = conn_reg.clone();
                        std::thread::spawn(move || {
                            // Client errors only affect that client.
                            let _ = handle_connection(stream, handle, engine);
                            reg.lock().unwrap().retain(|(i, _)| *i != id);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            // Force-close anything still connected so every handler's
            // blocking read returns, its BatchHandle drops, and the
            // batcher Drop below can join its worker.
            for (_, c) in conn_reg.lock().unwrap().drain(..) {
                let _ = c.shutdown(Shutdown::Both);
            }
            // Dropping the batcher joins its worker once the last
            // connection handler releases its handle.
        });
        Ok(Server {
            addr,
            shutdown,
            accept: Some(accept),
            engine,
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The engine this server fronts.
    pub fn engine(&self) -> &Arc<ServeEngine> {
        &self.engine
    }

    /// Stop accepting and join the accept loop; still-open connections
    /// are force-closed so shutdown never waits on an idle client.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    handle: super::batcher::BatchHandle,
    engine: Arc<ServeEngine>,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let d = engine.dim();
    for line in reader.lines() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match trimmed {
            "quit" => break,
            "ping" => writeln!(writer, "ok pong")?,
            "dim" => writeln!(writer, "ok {d}")?,
            "stats" => writeln!(writer, "ok {}", engine.stats_line())?,
            _ => {
                let body = trimmed.strip_prefix("predict").unwrap_or(trimmed);
                let mut xs = Vec::with_capacity(d);
                let mut bad = None;
                for tok in body.split_whitespace() {
                    match tok.parse::<f64>() {
                        Ok(v) => xs.push(v),
                        Err(_) => {
                            bad = Some(tok.to_string());
                            break;
                        }
                    }
                }
                if let Some(tok) = bad {
                    writeln!(writer, "err not a number: '{tok}'")?;
                } else if xs.len() != d {
                    writeln!(writer, "err expected {d} coordinates, got {}", xs.len())?;
                } else {
                    let r = handle.predict(&xs);
                    writeln!(
                        writer,
                        "ok {} {} {:.1} {}",
                        r.mean,
                        r.var,
                        r.latency.as_secs_f64() * 1e6,
                        r.batch_size
                    )?;
                }
            }
        }
    }
    Ok(())
}
