//! The serving front-end: an in-process [`ServeEngine`] plus a
//! `std::net` TCP line-protocol server (`skip-gp serve`).
//!
//! The engine owns a published [`ModelSnapshot`] and a [`Metrics`]
//! registry; every prediction — one-at-a-time or batched — goes through
//! [`ServeEngine::predict`], which is where QPS counters and per-batch
//! timers accumulate. A **live** engine ([`ServeEngine::new_live`])
//! additionally owns a [`IncrementalState`] and accepts observations:
//! [`ServeEngine::observe_block`] ingests a block (one warm-started α
//! re-solve for all of it, see [`crate::stream`]) and republishes the
//! updated snapshot, so subsequent predictions reflect the new data. A
//! frozen engine ([`ServeEngine::new`]) refuses observations with a
//! typed error.
//!
//! The TCP server accepts any number of concurrent connections, forwards
//! each request line into a shared [`RequestBatcher`], and therefore
//! coalesces traffic *across* connections into blocks — observations
//! and predictions alike. Each connection gets its own handler thread,
//! which is simple and fine up to a few hundred clients; for large
//! connection counts, multiple models, or admission control, use the
//! bounded-worker fleet front-end in [`crate::serve::fleet`] instead.
//!
//! # Wire protocol
//!
//! One request per line, whitespace-separated; one response line per
//! request (no HTTP — the offline build has no networking crates, and a
//! line protocol is trivially scriptable with `nc`):
//!
//! ```text
//! → predict <x1> <x2> … <xd>     (the word `predict` is optional)
//! ← ok <mean> <variance> <latency_us> <batch_size>
//! → observe <x1> … <xd> <y>
//! → observe <x1> … <xd> <y> grad <g1> … <gd>   (D-SKI: value + gradient)
//! ← ok <seq> <n> <pending> <latency_us> <batch_size>
//! ← ok dup <n> <pending> <latency_us> <batch_size>   (bitwise duplicate)
//! → ping                          ← ok pong
//! → dim                           ← ok <d>
//! → tasks                         ← ok <num_tasks>   (1 for single-task)
//! → stats                         ← ok qps=… p50_us=… p99_us=… served=…
//! → quit                          (closes the connection)
//! ← err <message>                 (malformed input / frozen model;
//!                                  connection stays open)
//! ```
//!
//! The grammar is defined once, in [`crate::serve::protocol`] (see also
//! `docs/PROTOCOL.md`): this server, the fleet reactor, and the
//! `skip-gp observe` CLI client all parse and format through it, so
//! verbs and error wordings cannot drift between front-ends.
//!
//! **Multi-task models** (a snapshot with a task head, format v5) address
//! every query and observation at a task, so the leading token of the
//! request body is the task id:
//!
//! ```text
//! → predict <task> <x1> … <xd>        (task < num_tasks)
//! → observe <task> <x1> … <xd> <y>    (task == num_tasks enrolls a new
//!                                      task online, see crate::stream)
//! ```
//!
//! The plain forms on a multi-task model answer `err` naming the expected
//! shape; task ids are validated here at the wire (the batched
//! [`PredictResponse`](super::batcher::PredictResponse) carries no error
//! channel, and task counts only ever grow, so a task valid at parse time
//! stays valid at serve time).
//!
//! Floats are printed with Rust's shortest-round-trip formatting, so a
//! client parsing them back gets bit-identical values.

use super::batcher::{BatcherConfig, RequestBatcher};
use super::snapshot::ModelSnapshot;
use crate::coordinator::Metrics;
use crate::linalg::Matrix;
use crate::stream::{IncrementalState, RowOutcome};
use crate::util::parallel::par_map_range;
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-observation acknowledgement from [`ServeEngine::observe_block`].
#[derive(Clone, Copy, Debug)]
pub struct ObserveAck {
    /// Observation-log sequence number (0 for duplicates).
    pub seq: u64,
    /// The observation bitwise-duplicated a pending one and was dropped.
    pub duplicate: bool,
    /// Model size after the ingest.
    pub n: usize,
    /// Pending (un-refreshed) observations after the ingest.
    pub pending: usize,
    /// Whether this ingest escalated to a full refresh.
    pub refreshed: bool,
}

/// In-process prediction engine over a published snapshot, optionally
/// backed by a live incremental model.
pub struct ServeEngine {
    /// The published snapshot predictions are served from. Live engines
    /// republish it after every ingest.
    state: RwLock<ModelSnapshot>,
    /// The live model behind `observe` (None ⇒ frozen snapshot).
    stream: Option<Mutex<IncrementalState>>,
    dim: usize,
    /// QPS counters, per-batch timers, and the request-latency histograms
    /// (fed by the batcher).
    pub metrics: Metrics,
    started: Instant,
}

impl ServeEngine {
    /// Wrap a frozen snapshot for serving. Requires a variance cache — a
    /// serving endpoint that silently returns no uncertainty is a
    /// footgun — and reports its absence as [`Error::Snapshot`] so CLI
    /// callers fail cleanly instead of panicking.
    pub fn new(snapshot: ModelSnapshot) -> Result<Self> {
        if !snapshot.cache.has_variance() {
            return Err(Error::Snapshot(
                "snapshot has no variance cache — rebuild with \
                 VarianceMode::Exact or VarianceMode::Lanczos (--var exact|lanczos)"
                    .into(),
            ));
        }
        let dim = snapshot.cache.dim();
        Ok(ServeEngine {
            state: RwLock::new(snapshot),
            stream: None,
            dim,
            metrics: Metrics::new(),
            started: Instant::now(),
        })
    }

    /// Wrap a live incremental model: predictions come from its
    /// published snapshot, and `observe` requests ingest into it. The
    /// same variance-cache requirement as [`ServeEngine::new`] applies.
    pub fn new_live(live: IncrementalState) -> Result<Self> {
        if !live.cache().has_variance() {
            return Err(Error::Snapshot(
                "live model has no variance cache — use a StreamConfig \
                 with VarianceMode::Exact or VarianceMode::Lanczos"
                    .into(),
            ));
        }
        let dim = live.dim();
        let snapshot = live.to_snapshot();
        Ok(ServeEngine {
            state: RwLock::new(snapshot),
            stream: Some(Mutex::new(live)),
            dim,
            metrics: Metrics::new(),
            started: Instant::now(),
        })
    }

    /// Input dimensionality d.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// True iff this engine accepts observations.
    pub fn is_live(&self) -> bool {
        self.stream.is_some()
    }

    /// Number of tasks the published snapshot serves (1 for single-task
    /// models). Read per call — online enrollment grows it mid-serve.
    pub fn num_tasks(&self) -> usize {
        self.state.read().unwrap().num_tasks()
    }

    /// True iff the published snapshot carries a multi-task head.
    pub fn is_multitask(&self) -> bool {
        self.state.read().unwrap().is_multitask()
    }

    /// A clone of the currently-published snapshot (what a `predict`
    /// sees right now; includes the pending log on live engines).
    pub fn snapshot(&self) -> ModelSnapshot {
        self.state.read().unwrap().clone()
    }

    /// Serve a block of queries: (means, latent variances).
    pub fn predict(&self, xtest: &Matrix) -> (Vec<f64>, Vec<f64>) {
        let out = self.metrics.time("serve.predict_block", || {
            self.state.read().unwrap().cache.predict(xtest)
        });
        self.metrics.incr("serve.points", xtest.rows as u64);
        self.metrics.incr("serve.batches", 1);
        out
    }

    /// Serve a block of task-addressed queries: row `i` is answered from
    /// task `tasks[i]`'s cache. Per-row arithmetic is
    /// [`PredictCache::predict_one`](super::cache::PredictCache::predict_one),
    /// so a task-0 block agrees bitwise with [`ServeEngine::predict`].
    /// Rows naming an out-of-range task answer NaN — task ids are
    /// validated at the wire front-ends, and a misrouted row must not
    /// take down the batcher worker serving everyone else's block.
    pub fn predict_tasks(&self, xtest: &Matrix, tasks: &[usize]) -> (Vec<f64>, Vec<f64>) {
        assert_eq!(xtest.rows, tasks.len(), "one task id per query row");
        let out = self.metrics.time("serve.predict_block", || {
            let snap = self.state.read().unwrap();
            let rows = par_map_range(xtest.rows, 256, |i| match snap.task_cache(tasks[i]) {
                Some(c) => c.predict_one(xtest.row(i)),
                None => (f64::NAN, f64::NAN),
            });
            rows.into_iter().unzip()
        });
        self.metrics.incr("serve.points", xtest.rows as u64);
        self.metrics.incr("serve.batches", 1);
        out
    }

    /// Ingest a block of observations into the live model (one extended
    /// warm-started α re-solve for the whole block) and republish the
    /// serving snapshot. Frozen engines return [`Error::Stream`].
    ///
    /// Returns one [`ObserveAck`] per input row, in order.
    pub fn observe_block(&self, xs: &Matrix, ys: &[f64]) -> Result<Vec<ObserveAck>> {
        self.observe_inner(xs, ys, None, None)
    }

    /// Task-addressed [`observe_block`](Self::observe_block): row `i`
    /// belongs to task `tasks[i]`, and a row naming the first unseen task
    /// id enrolls it online (see
    /// [`IncrementalState::ingest_block_tasks`]).
    pub fn observe_block_tasks(
        &self,
        xs: &Matrix,
        ys: &[f64],
        tasks: &[usize],
    ) -> Result<Vec<ObserveAck>> {
        self.observe_inner(xs, ys, Some(tasks), None)
    }

    /// Derivative-carrying [`observe_block`](Self::observe_block): row `i`
    /// observes `(ys[i], ∇ys[i] = grads.row(i))`, and the ingest extends
    /// the operator with d gradient stencil rows per point (D-SKI, see
    /// [`IncrementalState::ingest_block_grads`]). Single-task only — the
    /// multi-task Hadamard operator has no extended derivative-row form.
    pub fn observe_block_grads(
        &self,
        xs: &Matrix,
        ys: &[f64],
        grads: &Matrix,
    ) -> Result<Vec<ObserveAck>> {
        self.observe_inner(xs, ys, None, Some(grads))
    }

    fn observe_inner(
        &self,
        xs: &Matrix,
        ys: &[f64],
        tasks: Option<&[usize]>,
        grads: Option<&Matrix>,
    ) -> Result<Vec<ObserveAck>> {
        let stream = self.stream.as_ref().ok_or_else(|| {
            Error::Stream(
                "this engine serves a frozen snapshot — observations need a \
                 live model (skip-gp serve --live); note a live model must \
                 be the KISS (grid) variant on a single-term dense grid — \
                 SKIP and sparse-grid multi-term snapshots stay frozen, \
                 single- and multi-task alike"
                    .into(),
            )
        })?;
        let report = self.metrics.time("stream.ingest_block", || {
            let mut live = stream.lock().unwrap();
            let report = match (tasks, grads) {
                (Some(t), None) => live.ingest_block_tasks(xs, ys, t)?,
                (None, Some(g)) => live.ingest_block_grads(xs, ys, g)?,
                (None, None) => live.ingest_block(xs, ys)?,
                (Some(_), Some(_)) => {
                    // No public entrypoint builds this combination; the
                    // wire parser rejects `grad` on multi-task models.
                    return Err(Error::Stream(
                        "gradient observations are single-task only — the \
                         multi-task Hadamard operator (K_ski ∘ K_task) has \
                         no extended derivative-row form"
                            .into(),
                    ));
                }
            };
            // Republish by value: `to_snapshot` clones α + both caches
            // (≈ M·(1+r) floats) once per coalesced block — simple and
            // lock-light (the write lock is held only for the swap, the
            // clone happens under the stream mutex predictions never
            // take). Revisit with structural sharing if M·r grows to
            // where the per-block memcpy shows up next to the solve.
            let snapshot = live.to_snapshot();
            *self.state.write().unwrap() = snapshot;
            Ok::<_, Error>(report)
        })?;

        // stream.* metrics: ingest effort, warm-start savings, and
        // cache patch-vs-rebuild accounting.
        self.metrics.incr("stream.points", report.accepted as u64);
        self.metrics.incr("stream.duplicates", report.duplicates as u64);
        self.metrics.incr("stream.batches", 1);
        if report.accepted > 0 {
            self.metrics
                .observe("stream.solve.iters", report.solve_iters as u64);
            self.metrics
                .observe("stream.solve.iters_saved", report.iters_saved as u64);
            self.metrics.incr("stream.cache.mean_patches", 1);
            self.metrics
                .incr("stream.cache.rows_patched", report.rows_patched as u64);
        }
        if report.enrolled > 0 {
            self.metrics.incr("stream.enrollments", report.enrolled as u64);
        }
        if report.var_rebuilt {
            self.metrics.incr("stream.cache.var_rebuilds", 1);
        }
        if report.refreshed.is_some() {
            self.metrics.incr("stream.refreshes", 1);
        }

        Ok(report
            .outcomes
            .iter()
            .map(|o| match *o {
                RowOutcome::Accepted { seq } => ObserveAck {
                    seq,
                    duplicate: false,
                    n: report.n,
                    pending: report.pending,
                    refreshed: report.refreshed.is_some(),
                },
                RowOutcome::Duplicate => ObserveAck {
                    seq: 0,
                    duplicate: true,
                    n: report.n,
                    pending: report.pending,
                    refreshed: false,
                },
            })
            .collect())
    }

    /// Persist the currently-published snapshot (live engines include
    /// their pending log — format v3).
    pub fn save_snapshot(&self, path: &std::path::Path) -> Result<()> {
        self.state.read().unwrap().save(path)
    }

    /// Points served per wall-clock second since the engine was created.
    pub fn lifetime_qps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        self.metrics.counter("serve.points") as f64 / secs
    }

    /// One-line human summary (the `stats` wire command).
    pub fn stats_line(&self) -> String {
        let lat = self.metrics.latency_snapshot("serve.request");
        let mut line = format!(
            "qps={:.0} p50_us={:.1} p99_us={:.1} served={} batches={}",
            self.lifetime_qps(),
            lat.p50_s * 1e6,
            lat.p99_s * 1e6,
            self.metrics.counter("serve.points"),
            self.metrics.counter("serve.batches"),
        );
        if self.is_live() {
            let ingest = self.metrics.latency_snapshot("stream.ingest");
            let (n, pending) = {
                let s = self.state.read().unwrap();
                (s.alpha.len(), s.pending.len())
            };
            line.push_str(&format!(
                " n={n} pending={pending} ingested={} ingest_p50_us={:.1} \
                 ingest_p99_us={:.1} refreshes={}",
                self.metrics.counter("stream.points"),
                ingest.p50_s * 1e6,
                ingest.p99_s * 1e6,
                self.metrics.counter("stream.refreshes"),
            ));
        }
        line
    }
}

/// TCP server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. `"127.0.0.1:7470"` (port 0 picks a free port).
    pub bind: String,
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            bind: "127.0.0.1:7470".to_string(),
            batcher: BatcherConfig::default(),
        }
    }
}

/// A running TCP serving endpoint.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    engine: Arc<ServeEngine>,
}

impl Server {
    /// Bind and start accepting connections. Each connection gets a
    /// handler thread; all handlers share one [`RequestBatcher`].
    pub fn start(engine: Arc<ServeEngine>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.bind)?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Config(format!("no local addr: {e}")))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let eng = engine.clone();
        // Live-connection registry: handlers deregister (closing the
        // clone's fd) when their client hangs up; shutdown force-closes
        // whatever is left so no blocking read can outlive the server.
        let conn_reg: Arc<Mutex<Vec<(u64, TcpStream)>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = std::thread::spawn(move || {
            let batcher = RequestBatcher::start(eng.clone(), cfg.batcher);
            let mut next_id = 0u64;
            let mut handlers: Vec<JoinHandle<()>> = Vec::new();
            // Accept blocks — no sleep-poll burning a core on an idle
            // server. Shutdown wakes it with a throwaway self-connection
            // after setting the flag, so the check below fires.
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if flag.load(Ordering::Relaxed) {
                            break; // the shutdown wake-connection
                        }
                        let id = next_id;
                        next_id += 1;
                        // Every served connection MUST be registered, or
                        // shutdown could wait forever on its blocking
                        // read. If the registry clone fails (fd
                        // exhaustion), reject the connection instead.
                        let clone = match stream.try_clone() {
                            Ok(c) => c,
                            Err(_) => continue, // drops `stream`, closing it
                        };
                        conn_reg.lock().unwrap().push((id, clone));
                        let handle = batcher.handle();
                        let engine = eng.clone();
                        let reg = conn_reg.clone();
                        handlers.push(std::thread::spawn(move || {
                            // Client errors only affect that client.
                            let _ = handle_connection(stream, handle, engine);
                            reg.lock().unwrap().retain(|(i, _)| *i != id);
                        }));
                        // Reap finished handlers so a long-lived server
                        // doesn't accumulate zombie JoinHandles.
                        let mut i = 0;
                        while i < handlers.len() {
                            if handlers[i].is_finished() {
                                let _ = handlers.swap_remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
            // Force-close anything still connected so every handler's
            // blocking read returns EOF…
            for (_, c) in conn_reg.lock().unwrap().drain(..) {
                let _ = c.shutdown(Shutdown::Both);
            }
            // …then join every handler: when the accept thread exits, no
            // connection thread is left running (the old code leaked
            // them, so a handler mid-request could outlive `shutdown()`).
            for h in handlers {
                let _ = h.join();
            }
            // Dropping the batcher joins its worker once the last
            // connection handler releases its handle.
        });
        Ok(Server {
            addr,
            shutdown,
            accept: Some(accept),
            engine,
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The engine this server fronts.
    pub fn engine(&self) -> &Arc<ServeEngine> {
        &self.engine
    }

    fn stop_impl(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(a) = self.accept.take() {
            // Wake the blocking accept so it observes the flag.
            let _ = TcpStream::connect_timeout(&wake_addr(self.addr), Duration::from_millis(500));
            let _ = a.join();
        }
    }

    /// Stop accepting, force-close still-open connections, and join the
    /// accept loop *and every connection handler* — after this returns,
    /// no server thread is running.
    pub fn shutdown(mut self) {
        self.stop_impl();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

/// Where a shutdown wake-connection should dial: the bound address, with
/// unspecified IPs (`0.0.0.0` / `::`) rewritten to the same-family
/// loopback so the connect actually reaches our listener.
pub(crate) fn wake_addr(mut addr: SocketAddr) -> SocketAddr {
    match addr {
        SocketAddr::V4(v4) if v4.ip().is_unspecified() => {
            addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        SocketAddr::V6(v6) if v6.ip().is_unspecified() => {
            addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST));
        }
        _ => {}
    }
    addr
}

fn handle_connection(
    stream: TcpStream,
    handle: super::batcher::BatchHandle,
    engine: Arc<ServeEngine>,
) -> std::io::Result<()> {
    use super::protocol::{self, ModelShape, Request, Response};
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let d = engine.dim();
    for line in reader.lines() {
        let line = line?;
        // Shape is re-read per request: online enrollment grows the task
        // count mid-connection.
        let shape = ModelShape {
            dim: d,
            num_tasks: engine.num_tasks(),
            multitask: engine.is_multitask(),
        };
        let req = match protocol::parse_request(&line, &shape, false) {
            Ok(None) => continue, // blank line
            Ok(Some(req)) => req,
            Err(msg) => {
                writeln!(writer, "{}", Response::Error(msg).format())?;
                continue;
            }
        };
        let resp = match req {
            Request::Quit => break,
            Request::Ping => Response::Pong,
            Request::Dim => Response::Dim(d),
            Request::Tasks => Response::Tasks(engine.num_tasks()),
            Request::Stats => Response::Stats(engine.stats_line()),
            // `models` is a fleet-only verb: with `models_verb = false`
            // the parser routes the token through the predict parse,
            // which errors — this arm cannot be reached.
            Request::Models => unreachable!("models verb disabled on the legacy server"),
            Request::Observe(o) => Response::Observe(match &o.grad {
                // The parser rejects `grad` on multi-task models, so a
                // gradient-carrying request is always task 0.
                Some(g) => handle.observe_grad(&o.x, o.y, g),
                None => handle.observe_task(o.task, &o.x, o.y),
            }),
            Request::Predict(p) => Response::Predict(handle.predict_task(p.task, &p.x)),
        };
        writeln!(writer, "{}", resp.format())?;
    }
    Ok(())
}
