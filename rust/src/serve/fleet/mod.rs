//! Sharded multi-model serving plane (`skip-gp serve --fleet`).
//!
//! Three layers, each independently testable:
//!
//! - [`router`] — one logical model as k replica shards, each with a
//!   private engine + batcher; spatial (local-expert) query placement.
//! - [`registry`] — many models resident at once, lazily loaded from a
//!   snapshot directory, LRU-evicted under a memory budget; live and
//!   frozen models coexist (live ones pinned).
//! - [`reactor`] — a bounded worker pool with a readiness-style
//!   multiplexing loop, admission control (`busy` backpressure), and
//!   two-phase graceful shutdown, replacing thread-per-connection.
//!
//! Replica shards hold bitwise-identical caches, so sharding changes
//! *where* a query is computed but never *what* it returns — the
//! equivalence tests assert bitwise-equal predictions at k ∈ {1, 2, 8}.

pub mod reactor;
pub mod registry;
pub mod router;

pub use reactor::{FleetConfig, FleetServer};
pub use registry::{ModelRegistry, RegistryConfig};
pub use router::{RoutePolicy, ShardedModel};
