//! Readiness-loop front-end: bounded workers, admission control,
//! graceful drain.
//!
//! The legacy [`Server`](crate::serve::server::Server) spawns one thread
//! per connection — fine for a handful of clients, fatal for 10⁴ (tens of
//! gigabytes of stacks, scheduler collapse). The fleet reactor replaces
//! it with a fixed pool: a **blocking accept loop** hands sockets
//! round-robin to N **worker threads**, each multiplexing its share of
//! connections with nonblocking reads/writes. Thread count is set by
//! [`FleetConfig::workers`], not by client count.
//!
//! The crate has no dependencies (no epoll binding), so readiness is
//! polled: a worker sweeps its connections and, when a full sweep makes
//! no progress, parks briefly ([`std::thread::park_timeout`]) instead of
//! spinning; the accept loop unparks it when new work arrives. That
//! trades a sub-millisecond idle-wakeup for zero unsafe code and zero
//! platform surface.
//!
//! **Admission control**: at most [`FleetConfig::max_inflight`] requests
//! may be queued across the fleet. Past that, requests are answered with
//! a `busy …` line immediately — clients get backpressure they can see,
//! instead of latency they can't explain. Connection count is likewise
//! capped ([`FleetConfig::max_conns`]).
//!
//! **Shutdown** is two-phase: *drain* (stop reading, finish every
//! admitted request, flush replies, bounded by [`FleetConfig::grace`]),
//! then *hard stop* (close whatever is left, join every thread). No
//! connection handler can outlive the server — the regression tests hold
//! open idle connections through a shutdown to prove it.
//!
//! The wire protocol is the legacy one plus multi-model addressing:
//!
//! ```text
//! → [model <id>] predict <x1> … <xd>      (per-request model choice)
//! → [model <id>] observe <x1> … <xd> <y>
//! → [model <id>] predict <task> <x1> … <xd>      (multi-task models)
//! → [model <id>] observe <task> <x1> … <xd> <y>  (task == num_tasks
//!                                                 enrolls a new task)
//! → [model <id>] dim
//! → [model <id>] tasks                     ← ok <num_tasks>
//! → models                                 ← ok <id> <id> …
//! → stats                                  ← ok fleet models=… | <id>: …
//! ← busy <limit> requests in flight, retry later
//! ```
//!
//! Multi-task requests follow the same rules as the legacy server
//! ([`crate::serve::server`]): the task id leads the body, plain forms
//! on a multi-task model answer `err` naming the expected shape, and
//! task validation happens here at the wire. A block coalesces requests
//! across the tasks of one model — never across models, since every
//! shard batcher is pinned to its model.
//!
//! Responses come back **in request order per connection** (pipelining
//! is safe); different connections never wait on each other's batches.

use super::registry::ModelRegistry;
use super::router::ShardedModel;
use crate::coordinator::Metrics;
use crate::serve::batcher::{ObserveResponse, PredictResponse};
use crate::serve::protocol::{self, ModelShape, Response, Verb};
use crate::serve::server::wake_addr;
use crate::{Error, Result};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Reactor policy.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Bind address (port 0 picks a free port).
    pub bind: String,
    /// Worker threads multiplexing connections (0 = derive from
    /// available parallelism, clamped to 2..=16).
    pub workers: usize,
    /// Most requests admitted fleet-wide at once; excess get `busy`
    /// (0 = unlimited).
    pub max_inflight: usize,
    /// Most connections held open at once; excess are told `busy` and
    /// closed (0 = unlimited).
    pub max_conns: usize,
    /// How long shutdown waits for in-flight work to drain before
    /// force-closing.
    pub grace: Duration,
    /// Model served when a request has no `model <id>` prefix.
    pub default_model: Option<String>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            bind: "127.0.0.1:7471".to_string(),
            workers: 0,
            max_inflight: 1024,
            max_conns: 16384,
            grace: Duration::from_millis(500),
            default_model: None,
        }
    }
}

/// State shared by the accept loop and every worker.
struct Shared {
    registry: Arc<ModelRegistry>,
    metrics: Arc<Metrics>,
    inflight: AtomicUsize,
    max_inflight: usize,
    max_conns: usize,
    draining: AtomicBool,
    shutdown: AtomicBool,
    conns: AtomicUsize,
    default_model: Option<String>,
}

impl Shared {
    /// Try to claim an in-flight slot; false means the caller must send
    /// the `busy` line instead of submitting.
    fn admit(&self) -> bool {
        if self.max_inflight == 0 {
            let now = self.inflight.fetch_add(1, Ordering::Relaxed) + 1;
            self.metrics.observe("serve.fleet.inflight", now as u64);
            self.metrics.incr("serve.fleet.requests", 1);
            return true;
        }
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_inflight {
                return false;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.metrics.observe("serve.fleet.inflight", (cur + 1) as u64);
                    self.metrics.incr("serve.fleet.requests", 1);
                    return true;
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Account a rejection and produce the wire `busy` line.
    fn reject(&self) -> String {
        self.metrics.incr("serve.fleet.rejected", 1);
        Response::Busy { limit: self.max_inflight }.format()
    }

    fn dec_inflight(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Resolve the model a request addresses (explicit `model <id>`
    /// prefix, else the configured default). Errors are wire messages.
    fn resolve(
        &self,
        explicit: Option<&str>,
    ) -> std::result::Result<Arc<ShardedModel>, String> {
        let id = match explicit {
            Some(id) => id,
            None => match &self.default_model {
                Some(id) => id.as_str(),
                None => {
                    return Err("no model specified — use: model <id> <verb> …".to_string())
                }
            },
        };
        self.registry.get(id).map_err(|e| e.to_string())
    }

    /// The fleet `stats` line: reactor counters plus one fragment per
    /// resident model.
    fn stats_line(&self) -> String {
        let mut line = format!(
            "fleet models={} conns={} inflight={} routed={} rejected={}",
            self.registry.len(),
            self.conns.load(Ordering::Relaxed),
            self.inflight.load(Ordering::Relaxed),
            self.metrics.counter("serve.fleet.requests"),
            self.metrics.counter("serve.fleet.rejected"),
        );
        for frag in self.registry.stats_fragments() {
            line.push_str(" | ");
            line.push_str(&frag);
        }
        line
    }
}

/// Hard cap on a single request line; past it the connection is closed
/// (a client streaming garbage must not grow our buffers unboundedly).
const MAX_LINE: usize = 64 * 1024;

/// A response slot in a connection's FIFO. Replies go out strictly in
/// request order even though shards complete out of order.
enum Pending {
    /// Already-formatted line (errors, ping, stats, busy, …).
    Ready(String),
    /// A prediction in flight on some shard.
    Predict(Receiver<PredictResponse>),
    /// An observation in flight on shard 0.
    Observe(Receiver<ObserveResponse>),
}

/// Per-connection state owned by exactly one worker (no locking).
struct Conn {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    pending: VecDeque<Pending>,
    closing: bool,
}

impl Conn {
    fn push_ready(&mut self, line: String) {
        self.pending.push_back(Pending::Ready(line));
    }
}

enum Status {
    /// Did something; sweep again soon.
    Progress,
    /// Nothing to do right now.
    Idle,
    /// Finished (client gone, `quit`, fatal error, or fully drained).
    Done,
}

/// Parse one request line and either queue a `Ready` reply or submit to
/// a shard (after passing admission control). Classification and body
/// parsing both live in [`crate::serve::protocol`]; this function only
/// interleaves them with model resolution (resolution errors precede
/// parse errors, exactly as before the shared parser existed).
fn handle_line(line: &str, c: &mut Conn, shared: &Shared) {
    // Optional multi-model prefix: `model <id> <verb> …`.
    let (explicit, rest) = match protocol::split_model_prefix(line) {
        Ok(split) => split,
        Err(msg) => {
            c.push_ready(Response::Error(msg).format());
            return;
        }
    };
    match protocol::classify(rest, true) {
        Verb::Empty => {}
        Verb::Quit => c.closing = true,
        Verb::Ping => c.push_ready(Response::Pong.format()),
        Verb::Models => {
            c.push_ready(Response::Models(shared.registry.available()).format())
        }
        Verb::Stats => c.push_ready(Response::Stats(shared.stats_line()).format()),
        Verb::Dim => match shared.resolve(explicit) {
            Ok(m) => c.push_ready(Response::Dim(m.dim()).format()),
            Err(msg) => c.push_ready(Response::Error(msg).format()),
        },
        Verb::Tasks => match shared.resolve(explicit) {
            Ok(m) => c.push_ready(Response::Tasks(m.num_tasks()).format()),
            Err(msg) => c.push_ready(Response::Error(msg).format()),
        },
        Verb::Observe(body) => {
            let model = match shared.resolve(explicit) {
                Ok(m) => m,
                Err(msg) => {
                    c.push_ready(Response::Error(msg).format());
                    return;
                }
            };
            let shape = ModelShape {
                dim: model.dim(),
                num_tasks: model.num_tasks(),
                multitask: model.is_multitask(),
            };
            match protocol::parse_observe(body, &shape) {
                Err(msg) => c.push_ready(Response::Error(msg).format()),
                Ok(o) => {
                    if !shared.admit() {
                        c.push_ready(shared.reject());
                        return;
                    }
                    let rx = match &o.grad {
                        Some(g) => model.submit_observe_grad(&o.x, o.y, g),
                        None => model.submit_observe_task(o.task, &o.x, o.y),
                    };
                    c.pending.push_back(Pending::Observe(rx));
                }
            }
        }
        Verb::Predict(body) => {
            let model = match shared.resolve(explicit) {
                Ok(m) => m,
                Err(msg) => {
                    c.push_ready(Response::Error(msg).format());
                    return;
                }
            };
            let shape = ModelShape {
                dim: model.dim(),
                num_tasks: model.num_tasks(),
                multitask: model.is_multitask(),
            };
            match protocol::parse_predict(body, &shape) {
                Err(msg) => c.push_ready(Response::Error(msg).format()),
                Ok(p) => {
                    if !shared.admit() {
                        c.push_ready(shared.reject());
                        return;
                    }
                    let rx = model.submit_predict_task(p.task, &p.x);
                    c.pending.push_back(Pending::Predict(rx));
                }
            }
        }
    }
}

/// One nonblocking sweep over a connection: harvest completed responses
/// (strictly FIFO), flush output, read and parse new requests.
fn service_conn(c: &mut Conn, shared: &Shared, draining: bool) -> Status {
    let mut progress = false;

    // 1. Harvest whatever is ready at the FIFO head.
    enum Step {
        Stop,
        Emit { line: String, dec: bool },
    }
    loop {
        let step = match c.pending.front_mut() {
            None => Step::Stop,
            Some(Pending::Ready(s)) => Step::Emit { line: std::mem::take(s), dec: false },
            Some(Pending::Predict(rx)) => match rx.try_recv() {
                Ok(r) => Step::Emit { line: Response::Predict(r).format(), dec: true },
                Err(TryRecvError::Empty) => Step::Stop,
                Err(TryRecvError::Disconnected) => Step::Emit {
                    line: "err shard unavailable".to_string(),
                    dec: true,
                },
            },
            Some(Pending::Observe(rx)) => match rx.try_recv() {
                Ok(r) => Step::Emit { line: Response::Observe(r).format(), dec: true },
                Err(TryRecvError::Empty) => Step::Stop,
                Err(TryRecvError::Disconnected) => Step::Emit {
                    line: "err shard unavailable".to_string(),
                    dec: true,
                },
            },
        };
        match step {
            Step::Stop => break,
            Step::Emit { line, dec } => {
                c.pending.pop_front();
                if dec {
                    shared.dec_inflight();
                }
                c.outbuf.extend_from_slice(line.as_bytes());
                c.outbuf.push(b'\n');
                progress = true;
            }
        }
    }

    // 2. Flush buffered replies.
    while !c.outbuf.is_empty() {
        match c.stream.write(&c.outbuf) {
            Ok(0) => return Status::Done,
            Ok(n) => {
                c.outbuf.drain(..n);
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return Status::Done,
        }
    }

    // 3. Read new requests — unless draining (shutdown stops *reading*,
    // never answering) or the client already said quit.
    if !draining && !c.closing {
        let mut buf = [0u8; 4096];
        match c.stream.read(&mut buf) {
            Ok(0) => return Status::Done, // EOF
            Ok(n) => {
                c.inbuf.extend_from_slice(&buf[..n]);
                progress = true;
                while let Some(pos) = c.inbuf.iter().position(|&b| b == b'\n') {
                    let raw: Vec<u8> = c.inbuf.drain(..=pos).collect();
                    let line = String::from_utf8_lossy(&raw[..raw.len() - 1]).into_owned();
                    handle_line(&line, c, shared);
                    if c.closing {
                        break;
                    }
                }
                if c.inbuf.len() > MAX_LINE {
                    c.push_ready(
                        Response::Error(format!("request line exceeds {MAX_LINE} bytes"))
                            .format(),
                    );
                    c.closing = true;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return Status::Done,
        }
    }

    if (c.closing || draining) && c.pending.is_empty() && c.outbuf.is_empty() {
        return Status::Done;
    }
    if progress {
        Status::Progress
    } else {
        Status::Idle
    }
}

/// Release a connection: free its admission slots (responses that will
/// never be delivered), close the socket, account it.
fn close_conn(c: &mut Conn, shared: &Shared) {
    let abandoned = c
        .pending
        .iter()
        .filter(|p| !matches!(p, Pending::Ready(_)))
        .count();
    for _ in 0..abandoned {
        shared.dec_inflight();
    }
    c.pending.clear();
    let _ = c.stream.shutdown(Shutdown::Both);
    shared.conns.fetch_sub(1, Ordering::Relaxed);
    shared.metrics.incr("serve.fleet.conns_closed", 1);
}

/// Hand-off mailbox from the accept loop to one worker.
type Inbox = Arc<Mutex<Vec<TcpStream>>>;

fn worker_loop(shared: Arc<Shared>, inbox: Inbox) {
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        // Adopt connections the accept loop handed over.
        for stream in inbox.lock().unwrap().drain(..) {
            if stream.set_nonblocking(true).is_err() {
                shared.conns.fetch_sub(1, Ordering::Relaxed);
                continue;
            }
            stream.set_nodelay(true).ok();
            conns.push(Conn {
                stream,
                inbuf: Vec::new(),
                outbuf: Vec::new(),
                pending: VecDeque::new(),
                closing: false,
            });
        }

        if shared.shutdown.load(Ordering::Relaxed) {
            // Hard stop: the accept loop was joined before this flag was
            // set, so the inbox cannot refill behind us.
            for mut c in conns.drain(..) {
                close_conn(&mut c, &shared);
            }
            for stream in inbox.lock().unwrap().drain(..) {
                let mut c = Conn {
                    stream,
                    inbuf: Vec::new(),
                    outbuf: Vec::new(),
                    pending: VecDeque::new(),
                    closing: false,
                };
                close_conn(&mut c, &shared);
            }
            return;
        }

        let draining = shared.draining.load(Ordering::Relaxed);
        let mut progress = false;
        let mut i = 0;
        while i < conns.len() {
            match service_conn(&mut conns[i], &shared, draining) {
                Status::Done => {
                    let mut c = conns.swap_remove(i);
                    close_conn(&mut c, &shared);
                    progress = true;
                }
                Status::Progress => {
                    progress = true;
                    i += 1;
                }
                Status::Idle => i += 1,
            }
        }
        if !progress {
            // Nothing ready anywhere: park briefly. The accept loop (new
            // connection) and shutdown both unpark us; batch completions
            // are picked up on the next sweep.
            std::thread::park_timeout(Duration::from_micros(500));
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    inboxes: Vec<Inbox>,
    workers: Vec<std::thread::Thread>,
) {
    let mut rr = 0usize;
    loop {
        match listener.accept() {
            Ok((mut stream, _)) => {
                if shared.draining.load(Ordering::Relaxed)
                    || shared.shutdown.load(Ordering::Relaxed)
                {
                    // The shutdown wake-connection (or a late client).
                    break;
                }
                if shared.max_conns > 0
                    && shared.conns.load(Ordering::Relaxed) >= shared.max_conns
                {
                    let _ = stream.write_all(b"busy connection limit reached\n");
                    let _ = stream.shutdown(Shutdown::Both);
                    shared.metrics.incr("serve.fleet.conns_rejected", 1);
                    continue;
                }
                shared.conns.fetch_add(1, Ordering::Relaxed);
                shared.metrics.incr("serve.fleet.conns", 1);
                inboxes[rr % inboxes.len()].lock().unwrap().push(stream);
                workers[rr % workers.len()].unpark();
                rr = rr.wrapping_add(1);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
}

/// A running fleet endpoint: one accept thread, N workers, a registry.
pub struct FleetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    registry: Arc<ModelRegistry>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    worker_threads: Vec<std::thread::Thread>,
    grace: Duration,
}

impl FleetServer {
    /// Bind and start the reactor over `registry`.
    pub fn start(registry: Arc<ModelRegistry>, cfg: FleetConfig) -> Result<FleetServer> {
        let listener = TcpListener::bind(&cfg.bind)?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Config(format!("no local addr: {e}")))?;
        let w = if cfg.workers > 0 {
            cfg.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 16)
        };
        let shared = Arc::new(Shared {
            registry: registry.clone(),
            metrics: registry.metrics().clone(),
            inflight: AtomicUsize::new(0),
            max_inflight: cfg.max_inflight,
            max_conns: cfg.max_conns,
            draining: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            default_model: cfg.default_model.clone(),
        });
        let mut workers = Vec::with_capacity(w);
        let mut worker_threads = Vec::with_capacity(w);
        let mut inboxes = Vec::with_capacity(w);
        for _ in 0..w {
            let inbox: Inbox = Arc::new(Mutex::new(Vec::new()));
            let s = shared.clone();
            let ib = inbox.clone();
            let h = std::thread::spawn(move || worker_loop(s, ib));
            worker_threads.push(h.thread().clone());
            workers.push(h);
            inboxes.push(inbox);
        }
        let s = shared.clone();
        let wt = worker_threads.clone();
        let accept = std::thread::spawn(move || accept_loop(listener, s, inboxes, wt));
        Ok(FleetServer {
            addr,
            shared,
            registry,
            accept: Some(accept),
            workers,
            worker_threads,
            grace: cfg.grace,
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The registry this fleet serves from.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Connections currently open.
    pub fn conn_count(&self) -> usize {
        self.shared.conns.load(Ordering::Relaxed)
    }

    /// The fleet `stats` line (what the wire `stats` verb returns).
    pub fn stats_line(&self) -> String {
        self.shared.stats_line()
    }

    fn stop_impl(&mut self) {
        if self.accept.is_none() && self.workers.is_empty() {
            return;
        }
        // Phase 1 — drain: stop reading new requests, keep answering the
        // admitted ones. Wake the blocking accept with a throwaway
        // connection so it observes the flag and exits.
        self.shared.draining.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect_timeout(&wake_addr(self.addr), Duration::from_millis(500));
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let deadline = Instant::now() + self.grace;
        while self.shared.conns.load(Ordering::Relaxed) > 0 && Instant::now() < deadline {
            for t in &self.worker_threads {
                t.unpark();
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // Phase 2 — hard stop: workers close whatever outlived the grace
        // period, then exit; join them all.
        self.shared.shutdown.store(true, Ordering::Relaxed);
        for t in &self.worker_threads {
            t.unpark();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.worker_threads.clear();
    }

    /// Drain in-flight work (bounded by the grace period), close every
    /// connection, and join every thread.
    pub fn shutdown(mut self) {
        self.stop_impl();
    }
}

impl Drop for FleetServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::RegistryConfig;
    use super::*;

    fn test_shared(max_inflight: usize) -> Shared {
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(ModelRegistry::new(RegistryConfig::default(), metrics.clone()));
        Shared {
            registry,
            metrics,
            inflight: AtomicUsize::new(0),
            max_inflight,
            max_conns: 0,
            draining: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            default_model: None,
        }
    }

    #[test]
    fn admission_control_caps_inflight() {
        let shared = test_shared(2);
        assert!(shared.admit());
        assert!(shared.admit());
        assert!(!shared.admit());
        let busy = shared.reject();
        assert!(busy.starts_with("busy 2 "), "{busy}");
        shared.dec_inflight();
        assert!(shared.admit());
        assert_eq!(shared.metrics.counter("serve.fleet.rejected"), 1);
        assert_eq!(shared.metrics.counter("serve.fleet.requests"), 3);
    }

    #[test]
    fn unaddressed_request_without_default_is_an_error() {
        let shared = test_shared(0);
        let err = shared.resolve(None).unwrap_err();
        assert!(err.contains("no model specified"), "{err}");
        let err = shared.resolve(Some("ghost")).unwrap_err();
        assert!(err.contains("unknown model"), "{err}");
    }
}
