//! Shard router: one logical model served by k independent engines.
//!
//! Each shard is a full replica of the same [`ModelSnapshot`] behind its
//! own [`ServeEngine`] **and its own** [`RequestBatcher`], so shards
//! never contend on one queue (no shared mutex, no shared channel on the
//! hot path). Because replicas hold bitwise-identical caches and the
//! cache's per-point arithmetic is deterministic, routing is a pure
//! load-placement decision: predictions are bitwise identical at any
//! shard count.
//!
//! Placement follows the local-expert idea from the KISS-GP line of work
//! (Wilson & Nickisch, 2015): partition input space with the
//! [`crate::gp::cluster`] k-means ([`spatial_centroids`]) and send each
//! query to the shard owning its region, so a shard's working set (cache
//! pages, stencil neighborhoods) stays spatially coherent. When the
//! model's grid bounding box is degenerate the router falls back to an
//! FNV hash of the query bytes.
//!
//! Live (observation-accepting) models are deliberately single-shard:
//! replicated incremental state would need cross-shard write fan-out,
//! which is exactly the contention sharding exists to remove.
//!
//! Multi-task models shard exactly like single-task ones: every replica
//! carries the full per-task cache set (snapshot format v5), so the task
//! id never enters the routing decision — placement stays purely
//! spatial, and per-task predictions are bitwise identical at any shard
//! count. Observations (including online task enrollment) still pin to
//! shard 0.

use crate::coordinator::Metrics;
use crate::gp::cluster::{nearest_centroid, spatial_centroids};
use crate::linalg::Matrix;
use crate::serve::batcher::{
    BatchHandle, BatcherConfig, ObserveResponse, PredictResponse, RequestBatcher,
};
use crate::serve::server::ServeEngine;
use crate::serve::snapshot::ModelSnapshot;
use crate::stream::IncrementalState;
use crate::util::Rng;
use crate::{Error, Result};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// How a query picks its shard.
#[derive(Clone, Debug)]
pub enum RoutePolicy {
    /// One shard: everything goes to shard 0.
    Single,
    /// Nearest of k centroids (rows of the matrix) — local experts.
    Spatial(Matrix),
    /// FNV-1a over the query's f64 bytes, modulo k (fallback when no
    /// usable spatial structure exists).
    Hash,
}

impl RoutePolicy {
    /// Shard index for query `x` among `k` shards.
    pub fn route(&self, x: &[f64], k: usize) -> usize {
        match self {
            RoutePolicy::Single => 0,
            RoutePolicy::Spatial(c) => nearest_centroid(x, c).min(k - 1),
            RoutePolicy::Hash => (hash_point(x) % k as u64) as usize,
        }
    }

    /// Short name for stats lines.
    pub fn kind(&self) -> &'static str {
        match self {
            RoutePolicy::Single => "single",
            RoutePolicy::Spatial(_) => "spatial",
            RoutePolicy::Hash => "hash",
        }
    }
}

/// FNV-1a over the bitwise representation of the query.
fn hash_point(x: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in x {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Spatial policy for a snapshot: k-means centroids over a deterministic
/// sample of the model's grid bounding box (the region queries live in).
/// Falls back to hashing when the box is degenerate.
fn spatial_policy(snap: &ModelSnapshot, k: usize) -> RoutePolicy {
    if k <= 1 {
        return RoutePolicy::Single;
    }
    let axes = &snap.cache.terms()[0].axes;
    let d = axes.len();
    let (lo, hi): (Vec<f64>, Vec<f64>) = axes.iter().map(|g| (g.min, g.max())).unzip();
    if lo
        .iter()
        .zip(&hi)
        .any(|(l, h)| !l.is_finite() || !h.is_finite() || h <= l)
    {
        return RoutePolicy::Hash;
    }
    let n = (64 * k).max(256);
    let mut rng = Rng::new(0x5A1D_0000 ^ k as u64);
    let mut sample = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            sample.set(i, j, rng.uniform_in(lo[j], hi[j]));
        }
    }
    match spatial_centroids(&sample, k, 16, 17) {
        Ok(c) => RoutePolicy::Spatial(c),
        Err(_) => RoutePolicy::Hash,
    }
}

/// One shard: a replica engine plus its private batcher.
///
/// Field order matters for Drop: the handle must release its sender
/// before the batcher's Drop joins the worker thread.
struct Shard {
    engine: Arc<ServeEngine>,
    handle: BatchHandle,
    batcher: RequestBatcher,
}

/// One logical model, served by k shards behind one routing policy.
pub struct ShardedModel {
    id: String,
    shards: Vec<Shard>,
    policy: RoutePolicy,
    live: bool,
    dim: usize,
    bytes: usize,
    /// Fleet-wide metrics (shared with the registry and reactor).
    metrics: Arc<Metrics>,
}

impl ShardedModel {
    /// Replicate a frozen snapshot across `k` shards.
    pub fn from_snapshot(
        id: &str,
        snap: ModelSnapshot,
        k: usize,
        batcher: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        if k == 0 {
            return Err(Error::Fleet("shard count must be at least 1".into()));
        }
        let policy = spatial_policy(&snap, k);
        let dim = snap.cache.dim();
        let bytes = snap.approx_bytes() * k;
        let mut shards = Vec::with_capacity(k);
        for _ in 0..k {
            let engine = Arc::new(ServeEngine::new(snap.clone())?);
            let b = RequestBatcher::start(engine.clone(), batcher);
            let handle = b.handle();
            shards.push(Shard { engine, handle, batcher: b });
        }
        Ok(ShardedModel {
            id: id.to_string(),
            shards,
            policy,
            live: false,
            dim,
            bytes,
            metrics,
        })
    }

    /// Wrap a live incremental model (always single-shard; see the
    /// module docs for why).
    pub fn live(
        id: &str,
        state: IncrementalState,
        batcher: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        let engine = Arc::new(ServeEngine::new_live(state)?);
        let dim = engine.dim();
        let bytes = engine.snapshot().approx_bytes();
        let b = RequestBatcher::start(engine.clone(), batcher);
        let handle = b.handle();
        Ok(ShardedModel {
            id: id.to_string(),
            shards: vec![Shard { engine, handle, batcher: b }],
            policy: RoutePolicy::Single,
            live: true,
            dim,
            bytes,
            metrics,
        })
    }

    /// Model id (registry key and wire-protocol `model <id>` prefix).
    pub fn id(&self) -> &str {
        &self.id
    }

    /// Input dimensionality d.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of shards k.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// True iff observations are accepted.
    pub fn is_live(&self) -> bool {
        self.live
    }

    /// Number of tasks served (1 for single-task models). Read from
    /// shard 0 — the shard whose live engine enrollment can grow (frozen
    /// replicas are identical, so the choice is moot for them).
    pub fn num_tasks(&self) -> usize {
        self.shards[0].engine.num_tasks()
    }

    /// True iff the model carries a multi-task head.
    pub fn is_multitask(&self) -> bool {
        self.shards[0].engine.is_multitask()
    }

    /// Approximate resident bytes across all shard replicas (what the
    /// registry charges against its memory budget).
    pub fn approx_bytes(&self) -> usize {
        self.bytes
    }

    /// The routing policy in use.
    pub fn policy(&self) -> &RoutePolicy {
        &self.policy
    }

    /// The engine behind shard `i` (tests and stats).
    pub fn engine(&self, shard: usize) -> &Arc<ServeEngine> {
        &self.shards[shard].engine
    }

    /// Shard index query `x` routes to.
    pub fn route(&self, x: &[f64]) -> usize {
        self.policy.route(x, self.shards.len())
    }

    /// Enqueue a prediction on its spatially-assigned shard; the
    /// receiver yields when the shard's batch completes.
    pub fn submit_predict(&self, x: &[f64]) -> Receiver<PredictResponse> {
        let s = &self.shards[self.route(x)];
        self.metrics
            .observe("serve.fleet.queue_depth", s.handle.queue_depth() as u64);
        s.handle.submit(x)
    }

    /// Submit a prediction and block for the response.
    pub fn predict(&self, x: &[f64]) -> PredictResponse {
        self.submit_predict(x)
            .recv()
            .expect("shard batcher shut down while a request was in flight")
    }

    /// Enqueue a task-addressed prediction. Placement is the same
    /// spatial decision as [`submit_predict`](Self::submit_predict) —
    /// every shard replicates every task's cache, so the task id plays
    /// no routing role.
    pub fn submit_predict_task(&self, task: usize, x: &[f64]) -> Receiver<PredictResponse> {
        let s = &self.shards[self.route(x)];
        self.metrics
            .observe("serve.fleet.queue_depth", s.handle.queue_depth() as u64);
        s.handle.submit_predict_task(task, x)
    }

    /// Submit a task-addressed prediction and block for the response.
    pub fn predict_task(&self, task: usize, x: &[f64]) -> PredictResponse {
        self.submit_predict_task(task, x)
            .recv()
            .expect("shard batcher shut down while a request was in flight")
    }

    /// Enqueue an observation. Observations always land on shard 0:
    /// live models are single-shard, and frozen models reject the
    /// observation downstream with the typed frozen-engine error.
    pub fn submit_observe(&self, x: &[f64], y: f64) -> Receiver<ObserveResponse> {
        let s = &self.shards[0];
        self.metrics
            .observe("serve.fleet.queue_depth", s.handle.queue_depth() as u64);
        s.handle.submit_observe(x, y)
    }

    /// Submit an observation and block for the ack.
    pub fn observe(&self, x: &[f64], y: f64) -> ObserveResponse {
        self.submit_observe(x, y)
            .recv()
            .expect("shard batcher shut down while an observation was in flight")
    }

    /// Enqueue a task-addressed observation — shard 0, like every
    /// observation (see [`submit_observe`](Self::submit_observe)); on a
    /// live multi-task model the first unseen task id enrolls online.
    pub fn submit_observe_task(
        &self,
        task: usize,
        x: &[f64],
        y: f64,
    ) -> Receiver<ObserveResponse> {
        let s = &self.shards[0];
        self.metrics
            .observe("serve.fleet.queue_depth", s.handle.queue_depth() as u64);
        s.handle.submit_observe_task(task, x, y)
    }

    /// Submit a task-addressed observation and block for the ack.
    pub fn observe_task(&self, task: usize, x: &[f64], y: f64) -> ObserveResponse {
        self.submit_observe_task(task, x, y)
            .recv()
            .expect("shard batcher shut down while an observation was in flight")
    }

    /// Enqueue a derivative observation `(x, y, ∇y)` (D-SKI) — shard 0,
    /// like every observation (see [`submit_observe`](Self::submit_observe)).
    pub fn submit_observe_grad(
        &self,
        x: &[f64],
        y: f64,
        grad: &[f64],
    ) -> Receiver<ObserveResponse> {
        let s = &self.shards[0];
        self.metrics
            .observe("serve.fleet.queue_depth", s.handle.queue_depth() as u64);
        s.handle.submit_observe_grad(x, y, grad)
    }

    /// Submit a derivative observation and block for the ack.
    pub fn observe_grad(&self, x: &[f64], y: f64, grad: &[f64]) -> ObserveResponse {
        self.submit_observe_grad(x, y, grad)
            .recv()
            .expect("shard batcher shut down while an observation was in flight")
    }

    /// Total points served across shards.
    pub fn served(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.engine.metrics.counter("serve.points"))
            .sum()
    }

    /// One-line per-model summary: shard count, routing policy, total
    /// and per-shard served counts (the fleet `stats` verb appends one
    /// fragment per resident model).
    pub fn stats_line(&self) -> String {
        let mut line = format!(
            "shards={} route={} served={}",
            self.shards.len(),
            self.policy.kind(),
            self.served(),
        );
        for (i, s) in self.shards.iter().enumerate() {
            line.push_str(&format!(
                " s{i}={}",
                s.engine.metrics.counter("serve.points")
            ));
        }
        if self.live {
            line.push_str(" live=1");
        }
        line
    }

    /// Drain and join every shard's batcher (queued requests are still
    /// served). Dropping the model does the same via the batcher Drops.
    pub fn shutdown(self) {
        for s in self.shards {
            drop(s.handle);
            s.batcher.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_routing_is_deterministic_and_spread() {
        let p = RoutePolicy::Hash;
        let a = p.route(&[0.25, -1.5], 8);
        assert_eq!(a, p.route(&[0.25, -1.5], 8));
        assert!(a < 8);
        // Different points spread across shards (not all on one).
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..64 {
            seen.insert(p.route(&[i as f64 * 0.37, -(i as f64)], 8));
        }
        assert!(seen.len() > 2, "hash routing collapsed: {seen:?}");
    }

    #[test]
    fn spatial_routing_sends_neighbors_together() {
        let c = Matrix::from_vec(2, 1, vec![-1.0, 1.0]);
        let p = RoutePolicy::Spatial(c);
        assert_eq!(p.route(&[-0.9], 2), p.route(&[-1.1], 2));
        assert_eq!(p.route(&[0.9], 2), p.route(&[1.1], 2));
        assert_ne!(p.route(&[-0.9], 2), p.route(&[0.9], 2));
    }
}
