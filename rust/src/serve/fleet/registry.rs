//! Model registry: many models hot, LRU-evicted under a memory budget.
//!
//! The registry keys [`ShardedModel`]s by id. A `get` on a resident
//! model is a map lookup plus an LRU-tick bump; a miss lazily loads
//! `<dir>/<id>.snap` from the snapshot directory, builds the sharded
//! model, and evicts least-recently-used non-pinned entries until the
//! configured memory budget (measured with
//! [`ModelSnapshot::approx_bytes`] times the shard count) is satisfied
//! again. Live (observation-accepting) models are inserted **pinned**:
//! evicting one would discard un-checkpointed observations, so the LRU
//! never touches them — live and frozen engines coexist in one registry.
//!
//! Locking is deliberately coarse (one mutex around the map): lookups
//! are nanoseconds, loads are rare, and a finer scheme would buy nothing
//! until model counts reach the tens of thousands. Eviction drops the
//! registry's `Arc`; the model's shard batchers join once the last
//! in-flight request releases its handle, so eviction never truncates
//! queued work.
//!
//! Registry traffic records into the shared fleet metrics:
//! `serve.fleet.{hits,misses,loads,evictions}` counters and the
//! `serve.fleet.resident_models` gauge histogram
//! ([`Metrics::fleet_report`] renders them).
//!
//! [`Metrics::fleet_report`]: crate::coordinator::Metrics::fleet_report

use super::router::ShardedModel;
use crate::coordinator::Metrics;
use crate::serve::batcher::BatcherConfig;
use crate::serve::snapshot::ModelSnapshot;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Registry policy.
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Snapshot directory for lazy loads (`<dir>/<id>.snap`); `None`
    /// disables loading — only explicitly-inserted models resolve.
    pub dir: Option<PathBuf>,
    /// Approximate resident-bytes budget across models (0 = unlimited).
    /// A single model larger than the budget still loads — the registry
    /// overshoots rather than refusing to serve.
    pub memory_budget: usize,
    /// Shards per lazily-loaded frozen model.
    pub shards: usize,
    /// Batcher policy for every shard.
    pub batcher: BatcherConfig,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        RegistryConfig {
            dir: None,
            memory_budget: 0,
            shards: 1,
            batcher: BatcherConfig::default(),
        }
    }
}

struct Entry {
    model: Arc<ShardedModel>,
    last_used: u64,
    pinned: bool,
}

struct Inner {
    models: HashMap<String, Entry>,
    tick: u64,
    resident_bytes: usize,
}

/// Thread-safe model registry (shared by every reactor worker).
pub struct ModelRegistry {
    cfg: RegistryConfig,
    metrics: Arc<Metrics>,
    inner: Mutex<Inner>,
}

/// Model ids double as file stems, so they are locked down hard enough
/// that no id can escape the snapshot directory.
pub fn valid_id(id: &str) -> bool {
    !id.is_empty()
        && id.len() <= 64
        && id
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
}

impl ModelRegistry {
    /// An empty registry recording into `metrics`.
    pub fn new(cfg: RegistryConfig, metrics: Arc<Metrics>) -> Self {
        ModelRegistry {
            cfg,
            metrics,
            inner: Mutex::new(Inner {
                models: HashMap::new(),
                tick: 0,
                resident_bytes: 0,
            }),
        }
    }

    /// The shared fleet metrics.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Insert a pre-built model (replacing any same-id entry). `pinned`
    /// exempts it from LRU eviction — live models must pass `true`.
    pub fn insert(&self, model: ShardedModel, pinned: bool) -> Arc<ShardedModel> {
        let id = model.id().to_string();
        let arc = Arc::new(model);
        let bytes = arc.approx_bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.models.insert(
            id.clone(),
            Entry { model: arc.clone(), last_used: tick, pinned },
        ) {
            inner.resident_bytes = inner.resident_bytes.saturating_sub(old.model.approx_bytes());
        }
        inner.resident_bytes += bytes;
        self.evict_over_budget(&mut inner, &id);
        self.metrics
            .observe("serve.fleet.resident_models", inner.models.len() as u64);
        arc
    }

    /// Resolve `id`: resident models return immediately (bumping their
    /// LRU tick); misses load `<dir>/<id>.snap`, shard it, and evict
    /// down to the memory budget.
    pub fn get(&self, id: &str) -> Result<Arc<ShardedModel>> {
        if !valid_id(id) {
            return Err(Error::Fleet(format!(
                "invalid model id '{id}' (allowed: [A-Za-z0-9_-], \
                 at most 64 chars)"
            )));
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.models.get_mut(id) {
            e.last_used = tick;
            self.metrics.incr("serve.fleet.hits", 1);
            return Ok(e.model.clone());
        }
        self.metrics.incr("serve.fleet.misses", 1);
        let dir = self.cfg.dir.as_ref().ok_or_else(|| {
            Error::Fleet(format!(
                "unknown model '{id}' (and no --models directory to load from)"
            ))
        })?;
        // The load runs under the registry lock: a burst of misses for
        // the same id must not load it once per request.
        let path = dir.join(format!("{id}.snap"));
        if !path.exists() {
            return Err(Error::Fleet(format!(
                "unknown model '{id}' (no {id}.snap in the model directory)"
            )));
        }
        let snap = ModelSnapshot::load(&path)
            .map_err(|e| Error::Fleet(format!("model '{id}': {e}")))?;
        let model = Arc::new(ShardedModel::from_snapshot(
            id,
            snap,
            self.cfg.shards.max(1),
            self.cfg.batcher,
            self.metrics.clone(),
        )?);
        self.metrics.incr("serve.fleet.loads", 1);
        let bytes = model.approx_bytes();
        inner.models.insert(
            id.to_string(),
            Entry { model: model.clone(), last_used: tick, pinned: false },
        );
        inner.resident_bytes += bytes;
        self.evict_over_budget(&mut inner, id);
        self.metrics
            .observe("serve.fleet.resident_models", inner.models.len() as u64);
        Ok(model)
    }

    /// Evict LRU non-pinned entries (never `keep`) until the budget
    /// holds. With only pinned entries (or only `keep`) left, the
    /// registry overshoots — refusing to serve would be worse.
    fn evict_over_budget(&self, inner: &mut Inner, keep: &str) {
        if self.cfg.memory_budget == 0 {
            return;
        }
        while inner.resident_bytes > self.cfg.memory_budget {
            let victim = inner
                .models
                .iter()
                .filter(|(mid, e)| !e.pinned && mid.as_str() != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(mid, _)| mid.clone());
            let Some(mid) = victim else { break };
            if let Some(e) = inner.models.remove(&mid) {
                inner.resident_bytes = inner.resident_bytes.saturating_sub(e.model.approx_bytes());
            }
            self.metrics.incr("serve.fleet.evictions", 1);
        }
    }

    /// Number of resident models.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().models.len()
    }

    /// True iff nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes across models.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }

    /// True iff `id` is resident right now (no LRU bump, no load).
    pub fn contains(&self, id: &str) -> bool {
        self.inner.lock().unwrap().models.contains_key(id)
    }

    /// Sorted resident ids.
    pub fn ids(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<String> = inner.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Sorted serveable ids: resident models plus every `<id>.snap` in
    /// the snapshot directory (the wire-protocol `models` verb).
    pub fn available(&self) -> Vec<String> {
        let mut ids = self.ids();
        if let Some(dir) = &self.cfg.dir {
            if let Ok(entries) = std::fs::read_dir(dir) {
                for e in entries.flatten() {
                    let name = e.file_name();
                    let name = name.to_string_lossy();
                    if let Some(stem) = name.strip_suffix(".snap") {
                        if valid_id(stem) {
                            ids.push(stem.to_string());
                        }
                    }
                }
            }
        }
        ids.sort();
        ids.dedup();
        ids
    }

    /// One `"<id>: <model stats>"` fragment per resident model, sorted
    /// by id (no LRU bumps — stats must not distort eviction order).
    pub fn stats_fragments(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<String> = inner
            .models
            .iter()
            .map(|(id, e)| format!("{id}: {}", e.model.stats_line()))
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_validation_blocks_path_escapes() {
        assert!(valid_id("model-a_1"));
        assert!(!valid_id(""));
        assert!(!valid_id("../etc/passwd"));
        assert!(!valid_id("a/b"));
        assert!(!valid_id("a.snap"));
        assert!(!valid_id(&"x".repeat(65)));
    }

    #[test]
    fn unknown_model_without_dir_is_typed_error() {
        let reg = ModelRegistry::new(RegistryConfig::default(), Arc::new(Metrics::new()));
        let err = reg.get("nope").unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
        let err = reg.get("../sneaky").unwrap_err();
        assert!(err.to_string().contains("invalid model id"), "{err}");
    }
}
