//! O(1)-per-point predictive caches on the inducing grid.
//!
//! KISS-GP's observation (Wilson & Nickisch, 2015) is that once a model is
//! trained, the SKI structure makes *prediction* nearly free: the
//! cross-covariance `k(x*, X) ≈ w(x*) K_UU Wᵀ` touches the query point
//! only through its sparse tensor interpolation stencil `w(x*)`, so
//! every training-data-sized quantity can be pushed onto the grid **once**
//! at snapshot-build time:
//!
//! - **mean cache** `u = σ_f² (⊗K_UU)(Wᵀ α)` (length M = Π m_k): the
//!   predictive mean collapses to one sparse stencil dot,
//!   `μ(x*) = w(x*) · u`, in O(4ᵈ) per query;
//! - **variance cache** `R = σ_f² (⊗K_UU)(Wᵀ S)` (M × r, where
//!   `K̂⁻¹ ≈ S Sᵀ`): the predictive variance collapses to a rank-r gemv
//!   against the stencil rows, `σ²(x*) = k** − ‖Rᵀ w(x*)‖²`, in O(4ᵈ r).
//!
//! The cache is built **per grid term** through the
//! [`crate::grid::InducingGrid`] trait: a dense rectilinear grid is the
//! single-term special case, and a combination-technique sparse grid
//! ([`crate::grid::SparseGrid`]) holds one `(uₜ, Rₜ)` pair per
//! anisotropic term, combined at query time with the signed coefficients:
//! `μ(x*) = Σ_t c_t wₜ(x*)·uₜ` and
//! `σ²(x*) = k** − ‖Σ_t c_t Rₜᵀ wₜ(x*)‖²`. Coarse axes of sparse terms
//! carry 1- or 2-wide stencils, so the per-query cost stays tiny even at
//! d = 10.
//!
//! `S` comes from either the exact Cholesky root `L⁻ᵀ` (rank n, small
//! problems) or r Lanczos iterations on the training operator
//! (`K̂⁻¹ ≈ Q T⁻¹ Qᵀ`, the LOVE-style low-rank route) — see
//! [`inverse_root_exact`] / [`inverse_root_lanczos`].
//!
//! Cache construction itself rides the batched engine: the r variance
//! columns go through the Kronecker–Toeplitz grid apply in parallel
//! (`util::parallel`), and the per-point stencil scatter decodes each
//! training row once for all r columns — the same single-decode idiom as
//! `KroneckerSkiOp::matmat`.

use crate::gp::GpHypers;
use crate::grid::{
    tensor_stencil, tensor_stencil_grad, tensor_strides, Grid1d, GridSpec, InducingGrid,
};
use crate::kernels::Stationary1d;
use crate::linalg::{Cholesky, Matrix, SymToeplitz};
use crate::operators::{kron_toeplitz_matvec, LinearOp};
use crate::solvers::lanczos::lanczos;
use crate::util::parallel::par_map_range;
use crate::{Error, Result};

/// How to build the data-side inverse-root factor `S` (`K̂⁻¹ ≈ S Sᵀ`) for
/// the variance cache.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VarianceMode {
    /// Mean-only cache; predictive variance is unavailable from it.
    None,
    /// Exact `S = L⁻ᵀ` from a dense Cholesky of K̂ (rank n — O(n³) once
    /// at snapshot time; the right call for n up to a few thousand).
    Exact,
    /// `S = Q C⁻ᵀ` from r Lanczos iterations on the training operator
    /// (`T = C Cᵀ`), rank r ≪ n.
    Lanczos(usize),
}

/// Per-term grid-side caches: the mean vector and variance factor of one
/// rectilinear term, plus its signed combination coefficient.
#[derive(Clone, Debug)]
pub struct TermCache {
    /// Signed combination coefficient c_t (1 for a dense grid).
    pub coeff: f64,
    /// Per-dimension axes of this term.
    pub axes: Vec<Grid1d>,
    /// Mean cache `σ_f² (⊗K)(Wᵀα)`, length M_t = Π m_k.
    pub mean: Vec<f64>,
    /// Variance factor `R_t = σ_f² (⊗K)(Wᵀ S)`, M_t × r (zero columns ⇒
    /// no variance cache).
    pub var_r: Matrix,
    /// Row-major strides of the term's flat layout (derived from `axes`).
    strides: Vec<usize>,
}

impl TermCache {
    /// Assemble one term from parts, validating buffer sizes against the
    /// axes.
    pub fn new(
        coeff: f64,
        axes: Vec<Grid1d>,
        mean: Vec<f64>,
        var_r: Matrix,
    ) -> Result<Self> {
        let dims: Vec<usize> = axes.iter().map(|g| g.m).collect();
        let total: usize = dims.iter().product();
        if mean.len() != total {
            return Err(Error::DimMismatch {
                context: "predict cache mean buffer",
                expected: total,
                got: mean.len(),
            });
        }
        if var_r.cols > 0 && var_r.rows != total {
            return Err(Error::DimMismatch {
                context: "predict cache variance factor rows",
                expected: total,
                got: var_r.rows,
            });
        }
        let strides = tensor_strides(&dims);
        Ok(TermCache { coeff, axes, mean, var_r, strides })
    }

    /// Approximate resident size of this term in bytes (the f64 payload
    /// buffers; struct overhead is negligible next to them). The fleet
    /// registry charges models against its memory budget with this.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<f64>()
            * (self.mean.len() + self.var_r.rows * self.var_r.cols)
    }
}

/// Grid-side predictive cache: everything a prediction needs, with no
/// reference to the training data.
#[derive(Clone, Debug)]
pub struct PredictCache {
    /// The grid spec the cache was built on (persisted by snapshots).
    pub spec: GridSpec,
    /// One cache per grid term (exactly one for dense grids).
    terms: Vec<TermCache>,
    /// Prior latent variance k** = σ_f².
    pub prior_var: f64,
    /// Observation noise σ_n² (add to the latent variance for y-variance).
    pub noise: f64,
}

impl PredictCache {
    /// Assemble from per-term parts (used by the snapshot loader);
    /// validates that every term agrees on dimensionality and variance
    /// rank.
    pub fn from_parts(
        spec: GridSpec,
        terms: Vec<TermCache>,
        prior_var: f64,
        noise: f64,
    ) -> Result<Self> {
        if terms.is_empty() {
            return Err(Error::Snapshot("predict cache with no grid terms".into()));
        }
        let d = terms[0].axes.len();
        let r = terms[0].var_r.cols;
        for t in &terms {
            if t.axes.len() != d {
                return Err(Error::DimMismatch {
                    context: "predict cache term dimensionality",
                    expected: d,
                    got: t.axes.len(),
                });
            }
            if t.var_r.cols != r {
                return Err(Error::DimMismatch {
                    context: "predict cache variance rank across terms",
                    expected: r,
                    got: t.var_r.cols,
                });
            }
        }
        Ok(PredictCache { spec, terms, prior_var, noise })
    }

    /// The per-term caches.
    pub fn terms(&self) -> &[TermCache] {
        &self.terms
    }

    /// Mutable access to the per-term caches — the streaming path
    /// ([`crate::stream`]) patches the mean cache in place after each
    /// incremental α re-solve instead of rebuilding the whole cache.
    /// Callers must preserve the invariants [`Self::from_parts`] checks
    /// (buffer sizes against the axes, one variance rank across terms).
    pub fn terms_mut(&mut self) -> &mut [TermCache] {
        &mut self.terms
    }

    /// Input dimensionality d.
    pub fn dim(&self) -> usize {
        self.terms[0].axes.len()
    }

    /// Total stored grid cells Σ_t M_t across terms.
    pub fn total_grid(&self) -> usize {
        self.terms.iter().map(|t| t.mean.len()).sum()
    }

    /// Rank r of the variance cache (0 ⇒ mean-only).
    pub fn var_rank(&self) -> usize {
        self.terms[0].var_r.cols
    }

    /// True iff a variance cache was built.
    pub fn has_variance(&self) -> bool {
        self.var_rank() > 0
    }

    /// Approximate resident size of the cache in bytes (sum of the
    /// per-term payload buffers) — see [`TermCache::approx_bytes`].
    pub fn approx_bytes(&self) -> usize {
        self.terms.iter().map(TermCache::approx_bytes).sum()
    }

    /// Predictive mean at one point: one sparse stencil dot per term.
    pub fn predict_mean_one(&self, x: &[f64]) -> f64 {
        let mut out = 0.0;
        for t in &self.terms {
            let mut acc = 0.0;
            tensor_stencil(x, &t.axes, &t.strides, |g, w| {
                acc += w * t.mean[g];
            });
            out += t.coeff * acc;
        }
        out
    }

    /// Latent predictive variance at one point:
    /// `k** − ‖Σ_t c_t Rₜᵀ wₜ(x*)‖²`, O(stencil · r). Clamped at 1e-12
    /// like `ExactGp::predict_var`.
    pub fn predict_var_one(&self, x: &[f64]) -> f64 {
        assert!(self.has_variance(), "cache was built without a variance factor");
        with_rank_scratch(self.var_rank(), |acc| {
            for t in &self.terms {
                let c = t.coeff;
                tensor_stencil(x, &t.axes, &t.strides, |g, w| {
                    let cw = c * w;
                    let row = t.var_r.row(g);
                    for (a, &v) in acc.iter_mut().zip(row.iter()) {
                        *a += cw * v;
                    }
                });
            }
            let reduce: f64 = acc.iter().map(|a| a * a).sum();
            (self.prior_var - reduce).max(1e-12)
        })
    }

    /// Gradient of the predictive mean at one point (D-SKI's query-side
    /// trick): `∇μ(x*)_a = Σ_t c_t · dwₜ_a(x*)·uₜ` — the *same* grid-side
    /// mean cache queried through differentiated stencil weights, one
    /// sparse stencil dot per axis per term. Writes the d components into
    /// `out`.
    pub fn predict_grad_one(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim());
        out.fill(0.0);
        for t in &self.terms {
            for (a, o) in out.iter_mut().enumerate() {
                let mut acc = 0.0;
                tensor_stencil_grad(x, a, &t.axes, &t.strides, |g, w| {
                    acc += w * t.mean[g];
                });
                *o += t.coeff * acc;
            }
        }
    }

    /// Batched predictive-mean gradients (n* × d, row i = ∇μ at query i).
    pub fn predict_grad(&self, xtest: &Matrix) -> Matrix {
        assert_eq!(xtest.cols, self.dim(), "query dimensionality mismatch");
        let d = self.dim();
        let rows = par_map_range(xtest.rows, 256, |i| {
            let mut g = vec![0.0; d];
            self.predict_grad_one(xtest.row(i), &mut g);
            g
        });
        let mut out = Matrix::zeros(xtest.rows, d);
        for (i, g) in rows.iter().enumerate() {
            out.row_mut(i).copy_from_slice(g);
        }
        out
    }

    /// Batched predictive means for an n*×d block (parallel across row
    /// chunks for large batches; per-row arithmetic is identical to
    /// [`predict_mean_one`](Self::predict_mean_one), so batched and
    /// one-at-a-time serving agree bitwise).
    pub fn predict_mean(&self, xtest: &Matrix) -> Vec<f64> {
        assert_eq!(xtest.cols, self.dim(), "query dimensionality mismatch");
        par_map_range(xtest.rows, 256, |i| self.predict_mean_one(xtest.row(i)))
    }

    /// Batched latent predictive variances (see
    /// [`predict_mean`](Self::predict_mean) for the equivalence contract).
    pub fn predict_var(&self, xtest: &Matrix) -> Vec<f64> {
        assert_eq!(xtest.cols, self.dim(), "query dimensionality mismatch");
        par_map_range(xtest.rows, 256, |i| self.predict_var_one(xtest.row(i)))
    }

    /// (mean, latent variance) at one point in a **single** stencil pass
    /// per term: the weights are decoded once and feed both the mean dot
    /// and the rank-r variance accumulator. The accumulation order per
    /// output matches [`predict_mean_one`](Self::predict_mean_one) /
    /// [`predict_var_one`](Self::predict_var_one) exactly, so the fused
    /// path is bitwise identical to the two separate ones.
    pub fn predict_one(&self, x: &[f64]) -> (f64, f64) {
        assert!(self.has_variance(), "cache was built without a variance factor");
        with_rank_scratch(self.var_rank(), |acc| {
            let mut mean = 0.0;
            for t in &self.terms {
                let c = t.coeff;
                let mut term_mean = 0.0;
                tensor_stencil(x, &t.axes, &t.strides, |g, w| {
                    term_mean += w * t.mean[g];
                    let cw = c * w;
                    let row = t.var_r.row(g);
                    for (a, &v) in acc.iter_mut().zip(row.iter()) {
                        *a += cw * v;
                    }
                });
                mean += c * term_mean;
            }
            let reduce: f64 = acc.iter().map(|a| a * a).sum();
            (mean, (self.prior_var - reduce).max(1e-12))
        })
    }

    /// Batched (means, variances), one fused stencil pass per row per term.
    pub fn predict(&self, xtest: &Matrix) -> (Vec<f64>, Vec<f64>) {
        assert_eq!(xtest.cols, self.dim(), "query dimensionality mismatch");
        let rows = par_map_range(xtest.rows, 256, |i| self.predict_one(xtest.row(i)));
        rows.into_iter().unzip()
    }

    /// Build the cache from training data and a cached solve.
    ///
    /// - `xs`: n × d training inputs (consumed only at build time);
    /// - `alpha`: the cached solve `K̂⁻¹ y`;
    /// - `grid`: the inducing grid (dense rectilinear or sparse) — one
    ///   `(uₜ, Rₜ)` pair is pushed onto every term;
    /// - `s`: optional n × r inverse-root factor with `K̂⁻¹ ≈ S Sᵀ`
    ///   (None ⇒ mean-only cache).
    pub fn build(
        xs: &Matrix,
        alpha: &[f64],
        hypers: &GpHypers,
        grid: &dyn InducingGrid,
        s: Option<&Matrix>,
    ) -> Result<Self> {
        assert_eq!(xs.rows, alpha.len());
        assert_eq!(xs.cols, grid.dim());
        if let Some(s) = s {
            assert_eq!(s.rows, xs.rows, "inverse-root factor row count");
        }
        let kern = Stationary1d::rbf(hypers.ell());
        let mut terms = Vec::with_capacity(grid.terms().len());
        for t in grid.terms() {
            terms.push(build_term(xs, alpha, hypers, &kern, t.coeff, &t.axes, s)?);
        }
        PredictCache::from_parts(grid.spec(), terms, hypers.sf2(), hypers.sn2())
    }
}

/// Build **one task's** serving cache of a multi-task model (paper §6).
///
/// With the multi-task covariance `K̂ = σ_f²(K_data ∘ K_task) + σ_n² I`,
/// the cross-covariance of a query `(x*, t)` against training row `i` is
/// `k_data(x*, xᵢ) · k_task(t, tᵢ)` — the data part is the usual SKI
/// stencil, and the task part is a fixed per-row coefficient
/// `c_t[i] = k_task(t, tᵢ)` ([`crate::kernels::TaskKernel::row_mask`]).
/// So task t's caches are the single-task caches of *masked* data-side
/// vectors:
///
/// - mean `u_t = σ_f²(⊗K)(Wᵀ(c_t ∘ α))` → `μ(x*, t) = w(x*)·u_t`;
/// - variance root `R_t = σ_f²(⊗K)(Wᵀ diag(c_t) S)` →
///   `σ²(x*, t) = σ_f²·k_task(t,t) − ‖R_tᵀ w(x*)‖²`.
///
/// `task_mask` is `c_t`, and `task_prior` is the query's prior latent
/// variance `σ_f²·k_task(t,t)` (which replaces the single-task `σ_f²` in
/// [`PredictCache::prior_var`]). Everything else — stencil decode, grid
/// apply, clamping — reuses [`PredictCache::build`] verbatim, so
/// single-task models (mask all-ones, prior σ_f²) produce bitwise the
/// same cache through either entry point.
pub fn build_task_cache(
    xs: &Matrix,
    alpha: &[f64],
    hypers: &GpHypers,
    grid: &dyn InducingGrid,
    s: Option<&Matrix>,
    task_mask: &[f64],
    task_prior: f64,
) -> Result<PredictCache> {
    assert_eq!(task_mask.len(), alpha.len(), "task mask length");
    let masked_alpha: Vec<f64> =
        alpha.iter().zip(task_mask).map(|(&a, &c)| c * a).collect();
    let masked_s = s.map(|s| {
        let mut m = s.clone();
        for (i, &c) in task_mask.iter().enumerate() {
            for v in m.row_mut(i) {
                *v *= c;
            }
        }
        m
    });
    let mut cache =
        PredictCache::build(xs, &masked_alpha, hypers, grid, masked_s.as_ref())?;
    cache.prior_var = task_prior;
    Ok(cache)
}

/// Build the serving cache of a **gradient-observation (D-SKI)** model:
/// the cached solve `alpha` is row-aligned with the extended operator
/// `W_ext` — for each training point, one value row, then (when its
/// `has_grad` flag is set) d gradient rows, the
/// [`crate::kernels::deriv_layout`] order. The mean cache becomes
/// `u = σ_f² (⊗K)(W_extᵀ α)`: value rows scatter through plain stencils,
/// gradient rows through differentiated ones, and the *query* side is
/// untouched — `μ(x*) = w(x*)·u` and `∇μ(x*) = dw(x*)·u` read the same
/// buffer. The optional `s` is an N × r inverse-root factor of the
/// extended system (`K̂_ext⁻¹ ≈ S Sᵀ`), scattered the same way into the
/// variance factor `R = σ_f² (⊗K)(W_extᵀ S)`.
///
/// Gradient models are single-term dense-grid only (the combination
/// technique would need per-term differentiated stencils on coarse axes
/// where the derivative error dominates), so this builds exactly one
/// [`TermCache`] on `axes`.
pub fn build_grad_cache(
    xs: &Matrix,
    has_grad: &[bool],
    alpha: &[f64],
    hypers: &GpHypers,
    spec: GridSpec,
    axes: Vec<Grid1d>,
    s: Option<&Matrix>,
) -> Result<PredictCache> {
    assert_eq!(xs.rows, has_grad.len());
    assert_eq!(xs.cols, axes.len());
    let d = axes.len();
    let n_rows =
        xs.rows + d * has_grad.iter().filter(|&&g| g).count();
    if alpha.len() != n_rows {
        return Err(Error::DimMismatch {
            context: "gradient cache α rows",
            expected: n_rows,
            got: alpha.len(),
        });
    }
    if let Some(s) = s {
        if s.rows != n_rows {
            return Err(Error::DimMismatch {
                context: "gradient cache inverse-root factor rows",
                expected: n_rows,
                got: s.rows,
            });
        }
    }
    let dims: Vec<usize> = axes.iter().map(|g| g.m).collect();
    let strides = tensor_strides(&dims);
    let total: usize = dims.iter().product();
    let kern = Stationary1d::rbf(hypers.ell());
    let factors: Vec<SymToeplitz> = axes
        .iter()
        .map(|g| SymToeplitz::new(kern.toeplitz_column(g.m, g.h)))
        .collect();

    // Mean cache: scatter W_extᵀα, walking the interleaved row layout.
    let mut wta = vec![0.0; total];
    let mut row = 0usize;
    for i in 0..xs.rows {
        let a = alpha[row];
        tensor_stencil(xs.row(i), &axes, &strides, |g, w| {
            wta[g] += w * a;
        });
        row += 1;
        if has_grad[i] {
            for axis in 0..d {
                let a = alpha[row];
                tensor_stencil_grad(xs.row(i), axis, &axes, &strides, |g, w| {
                    wta[g] += w * a;
                });
                row += 1;
            }
        }
    }
    let mean = mean_from_scatter(&wta, &factors, &dims, hypers.sf2());

    // Variance cache: W_extᵀ S scatter (each row decoded once for all r
    // columns), then the grid apply per column — the extended-row twin of
    // `build_term`'s variance path.
    let var_r = match s {
        None => Matrix::zeros(total, 0),
        Some(s) => {
            let r = s.cols;
            let mut wts = Matrix::zeros(total, r);
            let mut row = 0usize;
            let mut scatter = |x: &[f64], axis: Option<usize>, srow: &[f64]| {
                let fold = |g: usize, w: f64, wts: &mut Matrix| {
                    let out = wts.row_mut(g);
                    for (o, &v) in out.iter_mut().zip(srow) {
                        *o += w * v;
                    }
                };
                match axis {
                    None => tensor_stencil(x, &axes, &strides, |g, w| {
                        fold(g, w, &mut wts)
                    }),
                    Some(a) => tensor_stencil_grad(x, a, &axes, &strides, |g, w| {
                        fold(g, w, &mut wts)
                    }),
                }
            };
            for i in 0..xs.rows {
                scatter(xs.row(i), None, s.row(row));
                row += 1;
                if has_grad[i] {
                    for axis in 0..d {
                        scatter(xs.row(i), Some(axis), s.row(row));
                        row += 1;
                    }
                }
            }
            let cols = par_map_range(r, 2, |j| {
                kron_toeplitz_matvec(&factors, &dims, &wts.col(j))
            });
            let mut rmat = Matrix::zeros(total, r);
            for (j, c) in cols.iter().enumerate() {
                rmat.set_col(j, c);
            }
            for v in rmat.data.iter_mut() {
                *v *= hypers.sf2();
            }
            rmat
        }
    };
    let term = TermCache::new(1.0, axes, mean, var_r)?;
    PredictCache::from_parts(spec, vec![term], hypers.sf2(), hypers.sn2())
}

/// Scatter `Wᵀ v` (v data-sized) onto one term's grid: one stencil
/// decode per data row. Shared by the snapshot-time cache build and the
/// streaming layer's scatter bookkeeping ([`crate::stream`]), so the
/// two can never drift.
pub fn scatter_wt(xs: &Matrix, v: &[f64], axes: &[Grid1d]) -> Vec<f64> {
    assert_eq!(xs.rows, v.len());
    let dims: Vec<usize> = axes.iter().map(|g| g.m).collect();
    let strides = tensor_strides(&dims);
    let total: usize = dims.iter().product();
    let mut out = vec![0.0; total];
    for i in 0..xs.rows {
        let a = v[i];
        tensor_stencil(xs.row(i), axes, &strides, |g, w| {
            out[g] += w * a;
        });
    }
    out
}

/// One term's mean cache from its scatter: `σ_f² (⊗K) wta` — one
/// Kronecker–Toeplitz apply plus the output scale. Shared by
/// [`PredictCache::build`] and the streaming layer's per-ingest mean
/// patch.
pub fn mean_from_scatter(
    wta: &[f64],
    factors: &[SymToeplitz],
    dims: &[usize],
    sf2: f64,
) -> Vec<f64> {
    let mut mean = kron_toeplitz_matvec(factors, dims, wta);
    for v in mean.iter_mut() {
        *v *= sf2;
    }
    mean
}

/// Build one term's `(uₜ, Rₜ)` caches.
fn build_term(
    xs: &Matrix,
    alpha: &[f64],
    hypers: &GpHypers,
    kern: &Stationary1d,
    coeff: f64,
    axes: &[Grid1d],
    s: Option<&Matrix>,
) -> Result<TermCache> {
    let dims: Vec<usize> = axes.iter().map(|g| g.m).collect();
    let strides = tensor_strides(&dims);
    let total: usize = dims.iter().product();
    let factors: Vec<crate::linalg::SymToeplitz> = axes
        .iter()
        .map(|g| crate::linalg::SymToeplitz::new(kern.toeplitz_column(g.m, g.h)))
        .collect();

    // Mean cache: scatter Wᵀα onto the grid, one stencil decode per
    // training point, then one Kronecker–Toeplitz apply.
    let wta = scatter_wt(xs, alpha, axes);
    let mean = mean_from_scatter(&wta, &factors, &dims, hypers.sf2());

    // Variance cache: Wᵀ S scatter (each training row decoded once for
    // all r columns), then the grid apply per column in parallel.
    let var_r = match s {
        None => Matrix::zeros(total, 0),
        Some(s) => {
            let r = s.cols;
            let mut wts = Matrix::zeros(total, r);
            for i in 0..xs.rows {
                let srow = s.row(i);
                tensor_stencil(xs.row(i), axes, &strides, |g, w| {
                    let out = wts.row_mut(g);
                    for (o, &v) in out.iter_mut().zip(srow) {
                        *o += w * v;
                    }
                });
            }
            let cols =
                par_map_range(r, 2, |j| kron_toeplitz_matvec(&factors, &dims, &wts.col(j)));
            let mut rmat = Matrix::zeros(total, r);
            for (j, c) in cols.iter().enumerate() {
                rmat.set_col(j, c);
            }
            for v in rmat.data.iter_mut() {
                *v *= hypers.sf2();
            }
            rmat
        }
    };

    TermCache::new(coeff, axes.to_vec(), mean, var_r)
}

thread_local! {
    /// Per-thread rank-r accumulator for the variance gemv — the serving
    /// hot path must not heap-allocate per query (with `VarianceMode::Exact`
    /// r = n, and one-at-a-time traffic calls in here per point).
    static RANK_SCRATCH: std::cell::RefCell<Vec<f64>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Run `f` on a zeroed length-`r` scratch slice reused across calls on
/// this thread.
fn with_rank_scratch<R>(r: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    RANK_SCRATCH.with(|cell| {
        let mut v = cell.borrow_mut();
        v.clear();
        v.resize(r, 0.0);
        f(&mut v)
    })
}

/// Exact inverse root `S = L⁻ᵀ` (rank n) from a dense Cholesky of K̂:
/// `S Sᵀ = L⁻ᵀ L⁻¹ = K̂⁻¹`.
pub fn inverse_root_exact(chol: &Cholesky) -> Matrix {
    let n = chol.l.rows;
    chol.solve_upper_mat(&Matrix::eye(n))
}

/// Low-rank inverse root from `rank` Lanczos iterations of the training
/// operator started at `probe`: with `K̂ ≈ Q T Qᵀ` and `T = C Cᵀ`,
/// `S = Q C⁻ᵀ` gives `S Sᵀ = Q T⁻¹ Qᵀ ≈ K̂⁻¹` (the LOVE-style route; the
/// Krylov space of `probe = y` puts the accuracy where queries near the
/// data need it).
pub fn inverse_root_lanczos(
    op: &dyn LinearOp,
    probe: &[f64],
    rank: usize,
) -> Result<Matrix> {
    let res = lanczos(op, probe, rank, 1e-10);
    let t = res.t_dense();
    let chol = Cholesky::new_with_jitter(&t, 0.0)?;
    // S = Q C⁻ᵀ  ⇔  Sᵀ = C⁻¹ Qᵀ  ⇔  C Sᵀ = Qᵀ.
    let st = chol.solve_lower_mat(&res.q.transpose());
    Ok(st.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::ExactGp;
    use crate::grid::{RectilinearGrid, SparseGrid};
    use crate::kernels::ProductKernel;
    use crate::operators::DenseOp;
    use crate::util::Rng;

    fn toy(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..n)
            .map(|i| {
                xs.row(i).iter().map(|&x| (2.0 * x).sin()).sum::<f64>()
                    + 0.05 * rng.normal()
            })
            .collect();
        (xs, ys)
    }

    #[test]
    fn mean_cache_matches_exact_gp_2d() {
        let (xs, ys) = toy(200, 2, 1);
        let h = GpHypers::new(0.7, 1.0, 0.05);
        let mut gp = ExactGp::new(xs.clone(), ys, h);
        gp.refresh().unwrap();
        let alpha = gp.alpha().unwrap().to_vec();
        let grid = RectilinearGrid::fit_uniform(&xs, 64).unwrap();
        let cache = PredictCache::build(&xs, &alpha, &h, &grid, None).unwrap();
        let mut rng = Rng::new(2);
        let xt = Matrix::from_fn(40, 2, |_, _| rng.uniform_in(-0.9, 0.9));
        let want = gp.predict_mean(&xt);
        let got = cache.predict_mean(&xt);
        // Off-grid queries inherit the SKI interpolation error amplified
        // by ‖α‖₁; the tight (1e-6) algebra check lives in the on-grid
        // round-trip integration test.
        let err = crate::util::mae(&got, &want);
        assert!(err < 2e-2, "stencil mean vs dense mean: mae {err}");
        assert!(!cache.has_variance());
    }

    #[test]
    fn sparse_grid_cache_matches_exact_gp_2d() {
        let (xs, ys) = toy(180, 2, 2);
        let h = GpHypers::new(0.8, 1.0, 0.05);
        let mut gp = ExactGp::new(xs.clone(), ys, h);
        gp.refresh().unwrap();
        let alpha = gp.alpha().unwrap().to_vec();
        let s = inverse_root_exact(gp.cholesky().unwrap());
        let grid = SparseGrid::fit(&xs, 6).unwrap();
        let cache = PredictCache::build(&xs, &alpha, &h, &grid, Some(&s)).unwrap();
        assert!(cache.terms().len() > 1, "sparse cache should be multi-term");
        let mut rng = Rng::new(3);
        let xt = Matrix::from_fn(40, 2, |_, _| rng.uniform_in(-0.9, 0.9));
        let want_mean = gp.predict_mean(&xt);
        let got_mean = cache.predict_mean(&xt);
        let merr = crate::util::mae(&got_mean, &want_mean);
        assert!(merr < 2e-2, "sparse stencil mean: mae {merr}");
        let want_var = gp.predict_var(&xt);
        let got_var = cache.predict_var(&xt);
        let verr = crate::util::mae(&got_var, &want_var);
        assert!(verr < 2e-2, "sparse stencil var: mae {verr}");
    }

    #[test]
    fn variance_cache_matches_exact_gp_2d() {
        let (xs, ys) = toy(150, 2, 3);
        let h = GpHypers::new(0.7, 1.2, 0.05);
        let mut gp = ExactGp::new(xs.clone(), ys, h);
        gp.refresh().unwrap();
        let alpha = gp.alpha().unwrap().to_vec();
        let s = inverse_root_exact(gp.cholesky().unwrap());
        let grid = RectilinearGrid::fit_uniform(&xs, 64).unwrap();
        let cache = PredictCache::build(&xs, &alpha, &h, &grid, Some(&s)).unwrap();
        assert_eq!(cache.var_rank(), 150);
        let mut rng = Rng::new(4);
        let xt = Matrix::from_fn(30, 2, |_, _| rng.uniform_in(-0.9, 0.9));
        let want = gp.predict_var(&xt);
        let got = cache.predict_var(&xt);
        let err = crate::util::mae(&got, &want);
        assert!(err < 5e-2, "stencil var vs dense var: mae {err}");
        // Variance is bounded by the prior.
        for v in &got {
            assert!(*v > 0.0 && *v <= cache.prior_var + 1e-9);
        }
    }

    #[test]
    fn lanczos_root_approximates_inverse() {
        let mut rng = Rng::new(5);
        let b = Matrix::from_fn(40, 40, |_, _| rng.normal());
        let mut a = b.matmul_t(&b);
        a.add_diag(40.0 * 0.1);
        let op = DenseOp(a.clone());
        let probe = rng.normal_vec(40);
        // Full-rank Lanczos reproduces the inverse.
        let s = inverse_root_lanczos(&op, &probe, 40).unwrap();
        let approx = s.matmul_t(&s); // S Sᵀ
        let kinv = Cholesky::new(&a).unwrap().inverse();
        assert!(
            approx.max_abs_diff(&kinv) < 1e-6,
            "S Sᵀ vs K⁻¹: {}",
            approx.max_abs_diff(&kinv)
        );
    }

    #[test]
    fn batched_predictions_bitwise_equal_one_at_a_time() {
        let (xs, ys) = toy(80, 2, 6);
        let h = GpHypers::new(0.8, 1.0, 0.1);
        let mut gp = ExactGp::new(xs.clone(), ys, h);
        gp.refresh().unwrap();
        let alpha = gp.alpha().unwrap().to_vec();
        let s = inverse_root_exact(gp.cholesky().unwrap());
        let grid = RectilinearGrid::fit_uniform(&xs, 32).unwrap();
        let cache = PredictCache::build(&xs, &alpha, &h, &grid, Some(&s)).unwrap();
        let mut rng = Rng::new(7);
        let xt = Matrix::from_fn(300, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let (means, vars) = cache.predict(&xt);
        for i in 0..xt.rows {
            assert_eq!(means[i], cache.predict_mean_one(xt.row(i)), "mean row {i}");
            assert_eq!(vars[i], cache.predict_var_one(xt.row(i)), "var row {i}");
        }
    }

    #[test]
    fn far_field_query_returns_prior() {
        let (xs, ys) = toy(60, 2, 8);
        let h = GpHypers::new(0.5, 1.0, 0.05);
        let kern = ProductKernel::rbf(2, h.ell(), h.sf2());
        let mut khat = kern.gram_sym(&xs);
        khat.add_diag(h.sn2());
        let chol = Cholesky::new(&khat).unwrap();
        let alpha = chol.solve(&ys);
        let s = inverse_root_exact(&chol);
        let grid = RectilinearGrid::fit_uniform(&xs, 32).unwrap();
        let cache = PredictCache::build(&xs, &alpha, &h, &grid, Some(&s)).unwrap();
        // Far outside the grid every stencil weight underflows to zero →
        // mean 0 (the prior mean) and variance k** (the prior variance),
        // exactly like the dense far-field limit.
        let far = Matrix::from_vec(1, 2, vec![500.0, -500.0]);
        assert_eq!(cache.predict_mean(&far)[0], 0.0);
        assert!((cache.predict_var(&far)[0] - cache.prior_var).abs() < 1e-12);
    }

    #[test]
    fn from_parts_validates_sizes() {
        let axes = vec![Grid1d::fit(0.0, 1.0, 8).unwrap()];
        let err = TermCache::new(1.0, axes.clone(), vec![0.0; 7], Matrix::zeros(8, 0))
            .unwrap_err();
        assert!(err.to_string().contains("mean buffer"), "{err}");
        let t1 = TermCache::new(1.0, axes.clone(), vec![0.0; 8], Matrix::zeros(8, 2))
            .unwrap();
        let t2 =
            TermCache::new(-1.0, axes, vec![0.0; 8], Matrix::zeros(8, 3)).unwrap();
        let err = PredictCache::from_parts(
            GridSpec::Rectilinear(vec![8]),
            vec![t1, t2],
            1.0,
            0.1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("rank"), "{err}");
    }
}
