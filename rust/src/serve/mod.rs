//! Layer-3 online prediction serving: snapshots, O(1)-per-point
//! predictive caches, request batching, and a TCP front-end.
//!
//! Training (the paper's subject) reduces GP inference to fast MVMs;
//! serving reduces *prediction* to almost nothing. Once a model is
//! trained, every training-data-sized quantity is pushed onto the
//! inducing grid at snapshot time, after which a query point touches the
//! model only through its 4ᵈ-sparse interpolation stencil:
//!
//! - [`cache`] — the grid-side mean cache `σ_f²(⊗K)(Wᵀα)` (one sparse
//!   stencil dot per mean) and the low-rank variance factor `R` with
//!   `σ²(x*) = k** − ‖Rᵀ w(x*)‖²` (one rank-r gemv per variance);
//! - [`snapshot`] — a versioned, zero-dependency binary format that
//!   persists hypers, grid spec, `α`, and both caches, and reloads them
//!   without touching training data;
//! - [`batcher`] — coalesces concurrent requests (predictions *and*
//!   observations, see [`crate::stream`]) into blocks with configurable
//!   max-batch/max-wait and per-request latency accounting;
//! - [`protocol`] — the typed wire protocol (`Request`/`Response` plus
//!   the one parser and formatter, including the D-SKI `grad` clause)
//!   shared by the TCP server, the fleet reactor, and the
//!   `skip-gp observe` CLI client — see `docs/PROTOCOL.md`;
//! - [`server`] — the in-process [`ServeEngine`] (frozen snapshot or
//!   live incremental model) and a `std::net` TCP line-protocol server
//!   behind `skip-gp serve` / `skip-gp serve --live`;
//! - [`fleet`] — the sharded multi-model serving plane behind
//!   `skip-gp serve --fleet`: a model registry with LRU eviction, a
//!   local-expert shard router, and a bounded-worker reactor with
//!   admission control and graceful drain.
//!
//! ```
//! use skip_gp::gp::{ExactGp, GpHypers};
//! use skip_gp::grid::GridSpec;
//! use skip_gp::linalg::Matrix;
//! use skip_gp::serve::{ModelSnapshot, SnapshotConfig, VarianceMode};
//!
//! // Train a small exact GP…
//! let xs = Matrix::from_fn(30, 1, |i, _| i as f64 / 10.0);
//! let ys: Vec<f64> = (0..30).map(|i| (i as f64 / 5.0).sin()).collect();
//! let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.5, 1.0, 0.01));
//! gp.refresh().unwrap();
//!
//! // …freeze it into a snapshot and predict from the cache alone.
//! let cfg = SnapshotConfig {
//!     grid: Some(GridSpec::uniform(32)),
//!     variance: VarianceMode::Exact,
//!     ..Default::default()
//! };
//! let snap = ModelSnapshot::from_exact(&gp, &cfg).unwrap();
//! let bytes = snap.to_bytes();
//! let back = ModelSnapshot::from_bytes(&bytes).unwrap();
//! let q = Matrix::from_vec(1, 1, vec![1.25]);
//! assert_eq!(back.cache.predict_mean(&q), snap.cache.predict_mean(&q));
//! ```

pub mod batcher;
pub mod cache;
pub mod fleet;
pub mod protocol;
pub mod server;
pub mod snapshot;

pub use batcher::{
    BatchHandle, BatcherConfig, ObserveResponse, PredictResponse, RequestBatcher,
};
pub use fleet::{
    FleetConfig, FleetServer, ModelRegistry, RegistryConfig, RoutePolicy, ShardedModel,
};
pub use cache::{build_task_cache, PredictCache, TermCache, VarianceMode};
pub use protocol::{
    ModelShape, ObserveRequest, PredictRequest, Request, Response, Verb,
};
pub use server::{ObserveAck, ServeEngine, Server, ServerConfig};
pub use snapshot::{
    ModelSnapshot, SnapshotConfig, SnapshotVariant, TaskHead, SNAPSHOT_MIN_VERSION,
    SNAPSHOT_VERSION,
};
