//! The typed wire protocol: **one** parser and **one** formatter for
//! every front-end.
//!
//! Four entrypoints speak the TCP line protocol — the legacy thread-per-
//! connection [`Server`](super::server::Server), the bounded fleet
//! reactor ([`crate::serve::fleet::reactor`]), the request batcher's
//! in-process callers, and the `skip-gp observe` CLI client. Before this
//! module each of them re-implemented the grammar with its own
//! `strip_prefix`/`format!` calls, so verbs and error wordings could
//! drift between front-ends. Now the grammar lives here once: requests
//! parse into a typed [`Request`], responses format from a typed
//! [`Response`], and both front-ends are byte-for-byte identical by
//! construction (a property test pins this).
//!
//! # Grammar
//!
//! One request per line, whitespace-separated tokens; one response line
//! per request. See `docs/PROTOCOL.md` for the human-oriented version.
//!
//! ```text
//! request  = [ "model" <id> ] verb
//! verb     = "quit" | "ping" | "dim" | "tasks" | "stats" | "models"
//!          | [ "predict" ] [ <task> ] <x1> … <xd>
//!          | "observe"    [ <task> ] <x1> … <xd> <y> [ "grad" <g1> … <gd> ]
//! ```
//!
//! - The `model <id>` prefix and the `models` verb exist only on the
//!   fleet front-end ([`split_model_prefix`], [`classify`] with
//!   `models_verb = true`); on the legacy server `models` falls through
//!   to the predict parse and errors, exactly as it always has.
//! - The `<task>` token is present iff the model is multi-task
//!   ([`ModelShape::multitask`]); `observe` additionally admits
//!   `task == num_tasks` (online enrollment).
//! - The `grad` clause (D-SKI) attaches the observed gradient ∇y to the
//!   observation; gradient observations are single-task only, because
//!   the multi-task Hadamard operator has no extended derivative-row
//!   form (see [`crate::stream`]).
//!
//! Responses (`Response::format`):
//!
//! ```text
//! ok pong                                       (ping)
//! ok <d>                                        (dim / tasks)
//! ok <stats line>                               (stats)
//! ok [<id> <id> …]                              (models)
//! ok <mean> <var> <latency_us> <batch>          (predict)
//! ok <seq> <n> <pending> <latency_us> <batch>   (observe)
//! ok dup <n> <pending> <latency_us> <batch>     (duplicate observe)
//! err <message>
//! busy <limit> requests in flight, retry later
//! ```
//!
//! Floats are printed with Rust's shortest-round-trip formatting, so
//! [`format_request`] → [`parse_request`] reproduces every payload
//! bitwise (the round-trip property test in `rust/tests/protocol_props.rs`).

use super::batcher::{ObserveResponse, PredictResponse};

/// What the parser needs to know about the model a request addresses:
/// input dimensionality, task count, and whether the wire form is
/// task-led. Build it per request — online enrollment grows
/// `num_tasks` mid-serve.
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    /// Input dimensionality d.
    pub dim: usize,
    /// Tasks the model serves (1 for single-task models).
    pub num_tasks: usize,
    /// True iff requests must lead with a task id.
    pub multitask: bool,
}

impl ModelShape {
    /// The shape of a plain single-task model.
    pub fn single(dim: usize) -> Self {
        ModelShape { dim, num_tasks: 1, multitask: false }
    }
}

/// A parsed `predict` request.
#[derive(Clone, Debug, PartialEq)]
pub struct PredictRequest {
    /// Task the query addresses (0 for single-task models).
    pub task: usize,
    pub x: Vec<f64>,
}

/// A parsed `observe` request.
#[derive(Clone, Debug, PartialEq)]
pub struct ObserveRequest {
    /// Task the observation belongs to (0 for single-task models; on a
    /// multi-task model `task == num_tasks` enrolls a new task).
    pub task: usize,
    pub x: Vec<f64>,
    pub y: f64,
    /// The D-SKI gradient payload of an `observe … grad …` request.
    pub grad: Option<Vec<f64>>,
}

/// One fully-parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Quit,
    Ping,
    Dim,
    Tasks,
    Stats,
    /// Fleet-only: list resident model ids.
    Models,
    Predict(PredictRequest),
    Observe(ObserveRequest),
}

/// One response line, formatted by [`Response::format`]. Predict and
/// observe responses wrap the batcher's accounting structs so the
/// latency/batch fields print identically everywhere.
#[derive(Clone, Debug)]
pub enum Response {
    Pong,
    Dim(usize),
    Tasks(usize),
    Stats(String),
    Models(Vec<String>),
    Predict(PredictResponse),
    Observe(ObserveResponse),
    Error(String),
    /// Fleet admission control: the request was not admitted.
    Busy { limit: usize },
}

impl Response {
    /// The wire line (no trailing newline).
    pub fn format(&self) -> String {
        match self {
            Response::Pong => "ok pong".to_string(),
            Response::Dim(d) => format!("ok {d}"),
            Response::Tasks(t) => format!("ok {t}"),
            Response::Stats(s) => format!("ok {s}"),
            Response::Models(ids) => {
                if ids.is_empty() {
                    "ok".to_string()
                } else {
                    format!("ok {}", ids.join(" "))
                }
            }
            Response::Predict(r) => format!(
                "ok {} {} {:.1} {}",
                r.mean,
                r.var,
                r.latency.as_secs_f64() * 1e6,
                r.batch_size
            ),
            Response::Observe(r) => match &r.result {
                Err(msg) => format!("err {msg}"),
                Ok(ack) if ack.duplicate => format!(
                    "ok dup {} {} {:.1} {}",
                    ack.n,
                    ack.pending,
                    r.latency.as_secs_f64() * 1e6,
                    r.batch_size
                ),
                Ok(ack) => format!(
                    "ok {} {} {} {:.1} {}",
                    ack.seq,
                    ack.n,
                    ack.pending,
                    r.latency.as_secs_f64() * 1e6,
                    r.batch_size
                ),
            },
            Response::Error(msg) => format!("err {msg}"),
            Response::Busy { limit } => {
                format!("busy {limit} requests in flight, retry later")
            }
        }
    }
}

/// Context-free verb classification — the piece of parsing that needs no
/// model. Front-ends that resolve a model per request (the fleet) run
/// this first, resolve, then hand the body to [`parse_predict`] /
/// [`parse_observe`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verb<'a> {
    /// Blank line — ignore.
    Empty,
    Quit,
    Ping,
    Dim,
    Tasks,
    Stats,
    Models,
    /// `observe …` with the body after the verb.
    Observe(&'a str),
    /// Everything else: the body after an *optional* `predict` verb
    /// (a bare `x1 … xd` line predicts, as it always has).
    Predict(&'a str),
}

/// Classify a request line. `models_verb` enables the fleet-only
/// `models` verb; without it the token falls through to the predict
/// parse and errors exactly as the legacy server always did.
pub fn classify(line: &str, models_verb: bool) -> Verb<'_> {
    let trimmed = line.trim();
    if trimmed.is_empty() {
        return Verb::Empty;
    }
    match trimmed {
        "quit" => Verb::Quit,
        "ping" => Verb::Ping,
        "dim" => Verb::Dim,
        "tasks" => Verb::Tasks,
        "stats" => Verb::Stats,
        "models" if models_verb => Verb::Models,
        _ => {
            if let Some(body) = trimmed.strip_prefix("observe") {
                Verb::Observe(body)
            } else {
                Verb::Predict(trimmed.strip_prefix("predict").unwrap_or(trimmed))
            }
        }
    }
}

/// Split the fleet's optional `model <id>` prefix off a request line,
/// returning `(explicit_model, rest)`. `Err` carries the wire error
/// line.
pub fn split_model_prefix(line: &str) -> Result<(Option<&str>, &str), String> {
    let trimmed = line.trim();
    match trimmed.strip_prefix("model ") {
        Some(body) => {
            let body = body.trim_start();
            match body.split_once(|ch: char| ch.is_whitespace()) {
                Some((id, tail)) => Ok((Some(id), tail.trim_start())),
                None => Err("usage: model <id> <verb> …".to_string()),
            }
        }
        None => Ok((None, trimmed)),
    }
}

/// Parse `expect` whitespace-separated floats from `body`; `Err` carries
/// the wire-protocol error line.
pub fn parse_floats(body: &str, expect: usize) -> Result<Vec<f64>, String> {
    let mut out = Vec::with_capacity(expect);
    for tok in body.split_whitespace() {
        match tok.parse::<f64>() {
            Ok(v) => out.push(v),
            Err(_) => return Err(format!("not a number: '{tok}'")),
        }
    }
    if out.len() != expect {
        return Err(format!("expected {expect} numbers, got {}", out.len()));
    }
    Ok(out)
}

/// Split the leading task id off a multi-task request body, returning
/// `(task, rest)`. `observe` selects the observe wire form, which also
/// admits `task == num_tasks` (online enrollment); predictions require
/// `task < num_tasks`. `Err` carries the wire-protocol error line.
pub fn parse_task(
    body: &str,
    num_tasks: usize,
    dim: usize,
    observe: bool,
) -> Result<(usize, &str), String> {
    let body = body.trim_start();
    let (tok, rest) = match body.split_once(|ch: char| ch.is_whitespace()) {
        Some((tok, rest)) => (tok, rest),
        None => (body, ""),
    };
    let Ok(task) = tok.parse::<usize>() else {
        let form = if observe {
            format!("observe <task> x1 … x{dim} y")
        } else {
            format!("predict <task> x1 … x{dim}")
        };
        return Err(format!(
            "this model is multi-task — requests must lead with a task id: {form}"
        ));
    };
    let limit = if observe { num_tasks + 1 } else { num_tasks };
    if task >= limit {
        return Err(if observe {
            format!(
                "task {task} out of range (model has {num_tasks} tasks; \
                 task {num_tasks} would enroll a new one)"
            )
        } else {
            format!("task {task} out of range (model has {num_tasks} tasks)")
        });
    }
    Ok((task, rest))
}

/// Split an observe body at the literal `grad` token: everything before
/// is the `(x, y)` payload, everything after is the gradient clause.
/// Token-aware, so a float like `7` in `0.7` can never false-match.
fn split_grad(body: &str) -> (&str, Option<&str>) {
    let mut token_start: Option<usize> = None;
    for (i, ch) in body.char_indices() {
        if ch.is_whitespace() {
            if let Some(s) = token_start.take() {
                if &body[s..i] == "grad" {
                    return (&body[..s], Some(&body[i..]));
                }
            }
        } else if token_start.is_none() {
            token_start = Some(i);
        }
    }
    if let Some(s) = token_start {
        if &body[s..] == "grad" {
            return (&body[..s], Some(""));
        }
    }
    (body, None)
}

/// Parse a predict body (everything after the optional `predict` verb).
pub fn parse_predict(body: &str, shape: &ModelShape) -> Result<PredictRequest, String> {
    let (task, body) = if shape.multitask {
        parse_task(body, shape.num_tasks, shape.dim, false)?
    } else {
        (0, body)
    };
    let x = parse_floats(body, shape.dim)?;
    Ok(PredictRequest { task, x })
}

/// Parse an observe body (everything after the `observe` verb),
/// including the optional D-SKI `grad g1 … gd` clause. Non-finite
/// values are rejected here, per request — inside a coalesced ingest
/// they would fail the whole block, punishing well-behaved clients.
pub fn parse_observe(body: &str, shape: &ModelShape) -> Result<ObserveRequest, String> {
    let (task, body) = if shape.multitask {
        parse_task(body, shape.num_tasks, shape.dim, true)?
    } else {
        (0, body)
    };
    let d = shape.dim;
    let (vals_part, grad_part) = split_grad(body);
    let vals = parse_floats(vals_part, d + 1)?;
    let grad = match grad_part {
        None => None,
        Some(g) => {
            if shape.multitask {
                return Err(
                    "gradient observations are single-task only — the \
                     multi-task Hadamard operator (K_ski ∘ K_task) has no \
                     extended derivative-row form"
                        .to_string(),
                );
            }
            Some(parse_floats(g, d)?)
        }
    };
    if vals.iter().any(|v| !v.is_finite()) {
        return Err("non-finite observation".to_string());
    }
    if grad.iter().flatten().any(|v| !v.is_finite()) {
        return Err("non-finite gradient observation".to_string());
    }
    Ok(ObserveRequest {
        task,
        x: vals[..d].to_vec(),
        y: vals[d],
        grad,
    })
}

/// Parse a whole request line against one model's shape — the
/// single-model front-ends' entrypoint (the fleet interleaves
/// [`classify`] with model resolution instead). `Ok(None)` is a blank
/// line; `Err` carries the wire error line.
pub fn parse_request(
    line: &str,
    shape: &ModelShape,
    models_verb: bool,
) -> Result<Option<Request>, String> {
    Ok(Some(match classify(line, models_verb) {
        Verb::Empty => return Ok(None),
        Verb::Quit => Request::Quit,
        Verb::Ping => Request::Ping,
        Verb::Dim => Request::Dim,
        Verb::Tasks => Request::Tasks,
        Verb::Stats => Request::Stats,
        Verb::Models => Request::Models,
        Verb::Observe(body) => Request::Observe(parse_observe(body, shape)?),
        Verb::Predict(body) => Request::Predict(parse_predict(body, shape)?),
    }))
}

/// Format a request back into its wire line. `multitask` selects the
/// task-led form (the task id is omitted for single-task models, whose
/// parse fixes it at 0). Inverse of [`parse_request`] bitwise: floats
/// print with shortest-round-trip formatting.
pub fn format_request(req: &Request, multitask: bool) -> String {
    use std::fmt::Write as _;
    match req {
        Request::Quit => "quit".to_string(),
        Request::Ping => "ping".to_string(),
        Request::Dim => "dim".to_string(),
        Request::Tasks => "tasks".to_string(),
        Request::Stats => "stats".to_string(),
        Request::Models => "models".to_string(),
        Request::Predict(p) => {
            let mut s = "predict".to_string();
            if multitask {
                let _ = write!(s, " {}", p.task);
            }
            for v in &p.x {
                let _ = write!(s, " {v}");
            }
            s
        }
        Request::Observe(o) => {
            let mut s = "observe".to_string();
            if multitask {
                let _ = write!(s, " {}", o.task);
            }
            for v in &o.x {
                let _ = write!(s, " {v}");
            }
            let _ = write!(s, " {}", o.y);
            if let Some(g) = &o.grad {
                s.push_str(" grad");
                for v in g {
                    let _ = write!(s, " {v}");
                }
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape2() -> ModelShape {
        ModelShape::single(2)
    }

    #[test]
    fn classify_matches_legacy_verbs() {
        assert_eq!(classify("  ", false), Verb::Empty);
        assert_eq!(classify("quit", false), Verb::Quit);
        assert_eq!(classify("ping", false), Verb::Ping);
        assert_eq!(classify("stats", true), Verb::Stats);
        assert_eq!(classify("models", true), Verb::Models);
        // Without the fleet verb set, `models` is a (doomed) predict.
        assert_eq!(classify("models", false), Verb::Predict("models"));
        assert_eq!(classify("observe 1 2 3", false), Verb::Observe(" 1 2 3"));
        assert_eq!(classify("predict 1 2", false), Verb::Predict(" 1 2"));
        // The bare form predicts, as it always has.
        assert_eq!(classify("1 2", false), Verb::Predict("1 2"));
    }

    #[test]
    fn model_prefix_splits_and_errors_like_the_reactor() {
        assert_eq!(split_model_prefix("predict 1 2"), Ok((None, "predict 1 2")));
        assert_eq!(
            split_model_prefix("model abc predict 1 2"),
            Ok((Some("abc"), "predict 1 2"))
        );
        assert_eq!(
            split_model_prefix("model abc"),
            Err("usage: model <id> <verb> …".to_string())
        );
    }

    #[test]
    fn parse_errors_are_bitwise_legacy() {
        let s = shape2();
        assert_eq!(
            parse_predict("1 two", &s).unwrap_err(),
            "not a number: 'two'"
        );
        assert_eq!(
            parse_predict("1 2 3", &s).unwrap_err(),
            "expected 2 numbers, got 3"
        );
        assert_eq!(
            parse_observe(" 1 2", &s).unwrap_err(),
            "expected 3 numbers, got 2"
        );
        assert_eq!(
            parse_observe(" 1 2 nan", &s).unwrap_err(),
            "non-finite observation"
        );
        let mt = ModelShape { dim: 2, num_tasks: 3, multitask: true };
        assert_eq!(
            parse_predict("x 1 2", &mt).unwrap_err(),
            "this model is multi-task — requests must lead with a task id: \
             predict <task> x1 … x2"
        );
        assert_eq!(
            parse_predict("3 1 2", &mt).unwrap_err(),
            "task 3 out of range (model has 3 tasks)"
        );
        assert_eq!(
            parse_observe(" 4 1 2 0.5", &mt).unwrap_err(),
            "task 4 out of range (model has 3 tasks; task 3 would enroll a new one)"
        );
        // Enrollment (task == num_tasks) is admitted for observe.
        assert!(parse_observe(" 3 1 2 0.5", &mt).is_ok());
    }

    #[test]
    fn grad_clause_parses_and_validates() {
        let s = shape2();
        let o = parse_observe(" 0.5 -0.25 1.5 grad 2.0 -3.0", &s).unwrap();
        assert_eq!(o.x, vec![0.5, -0.25]);
        assert_eq!(o.y, 1.5);
        assert_eq!(o.grad, Some(vec![2.0, -3.0]));
        // Wrong gradient arity / non-finite gradients are typed errors.
        assert_eq!(
            parse_observe(" 0.5 -0.25 1.5 grad 2.0", &s).unwrap_err(),
            "expected 2 numbers, got 1"
        );
        assert_eq!(
            parse_observe(" 0.5 -0.25 1.5 grad inf 0", &s).unwrap_err(),
            "non-finite gradient observation"
        );
        // A trailing bare `grad` is an empty clause, not a float error.
        assert_eq!(
            parse_observe(" 0.5 -0.25 1.5 grad", &s).unwrap_err(),
            "expected 2 numbers, got 0"
        );
        // Multi-task models have no extended derivative-row form.
        let mt = ModelShape { dim: 2, num_tasks: 2, multitask: true };
        let err = parse_observe(" 0 0.5 -0.25 1.5 grad 1 2", &mt).unwrap_err();
        assert!(err.contains("single-task only"), "{err}");
    }

    #[test]
    fn requests_round_trip_through_format() {
        let s = shape2();
        let reqs = [
            Request::Ping,
            Request::Predict(PredictRequest { task: 0, x: vec![0.1, -2.5e-3] }),
            Request::Observe(ObserveRequest {
                task: 0,
                x: vec![1.0 / 3.0, -0.0],
                y: f64::MIN_POSITIVE,
                grad: Some(vec![std::f64::consts::PI, -1e300]),
            }),
        ];
        for req in &reqs {
            let line = format_request(req, false);
            let back = parse_request(&line, &s, false).unwrap().unwrap();
            assert_eq!(&back, req, "line: {line}");
        }
        let mt = ModelShape { dim: 1, num_tasks: 4, multitask: true };
        let req = Request::Observe(ObserveRequest {
            task: 4, // enrollment
            x: vec![0.25],
            y: -1.75,
            grad: None,
        });
        let line = format_request(&req, true);
        assert_eq!(line, "observe 4 0.25 -1.75");
        assert_eq!(parse_request(&line, &mt, false).unwrap().unwrap(), req);
    }
}
